//! Multi-tenant serving economics at the nano preset — the `multi_tenant`
//! section of `BENCH_native.json` (asserted by CI bench-smoke).
//!
//! Two timed rows per pool size N in {1, 4, 16}: end-to-end steps/sec
//! through the shared-base [`conmezo::serve::Server`] (tenant admission +
//! round-robin to completion over ONE base buffer and ONE session per
//! `(preset, rank)`), and the same N tenant workloads run as independent
//! full-weight trainers — each owning d_pad parameters, its own bound
//! sessions, and d_pad-sized optimizer state. Memory rows record the
//! per-tenant marginal bytes from the server's `MemoryMeter` ledger vs the
//! full-weight params+optimizer footprint; `items_per_iter` carries the
//! byte count so the JSON stays machine-comparable.
//!
//! `cargo bench --bench multi_tenant [-- --quick]`

use std::time::Instant;

use conmezo::bench::{consume, write_bench_json, write_results, BenchArgs, BenchResult};
use conmezo::data::{spec, TaskGen, TrainSampler};
use conmezo::objective::{ModelObjective, Objective};
use conmezo::optimizer::{by_name, BetaSchedule, ZoOptimizer};
use conmezo::runtime::{lit_vec_f32, Arg, ParallelPolicy, Runtime};
use conmezo::serve::{ServeConfig, Server};
use conmezo::util::memory::MemoryMeter;

/// The full-weight baseline uses the serve manifest's default
/// hyperparameters (conmezo, eta 1e-2, lam 1e-3, theta 1.35, beta 0.9) so
/// the two paths do the same optimizer math per step.
fn full_weight_opt(d: usize) -> conmezo::util::error::Result<Box<dyn ZoOptimizer>> {
    by_name("conmezo", d, 1e-2, 1e-3, 1.35, BetaSchedule::Constant(0.9), &[])
}

/// A single-sample memory record: `items_per_iter` is the byte count, the
/// time fields hold the (one-shot) setup wall-clock that produced it.
fn mem_result(name: String, secs: f64, bytes: usize) -> BenchResult {
    BenchResult {
        name,
        samples: 1,
        mean_s: secs,
        std_s: 0.0,
        p50_s: secs,
        p99_s: secs,
        items_per_iter: Some(bytes as f64),
    }
}

fn main() -> conmezo::util::error::Result<()> {
    let args = BenchArgs::parse();
    let b = args.bencher();
    let rt = Runtime::native_with(ParallelPolicy::auto());
    let meta = rt.preset("nano")?.clone();
    let steps = if args.quick { 2 } else { 8 };
    let ckpt_dir = std::env::temp_dir().join(format!("conmezo_bench_serve_{}", std::process::id()));
    std::fs::create_dir_all(&ckpt_dir)?;
    let init = rt.load_kind("nano", "init")?;
    let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
    let mut results = Vec::new();
    let mut adapter_marginal = 0usize;

    for &n in &[1usize, 4, 16] {
        // manifest defaults: preset=nano rank=4 opt=conmezo — the adapter
        // twin of the full-weight baseline below
        let mut mani = String::from("quantum 1\nbase_seed 7\n");
        for i in 0..n {
            let line = format!("tenant name=j{i} steps={steps} seed={} train_n=16\n", 100 + i);
            mani.push_str(&line);
        }
        let cfg = ServeConfig::parse(&mani)?;
        let units = (n * steps) as f64;

        // steps/sec through the scheduler; admission (base load + session
        // bind + job build) is part of each sample, as it is when serving
        let name = format!("multi_tenant/nano/serve_n{n}_steps");
        let r = b.run_items(&name, Some(units), &mut || {
            let mut server = Server::new(&rt, cfg.clone(), ckpt_dir.clone()).unwrap();
            let report = server.run().unwrap();
            assert_eq!(report.jobs.len(), n);
        });
        println!("{}", r.report());
        results.push(r);

        // per-tenant marginal bytes from the server's own ledger
        let t0 = Instant::now();
        let server = Server::new(&rt, cfg.clone(), ckpt_dir.clone())?;
        let admit_s = t0.elapsed().as_secs_f64();
        let tenant_bytes: usize = server
            .meter()
            .breakdown()
            .iter()
            .filter(|(k, _)| k.starts_with("tenant."))
            .map(|(_, v)| *v)
            .sum();
        adapter_marginal = tenant_bytes / n;
        let r = mem_result(
            format!("multi_tenant/nano/serve_n{n}_marginal_bytes_per_tenant"),
            admit_s,
            adapter_marginal,
        );
        println!("{}", r.report());
        results.push(r);

        // N independent full-weight trainers over the same tasks/seeds:
        // every tenant binds its own sessions and steps a d_pad vector
        let name = format!("multi_tenant/nano/full_weight_n{n}_steps");
        let r = b.run_items(&name, Some(units), &mut || {
            for i in 0..n {
                let seed = 100 + i as u64;
                let data = gen.dataset(16, seed);
                let sampler = TrainSampler::new(data, meta.batch, meta.seq_len, seed, 0);
                let mut obj = ModelObjective::new(&rt, "nano", Box::new(sampler)).unwrap();
                let flat = init.call(&[Arg::I32(seed as i32)]).unwrap();
                let mut x = lit_vec_f32(&flat[0]).unwrap();
                let mut opt = full_weight_opt(meta.d_pad).unwrap();
                for t in 0..steps {
                    opt.step(&mut x, &mut obj, t, seed).unwrap();
                    obj.advance();
                }
                consume(x[0]);
            }
        });
        println!("{}", r.report());
        results.push(r);
    }

    // the full-weight tenant's persistent marginal (params + optimizer
    // state), constant in N — the denominator of the serving win
    let t0 = Instant::now();
    let mut m = MemoryMeter::new();
    m.alloc_f32("params", meta.d_pad);
    full_weight_opt(meta.d_pad)?.record_memory(&mut m);
    let full_bytes = m.current_bytes();
    let r = mem_result(
        "multi_tenant/nano/full_weight_marginal_bytes_per_tenant".to_string(),
        t0.elapsed().as_secs_f64(),
        full_bytes,
    );
    println!("{}", r.report());
    results.push(r);

    println!(
        "nano marginals: adapter tenant {:.1} KiB vs full-weight trainer {:.1} KiB ({:.1}x)",
        adapter_marginal as f64 / 1024.0,
        full_bytes as f64 / 1024.0,
        full_bytes as f64 / adapter_marginal.max(1) as f64
    );

    write_results("multi_tenant.jsonl", &results)?;
    write_bench_json("multi_tenant", &results)?;
    Ok(())
}
