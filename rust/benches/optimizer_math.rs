//! L3 host-kernel benchmarks: the vecmath flat-buffer ops against their
//! memory-bandwidth roofline, the naive-vs-blocked-vs-threaded GEMM
//! matrix (the `optimizer_math` section of `BENCH_native.json`), plus full
//! composed-mode optimizer steps on the native quadratic.
//! `cargo bench --bench optimizer_math [-- --quick]`.

use conmezo::bench::{consume, write_bench_json, write_results, BenchArgs};
use conmezo::objective::NativeQuadratic;
use conmezo::optimizer::{self, BetaSchedule, ZoOptimizer};
use conmezo::parallel::WorkerPool;
use conmezo::runtime::ParallelPolicy;
use conmezo::util::rng::Xoshiro256pp;
use conmezo::vecmath;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0f32; n];
    r.fill_normal_f32(&mut v);
    v
}

fn main() -> conmezo::util::error::Result<()> {
    conmezo::runtime::enable_flush_to_zero();
    let args = BenchArgs::parse();
    let b = args.bencher();
    let mut results = Vec::new();

    let dims: &[usize] = if args.quick { &[65_536] } else { &[65_536, 1 << 20, 8 << 20] };
    for &d in dims {
        let x = randv(d, 1);
        let mut y = randv(d, 2);
        let m = randv(d, 3);
        let u = randv(d, 4);
        let mut z = vec![0f32; d];
        let label = |op: &str| format!("vecmath/{op}/d={d}");

        let r = b.run_items(&label("dot"), Some(d as f64), &mut || {
            consume(vecmath::dot(&x, &y));
        });
        println!("{}", r.report());
        results.push(r);

        let r = b.run_items(&label("axpy"), Some(d as f64), &mut || {
            vecmath::axpy(1e-6, &x, &mut y);
        });
        println!("{}", r.report());
        results.push(r);

        let r = b.run_items(&label("cone_direction"), Some(d as f64), &mut || {
            vecmath::cone_direction(&m, &u, 1.35, d, &mut z);
        });
        println!("{}", r.report());
        results.push(r);

        let mut xm = x.clone();
        let mut mm = m.clone();
        let r = b.run_items(&label("zo_update_fused"), Some(d as f64), &mut || {
            vecmath::zo_update(&mut xm, &mut mm, &u, 0.5, 1e-6, 0.99);
        });
        println!("{}", r.report());
        results.push(r);

        // unfused reference: two separate passes (what the fusion saves)
        let mut x2 = x.clone();
        let mut m2 = m.clone();
        let r = b.run_items(&label("zo_update_unfused"), Some(d as f64), &mut || {
            vecmath::axpy(-1e-6 * 0.5, &u, &mut x2);
            for i in 0..d {
                m2[i] = 0.99 * m2[i] + 0.01 * 0.5 * u[i];
            }
        });
        println!("{}", r.report());
        results.push(r);

        // direction regeneration (the seed-replay cost)
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let r = b.run_items(&label("sample_normal"), Some(d as f64), &mut || {
            rng.fill_normal_f32(&mut z);
        });
        println!("{}", r.report());
        results.push(r);
    }

    // dense GEMM matrix: the pre-blocking naive saxpy loop vs the
    // register-blocked kernel vs the row-parallel threaded kernel (the
    // transformer forward/backward hot path; the 512x256x768 shape IS the
    // medium-preset QKV projection, so the threaded/blocked ratio here is
    // the medium-preset GEMM speedup recorded in BENCH_native.json)
    fn matmul_naive(a: &[f32], bm: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        for o in out.iter_mut() {
            *o = 0.0;
        }
        for i in 0..m {
            for (p, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
                let brow = &bm[p * n..(p + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
    }
    let threads = ParallelPolicy::auto().threads;
    let pool = WorkerPool::new(threads);
    for (m, k, n) in [(128usize, 64usize, 256usize), (512, 256, 768)] {
        let a = randv(m * k, 31);
        let bm = randv(k * n, 32);
        let mut out = vec![0f32; m * n];
        let items = Some((m * k * n) as f64);
        let r = b.run_items(&format!("matmul/naive/{m}x{k}x{n}"), items, &mut || {
            matmul_naive(&a, &bm, m, k, n, &mut out);
        });
        println!("{}", r.report());
        results.push(r);
        let r = b.run_items(&format!("matmul/blocked/{m}x{k}x{n}"), items, &mut || {
            vecmath::matmul(&a, &bm, m, k, n, &mut out);
        });
        println!("{}", r.report());
        results.push(r);
        if threads > 1 {
            let r = b.run_items(&format!("matmul/threaded{threads}/{m}x{k}x{n}"), items, &mut || {
                vecmath::matmul_threaded(&a, &bm, m, k, n, &mut out, &pool);
            });
            println!("{}", r.report());
            results.push(r);
        }
        let d = randv(m * n, 33);
        let mut dw = vec![0f32; k * n];
        let r = b.run_items(&format!("matmul/backward_at/{m}x{k}x{n}"), items, &mut || {
            vecmath::matmul_at(&a, &d, m, k, n, &mut dw);
        });
        println!("{}", r.report());
        results.push(r);
        if threads > 1 {
            let r = b.run_items(&format!("matmul/backward_at_threaded{threads}/{m}x{k}x{n}"), items, &mut || {
                vecmath::matmul_at_threaded(&a, &d, m, k, n, &mut dw, &pool);
            });
            println!("{}", r.report());
            results.push(r);
        }
    }

    // -----------------------------------------------------------------------
    // worker-pool dispatch: pooled vs per-call scoped spawning, and single-
    // vs multi-thread attention at the medium preset (the `parallel` section
    // of BENCH_native.json)
    // -----------------------------------------------------------------------

    // the pre-pool dispatch for reference: spawn scoped OS threads per
    // call, each running the blocked kernel on a contiguous row chunk
    fn matmul_scoped(a: &[f32], bm: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], t: usize) {
        let base = m / t;
        let extra = m % t;
        std::thread::scope(|scope| {
            let mut rest = out;
            let mut row0 = 0usize;
            for i in 0..t {
                let rows = base + usize::from(i < extra);
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(rows * n);
                rest = tail;
                let a_rows = &a[row0 * k..(row0 + rows) * k];
                scope.spawn(move || vecmath::matmul(a_rows, bm, rows, k, n, chunk));
                row0 += rows;
            }
        });
    }
    let mut par_results = Vec::new();
    if threads > 1 {
        // the medium-preset QKV projection shape: dispatch overhead is the
        // pooled-vs-scoped delta at identical math
        let (m, k, n) = (512usize, 256usize, 768usize);
        let a = randv(m * k, 61);
        let bm = randv(k * n, 62);
        let mut out = vec![0f32; m * n];
        let items = Some((m * k * n) as f64);
        let r = b.run_items(&format!("gemm_dispatch/scoped{threads}/{m}x{k}x{n}"), items, &mut || {
            matmul_scoped(&a, &bm, m, k, n, &mut out, threads);
        });
        println!("{}", r.report());
        par_results.push(r);
        let r = b.run_items(&format!("gemm_dispatch/pooled{threads}/{m}x{k}x{n}"), items, &mut || {
            vecmath::matmul_threaded(&a, &bm, m, k, n, &mut out, &pool);
        });
        println!("{}", r.report());
        par_results.push(r);
    }
    {
        // the medium-preset forward with the GEMMs pooled in BOTH runs; the
        // baseline pins attention to one participant via a single-slot
        // scratch (att_parts is capped by ws.slots), so the multi/single
        // delta isolates the threaded per-(batch, head) attention core
        // instead of re-measuring the GEMM row-parallel win
        use conmezo::runtime::model::{build_preset, FwdScratch, NativeModel};
        let meta = build_preset("medium", 512, 256, 8, 8, 64, 8);
        let (bsz, s) = (meta.batch, meta.seq_len);
        let ids: Vec<i32> = (0..bsz * s).map(|i| ((i * 13) % 509) as i32).collect();
        let tgt: Vec<i32> = (0..bsz * s).map(|i| ((i * 7) % 509) as i32).collect();
        let mut mask = vec![0f32; bsz * s];
        for i in 0..bsz {
            mask[i * s + s - 1] = 1.0;
        }
        let model = NativeModel::new(meta.clone()).with_threads(threads);
        let params = model.init_flat(1);
        let mut ws1 = FwdScratch::with_slots(&meta, 1);
        let r = b.run_items("attention/medium_loss/att_threads1", Some(1.0), &mut || {
            consume(model.loss_with(&params, &ids, &tgt, &mask, bsz, s, &mut ws1));
        });
        println!("{}", r.report());
        par_results.push(r);
        if threads > 1 {
            let mut ws = model.scratch();
            let r = b.run_items(&format!("attention/medium_loss/att_threads{threads}"), Some(1.0), &mut || {
                consume(model.loss_with(&params, &ids, &tgt, &mask, bsz, s, &mut ws));
            });
            println!("{}", r.report());
            par_results.push(r);
        }
    }

    // the native reverse pass itself (fo_sgd's per-step cost on nano)
    {
        use conmezo::runtime::{autograd, model};
        let model = model::NativeModel::new(model::build_preset("nano", 64, 32, 2, 2, 16, 4));
        let params = model.init_flat(1);
        let (bsz, s) = (model.meta.batch, model.meta.seq_len);
        let ids: Vec<i32> = (0..bsz * s).map(|i| (i % 61) as i32).collect();
        let tgt: Vec<i32> = (0..bsz * s).map(|i| ((i * 3) % 61) as i32).collect();
        let mut mask = vec![0f32; bsz * s];
        for i in 0..bsz {
            mask[i * s + s - 1] = 1.0;
        }
        let r = b.run_items("autograd/loss_and_grad/nano", Some(1.0), &mut || {
            consume(autograd::loss_and_grad(&model, &params, &ids, &tgt, &mask, bsz, s).loss);
        });
        println!("{}", r.report());
        results.push(r);
        let r = b.run_items("autograd/forward_only/nano", Some(1.0), &mut || {
            consume(model.loss(&params, &ids, &tgt, &mask, bsz, s));
        });
        println!("{}", r.report());
        results.push(r);
    }

    // full composed steps on the Fig. 3 quadratic
    let d = 1000;
    for name in ["mezo", "conmezo", "zo_adamm", "hizoo", "mezo_svrg"] {
        let mut opt: Box<dyn ZoOptimizer> = optimizer::by_name(
            name,
            d,
            1e-3,
            1e-2,
            1.35,
            BetaSchedule::Constant(0.99),
            &[(0, vec![d / 8, 8])],
        )?;
        let mut obj = NativeQuadratic::new(d);
        let mut x = randv(d, 7);
        let mut t = 0usize;
        let r = b.run_items(&format!("quad_step/{name}/d={d}"), Some(1.0), &mut || {
            opt.step(&mut x, &mut obj, t, 5).unwrap();
            t += 1;
        });
        println!("{}", r.report());
        results.push(r);
    }

    write_results("optimizer_math.jsonl", &results)?;
    write_bench_json("optimizer_math", &results)?;
    write_results("parallel.jsonl", &par_results)?;
    write_bench_json("parallel", &par_results)?;
    Ok(())
}
