//! Table 3 micro-benchmark: wall-clock per optimizer step, loop-based MeZO
//! (4 RNG regenerations, tensor-by-tensor walk) vs vectorized MeZO vs fused
//! ConMeZO. The accuracy-side version lives in `repro table3`; this target
//! isolates the stepping machinery with identical data.
//!
//! `cargo bench --bench table3_wallclock [preset]`

use conmezo::bench::{write_results, Bencher};
use conmezo::coordinator::{Mode, TrainConfig, Trainer};
use conmezo::runtime::Runtime;

fn main() -> conmezo::util::error::Result<()> {
    let rt = Runtime::open_default()?;
    let preset = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_else(|| "tiny".to_string());
    let b = Bencher::quick();
    let mut results = Vec::new();

    for (label, opt, mode) in [
        ("mezo_loop(4 rng regens)", "mezo_loop", Mode::Composed),
        ("mezo_vectorized", "mezo", Mode::Fused),
        ("conmezo_fused", "conmezo", Mode::Fused),
        ("mezo_composed", "mezo", Mode::Composed),
        ("conmezo_composed", "conmezo", Mode::Composed),
    ] {
        let mut cfg = TrainConfig::preset(&preset, "sst2", opt);
        cfg.mode = mode;
        cfg.steps = 1;
        cfg.eta = 1e-5;
        cfg.eval_every = usize::MAX / 2;
        cfg.log_every = usize::MAX / 2;
        let mut tr = Trainer::new(&rt, cfg)?;
        tr.step(0)?; // compile + warm
        let mut t = 1usize;
        let r = b.run_items(&format!("{preset}/{label}"), Some(1.0), &mut || {
            tr.step(t).unwrap();
            t += 1;
        });
        println!("{}", r.report());
        results.push(r);
    }
    write_results(&format!("table3_wallclock_{preset}.jsonl"), &results)?;
    Ok(())
}
