//! End-to-end step latency through the runtime, per preset and engine —
//! the session-API hot-path measurement backing EXPERIMENTS.md §Perf and
//! the `step_latency` section of `BENCH_native.json` at the repo root.
//!
//! Measures: loss forward through the legacy `Program::call` shim vs a
//! bound `Session` (the bind-once/run-many overhead delta), the native
//! `loss_pallas` kernel-composition ablation, fused conmezo/mezo steps,
//! the composed two-point path (the `Session::two_point` antithetic fast
//! path), and — when the thread policy allows — a threaded two_point.
//!
//! `cargo bench --bench step_latency [-- --quick] [presets...]`; `--quick`
//! runs a few iterations of everything (the CI smoke mode).

use conmezo::bench::{write_bench_json, write_results, BenchArgs};
use conmezo::coordinator::{FusedConMeZo, FusedMezo};
use conmezo::data::{spec, TaskGen, TrainSampler};
use conmezo::objective::{BatchSource, ModelObjective, Objective};
use conmezo::runtime::{lit_f32, lit_vec_f32, Arg, ParallelPolicy, Runtime, Session};

fn main() -> conmezo::util::error::Result<()> {
    let args = BenchArgs::parse();
    let rt = Runtime::open_default()?;
    let presets: Vec<String> = if args.rest.is_empty() {
        vec!["nano".into(), "tiny".into(), "small".into()]
    } else {
        args.rest.clone()
    };
    let b = args.bencher();
    let mut results = Vec::new();

    for preset in &presets {
        let meta = rt.preset(preset)?.clone();
        let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
        let mut sampler = TrainSampler::new(gen.dataset(64, 1), meta.batch, meta.seq_len, 1, 0);
        let batch = sampler.next_batch();
        let init = rt.load_kind(preset, "init")?;
        let mut params = lit_vec_f32(&init.call(&[Arg::I32(1)])?[0])?;
        let d = meta.d_pad;
        let flops_per_fwd = 2.0 * meta.d_raw as f64 * (meta.batch * meta.seq_len) as f64;
        let dims = vec![meta.batch, meta.seq_len];

        // loss-only forward, legacy Program::call shim (validates + clones
        // outputs per call) vs a bound session (zero steady-state alloc) —
        // the session-vs-legacy overhead entry of BENCH_native.json
        let loss_prog = rt.load_kind(preset, "loss")?;
        let r = b.run_items(&format!("{preset}/loss_fwd_legacy_call"), Some(flops_per_fwd), &mut || {
            let outs = loss_prog
                .call(&[
                    Arg::VecF32(&params),
                    Arg::TensorI32(&batch.input_ids, dims.clone()),
                    Arg::TensorI32(&batch.targets, dims.clone()),
                    Arg::TensorF32(&batch.mask, dims.clone()),
                ])
                .unwrap();
            let _ = lit_f32(&outs[0]).unwrap();
        });
        println!("{}", r.report());
        results.push(r);

        let mut loss_sess = rt.bind_kind(preset, "loss")?;
        let r = b.run_items(&format!("{preset}/loss_fwd_session"), Some(flops_per_fwd), &mut || {
            let outs = loss_sess
                .run(&[
                    Arg::VecF32(&params),
                    Arg::TensorI32(&batch.input_ids, dims.clone()),
                    Arg::TensorI32(&batch.targets, dims.clone()),
                    Arg::TensorF32(&batch.mask, dims.clone()),
                ])
                .unwrap();
            let _ = lit_f32(&outs[0]).unwrap();
        });
        println!("{}", r.report());
        results.push(r);

        // kernel-composition attention ablation (native loss_pallas twin;
        // same math, kernel-materialized attention inside). Optional so
        // older pjrt artifact sets without the program keep benching.
        if let Ok(mut pallas) = rt.bind_kind(preset, "loss_pallas") {
            let r = b.run_items(&format!("{preset}/loss_fwd_pallas"), Some(flops_per_fwd), &mut || {
                let outs = pallas
                    .run(&[
                        Arg::VecF32(&params),
                        Arg::TensorI32(&batch.input_ids, dims.clone()),
                        Arg::TensorI32(&batch.targets, dims.clone()),
                        Arg::TensorF32(&batch.mask, dims.clone()),
                    ])
                    .unwrap();
                let _ = lit_f32(&outs[0]).unwrap();
            });
            println!("{}", r.report());
            results.push(r);
        }

        // fused ZO steps (session-backed engines)
        let mut con = FusedConMeZo::new(&rt, preset, 1.35)?;
        let mut t = 0i32;
        let r = b.run_items(&format!("{preset}/conmezo_fused_step"), Some(2.0 * flops_per_fwd), &mut || {
            con.step(&mut params, &batch, t, 0.99, 1e-5, 1e-3).unwrap();
            t += 1;
        });
        println!("{}", r.report());
        results.push(r);

        let mut mz = FusedMezo::new(&rt, preset)?;
        let r = b.run_items(&format!("{preset}/mezo_fused_step"), Some(2.0 * flops_per_fwd), &mut || {
            mz.step(&mut params, &batch, t, 1e-5, 1e-3).unwrap();
            t += 1;
        });
        println!("{}", r.report());
        results.push(r);

        // composed two-point path: the Session::two_point antithetic-pair
        // fast path through ModelObjective (host-held direction)
        let sampler2 = TrainSampler::new(gen.dataset(64, 1), meta.batch, meta.seq_len, 1, 0);
        let mut obj = ModelObjective::new(&rt, preset, Box::new(sampler2))?;
        let z = vec![0.01f32; d];
        let r = b.run_items(&format!("{preset}/composed_two_point"), Some(2.0 * flops_per_fwd), &mut || {
            let _ = obj.two_point(&params, &z, 1e-3).unwrap();
        });
        println!("{}", r.report());
        results.push(r);

        // row-parallel GEMMs: the same two_point pair on an all-cores
        // native runtime (bit-identical results; wall-clock is the point)
        let auto = ParallelPolicy::auto();
        if auto.threads > 1 {
            let rt_mt = Runtime::native_with(auto);
            let mut tp = rt_mt.bind_kind(preset, "two_point")?;
            let r = b.run_items(
                &format!("{preset}/two_point_threads{}", auto.threads),
                Some(2.0 * flops_per_fwd),
                &mut || {
                    let _ = tp
                        .two_point(&params, &z, 1e-3, &batch.input_ids, &batch.targets, &batch.mask)
                        .unwrap();
                },
            );
            println!("{}", r.report());
            results.push(r);
        }
    }

    write_results("step_latency.jsonl", &results)?;
    write_bench_json("step_latency", &results)?;
    Ok(())
}
