//! End-to-end step latency through the PJRT runtime, per preset and
//! engine — the L2/L3 boundary measurement backing EXPERIMENTS.md §Perf.
//!
//! Measures: fused conmezo/mezo step, composed two-point path, loss-only
//! forward, eval, and the `loss_pallas` ablation (Pallas attention/LN vs
//! the XLA-fused default). `cargo bench --bench step_latency [presets]`.

use conmezo::bench::{write_results, Bencher};
use conmezo::coordinator::{FusedConMeZo, FusedMezo};
use conmezo::data::{spec, TaskGen, TrainSampler};
use conmezo::objective::{BatchSource, ModelObjective, Objective};
use conmezo::runtime::{lit_f32, lit_vec_f32, Arg, Runtime};

fn main() -> conmezo::util::error::Result<()> {
    let rt = Runtime::open_default()?;
    // cargo bench passes flags like --bench; keep only bare preset names
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let presets: Vec<String> = if args.is_empty() {
        vec!["nano".into(), "tiny".into(), "small".into()]
    } else {
        args
    };
    let b = Bencher::quick();
    let mut results = Vec::new();

    for preset in &presets {
        let meta = rt.preset(preset)?.clone();
        let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
        let mut sampler = TrainSampler::new(gen.dataset(64, 1), meta.batch, meta.seq_len, 1, 0);
        let batch = sampler.next_batch();
        let init = rt.load_kind(preset, "init")?;
        let mut params = lit_vec_f32(&init.call(&[Arg::I32(1)])?[0])?;
        let d = meta.d_pad;
        let flops_per_fwd = 2.0 * meta.d_raw as f64 * (meta.batch * meta.seq_len) as f64;

        // loss-only forward
        let loss_prog = rt.load_kind(preset, "loss")?;
        let dims = vec![meta.batch, meta.seq_len];
        let r = b.run_items(&format!("{preset}/loss_fwd"), Some(flops_per_fwd), &mut || {
            let outs = loss_prog
                .call(&[
                    Arg::VecF32(&params),
                    Arg::TensorI32(&batch.input_ids, dims.clone()),
                    Arg::TensorI32(&batch.targets, dims.clone()),
                    Arg::TensorF32(&batch.mask, dims.clone()),
                ])
                .unwrap();
            let _ = lit_f32(&outs[0]).unwrap();
        });
        println!("{}", r.report());
        results.push(r);

        // pallas-attention ablation (same math, L1 kernels inside)
        if let Ok(pl) = rt.load_kind(preset, "loss_pallas") {
            let r = b.run_items(&format!("{preset}/loss_fwd_pallas"), Some(flops_per_fwd), &mut || {
                let outs = pl
                    .call(&[
                        Arg::VecF32(&params),
                        Arg::TensorI32(&batch.input_ids, dims.clone()),
                        Arg::TensorI32(&batch.targets, dims.clone()),
                        Arg::TensorF32(&batch.mask, dims.clone()),
                    ])
                    .unwrap();
                let _ = lit_f32(&outs[0]).unwrap();
            });
            println!("{}", r.report());
            results.push(r);
        }

        // fused ZO steps
        let mut con = FusedConMeZo::new(&rt, preset, 1.35)?;
        let mut t = 0i32;
        let r = b.run_items(&format!("{preset}/conmezo_fused_step"), Some(2.0 * flops_per_fwd), &mut || {
            con.step(&mut params, &batch, t, 0.99, 1e-5, 1e-3).unwrap();
            t += 1;
        });
        println!("{}", r.report());
        results.push(r);

        let mut mz = FusedMezo::new(&rt, preset)?;
        let r = b.run_items(&format!("{preset}/mezo_fused_step"), Some(2.0 * flops_per_fwd), &mut || {
            mz.step(&mut params, &batch, t, 1e-5, 1e-3).unwrap();
            t += 1;
        });
        println!("{}", r.report());
        results.push(r);

        // composed two-point path (host-held direction)
        let sampler2 = TrainSampler::new(gen.dataset(64, 1), meta.batch, meta.seq_len, 1, 0);
        let mut obj = ModelObjective::new(&rt, preset, Box::new(sampler2))?;
        let z = vec![0.01f32; d];
        let r = b.run_items(&format!("{preset}/composed_two_point"), Some(2.0 * flops_per_fwd), &mut || {
            let _ = obj.two_point(&params, &z, 1e-3).unwrap();
        });
        println!("{}", r.report());
        results.push(r);
    }

    write_results("step_latency.jsonl", &results)?;
    Ok(())
}
