//! End-to-end step latency through the runtime, per preset and engine —
//! the session-API hot-path measurement backing EXPERIMENTS.md §Perf and
//! the `step_latency` section of `BENCH_native.json` at the repo root.
//!
//! Measures: loss forward through the legacy `Program::call` shim vs a
//! bound `Session` (the bind-once/run-many overhead delta), the native
//! `loss_pallas` kernel-composition ablation, fused conmezo/mezo steps,
//! the composed two-point path (the `Session::two_point` antithetic fast
//! path), and — when the thread policy allows — a threaded two_point.
//! A separate `two_point` section records the materialized-vs-fused
//! antithetic pair at the medium preset (the `ParamView` win: zero
//! parameter-sized writes per pair) with a derived parameter-stream
//! bytes-per-pair estimate as the throughput denominator. A `telemetry`
//! section pins the Registry instrumentation's on-vs-off cost on the same
//! two_point hot path (interleaved sampling; <1% p50 regression asserted).
//!
//! `cargo bench --bench step_latency [-- --quick] [presets...]`; `--quick`
//! runs a few iterations of everything (the CI smoke mode).

use conmezo::bench::{consume, write_bench_json, write_results, BenchArgs, BenchResult};
use conmezo::coordinator::{FusedConMeZo, FusedMezo};
use conmezo::data::{spec, TaskGen, TrainSampler};
use conmezo::objective::{BatchSource, ModelObjective, Objective};
use conmezo::runtime::{lit_f32, lit_vec_f32, Arg, ParallelPolicy, Runtime, Session};
use conmezo::vecmath::{self, ParamView};

fn main() -> conmezo::util::error::Result<()> {
    let args = BenchArgs::parse();
    let rt = Runtime::open_default()?;
    let presets: Vec<String> = if args.rest.is_empty() {
        vec!["nano".into(), "tiny".into(), "small".into()]
    } else {
        args.rest.clone()
    };
    let b = args.bencher();
    let mut results = Vec::new();

    for preset in &presets {
        let meta = rt.preset(preset)?.clone();
        let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
        let mut sampler = TrainSampler::new(gen.dataset(64, 1), meta.batch, meta.seq_len, 1, 0);
        let batch = sampler.next_batch();
        let init = rt.load_kind(preset, "init")?;
        let mut params = lit_vec_f32(&init.call(&[Arg::I32(1)])?[0])?;
        let d = meta.d_pad;
        let flops_per_fwd = 2.0 * meta.d_raw as f64 * (meta.batch * meta.seq_len) as f64;
        let dims = vec![meta.batch, meta.seq_len];

        // loss-only forward, legacy Program::call shim (validates + clones
        // outputs per call) vs a bound session (zero steady-state alloc) —
        // the session-vs-legacy overhead entry of BENCH_native.json
        let loss_prog = rt.load_kind(preset, "loss")?;
        let r = b.run_items(&format!("{preset}/loss_fwd_legacy_call"), Some(flops_per_fwd), &mut || {
            let outs = loss_prog
                .call(&[
                    Arg::VecF32(&params),
                    Arg::TensorI32(&batch.input_ids, dims.clone()),
                    Arg::TensorI32(&batch.targets, dims.clone()),
                    Arg::TensorF32(&batch.mask, dims.clone()),
                ])
                .unwrap();
            let _ = lit_f32(&outs[0]).unwrap();
        });
        println!("{}", r.report());
        results.push(r);

        let mut loss_sess = rt.bind_kind(preset, "loss")?;
        let r = b.run_items(&format!("{preset}/loss_fwd_session"), Some(flops_per_fwd), &mut || {
            let outs = loss_sess
                .run(&[
                    Arg::VecF32(&params),
                    Arg::TensorI32(&batch.input_ids, dims.clone()),
                    Arg::TensorI32(&batch.targets, dims.clone()),
                    Arg::TensorF32(&batch.mask, dims.clone()),
                ])
                .unwrap();
            let _ = lit_f32(&outs[0]).unwrap();
        });
        println!("{}", r.report());
        results.push(r);

        // kernel-composition attention ablation (native loss_pallas twin;
        // same math, kernel-materialized attention inside). Optional so
        // older pjrt artifact sets without the program keep benching.
        if let Ok(mut pallas) = rt.bind_kind(preset, "loss_pallas") {
            let r = b.run_items(&format!("{preset}/loss_fwd_pallas"), Some(flops_per_fwd), &mut || {
                let outs = pallas
                    .run(&[
                        Arg::VecF32(&params),
                        Arg::TensorI32(&batch.input_ids, dims.clone()),
                        Arg::TensorI32(&batch.targets, dims.clone()),
                        Arg::TensorF32(&batch.mask, dims.clone()),
                    ])
                    .unwrap();
                let _ = lit_f32(&outs[0]).unwrap();
            });
            println!("{}", r.report());
            results.push(r);
        }

        // fused ZO steps (session-backed engines)
        let mut con = FusedConMeZo::new(&rt, preset, 1.35)?;
        let mut t = 0i32;
        let r = b.run_items(&format!("{preset}/conmezo_fused_step"), Some(2.0 * flops_per_fwd), &mut || {
            con.step(&mut params, &batch, t, 0.99, 1e-5, 1e-3).unwrap();
            t += 1;
        });
        println!("{}", r.report());
        results.push(r);

        let mut mz = FusedMezo::new(&rt, preset)?;
        let r = b.run_items(&format!("{preset}/mezo_fused_step"), Some(2.0 * flops_per_fwd), &mut || {
            mz.step(&mut params, &batch, t, 1e-5, 1e-3).unwrap();
            t += 1;
        });
        println!("{}", r.report());
        results.push(r);

        // composed two-point path: the Session::two_point antithetic-pair
        // fast path through ModelObjective (host-held direction)
        let sampler2 = TrainSampler::new(gen.dataset(64, 1), meta.batch, meta.seq_len, 1, 0);
        let mut obj = ModelObjective::new(&rt, preset, Box::new(sampler2))?;
        let z = vec![0.01f32; d];
        let r = b.run_items(&format!("{preset}/composed_two_point"), Some(2.0 * flops_per_fwd), &mut || {
            let _ = obj.two_point(&params, &z, 1e-3).unwrap();
        });
        println!("{}", r.report());
        results.push(r);

        // row-parallel GEMMs: the same two_point pair on an all-cores
        // native runtime (bit-identical results; wall-clock is the point)
        let auto = ParallelPolicy::auto();
        if auto.threads > 1 {
            let rt_mt = Runtime::native_with(auto);
            let mut tp = rt_mt.bind_kind(preset, "two_point")?;
            let r = b.run_items(
                &format!("{preset}/two_point_threads{}", auto.threads),
                Some(2.0 * flops_per_fwd),
                &mut || {
                    let _ = tp
                        .two_point(&params, &z, 1e-3, &batch.input_ids, &batch.targets, &batch.mask)
                        .unwrap();
                },
            );
            println!("{}", r.report());
            results.push(r);
        }
    }

    write_results("step_latency.jsonl", &results)?;
    write_bench_json("step_latency", &results)?;

    // -----------------------------------------------------------------------
    // materialized-vs-fused antithetic pair at the medium preset (the
    // `two_point` section of BENCH_native.json, asserted by CI): the
    // retired path writes x ± λz to a d-sized buffer the forward re-reads
    // (~5 full-d parameter streams per pair: 2 writes + 3 reads), the
    // ParamView path streams params and z straight through the kernels
    // (~2 reads, zero parameter-sized writes). items_per_iter carries the
    // derived bytes-per-pair estimate, so the throughput line reads as
    // perturbation-stream bandwidth. Runs regardless of the preset args so
    // the section always lands.
    // -----------------------------------------------------------------------
    {
        use conmezo::runtime::model::{build_preset, NativeModel};
        let meta = build_preset("medium", 512, 256, 8, 8, 64, 8);
        let threads = ParallelPolicy::auto().threads;
        let model = NativeModel::new(meta.clone()).with_threads(threads);
        let params = model.init_flat(1);
        let z = model.sample_u(2);
        let (bsz, s) = (meta.batch, meta.seq_len);
        let ids: Vec<i32> = (0..bsz * s).map(|i| ((i * 13) % 509) as i32).collect();
        let tgt: Vec<i32> = (0..bsz * s).map(|i| ((i * 7) % 509) as i32).collect();
        let mut mask = vec![0f32; bsz * s];
        for i in 0..bsz {
            mask[i * s + s - 1] = 1.0;
        }
        let mut ws = model.scratch();
        let lam = 1e-3f32;
        let d = meta.d_pad;

        // sanity: the two paths must agree bitwise before we time them
        let mut xs = vec![0f32; d];
        vecmath::axpy_into(lam, &z, &params, &mut xs);
        let want = model.loss_with(&xs, &ids, &tgt, &mask, bsz, s, &mut ws);
        let got = model.loss_view_with(
            ParamView::perturbed(&params, &z, lam),
            &ids,
            &tgt,
            &mask,
            bsz,
            s,
            &mut ws,
        );
        assert_eq!(got, want, "fused two_point diverged from materialized");

        let mut tp_results = Vec::new();
        let bytes_materialized = (5 * d * 4) as f64;
        let r = b.run_items(
            &format!("two_point/medium/materialized_pair_threads{threads}"),
            Some(bytes_materialized),
            &mut || {
                vecmath::axpy_into(lam, &z, &params, &mut xs);
                let lp = model.loss_with(&xs, &ids, &tgt, &mask, bsz, s, &mut ws);
                vecmath::axpy_into(-lam, &z, &params, &mut xs);
                let lm = model.loss_with(&xs, &ids, &tgt, &mask, bsz, s, &mut ws);
                consume((lp, lm));
            },
        );
        println!("{}", r.report());
        tp_results.push(r);
        let bytes_fused = (2 * d * 4) as f64;
        let r = b.run_items(
            &format!("two_point/medium/fused_view_pair_threads{threads}"),
            Some(bytes_fused),
            &mut || {
                let lp = model.loss_view_with(
                    ParamView::perturbed(&params, &z, lam),
                    &ids,
                    &tgt,
                    &mask,
                    bsz,
                    s,
                    &mut ws,
                );
                let lm = model.loss_view_with(
                    ParamView::perturbed(&params, &z, -lam),
                    &ids,
                    &tgt,
                    &mask,
                    bsz,
                    s,
                    &mut ws,
                );
                consume((lp, lm));
            },
        );
        println!("{}", r.report());
        tp_results.push(r);
        write_results("two_point.jsonl", &tp_results)?;
        write_bench_json("two_point", &tp_results)?;
    }

    // -----------------------------------------------------------------------
    // telemetry overhead: the zero-overhead claim behind the `telemetry`
    // section of BENCH_native.json (asserted by CI bench-smoke). Same bound
    // two_point session timed with Registry recording on vs off, toggled per
    // sample in an interleaved pattern so thermal / scheduler drift cancels
    // out of the comparison; the assert pins the p50 regression under 1%
    // (plus a small absolute slack for timer granularity). Runs regardless
    // of the preset args so the section always lands.
    // -----------------------------------------------------------------------
    {
        use std::time::Instant;

        use conmezo::util::{mean_std, percentile};

        let auto = ParallelPolicy::auto();
        let rt_t = Runtime::native_with(auto);
        let preset = "small";
        let meta = rt_t.preset(preset)?.clone();
        let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
        let mut sampler = TrainSampler::new(gen.dataset(64, 1), meta.batch, meta.seq_len, 1, 0);
        let batch = sampler.next_batch();
        let init = rt_t.load_kind(preset, "init")?;
        let params = lit_vec_f32(&init.call(&[Arg::I32(1)])?[0])?;
        let z = vec![0.01f32; meta.d_pad];
        let mut tp = rt_t.bind_kind(preset, "two_point")?;
        let reg = rt_t.telemetry().expect("native backend always carries a Registry").clone();

        // sanity: recording must not perturb the numbers themselves
        reg.set_enabled(true);
        let on = tp.two_point(&params, &z, 1e-3, &batch.input_ids, &batch.targets, &batch.mask)?;
        reg.set_enabled(false);
        let off = tp.two_point(&params, &z, 1e-3, &batch.input_ids, &batch.targets, &batch.mask)?;
        assert_eq!(on, off, "toggling telemetry changed two_point results");

        let pairs = if args.quick { 25 } else { 300 };
        let mut on_s = Vec::with_capacity(pairs);
        let mut off_s = Vec::with_capacity(pairs);
        for _ in 0..3 {
            let _ =
                tp.two_point(&params, &z, 1e-3, &batch.input_ids, &batch.targets, &batch.mask)?;
        }
        for _ in 0..pairs {
            reg.set_enabled(true);
            let t0 = Instant::now();
            let _ =
                tp.two_point(&params, &z, 1e-3, &batch.input_ids, &batch.targets, &batch.mask)?;
            on_s.push(t0.elapsed().as_secs_f64());
            reg.set_enabled(false);
            let t0 = Instant::now();
            let _ =
                tp.two_point(&params, &z, 1e-3, &batch.input_ids, &batch.targets, &batch.mask)?;
            off_s.push(t0.elapsed().as_secs_f64());
        }
        reg.set_enabled(true);

        let mk = |name: String, s: &[f64]| {
            let (mean, std) = mean_std(s);
            BenchResult {
                name,
                samples: s.len(),
                mean_s: mean,
                std_s: std,
                p50_s: percentile(s, 50.0),
                p99_s: percentile(s, 99.0),
                items_per_iter: None,
            }
        };
        let r_on = mk(format!("telemetry/{preset}/two_point_on_threads{}", auto.threads), &on_s);
        let r_off = mk(format!("telemetry/{preset}/two_point_off_threads{}", auto.threads), &off_s);
        println!("{}", r_on.report());
        println!("{}", r_off.report());
        let overhead = r_on.p50_s / r_off.p50_s - 1.0;
        println!("telemetry overhead (p50, interleaved): {:+.3}%", overhead * 100.0);
        assert!(
            r_on.p50_s <= r_off.p50_s * 1.01 + 25e-6,
            "telemetry-on p50 {:.6}s vs off {:.6}s exceeds the 1% overhead budget",
            r_on.p50_s,
            r_off.p50_s
        );
        let tel_results = vec![r_on, r_off];
        write_results("telemetry.jsonl", &tel_results)?;
        write_bench_json("telemetry", &tel_results)?;
    }
    Ok(())
}
