//! GFLOP/s roofline for the forward GEMM kernels: scalar vs SIMD vs
//! SIMD+packed, single-core, against the machine's theoretical non-FMA
//! AVX2 peak (the `gflops` section of `BENCH_native.json`). Before any
//! timing, every variant is asserted BITWISE equal to the scalar kernel —
//! the bench doubles as a smoke test of the bit-identity contract.
//! `cargo bench --bench gflops [-- --quick]`.

use conmezo::bench::{write_bench_json, write_results, BenchArgs, BenchResult};
use conmezo::parallel::WorkerPool;
use conmezo::util::rng::Xoshiro256pp;
use conmezo::vecmath::{self, simd, simd::SimdPolicy, PackedB};

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0f32; n];
    r.fill_normal_f32(&mut v);
    v
}

fn assert_bits(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: bit mismatch at {i}: {x} vs {y}"
        );
    }
}

/// Theoretical single-core f32 peak for the dispatch the kernels actually
/// use: 8 lanes × (1 mul + 1 add) per cycle — NOT the FMA peak, because
/// the bit-identity contract forbids contraction (`vecmath::simd` module
/// docs). Frequency from /proc/cpuinfo when readable, else 3 GHz.
fn theoretical_peak_flops() -> f64 {
    let ghz = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|text| {
            text.lines()
                .filter(|l| l.starts_with("cpu MHz"))
                .filter_map(|l| l.split(':').nth(1)?.trim().parse::<f64>().ok())
                .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v))))
        })
        .map(|mhz| mhz / 1000.0)
        .unwrap_or(3.0);
    ghz * 1e9 * 16.0
}

fn main() -> conmezo::util::error::Result<()> {
    conmezo::runtime::enable_flush_to_zero();
    let args = BenchArgs::parse();
    let b = args.bencher();
    let mut results = Vec::new();
    let avail = simd::available();
    println!("simd available: {avail} (status before policy overrides: {})", simd::status());

    // single participant: per-kernel GFLOP/s, not pool scaling (the
    // `parallel` section already covers dispatch)
    let pool = WorkerPool::new(1);

    // (label, m, k, n, transposed B) — the medium-preset QKV projection and
    // the tied LM head (vocab=512), the two biggest forward GEMM shapes
    let shapes: &[(&str, usize, usize, usize, bool)] = &[
        ("qkv_512x256x768", 512, 256, 768, false),
        ("lmhead_bt_512x256x512", 512, 256, 512, true),
    ];
    for &(label, m, k, n, bt) in shapes {
        let a = randv(m * k, 11);
        let w = randv(k * n, 12); // n*k == k*n elements either storage order
        let mut packed = vec![0f32; vecmath::packed_len(k, n)];
        if bt {
            vecmath::pack_bt(&w, k, n, &mut packed);
        } else {
            vecmath::pack_b(&w, k, n, &mut packed);
        }
        let run = |out: &mut [f32]| {
            if bt {
                vecmath::matmul_bt(&a, &w, m, k, n, out);
            } else {
                vecmath::matmul(&a, &w, m, k, n, out);
            }
        };
        let run_packed = |out: &mut [f32]| {
            vecmath::matmul_packed_view_threaded(&a, PackedB::Plain(&packed[..]), m, k, n, out, &pool);
        };

        // bitwise pre-assert: scalar is the reference; SIMD and packed
        // (both dispatches) must reproduce it exactly
        let mut reference = vec![0f32; m * n];
        let mut out = vec![0f32; m * n];
        simd::set_policy(SimdPolicy::Off);
        run(&mut reference);
        run_packed(&mut out);
        assert_bits(&reference, &out, &format!("{label}/packed-scalar vs scalar"));
        if avail {
            simd::set_policy(SimdPolicy::Auto);
            run(&mut out);
            assert_bits(&reference, &out, &format!("{label}/simd vs scalar"));
            run_packed(&mut out);
            assert_bits(&reference, &out, &format!("{label}/simd-packed vs scalar"));
        }
        println!("{label}: bit-identity pre-assert passed (simd avail: {avail})");

        let flops = Some((2 * m * k * n) as f64);
        simd::set_policy(SimdPolicy::Off);
        let r = b.run_items(&format!("{label}/scalar"), flops, &mut || run(&mut out));
        println!("{}", r.report());
        results.push(r);
        if avail {
            simd::set_policy(SimdPolicy::Auto);
            let r = b.run_items(&format!("{label}/simd"), flops, &mut || run(&mut out));
            println!("{}", r.report());
            results.push(r);
            let r = b.run_items(&format!("{label}/simd_packed"), flops, &mut || {
                run_packed(&mut out)
            });
            println!("{}", r.report());
            results.push(r);
        } else {
            // no AVX2: record the packed-scalar row so the section still
            // shows the layout's cache effect
            let r = b.run_items(&format!("{label}/scalar_packed"), flops, &mut || {
                run_packed(&mut out)
            });
            println!("{}", r.report());
            results.push(r);
        }
    }

    // the fused perturbation kernel (out = x + a*z), 2 FLOP per element
    {
        let d = 1 << 20;
        let x = randv(d, 21);
        let z = randv(d, 22);
        let mut reference = vec![0f32; d];
        let mut out = vec![0f32; d];
        simd::set_policy(SimdPolicy::Off);
        vecmath::axpy_into(1e-3, &z, &x, &mut reference);
        if avail {
            simd::set_policy(SimdPolicy::Auto);
            vecmath::axpy_into(1e-3, &z, &x, &mut out);
            assert_bits(&reference, &out, "axpy_into/simd vs scalar");
        }
        let flops = Some(2.0 * d as f64);
        simd::set_policy(SimdPolicy::Off);
        let r = b.run_items("axpy_into_1m/scalar", flops, &mut || {
            vecmath::axpy_into(1e-3, &z, &x, &mut out)
        });
        println!("{}", r.report());
        results.push(r);
        if avail {
            simd::set_policy(SimdPolicy::Auto);
            let r = b.run_items("axpy_into_1m/simd", flops, &mut || {
                vecmath::axpy_into(1e-3, &z, &x, &mut out)
            });
            println!("{}", r.report());
            results.push(r);
        }
    }

    // synthetic roofline row: mean_s = 1 s, items = peak FLOPs, so
    // throughput() reads back as the peak itself
    let peak = theoretical_peak_flops();
    println!("theoretical peak (1 core, 8 lanes x mul+add, no FMA): {:.1} GFLOP/s", peak / 1e9);
    results.push(BenchResult {
        name: "peak/avx2_mul_add_1core".into(),
        samples: 1,
        mean_s: 1.0,
        std_s: 0.0,
        p50_s: 1.0,
        p99_s: 1.0,
        items_per_iter: Some(peak),
    });

    simd::set_policy(SimdPolicy::Auto);
    write_results("gflops.jsonl", &results)?;
    write_bench_json("gflops", &results)?;
    Ok(())
}
