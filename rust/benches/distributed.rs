//! Distributed-layer cost model — the `distributed` section of
//! `BENCH_native.json` (asserted by CI's bench-smoke job).
//!
//! Three measurements back the paper's O(1)-bytes/step claim and ISSUE-6's
//! recovery-path costs:
//!
//! * `local_cluster/step_nN` — per-step cost of the shared-randomness
//!   protocol math in-process (no transport): direction regen, antithetic
//!   pair, projected-gradient average, lockstep update, at N ∈ {1, 2, 4}
//!   replicas. items_per_iter carries the leader-side wire bytes the same
//!   steps would cost over sockets (91 B/step/worker steady state), so the
//!   throughput line reads as protocol bandwidth.
//! * `cluster/channel_step_nN` — the same steps end-to-end through the
//!   framed transport layer (encode/decode + channel hop + leader
//!   collect), workers on real threads: the coordination overhead on top
//!   of the math.
//! * `replay/fast_forward` — seed-replay rejoin throughput: steps/sec a
//!   rejoining replica fast-forwards through leader `StepRecord`s with
//!   ZERO function evaluations (items = replayed steps).
//!
//! `cargo bench --bench distributed [-- --quick]`; `--quick` is the CI
//! smoke mode.

use conmezo::bench::{write_bench_json, write_results, BenchArgs};
use conmezo::checkpoint::StepRecord;
use conmezo::coordinator::{
    run_worker, step_seed, DistHypers, Leader, LeaderConfig, LocalCluster, ZoWorker,
};
use conmezo::net::{channel_pair, Transport};
use conmezo::objective::NativeQuadratic;
use conmezo::optimizer::BetaSchedule;

const D: usize = 4096;
const HYP: DistHypers = DistHypers { theta: 1.2, eta: 1e-3, lam: 1e-2 };

fn x0() -> Vec<f32> {
    (0..D).map(|i| ((i * 37 + 11) as f32 * 0.1).sin()).collect()
}

fn workers(n: usize) -> Vec<ZoWorker> {
    (0..n)
        .map(|id| ZoWorker::new(id as u32, x0(), Box::new(NativeQuadratic::new(D))))
        .collect()
}

fn main() -> conmezo::util::error::Result<()> {
    let args = BenchArgs::parse();
    let b = args.bencher();
    let beta = BetaSchedule::Constant(0.9);
    let mut results = Vec::new();

    // per-iteration step count: enough to amortize per-run setup, small
    // enough that --quick stays a smoke test
    let steps_per_iter = 16u64;

    for &n in &[1usize, 2, 4] {
        // calibrate the wire-byte denominator from the accounting itself
        // (pinned elsewhere to equal the TCP leader's) instead of
        // hardcoding frame sizes
        let mut cal = LocalCluster::new(workers(n), 42);
        let bytes_per_iter = cal.run(steps_per_iter, HYP, &beta, 0)?.wire_bytes as f64;

        let mut cluster = LocalCluster::new(workers(n), 42);
        let r = b.run_items(&format!("local_cluster/step_n{n}_d{D}"), Some(bytes_per_iter), &mut || {
            cluster.run(steps_per_iter, HYP, &beta, 0).unwrap();
        });
        println!("{}", r.report());
        results.push(r);

        // the same protocol through framed channel transports + threads:
        // each iteration is a full cluster lifecycle (handshake, steps,
        // shutdown), so this upper-bounds the per-step coordination cost
        let r = b.run_items(&format!("cluster/channel_step_n{n}_d{D}"), Some(bytes_per_iter), &mut || {
            let mut conns: Vec<Box<dyn Transport>> = Vec::new();
            let mut handles = Vec::new();
            for id in 0..n as u32 {
                let (wside, lside) = channel_pair();
                conns.push(Box::new(lside));
                handles.push(std::thread::spawn(move || {
                    let mut wside = wside;
                    let mut w = ZoWorker::new(id, x0(), Box::new(NativeQuadratic::new(D)));
                    run_worker(&mut wside, &mut w).unwrap();
                }));
            }
            let cfg = LeaderConfig::new(n as u32, 42, steps_per_iter, HYP, beta.clone());
            Leader::new(cfg).run(conns).unwrap();
            for h in handles {
                h.join().unwrap();
            }
        });
        println!("{}", r.report());
        results.push(r);
    }

    // rejoin cost: fast-forward a fresh replica through a leader step log
    // (pure record-stream math, zero function evaluations)
    let replay_steps = 64u64;
    let records: Vec<StepRecord> = (0..replay_steps)
        .map(|t| StepRecord {
            seed: step_seed(42, t),
            g: 0.01,
            theta: HYP.theta,
            eta: HYP.eta,
            beta: 0.9,
        })
        .collect();
    let r = b.run_items(
        &format!("replay/fast_forward_{replay_steps}steps_d{D}"),
        Some(replay_steps as f64),
        &mut || {
            let mut w = ZoWorker::new(0, x0(), Box::new(NativeQuadratic::new(D)));
            w.replay(0, &records).unwrap();
        },
    );
    println!("{}", r.report());
    results.push(r);

    write_results("distributed.jsonl", &results)?;
    write_bench_json("distributed", &results)?;
    Ok(())
}
