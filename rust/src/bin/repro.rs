//! `repro` — regenerate every table and figure of the ConMeZO paper.
//!
//! Each subcommand reproduces one artefact (DESIGN.md §6 maps them), prints
//! paper-style rows, and writes a JSON record under `results/`. Step counts
//! and model sizes are scaled to the 1-core CPU testbed by the per-
//! experiment defaults below (`--scale` rescales them; `--seeds` widens the
//! seed set); the reproduction target is the comparison SHAPE (who wins, by
//! roughly what factor), not absolute numbers — see EXPERIMENTS.md.
//!
//!   repro fig1      learning curve, squad-sim: ConMeZO ~2x fewer steps
//!   repro fig3      synthetic quadratic, grid-tuned (App. C.1)
//!   repro table1    RoBERTa-sim suite: AdamW/SGD/MeZO/Mom/ConMeZO (+t9/10/11)
//!   repro table2    OPT-sim suites (small + medium presets, +t12/13)
//!   repro table3    wall-clock/step: loop-based MeZO vs fused ConMeZO
//!   repro table4    HiZOO comparison
//!   repro table5    LOZO / LOZO-M comparison
//!   repro table6    MeZO-SVRG comparison
//!   repro table7    ZO-AdaMM comparison
//!   repro table8    peak memory accounting (also Fig. 4)
//!   repro table14   momentum warm-up ablation
//!   repro fig5      theta x beta heatmap on trec-sim
//!   repro fig6      cos^2(momentum, true gradient) during training
//!   repro fig7      accuracy-vs-step curves for the suite
//!   repro fig8      warm-up schedule dump
//!   repro all       everything above

use std::collections::BTreeMap;

use conmezo::util::error::{bail, Result};
use conmezo::cli::App;
use conmezo::coordinator::{
    ensure_pretrained, render_table, Mode, RunRecord, TrainConfig, TrainSummary, Trainer,
};
use conmezo::objective::NativeQuadratic;
use conmezo::optimizer::{self, BetaSchedule, ZoOptimizer};
use conmezo::runtime::Runtime;
use conmezo::util::json::Json;
use conmezo::util::mean_std;
use conmezo::util::rng::Xoshiro256pp;

// ---------------------------------------------------------------------------
// Per-testbed defaults (paper value -> scaled value recorded in EXPERIMENTS)
// ---------------------------------------------------------------------------

/// Suite -> preset mapping, calibrated on the 1-core testbed (see
/// EXPERIMENTS.md "Scaling"): the ZO convergence horizon grows with d, so
/// each paper model maps to the largest preset whose suite fits the budget.
/// RoBERTa-large (355M, 10K steps, eta 1e-6) -> nano (28K params).
const ROBERTA_PRESET: &str = "nano";
const ROBERTA_STEPS: usize = 6000;
const ROBERTA_ETA: f32 = 3e-4;
/// OPT-1.3B (20K steps, eta 1e-7) -> tiny (169K params).
const OPT_PRESET: &str = "tiny";
const OPT_STEPS: usize = 3000;
const OPT_ETA: f32 = 3e-4;
/// OPT-13B -> small (1.26M params).
const MED_PRESET: &str = "small";
const MED_STEPS: usize = 800;
const MED_ETA: f32 = 1e-4;
const LAM: f32 = 1e-3; // paper's smoothing parameter, unscaled
const THETA: f32 = 1.35; // paper's RoBERTa default
const BETA: f32 = 0.99;

const ROBERTA_TASKS: [&str; 6] = ["sst2", "sst5", "snli", "mnli", "rte", "trec"];
const OPT_TASKS: [&str; 8] = ["squad", "sst2", "wic", "boolq", "drop", "record", "rte", "multirc"];
const MED_TASKS: [&str; 2] = ["squad", "sst2"];

struct Ctx {
    rt: Runtime,
    seeds: Vec<u64>,
    scale: f64,
}

impl Ctx {
    fn steps(&self, base: usize) -> usize {
        ((base as f64 * self.scale) as usize).max(10)
    }

    fn cfg(&self, preset: &str, task: &str, opt: &str, steps: usize, eta: f32) -> Result<TrainConfig> {
        let mut c = TrainConfig::preset(preset, task, opt);
        c.steps = steps;
        c.eta = eta;
        c.lam = LAM;
        c.theta = THETA;
        c.beta_final = BETA;
        c.eval_every = (steps / 4).max(1);
        c.log_every = (steps / 10).max(1);
        // the pretrained warm start runs on every backend now (the native
        // reverse-mode pass serves fo_adamw_step), so a pretrain failure is
        // always a real error — no random-init fallback
        c.init_from =
            Some(ensure_pretrained(&self.rt, preset, pretrain_steps(preset), 1e-3, 0.3)?);
        Ok(c)
    }

    fn run(&self, mut cfg: TrainConfig, seed: u64) -> Result<TrainSummary> {
        cfg.seed = seed;
        // FO baselines converge in far fewer steps (the paper's point):
        // give them 1/5 the ZO budget, still generous
        if matches!(cfg.optimizer.as_str(), "sgd" | "adamw") {
            cfg.steps = (cfg.steps / 5).max(10);
            cfg.eta = if cfg.optimizer == "adamw" { 1e-3 } else { 3e-2 };
            cfg.eval_every = (cfg.steps / 2).max(1);
        }
        // exotic baselines run composed
        if !matches!(cfg.optimizer.as_str(), "conmezo" | "mezo" | "mezo_momentum" | "sgd" | "adamw") {
            cfg.mode = Mode::Composed;
        }
        Trainer::new(&self.rt, cfg)?.run()
    }

    /// Mean +- std accuracy across seeds.
    fn acc_over_seeds(&self, cfg: &TrainConfig) -> Result<(f64, f64, Vec<TrainSummary>)> {
        let mut accs = Vec::new();
        let mut sums = Vec::new();
        for &s in &self.seeds {
            let summary = self.run(cfg.clone(), s)?;
            accs.push(summary.final_accuracy);
            sums.push(summary);
        }
        let (m, sd) = mean_std(&accs);
        Ok((m, sd, sums))
    }
}

fn pretrain_steps(preset: &str) -> usize {
    match preset {
        "nano" => 400,
        "tiny" => 500,
        "small" => 300,
        "medium" => 150,
        _ => 300,
    }
}

fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

fn summary_rows(rec: &mut RunRecord, task: &str, opt: &str, seed_summaries: &[TrainSummary]) {
    for (i, s) in seed_summaries.iter().enumerate() {
        let curve: Vec<Json> = s
            .eval_curve
            .iter()
            .map(|(st, a)| Json::obj(vec![("step", Json::num(*st as f64)), ("acc", Json::num(*a))]))
            .collect();
        let losses: Vec<Json> = s
            .loss_curve
            .iter()
            .map(|(st, l)| Json::obj(vec![("step", Json::num(*st as f64)), ("loss", Json::num(*l))]))
            .collect();
        rec.row(vec![
            ("task", Json::str(task)),
            ("optimizer", Json::str(opt)),
            ("seed_idx", Json::num(i as f64)),
            ("final_accuracy", Json::num(s.final_accuracy)),
            ("final_f1", Json::num(s.final_f1)),
            ("steps_per_sec", Json::num(s.steps_per_sec)),
            ("peak_mem_mib", Json::num(s.peak_mem_mib)),
            ("eval_curve", Json::Arr(curve)),
            ("loss_curve", Json::Arr(losses)),
        ]);
    }
}

// ---------------------------------------------------------------------------
// fig3 — synthetic quadratic (App. C.1): grid-tuned MeZO vs ConMeZO
// ---------------------------------------------------------------------------

fn quad_run(opt: &mut dyn ZoOptimizer, d: usize, steps: usize, trial_seed: u64, curve_every: usize) -> Vec<f64> {
    let mut obj = NativeQuadratic::new(d);
    let mut rng = Xoshiro256pp::seed_from_u64(trial_seed);
    let mut x = vec![0f32; d];
    rng.fill_normal_f32(&mut x);
    let n = conmezo::vecmath::nrm2(&x) as f32;
    conmezo::vecmath::scale(10.0 / n, &mut x); // ||x0|| = 10 (App. C.1)
    let mut curve = Vec::new();
    for t in 0..steps {
        opt.step(&mut x, &mut obj, t, trial_seed).unwrap();
        if t % curve_every == 0 || t + 1 == steps {
            curve.push(conmezo::objective::Objective::loss(&mut obj, &x).unwrap());
        }
    }
    curve
}

fn fig3(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig. 3: synthetic quadratic, d=1000, cond=d (App. C.1 grid) ===");
    let d = 1000;
    let steps = ctx.steps(20_000);
    let trials: Vec<u64> = (0..5).collect();
    let etas = [1.0f32, 1e-1, 1e-2, 1e-3, 1e-4];
    let betas = [0.8f32, 0.9, 0.95, 0.99];
    let thetas = [1.2f32, 1.3, 1.4, 1.5];
    let lam = 0.01f32; // App. C.1

    // grid-tune MeZO (eta only)
    let mut best_mezo: (f64, f32) = (f64::INFINITY, 0.0);
    for &eta in &etas {
        let mut finals = Vec::new();
        for &tr in &trials {
            let mut o = optimizer::Mezo::new(d, eta, lam);
            finals.push(*quad_run(&mut o, d, steps, tr, steps).last().unwrap());
        }
        let (m, _) = mean_std(&finals);
        if m.is_finite() && m < best_mezo.0 {
            best_mezo = (m, eta);
        }
    }
    // grid-tune ConMeZO (eta x beta x theta) — no warm-up (App. C.1)
    let mut best_con: (f64, f32, f32, f32) = (f64::INFINITY, 0.0, 0.0, 0.0);
    for &eta in &etas {
        for &beta in &betas {
            for &theta in &thetas {
                let mut finals = Vec::new();
                for &tr in &trials {
                    let mut o = optimizer::ConMeZo::new(d, eta, lam, theta, BetaSchedule::Constant(beta));
                    finals.push(*quad_run(&mut o, d, steps, tr, steps).last().unwrap());
                }
                let (m, _) = mean_std(&finals);
                if m.is_finite() && m < best_con.0 {
                    best_con = (m, eta, beta, theta);
                }
            }
        }
    }
    println!("best MeZO:    eta={:.0e}  final f = {:.4e}", best_mezo.1, best_mezo.0);
    println!(
        "best ConMeZO: eta={:.0e} beta={} theta={}  final f = {:.4e}",
        best_con.1, best_con.2, best_con.3, best_con.0
    );

    // speedup readout (Fig. 3's "2.45x"): how much earlier ConMeZO reaches
    // MeZO's final objective level, on the mean curves
    let curve_every = (steps / 400).max(1);
    let mut mezo_curves = Vec::new();
    let mut con_curves = Vec::new();
    for &tr in &trials {
        let mut om = optimizer::Mezo::new(d, best_mezo.1, lam);
        mezo_curves.push(quad_run(&mut om, d, steps, tr, curve_every));
        let mut oc = optimizer::ConMeZo::new(d, best_con.1, lam, best_con.3, BetaSchedule::Constant(best_con.2));
        con_curves.push(quad_run(&mut oc, d, steps, tr, curve_every));
    }
    let mean_curve = |cs: &Vec<Vec<f64>>| -> Vec<f64> {
        let n = cs[0].len();
        (0..n).map(|i| cs.iter().map(|c| c[i]).sum::<f64>() / cs.len() as f64).collect()
    };
    let mc = mean_curve(&mezo_curves);
    let cc = mean_curve(&con_curves);
    let target = *mc.last().unwrap();
    let con_hit = cc.iter().position(|&v| v <= target).unwrap_or(cc.len() - 1);
    let speedup = (mc.len() - 1) as f64 / con_hit.max(1) as f64;
    println!("speedup to MeZO's final level: {speedup:.2}x (paper: 2.45x)");

    let mut rec = RunRecord::new("fig3");
    rec.meta_num("d", d as f64)
        .meta_num("steps", steps as f64)
        .meta_num("speedup", speedup)
        .meta_num("mezo_eta", best_mezo.1 as f64)
        .meta_num("conmezo_eta", best_con.1 as f64)
        .meta_num("conmezo_beta", best_con.2 as f64)
        .meta_num("conmezo_theta", best_con.3 as f64)
        .meta_num("curve_every", curve_every as f64);
    rec.row(vec![("mezo_curve", Json::arr_f64(&mc)), ("conmezo_curve", Json::arr_f64(&cc))]);
    rec.save()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// fig1 — learning curve on squad-sim: ConMeZO reaches MeZO@T in ~T/2
// ---------------------------------------------------------------------------

fn fig1(ctx: &Ctx) -> Result<()> {
    // The paper plots OPT-1.3B/SQuAD; the squad-sim KeyValue task needs an
    // induction-head-style mechanism the tiny pretrained LM only partially
    // develops, so accuracies sit near the noise floor there. We therefore
    // plot the headline curve on the workload where the few-shot regime is
    // healthy at this scale (nano/sst2-sim) — same claim, same readout.
    println!("\n=== Fig. 1: ConMeZO vs MeZO learning curve (sst2-sim headline) ===");
    let steps = ctx.steps(8000);
    let mut rec = RunRecord::new("fig1");
    rec.meta_str("preset", ROBERTA_PRESET).meta_str("task", "sst2").meta_num("steps", steps as f64);
    let mut finals: BTreeMap<String, (f64, Vec<TrainSummary>)> = BTreeMap::new();
    for opt in ["mezo", "conmezo"] {
        let mut cfg = ctx.cfg(ROBERTA_PRESET, "sst2", opt, steps, ROBERTA_ETA)?;
        cfg.eval_every = (steps / 10).max(1);
        let (acc, _, sums) = ctx.acc_over_seeds(&cfg)?;
        println!("{opt}: final acc {}", pct(acc));
        summary_rows(&mut rec, "sst2", opt, &sums);
        finals.insert(opt.to_string(), (acc, sums));
    }
    // crossover: step at which ConMeZO first exceeds MeZO's final accuracy
    let mezo_final = finals["mezo"].0;
    let con = &finals["conmezo"].1[0];
    if let Some((step, _)) = con.eval_curve.iter().find(|(_, a)| *a >= mezo_final) {
        println!(
            "ConMeZO reached MeZO's final accuracy at step {} of {} -> {:.2}x fewer iterations (paper: ~2x)",
            step,
            steps,
            steps as f64 / *step as f64
        );
        rec.meta_num("speedup", steps as f64 / *step as f64);
    } else {
        println!("ConMeZO did not cross MeZO's final accuracy within {steps} steps");
    }
    rec.save()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// table1 (+9/10/11) — RoBERTa-sim suite
// ---------------------------------------------------------------------------

fn table1(ctx: &Ctx) -> Result<()> {
    println!("\n=== Tables 1/9/10/11: RoBERTa-sim suite ({ROBERTA_PRESET} preset) ===");
    let steps = ctx.steps(ROBERTA_STEPS);
    let optimizers = ["adamw", "sgd", "mezo", "mezo_momentum", "conmezo"];
    let mut rec = RunRecord::new("table1");
    rec.meta_str("preset", ROBERTA_PRESET).meta_num("steps", steps as f64).meta_num("seeds", ctx.seeds.len() as f64);
    let mut cells: BTreeMap<(String, String), (f64, f64)> = BTreeMap::new();
    for task in ROBERTA_TASKS {
        for opt in optimizers {
            let mut cfg = ctx.cfg(ROBERTA_PRESET, task, opt, steps, ROBERTA_ETA)?;
            cfg.eval_every = (steps / 5).max(1); // intermediate rows = Table 11
            let (m, sd, sums) = ctx.acc_over_seeds(&cfg)?;
            summary_rows(&mut rec, task, opt, &sums);
            cells.insert((task.to_string(), opt.to_string()), (m, sd));
            println!("  {task:>5} / {opt:<14} acc {} ± {}", pct(m), pct(sd));
        }
    }
    let mut rows = Vec::new();
    let mut avgs: BTreeMap<&str, f64> = BTreeMap::new();
    for task in ROBERTA_TASKS {
        let mut row = vec![task.to_string()];
        for opt in optimizers {
            let (m, sd) = cells[&(task.to_string(), opt.to_string())];
            row.push(format!("{}±{}", pct(m), pct(sd)));
            *avgs.entry(opt).or_default() += m / ROBERTA_TASKS.len() as f64;
        }
        rows.push(row);
    }
    let mut avg_row = vec!["Average".to_string()];
    for opt in optimizers {
        avg_row.push(pct(avgs[opt]));
    }
    rows.push(avg_row);
    println!("\n{}", render_table(&["Task", "AdamW", "SGD", "MeZO", "Mom.", "ConMeZO"], &rows));
    println!("paper Table 1 shape: AdamW > ConMeZO > Mom. > MeZO on average");
    rec.save()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// table2 (+12/13) — OPT-sim suites
// ---------------------------------------------------------------------------

fn table2(ctx: &Ctx) -> Result<()> {
    println!("\n=== Tables 2/12/13: OPT-sim suites ===");
    let mut rec = RunRecord::new("table2");
    for (preset, tasks, steps, eta) in [
        (OPT_PRESET, &OPT_TASKS[..], ctx.steps(OPT_STEPS), OPT_ETA),
        (MED_PRESET, &MED_TASKS[..], ctx.steps(MED_STEPS), MED_ETA),
    ] {
        println!("--- preset {preset} ({} tasks, {steps} steps) ---", tasks.len());
        let mut rows = Vec::new();
        let mut avg = BTreeMap::from([("mezo", 0f64), ("conmezo", 0f64)]);
        for task in tasks {
            let mut row = vec![task.to_string()];
            for opt in ["mezo", "conmezo"] {
                let cfg = ctx.cfg(preset, task, opt, steps, eta)?;
                let (m, sd, sums) = ctx.acc_over_seeds(&cfg)?;
                summary_rows(&mut rec, &format!("{preset}/{task}"), opt, &sums);
                row.push(format!("{}±{}", pct(m), pct(sd)));
                *avg.get_mut(opt).unwrap() += m / tasks.len() as f64;
                println!("  {task:>8} / {opt:<8} acc {} ± {}", pct(m), pct(sd));
            }
            rows.push(row);
        }
        rows.push(vec!["Average".into(), pct(avg["mezo"]), pct(avg["conmezo"])]);
        println!("\n{}", render_table(&["Task", "MeZO", "ConMeZO"], &rows));
    }
    println!("paper Table 2 shape: ConMeZO >= MeZO on most tasks and on average");
    rec.save()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// table3 — wall-clock per step: loop-based MeZO vs fused/vectorized ConMeZO
// ---------------------------------------------------------------------------

fn table3(ctx: &Ctx) -> Result<()> {
    println!("\n=== Table 3: wall-clock per step (loop-based MeZO vs fused ConMeZO) ===");
    let mut rec = RunRecord::new("table3");
    let mut rows = Vec::new();
    for (preset, tasks, nsteps) in [
        ("nano", &ROBERTA_TASKS[..3], 150usize),
        ("tiny", &OPT_TASKS[..2], 50),
        ("small", &OPT_TASKS[..1], 12),
    ] {
        for task in tasks {
            let mut times: BTreeMap<&str, f64> = BTreeMap::new();
            for (opt, mode) in [("mezo_loop", Mode::Composed), ("mezo", Mode::Fused), ("conmezo", Mode::Fused)] {
                let mut cfg = ctx.cfg(preset, task, opt, nsteps + 1, ROBERTA_ETA)?;
                cfg.mode = mode;
                cfg.eval_every = usize::MAX / 2;
                cfg.log_every = usize::MAX / 2;
                let mut tr = Trainer::new(&ctx.rt, cfg)?;
                tr.step(0)?; // warm the executable cache
                let sw = conmezo::util::Stopwatch::start();
                for t in 1..=nsteps {
                    tr.step(t)?;
                }
                times.insert(opt, sw.secs() / nsteps as f64);
            }
            let loopy = times["mezo_loop"];
            let fused = times["conmezo"];
            let speedup = (loopy - fused) / loopy * 100.0;
            println!(
                "  {preset}/{task}: MeZO-loop {:.1} ms  MeZO-fused {:.1} ms  ConMeZO-fused {:.1} ms  speedup {:.1}%",
                loopy * 1e3,
                times["mezo"] * 1e3,
                fused * 1e3,
                speedup
            );
            rows.push(vec![
                format!("{preset}/{task}"),
                format!("{:.1}", loopy * 1e3),
                format!("{:.1}", times["mezo"] * 1e3),
                format!("{:.1}", fused * 1e3),
                format!("{speedup:.1}%"),
            ]);
            rec.row(vec![
                ("preset", Json::str(preset)),
                ("task", Json::str(*task)),
                ("mezo_loop_s", Json::num(loopy)),
                ("mezo_fused_s", Json::num(times["mezo"])),
                ("conmezo_fused_s", Json::num(fused)),
                ("speedup_pct", Json::num(speedup)),
            ]);
        }
    }
    println!(
        "\n{}",
        render_table(&["workload", "MeZO-loop ms", "MeZO-fused ms", "ConMeZO ms", "speedup"], &rows)
    );
    println!("paper Table 3 shape: fused ConMeZO per-step time < loop-based MeZO (3.6-7.9% on GPU)");
    rec.save()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// table8 / fig4 — peak memory accounting
// ---------------------------------------------------------------------------

fn table8(ctx: &Ctx) -> Result<()> {
    println!("\n=== Table 8 / Fig. 4: peak state memory (MiB) ===");
    let mut rec = RunRecord::new("table8");
    let mut rows = Vec::new();
    for preset in ["tiny", "small", "medium"] {
        let mut mems: BTreeMap<&str, f64> = BTreeMap::new();
        for opt in ["mezo", "conmezo", "adamw"] {
            // byte accounting does not need trained weights: skip the
            // pretrained warm start (medium's FO pretrain costs minutes)
            let mut cfg = TrainConfig::preset(preset, "sst2", opt);
            cfg.steps = 2;
            cfg.eval_every = usize::MAX / 2;
            let tr = Trainer::new(&ctx.rt, cfg)?;
            mems.insert(opt, tr.peak_mem_mib());
        }
        let delta = mems["conmezo"] - mems["mezo"];
        println!(
            "  {preset}: MeZO {:.1}  ConMeZO {:.1} (Δ {:.1})  AdamW {:.1}",
            mems["mezo"], mems["conmezo"], delta, mems["adamw"]
        );
        rows.push(vec![
            preset.to_string(),
            format!("{:.1}", mems["mezo"]),
            format!("{:.1}", mems["conmezo"]),
            format!("{delta:.1}"),
            format!("{:.1}", mems["adamw"]),
        ]);
        rec.row(vec![
            ("preset", Json::str(preset)),
            ("mezo_mib", Json::num(mems["mezo"])),
            ("conmezo_mib", Json::num(mems["conmezo"])),
            ("delta_mib", Json::num(delta)),
            ("adamw_mib", Json::num(mems["adamw"])),
        ]);
    }
    println!("\n{}", render_table(&["preset", "MeZO", "ConMeZO", "Δ", "AdamW"], &rows));
    println!("paper shape: ConMeZO = MeZO + one constant buffer; AdamW >> both");
    rec.save()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// tables 4-7 — recent-ZO-method comparisons
// ---------------------------------------------------------------------------

fn compare_table(
    ctx: &Ctx,
    name: &str,
    paper_note: &str,
    workloads: &[(&str, &str)],
    opts: &[&str],
    steps_base: usize,
) -> Result<()> {
    println!("\n=== {name}: {paper_note} ===");
    let mut rec = RunRecord::new(name);
    let mut rows = Vec::new();
    for (preset, task) in workloads {
        let steps = ctx.steps(steps_base);
        let mut row = vec![format!("{preset}/{task}")];
        for opt in opts {
            let eta = if *preset == "small" { MED_ETA } else { ROBERTA_ETA };
            let cfg = ctx.cfg(preset, task, opt, steps, eta)?;
            let sw = conmezo::util::Stopwatch::start();
            let (m, sd, sums) = ctx.acc_over_seeds(&cfg)?;
            let wall = sw.secs() / ctx.seeds.len() as f64;
            summary_rows(&mut rec, &format!("{preset}/{task}"), opt, &sums);
            row.push(format!("{}±{} ({:.0}s)", pct(m), pct(sd), wall));
            println!("  {preset}/{task} / {opt:<14} acc {} ± {}  wall {:.0}s", pct(m), pct(sd), wall);
        }
        rows.push(row);
    }
    let mut headers = vec!["workload"];
    headers.extend_from_slice(opts);
    println!("\n{}", render_table(&headers, &rows));
    rec.save()?;
    Ok(())
}

fn table4(ctx: &Ctx) -> Result<()> {
    compare_table(
        ctx,
        "table4",
        "HiZOO (3 evals/step) vs ConMeZO — paper: ConMeZO wins accuracy, ~2x faster wall-clock",
        &[("nano", "sst2"), ("nano", "rte")],
        &["hizoo", "conmezo"],
        2000,
    )
}

fn table5(ctx: &Ctx) -> Result<()> {
    compare_table(
        ctx,
        "table5",
        "LOZO/LOZO-M low-rank vs ConMeZO — paper: ConMeZO best average under equal wall-clock",
        &[("nano", "sst2"), ("nano", "trec"), ("nano", "mnli")],
        &["lozo", "lozo_m", "conmezo"],
        2000,
    )
}

fn table6(ctx: &Ctx) -> Result<()> {
    compare_table(
        ctx,
        "table6",
        "MeZO-SVRG vs ConMeZO — paper: ConMeZO matches/exceeds with far cheaper steps",
        &[("nano", "sst2"), ("nano", "mnli")],
        &["mezo_svrg", "conmezo"],
        2000,
    )
}

fn table7(ctx: &Ctx) -> Result<()> {
    compare_table(
        ctx,
        "table7",
        "ZO-AdaMM vs ConMeZO on SST-2 — paper: ConMeZO wins on both model scales",
        &[("nano", "sst2"), ("tiny", "sst2")],
        &["zo_adamm", "conmezo"],
        2000,
    )
}

// ---------------------------------------------------------------------------
// table14 — warm-up ablation
// ---------------------------------------------------------------------------

fn table14(ctx: &Ctx) -> Result<()> {
    println!("\n=== Table 14: momentum warm-up ablation ===");
    let steps = ctx.steps(4000);
    let tasks = ["sst2", "mnli", "trec"];
    let mut rec = RunRecord::new("table14");
    let mut rows = Vec::new();
    let mut avgs = [0f64; 3];
    for task in tasks {
        let mut row = vec![task.to_string()];
        for (i, (label, opt, warmup)) in [
            ("mezo", "mezo", false),
            ("conmezo-nowarm", "conmezo", false),
            ("conmezo-warm", "conmezo", true),
        ]
        .iter()
        .enumerate()
        {
            let mut cfg = ctx.cfg(ROBERTA_PRESET, task, opt, steps, ROBERTA_ETA)?;
            cfg.warmup = *warmup;
            let (m, sd, sums) = ctx.acc_over_seeds(&cfg)?;
            summary_rows(&mut rec, task, label, &sums);
            row.push(format!("{}±{}", pct(m), pct(sd)));
            avgs[i] += m / tasks.len() as f64;
            println!("  {task:>5} / {label:<15} acc {} ± {}", pct(m), pct(sd));
        }
        rows.push(row);
    }
    rows.push(vec!["Average".into(), pct(avgs[0]), pct(avgs[1]), pct(avgs[2])]);
    println!("\n{}", render_table(&["Task", "MeZO", "ConMeZO (no warmup)", "ConMeZO (warmup)"], &rows));
    println!("paper shape: warmup >= no-warmup >= MeZO on average");
    rec.save()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// fig5 — theta x beta heatmap on trec-sim
// ---------------------------------------------------------------------------

fn fig5(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig. 5: theta x beta heatmap (trec-sim) ===");
    let thetas = [0.9f32, 1.2, 1.35, 1.5];
    let betas = [0.5f32, 0.9, 0.95, 0.99];
    let steps = ctx.steps(3000);
    let mid = (steps / 3).max(1); // the "after 1K iters" early snapshot
    let mut rec = RunRecord::new("fig5");
    rec.meta_num("steps", steps as f64).meta_num("early_step", mid as f64);
    println!("rows = theta {thetas:?}, cols = beta {betas:?}; cell = early/final accuracy");
    let mut rows = Vec::new();
    for &theta in &thetas {
        let mut row = vec![format!("θ={theta}")];
        for &beta in &betas {
            let mut cfg = ctx.cfg(ROBERTA_PRESET, "trec", "conmezo", steps, ROBERTA_ETA)?;
            cfg.theta = theta;
            cfg.beta_final = beta;
            cfg.warmup = false; // isolate the raw (theta, beta) response
            cfg.eval_every = mid;
            cfg.seed = ctx.seeds[0];
            let summary = Trainer::new(&ctx.rt, cfg)?.run()?;
            let early = summary.eval_curve.first().map(|x| x.1).unwrap_or(f64::NAN);
            row.push(format!("{}/{}", pct(early), pct(summary.final_accuracy)));
            rec.row(vec![
                ("theta", Json::num(theta as f64)),
                ("beta", Json::num(beta as f64)),
                ("early_acc", Json::num(early)),
                ("final_acc", Json::num(summary.final_accuracy)),
            ]);
        }
        rows.push(row);
    }
    let headers: Vec<String> = std::iter::once("".to_string())
        .chain(betas.iter().map(|b| format!("β={b}")))
        .collect();
    let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    println!("\n{}", render_table(&h, &rows));
    rec.save()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// fig6 — cos^2(momentum, true gradient) during training
// ---------------------------------------------------------------------------

fn fig6(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig. 6: squared cosine similarity momentum vs true gradient ===");
    let steps = ctx.steps(3000);
    let mut rec = RunRecord::new("fig6");
    for beta in [0.9f32, 0.99] {
        let mut cfg = ctx.cfg(ROBERTA_PRESET, "sst2", "conmezo", steps, ROBERTA_ETA)?;
        cfg.beta_final = beta;
        cfg.warmup = false;
        cfg.probe_cos2 = true;
        cfg.eval_every = (steps / 12).max(1);
        cfg.seed = ctx.seeds[0];
        let summary = Trainer::new(&ctx.rt, cfg)?.run()?;
        let d = ctx.rt.preset(ROBERTA_PRESET)?.d_raw as f64;
        let mean_cos2: f64 =
            summary.cos2_curve.iter().map(|x| x.1).sum::<f64>() / summary.cos2_curve.len().max(1) as f64;
        println!(
            "  beta={beta}: mean cos2 {:.2e} vs random-direction baseline 1/d = {:.2e}  ({:.1}x better)",
            mean_cos2,
            1.0 / d,
            mean_cos2 * d
        );
        for (t, c) in &summary.cos2_curve {
            rec.row(vec![
                ("beta", Json::num(beta as f64)),
                ("step", Json::num(*t as f64)),
                ("cos2", Json::num(*c)),
                ("one_over_d", Json::num(1.0 / d)),
            ]);
        }
    }
    println!("paper shape: momentum alignment well above the 1/d random baseline");
    rec.save()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// fig7 — accuracy curves for the suite (table1 geometry, denser evals)
// ---------------------------------------------------------------------------

fn fig7(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig. 7: accuracy-vs-step curves (tiny suite) ===");
    let steps = ctx.steps(ROBERTA_STEPS);
    let mut rec = RunRecord::new("fig7");
    for task in ROBERTA_TASKS {
        for opt in ["mezo", "conmezo"] {
            let mut cfg = ctx.cfg(ROBERTA_PRESET, task, opt, steps, ROBERTA_ETA)?;
            cfg.eval_every = (steps / 10).max(1);
            cfg.seed = ctx.seeds[0];
            let summary = Trainer::new(&ctx.rt, cfg)?.run()?;
            let last = summary.final_accuracy;
            println!("  {task:>5} / {opt:<8} final acc {}", pct(last));
            summary_rows(&mut rec, task, opt, &[summary]);
        }
    }
    rec.save()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// fig8 — warm-up schedule dump
// ---------------------------------------------------------------------------

fn fig8(_ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig. 8: momentum warm-up schedule (20K-step run, beta=0.99) ===");
    let s = BetaSchedule::PaperWarmup { beta_final: 0.99, total_steps: 20_000 };
    let mut rec = RunRecord::new("fig8");
    let mut sample = Vec::new();
    for t in (0..=20_000).step_by(100) {
        let b = s.at(t);
        sample.push((t, b));
        rec.row(vec![("step", Json::num(t as f64)), ("beta", Json::num(b as f64))]);
    }
    for (t, b) in sample.iter().step_by(10) {
        let bar = "#".repeat((b * 60.0) as usize);
        println!("{t:>6} {b:.3} {bar}");
    }
    rec.save()?;
    Ok(())
}

// ---------------------------------------------------------------------------

fn main() -> Result<()> {
    let app = App::new("repro", "regenerate the paper's tables and figures")
        .subcommand("fig1", "learning curve squad-sim")
        .subcommand("fig3", "synthetic quadratic")
        .subcommand("fig5", "theta x beta heatmap")
        .subcommand("fig6", "momentum/gradient alignment")
        .subcommand("fig7", "suite accuracy curves")
        .subcommand("fig8", "warm-up schedule")
        .subcommand("table1", "RoBERTa-sim suite")
        .subcommand("table2", "OPT-sim suites")
        .subcommand("table3", "wall-clock per step")
        .subcommand("table4", "HiZOO comparison")
        .subcommand("table5", "LOZO comparison")
        .subcommand("table6", "MeZO-SVRG comparison")
        .subcommand("table7", "ZO-AdaMM comparison")
        .subcommand("table8", "memory accounting")
        .subcommand("table14", "warm-up ablation")
        .subcommand("all", "everything")
        .opt_default("seeds", "2", "number of seeds per cell")
        .opt_default("scale", "1.0", "step-count scale factor")
        .opt_default("backend", "auto", "execution backend (native|pjrt|auto)");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = match app.parse(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let n_seeds = p.usize_or("seeds", 2);
    let ctx = Ctx {
        rt: Runtime::from_name(&p.str_or("backend", "auto"))?,
        seeds: (0..n_seeds as u64).map(|i| 42 + 1000 * i).collect(),
        scale: p.f64_or("scale", 1.0),
    };
    let sw = conmezo::util::Stopwatch::start();
    match p.subcommand.as_str() {
        "fig1" => fig1(&ctx)?,
        "fig3" => fig3(&ctx)?,
        "fig5" => fig5(&ctx)?,
        "fig6" => fig6(&ctx)?,
        "fig7" => fig7(&ctx)?,
        "fig8" => fig8(&ctx)?,
        "table1" => table1(&ctx)?,
        "table2" => table2(&ctx)?,
        "table3" => table3(&ctx)?,
        "table4" => table4(&ctx)?,
        "table5" => table5(&ctx)?,
        "table6" => table6(&ctx)?,
        "table7" => table7(&ctx)?,
        "table8" => table8(&ctx)?,
        "table14" => table14(&ctx)?,
        "all" => {
            fig8(&ctx)?;
            table8(&ctx)?;
            fig3(&ctx)?;
            table3(&ctx)?;
            fig6(&ctx)?;
            fig5(&ctx)?;
            fig1(&ctx)?;
            table4(&ctx)?;
            table5(&ctx)?;
            table6(&ctx)?;
            table7(&ctx)?;
            table14(&ctx)?;
            table1(&ctx)?;
            fig7(&ctx)?;
            table2(&ctx)?;
        }
        other => bail!("unknown experiment {other:?}; see --help"),
    }
    println!("\n[repro] finished in {:.1}s; records in results/", sw.secs());
    Ok(())
}
