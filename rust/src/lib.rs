//! # ConMeZO — gradient-free LLM finetuning, three-layer reproduction
//!
//! Rust L3 coordinator for the AISTATS 2026 paper *ConMeZO: Adaptive
//! Descent-Direction Sampling for Gradient-Free Finetuning of Large
//! Language Models*. The `runtime` module executes the manifest's program
//! set on a pluggable [`runtime::Backend`]:
//!
//! * **native** (default): a pure-Rust transformer forward + fused ZO step
//!   emulation built on `vecmath` — zero external dependencies, the whole
//!   train/eval/distributed stack runs offline with no Python or XLA;
//! * **pjrt** (cargo feature `pjrt`): the AOT HLO artifacts produced by
//!   `python/compile/aot.py` (L2 JAX graphs + L1 Pallas kernels), executed
//!   on the PJRT CPU client via the external `xla` crate.
//!
//! On top of that sit the optimizer family (`optimizer`), the training
//! orchestration and the O(1)-bytes/step distributed shared-randomness
//! trainer (`coordinator`), the zero-overhead instrumentation layer
//! (`telemetry`: per-`Runtime` metric registry, phase spans, step traces,
//! cluster health), plus every substrate the offline environment lacks
//! (`util`, `config`, `cli`, `vecmath`, `net`, `checkpoint`, `bench`,
//! `testing`).
//!
//! Quick start (no artifacts needed): see `examples/quickstart.rs`.

// Style lints that fight the flat-buffer kernel idiom this crate is built
// on (index-driven loops over strided f32 buffers, wide kernel signatures):
// allowed crate-wide so CI can hold `clippy -- -D warnings` on everything
// else.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::type_complexity
)]

pub mod bench;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod net;
pub mod objective;
pub mod optimizer;
pub mod parallel;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod testing;
pub mod util;
pub mod vecmath;
