//! # ConMeZO — gradient-free LLM finetuning, three-layer reproduction
//!
//! Rust L3 coordinator for the AISTATS 2026 paper *ConMeZO: Adaptive
//! Descent-Direction Sampling for Gradient-Free Finetuning of Large
//! Language Models*. The compute graph (L2, JAX) and kernels (L1, Pallas)
//! are AOT-compiled to HLO text by `python/compile/aot.py`; this crate
//! loads and executes them via PJRT (`runtime`), implements the optimizer
//! family (`optimizer`), the training orchestration and the O(1)-bytes/step
//! distributed shared-randomness trainer (`coordinator`), plus every
//! substrate the offline environment lacks (`util`, `config`, `cli`,
//! `vecmath`, `net`, `checkpoint`, `bench`, `testing`).
//!
//! Quick start (after `make artifacts`): see `examples/quickstart.rs`.

pub mod bench;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod net;
pub mod objective;
pub mod optimizer;
pub mod runtime;
pub mod testing;
pub mod util;
pub mod vecmath;
