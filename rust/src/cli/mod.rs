//! Declarative CLI argument parser (clap is not vendored offline).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, repeated
//! options, positional args, and auto-generated help text.

use std::collections::BTreeMap;

use crate::util::error::{bail, Result};

#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub repeated: bool,
    pub default: Option<&'static str>,
}

#[derive(Default, Debug)]
pub struct Parsed {
    pub subcommand: String,
    opts: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn flag(&self, name: &str) -> bool {
        self.opts.contains_key(name)
    }

    pub fn value(&self, name: &str) -> Option<&str> {
        self.opts.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn values(&self, name: &str) -> Vec<&str> {
        self.opts
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.value(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.value(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.value(name).unwrap_or(default).to_string()
    }
}

pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub subcommands: Vec<(&'static str, &'static str)>,
    pub opts: Vec<OptSpec>,
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        App { name, about, subcommands: Vec::new(), opts: Vec::new() }
    }

    pub fn subcommand(mut self, name: &'static str, help: &'static str) -> Self {
        self.subcommands.push((name, help));
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, repeated: false, default: None });
        self
    }

    pub fn opt_default(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, repeated: false, default: Some(default) });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false, repeated: false, default: None });
        self
    }

    pub fn repeated(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, repeated: true, default: None });
        self
    }

    pub fn usage(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        if !self.subcommands.is_empty() {
            let _ = writeln!(s, "USAGE: {} <subcommand> [options]\n\nSUBCOMMANDS:", self.name);
            for (n, h) in &self.subcommands {
                let _ = writeln!(s, "  {n:<18} {h}");
            }
            let _ = writeln!(s);
        }
        let _ = writeln!(s, "OPTIONS:");
        for o in &self.opts {
            let meta = if o.takes_value { format!("--{} <v>", o.name) } else { format!("--{}", o.name) };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            let _ = writeln!(s, "  {meta:<22} {}{def}", o.help);
        }
        s
    }

    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        let mut p = Parsed::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                p.opts.insert(o.name.to_string(), vec![d.to_string()]);
            }
        }
        let mut it = args.iter().peekable();
        // subcommand first if declared
        if !self.subcommands.is_empty() {
            match it.peek() {
                Some(s) if !s.starts_with('-') => {
                    let sub = it.next().unwrap().clone();
                    if !self.subcommands.iter().any(|(n, _)| *n == sub) {
                        bail!("unknown subcommand {sub:?}\n\n{}", self.usage());
                    }
                    p.subcommand = sub;
                }
                _ => {}
            }
        }
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| crate::anyhow!("unknown option --{name}\n\n{}", self.usage()))?;
                let val = if !spec.takes_value {
                    if inline.is_some() {
                        bail!("flag --{name} takes no value");
                    }
                    "true".to_string()
                } else if let Some(v) = inline {
                    v
                } else {
                    it.next()
                        .ok_or_else(|| crate::anyhow!("option --{name} requires a value"))?
                        .clone()
                };
                let entry = p.opts.entry(name.clone()).or_default();
                if spec.repeated {
                    // keep defaults out of repeated accumulation
                    if spec.default.map(|d| entry.len() == 1 && entry[0] == d).unwrap_or(false) {
                        entry.clear();
                    }
                    entry.push(val);
                } else {
                    *entry = vec![val];
                }
            } else {
                p.positional.push(a.clone());
            }
        }
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("t", "test")
            .subcommand("run", "run it")
            .subcommand("list", "list things")
            .opt_default("steps", "100", "step count")
            .opt("config", "config path")
            .flag("verbose", "noisy")
            .repeated("set", "overrides")
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let p = app().parse(&sv(&["run", "--steps", "5", "--verbose", "x.toml"])).unwrap();
        assert_eq!(p.subcommand, "run");
        assert_eq!(p.usize_or("steps", 0), 5);
        assert!(p.flag("verbose"));
        assert_eq!(p.positional, vec!["x.toml"]);
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let p = app().parse(&sv(&["run", "--config=a.toml"])).unwrap();
        assert_eq!(p.value("config"), Some("a.toml"));
        assert_eq!(p.usize_or("steps", 0), 100); // default
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn repeated_options_accumulate() {
        let p = app().parse(&sv(&["run", "--set", "a=1", "--set", "b=2"])).unwrap();
        assert_eq!(p.values("set"), vec!["a=1", "b=2"]);
    }

    #[test]
    fn unknown_rejected() {
        assert!(app().parse(&sv(&["bogus"])).is_err());
        assert!(app().parse(&sv(&["run", "--nope"])).is_err());
        assert!(app().parse(&sv(&["run", "--config"])).is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = app().parse(&sv(&["--help"])).unwrap_err().to_string();
        assert!(err.contains("SUBCOMMANDS"));
        assert!(err.contains("--steps"));
    }
}
