//! Fused execution engines: one runtime program per optimizer step.
//!
//! This is the paper's §3.3 hot path — direction sampling (seed replay),
//! cone construction, both forward passes and the fused parameter+momentum
//! update all execute inside a single bound program; Rust only moves the
//! state buffers and O(1) scalars. Every engine owns its step program as a
//! [`Session`] (bind once at construction, run every step over reused
//! workspaces and bind-time-resolved layout offsets — zero steady-state
//! allocation on the native backend, with GEMMs + attention dispatched
//! onto the `Runtime`'s one persistent `WorkerPool`). Semantically
//! equivalent to the composed-mode optimizers (cross-checked in
//! rust/tests/).

use crate::util::error::Result;

use crate::objective::Batch;
use crate::runtime::{lit_copy_f32, lit_f32, Arg, Runtime, Session};

/// Outcome of one fused step.
#[derive(Clone, Copy, Debug)]
pub struct FusedStats {
    pub loss: f64,
    pub proj_grad: f64,
}

fn batch_args(batch: &Batch) -> [Arg<'_>; 3] {
    let dims = [batch.batch, batch.seq];
    [
        Arg::TensorI32(&batch.input_ids, vec![dims[0], dims[1]]),
        Arg::TensorI32(&batch.targets, vec![dims[0], dims[1]]),
        Arg::TensorF32(&batch.mask, vec![dims[0], dims[1]]),
    ]
}

/// Fused ConMeZO (Algorithm 1): `{preset}_conmezo_step`.
pub struct FusedConMeZo {
    sess: Box<dyn Session>,
    sample_u: Box<dyn Session>,
    /// momentum buffer (device round-trips through host each step on this
    /// CPU testbed; see EXPERIMENTS.md §Perf for the measured overhead)
    pub m: Vec<f32>,
    pub theta: f32,
    started: bool,
}

impl FusedConMeZo {
    pub fn new(rt: &Runtime, preset: &str, theta: f32) -> Result<Self> {
        let meta = rt.preset(preset)?;
        let d_pad = meta.d_pad;
        Ok(FusedConMeZo {
            sess: rt.bind_kind(preset, "conmezo_step")?,
            sample_u: rt.bind_kind(preset, "sample_u")?,
            m: vec![0.0; d_pad],
            theta,
            started: false,
        })
    }

    pub fn step(
        &mut self,
        params: &mut [f32],
        batch: &Batch,
        seed: i32,
        beta: f32,
        eta: f32,
        lam: f32,
    ) -> Result<FusedStats> {
        if !self.started {
            // Algorithm 1: m_0 <- u_0, regenerated from the same seed the
            // step program will use for u at t=0
            let outs = self.sample_u.run(&[Arg::I32(seed)])?;
            lit_copy_f32(&outs[0], &mut self.m)?;
            self.started = true;
        }
        let [ids, tgt, mask] = batch_args(batch);
        let outs = self.sess.run(&[
            Arg::VecF32(params),
            Arg::VecF32(&self.m),
            Arg::I32(seed),
            Arg::F32(self.theta),
            Arg::F32(beta),
            Arg::F32(eta),
            Arg::F32(lam),
            ids,
            tgt,
            mask,
        ])?;
        lit_copy_f32(&outs[0], params)?;
        let lp = lit_f32(&outs[2])? as f64;
        let lm = lit_f32(&outs[3])? as f64;
        let g = lit_f32(&outs[4])? as f64;
        let m_new = &outs[1];
        lit_copy_f32(m_new, &mut self.m)?;
        Ok(FusedStats { loss: 0.5 * (lp + lm), proj_grad: g })
    }
}

/// Fused MeZO: `{preset}_mezo_step`.
pub struct FusedMezo {
    sess: Box<dyn Session>,
}

impl FusedMezo {
    pub fn new(rt: &Runtime, preset: &str) -> Result<Self> {
        Ok(FusedMezo { sess: rt.bind_kind(preset, "mezo_step")? })
    }

    pub fn step(&mut self, params: &mut [f32], batch: &Batch, seed: i32, eta: f32, lam: f32) -> Result<FusedStats> {
        let [ids, tgt, mask] = batch_args(batch);
        let outs = self.sess.run(&[
            Arg::VecF32(params),
            Arg::I32(seed),
            Arg::F32(eta),
            Arg::F32(lam),
            ids,
            tgt,
            mask,
        ])?;
        lit_copy_f32(&outs[0], params)?;
        let lp = lit_f32(&outs[1])? as f64;
        let lm = lit_f32(&outs[2])? as f64;
        let g = lit_f32(&outs[3])? as f64;
        Ok(FusedStats { loss: 0.5 * (lp + lm), proj_grad: g })
    }
}

/// Fused MeZO+Momentum: `{preset}_mezo_momentum_step`.
pub struct FusedMezoMomentum {
    sess: Box<dyn Session>,
    pub m: Vec<f32>,
}

impl FusedMezoMomentum {
    pub fn new(rt: &Runtime, preset: &str) -> Result<Self> {
        let d_pad = rt.preset(preset)?.d_pad;
        Ok(FusedMezoMomentum { sess: rt.bind_kind(preset, "mezo_momentum_step")?, m: vec![0.0; d_pad] })
    }

    pub fn step(
        &mut self,
        params: &mut [f32],
        batch: &Batch,
        seed: i32,
        beta: f32,
        eta: f32,
        lam: f32,
    ) -> Result<FusedStats> {
        let [ids, tgt, mask] = batch_args(batch);
        let outs = self.sess.run(&[
            Arg::VecF32(params),
            Arg::VecF32(&self.m),
            Arg::I32(seed),
            Arg::F32(beta),
            Arg::F32(eta),
            Arg::F32(lam),
            ids,
            tgt,
            mask,
        ])?;
        lit_copy_f32(&outs[0], params)?;
        let lp = lit_f32(&outs[2])? as f64;
        let lm = lit_f32(&outs[3])? as f64;
        let g = lit_f32(&outs[4])? as f64;
        let m_new = &outs[1];
        lit_copy_f32(m_new, &mut self.m)?;
        Ok(FusedStats { loss: 0.5 * (lp + lm), proj_grad: g })
    }
}

/// First-order engines (Tables 1 & 9, Fig. 4): ordinary manifest programs
/// on every backend — build-time `jax.grad` traces on pjrt, the native
/// reverse-mode pass (`runtime::autograd`, tape workspace reused across
/// steps) on the default backend.
pub struct FoSgd {
    sess: Box<dyn Session>,
}

impl FoSgd {
    pub fn new(rt: &Runtime, preset: &str) -> Result<Self> {
        Ok(FoSgd { sess: rt.bind_kind(preset, "fo_sgd_step")? })
    }

    pub fn step(&mut self, params: &mut [f32], batch: &Batch, eta: f32) -> Result<f64> {
        let [ids, tgt, mask] = batch_args(batch);
        let outs = self.sess.run(&[Arg::VecF32(params), Arg::F32(eta), ids, tgt, mask])?;
        lit_copy_f32(&outs[0], params)?;
        Ok(lit_f32(&outs[1])? as f64)
    }
}

pub struct FoAdamW {
    sess: Box<dyn Session>,
    pub mu: Vec<f32>,
    pub nu: Vec<f32>,
    pub t: f32,
}

impl FoAdamW {
    pub fn new(rt: &Runtime, preset: &str) -> Result<Self> {
        let d_pad = rt.preset(preset)?.d_pad;
        Ok(FoAdamW {
            sess: rt.bind_kind(preset, "fo_adamw_step")?,
            mu: vec![0.0; d_pad],
            nu: vec![0.0; d_pad],
            t: 0.0,
        })
    }

    pub fn step(&mut self, params: &mut [f32], batch: &Batch, eta: f32) -> Result<f64> {
        self.t += 1.0;
        let [ids, tgt, mask] = batch_args(batch);
        let outs = self.sess.run(&[
            Arg::VecF32(params),
            Arg::VecF32(&self.mu),
            Arg::VecF32(&self.nu),
            Arg::F32(self.t),
            Arg::F32(eta),
            ids,
            tgt,
            mask,
        ])?;
        lit_copy_f32(&outs[0], params)?;
        let loss = lit_f32(&outs[3])? as f64;
        let (mu_new, nu_new) = (&outs[1], &outs[2]);
        lit_copy_f32(mu_new, &mut self.mu)?;
        lit_copy_f32(nu_new, &mut self.nu)?;
        Ok(loss)
    }
}

/// Fig. 6 probe: cos^2(m, grad f) via the bound `grad_cos2` program.
/// (`RefCell` keeps the probe callable through `&self` from the trainer's
/// eval loop; single-threaded, never re-entered.)
pub struct GradProbe {
    sess: std::cell::RefCell<Box<dyn Session>>,
}

impl GradProbe {
    pub fn new(rt: &Runtime, preset: &str) -> Result<Self> {
        Ok(GradProbe { sess: std::cell::RefCell::new(rt.bind_kind(preset, "grad_cos2")?) })
    }

    pub fn cos2(&self, params: &[f32], m: &[f32], batch: &Batch) -> Result<f64> {
        let [ids, tgt, mask] = batch_args(batch);
        let mut sess = self.sess.borrow_mut();
        let outs = sess.run(&[Arg::VecF32(params), Arg::VecF32(m), ids, tgt, mask])?;
        Ok(lit_f32(&outs[0])? as f64)
    }
}
