//! Fused execution engines: one runtime program per optimizer step.
//!
//! This is the paper's §3.3 hot path — direction sampling (seed replay),
//! cone construction, both forward passes and the fused parameter+momentum
//! update all execute inside a single bound program; Rust only moves the
//! state buffers and O(1) scalars. Every engine owns its step program as a
//! [`Session`] (bind once at construction, run every step over reused
//! workspaces and bind-time-resolved layout offsets — zero steady-state
//! allocation on the native backend, with GEMMs + attention dispatched
//! onto the `Runtime`'s one persistent `WorkerPool`). Semantically
//! equivalent to the composed-mode optimizers (cross-checked in
//! rust/tests/).

use crate::util::error::Result;

use crate::objective::Batch;
use crate::runtime::{lit_copy_f32, lit_f32, Arg, Runtime, Session, Value};

/// Outcome of one fused step.
#[derive(Clone, Copy, Debug)]
pub struct FusedStats {
    pub loss: f64,
    pub proj_grad: f64,
    /// f(x + lam z) — the `+` arm of the antithetic pair
    pub loss_plus: f64,
    /// f(x - lam z)
    pub loss_minus: f64,
    /// cosine between the step direction z and the pre-step momentum;
    /// `NaN` when unavailable (no momentum buffer, degenerate g, or cosine
    /// telemetry disabled — see [`FusedConMeZo::trace_cos`])
    pub cos_zm: f64,
}

impl FusedStats {
    fn new(lp: f64, lm: f64, g: f64) -> FusedStats {
        FusedStats {
            loss: 0.5 * (lp + lm),
            proj_grad: g,
            loss_plus: lp,
            loss_minus: lm,
            cos_zm: f64::NAN,
        }
    }
}

/// cos(z, m_old) reconstructed WITHOUT materializing z: the momentum
/// update is `m' = beta m + (1-beta) g z`, so `(1-beta) g z = m' - beta m`
/// and the cosine needs only three dot products over the two momentum
/// buffers (the sign of the scalar `(1-beta) g` flips the direction).
/// Returns `NaN` when degenerate (g ~ 0, beta = 1, or zero norms).
fn cos_z_momentum(m_new: &[f32], m_old: &[f32], beta: f64, g: f64) -> f64 {
    let scale = (1.0 - beta) * g;
    if !scale.is_finite() || scale == 0.0 {
        return f64::NAN;
    }
    let (mut ww, mut wv, mut vv) = (0f64, 0f64, 0f64);
    for (&w, &v) in m_new.iter().zip(m_old) {
        let (w, v) = (w as f64, v as f64);
        ww += w * w;
        wv += w * v;
        vv += v * v;
    }
    // |z|^2 (1-beta)^2 g^2 = |m' - beta m|^2
    let zz = ww - 2.0 * beta * wv + beta * beta * vv;
    let den = zz.max(0.0).sqrt() * vv.sqrt();
    if den <= 0.0 || !den.is_finite() {
        return f64::NAN;
    }
    (scale.signum() * (wv - beta * vv) / den).clamp(-1.0, 1.0)
}

fn batch_args(batch: &Batch) -> [Arg<'_>; 3] {
    let dims = [batch.batch, batch.seq];
    [
        Arg::TensorI32(&batch.input_ids, vec![dims[0], dims[1]]),
        Arg::TensorI32(&batch.targets, vec![dims[0], dims[1]]),
        Arg::TensorF32(&batch.mask, vec![dims[0], dims[1]]),
    ]
}

/// Fused ConMeZO (Algorithm 1): `{preset}_conmezo_step`.
pub struct FusedConMeZo {
    sess: Box<dyn Session>,
    sample_u: Box<dyn Session>,
    /// momentum buffer (device round-trips through host each step on this
    /// CPU testbed; see EXPERIMENTS.md §Perf for the measured overhead)
    pub m: Vec<f32>,
    pub theta: f32,
    /// when set, every step also reports `cos(z, m)` in its stats (three
    /// extra length-d dot products; off by default so untraced runs pay
    /// nothing)
    pub trace_cos: bool,
    started: bool,
}

impl FusedConMeZo {
    pub fn new(rt: &Runtime, preset: &str, theta: f32) -> Result<Self> {
        let meta = rt.preset(preset)?;
        let d_pad = meta.d_pad;
        Ok(FusedConMeZo {
            sess: rt.bind_kind(preset, "conmezo_step")?,
            sample_u: rt.bind_kind(preset, "sample_u")?,
            m: vec![0.0; d_pad],
            theta,
            trace_cos: false,
            started: false,
        })
    }

    pub fn step(
        &mut self,
        params: &mut [f32],
        batch: &Batch,
        seed: i32,
        beta: f32,
        eta: f32,
        lam: f32,
    ) -> Result<FusedStats> {
        if !self.started {
            // Algorithm 1: m_0 <- u_0, regenerated from the same seed the
            // step program will use for u at t=0
            let outs = self.sample_u.run(&[Arg::I32(seed)])?;
            lit_copy_f32(&outs[0], &mut self.m)?;
            self.started = true;
        }
        let [ids, tgt, mask] = batch_args(batch);
        let outs = self.sess.run(&[
            Arg::VecF32(params),
            Arg::VecF32(&self.m),
            Arg::I32(seed),
            Arg::F32(self.theta),
            Arg::F32(beta),
            Arg::F32(eta),
            Arg::F32(lam),
            ids,
            tgt,
            mask,
        ])?;
        lit_copy_f32(&outs[0], params)?;
        let lp = lit_f32(&outs[2])? as f64;
        let lm = lit_f32(&outs[3])? as f64;
        let g = lit_f32(&outs[4])? as f64;
        let mut stats = FusedStats::new(lp, lm, g);
        let m_new = &outs[1];
        if self.trace_cos {
            if let Value::F32(w) = m_new {
                stats.cos_zm = cos_z_momentum(w, &self.m, beta as f64, g);
            }
        }
        lit_copy_f32(m_new, &mut self.m)?;
        Ok(stats)
    }
}

/// Fused MeZO: `{preset}_mezo_step`.
pub struct FusedMezo {
    sess: Box<dyn Session>,
}

impl FusedMezo {
    pub fn new(rt: &Runtime, preset: &str) -> Result<Self> {
        Ok(FusedMezo { sess: rt.bind_kind(preset, "mezo_step")? })
    }

    pub fn step(&mut self, params: &mut [f32], batch: &Batch, seed: i32, eta: f32, lam: f32) -> Result<FusedStats> {
        let [ids, tgt, mask] = batch_args(batch);
        let outs = self.sess.run(&[
            Arg::VecF32(params),
            Arg::I32(seed),
            Arg::F32(eta),
            Arg::F32(lam),
            ids,
            tgt,
            mask,
        ])?;
        lit_copy_f32(&outs[0], params)?;
        let lp = lit_f32(&outs[1])? as f64;
        let lm = lit_f32(&outs[2])? as f64;
        let g = lit_f32(&outs[3])? as f64;
        Ok(FusedStats::new(lp, lm, g))
    }
}

/// Fused MeZO+Momentum: `{preset}_mezo_momentum_step`.
pub struct FusedMezoMomentum {
    sess: Box<dyn Session>,
    pub m: Vec<f32>,
    /// when set, every step also reports `cos(z, m)` in its stats (same
    /// reconstruction as [`FusedConMeZo::trace_cos`]; off by default)
    pub trace_cos: bool,
}

impl FusedMezoMomentum {
    pub fn new(rt: &Runtime, preset: &str) -> Result<Self> {
        let d_pad = rt.preset(preset)?.d_pad;
        Ok(FusedMezoMomentum {
            sess: rt.bind_kind(preset, "mezo_momentum_step")?,
            m: vec![0.0; d_pad],
            trace_cos: false,
        })
    }

    pub fn step(
        &mut self,
        params: &mut [f32],
        batch: &Batch,
        seed: i32,
        beta: f32,
        eta: f32,
        lam: f32,
    ) -> Result<FusedStats> {
        let [ids, tgt, mask] = batch_args(batch);
        let outs = self.sess.run(&[
            Arg::VecF32(params),
            Arg::VecF32(&self.m),
            Arg::I32(seed),
            Arg::F32(beta),
            Arg::F32(eta),
            Arg::F32(lam),
            ids,
            tgt,
            mask,
        ])?;
        lit_copy_f32(&outs[0], params)?;
        let lp = lit_f32(&outs[2])? as f64;
        let lm = lit_f32(&outs[3])? as f64;
        let g = lit_f32(&outs[4])? as f64;
        let mut stats = FusedStats::new(lp, lm, g);
        let m_new = &outs[1];
        if self.trace_cos {
            if let Value::F32(w) = m_new {
                stats.cos_zm = cos_z_momentum(w, &self.m, beta as f64, g);
            }
        }
        lit_copy_f32(m_new, &mut self.m)?;
        Ok(stats)
    }
}

/// First-order engines (Tables 1 & 9, Fig. 4): ordinary manifest programs
/// on every backend — build-time `jax.grad` traces on pjrt, the native
/// reverse-mode pass (`runtime::autograd`, tape workspace reused across
/// steps) on the default backend.
pub struct FoSgd {
    sess: Box<dyn Session>,
}

impl FoSgd {
    pub fn new(rt: &Runtime, preset: &str) -> Result<Self> {
        Ok(FoSgd { sess: rt.bind_kind(preset, "fo_sgd_step")? })
    }

    pub fn step(&mut self, params: &mut [f32], batch: &Batch, eta: f32) -> Result<f64> {
        let [ids, tgt, mask] = batch_args(batch);
        let outs = self.sess.run(&[Arg::VecF32(params), Arg::F32(eta), ids, tgt, mask])?;
        lit_copy_f32(&outs[0], params)?;
        Ok(lit_f32(&outs[1])? as f64)
    }
}

pub struct FoAdamW {
    sess: Box<dyn Session>,
    pub mu: Vec<f32>,
    pub nu: Vec<f32>,
    pub t: f32,
}

impl FoAdamW {
    pub fn new(rt: &Runtime, preset: &str) -> Result<Self> {
        let d_pad = rt.preset(preset)?.d_pad;
        Ok(FoAdamW {
            sess: rt.bind_kind(preset, "fo_adamw_step")?,
            mu: vec![0.0; d_pad],
            nu: vec![0.0; d_pad],
            t: 0.0,
        })
    }

    pub fn step(&mut self, params: &mut [f32], batch: &Batch, eta: f32) -> Result<f64> {
        self.t += 1.0;
        let [ids, tgt, mask] = batch_args(batch);
        let outs = self.sess.run(&[
            Arg::VecF32(params),
            Arg::VecF32(&self.mu),
            Arg::VecF32(&self.nu),
            Arg::F32(self.t),
            Arg::F32(eta),
            ids,
            tgt,
            mask,
        ])?;
        lit_copy_f32(&outs[0], params)?;
        let loss = lit_f32(&outs[3])? as f64;
        let (mu_new, nu_new) = (&outs[1], &outs[2]);
        lit_copy_f32(mu_new, &mut self.mu)?;
        lit_copy_f32(nu_new, &mut self.nu)?;
        Ok(loss)
    }
}

/// Fig. 6 probe: cos^2(m, grad f) via the bound `grad_cos2` program.
/// (`RefCell` keeps the probe callable through `&self` from the trainer's
/// eval loop; single-threaded, never re-entered.)
pub struct GradProbe {
    sess: std::cell::RefCell<Box<dyn Session>>,
}

impl GradProbe {
    pub fn new(rt: &Runtime, preset: &str) -> Result<Self> {
        Ok(GradProbe { sess: std::cell::RefCell::new(rt.bind_kind(preset, "grad_cos2")?) })
    }

    pub fn cos2(&self, params: &[f32], m: &[f32], batch: &Batch) -> Result<f64> {
        let [ids, tgt, mask] = batch_args(batch);
        let mut sess = self.sess.borrow_mut();
        let outs = sess.run(&[Arg::VecF32(params), Arg::VecF32(m), ids, tgt, mask])?;
        Ok(lit_f32(&outs[0])? as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::cos_z_momentum;

    fn direct_cos(z: &[f64], v: &[f64]) -> f64 {
        let zv: f64 = z.iter().zip(v).map(|(a, b)| a * b).sum();
        let zz: f64 = z.iter().map(|a| a * a).sum();
        let vv: f64 = v.iter().map(|a| a * a).sum();
        zv / (zz.sqrt() * vv.sqrt())
    }

    #[test]
    fn cos_z_momentum_matches_direct_cosine() {
        // Fabricate m' = beta m + (1-beta) g z for a known z and check the
        // reconstruction against the explicit cosine.
        let v = [0.5f64, -1.25, 2.0, 0.75, -0.1];
        let z = [1.0f64, 0.25, -0.5, 2.0, 1.5];
        for &(beta, g) in &[(0.9f64, 0.37f64), (0.5, -1.2), (0.0, 2.0)] {
            let m_old: Vec<f32> = v.iter().map(|&x| x as f32).collect();
            let m_new: Vec<f32> = v
                .iter()
                .zip(&z)
                .map(|(&vi, &zi)| (beta * vi + (1.0 - beta) * g * zi) as f32)
                .collect();
            let got = cos_z_momentum(&m_new, &m_old, beta, g);
            let want = direct_cos(&z, &v);
            assert!(
                (got - want).abs() < 1e-3,
                "beta={beta} g={g}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn cos_z_momentum_degenerate_cases_are_nan() {
        let m = [1.0f32, 2.0, 3.0];
        // g = 0 -> direction unrecoverable
        assert!(cos_z_momentum(&m, &m, 0.9, 0.0).is_nan());
        // beta = 1 -> (1-beta) g = 0
        assert!(cos_z_momentum(&m, &m, 1.0, 0.5).is_nan());
        // zero old momentum -> no reference direction
        assert!(cos_z_momentum(&m, &[0.0; 3], 0.9, 0.5).is_nan());
        // m' = beta m exactly -> z reconstructs to zero
        let m_new: Vec<f32> = m.iter().map(|&x| 0.9 * x).collect();
        assert!(cos_z_momentum(&m_new, &m, 0.9, 0.5).is_nan());
    }
}
