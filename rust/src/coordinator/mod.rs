//! L3 coordination: training orchestration (single-node + distributed),
//! fused-step engines, metrics. See DESIGN.md §4.

pub mod cluster;
pub mod distributed;
pub mod fused;
pub mod metrics;
pub mod sweep;
pub mod trainer;

pub use cluster::{run_worker_with, Leader, LeaderConfig, WorkerOpts};
pub use distributed::{
    model_workers_shared, run_leader, run_worker, step_seed, DistHypers, DistSummary, LocalCluster,
    ZoWorker,
};
pub use fused::{FoAdamW, FoSgd, FusedConMeZo, FusedMezo, FusedMezoMomentum, GradProbe};
pub use metrics::{render_table, RunRecord};
pub use sweep::{run_sweep, Axis, Grid, SweepResult};
pub use trainer::{ensure_pretrained, pretrain, pretrained_path, Evaluator, Mode, TrainConfig, TrainSummary, Trainer};
