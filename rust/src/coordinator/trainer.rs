//! Single-node training orchestrator.
//!
//! Owns the full finetuning lifecycle: parameter init (AOT `init` program
//! or checkpoint), few-shot dataset construction, the step loop (fused or
//! composed engine), the β warm-up schedule, periodic candidate-restricted
//! evaluation, the Fig. 6 alignment probe, memory accounting, checkpointing
//! and metrics. Python is never on this path. All sessions the trainer
//! binds (step engine, evaluator, probe) execute over the `Runtime`'s one
//! persistent `WorkerPool` (`--threads` / `runtime.threads` /
//! `CONMEZO_THREADS`), so multi-core runs spawn their workers once at
//! startup, never per step.

use std::cell::RefCell;
use std::path::PathBuf;

use crate::util::error::{bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::coordinator::fused::{
    FoAdamW, FoSgd, FusedConMeZo, FusedMezo, FusedMezoMomentum, FusedStats, GradProbe,
};
use crate::data::{PretrainSampler, TaskGen, TrainSampler};
use crate::eval::{predict, score, EvalResult};
use crate::objective::{Batch, BatchSource, ModelObjective, Objective};
use crate::optimizer::{BetaSchedule, ZoOptimizer};
use crate::runtime::{lit_vec_f32, Arg, Runtime, Session};
use crate::telemetry::{StepTrace, StepTracer};
use crate::util::memory::{activation_bytes, MemoryMeter};
use crate::util::rng::STREAM_DIRECTION;
use crate::util::Stopwatch;

/// How a step executes (DESIGN.md §4 "Execution modes").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mode {
    /// whole step = one bound step program (conmezo / mezo / mezo_momentum / FO)
    Fused,
    /// loss-only program sessions + host-side optimizer math (all baselines)
    Composed,
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub preset: String,
    pub task: String,
    /// conmezo | mezo | mezo_loop | mezo_momentum | zo_adamm | hizoo |
    /// lozo | lozo_m | mezo_svrg | sgd | adamw
    pub optimizer: String,
    pub mode: Mode,
    pub steps: usize,
    pub eta: f32,
    pub lam: f32,
    pub theta: f32,
    pub beta_final: f32,
    pub warmup: bool,
    pub seed: u64,
    pub train_per_class: usize,
    pub eval_examples: usize,
    pub eval_every: usize,
    pub log_every: usize,
    /// warm-start from this checkpoint (the "pretrained model" of the
    /// few-shot regime); produced by [`pretrain`]
    pub init_from: Option<PathBuf>,
    /// record cos^2(m, grad f) every eval (Fig. 6)
    pub probe_cos2: bool,
    /// stream one [`StepTrace`] JSONL record per step to this file
    /// (`--trace out.jsonl`); also turns on per-step `cos(z, m)` for the
    /// momentum engines. `None` (the default) keeps the step loop free of
    /// trace bookkeeping entirely.
    pub trace: Option<PathBuf>,
}

impl TrainConfig {
    /// Paper-default hyperparameters (App. C.2/C.3), scaled step count.
    pub fn preset(preset: &str, task: &str, optimizer: &str) -> TrainConfig {
        TrainConfig {
            preset: preset.to_string(),
            task: task.to_string(),
            optimizer: optimizer.to_string(),
            mode: Mode::Fused,
            steps: 1000,
            eta: 5e-2,
            lam: 1e-3,
            theta: 1.35,
            beta_final: 0.99,
            warmup: true,
            seed: 42,
            train_per_class: 128,
            eval_examples: 128,
            eval_every: 200,
            log_every: 100,
            init_from: None,
            probe_cos2: false,
            trace: None,
        }
    }

    pub fn beta_schedule(&self) -> BetaSchedule {
        if self.warmup {
            BetaSchedule::PaperWarmup { beta_final: self.beta_final, total_steps: self.steps }
        } else {
            BetaSchedule::Constant(self.beta_final)
        }
    }

    fn uses_fused_zo(&self) -> bool {
        matches!(self.optimizer.as_str(), "conmezo" | "mezo" | "mezo_momentum")
    }

    fn is_fo(&self) -> bool {
        matches!(self.optimizer.as_str(), "sgd" | "adamw")
    }
}

/// Point-in-time training telemetry.
#[derive(Clone, Debug, Default)]
pub struct TrainSummary {
    pub task: String,
    pub optimizer: String,
    pub steps: usize,
    /// (step, mean two-point loss)
    pub loss_curve: Vec<(usize, f64)>,
    /// (step, eval accuracy)
    pub eval_curve: Vec<(usize, f64)>,
    /// (step, cos^2(m, grad)) when probed
    pub cos2_curve: Vec<(usize, f64)>,
    pub final_accuracy: f64,
    pub final_f1: f64,
    pub final_loss: f64,
    pub wall_seconds: f64,
    pub steps_per_sec: f64,
    pub peak_mem_mib: f64,
    pub evals_used: u64,
}

enum Engine {
    ConMeZo(FusedConMeZo),
    Mezo(FusedMezo),
    MezoMomentum(FusedMezoMomentum),
    Composed { opt: Box<dyn ZoOptimizer>, obj: ModelObjective },
    Sgd(FoSgd),
    AdamW(FoAdamW),
}

/// Candidate-restricted evaluation over a fixed example set. Owns a bound
/// `eval_logits` [`Session`] — the eval workspace binds once and is reused
/// across every periodic evaluation (`RefCell` keeps `evaluate` callable
/// through `&self`; single-threaded, never re-entered).
pub struct Evaluator {
    sess: RefCell<Box<dyn Session>>,
    examples: Vec<crate::data::Example>,
    batch: usize,
    seq: usize,
}

impl Evaluator {
    pub fn new(rt: &Runtime, preset: &str, examples: Vec<crate::data::Example>) -> Result<Self> {
        let meta = rt.preset(preset)?;
        let (batch, seq) = (meta.batch, meta.seq_len);
        Ok(Evaluator {
            sess: RefCell::new(rt.bind_kind(preset, "eval_logits")?),
            examples,
            batch,
            seq,
        })
    }

    pub fn evaluate(&self, params: &[f32]) -> Result<EvalResult> {
        let mut pairs = Vec::with_capacity(self.examples.len());
        let mut sess = self.sess.borrow_mut();
        let mut ids = vec![0i32; self.batch * self.seq];
        let mut pos = vec![0i32; self.batch];
        for chunk in self.examples.chunks(self.batch) {
            ids.fill(0);
            pos.fill(0);
            for (i, e) in chunk.iter().enumerate() {
                ids[i * self.seq..(i + 1) * self.seq].copy_from_slice(&e.tokens);
                pos[i] = e.predict_pos as i32;
            }
            let outs = sess.run(&[
                Arg::VecF32(params),
                Arg::TensorI32(&ids, vec![self.batch, self.seq]),
                Arg::TensorI32(&pos, vec![self.batch]),
            ])?;
            let logits = lit_vec_f32(&outs[0])?;
            let vocab = logits.len() / self.batch;
            for (i, e) in chunk.iter().enumerate() {
                let row = &logits[i * vocab..(i + 1) * vocab];
                pairs.push((e.label, predict(row, &e.candidates)));
            }
        }
        Ok(score(&pairs))
    }
}

pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub cfg: TrainConfig,
    pub params: Vec<f32>,
    engine: Engine,
    sampler: TrainSampler,
    evaluator: Evaluator,
    probe: Option<GradProbe>,
    tracer: Option<StepTracer>,
    meter: MemoryMeter,
    d_pad: usize,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: TrainConfig) -> Result<Trainer<'rt>> {
        let meta = rt.preset(&cfg.preset)?.clone();
        let spec = crate::data::spec(&cfg.task)
            .ok_or_else(|| crate::anyhow!("unknown task {:?}", cfg.task))?;
        let gen = TaskGen::new(spec, meta.vocab, meta.seq_len);
        let n_train = cfg.train_per_class * gen.n_classes().max(1);
        let train = gen.dataset(n_train, cfg.seed);
        let eval = gen.dataset(cfg.eval_examples, cfg.seed ^ 0xEEE);
        let sampler = TrainSampler::new(train, meta.batch, meta.seq_len, cfg.seed, 0);
        let evaluator = Evaluator::new(rt, &cfg.preset, eval)?;

        // parameters: checkpoint warm start or AOT init program
        let params = match &cfg.init_from {
            Some(path) => {
                let ck = Checkpoint::load(path)?;
                if ck.preset != cfg.preset {
                    bail!("checkpoint preset {:?} != config preset {:?}", ck.preset, cfg.preset);
                }
                ck.get("params")?.to_vec()
            }
            None => {
                let init = rt.load_kind(&cfg.preset, "init")?;
                let outs = init.call(&[Arg::I32(cfg.seed as i32)])?;
                lit_vec_f32(&outs[0])?
            }
        };

        // memory accounting: model + optimizer state (the activation
        // transient is added AFTER the engine allocates its buffers, so the
        // peak reflects persistent-state deltas correctly)
        let mut meter = MemoryMeter::new();
        meter.alloc_f32("params", meta.d_pad);

        let layout: Vec<(usize, Vec<usize>)> =
            meta.layout.iter().map(|l| (l.offset, l.shape.clone())).collect();

        let engine = if cfg.is_fo() {
            match cfg.optimizer.as_str() {
                "sgd" => Engine::Sgd(FoSgd::new(rt, &cfg.preset)?),
                _ => {
                    meter.alloc_f32("adam.mu", meta.d_pad);
                    meter.alloc_f32("adam.nu", meta.d_pad);
                    meter.alloc_f32("grad", meta.d_pad);
                    Engine::AdamW(FoAdamW::new(rt, &cfg.preset)?)
                }
            }
        } else if cfg.mode == Mode::Fused && cfg.uses_fused_zo() {
            match cfg.optimizer.as_str() {
                "conmezo" => {
                    meter.alloc_f32("momentum", meta.d_pad);
                    Engine::ConMeZo(FusedConMeZo::new(rt, &cfg.preset, cfg.theta)?)
                }
                "mezo" => Engine::Mezo(FusedMezo::new(rt, &cfg.preset)?),
                "mezo_momentum" => {
                    meter.alloc_f32("momentum", meta.d_pad);
                    Engine::MezoMomentum(FusedMezoMomentum::new(rt, &cfg.preset)?)
                }
                _ => unreachable!(),
            }
        } else {
            let opt = crate::optimizer::by_name(
                &cfg.optimizer,
                meta.d_pad,
                cfg.eta,
                cfg.lam,
                cfg.theta,
                cfg.beta_schedule(),
                &layout,
            )?;
            opt.record_memory(&mut meter);
            let source = TrainSampler::new(
                sampler.data.clone(),
                meta.batch,
                meta.seq_len,
                cfg.seed,
                0,
            );
            let obj = ModelObjective::new(rt, &cfg.preset, Box::new(source))?;
            Engine::Composed { opt, obj }
        };

        meter.transient(activation_bytes(
            meta.batch,
            meta.seq_len,
            meta.d_model,
            meta.d_ff,
            meta.n_layers,
            meta.vocab,
            cfg.is_fo(),
        ));

        let probe = if cfg.probe_cos2 { Some(GradProbe::new(rt, &cfg.preset)?) } else { None };

        // step tracing: open the JSONL sink up front (fail fast on a bad
        // path) and turn on cos(z, m) reconstruction where the engine has a
        // momentum buffer to compare against
        let mut engine = engine;
        let tracer = match &cfg.trace {
            Some(path) => {
                match &mut engine {
                    Engine::ConMeZo(e) => e.trace_cos = true,
                    Engine::MezoMomentum(e) => e.trace_cos = true,
                    _ => {}
                }
                Some(StepTracer::new(Some(path))?)
            }
            None => None,
        };

        Ok(Trainer { rt, cfg, params, engine, sampler, evaluator, probe, tracer, meter, d_pad: meta.d_pad })
    }

    /// Momentum buffer view (for probes), if the engine keeps one.
    pub fn momentum(&self) -> Option<&[f32]> {
        match &self.engine {
            Engine::ConMeZo(e) => Some(&e.m),
            Engine::MezoMomentum(e) => Some(&e.m),
            _ => None,
        }
    }

    /// Per-step direction seed: pure function of (run seed, t) so fused and
    /// distributed runs can replay it.
    pub fn step_seed(run_seed: u64, t: usize) -> i32 {
        let mut s = run_seed ^ (t as u64).rotate_left(17) ^ STREAM_DIRECTION;
        (crate::util::rng::splitmix64(&mut s) & 0x7FFF_FFFF) as i32
    }

    /// One optimizer step; returns the mean two-point loss.
    pub fn step(&mut self, t: usize) -> Result<f64> {
        Ok(self.step_stats(t)?.loss)
    }

    /// One optimizer step with full per-step telemetry. Fused ZO engines
    /// report both antithetic losses (and `cos(z, m)` when tracing);
    /// composed engines report the projected gradient; first-order engines
    /// only the loss — everything else is `NaN`.
    fn step_stats(&mut self, t: usize) -> Result<FusedStats> {
        let beta = self.cfg.beta_schedule().at(t);
        let seed = Self::step_seed(self.cfg.seed, t);
        let nan = f64::NAN;
        let stats = match &mut self.engine {
            Engine::ConMeZo(e) => {
                let batch = self.sampler.next_batch();
                e.step(&mut self.params, &batch, seed, beta, self.cfg.eta, self.cfg.lam)?
            }
            Engine::Mezo(e) => {
                let batch = self.sampler.next_batch();
                e.step(&mut self.params, &batch, seed, self.cfg.eta, self.cfg.lam)?
            }
            Engine::MezoMomentum(e) => {
                let batch = self.sampler.next_batch();
                e.step(&mut self.params, &batch, seed, beta, self.cfg.eta, self.cfg.lam)?
            }
            Engine::Composed { opt, obj } => {
                obj.advance();
                let s = opt.step(&mut self.params, obj, t, self.cfg.seed)?;
                FusedStats {
                    loss: s.loss,
                    proj_grad: s.proj_grad,
                    loss_plus: nan,
                    loss_minus: nan,
                    cos_zm: nan,
                }
            }
            Engine::Sgd(e) => {
                let batch = self.sampler.next_batch();
                let loss = e.step(&mut self.params, &batch, self.cfg.eta)?;
                FusedStats { loss, proj_grad: nan, loss_plus: nan, loss_minus: nan, cos_zm: nan }
            }
            Engine::AdamW(e) => {
                let batch = self.sampler.next_batch();
                let loss = e.step(&mut self.params, &batch, self.cfg.eta)?;
                FusedStats { loss, proj_grad: nan, loss_plus: nan, loss_minus: nan, cos_zm: nan }
            }
        };
        Ok(stats)
    }

    pub fn evaluate(&self) -> Result<EvalResult> {
        self.evaluator.evaluate(&self.params)
    }

    /// In-memory copy of every [`StepTrace`] recorded so far (empty unless
    /// [`TrainConfig::trace`] is set).
    pub fn trace_history(&self) -> &[StepTrace] {
        self.tracer.as_ref().map(|t| t.history()).unwrap_or(&[])
    }

    /// Full training run with periodic eval + probes.
    pub fn run(&mut self) -> Result<TrainSummary> {
        let sw = Stopwatch::start();
        let mut summary = TrainSummary {
            task: self.cfg.task.clone(),
            optimizer: self.cfg.optimizer.clone(),
            steps: self.cfg.steps,
            ..Default::default()
        };
        let mut loss_acc = 0f64;
        let mut loss_n = 0usize;
        let steps_counter = self.rt.telemetry().filter(|r| r.enabled()).cloned();
        for t in 0..self.cfg.steps {
            let step_sw = Stopwatch::start();
            let stats = self.step_stats(t)?;
            let wall_s = step_sw.secs();
            // trace bookkeeping happens OUTSIDE the timed region: wall_s
            // measures the step itself, not the JSONL formatting
            if let Some(reg) = &steps_counter {
                reg.steps.inc();
            }
            if let Some(tracer) = self.tracer.as_mut() {
                tracer.record(StepTrace {
                    step: t as u64,
                    seed: Self::step_seed(self.cfg.seed, t) as i64,
                    loss: stats.loss,
                    loss_plus: stats.loss_plus,
                    loss_minus: stats.loss_minus,
                    proj_grad: stats.proj_grad,
                    cos_zm: stats.cos_zm,
                    eta: self.cfg.eta as f64,
                    wall_s,
                })?;
            }
            let loss = stats.loss;
            loss_acc += loss;
            loss_n += 1;
            summary.final_loss = loss;
            if (t + 1) % self.cfg.log_every == 0 || t + 1 == self.cfg.steps {
                summary.loss_curve.push((t + 1, loss_acc / loss_n as f64));
                loss_acc = 0.0;
                loss_n = 0;
            }
            if (t + 1) % self.cfg.eval_every == 0 || t + 1 == self.cfg.steps {
                let r = self.evaluate()?;
                summary.eval_curve.push((t + 1, r.accuracy()));
                summary.final_accuracy = r.accuracy();
                summary.final_f1 = r.macro_f1;
                crate::info!(
                    "trainer",
                    "{}/{} t={} loss={:.4} acc={:.3}",
                    self.cfg.task,
                    self.cfg.optimizer,
                    t + 1,
                    summary.loss_curve.last().map(|x| x.1).unwrap_or(f64::NAN),
                    r.accuracy()
                );
                if self.probe.is_some() && self.momentum().is_some() {
                    let batch = self.sampler.next_batch();
                    let probe = self.probe.as_ref().unwrap();
                    let m = self.momentum().unwrap();
                    summary.cos2_curve.push((t + 1, probe.cos2(&self.params, m, &batch)?));
                }
            }
        }
        if let Some(tracer) = self.tracer.as_mut() {
            tracer.flush()?;
        }
        summary.wall_seconds = sw.secs();
        summary.steps_per_sec = self.cfg.steps as f64 / summary.wall_seconds.max(1e-9);
        summary.peak_mem_mib = self.meter.peak_mib();
        if let Engine::Composed { obj, .. } = &self.engine {
            summary.evals_used = crate::objective::Objective::evals(obj);
        } else {
            summary.evals_used = 2 * self.cfg.steps as u64;
        }
        Ok(summary)
    }

    pub fn save_checkpoint(&self, path: &std::path::Path, step: u64) -> Result<()> {
        let mut ck = Checkpoint::new(&self.cfg.preset, step);
        ck.put("params", &self.params);
        if let Some(m) = self.momentum() {
            ck.put("momentum", m);
        }
        ck.save(path)
    }

    pub fn peak_mem_mib(&self) -> f64 {
        self.meter.peak_mib()
    }

    pub fn d_pad(&self) -> usize {
        self.d_pad
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
}

/// Pretrain a preset on the mixed synthetic corpus with AdamW (the
/// `fo_adamw_step` program: native reverse-mode autograd by default,
/// build-time jax backprop on pjrt) and write the checkpoint. This is the
/// "pretrained LM" of the paper's few-shot finetuning regime; `label_noise`
/// leaves accuracy headroom for ZO finetuning to recover (DESIGN.md §2).
pub fn pretrain(
    rt: &Runtime,
    preset: &str,
    steps: usize,
    eta: f32,
    label_noise: f32,
    seed: u64,
    out: &std::path::Path,
) -> Result<Vec<(usize, f64)>> {
    let meta = rt.preset(preset)?.clone();
    let gens: Vec<TaskGen> = crate::data::registry()
        .into_iter()
        .map(|s| TaskGen::new(s, meta.vocab, meta.seq_len))
        .collect();
    let mut sampler = PretrainSampler::new(gens, meta.batch, meta.seq_len, label_noise, seed);
    let init = rt.load_kind(preset, "init")?;
    let mut params = lit_vec_f32(&init.call(&[Arg::I32(seed as i32)])?[0])?;
    let mut adamw = FoAdamW::new(rt, preset)
        .context("pretraining needs the first-order fo_adamw_step program")?;
    let mut curve = Vec::new();
    let mut acc = 0f64;
    for t in 0..steps {
        let batch: Batch = sampler.next_batch();
        let loss = adamw.step(&mut params, &batch, eta)?;
        acc += loss;
        if (t + 1) % 50 == 0 || t + 1 == steps {
            curve.push((t + 1, acc / 50f64.min((t + 1) as f64)));
            crate::info!("pretrain", "{preset} t={} loss={:.4}", t + 1, curve.last().unwrap().1);
            acc = 0.0;
        }
    }
    let mut ck = Checkpoint::new(preset, steps as u64);
    ck.put("params", &params);
    ck.save(out)?;
    Ok(curve)
}

/// Standard location for a preset's pretrained checkpoint.
pub fn pretrained_path(preset: &str) -> PathBuf {
    PathBuf::from(format!("results/pretrained_{preset}.ckpt"))
}

/// Pretrain only if the checkpoint does not exist yet; return its path.
pub fn ensure_pretrained(rt: &Runtime, preset: &str, steps: usize, eta: f32, label_noise: f32) -> Result<PathBuf> {
    let path = pretrained_path(preset);
    if !path.exists() {
        crate::info!("pretrain", "building pretrained checkpoint for {preset} ({steps} steps)");
        pretrain(rt, preset, steps, eta, label_noise, 7, &path)?;
    }
    Ok(path)
}
