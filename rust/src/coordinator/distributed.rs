//! Distributed data-parallel ZO training with shared randomness.
//!
//! Topology: one leader, N workers. Every worker holds a FULL replica of
//! the flat parameter and momentum buffers plus a private data shard. Per
//! step the leader broadcasts `Step{t, seed, theta, beta, eta, lam}`
//! (O(1) bytes); each worker regenerates the identical cone direction from
//! the seed, evaluates the two-point losses on its own minibatch, and
//! returns two scalars; the leader averages the projected gradient and
//! broadcasts `Apply{g}`; every worker applies the *same* deterministic
//! update, so replicas remain bit-identical without ever exchanging
//! parameters. Total wire traffic per step ≈ 90 bytes/worker vs 4·d bytes
//! for gradient all-reduce (d = 10^6..10^13 in the paper's setting).
//!
//! The same purity enables the rejoin path: `(x, m)` at step t is a
//! function of `x0` and the per-step `(seed, g, theta, eta, beta)` records,
//! so [`ZoWorker::replay`] reconstructs a replica's exact state from the
//! leader's [`crate::checkpoint::StepLog`] with zero function evaluations.
//! The fault-tolerant leader (timeouts, straggler drop, mid-run rejoin,
//! divergence tripwire) lives in [`super::cluster`]; this module keeps the
//! replica math, the in-process [`LocalCluster`], and the lockstep
//! [`run_leader`]/[`run_worker`] entry points.
//!
//! Invariants (enforced by tests):
//! * 1-worker cluster ≡ single-node composed ConMeZO, bit-for-bit;
//! * N workers stay bit-identical across all steps;
//! * N-worker aggregate ≡ single node stepping with the N shards'
//!   mean projected gradient;
//! * shared-session replicas ([`model_workers_shared`]) ≡ replicas with
//!   private sessions, bit-for-bit;
//! * leader-side and [`LocalCluster`] `wire_bytes` accounting agree.
//!
//! Model-objective replicas in ONE process share one bound `two_point`
//! session — and therefore one forward scratch and the `Runtime`'s one
//! `WorkerPool` — via [`model_workers_shared`] instead of binding a full
//! session set per replica (each worker keeps its private data shard; only
//! the stateless execution workspaces are shared).

use crate::util::error::{bail, Result};

use crate::checkpoint::{Checkpoint, StepRecord};
use crate::net::{Msg, Transport};
use crate::objective::{BatchSource, ModelObjective, Objective};
use crate::optimizer::{sample_direction, BetaSchedule};
use crate::runtime::Runtime;
use crate::vecmath;

/// Per-step broadcast seed: identical derivation on LocalCluster and the
/// TCP leader (and in replay tests), so the two paths are bit-comparable.
pub fn step_seed(run_seed: u64, t: u64) -> u64 {
    let mut s = run_seed ^ t.rotate_left(17);
    crate::util::rng::splitmix64(&mut s)
}

/// Worker-side replica state + step math (transport-agnostic).
pub struct ZoWorker {
    pub id: u32,
    pub x: Vec<f32>,
    pub m: Vec<f32>,
    u: Vec<f32>,
    z: Vec<f32>,
    started: bool,
    /// completed (applied) steps; the protocol's step counter
    pub t: u64,
    /// steps whose shard batch has been drawn. Advancement is a pure
    /// function of the step number: a step this replica already computed
    /// live can be re-issued (leader restart) or arrive again as a replay
    /// record without double-advancing the batch stream — double advance
    /// would silently desync the shard from an uninterrupted run
    advanced: u64,
    pub obj: Box<dyn Objective>,
    /// local eval closure: returns (correct, total); optional
    pub eval_fn: Option<Box<dyn FnMut(&[f32]) -> (u64, u64)>>,
}

impl ZoWorker {
    pub fn new(id: u32, x0: Vec<f32>, obj: Box<dyn Objective>) -> Self {
        let d = x0.len();
        ZoWorker {
            id,
            x: x0,
            m: vec![0.0; d],
            u: vec![0.0; d],
            z: vec![0.0; d],
            started: false,
            t: 0,
            advanced: 0,
            obj,
            eval_fn: None,
        }
    }

    /// Warm-start a replica from a CRC-checked snapshot (the snapshot-sync
    /// rejoin path: load the checkpoint, then [`Self::replay`] only the gap
    /// `ckpt.step..leader_t` shipped in a `Replay` message).
    pub fn from_checkpoint(id: u32, ckpt: &Checkpoint, obj: Box<dyn Objective>) -> Result<ZoWorker> {
        let x = ckpt.get("params")?.to_vec();
        let m = ckpt.get("momentum")?.to_vec();
        if x.len() != obj.dim() {
            bail!(
                "checkpoint params have {} entries but objective dim is {}",
                x.len(),
                obj.dim()
            );
        }
        if m.len() != x.len() {
            bail!("checkpoint momentum length {} != params length {}", m.len(), x.len());
        }
        let d = x.len();
        Ok(ZoWorker {
            id,
            x,
            m,
            u: vec![0.0; d],
            z: vec![0.0; d],
            started: ckpt.step > 0,
            t: ckpt.step,
            // the warm-started process has a fresh shard stream; the gap
            // replay advances it once per missed step, exactly as before
            advanced: ckpt.step,
            obj,
            eval_fn: None,
        })
    }

    /// Snapshot this replica's full optimizer state at its current step.
    pub fn to_checkpoint(&self, preset: &str) -> Checkpoint {
        let mut c = Checkpoint::new(preset, self.t);
        c.put("params", &self.x);
        c.put("momentum", &self.m);
        c
    }

    /// Phase 1 of a step: regenerate the direction from the broadcast seed
    /// and compute the local two-point losses.
    pub fn compute_proj(&mut self, t: u64, seed: u64, theta: f32, lam: f32) -> Result<(f64, f64)> {
        let d_raw = self.obj.d_raw();
        sample_direction(&mut self.u, d_raw, seed, t as usize);
        if !self.started {
            self.m.copy_from_slice(&self.u);
            self.started = true;
        }
        vecmath::cone_direction(&self.m, &self.u, theta, d_raw, &mut self.z);
        if self.advanced <= t {
            self.obj.advance(); // every worker advances its OWN shard stream
            self.advanced = t + 1;
        }
        self.obj.two_point(&self.x, &self.z, lam)
    }

    /// Phase 2: apply the aggregated projected gradient. Identical on all
    /// replicas, so states never diverge.
    pub fn apply(&mut self, g: f64, eta: f32, beta: f32) {
        vecmath::zo_update(&mut self.x, &mut self.m, &self.z, g as f32, eta, beta);
        self.t += 1;
    }

    /// Fast-forward through logged steps with ZERO function evaluations:
    /// the update is a pure function of the record stream, so this mirrors
    /// [`Self::compute_proj`]+[`Self::apply`] exactly minus the `two_point`
    /// call. Record `k` must correspond to step `from_t + k`, and `from_t`
    /// must equal this replica's current step.
    pub fn replay(&mut self, from_t: u64, records: &[StepRecord]) -> Result<()> {
        if from_t != self.t {
            bail!("replay starts at step {from_t} but this replica is at step {}", self.t);
        }
        let d_raw = self.obj.d_raw();
        for (k, r) in records.iter().enumerate() {
            let t = from_t + k as u64;
            sample_direction(&mut self.u, d_raw, r.seed, t as usize);
            if !self.started {
                self.m.copy_from_slice(&self.u);
                self.started = true;
            }
            vecmath::cone_direction(&self.m, &self.u, r.theta, d_raw, &mut self.z);
            if self.advanced <= t {
                self.obj.advance(); // keep the shard stream in step with live peers
                self.advanced = t + 1;
            }
            vecmath::zo_update(&mut self.x, &mut self.m, &self.z, r.g as f32, r.eta, r.beta);
            self.t = t + 1;
        }
        Ok(())
    }

    /// Cheap deterministic hash of the parameter replica (the divergence
    /// tripwire / rejoin comparison value).
    pub fn params_hash(&self) -> u64 {
        crate::checkpoint::params_hash(&self.x)
    }

    /// Run the local sharded eval. Temporarily takes the closure out of
    /// `self` so it can borrow `self.x` directly — zero parameter-sized
    /// allocations (the old version cloned all of `x` per eval purely to
    /// appease the borrow checker).
    pub fn eval(&mut self) -> (u64, u64) {
        match self.eval_fn.take() {
            Some(mut f) => {
                let r = f(&self.x);
                self.eval_fn = Some(f);
                r
            }
            None => (0, 0),
        }
    }
}

/// Build N full-replica model workers for one process, all sharing ONE
/// bound `loss`/`two_point` session pair — hence one forward scratch and
/// the runtime's one `WorkerPool` — instead of binding a session set per
/// replica (the ROADMAP per-process sharing item). Worker `i` owns
/// `samplers[i]` as its private data shard and starts from the same `x0`
/// replica. Bit-identical to per-worker sessions because session
/// workspaces carry no state across calls (pinned by
/// `shared_session_workers_match_private_session_workers`).
pub fn model_workers_shared(
    rt: &Runtime,
    preset: &str,
    x0: &[f32],
    samplers: Vec<Box<dyn BatchSource>>,
) -> Result<Vec<ZoWorker>> {
    let mut shared = None;
    let mut workers = Vec::with_capacity(samplers.len());
    for (id, src) in samplers.into_iter().enumerate() {
        let obj = match &shared {
            None => {
                let first = ModelObjective::new(rt, preset, src)?;
                shared = Some(first.sessions());
                first
            }
            Some((loss, two_point)) => {
                ModelObjective::with_sessions(rt, preset, src, loss.clone(), two_point.clone())?
            }
        };
        workers.push(ZoWorker::new(id as u32, x0.to_vec(), Box::new(obj)));
    }
    Ok(workers)
}

/// Per-step hyperparameters broadcast by the leader.
#[derive(Clone, Copy, Debug)]
pub struct DistHypers {
    pub theta: f32,
    pub eta: f32,
    pub lam: f32,
}

#[derive(Clone, Debug, Default)]
pub struct DistSummary {
    pub steps: u64,
    pub loss_curve: Vec<(u64, f64)>,
    pub eval_curve: Vec<(u64, f64)>,
    /// leader-side per-step wire bytes (`Step`/`Proj`/`Apply` only — the
    /// O(1)/step claim; identical accounting in LocalCluster and Leader)
    pub wire_bytes: u64,
    /// non-step traffic: registration, replay, eval, hash checks, heartbeats
    pub control_bytes: u64,
    /// Proj timeouts survived (the worker was skipped for that step's
    /// average but kept alive)
    pub straggler_events: u64,
    /// workers dropped (dead socket, protocol violation, or strike-out)
    pub workers_lost: u64,
    /// successful mid-run (re)admissions via seed replay
    pub rejoins: u64,
}

/// In-process cluster: drives N replicas deterministically on one thread
/// (PJRT handles are not Send; process-level parallelism is provided by the
/// TCP path below). The protocol logic is identical.
pub struct LocalCluster {
    pub workers: Vec<ZoWorker>,
    pub run_seed: u64,
}

impl LocalCluster {
    pub fn new(workers: Vec<ZoWorker>, run_seed: u64) -> Self {
        LocalCluster { workers, run_seed }
    }

    fn step_seed(&self, t: u64) -> u64 {
        step_seed(self.run_seed, t)
    }

    /// Run `steps` iterations; eval every `eval_every` (0 = never).
    pub fn run(&mut self, steps: u64, hypers: DistHypers, beta: &BetaSchedule, eval_every: u64) -> Result<DistSummary> {
        let mut summary = DistSummary::default();
        summary.steps = steps;
        let n = self.workers.len() as f64;
        for t in 0..steps {
            let seed = self.step_seed(t);
            let mut g_sum = 0f64;
            let mut loss_sum = 0f64;
            let mut wire = 0u64;
            let step_msg = Msg::Step { t, seed, theta: hypers.theta, beta: beta.at(t as usize), eta: hypers.eta, lam: hypers.lam };
            for w in &mut self.workers {
                wire += step_msg.wire_bytes() as u64;
                let (lp, lm) = w.compute_proj(t, seed, hypers.theta, hypers.lam)?;
                wire += Msg::Proj { t, worker_id: w.id, loss_plus: lp, loss_minus: lm }.wire_bytes() as u64;
                g_sum += (lp - lm) / (2.0 * hypers.lam as f64);
                loss_sum += 0.5 * (lp + lm);
            }
            let g = g_sum / n;
            let b = beta.at(t as usize);
            for w in &mut self.workers {
                wire += Msg::Apply { t, g }.wire_bytes() as u64;
                w.apply(g, hypers.eta, b);
            }
            summary.wire_bytes += wire;
            if t % 10 == 0 || t + 1 == steps {
                summary.loss_curve.push((t, loss_sum / n));
            }
            if eval_every > 0 && (t + 1) % eval_every == 0 {
                let (mut c, mut tot) = (0u64, 0u64);
                for w in &mut self.workers {
                    let (wc, wt) = w.eval();
                    c += wc;
                    tot += wt;
                }
                if tot > 0 {
                    summary.eval_curve.push((t + 1, c as f64 / tot as f64));
                }
            }
        }
        Ok(summary)
    }

    /// Check that all replicas hold bit-identical state.
    pub fn replicas_identical(&self) -> bool {
        let first = &self.workers[0];
        self.workers.iter().all(|w| w.x == first.x && w.m == first.m)
    }
}

// ---------------------------------------------------------------------------
// TCP leader / worker (lockstep entry points)
// ---------------------------------------------------------------------------

/// Leader side, lockstep flavor: no timeouts, any worker failure is fatal.
/// A thin wrapper over [`super::cluster::Leader`] — the fault-tolerant
/// engine with straggler drop / rejoin / tripwire enabled lives there.
pub fn run_leader(
    conns: Vec<Box<dyn Transport>>,
    run_seed: u64,
    steps: u64,
    hypers: DistHypers,
    beta: &BetaSchedule,
    eval_every: u64,
) -> Result<DistSummary> {
    let mut cfg = super::cluster::LeaderConfig::new(conns.len() as u32, run_seed, steps, hypers, beta.clone());
    cfg.eval_every = eval_every;
    super::cluster::Leader::new(cfg).run(conns)
}

/// Worker side: serve the protocol until Shutdown (no checkpointing).
pub fn run_worker(conn: &mut dyn Transport, worker: &mut ZoWorker) -> Result<()> {
    super::cluster::run_worker_with(conn, worker, &super::cluster::WorkerOpts::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::{NativeQuadratic, Objective};

    const D: usize = 200;
    const HYP: DistHypers = DistHypers { theta: 1.2, eta: 1e-3, lam: 1e-2 };

    fn start(seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(seed);
        let mut x = vec![0f32; D];
        rng.fill_normal_f32(&mut x);
        x
    }

    fn worker(id: u32, x: Vec<f32>) -> ZoWorker {
        ZoWorker::new(id, x, Box::new(NativeQuadratic::new(D)))
    }

    #[test]
    fn replicas_stay_bit_identical() {
        let x0 = start(1);
        let mut cluster = LocalCluster::new(
            (0..4).map(|i| worker(i, x0.clone())).collect(),
            99,
        );
        cluster.run(100, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        assert!(cluster.replicas_identical());
    }

    #[test]
    fn cluster_descends() {
        let x0 = start(2);
        let mut obj = NativeQuadratic::new(D);
        let l0 = obj.loss(&x0).unwrap();
        let mut cluster = LocalCluster::new(vec![worker(0, x0)], 7);
        cluster.run(800, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        let l1 = obj.loss(&cluster.workers[0].x).unwrap();
        assert!(l1 < 0.5 * l0, "{l1} vs {l0}");
    }

    #[test]
    fn one_worker_cluster_equals_composed_conmezo() {
        // THE coordinator invariant: the distributed protocol with one
        // worker is bit-identical to single-node composed ConMeZO when both
        // regenerate directions from the same per-step seeds.
        let x0 = start(3);
        let steps = 50u64;
        let run_seed = 42u64;

        let mut cluster = LocalCluster::new(vec![worker(0, x0)], run_seed);
        // single node: run a manual loop that mirrors the worker math with
        // the same per-step seed derivation
        let mut x = start(3);
        let mut m = vec![0f32; D];
        let mut u = vec![0f32; D];
        let mut z = vec![0f32; D];
        let mut obj = NativeQuadratic::new(D);
        let mut started = false;
        for t in 0..steps {
            let seed = cluster.step_seed(t);
            sample_direction(&mut u, D, seed, t as usize);
            if !started {
                m.copy_from_slice(&u);
                started = true;
            }
            vecmath::cone_direction(&m, &u, HYP.theta, D, &mut z);
            let (lp, lm) = obj.two_point(&x, &z, HYP.lam).unwrap();
            let g = (lp - lm) / (2.0 * HYP.lam as f64);
            vecmath::zo_update(&mut x, &mut m, &z, g as f32, HYP.eta, 0.9);
        }
        cluster.run(steps, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        assert_eq!(cluster.workers[0].x, x, "distributed != single-node");
        assert_eq!(cluster.workers[0].m, m);
    }

    #[test]
    fn multi_worker_aggregate_matches_manual_average() {
        // 2 deterministic workers on the same objective: the applied g must
        // equal the mean of the individual projections
        let x0 = start(4);
        let mut w0 = worker(0, x0.clone());
        let mut w1 = worker(1, x0.clone());
        let seed = 1234u64;
        let (lp0, lm0) = w0.compute_proj(0, seed, HYP.theta, HYP.lam).unwrap();
        let (lp1, lm1) = w1.compute_proj(0, seed, HYP.theta, HYP.lam).unwrap();
        let g = ((lp0 - lm0) + (lp1 - lm1)) / (2.0 * 2.0 * HYP.lam as f64);
        w0.apply(g, HYP.eta, 0.9);
        w1.apply(g, HYP.eta, 0.9);
        assert_eq!(w0.x, w1.x);

        let mut cluster = LocalCluster::new(vec![worker(0, x0.clone()), worker(1, x0)], 0);
        cluster.run(1, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        assert!(cluster.replicas_identical());
    }

    #[test]
    fn wire_bytes_are_o1_per_step() {
        let x0 = start(5);
        let mut cluster = LocalCluster::new(vec![worker(0, x0.clone()), worker(1, x0)], 1);
        let s = cluster.run(10, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        let per_step_per_worker = s.wire_bytes as f64 / 10.0 / 2.0;
        assert!(per_step_per_worker < 200.0, "{per_step_per_worker} B");
        // vs shipping the direction: 4*D bytes
        assert!(per_step_per_worker < (4 * D) as f64 / 2.0);
    }

    #[test]
    fn eval_borrows_params_in_place() {
        // the per-eval O(d) clone fix: the closure must see self.x ITSELF,
        // not a copy — pin via pointer identity
        let x0 = start(6);
        let mut w = worker(0, x0);
        let expect = w.x.as_ptr() as usize;
        let seen = std::rc::Rc::new(std::cell::Cell::new(0usize));
        let seen2 = seen.clone();
        w.eval_fn = Some(Box::new(move |x: &[f32]| {
            seen2.set(x.as_ptr() as usize);
            (1, 2)
        }));
        assert_eq!(w.eval(), (1, 2));
        assert_eq!(seen.get(), expect, "eval saw a copied parameter buffer");
        // the closure is put back: a second eval still works
        assert_eq!(w.eval(), (1, 2));
    }

    #[test]
    fn replay_matches_live_run_bitwise() {
        // the rejoin substrate: replaying the logged (seed, g, theta, eta,
        // beta) records reproduces a live replica's (x, m) exactly
        let x0 = start(7);
        let steps = 40u64;
        let run_seed = 77u64;
        let mut live = worker(0, x0.clone());
        let mut records = Vec::new();
        for t in 0..steps {
            let seed = step_seed(run_seed, t);
            let (lp, lm) = live.compute_proj(t, seed, HYP.theta, HYP.lam).unwrap();
            let g = (lp - lm) / (2.0 * HYP.lam as f64);
            let beta = 0.9 + (t as f32) * 1e-4;
            live.apply(g, HYP.eta, beta);
            records.push(StepRecord { seed, g, theta: HYP.theta, eta: HYP.eta, beta });
        }
        let mut replayed = worker(0, x0.clone());
        replayed.replay(0, &records).unwrap();
        assert_eq!(replayed.x, live.x, "replayed params diverged");
        assert_eq!(replayed.m, live.m, "replayed momentum diverged");
        assert_eq!(replayed.t, steps);
        assert_eq!(replayed.params_hash(), live.params_hash());

        // and the snapshot+gap path: checkpoint at the midpoint, replay the
        // back half only
        let mut half = worker(0, x0);
        half.replay(0, &records[..20]).unwrap();
        let ckpt = half.to_checkpoint("test");
        let mut resumed =
            ZoWorker::from_checkpoint(0, &ckpt, Box::new(NativeQuadratic::new(D))).unwrap();
        assert_eq!(resumed.t, 20);
        resumed.replay(20, &records[20..]).unwrap();
        assert_eq!(resumed.x, live.x, "snapshot+gap replay diverged");
        assert_eq!(resumed.m, live.m);

        // replay from the wrong offset is rejected
        let mut wrong = ZoWorker::from_checkpoint(0, &ckpt, Box::new(NativeQuadratic::new(D))).unwrap();
        assert!(wrong.replay(0, &records).is_err());
    }

    #[test]
    fn from_checkpoint_validates_dims() {
        let mut c = Checkpoint::new("test", 5);
        c.put("params", &[0.0; 7]); // wrong size for D
        c.put("momentum", &[0.0; 7]);
        assert!(ZoWorker::from_checkpoint(0, &c, Box::new(NativeQuadratic::new(D))).is_err());
        let mut c2 = Checkpoint::new("test", 5);
        c2.put("params", &[0.0; D]);
        assert!(ZoWorker::from_checkpoint(0, &c2, Box::new(NativeQuadratic::new(D))).is_err());
    }

    #[test]
    fn tcp_leader_worker_end_to_end() {
        use crate::net::TcpTransport;
        use std::net::TcpListener;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let x0 = start(6);
        let x0c = x0.clone();

        let wh = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr.to_string()).unwrap();
            let mut w = worker(0, x0c);
            run_worker(&mut t, &mut w).unwrap();
            w.x
        });
        let (s, _) = listener.accept().unwrap();
        let conns: Vec<Box<dyn Transport>> = vec![Box::new(TcpTransport::new(s).unwrap())];
        let summary = run_leader(conns, 11, 30, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        let x_worker = wh.join().unwrap();

        // equivalence with LocalCluster under the same run seed
        let mut cluster = LocalCluster::new(vec![worker(0, x0)], 11);
        cluster.run(30, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        assert_eq!(x_worker, cluster.workers[0].x);
        assert!(summary.wire_bytes > 0);
    }
}
