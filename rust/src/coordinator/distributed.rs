//! Distributed data-parallel ZO training with shared randomness.
//!
//! Topology: one leader, N workers. Every worker holds a FULL replica of
//! the flat parameter and momentum buffers plus a private data shard. Per
//! step the leader broadcasts `Step{t, seed, theta, beta, eta, lam}`
//! (O(1) bytes); each worker regenerates the identical cone direction from
//! the seed, evaluates the two-point losses on its own minibatch, and
//! returns two scalars; the leader averages the projected gradient and
//! broadcasts `Apply{g}`; every worker applies the *same* deterministic
//! update, so replicas remain bit-identical without ever exchanging
//! parameters. Total wire traffic per step ≈ 60 bytes/worker vs 4·d bytes
//! for gradient all-reduce (d = 10^6..10^13 in the paper's setting).
//!
//! Invariants (enforced by tests):
//! * 1-worker cluster ≡ single-node composed ConMeZO, bit-for-bit;
//! * N workers stay bit-identical across all steps;
//! * N-worker aggregate ≡ single node stepping with the N shards'
//!   mean projected gradient;
//! * shared-session replicas ([`model_workers_shared`]) ≡ replicas with
//!   private sessions, bit-for-bit.
//!
//! Model-objective replicas in ONE process share one bound `two_point`
//! session — and therefore one forward scratch and the `Runtime`'s one
//! `WorkerPool` — via [`model_workers_shared`] instead of binding a full
//! session set per replica (each worker keeps its private data shard; only
//! the stateless execution workspaces are shared).

use crate::util::error::{bail, Result};

use crate::net::{Msg, Transport};
use crate::objective::{BatchSource, ModelObjective, Objective};
use crate::optimizer::{sample_direction, BetaSchedule};
use crate::runtime::Runtime;
use crate::vecmath;

/// Worker-side replica state + step math (transport-agnostic).
pub struct ZoWorker {
    pub id: u32,
    pub x: Vec<f32>,
    pub m: Vec<f32>,
    u: Vec<f32>,
    z: Vec<f32>,
    started: bool,
    pub obj: Box<dyn Objective>,
    /// local eval closure: returns (correct, total); optional
    pub eval_fn: Option<Box<dyn FnMut(&[f32]) -> (u64, u64)>>,
}

impl ZoWorker {
    pub fn new(id: u32, x0: Vec<f32>, obj: Box<dyn Objective>) -> Self {
        let d = x0.len();
        ZoWorker {
            id,
            x: x0,
            m: vec![0.0; d],
            u: vec![0.0; d],
            z: vec![0.0; d],
            started: false,
            obj,
            eval_fn: None,
        }
    }

    /// Phase 1 of a step: regenerate the direction from the broadcast seed
    /// and compute the local two-point losses.
    pub fn compute_proj(&mut self, t: u64, seed: u64, theta: f32, lam: f32) -> Result<(f64, f64)> {
        let d_raw = self.obj.d_raw();
        sample_direction(&mut self.u, d_raw, seed, t as usize);
        if !self.started {
            self.m.copy_from_slice(&self.u);
            self.started = true;
        }
        vecmath::cone_direction(&self.m, &self.u, theta, d_raw, &mut self.z);
        self.obj.advance(); // every worker advances its OWN shard stream
        self.obj.two_point(&self.x, &self.z, lam)
    }

    /// Phase 2: apply the aggregated projected gradient. Identical on all
    /// replicas, so states never diverge.
    pub fn apply(&mut self, g: f64, eta: f32, beta: f32) {
        vecmath::zo_update(&mut self.x, &mut self.m, &self.z, g as f32, eta, beta);
    }

    pub fn eval(&mut self) -> (u64, u64) {
        let x = self.x.clone();
        match &mut self.eval_fn {
            Some(f) => f(&x),
            None => (0, 0),
        }
    }
}

/// Build N full-replica model workers for one process, all sharing ONE
/// bound `loss`/`two_point` session pair — hence one forward scratch and
/// the runtime's one `WorkerPool` — instead of binding a session set per
/// replica (the ROADMAP per-process sharing item). Worker `i` owns
/// `samplers[i]` as its private data shard and starts from the same `x0`
/// replica. Bit-identical to per-worker sessions because session
/// workspaces carry no state across calls (pinned by
/// `shared_session_workers_match_private_session_workers`).
pub fn model_workers_shared(
    rt: &Runtime,
    preset: &str,
    x0: &[f32],
    samplers: Vec<Box<dyn BatchSource>>,
) -> Result<Vec<ZoWorker>> {
    let mut shared = None;
    let mut workers = Vec::with_capacity(samplers.len());
    for (id, src) in samplers.into_iter().enumerate() {
        let obj = match &shared {
            None => {
                let first = ModelObjective::new(rt, preset, src)?;
                shared = Some(first.sessions());
                first
            }
            Some((loss, two_point)) => {
                ModelObjective::with_sessions(rt, preset, src, loss.clone(), two_point.clone())?
            }
        };
        workers.push(ZoWorker::new(id as u32, x0.to_vec(), Box::new(obj)));
    }
    Ok(workers)
}

/// Per-step hyperparameters broadcast by the leader.
#[derive(Clone, Copy, Debug)]
pub struct DistHypers {
    pub theta: f32,
    pub eta: f32,
    pub lam: f32,
}

#[derive(Clone, Debug, Default)]
pub struct DistSummary {
    pub steps: u64,
    pub loss_curve: Vec<(u64, f64)>,
    pub eval_curve: Vec<(u64, f64)>,
    /// leader-side wire bytes sent + received (the O(1)/step claim)
    pub wire_bytes: u64,
}

/// In-process cluster: drives N replicas deterministically on one thread
/// (PJRT handles are not Send; process-level parallelism is provided by the
/// TCP path below). The protocol logic is identical.
pub struct LocalCluster {
    pub workers: Vec<ZoWorker>,
    pub run_seed: u64,
}

impl LocalCluster {
    pub fn new(workers: Vec<ZoWorker>, run_seed: u64) -> Self {
        LocalCluster { workers, run_seed }
    }

    fn step_seed(&self, t: u64) -> u64 {
        let mut s = self.run_seed ^ t.rotate_left(17);
        crate::util::rng::splitmix64(&mut s)
    }

    /// Run `steps` iterations; eval every `eval_every` (0 = never).
    pub fn run(&mut self, steps: u64, hypers: DistHypers, beta: &BetaSchedule, eval_every: u64) -> Result<DistSummary> {
        let mut summary = DistSummary::default();
        summary.steps = steps;
        let n = self.workers.len() as f64;
        for t in 0..steps {
            let seed = self.step_seed(t);
            let mut g_sum = 0f64;
            let mut loss_sum = 0f64;
            let mut wire = 0u64;
            let step_msg = Msg::Step { t, seed, theta: hypers.theta, beta: beta.at(t as usize), eta: hypers.eta, lam: hypers.lam };
            for w in &mut self.workers {
                wire += step_msg.wire_bytes() as u64;
                let (lp, lm) = w.compute_proj(t, seed, hypers.theta, hypers.lam)?;
                wire += Msg::Proj { t, worker_id: w.id, loss_plus: lp, loss_minus: lm }.wire_bytes() as u64;
                g_sum += (lp - lm) / (2.0 * hypers.lam as f64);
                loss_sum += 0.5 * (lp + lm);
            }
            let g = g_sum / n;
            let b = beta.at(t as usize);
            for w in &mut self.workers {
                wire += Msg::Apply { t, g }.wire_bytes() as u64;
                w.apply(g, hypers.eta, b);
            }
            summary.wire_bytes += wire;
            if t % 10 == 0 || t + 1 == steps {
                summary.loss_curve.push((t, loss_sum / n));
            }
            if eval_every > 0 && (t + 1) % eval_every == 0 {
                let (mut c, mut tot) = (0u64, 0u64);
                for w in &mut self.workers {
                    let (wc, wt) = w.eval();
                    c += wc;
                    tot += wt;
                }
                if tot > 0 {
                    summary.eval_curve.push((t + 1, c as f64 / tot as f64));
                }
            }
        }
        Ok(summary)
    }

    /// Check that all replicas hold bit-identical state.
    pub fn replicas_identical(&self) -> bool {
        let first = &self.workers[0];
        self.workers.iter().all(|w| w.x == first.x && w.m == first.m)
    }
}

// ---------------------------------------------------------------------------
// TCP leader / worker
// ---------------------------------------------------------------------------

/// Leader side: drive registered worker connections through the protocol.
pub fn run_leader(
    conns: &mut [Box<dyn Transport>],
    run_seed: u64,
    steps: u64,
    hypers: DistHypers,
    beta: &BetaSchedule,
    eval_every: u64,
) -> Result<DistSummary> {
    // registration
    let n_workers = conns.len() as u32;
    for (i, c) in conns.iter_mut().enumerate() {
        match c.recv()? {
            Msg::Hello { .. } => {}
            other => bail!("worker {i}: expected Hello, got {other:?}"),
        }
        c.send(&Msg::Welcome { n_workers, run_seed })?;
    }
    let mut summary = DistSummary::default();
    summary.steps = steps;
    let n = conns.len() as f64;
    for t in 0..steps {
        let mut s = run_seed ^ t.rotate_left(17);
        let seed = crate::util::rng::splitmix64(&mut s);
        let b = beta.at(t as usize);
        let msg = Msg::Step { t, seed, theta: hypers.theta, beta: b, eta: hypers.eta, lam: hypers.lam };
        for c in conns.iter_mut() {
            c.send(&msg)?;
            summary.wire_bytes += msg.wire_bytes() as u64;
        }
        let mut g_sum = 0f64;
        let mut loss_sum = 0f64;
        for c in conns.iter_mut() {
            match c.recv()? {
                Msg::Proj { t: pt, loss_plus, loss_minus, .. } if pt == t => {
                    g_sum += (loss_plus - loss_minus) / (2.0 * hypers.lam as f64);
                    loss_sum += 0.5 * (loss_plus + loss_minus);
                    summary.wire_bytes += 29; // Proj frame size
                }
                other => bail!("step {t}: expected Proj, got {other:?}"),
            }
        }
        let g = g_sum / n;
        let apply = Msg::Apply { t, g };
        for c in conns.iter_mut() {
            c.send(&apply)?;
            summary.wire_bytes += apply.wire_bytes() as u64;
        }
        if t % 10 == 0 || t + 1 == steps {
            summary.loss_curve.push((t, loss_sum / n));
        }
        if eval_every > 0 && (t + 1) % eval_every == 0 {
            let (mut corr, mut tot) = (0u64, 0u64);
            let emsg = Msg::Eval { t };
            for c in conns.iter_mut() {
                c.send(&emsg)?;
            }
            for c in conns.iter_mut() {
                match c.recv()? {
                    Msg::EvalResult { correct, total, .. } => {
                        corr += correct;
                        tot += total;
                    }
                    other => bail!("expected EvalResult, got {other:?}"),
                }
            }
            if tot > 0 {
                summary.eval_curve.push((t + 1, corr as f64 / tot as f64));
            }
        }
    }
    for c in conns.iter_mut() {
        c.send(&Msg::Shutdown)?;
    }
    Ok(summary)
}

/// Worker side: serve the protocol until Shutdown.
pub fn run_worker(conn: &mut dyn Transport, worker: &mut ZoWorker) -> Result<()> {
    conn.send(&Msg::Hello { worker_id: worker.id })?;
    match conn.recv()? {
        Msg::Welcome { .. } => {}
        other => bail!("expected Welcome, got {other:?}"),
    }
    let mut pending: Option<(u64, f32, f32)> = None; // (t, eta, beta)
    loop {
        match conn.recv()? {
            Msg::Step { t, seed, theta, beta, eta, lam } => {
                let (lp, lm) = worker.compute_proj(t, seed, theta, lam)?;
                conn.send(&Msg::Proj { t, worker_id: worker.id, loss_plus: lp, loss_minus: lm })?;
                pending = Some((t, eta, beta));
            }
            Msg::Apply { t, g } => {
                match pending.take() {
                    Some((pt, eta, beta)) if pt == t => worker.apply(g, eta, beta),
                    _ => bail!("Apply{{t={t}}} without matching Step"),
                }
            }
            Msg::Eval { t } => {
                let (c, tot) = worker.eval();
                conn.send(&Msg::EvalResult { t, worker_id: worker.id, correct: c, total: tot })?;
            }
            Msg::Shutdown => return Ok(()),
            other => bail!("unexpected message {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::{NativeQuadratic, Objective};

    const D: usize = 200;
    const HYP: DistHypers = DistHypers { theta: 1.2, eta: 1e-3, lam: 1e-2 };

    fn start(seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(seed);
        let mut x = vec![0f32; D];
        rng.fill_normal_f32(&mut x);
        x
    }

    fn worker(id: u32, x: Vec<f32>) -> ZoWorker {
        ZoWorker::new(id, x, Box::new(NativeQuadratic::new(D)))
    }

    #[test]
    fn replicas_stay_bit_identical() {
        let x0 = start(1);
        let mut cluster = LocalCluster::new(
            (0..4).map(|i| worker(i, x0.clone())).collect(),
            99,
        );
        cluster.run(100, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        assert!(cluster.replicas_identical());
    }

    #[test]
    fn cluster_descends() {
        let x0 = start(2);
        let mut obj = NativeQuadratic::new(D);
        let l0 = obj.loss(&x0).unwrap();
        let mut cluster = LocalCluster::new(vec![worker(0, x0)], 7);
        cluster.run(800, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        let l1 = obj.loss(&cluster.workers[0].x).unwrap();
        assert!(l1 < 0.5 * l0, "{l1} vs {l0}");
    }

    #[test]
    fn one_worker_cluster_equals_composed_conmezo() {
        // THE coordinator invariant: the distributed protocol with one
        // worker is bit-identical to single-node composed ConMeZO when both
        // regenerate directions from the same per-step seeds.
        let x0 = start(3);
        let steps = 50u64;
        let run_seed = 42u64;

        let mut cluster = LocalCluster::new(vec![worker(0, x0)], run_seed);
        // single node: run a manual loop that mirrors the worker math with
        // the same per-step seed derivation
        let mut x = start(3);
        let mut m = vec![0f32; D];
        let mut u = vec![0f32; D];
        let mut z = vec![0f32; D];
        let mut obj = NativeQuadratic::new(D);
        let mut started = false;
        for t in 0..steps {
            let seed = cluster.step_seed(t);
            sample_direction(&mut u, D, seed, t as usize);
            if !started {
                m.copy_from_slice(&u);
                started = true;
            }
            vecmath::cone_direction(&m, &u, HYP.theta, D, &mut z);
            let (lp, lm) = obj.two_point(&x, &z, HYP.lam).unwrap();
            let g = (lp - lm) / (2.0 * HYP.lam as f64);
            vecmath::zo_update(&mut x, &mut m, &z, g as f32, HYP.eta, 0.9);
        }
        cluster.run(steps, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        assert_eq!(cluster.workers[0].x, x, "distributed != single-node");
        assert_eq!(cluster.workers[0].m, m);
    }

    #[test]
    fn multi_worker_aggregate_matches_manual_average() {
        // 2 deterministic workers on the same objective: the applied g must
        // equal the mean of the individual projections
        let x0 = start(4);
        let mut w0 = worker(0, x0.clone());
        let mut w1 = worker(1, x0.clone());
        let seed = 1234u64;
        let (lp0, lm0) = w0.compute_proj(0, seed, HYP.theta, HYP.lam).unwrap();
        let (lp1, lm1) = w1.compute_proj(0, seed, HYP.theta, HYP.lam).unwrap();
        let g = ((lp0 - lm0) + (lp1 - lm1)) / (2.0 * 2.0 * HYP.lam as f64);
        w0.apply(g, HYP.eta, 0.9);
        w1.apply(g, HYP.eta, 0.9);
        assert_eq!(w0.x, w1.x);

        let mut cluster = LocalCluster::new(vec![worker(0, x0.clone()), worker(1, x0)], 0);
        // reproduce: force the same seed via run_seed so that step_seed(0)
        // equals `seed`? Not needed — just check the cluster's own first
        // step keeps replicas identical and applies a mean.
        cluster.run(1, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        assert!(cluster.replicas_identical());
    }

    #[test]
    fn wire_bytes_are_o1_per_step() {
        let x0 = start(5);
        let mut cluster = LocalCluster::new(vec![worker(0, x0.clone()), worker(1, x0)], 1);
        let s = cluster.run(10, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        let per_step_per_worker = s.wire_bytes as f64 / 10.0 / 2.0;
        assert!(per_step_per_worker < 200.0, "{per_step_per_worker} B");
        // vs shipping the direction: 4*D bytes
        assert!(per_step_per_worker < (4 * D) as f64 / 2.0);
    }

    #[test]
    fn tcp_leader_worker_end_to_end() {
        use crate::net::TcpTransport;
        use std::net::TcpListener;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let x0 = start(6);
        let x0c = x0.clone();

        let wh = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr.to_string()).unwrap();
            let mut w = worker(0, x0c);
            run_worker(&mut t, &mut w).unwrap();
            w.x
        });
        let (s, _) = listener.accept().unwrap();
        let mut conns: Vec<Box<dyn Transport>> = vec![Box::new(TcpTransport::new(s).unwrap())];
        let summary = run_leader(&mut conns, 11, 30, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        let x_worker = wh.join().unwrap();

        // equivalence with LocalCluster under the same run seed
        let mut cluster = LocalCluster::new(vec![worker(0, x0)], 11);
        cluster.run(30, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        assert_eq!(x_worker, cluster.workers[0].x);
        assert!(summary.wire_bytes > 0);
    }
}
