//! Hyperparameter grid sweeps — the paper's tuning protocol (App. C.1/C.2:
//! grids over eta x beta x theta with mean-final-objective selection).
//!
//! `Grid` enumerates the cartesian product of axes; `Sweep` runs a user
//! closure per point (typically a Trainer or quadratic run), aggregates
//! over trial seeds, and reports the argmin/argmax with the full response
//! surface for heatmap records (Fig. 5).

use crate::util::error::Result;

use crate::util::json::Json;
use crate::util::mean_std;

/// One named axis of the grid.
#[derive(Clone, Debug)]
pub struct Axis {
    pub name: String,
    pub values: Vec<f64>,
}

impl Axis {
    pub fn new(name: &str, values: &[f64]) -> Axis {
        assert!(!values.is_empty(), "axis {name} is empty");
        Axis { name: name.to_string(), values: values.to_vec() }
    }
}

/// A point in the grid: (axis name, value) pairs in axis order.
pub type Point = Vec<(String, f64)>;

#[derive(Clone, Debug)]
pub struct Grid {
    pub axes: Vec<Axis>,
}

impl Grid {
    pub fn new(axes: Vec<Axis>) -> Grid {
        Grid { axes }
    }

    pub fn len(&self) -> usize {
        self.axes.iter().map(|a| a.values.len()).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enumerate all points in row-major order (last axis fastest).
    pub fn points(&self) -> Vec<Point> {
        let mut out = vec![Vec::new()];
        for ax in &self.axes {
            let mut next = Vec::with_capacity(out.len() * ax.values.len());
            for p in &out {
                for &v in &ax.values {
                    let mut q = p.clone();
                    q.push((ax.name.clone(), v));
                    next.push(q);
                }
            }
            out = next;
        }
        out
    }
}

/// Convenience accessor on a Point.
pub fn point_get(p: &Point, name: &str) -> f64 {
    p.iter()
        .find(|(n, _)| n == name)
        .map(|(_, v)| *v)
        .unwrap_or_else(|| panic!("point has no axis {name:?}"))
}

/// Result of one sweep cell (mean over trials).
#[derive(Clone, Debug)]
pub struct Cell {
    pub point: Point,
    pub mean: f64,
    pub std: f64,
    pub trials: usize,
}

/// Outcome of a sweep: every cell plus the selected optimum.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub cells: Vec<Cell>,
    pub best: Cell,
    pub minimize: bool,
}

impl SweepResult {
    pub fn to_json(&self) -> Json {
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut pairs: Vec<(&str, Json)> = Vec::new();
                for (n, v) in &c.point {
                    pairs.push((Box::leak(n.clone().into_boxed_str()), Json::num(*v)));
                }
                pairs.push(("mean", Json::num(c.mean)));
                pairs.push(("std", Json::num(c.std)));
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![
            ("minimize", Json::Bool(self.minimize)),
            ("best_mean", Json::num(self.best.mean)),
            (
                "best_point",
                Json::Arr(
                    self.best
                        .point
                        .iter()
                        .map(|(n, v)| Json::obj(vec![("axis", Json::str(n.as_str())), ("value", Json::num(*v))]))
                        .collect(),
                ),
            ),
            ("cells", Json::Arr(cells)),
        ])
    }
}

/// Run the sweep: `objective(point, trial_seed)` returns the scalar to
/// aggregate (lower is better when `minimize`).
pub fn run_sweep(
    grid: &Grid,
    trial_seeds: &[u64],
    minimize: bool,
    mut objective: impl FnMut(&Point, u64) -> Result<f64>,
) -> Result<SweepResult> {
    assert!(!trial_seeds.is_empty());
    let mut cells = Vec::with_capacity(grid.len());
    for point in grid.points() {
        let mut vals = Vec::with_capacity(trial_seeds.len());
        for &s in trial_seeds {
            let v = objective(&point, s)?;
            if v.is_finite() {
                vals.push(v);
            }
        }
        // all-diverged cells get the worst possible score
        let (mean, std) = if vals.is_empty() {
            (if minimize { f64::INFINITY } else { f64::NEG_INFINITY }, f64::NAN)
        } else {
            mean_std(&vals)
        };
        cells.push(Cell { point, mean, std, trials: vals.len() });
    }
    let best = cells
        .iter()
        .min_by(|a, b| {
            let (x, y) = if minimize { (a.mean, b.mean) } else { (b.mean, a.mean) };
            x.partial_cmp(&y).unwrap_or(std::cmp::Ordering::Equal)
        })
        .expect("empty grid")
        .clone();
    Ok(SweepResult { cells, best, minimize })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid2() -> Grid {
        Grid::new(vec![Axis::new("eta", &[0.1, 0.2]), Axis::new("beta", &[0.5, 0.9, 0.99])])
    }

    #[test]
    fn enumerates_cartesian_product() {
        let g = grid2();
        assert_eq!(g.len(), 6);
        let pts = g.points();
        assert_eq!(pts.len(), 6);
        // last axis fastest
        assert_eq!(point_get(&pts[0], "eta"), 0.1);
        assert_eq!(point_get(&pts[0], "beta"), 0.5);
        assert_eq!(point_get(&pts[1], "beta"), 0.9);
        assert_eq!(point_get(&pts[3], "eta"), 0.2);
    }

    #[test]
    fn selects_minimum_with_trial_averaging() {
        // objective = (eta - 0.2)^2 + (beta - 0.9)^2 + seed-dependent noise
        let r = run_sweep(&grid2(), &[1, 2, 3, 4], true, |p, s| {
            let e = point_get(p, "eta");
            let b = point_get(p, "beta");
            let noise = ((s as f64 * 0.37).sin()) * 1e-3;
            Ok((e - 0.2).powi(2) + (b - 0.9).powi(2) + noise)
        })
        .unwrap();
        assert_eq!(point_get(&r.best.point, "eta"), 0.2);
        assert_eq!(point_get(&r.best.point, "beta"), 0.9);
        assert_eq!(r.cells.len(), 6);
        assert_eq!(r.best.trials, 4);
    }

    #[test]
    fn maximize_mode() {
        let g = Grid::new(vec![Axis::new("x", &[1.0, 2.0, 3.0])]);
        let r = run_sweep(&g, &[0], false, |p, _| Ok(point_get(p, "x"))).unwrap();
        assert_eq!(r.best.mean, 3.0);
    }

    #[test]
    fn diverged_cells_lose() {
        let g = Grid::new(vec![Axis::new("x", &[0.0, 1.0])]);
        let r = run_sweep(&g, &[0, 1], true, |p, _| {
            let x = point_get(p, "x");
            Ok(if x == 0.0 { f64::NAN } else { 5.0 })
        })
        .unwrap();
        assert_eq!(point_get(&r.best.point, "x"), 1.0);
        assert_eq!(r.cells[0].trials, 0);
        assert!(r.cells[0].mean.is_infinite());
    }

    #[test]
    fn json_emission_roundtrips() {
        let g = Grid::new(vec![Axis::new("x", &[1.0])]);
        let r = run_sweep(&g, &[0], true, |_, _| Ok(2.5)).unwrap();
        let j = r.to_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(v.get("best_mean").unwrap().as_f64(), Some(2.5));
    }

    #[test]
    fn sweep_tunes_conmezo_on_quadratic() {
        // end-to-end: a tiny App C.1-style grid actually selects a working
        // (eta, theta) pair for ConMeZO on the synthetic quadratic
        use crate::objective::NativeQuadratic;
        use crate::optimizer::{BetaSchedule, ConMeZo, ZoOptimizer};
        let g = Grid::new(vec![
            Axis::new("eta", &[1e-1, 1e-3, 1e-5]),
            Axis::new("theta", &[1.2, 1.5]),
        ]);
        let d = 200;
        let r = run_sweep(&g, &[0, 1], true, |p, s| {
            let mut opt = ConMeZo::new(
                d,
                point_get(p, "eta") as f32,
                1e-2,
                point_get(p, "theta") as f32,
                BetaSchedule::Constant(0.9),
            );
            let mut obj = NativeQuadratic::new(d);
            let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(s);
            let mut x = vec![0f32; d];
            rng.fill_normal_f32(&mut x);
            for t in 0..300 {
                opt.step(&mut x, &mut obj, t, s)?;
            }
            crate::objective::Objective::loss(&mut obj, &x)
        })
        .unwrap();
        // eta=1e-3 descends; 1e-1 diverges; 1e-5 barely moves
        assert_eq!(point_get(&r.best.point, "eta"), 1e-3, "best: {:?}", r.best.point);
    }
}
