//! Run recording: every experiment writes a structured JSON record under
//! `results/` so tables/figures are regenerable and auditable.

use std::path::{Path, PathBuf};

use crate::util::error::Result;

use crate::util::json::Json;

#[derive(Debug)]
pub struct RunRecord {
    pub name: String,
    meta: Vec<(String, Json)>,
    rows: Vec<Json>,
}

impl RunRecord {
    pub fn new(name: &str) -> Self {
        RunRecord { name: name.to_string(), meta: Vec::new(), rows: Vec::new() }
    }

    pub fn meta(&mut self, key: &str, v: Json) -> &mut Self {
        self.meta.push((key.to_string(), v));
        self
    }

    pub fn meta_str(&mut self, key: &str, v: &str) -> &mut Self {
        self.meta(key, Json::str(v))
    }

    pub fn meta_num(&mut self, key: &str, v: f64) -> &mut Self {
        self.meta(key, Json::num(v))
    }

    pub fn push_row(&mut self, row: Json) {
        self.rows.push(row);
    }

    pub fn row(&mut self, pairs: Vec<(&str, Json)>) {
        self.rows.push(Json::obj(pairs));
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn to_json(&self) -> Json {
        let mut obj = vec![("name".to_string(), Json::str(&self.name))];
        obj.extend(self.meta.iter().cloned());
        obj.push(("rows".to_string(), Json::Arr(self.rows.clone())));
        Json::Obj(obj.into_iter().collect())
    }

    /// Write to results/<name>.json (creating the directory).
    pub fn save(&self) -> Result<PathBuf> {
        self.save_in(Path::new("results"))
    }

    pub fn save_in(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(format!("{}.json", self.name));
        // atomic: a crash mid-save must never leave a half-written summary
        crate::util::fs::atomic_write(&path, self.to_json().to_string().as_bytes())?;
        Ok(path)
    }
}

/// Render an aligned text table (the repro binary prints paper-style rows).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            } else {
                // rows may be wider than the header list; grow the width
                // vector so the extra columns still align instead of being
                // padded to an arbitrary 8
                widths.push(cell.len());
            }
        }
    }
    let mut out = String::new();
    let line = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&line(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    out.push('\n');
    for row in rows {
        out.push_str(&line(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrip() {
        let dir = std::env::temp_dir().join("conmezo_metrics_tests");
        let mut r = RunRecord::new("unit_test_run");
        r.meta_str("task", "sst2").meta_num("steps", 100.0);
        r.row(vec![("step", Json::num(1.0)), ("loss", Json::num(0.5))]);
        r.row(vec![("step", Json::num(2.0)), ("loss", Json::num(0.4))]);
        let path = r.save_in(&dir).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("task").unwrap().as_str(), Some("sst2"));
        assert_eq!(v.get("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["task", "MeZO", "ConMeZO"],
            &[
                vec!["sst2".into(), "92.8".into(), "93.5".into()],
                vec!["trec-long-name".into(), "88.4".into(), "90.0".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("task"));
        assert!(lines[2].contains("92.8"));
    }

    #[test]
    fn rows_wider_than_headers_stay_aligned() {
        // regression: extra cells used to be padded to a hardcoded 8,
        // misaligning every row with a different overflow width
        let t = render_table(
            &["task", "acc"],
            &[
                vec!["sst2".into(), "92.8".into(), "wide-overflow-cell".into(), "zz".into()],
                vec!["trec".into(), "88.4".into(), "y".into(), "longer-tail".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        // both data rows pad overflow columns to the widest cell, so the
        // last column starts at the same offset in each row
        assert_eq!(
            lines[2].find("zz").unwrap(),
            lines[3].find("longer-tail").unwrap(),
            "overflow columns misaligned:\n{t}"
        );
        // trailing-column cells are fully present, not truncated
        assert!(lines[2].contains("wide-overflow-cell"));
        assert!(lines[3].contains("longer-tail"));
    }
}
