//! Fault-tolerant multi-process cluster engine (ISSUE-6 tentpole).
//!
//! [`Leader`] generalizes the lockstep `run_leader` loop with the failure
//! semantics a real deployment needs, all riding on the shared-randomness
//! property that makes recovery nearly free:
//!
//! * **Stragglers** — `Proj` collection runs under `proj_timeout`; a worker
//!   that misses the window is simply skipped for that step and the
//!   projected gradient is renormalized by the count actually heard from
//!   (`g = Σ gᵢ / |received|`). The straggler still gets the `Apply`, so
//!   its replica stays bit-identical; `max_strikes` consecutive timeouts
//!   drop it for good.
//! * **Worker death** — a dead socket (send/recv error, EOF, protocol
//!   violation) drops the worker; training continues while at least one
//!   replica is live.
//! * **Rejoin via seed replay** — the leader appends a 28-byte
//!   [`StepRecord`] `(seed, g, theta, eta, beta)` per step to a
//!   [`StepLog`], persisted through an append-only write-ahead log
//!   ([`crate::checkpoint::StepLogWriter`]: per-record CRC framing, O(1)
//!   bytes/step, fsync policy knob). A worker that (re)connects at leader
//!   step `T` announcing its own step `t ≤ T` (0 fresh, or `ckpt.step`
//!   when warm-started from a snapshot) receives the gap `t..T` in chunked
//!   `Replay` frames and fast-forwards with ZERO function evaluations
//!   ([`ZoWorker::replay`]) — O(1) bytes per missed step.
//! * **Leader restart** — the WAL append (+ fsync under the default
//!   `every-step` policy) happens BEFORE the step's `Apply` broadcast, so
//!   no replica can ever apply a step the log doesn't hold. A killed
//!   leader therefore restarts with [`Leader::resume`]: step count,
//!   replayable record stream and last consensus hash all come back from
//!   the WAL (a torn tail is truncated, not fatal), workers re-admit
//!   through the ordinary `Hello`/`Replay` path, and the run continues
//!   bit-identical to an uninterrupted one.
//! * **Divergence tripwire** — every `hash_check_every` steps (and
//!   immediately after every rejoin) the leader collects an FNV-1a hash of
//!   each replica's parameters; any disagreement aborts the run rather
//!   than silently training divergent replicas. The last agreed hash also
//!   rides in `Welcome`, letting a rejoining worker verify itself before
//!   taking any step.
//!
//! Wire accounting stays split: `wire_bytes` counts only the steady-state
//! `Step`/`Proj`/`Apply` frames (identical to `LocalCluster`, pinned by a
//! parity test); registration, replay, eval, hash checks and heartbeats
//! land in `control_bytes`.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crate::checkpoint::{Checkpoint, FsyncPolicy, StepLog, StepLogWriter, StepRecord};
use crate::net::{Msg, Transport, TransportErrorKind, PROTO_VERSION, REPLAY_CHUNK};
use crate::optimizer::BetaSchedule;
use crate::telemetry::{Registry, StepTrace, StepTracer};
use crate::util::error::{bail, Result};
use crate::util::Stopwatch;

use super::distributed::{step_seed, DistHypers, DistSummary, ZoWorker};

/// Leader-side configuration. [`LeaderConfig::new`] yields lockstep
/// semantics (no timeouts, no tripwire, no persistence) — the behavior of
/// the original `run_leader`; flip the public fields for fault tolerance.
#[derive(Clone, Debug)]
pub struct LeaderConfig {
    pub n_workers: u32,
    pub run_seed: u64,
    pub steps: u64,
    pub hypers: DistHypers,
    pub beta: BetaSchedule,
    /// eval every this many steps (0 = never)
    pub eval_every: u64,
    /// max wait for each worker's `Proj` (None = block forever, lockstep)
    pub proj_timeout: Option<Duration>,
    /// max wait for each worker's `EvalResult` (evals run long; heartbeats
    /// refresh this window)
    pub eval_timeout: Option<Duration>,
    /// consecutive Proj timeouts before a straggler is dropped for good
    pub max_strikes: u32,
    /// divergence tripwire period in steps (0 = only after rejoins)
    pub hash_check_every: u64,
    /// persist the step log here as an append-only WAL (the on-disk
    /// rejoin + leader-restart substrate)
    pub step_log: Option<PathBuf>,
    /// WAL durability knob: when each appended record hits the disk
    pub fsync: FsyncPolicy,
    /// health/RTT period in steps (0 = off): each period the leader pings
    /// every live worker with `Heartbeat`, records the round-trip time in
    /// its [`Registry`], and logs a one-line cluster health summary
    pub metrics_every: u64,
    /// stream one leader-side [`StepTrace`] JSONL record per step here
    pub trace: Option<PathBuf>,
}

impl LeaderConfig {
    pub fn new(n_workers: u32, run_seed: u64, steps: u64, hypers: DistHypers, beta: BetaSchedule) -> Self {
        LeaderConfig {
            n_workers,
            run_seed,
            steps,
            hypers,
            beta,
            eval_every: 0,
            proj_timeout: None,
            eval_timeout: None,
            max_strikes: 3,
            hash_check_every: 0,
            step_log: None,
            fsync: FsyncPolicy::EveryStep,
            metrics_every: 0,
            trace: None,
        }
    }
}

struct Slot {
    conn: Option<Box<dyn Transport>>,
    strikes: u32,
}

/// Outcome of draining one worker's connection for an expected message.
enum Polled<R> {
    Got(R, u64),
    Timeout,
    Dead(String),
}

pub struct Leader {
    cfg: LeaderConfig,
    slots: Vec<Slot>,
    log: StepLog,
    /// append-only on-disk mirror of `log` (opened when `cfg.step_log` is
    /// set; every record is appended + policy-synced BEFORE its `Apply`
    /// broadcast, so the WAL always covers every step any replica took)
    wal: Option<StepLogWriter>,
    t: u64,
    /// (step, hash) agreed by all live replicas at the last tripwire
    consensus: Option<(u64, u64)>,
    /// force a tripwire round before the next step (set on rejoin)
    verify_hash: bool,
    summary: DistSummary,
    telemetry: Arc<Registry>,
    tracer: Option<StepTracer>,
}

impl Leader {
    pub fn new(cfg: LeaderConfig) -> Self {
        let slots = (0..cfg.n_workers).map(|_| Slot { conn: None, strikes: 0 }).collect();
        let telemetry = Arc::new(Registry::new(cfg.n_workers as usize));
        Leader {
            cfg,
            slots,
            log: StepLog::new(),
            wal: None,
            t: 0,
            consensus: None,
            verify_hash: false,
            summary: DistSummary::default(),
            telemetry,
            tracer: None,
        }
    }

    /// Rebuild a leader from its WAL after a crash (the `--resume` path).
    ///
    /// The step count, the full replayable record stream, and the last
    /// agreed parameter hash all come back from the log; a torn tail left
    /// by the crash is truncated to the last valid record (counted in the
    /// `wal_truncations` telemetry), never fatal. Workers that survived
    /// the outage re-admit through the ordinary `Hello`/`Replay` path and
    /// must pass a divergence tripwire before the first resumed step.
    ///
    /// `init_from` optionally names a checkpoint used to sanity-check the
    /// log: a snapshot AHEAD of the recovered WAL means the log lost
    /// fsynced-but-applied steps (e.g. `every-N` policy + power loss) and
    /// resuming would fork history, so it bails instead.
    pub fn resume(cfg: LeaderConfig, init_from: Option<&Path>) -> Result<Leader> {
        let path = match cfg.step_log.clone() {
            Some(p) => p,
            None => bail!("leader resume requires a step-log path (the WAL is the recovery substrate)"),
        };
        let (writer, rec) = StepLogWriter::resume(&path, cfg.fsync)?;
        let mut leader = Leader::new(cfg);
        if rec.truncated() {
            leader.telemetry.wal_truncations.inc();
            crate::warn_!(
                "leader",
                "recovered WAL {}: truncated {} torn record(s) / {} B off the tail",
                path.display(),
                rec.dropped_records,
                rec.dropped_bytes
            );
        }
        leader.t = rec.log.records.len() as u64;
        leader.log = rec.log;
        leader.consensus = rec.consensus;
        // replicas that outlived the leader must prove bit-identity before
        // training moves again
        leader.verify_hash = leader.t > 0;
        if let Some(ckpt_path) = init_from {
            let ck = Checkpoint::load(ckpt_path)?;
            if ck.step > leader.t {
                bail!(
                    "checkpoint {} is at step {} but the recovered WAL only reaches step {} — the log is stale (lost tail under a relaxed fsync policy?)",
                    ckpt_path.display(),
                    ck.step,
                    leader.t
                );
            }
        }
        crate::info!(
            "leader",
            "resumed from WAL {} at step {} ({} records, consensus {})",
            path.display(),
            leader.t,
            leader.log.records.len(),
            match leader.consensus {
                Some((ct, h)) => format!("{h:016x}@{ct}"),
                None => "unknown".into(),
            }
        );
        leader.wal = Some(writer);
        Ok(leader)
    }

    /// Current step (= records logged so far).
    pub fn t(&self) -> u64 {
        self.t
    }

    /// The leader's metric registry (per-worker RTT, byte and fault
    /// counters). Clone the `Arc` before `run` consumes the leader to read
    /// the metrics afterwards.
    pub fn telemetry(&self) -> Arc<Registry> {
        self.telemetry.clone()
    }

    /// Byte accounting, mirrored into the registry counters so the health
    /// line and `DistSummary` always agree.
    fn acct(&mut self, wire: bool, bytes: u64) {
        if wire {
            self.summary.wire_bytes += bytes;
            self.telemetry.wire_bytes.add(bytes);
        } else {
            self.summary.control_bytes += bytes;
            self.telemetry.control_bytes.add(bytes);
        }
    }

    fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.conn.is_some()).count()
    }

    /// Register a (re)connecting worker: validate the v2 handshake, ship
    /// the replay gap, and await its `Ready`. Errors leave the cluster
    /// untouched (the offending connection is simply dropped by the
    /// caller). This is where the old `run_leader` bugs die: the
    /// `Hello { worker_id }` payload is actually validated — version,
    /// range, duplicates, and a step claim ahead of the leader all bail
    /// with a clear message.
    pub fn admit(&mut self, mut conn: Box<dyn Transport>) -> Result<u32> {
        let hello = conn.recv()?;
        self.acct(false, hello.wire_bytes() as u64);
        let (wid, wt) = match hello {
            Msg::Hello { proto, worker_id, t } => {
                if proto != PROTO_VERSION {
                    bail!("worker {worker_id}: protocol version mismatch (worker v{proto}, leader v{PROTO_VERSION})");
                }
                (worker_id, t)
            }
            other => bail!("expected Hello, got {other:?}"),
        };
        if wid >= self.cfg.n_workers {
            bail!("worker id {wid} out of range: cluster has {} data shards (ids 0..{})", self.cfg.n_workers, self.cfg.n_workers);
        }
        if self.slots[wid as usize].conn.is_some() {
            bail!("duplicate worker id {wid}: that data shard is already registered (two workers on one shard would skew the average)");
        }
        if wt > self.t {
            bail!("worker {wid} claims step {wt} but the leader is at step {}", self.t);
        }
        let welcome_hash = match self.consensus {
            Some((ct, h)) if ct == self.t => h,
            _ => 0, // unknown at this exact step
        };
        let welcome = Msg::Welcome {
            proto: PROTO_VERSION,
            n_workers: self.cfg.n_workers,
            run_seed: self.cfg.run_seed,
            t: self.t,
            params_hash: welcome_hash,
        };
        conn.send(&welcome)?;
        self.acct(false, welcome.wire_bytes() as u64);
        // ship the gap wt..t as chunked Replay frames (O(1) bytes/step)
        let mut from = wt as usize;
        while from < self.t as usize {
            let upto = (from + REPLAY_CHUNK).min(self.t as usize);
            let msg = Msg::Replay { from_t: from as u64, records: self.log.records[from..upto].to_vec() };
            conn.send(&msg)?;
            let bytes = msg.wire_bytes() as u64;
            self.telemetry.replay_bytes.add(bytes);
            self.acct(false, bytes);
            from = upto;
        }
        let ready = conn.recv()?;
        self.acct(false, ready.wire_bytes() as u64);
        match ready {
            Msg::Ready { t, worker_id, params_hash } => {
                if worker_id != wid {
                    bail!("Ready from worker {worker_id} on worker {wid}'s connection");
                }
                if t != self.t {
                    bail!("worker {wid} reports step {t} after replay but the leader is at {}", self.t);
                }
                if welcome_hash != 0 && params_hash != welcome_hash {
                    bail!("worker {wid} rejoined with divergent parameters: {params_hash:016x} != consensus {welcome_hash:016x}");
                }
            }
            other => bail!("expected Ready from worker {wid}, got {other:?}"),
        }
        self.slots[wid as usize] = Slot { conn: Some(conn), strikes: 0 };
        if self.t > 0 {
            self.summary.rejoins += 1;
            self.telemetry.reconnects.inc();
            // pin the rejoin at runtime: the very next thing the cluster
            // does is a tripwire round, so a diverged rejoiner aborts the
            // run instead of polluting the average
            self.verify_hash = true;
            crate::info!("leader", "worker {wid} rejoined at step {} via seed replay ({} records)", self.t, self.t - wt);
        } else {
            crate::info!("leader", "worker {wid} registered ({}/{} shards live)", self.live(), self.cfg.n_workers);
        }
        Ok(wid)
    }

    /// Run to completion with a static worker set (no mid-run joins).
    pub fn run(self, initial: Vec<Box<dyn Transport>>) -> Result<DistSummary> {
        self.run_with_joiner(initial, |_| Vec::new())
    }

    /// Run to completion; `joiner(t)` is polled between steps and returns
    /// any newly accepted connections (e.g. from a non-blocking TCP accept
    /// loop). Initial registration errors are fatal; a failed mid-run
    /// (re)join only drops that connection.
    pub fn run_with_joiner(
        mut self,
        initial: Vec<Box<dyn Transport>>,
        mut joiner: impl FnMut(u64) -> Vec<Box<dyn Transport>>,
    ) -> Result<DistSummary> {
        self.summary.steps = self.cfg.steps;
        if let Some(path) = self.cfg.trace.clone() {
            self.tracer = Some(StepTracer::new(Some(&path))?);
        }
        // fresh runs open (truncate) the WAL here; `resume` arrives with
        // the recovered writer already in place and must not clobber it
        if let Some(path) = self.cfg.step_log.clone().filter(|_| self.wal.is_none()) {
            self.wal = Some(StepLogWriter::create(&path, self.cfg.fsync)?);
        }
        for conn in initial {
            self.admit(conn)?;
        }
        while self.t < self.cfg.steps {
            for conn in joiner(self.t) {
                if let Err(e) = self.admit(conn) {
                    crate::warn_!("leader", "rejected (re)join at step {}: {e}", self.t);
                }
            }
            if self.live() == 0 {
                self.sync_wal();
                bail!("all {} workers lost at step {} (step log {})", self.cfg.n_workers, self.t,
                    match &self.cfg.step_log { Some(p) => format!("persisted at {}", p.display()), None => "not persisted".into() });
            }
            if self.verify_hash
                || (self.cfg.hash_check_every > 0 && self.t > 0 && self.t % self.cfg.hash_check_every == 0)
            {
                self.verify_hash = false;
                self.hash_round()?;
            }
            if self.cfg.metrics_every > 0 && self.t % self.cfg.metrics_every == 0 {
                self.rtt_round();
                self.health_line();
            }
            self.train_step()?;
            if self.cfg.eval_every > 0 && self.t % self.cfg.eval_every == 0 {
                self.eval_round();
            }
        }
        self.broadcast(&Msg::Shutdown, false);
        if let Some(w) = self.wal.as_mut() {
            w.sync()?;
        }
        if let Some(tracer) = self.tracer.as_mut() {
            tracer.flush()?;
        }
        Ok(self.summary)
    }

    /// Best-effort flush of any WAL bytes still pending under a relaxed
    /// fsync policy (abort paths; errors are logged, not compounded).
    fn sync_wal(&mut self) {
        if let Some(w) = self.wal.as_mut() {
            if let Err(e) = w.sync() {
                crate::warn_!("leader", "WAL flush failed: {e}");
            }
        }
    }

    /// Heartbeat ping/echo over every live connection: measures per-worker
    /// round-trip time into the registry's `rtt` histogram. Runs at a step
    /// boundary, so the only expected frame is our own echo — stale
    /// straggler traffic is drained as control bytes; a timeout only bumps
    /// the `timeouts` counter (the Proj window, not this probe, decides
    /// strikes); a dead socket drops the worker.
    fn rtt_round(&mut self) {
        let t = self.t;
        let ping = Msg::Heartbeat { t };
        let ping_bytes = ping.wire_bytes() as u64;
        let window = self.cfg.proj_timeout.unwrap_or(Duration::from_secs(5));
        for i in 0..self.slots.len() {
            let mut control = 0u64;
            let outcome = {
                let conn = match self.slots[i].conn.as_deref_mut() {
                    Some(c) => c,
                    None => continue,
                };
                let sw = Stopwatch::start();
                match conn.send(&ping) {
                    Err(e) => Err(format!("heartbeat send failed: {e}")),
                    Ok(()) => {
                        control += ping_bytes;
                        loop {
                            match conn.recv_timeout(window) {
                                Err(e) => break Err(format!("heartbeat recv failed: {e}")),
                                Ok(None) => break Ok(None),
                                Ok(Some(Msg::Heartbeat { t: et })) if et == t => {
                                    control += ping_bytes;
                                    break Ok(Some(sw.secs()));
                                }
                                Ok(Some(msg))
                                    if matches!(msg, Msg::Heartbeat { .. }) || out_of_phase(t, &msg) =>
                                {
                                    control += msg.wire_bytes() as u64;
                                    continue;
                                }
                                Ok(Some(msg)) => {
                                    break Err(format!("protocol violation: expected Heartbeat echo, got {msg:?}"))
                                }
                            }
                        }
                    }
                }
            };
            self.acct(false, control);
            match outcome {
                Ok(Some(secs)) => self.telemetry.rtt.observe(Duration::from_secs_f64(secs)),
                Ok(None) => self.telemetry.timeouts.inc(),
                Err(reason) => self.drop_worker(i, &reason),
            }
        }
    }

    /// One-line cluster health summary (the `--metrics-every N` output).
    fn health_line(&self) {
        let r = &self.telemetry;
        crate::info!(
            "leader",
            "health t={} live={}/{} rtt_p50={:.3}ms timeouts={} stragglers={} lost={} rejoins={} wire={}B control={}B wal_appends={} wal_fsyncs={} wal_trunc={} reconnects={} faults={}",
            self.t,
            self.live(),
            self.cfg.n_workers,
            r.rtt.percentile_ns(50.0) as f64 / 1e6,
            r.timeouts.get(),
            self.summary.straggler_events,
            self.summary.workers_lost,
            self.summary.rejoins,
            self.summary.wire_bytes,
            self.summary.control_bytes,
            r.wal_appends.get(),
            r.wal_fsyncs.get(),
            r.wal_truncations.get(),
            r.reconnects.get(),
            r.faults_injected.get(),
        );
    }

    fn train_step(&mut self) -> Result<()> {
        let sw = Stopwatch::start();
        let t = self.t;
        let seed = step_seed(self.cfg.run_seed, t);
        let beta = self.cfg.beta.at(t as usize);
        let hy = self.cfg.hypers;
        let msg = Msg::Step { t, seed, theta: hy.theta, beta, eta: hy.eta, lam: hy.lam };
        self.broadcast(&msg, true);
        let projs = loop {
            if self.live() == 0 {
                self.sync_wal();
                bail!("all {} workers lost at step {t}", self.cfg.n_workers);
            }
            let p = self.collect(t, self.cfg.proj_timeout, true, "Proj", |wid, m| match *m {
                Msg::Proj { t: pt, worker_id, loss_plus, loss_minus } if pt == t && worker_id == wid => {
                    Some((loss_plus, loss_minus))
                }
                _ => None,
            });
            if !p.is_empty() {
                break p;
            }
            // every live worker straggled this round (strikes were applied
            // inside collect) — wait out another window
        };
        let k = projs.len() as f64;
        let mut g_sum = 0f64;
        let mut loss_sum = 0f64;
        let (mut lp_sum, mut lm_sum) = (0f64, 0f64);
        for (lp, lm) in &projs {
            g_sum += (lp - lm) / (2.0 * hy.lam as f64);
            loss_sum += 0.5 * (lp + lm);
            lp_sum += lp;
            lm_sum += lm;
        }
        // renormalize by the replicas actually heard from, not the nominal
        // cluster size — a straggler's missing shard must not bias g to 0
        let g = g_sum / k;
        let rec = StepRecord { seed, g, theta: hy.theta, eta: hy.eta, beta };
        self.log.records.push(rec);
        // WAL-before-Apply: the record must be durable (per the fsync
        // policy) before any replica can act on it, so a crashed leader can
        // always replay every step a worker took — append failure is fatal
        // rather than a silent durability downgrade
        if let Some(w) = self.wal.as_mut() {
            let f0 = w.fsyncs();
            w.append_step(&rec)?;
            self.telemetry.wal_appends.inc();
            self.telemetry.wal_fsyncs.add(w.fsyncs() - f0);
        }
        // EVERY live replica gets the Apply — including stragglers whose
        // Proj was skipped — so all replicas stay bit-identical
        self.broadcast(&Msg::Apply { t, g }, true);
        if t % 10 == 0 || t + 1 == self.cfg.steps {
            self.summary.loss_curve.push((t, loss_sum / k));
        }
        // wall_s is frozen HERE: trace formatting/buffering happens after
        // the step it measures
        let wall_s = sw.secs();
        self.telemetry.steps.inc();
        if let Some(tracer) = self.tracer.as_mut() {
            tracer.record(StepTrace {
                step: t,
                seed: seed as i64,
                loss: loss_sum / k,
                loss_plus: lp_sum / k,
                loss_minus: lm_sum / k,
                proj_grad: g,
                cos_zm: f64::NAN,
                eta: hy.eta as f64,
                wall_s,
            })?;
        }
        self.t += 1;
        Ok(())
    }

    /// Divergence tripwire at the current step boundary: every live
    /// replica reports its parameter hash; any disagreement is fatal
    /// (bit-identity is the protocol's core invariant — training through a
    /// divergence would silently corrupt the run).
    fn hash_round(&mut self) -> Result<()> {
        let t = self.t;
        self.broadcast(&Msg::HashCheck { t }, false);
        let hashes = self.collect(t, self.cfg.proj_timeout, false, "HashReport", |wid, m| match *m {
            Msg::HashReport { t: ht, worker_id, hash } if ht == t && worker_id == wid => Some(hash),
            _ => None,
        });
        if let Some((&h0, rest)) = hashes.split_first() {
            if rest.iter().any(|&h| h != h0) {
                self.sync_wal();
                bail!("divergence tripwire at step {t}: replica parameter hashes disagree: {hashes:x?}");
            }
            self.consensus = Some((t, h0));
            // persist the agreement so a restarted leader can hand the
            // consensus hash to rejoining workers in `Welcome`
            if let Some(w) = self.wal.as_mut() {
                let f0 = w.fsyncs();
                w.append_consensus(t, h0)?;
                self.telemetry.wal_appends.inc();
                self.telemetry.wal_fsyncs.add(w.fsyncs() - f0);
            }
            crate::debug!("leader", "tripwire at step {t}: {} replicas agree on {h0:016x}", hashes.len());
        }
        Ok(())
    }

    fn eval_round(&mut self) {
        // tag eval frames with the last APPLIED step so a late EvalResult
        // reads as stale (not a protocol violation) at the next collect
        let te = self.t - 1;
        self.broadcast(&Msg::Eval { t: te }, false);
        let results = self.collect(te, self.cfg.eval_timeout, false, "EvalResult", |wid, m| match *m {
            Msg::EvalResult { t: mt, worker_id, correct, total } if mt == te && worker_id == wid => {
                Some((correct, total))
            }
            _ => None,
        });
        let (mut c, mut tot) = (0u64, 0u64);
        for (wc, wt) in results {
            c += wc;
            tot += wt;
        }
        if tot > 0 {
            self.summary.eval_curve.push((te + 1, c as f64 / tot as f64));
        }
    }

    /// Drain each live worker's connection until `want` matches, the
    /// timeout window closes, or the connection proves dead. Heartbeats
    /// refresh the window; out-of-phase messages (a straggler's late
    /// `Proj`, a slow `EvalResult`) are skipped as control traffic.
    fn collect<R>(
        &mut self,
        t: u64,
        timeout: Option<Duration>,
        wire: bool,
        what: &str,
        mut want: impl FnMut(u32, &Msg) -> Option<R>,
    ) -> Vec<R> {
        let mut out = Vec::new();
        for i in 0..self.slots.len() {
            let wid = i as u32;
            let mut control = 0u64;
            let polled = {
                let conn = match self.slots[i].conn.as_deref_mut() {
                    Some(c) => c,
                    None => continue,
                };
                loop {
                    let res = match timeout {
                        Some(d) => conn.recv_timeout(d),
                        None => conn.recv().map(Some),
                    };
                    match res {
                        Err(e) => break Polled::Dead(e.to_string()),
                        Ok(None) => break Polled::Timeout,
                        Ok(Some(msg)) => {
                            let bytes = msg.wire_bytes() as u64;
                            if matches!(msg, Msg::Heartbeat { .. }) {
                                control += bytes;
                                continue; // alive; restart the window
                            }
                            match want(wid, &msg) {
                                Some(r) => break Polled::Got(r, bytes),
                                None if out_of_phase(t, &msg) => {
                                    control += bytes;
                                    continue;
                                }
                                None => break Polled::Dead(format!("protocol violation: expected {what}, got {msg:?}")),
                            }
                        }
                    }
                }
            };
            self.acct(false, control);
            match polled {
                Polled::Got(r, bytes) => {
                    self.acct(wire, bytes);
                    self.slots[i].strikes = 0;
                    out.push(r);
                }
                Polled::Timeout => {
                    self.summary.straggler_events += 1;
                    self.telemetry.timeouts.inc();
                    self.telemetry.strikes.inc();
                    self.slots[i].strikes += 1;
                    let s = self.slots[i].strikes;
                    if s >= self.cfg.max_strikes {
                        self.drop_worker(i, &format!("unresponsive: {s} consecutive {what} timeouts"));
                    } else {
                        self.telemetry.skips.inc();
                        crate::warn_!("leader", "worker {wid} straggled on {what} at step {t} (strike {s}/{}); skipping it this round", self.cfg.max_strikes);
                    }
                }
                Polled::Dead(reason) => self.drop_worker(i, &reason),
            }
        }
        out
    }

    fn drop_worker(&mut self, i: usize, reason: &str) {
        if self.slots[i].conn.take().is_some() {
            self.summary.workers_lost += 1;
            if TransportErrorKind::classify_str(reason) == Some(TransportErrorKind::FaultInjected) {
                self.telemetry.faults_injected.inc();
            }
            crate::warn_!("leader", "dropping worker {i} at step {}: {reason} ({} live workers remain)", self.t, self.live());
        }
    }

    fn broadcast(&mut self, msg: &Msg, wire: bool) {
        let bytes = msg.wire_bytes() as u64;
        for i in 0..self.slots.len() {
            let res = match self.slots[i].conn.as_deref_mut() {
                Some(c) => c.send(msg),
                None => continue,
            };
            match res {
                Ok(()) => self.acct(wire, bytes),
                Err(e) => self.drop_worker(i, &format!("send failed: {e}")),
            }
        }
    }

}

/// Worker->leader messages carry the step they answer; anything at or
/// before the leader's current collection step may legitimately arrive
/// late (straggler Proj, slow EvalResult) and is drained, not fatal.
fn out_of_phase(t: u64, msg: &Msg) -> bool {
    match *msg {
        Msg::Proj { t: mt, .. }
        | Msg::HashReport { t: mt, .. }
        | Msg::EvalResult { t: mt, .. }
        | Msg::Ready { t: mt, .. } => mt <= t,
        _ => false,
    }
}

/// Worker-side runtime options (checkpointing + fault-injection hook).
#[derive(Clone, Debug, Default)]
pub struct WorkerOpts {
    /// preset name stamped into saved checkpoints
    pub preset: String,
    /// save the replica snapshot here (every `ckpt_every` steps + shutdown)
    pub ckpt: Option<PathBuf>,
    /// checkpoint period in applied steps (0 = shutdown only)
    pub ckpt_every: u64,
    /// fault injection: error out upon receiving `Step{t}` — simulates a
    /// worker crash mid-step for the cluster smoke script and tests
    pub die_at_step: Option<u64>,
}

/// Worker side of the v2 protocol: handshake (+ seed-replay catch-up when
/// behind the leader), then serve Step/Apply/Eval/HashCheck until
/// Shutdown. The `worker` keeps its state across calls, so a reconnect
/// loop can re-invoke this with the same replica after an error and only
/// the missed steps get replayed.
pub fn run_worker_with(conn: &mut dyn Transport, worker: &mut ZoWorker, opts: &WorkerOpts) -> Result<()> {
    conn.send(&Msg::Hello { proto: PROTO_VERSION, worker_id: worker.id, t: worker.t })?;
    let (leader_t, expect_hash) = match conn.recv()? {
        Msg::Welcome { proto, t, params_hash, .. } => {
            if proto != PROTO_VERSION {
                bail!("protocol version mismatch: leader speaks v{proto}, this worker speaks v{PROTO_VERSION}");
            }
            (t, params_hash)
        }
        other => bail!("expected Welcome, got {other:?}"),
    };
    if worker.t < leader_t {
        crate::info!("worker", "replica {} catching up from step {} to {} via seed replay", worker.id, worker.t, leader_t);
    }
    while worker.t < leader_t {
        match conn.recv()? {
            Msg::Replay { from_t, records } => worker.replay(from_t, &records)?,
            other => bail!("expected Replay records to reach step {leader_t}, got {other:?}"),
        }
    }
    let h = worker.params_hash();
    if expect_hash != 0 && h != expect_hash {
        bail!("rejoin diverged: local params hash {h:016x} != cluster consensus {expect_hash:016x}");
    }
    conn.send(&Msg::Ready { t: worker.t, worker_id: worker.id, params_hash: h })?;
    let mut pending: Option<(u64, f32, f32)> = None; // (t, eta, beta)
    loop {
        match conn.recv()? {
            Msg::Step { t, seed, theta, beta, eta, lam } => {
                if t != worker.t {
                    bail!("Step t={t} but this replica is at step {} (protocol desync)", worker.t);
                }
                if opts.die_at_step == Some(t) {
                    return Err(TransportErrorKind::FaultInjected
                        .err(format!("worker {} dying at step {t}", worker.id)));
                }
                let (lp, lm) = worker.compute_proj(t, seed, theta, lam)?;
                conn.send(&Msg::Proj { t, worker_id: worker.id, loss_plus: lp, loss_minus: lm })?;
                pending = Some((t, eta, beta));
            }
            Msg::Apply { t, g } => {
                match pending.take() {
                    Some((pt, eta, beta)) if pt == t => worker.apply(g, eta, beta),
                    _ => bail!("Apply{{t={t}}} without matching Step"),
                }
                if opts.ckpt_every > 0 && worker.t % opts.ckpt_every == 0 {
                    save_ckpt(worker, opts);
                }
            }
            Msg::Eval { t } => {
                // liveness signal first: the local eval may outlast the
                // leader's timeout window
                conn.send(&Msg::Heartbeat { t })?;
                let (c, tot) = worker.eval();
                conn.send(&Msg::EvalResult { t, worker_id: worker.id, correct: c, total: tot })?;
            }
            Msg::HashCheck { t } => {
                conn.send(&Msg::HashReport { t, worker_id: worker.id, hash: worker.params_hash() })?;
            }
            Msg::Heartbeat { t } => {
                // leader-side RTT probe: echo it straight back
                conn.send(&Msg::Heartbeat { t })?;
            }
            Msg::Shutdown => {
                save_ckpt(worker, opts);
                return Ok(());
            }
            other => bail!("unexpected message {other:?}"),
        }
    }
}

fn save_ckpt(worker: &ZoWorker, opts: &WorkerOpts) {
    if let Some(path) = &opts.ckpt {
        if let Err(e) = worker.to_checkpoint(&opts.preset).save(path) {
            crate::warn_!("worker", "failed to save checkpoint to {}: {e}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::channel_pair;
    use crate::objective::NativeQuadratic;

    const D: usize = 64;
    const HYP: DistHypers = DistHypers { theta: 1.2, eta: 1e-3, lam: 1e-2 };

    fn cfg(n: u32, steps: u64) -> LeaderConfig {
        LeaderConfig::new(n, 42, steps, HYP, BetaSchedule::Constant(0.9))
    }

    fn fake_hello(conn: &mut dyn Transport, proto: u8, wid: u32, t: u64) {
        conn.send(&Msg::Hello { proto, worker_id: wid, t }).unwrap();
    }

    // admission validation runs without threads: pre-queue the worker side
    // of the handshake on a channel transport, then drive admit()

    #[test]
    fn admit_validates_protocol_version() {
        let (mut w, l) = channel_pair();
        fake_hello(&mut w, 1, 0, 0); // stale protocol
        let err = Leader::new(cfg(2, 10)).admit(Box::new(l)).unwrap_err().to_string();
        assert!(err.contains("protocol version mismatch"), "{err}");
    }

    #[test]
    fn admit_rejects_out_of_range_id() {
        let (mut w, l) = channel_pair();
        fake_hello(&mut w, PROTO_VERSION, 5, 0);
        let err = Leader::new(cfg(2, 10)).admit(Box::new(l)).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn admit_rejects_duplicate_worker_id() {
        // the registration bugfix regression: the old run_leader discarded
        // Hello{worker_id} entirely, silently accepting two workers on one
        // shard — now the second one bails with a clear message
        let mut leader = Leader::new(cfg(2, 10));
        let (mut w0, l0) = channel_pair();
        fake_hello(&mut w0, PROTO_VERSION, 0, 0);
        w0.send(&Msg::Ready { t: 0, worker_id: 0, params_hash: 7 }).unwrap();
        leader.admit(Box::new(l0)).unwrap();
        // same id again on a fresh connection
        let (mut w1, l1) = channel_pair();
        fake_hello(&mut w1, PROTO_VERSION, 0, 0);
        let err = leader.admit(Box::new(l1)).unwrap_err().to_string();
        assert!(err.contains("duplicate worker id 0"), "{err}");
    }

    #[test]
    fn admit_rejects_step_claim_ahead_of_leader() {
        let (mut w, l) = channel_pair();
        fake_hello(&mut w, PROTO_VERSION, 0, 99); // leader is at step 0
        let err = Leader::new(cfg(2, 10)).admit(Box::new(l)).unwrap_err().to_string();
        assert!(err.contains("claims step 99"), "{err}");
    }

    #[test]
    fn worker_rejects_version_mismatch() {
        let (mut lside, mut wside) = channel_pair();
        lside.send(&Msg::Welcome { proto: 1, n_workers: 1, run_seed: 0, t: 0, params_hash: 0 }).unwrap();
        let mut w = ZoWorker::new(0, vec![0.0; D], Box::new(NativeQuadratic::new(D)));
        let err = run_worker_with(&mut wside, &mut w, &WorkerOpts::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("protocol version mismatch"), "{err}");
    }

    #[test]
    fn lockstep_leader_over_channels_matches_local_cluster() {
        // the wire-accounting bugfix regression: the old run_leader counted
        // a received Proj as 29 B (the frame is 33 B), so leader and
        // LocalCluster disagreed on the headline metric. Now both count
        // Step/Proj/Apply via wire_bytes() and must agree exactly — and the
        // replicas must be bit-identical across the two paths.
        use super::super::distributed::{run_leader, run_worker, LocalCluster};

        let n = 3u32;
        let steps = 25u64;
        let mut x0 = vec![0f32; D];
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(8);
        rng.fill_normal_f32(&mut x0);

        let mut conns: Vec<Box<dyn Transport>> = Vec::new();
        let mut handles = Vec::new();
        for id in 0..n {
            let (wside, lside) = channel_pair();
            conns.push(Box::new(lside));
            let x = x0.clone();
            handles.push(std::thread::spawn(move || {
                let mut wside = wside;
                let mut w = ZoWorker::new(id, x, Box::new(NativeQuadratic::new(D)));
                run_worker(&mut wside, &mut w).unwrap();
                (w.x, w.m)
            }));
        }
        let summary = run_leader(conns, 42, steps, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();
        let states: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

        let workers = (0..n)
            .map(|id| ZoWorker::new(id, x0.clone(), Box::new(NativeQuadratic::new(D))))
            .collect();
        let mut local = LocalCluster::new(workers, 42);
        let local_summary = local.run(steps, HYP, &BetaSchedule::Constant(0.9), 0).unwrap();

        assert_eq!(
            summary.wire_bytes, local_summary.wire_bytes,
            "leader and LocalCluster wire accounting diverged"
        );
        for (id, (x, m)) in states.iter().enumerate() {
            assert_eq!(x, &local.workers[id].x, "worker {id} params diverged from LocalCluster");
            assert_eq!(m, &local.workers[id].m, "worker {id} momentum diverged");
        }
        assert_eq!(summary.workers_lost, 0);
        assert_eq!(summary.straggler_events, 0);
    }

    #[test]
    fn heartbeat_rtt_and_leader_trace_over_channels() {
        // PR-6 shipped the Heartbeat frame; this pins the PR-7 wiring: the
        // leader pings every live worker each `metrics_every` boundary, the
        // worker echoes, and the RTT lands in the leader's registry —
        // WITHOUT perturbing the wire-bytes parity (heartbeats are control
        // traffic) or the replicas' bit-identical trajectories.
        let n = 2u32;
        let steps = 12u64;
        let mut x0 = vec![0f32; D];
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(9);
        rng.fill_normal_f32(&mut x0);

        let run = |metrics_every: u64, trace: Option<std::path::PathBuf>| {
            let mut conns: Vec<Box<dyn Transport>> = Vec::new();
            let mut handles = Vec::new();
            for id in 0..n {
                let (wside, lside) = channel_pair();
                conns.push(Box::new(lside));
                let x = x0.clone();
                handles.push(std::thread::spawn(move || {
                    let mut wside = wside;
                    let mut w = ZoWorker::new(id, x, Box::new(NativeQuadratic::new(D)));
                    run_worker_with(&mut wside, &mut w, &WorkerOpts::default()).unwrap();
                    w.x
                }));
            }
            let mut c = cfg(n, steps);
            c.metrics_every = metrics_every;
            c.trace = trace;
            let leader = Leader::new(c);
            let reg = leader.telemetry();
            let summary = leader.run(conns).unwrap();
            let states: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            (summary, reg, states)
        };

        let dir = std::env::temp_dir().join(format!("conmezo_hb_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let trace_path = dir.join("leader_trace.jsonl");
        let (s_on, reg_on, x_on) = run(3, Some(trace_path.clone()));
        let (s_off, reg_off, x_off) = run(0, None);

        // every metrics boundary pinged every worker, every echo came back
        let rounds = steps.div_ceil(3); // t = 0, 3, 6, 9
        assert_eq!(reg_on.rtt.count(), rounds * n as u64, "missing heartbeat echoes");
        assert_eq!(reg_on.timeouts.get(), 0);
        assert_eq!(s_on.workers_lost, 0, "heartbeats must not kill workers");
        assert_eq!(reg_off.rtt.count(), 0);

        // heartbeats are control traffic: the O(1)/step wire claim is intact
        assert_eq!(s_on.wire_bytes, s_off.wire_bytes, "heartbeats leaked into wire accounting");
        assert_eq!(reg_on.wire_bytes.get(), s_on.wire_bytes, "registry mirror diverged");
        assert!(s_on.control_bytes > s_off.control_bytes);

        // and the replicas never noticed
        assert_eq!(x_on, x_off, "heartbeat rounds perturbed training");

        // leader trace: one parseable record per step, matching the run
        let trace = crate::telemetry::read_trace(&trace_path).unwrap();
        assert_eq!(trace.len(), steps as usize);
        for (t, rec) in trace.iter().enumerate() {
            assert_eq!(rec.step, t as u64);
            assert_eq!(rec.seed, step_seed(42, t as u64) as i64);
            assert!(rec.loss.is_finite() && rec.proj_grad.is_finite());
            assert!(rec.wall_s >= 0.0);
            assert!(rec.cos_zm.is_nan(), "leader has no momentum buffer to compare against");
        }
        assert_eq!(reg_on.steps.get(), steps);
        std::fs::remove_file(&trace_path).ok();
    }

    #[test]
    fn leader_resume_requires_step_log() {
        let err = Leader::resume(cfg(1, 1), None).unwrap_err().to_string();
        assert!(err.contains("requires a step-log path"), "{err}");
    }

    #[test]
    fn leader_resume_from_wal_is_bit_identical() {
        // the leader-restart acceptance criterion, in-process: kill the
        // leader after 8 steps (here: just let phase 1 finish), resume from
        // the WAL alone, run to 16 — the trajectory must be bit-identical
        // to one uninterrupted 16-step run
        use crate::checkpoint::load_wal;

        let n = 2u32;
        let dir = std::env::temp_dir().join(format!("conmezo_resume_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let wal_path = dir.join("steps.cmzw");

        let mut x0 = vec![0f32; D];
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(11);
        rng.fill_normal_f32(&mut x0);

        type Spawned = (Vec<Box<dyn Transport>>, Vec<std::thread::JoinHandle<Vec<f32>>>);
        let spawn_workers = |x0: &[f32]| -> Spawned {
            let mut conns: Vec<Box<dyn Transport>> = Vec::new();
            let mut handles = Vec::new();
            for id in 0..n {
                let (wside, lside) = channel_pair();
                conns.push(Box::new(lside));
                let x = x0.to_vec();
                handles.push(std::thread::spawn(move || {
                    let mut wside = wside;
                    let mut w = ZoWorker::new(id, x, Box::new(NativeQuadratic::new(D)));
                    run_worker_with(&mut wside, &mut w, &WorkerOpts::default()).unwrap();
                    w.x
                }));
            }
            (conns, handles)
        };

        // phase 1: 8 steps against the WAL, with a tripwire round at t=4
        let mut c1 = cfg(n, 8);
        c1.step_log = Some(wal_path.clone());
        c1.hash_check_every = 4;
        let (conns, handles) = spawn_workers(&x0);
        Leader::new(c1).run(conns).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        let rec = load_wal(&wal_path).unwrap();
        assert_eq!(rec.log.records.len(), 8);
        assert!(rec.consensus.is_some(), "tripwire consensus must be persisted");
        assert!(!rec.truncated());

        // phase 2: resume from the WAL alone; FRESH workers replay 0..8
        // through the ordinary rejoin path, then train 8..16
        let mut c2 = cfg(n, 16);
        c2.step_log = Some(wal_path.clone());
        c2.hash_check_every = 4;
        let leader = Leader::resume(c2, None).unwrap();
        assert_eq!(leader.t(), 8, "step count must come back from the WAL");
        let (conns, handles) = spawn_workers(&x0);
        let summary = leader.run(conns).unwrap();
        let resumed: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(summary.rejoins, n as u64, "post-restart admissions count as rejoins");

        // baseline: one uninterrupted 16-step run, no persistence
        let mut c3 = cfg(n, 16);
        c3.hash_check_every = 4;
        let (conns, handles) = spawn_workers(&x0);
        Leader::new(c3).run(conns).unwrap();
        let baseline: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

        assert_eq!(resumed, baseline, "leader restart must be invisible to the trajectory");

        let rec = load_wal(&wal_path).unwrap();
        assert_eq!(rec.log.records.len(), 16, "the resumed leader appends to the same WAL");
        std::fs::remove_file(&wal_path).ok();
    }
}
