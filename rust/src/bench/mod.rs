//! Criterion-style benchmark harness (criterion is not vendored offline).
//!
//! `Bencher` warms up, runs timed samples until both a minimum sample count
//! and a minimum wall-clock budget are met, and reports mean/σ/p50/p99 plus
//! optional throughput. All `cargo bench` targets in `rust/benches/` are
//! `harness = false` binaries built on this module; results are also
//! appended as JSON lines under `results/bench/` for EXPERIMENTS.md §Perf.

use std::time::Instant;

use crate::util::{mean_std, percentile};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    /// elements (or items) processed per iteration, for throughput lines
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.mean_s)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} time: [{:>10} ± {:>9}]  p50 {:>10}  p99 {:>10}  ({} samples)",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.p50_s),
            fmt_time(self.p99_s),
            self.samples
        );
        if let Some(t) = self.throughput() {
            s.push_str(&format!("  thrpt: {}/s", fmt_count(t)));
        }
        s
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("samples", Json::num(self.samples as f64)),
            ("mean_s", Json::num(self.mean_s)),
            ("std_s", Json::num(self.std_s)),
            ("p50_s", Json::num(self.p50_s)),
            ("p99_s", Json::num(self.p99_s)),
            (
                "items_per_iter",
                self.items_per_iter.map(Json::num).unwrap_or(Json::Null),
            ),
        ])
    }
}

pub fn fmt_time(s: f64) -> String {
    if !s.is_finite() {
        "n/a".into()
    } else if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

fn fmt_count(c: f64) -> String {
    if c >= 1e9 {
        format!("{:.2}G", c / 1e9)
    } else if c >= 1e6 {
        format!("{:.2}M", c / 1e6)
    } else if c >= 1e3 {
        format!("{:.2}K", c / 1e3)
    } else {
        format!("{c:.1}")
    }
}

pub struct Bencher {
    pub warmup_iters: usize,
    pub min_samples: usize,
    pub max_samples: usize,
    pub min_seconds: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, min_samples: 10, max_samples: 200, min_seconds: 1.0 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup_iters: 1, min_samples: 5, max_samples: 30, min_seconds: 0.2 }
    }

    /// Benchmark `f`, timing each call as one sample.
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> BenchResult {
        self.run_items(name, None, &mut f)
    }

    /// Benchmark with a throughput denominator (items processed per call).
    pub fn run_items(&self, name: &str, items: Option<f64>, f: &mut dyn FnMut()) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_samples
            || (start.elapsed().as_secs_f64() < self.min_seconds && samples.len() < self.max_samples)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let (mean, std) = mean_std(&samples);
        BenchResult {
            name: name.to_string(),
            samples: samples.len(),
            mean_s: mean,
            std_s: std,
            p50_s: percentile(&samples, 50.0),
            p99_s: percentile(&samples, 99.0),
            items_per_iter: items,
        }
    }
}

/// Append results as JSON lines to results/bench/<file>.jsonl.
pub fn write_results(file: &str, results: &[BenchResult]) -> crate::util::error::Result<()> {
    let dir = std::path::Path::new("results/bench");
    std::fs::create_dir_all(dir)?;
    let mut text = String::new();
    for r in results {
        text.push_str(&r.to_json().to_string());
        text.push('\n');
    }
    std::fs::write(dir.join(file), text)?;
    Ok(())
}

/// Locate the repository root (the directory holding ROADMAP.md / .git):
/// cargo runs bench binaries from the package dir (`rust/`), so this is
/// usually the parent; falls back to the current directory.
pub fn repo_root() -> std::path::PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
    let mut dir = cwd.clone();
    for _ in 0..3 {
        if dir.join("ROADMAP.md").exists() || dir.join(".git").exists() {
            return dir;
        }
        match dir.parent() {
            Some(p) => dir = p.to_path_buf(),
            None => break,
        }
    }
    cwd
}

/// Merge one named section of results into `BENCH_native.json` at the repo
/// root — the machine-readable perf record (each bench bin owns a section,
/// so step_latency and optimizer_math can update independently without
/// clobbering each other).
pub fn write_bench_json(section: &str, results: &[BenchResult]) -> crate::util::error::Result<()> {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    let path = repo_root().join("BENCH_native.json");
    let mut root: BTreeMap<String, Json> = match std::fs::read_to_string(&path) {
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Obj(m)) => m,
            _ => BTreeMap::new(),
        },
        Err(_) => BTreeMap::new(),
    };
    root.insert(
        section.to_string(),
        Json::Arr(results.iter().map(|r| r.to_json()).collect()),
    );
    root.insert("schema".into(), Json::str("conmezo-bench-v1"));
    // read-modify-write over a shared file: the replace must be atomic so
    // a crashed bench bin can't tear every other section's results
    crate::util::fs::atomic_write(&path, Json::Obj(root).to_string().as_bytes())?;
    Ok(())
}

/// Shared bench-bin CLI: `--quick` runs a few iterations of everything (the
/// CI smoke mode that keeps BENCH_native.json generation from rotting);
/// remaining bare args pass through (e.g. preset names).
pub struct BenchArgs {
    pub quick: bool,
    pub rest: Vec<String>,
}

impl BenchArgs {
    pub fn parse() -> BenchArgs {
        let mut quick = false;
        let mut rest = Vec::new();
        // cargo bench passes harness flags like --bench; ignore any other
        // dashed flag except our own
        for a in std::env::args().skip(1) {
            if a == "--quick" {
                quick = true;
            } else if !a.starts_with('-') {
                rest.push(a);
            }
        }
        BenchArgs { quick, rest }
    }

    /// A Bencher budgeted for this mode.
    pub fn bencher(&self) -> Bencher {
        if self.quick {
            Bencher { warmup_iters: 1, min_samples: 2, max_samples: 3, min_seconds: 0.0 }
        } else {
            Bencher::default()
        }
    }
}

/// Prevent the optimizer from eliding a computed value (black_box stand-in).
#[inline]
pub fn consume<T>(x: T) -> T {
    unsafe { std::ptr::read_volatile(&x as *const T) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_stats() {
        let b = Bencher { warmup_iters: 1, min_samples: 8, max_samples: 16, min_seconds: 0.0 };
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i);
            }
        });
        assert!(r.samples >= 8);
        assert!(r.mean_s > 0.0);
        assert!(r.p50_s > 0.0 && r.p99_s >= r.p50_s);
        let _ = consume(acc);
    }

    #[test]
    fn throughput_computed() {
        let b = Bencher { warmup_iters: 0, min_samples: 3, max_samples: 3, min_seconds: 0.0 };
        let r = b.run_items("t", Some(1000.0), &mut || {
            std::thread::sleep(std::time::Duration::from_micros(100))
        });
        let t = r.throughput().unwrap();
        assert!(t > 1e5 && t < 1e8, "{t}");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(2.5e-3), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 µs");
        assert!(fmt_time(5e-9).ends_with("ns"));
    }

    #[test]
    fn report_contains_name_and_time() {
        let r = BenchResult {
            name: "x".into(),
            samples: 5,
            mean_s: 1e-3,
            std_s: 1e-5,
            p50_s: 1e-3,
            p99_s: 1.2e-3,
            items_per_iter: Some(100.0),
        };
        let rep = r.report();
        assert!(rep.contains('x') && rep.contains("ms") && rep.contains("thrpt"));
    }
}
