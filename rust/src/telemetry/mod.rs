//! Zero-overhead telemetry: hot-path metrics, step traces, phase spans.
//!
//! Every primitive here is a plain struct over `std::sync::atomic` — no
//! dependencies, no locks on the recording path (the span ring takes an
//! uncontended `Mutex` only on the single-threaded session driver), and
//! **no steady-state allocation**: the [`Registry`] and its histograms,
//! per-participant slots, and span ring are fully preallocated when the
//! backend is constructed, so instrumented `run()`/`two_point()` calls
//! stay allocation-free (pinned by the pointer-stability tests in
//! `runtime::native`). The measured cost of leaving telemetry on is
//! pinned <1% by the `telemetry` section of `BENCH_native.json`
//! (`benches/step_latency.rs`, asserted in CI bench-smoke).
//!
//! Four layers of the stack report into one registry per `Runtime`:
//!
//! 1. **kernels/pool** (`parallel`, `vecmath`, `runtime::model`) —
//!    per-dispatch queue-wait vs compute time per participant, a
//!    worker-imbalance gauge, and GEMM / attention span histograms;
//! 2. **session** (`runtime::native`) — `run`/`two_point` latency split
//!    into forward / backward / fused-step phases (also recorded as
//!    [`Span`]s in the ring for timeline reconstruction);
//! 3. **trainer** (`coordinator::trainer`) — a per-step [`StepTrace`]
//!    record streamed to an optional `--trace out.jsonl` file through a
//!    buffered writer flushed *outside* the timed region;
//! 4. **cluster** (`coordinator::cluster`) — leader-side per-worker RTT
//!    (over the protocol's `Heartbeat` frame), timeout/strike/skip
//!    counters, and replay/wire/control byte counters, surfaced by the
//!    leader's periodic `--metrics-every N` health line.
//!
//! `conmezo trace-summary <file>` renders percentiles of a recorded
//! trace via `coordinator::metrics::render_table`.
//!
//! All counters use `Ordering::Relaxed`: telemetry reads are statistical,
//! never synchronizing.

use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::error::{anyhow, Result};
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// scalar primitives
// ---------------------------------------------------------------------------

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-value gauge (an `f64` stored as its bit pattern).
#[derive(Debug)]
pub struct Gauge(AtomicU64);

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0x7ff8_0000_0000_0000)) // NaN: "never set"
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

// ---------------------------------------------------------------------------
// histogram
// ---------------------------------------------------------------------------

/// Fixed-bucket latency histogram in nanoseconds.
///
/// Bucket upper bounds are fixed at construction (no allocation on
/// `record_ns`); values above the last bound land in an overflow bucket.
/// Percentiles are bucket-upper-bound estimates — coarse by design, cheap
/// enough to read from a health loop.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<u64>,
    counts: Vec<AtomicU64>, // bounds.len() + 1 (overflow)
    sum_ns: AtomicU64,
    n: AtomicU64,
}

impl Histogram {
    /// Exponential buckets: `first, first*factor, first*factor^2, ...`.
    pub fn exponential_ns(first: u64, factor: u64, buckets: usize) -> Histogram {
        let mut bounds = Vec::with_capacity(buckets);
        let mut b = first.max(1);
        for _ in 0..buckets {
            bounds.push(b);
            b = b.saturating_mul(factor.max(2));
        }
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds, counts, sum_ns: AtomicU64::new(0), n: AtomicU64::new(0) }
    }

    /// The default latency layout: 1 µs .. ~2 s in powers of two.
    pub fn default_ns() -> Histogram {
        Histogram::exponential_ns(1_000, 2, 22)
    }

    #[inline]
    pub fn record_ns(&self, ns: u64) {
        // first bucket whose bound is >= ns (linear scan: ~22 u64 compares)
        let mut i = self.bounds.len();
        for (k, &b) in self.bounds.iter().enumerate() {
            if ns <= b {
                i = k;
                break;
            }
        }
        self.counts[i].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn observe(&self, d: Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            f64::NAN
        } else {
            self.sum_ns() as f64 / n as f64
        }
    }

    /// Nearest-rank percentile estimate (upper bound of the bucket holding
    /// the rank); `p` in [0, 100]. Returns 0 when empty.
    pub fn percentile_ns(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= rank {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    // overflow bucket: no upper bound; report the mean of
                    // what actually landed there is unknowable, use 2x last
                    self.bounds.last().copied().unwrap_or(u64::MAX).saturating_mul(2)
                };
            }
        }
        self.bounds.last().copied().unwrap_or(0)
    }

    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.sum_ns.store(0, Ordering::Relaxed);
        self.n.store(0, Ordering::Relaxed);
    }

    /// (upper_bound_ns, count) per bucket; the overflow bucket reports
    /// `u64::MAX` as its bound.
    pub fn buckets(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.counts.iter().enumerate().map(move |(i, c)| {
            let bound = self.bounds.get(i).copied().unwrap_or(u64::MAX);
            (bound, c.load(Ordering::Relaxed))
        })
    }
}

// ---------------------------------------------------------------------------
// span ring
// ---------------------------------------------------------------------------

/// One timed phase: label + offset from the registry epoch + duration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    pub label: &'static str,
    pub start_ns: u64,
    pub dur_ns: u64,
}

/// Preallocated ring buffer of [`Span`]s, drop-oldest on wrap.
///
/// The backing `Vec` is allocated once at construction and never regrows
/// (pinned by `ring_buffer_wraps_without_reallocating`); `push` is a
/// short uncontended mutex hold on the session driver thread.
#[derive(Debug)]
pub struct SpanRing {
    inner: Mutex<RingInner>,
    cap: usize,
}

#[derive(Debug)]
struct RingInner {
    buf: Vec<Span>,
    /// next write index once the buffer is full (oldest element)
    next: usize,
}

impl SpanRing {
    pub fn new(cap: usize) -> SpanRing {
        let cap = cap.max(1);
        SpanRing { inner: Mutex::new(RingInner { buf: Vec::with_capacity(cap), next: 0 }), cap }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().map(|r| r.buf.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn push(&self, s: Span) {
        if let Ok(mut r) = self.inner.lock() {
            if r.buf.len() < self.cap {
                r.buf.push(s);
            } else {
                let i = r.next;
                r.buf[i] = s;
                r.next = (i + 1) % self.cap;
            }
        }
    }

    /// Copy the ring contents, oldest first, into `out` (cleared first).
    pub fn snapshot(&self, out: &mut Vec<Span>) {
        out.clear();
        if let Ok(r) = self.inner.lock() {
            if r.buf.len() == self.cap {
                out.extend_from_slice(&r.buf[r.next..]);
                out.extend_from_slice(&r.buf[..r.next]);
            } else {
                out.extend_from_slice(&r.buf);
            }
        }
    }

    /// Address of the backing buffer — lets tests pin that wraparound never
    /// reallocates.
    pub fn buf_ptr(&self) -> *const Span {
        self.inner.lock().map(|r| r.buf.as_ptr()).unwrap_or(std::ptr::null())
    }
}

// ---------------------------------------------------------------------------
// registry + scoped timers
// ---------------------------------------------------------------------------

/// All instruments for one `Runtime` (shared `Arc` across the backend, its
/// `WorkerPool`, every bound session, and the trainer/cluster driving it).
///
/// Construction preallocates everything; recording is atomics only. The
/// `enabled` flag gates every record site so the measured-overhead bench
/// can toggle instrumentation without rebinding.
#[derive(Debug)]
pub struct Registry {
    enabled: AtomicBool,
    epoch: Instant,

    // -- pool (parallel::WorkerPool) --
    pub pool_dispatches: Counter,
    pub pool_queue_wait: Histogram,
    pub pool_compute: Histogram,
    /// max/mean busy-time ratio across participants of the last dispatch
    /// (1.0 = perfectly balanced)
    pub pool_imbalance: Gauge,
    /// cumulative busy nanoseconds per pool participant
    pub pool_busy_ns: Vec<AtomicU64>,
    /// busy nanoseconds per participant for the most recent dispatch
    pub pool_last_busy_ns: Vec<AtomicU64>,
    pub gemm: Histogram,
    pub attention: Histogram,

    // -- session (runtime::native) --
    pub run_latency: Histogram,
    pub forward: Histogram,
    pub backward: Histogram,
    pub fused_step: Histogram,

    // -- trainer --
    pub steps: Counter,

    // -- cluster (leader side) --
    pub rtt: Histogram,
    pub timeouts: Counter,
    pub strikes: Counter,
    pub skips: Counter,
    pub replay_bytes: Counter,
    pub wire_bytes: Counter,
    pub control_bytes: Counter,

    // -- durability (WAL + reconnect/chaos accounting) --
    /// cells appended to the leader's write-ahead step log
    pub wal_appends: Counter,
    /// fsyncs issued by the WAL writer (policy-dependent)
    pub wal_fsyncs: Counter,
    /// torn-tail truncations performed when recovering a WAL
    pub wal_truncations: Counter,
    /// worker (re)connections admitted after step 0 (leader view)
    pub reconnects: Counter,
    /// errors classified as injected faults (chaos/test harness traffic)
    pub faults_injected: Counter,

    pub spans: SpanRing,
}

impl Registry {
    /// `participants` sizes the per-participant pool slots (the pool's
    /// thread budget); the span ring defaults to 1024 entries.
    pub fn new(participants: usize) -> Registry {
        Registry::with_capacity(participants, 1024)
    }

    pub fn with_capacity(participants: usize, ring_cap: usize) -> Registry {
        let slots = |n: usize| (0..n.max(1)).map(|_| AtomicU64::new(0)).collect::<Vec<_>>();
        Registry {
            enabled: AtomicBool::new(true),
            epoch: Instant::now(),
            pool_dispatches: Counter::new(),
            pool_queue_wait: Histogram::default_ns(),
            pool_compute: Histogram::default_ns(),
            pool_imbalance: Gauge::new(),
            pool_busy_ns: slots(participants),
            pool_last_busy_ns: slots(participants),
            gemm: Histogram::default_ns(),
            attention: Histogram::default_ns(),
            run_latency: Histogram::default_ns(),
            forward: Histogram::default_ns(),
            backward: Histogram::default_ns(),
            fused_step: Histogram::default_ns(),
            steps: Counter::new(),
            rtt: Histogram::default_ns(),
            timeouts: Counter::new(),
            strikes: Counter::new(),
            skips: Counter::new(),
            replay_bytes: Counter::new(),
            wire_bytes: Counter::new(),
            control_bytes: Counter::new(),
            wal_appends: Counter::new(),
            wal_fsyncs: Counter::new(),
            wal_truncations: Counter::new(),
            reconnects: Counter::new(),
            faults_injected: Counter::new(),
            spans: SpanRing::new(ring_cap),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Nanoseconds since this registry was constructed (span timestamps).
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Scoped histogram timer; `None` when telemetry is disabled (the
    /// drop-guard records on scope exit, including early `?` returns).
    #[inline]
    pub fn timer<'a>(&self, hist: &'a Histogram) -> Option<HistTimer<'a>> {
        if !self.enabled() {
            return None;
        }
        Some(HistTimer { hist, start: Instant::now() })
    }

    /// Scoped span timer: records into the ring (and optionally a
    /// histogram) on drop.
    #[inline]
    pub fn span<'a>(&'a self, label: &'static str, hist: Option<&'a Histogram>) -> Option<SpanTimer<'a>> {
        if !self.enabled() {
            return None;
        }
        Some(SpanTimer { reg: self, hist, label, start: Instant::now(), start_ns: self.now_ns() })
    }
}

/// Drop-guard that records its lifetime into a histogram.
pub struct HistTimer<'a> {
    hist: &'a Histogram,
    start: Instant,
}

impl Drop for HistTimer<'_> {
    fn drop(&mut self) {
        self.hist.record_ns(self.start.elapsed().as_nanos() as u64);
    }
}

/// Drop-guard that records a [`Span`] (ring + optional histogram).
pub struct SpanTimer<'a> {
    reg: &'a Registry,
    hist: Option<&'a Histogram>,
    label: &'static str,
    start: Instant,
    start_ns: u64,
}

impl Drop for SpanTimer<'_> {
    fn drop(&mut self) {
        let dur_ns = self.start.elapsed().as_nanos() as u64;
        if let Some(h) = self.hist {
            h.record_ns(dur_ns);
        }
        self.reg.spans.push(Span { label: self.label, start_ns: self.start_ns, dur_ns });
    }
}

// ---------------------------------------------------------------------------
// step traces
// ---------------------------------------------------------------------------

/// One training step, as streamed to `--trace out.jsonl` (one JSON object
/// per line). Unavailable quantities are `NaN` in memory and `null` on the
/// wire (e.g. `cos_zm` for optimizers without a momentum buffer).
#[derive(Clone, Copy, Debug)]
pub struct StepTrace {
    pub step: u64,
    pub seed: i64,
    /// mean of the two perturbed losses (the reported train loss)
    pub loss: f64,
    pub loss_plus: f64,
    pub loss_minus: f64,
    /// projected gradient g = (f+ - f-) / (2 lambda)
    pub proj_grad: f64,
    /// cosine between the step direction z and the pre-step momentum
    pub cos_zm: f64,
    pub eta: f64,
    /// wall-clock seconds of the step itself (trace I/O excluded)
    pub wall_s: f64,
}

fn push_num(out: &mut String, v: f64) {
    use std::fmt::Write as _;
    if v.is_finite() {
        // Display is shortest-round-trip, so parse_line recovers the bits
        let _ = write!(out, "{v}");
    } else {
        out.push_str("null");
    }
}

impl StepTrace {
    /// Append this record to `out` as one JSONL line (with trailing `\n`).
    pub fn to_jsonl(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = write!(out, "{{\"step\":{},\"seed\":{},\"loss\":", self.step, self.seed);
        push_num(out, self.loss);
        out.push_str(",\"loss_plus\":");
        push_num(out, self.loss_plus);
        out.push_str(",\"loss_minus\":");
        push_num(out, self.loss_minus);
        out.push_str(",\"proj_grad\":");
        push_num(out, self.proj_grad);
        out.push_str(",\"cos_zm\":");
        push_num(out, self.cos_zm);
        out.push_str(",\"eta\":");
        push_num(out, self.eta);
        out.push_str(",\"wall_s\":");
        push_num(out, self.wall_s);
        out.push_str("}\n");
    }

    /// Parse one JSONL line back into a record (`null` -> `NaN`).
    pub fn parse_line(line: &str) -> Result<StepTrace> {
        let v = Json::parse(line.trim())?;
        let num = |k: &str| v.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
        Ok(StepTrace {
            step: v
                .expect("step")?
                .as_i64()
                .ok_or_else(|| anyhow!("step is not a number"))? as u64,
            seed: v
                .expect("seed")?
                .as_i64()
                .ok_or_else(|| anyhow!("seed is not a number"))?,
            loss: num("loss"),
            loss_plus: num("loss_plus"),
            loss_minus: num("loss_minus"),
            proj_grad: num("proj_grad"),
            cos_zm: num("cos_zm"),
            eta: num("eta"),
            wall_s: num("wall_s"),
        })
    }
}

/// Buffered JSONL writer for [`StepTrace`] records + in-memory history.
///
/// `record` formats into a reused line buffer and hands it to a
/// `BufWriter`; actual disk flushes happen in `flush()`, which callers
/// invoke *outside* the timed step region, so tracing does not perturb
/// the step latency it is measuring.
pub struct StepTracer {
    out: Option<std::io::BufWriter<std::fs::File>>,
    line: String,
    history: Vec<StepTrace>,
}

impl StepTracer {
    /// `path = None` keeps history in memory without writing a file.
    pub fn new(path: Option<&std::path::Path>) -> Result<StepTracer> {
        let out = match path {
            Some(p) => {
                if let Some(dir) = p.parent() {
                    if !dir.as_os_str().is_empty() {
                        std::fs::create_dir_all(dir)?;
                    }
                }
                Some(std::io::BufWriter::new(std::fs::File::create(p)?))
            }
            None => None,
        };
        Ok(StepTracer { out, line: String::with_capacity(256), history: Vec::new() })
    }

    pub fn record(&mut self, tr: StepTrace) -> Result<()> {
        self.history.push(tr);
        if let Some(w) = self.out.as_mut() {
            self.line.clear();
            tr.to_jsonl(&mut self.line);
            w.write_all(self.line.as_bytes())?;
        }
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(w) = self.out.as_mut() {
            w.flush()?;
        }
        Ok(())
    }

    pub fn history(&self) -> &[StepTrace] {
        &self.history
    }
}

impl Drop for StepTracer {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

/// Load every record of a `--trace` JSONL file (blank lines skipped).
pub fn read_trace(path: &std::path::Path) -> Result<Vec<StepTrace>> {
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(
            StepTrace::parse_line(line)
                .map_err(|e| anyhow!("{}:{}: {e}", path.display(), i + 1))?,
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
        c.reset();
        assert_eq!(c.get(), 0);

        let g = Gauge::new();
        assert!(g.get().is_nan(), "unset gauge reads NaN");
        g.set(1.25);
        assert_eq!(g.get(), 1.25);
    }

    #[test]
    fn histogram_bucket_boundaries() {
        // bounds: 10, 20, 40
        let h = Histogram::exponential_ns(10, 2, 3);
        h.record_ns(0); // -> bucket 0 (<= 10)
        h.record_ns(10); // boundary value lands in its own bucket, not the next
        h.record_ns(11); // -> bucket 1
        h.record_ns(20); // -> bucket 1
        h.record_ns(40); // -> bucket 2
        h.record_ns(41); // -> overflow
        let counts: Vec<u64> = h.buckets().map(|(_, c)| c).collect();
        assert_eq!(counts, vec![2, 2, 1, 1]);
        let bounds: Vec<u64> = h.buckets().map(|(b, _)| b).collect();
        assert_eq!(bounds, vec![10, 20, 40, u64::MAX]);
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum_ns(), 122);
        assert!((h.mean_ns() - 122.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentiles_are_bucket_bounds() {
        let h = Histogram::exponential_ns(10, 2, 4); // 10 20 40 80
        for _ in 0..50 {
            h.record_ns(5); // bucket 0
        }
        for _ in 0..49 {
            h.record_ns(35); // bucket 2
        }
        h.record_ns(1_000_000); // overflow
        assert_eq!(h.percentile_ns(50.0), 10);
        assert_eq!(h.percentile_ns(90.0), 40);
        // overflow bucket has no bound; estimate is 2x the last bound
        assert_eq!(h.percentile_ns(100.0), 160);
        let empty = Histogram::default_ns();
        assert_eq!(empty.percentile_ns(50.0), 0);
        assert!(empty.mean_ns().is_nan());
    }

    #[test]
    fn histogram_reset_clears_everything() {
        let h = Histogram::default_ns();
        h.observe(Duration::from_micros(7));
        assert_eq!(h.count(), 1);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_ns(), 0);
        assert!(h.buckets().all(|(_, c)| c == 0));
    }

    #[test]
    fn ring_buffer_wraps_without_reallocating() {
        let ring = SpanRing::new(4);
        assert!(ring.is_empty());
        let sp = |i: u64| Span { label: "t", start_ns: i, dur_ns: 1 };
        ring.push(sp(0));
        let p0 = ring.buf_ptr();
        for i in 1..11 {
            ring.push(sp(i));
        }
        // capacity preserved, oldest dropped, backing buffer never moved
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.buf_ptr(), p0, "ring reallocated on wrap");
        let mut out = Vec::new();
        ring.snapshot(&mut out);
        let starts: Vec<u64> = out.iter().map(|s| s.start_ns).collect();
        assert_eq!(starts, vec![7, 8, 9, 10]);
    }

    #[test]
    fn registry_timers_respect_enabled_flag() {
        let reg = Registry::with_capacity(2, 8);
        {
            let _t = reg.timer(&reg.forward);
            let _s = reg.span("phase", Some(&reg.backward));
        }
        assert_eq!(reg.forward.count(), 1);
        assert_eq!(reg.backward.count(), 1);
        assert_eq!(reg.spans.len(), 1);
        reg.set_enabled(false);
        assert!(reg.timer(&reg.forward).is_none());
        assert!(reg.span("phase", None).is_none());
        assert_eq!(reg.forward.count(), 1, "disabled timer recorded");
        reg.set_enabled(true);
        assert!(reg.timer(&reg.forward).is_some());
    }

    #[test]
    fn span_records_ring_and_histogram() {
        let reg = Registry::with_capacity(1, 8);
        {
            let _s = reg.span("fwd", Some(&reg.forward));
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut out = Vec::new();
        reg.spans.snapshot(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].label, "fwd");
        assert!(out[0].dur_ns >= 1_000_000, "span under 1ms: {}", out[0].dur_ns);
        assert_eq!(reg.forward.count(), 1);
    }

    #[test]
    fn step_trace_jsonl_roundtrip() {
        let tr = StepTrace {
            step: 17,
            seed: -123456789,
            loss: 2.718281828459045,
            loss_plus: 2.75,
            loss_minus: 2.6875,
            proj_grad: -0.001953125,
            cos_zm: 0.3333333333333333,
            eta: 1e-6,
            wall_s: 0.0123,
        };
        let mut line = String::new();
        tr.to_jsonl(&mut line);
        assert!(line.ends_with('\n'));
        let back = StepTrace::parse_line(&line).unwrap();
        assert_eq!(back.step, tr.step);
        assert_eq!(back.seed, tr.seed);
        assert_eq!(back.loss, tr.loss, "f64 did not round-trip");
        assert_eq!(back.proj_grad, tr.proj_grad);
        assert_eq!(back.cos_zm, tr.cos_zm);
        assert_eq!(back.eta, tr.eta);
    }

    #[test]
    fn step_trace_nan_becomes_null_and_back() {
        let tr = StepTrace {
            step: 0,
            seed: 1,
            loss: 0.5,
            loss_plus: f64::NAN,
            loss_minus: f64::INFINITY,
            proj_grad: 0.0,
            cos_zm: f64::NAN,
            eta: 1e-3,
            wall_s: 0.1,
        };
        let mut line = String::new();
        tr.to_jsonl(&mut line);
        assert!(line.contains("\"cos_zm\":null"), "{line}");
        assert!(line.contains("\"loss_minus\":null"), "{line}");
        let back = StepTrace::parse_line(&line).unwrap();
        assert!(back.cos_zm.is_nan());
        assert!(back.loss_plus.is_nan());
        assert_eq!(back.loss, 0.5);
    }

    #[test]
    fn step_trace_rejects_garbage() {
        assert!(StepTrace::parse_line("not json").is_err());
        assert!(StepTrace::parse_line("{\"loss\":1}").is_err(), "missing step must fail");
    }

    #[test]
    fn tracer_streams_jsonl_and_keeps_history() {
        let dir = std::env::temp_dir().join(format!("conmezo_tel_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let mk = |i: u64| StepTrace {
            step: i,
            seed: i as i64 * 7,
            loss: 1.0 / (i + 1) as f64,
            loss_plus: 0.0,
            loss_minus: 0.0,
            proj_grad: -0.25,
            cos_zm: f64::NAN,
            eta: 1e-4,
            wall_s: 0.001,
        };
        {
            let mut tracer = StepTracer::new(Some(&path)).unwrap();
            for i in 0..5 {
                tracer.record(mk(i)).unwrap();
            }
            tracer.flush().unwrap();
            assert_eq!(tracer.history().len(), 5);
        }
        let back = read_trace(&path).unwrap();
        assert_eq!(back.len(), 5);
        for (i, tr) in back.iter().enumerate() {
            assert_eq!(tr.step, i as u64);
            assert_eq!(tr.loss, 1.0 / (i + 1) as f64);
            assert!(tr.cos_zm.is_nan());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
