//! Explicit SIMD (AVX2) inner kernels behind the scalar `vecmath` paths.
//!
//! Every kernel here is a drop-in twin of a scalar kernel in the parent
//! module, under ONE contract: **bit-identical results**. The rules that
//! make that hold:
//!
//! * vectorize only across independent output elements (the j / column
//!   dimension of a GEMM, the elements of a row kernel) — every lane keeps
//!   the scalar kernel's p-ascending accumulation chain for its own output
//!   element;
//! * never contract `a*b + c` into an FMA: the scalar kernels evaluate one
//!   f32 multiply then one f32 add, so the vector kernels use
//!   `_mm256_add_ps(_mm256_mul_ps(..))` (the `fma` target feature is only
//!   part of the detection gate, it is never used for arithmetic);
//! * scalar tails run in index order after the full vector chunks;
//! * transcendentals (`exp`, `tanh`) and every f64 reduction (layernorm
//!   statistics, `dot`) stay scalar per element.
//!
//! Detection is lazy and overridable: `CONMEZO_SIMD={auto,off}` env var,
//! `runtime.simd` config key, `--simd` CLI flag (the latter two land here
//! through [`set_policy`]). The scalar path is always compiled and is the
//! only path on non-x86_64 targets.

use std::sync::atomic::{AtomicU8, Ordering};

/// How the SIMD dispatch should resolve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdPolicy {
    /// Use AVX2 kernels when the CPU supports avx2+fma (the default).
    Auto,
    /// Always run the scalar kernels.
    Off,
}

/// Explicit policy override: 0 = unset (read `CONMEZO_SIMD`), 1 = auto,
/// 2 = off.
static POLICY: AtomicU8 = AtomicU8::new(0);
/// Resolved dispatch state: 0 = unknown, 1 = SIMD on, 2 = SIMD off.
static RESOLVED: AtomicU8 = AtomicU8::new(0);

/// Install a dispatch policy (CLI `--simd` / `runtime.simd` config). Takes
/// effect on the next kernel call; racing callers see either the old or
/// the new policy, both of which produce bit-identical results.
pub fn set_policy(p: SimdPolicy) {
    POLICY.store(
        match p {
            SimdPolicy::Auto => 1,
            SimdPolicy::Off => 2,
        },
        Ordering::Relaxed,
    );
    RESOLVED.store(0, Ordering::Relaxed);
}

/// Whether this build/CPU can run the AVX2 kernels at all (ignores the
/// policy override).
#[cfg(target_arch = "x86_64")]
pub fn available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// Non-x86_64 targets always run the scalar fallback.
#[cfg(not(target_arch = "x86_64"))]
pub fn available() -> bool {
    false
}

/// Whether kernel dispatch takes the SIMD path right now (policy override,
/// else `CONMEZO_SIMD` env, else runtime CPU detection).
#[inline]
pub fn enabled() -> bool {
    match RESOLVED.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => resolve(),
    }
}

#[cold]
fn resolve() -> bool {
    let pol = match POLICY.load(Ordering::Relaxed) {
        1 => SimdPolicy::Auto,
        2 => SimdPolicy::Off,
        _ => match std::env::var("CONMEZO_SIMD") {
            Ok(v) if v.eq_ignore_ascii_case("off") || v == "0" => SimdPolicy::Off,
            _ => SimdPolicy::Auto,
        },
    };
    let on = pol == SimdPolicy::Auto && available();
    RESOLVED.store(if on { 1 } else { 2 }, Ordering::Relaxed);
    on
}

/// Human-readable dispatch state for `conmezo info` / benches.
pub fn status() -> &'static str {
    if enabled() {
        "on (avx2+fma)"
    } else if available() {
        "off (policy)"
    } else {
        "off (unavailable)"
    }
}

#[cfg(target_arch = "x86_64")]
pub(crate) use x86::*;

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::super::{PackForm, PackedB, ParamView, MATMUL_NR};
    use core::arch::x86_64::*;

    // ---------------------------------------------------------------
    // GEMM register kernels.
    //
    // Shared shape: j is tiled by MATMUL_NR like the scalar kernels, and
    // inside a tile 8-lane column chunks hold 4 row accumulators in
    // registers across the whole inner dimension. Each output element's
    // chain is `acc = add(acc, mul(broadcast(a), b))` with the inner index
    // ascending — exactly the scalar `acc += av * bv`.
    // ---------------------------------------------------------------

    /// SIMD twin of `matmul_span_scalar` (plain B, [k, n] row-major).
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn matmul_span(
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        row0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), rows * n);
        let (ap, bp, op) = (a.as_ptr(), b.as_ptr(), out.as_mut_ptr());
        let mut j0 = 0;
        while j0 < n {
            let nb = MATMUL_NR.min(n - j0);
            let nv = nb & !7;
            let mut i0 = 0;
            while i0 + 4 <= rows {
                let ar0 = ap.add((row0 + i0) * k);
                let ar1 = ap.add((row0 + i0 + 1) * k);
                let ar2 = ap.add((row0 + i0 + 2) * k);
                let ar3 = ap.add((row0 + i0 + 3) * k);
                let mut jv = 0;
                while jv < nv {
                    let mut acc0 = _mm256_setzero_ps();
                    let mut acc1 = _mm256_setzero_ps();
                    let mut acc2 = _mm256_setzero_ps();
                    let mut acc3 = _mm256_setzero_ps();
                    let mut wp = bp.add(j0 + jv);
                    for p in 0..k {
                        let bv = _mm256_loadu_ps(wp);
                        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(*ar0.add(p)), bv));
                        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(*ar1.add(p)), bv));
                        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(*ar2.add(p)), bv));
                        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(*ar3.add(p)), bv));
                        wp = wp.add(n);
                    }
                    _mm256_storeu_ps(op.add(i0 * n + j0 + jv), acc0);
                    _mm256_storeu_ps(op.add((i0 + 1) * n + j0 + jv), acc1);
                    _mm256_storeu_ps(op.add((i0 + 2) * n + j0 + jv), acc2);
                    _mm256_storeu_ps(op.add((i0 + 3) * n + j0 + jv), acc3);
                    jv += 8;
                }
                // tail columns of the tile: scalar, index order
                for j in j0 + nv..j0 + nb {
                    for (rr, arp) in [ar0, ar1, ar2, ar3].into_iter().enumerate() {
                        let mut acc = 0f32;
                        for p in 0..k {
                            acc += *arp.add(p) * *bp.add(p * n + j);
                        }
                        *op.add((i0 + rr) * n + j) = acc;
                    }
                }
                i0 += 4;
            }
            while i0 < rows {
                let arp = ap.add((row0 + i0) * k);
                let mut jv = 0;
                while jv < nv {
                    let mut acc = _mm256_setzero_ps();
                    let mut wp = bp.add(j0 + jv);
                    for p in 0..k {
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*arp.add(p)), _mm256_loadu_ps(wp)));
                        wp = wp.add(n);
                    }
                    _mm256_storeu_ps(op.add(i0 * n + j0 + jv), acc);
                    jv += 8;
                }
                for j in j0 + nv..j0 + nb {
                    let mut acc = 0f32;
                    for p in 0..k {
                        acc += *arp.add(p) * *bp.add(p * n + j);
                    }
                    *op.add(i0 * n + j) = acc;
                }
                i0 += 1;
            }
            j0 += nb;
        }
    }

    /// SIMD twin of `matmul_span_fused_scalar`: every weight load is
    /// `w + sc*z`, evaluated as separate mul+add per element before the
    /// accumulation multiply — the exact `axpy_into` expression.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn matmul_span_fused(
        a: &[f32],
        w: &[f32],
        z: &[f32],
        sc: f32,
        k: usize,
        n: usize,
        row0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), rows * n);
        let (ap, wp0, zp0, op) = (a.as_ptr(), w.as_ptr(), z.as_ptr(), out.as_mut_ptr());
        let scv = _mm256_set1_ps(sc);
        let mut j0 = 0;
        while j0 < n {
            let nb = MATMUL_NR.min(n - j0);
            let nv = nb & !7;
            let mut i0 = 0;
            while i0 + 4 <= rows {
                let ar0 = ap.add((row0 + i0) * k);
                let ar1 = ap.add((row0 + i0 + 1) * k);
                let ar2 = ap.add((row0 + i0 + 2) * k);
                let ar3 = ap.add((row0 + i0 + 3) * k);
                let mut jv = 0;
                while jv < nv {
                    let mut acc0 = _mm256_setzero_ps();
                    let mut acc1 = _mm256_setzero_ps();
                    let mut acc2 = _mm256_setzero_ps();
                    let mut acc3 = _mm256_setzero_ps();
                    let mut wp = wp0.add(j0 + jv);
                    let mut zp = zp0.add(j0 + jv);
                    for p in 0..k {
                        let bv = _mm256_add_ps(_mm256_loadu_ps(wp), _mm256_mul_ps(scv, _mm256_loadu_ps(zp)));
                        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(*ar0.add(p)), bv));
                        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(*ar1.add(p)), bv));
                        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(*ar2.add(p)), bv));
                        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(*ar3.add(p)), bv));
                        wp = wp.add(n);
                        zp = zp.add(n);
                    }
                    _mm256_storeu_ps(op.add(i0 * n + j0 + jv), acc0);
                    _mm256_storeu_ps(op.add((i0 + 1) * n + j0 + jv), acc1);
                    _mm256_storeu_ps(op.add((i0 + 2) * n + j0 + jv), acc2);
                    _mm256_storeu_ps(op.add((i0 + 3) * n + j0 + jv), acc3);
                    jv += 8;
                }
                for j in j0 + nv..j0 + nb {
                    for (rr, arp) in [ar0, ar1, ar2, ar3].into_iter().enumerate() {
                        let mut acc = 0f32;
                        for p in 0..k {
                            let e = p * n + j;
                            acc += *arp.add(p) * (*wp0.add(e) + sc * *zp0.add(e));
                        }
                        *op.add((i0 + rr) * n + j) = acc;
                    }
                }
                i0 += 4;
            }
            while i0 < rows {
                let arp = ap.add((row0 + i0) * k);
                let mut jv = 0;
                while jv < nv {
                    let mut acc = _mm256_setzero_ps();
                    let mut wp = wp0.add(j0 + jv);
                    let mut zp = zp0.add(j0 + jv);
                    for p in 0..k {
                        let bv = _mm256_add_ps(_mm256_loadu_ps(wp), _mm256_mul_ps(scv, _mm256_loadu_ps(zp)));
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*arp.add(p)), bv));
                        wp = wp.add(n);
                        zp = zp.add(n);
                    }
                    _mm256_storeu_ps(op.add(i0 * n + j0 + jv), acc);
                    jv += 8;
                }
                for j in j0 + nv..j0 + nb {
                    let mut acc = 0f32;
                    for p in 0..k {
                        let e = p * n + j;
                        acc += *arp.add(p) * (*wp0.add(e) + sc * *zp0.add(e));
                    }
                    *op.add(i0 * n + j) = acc;
                }
                i0 += 1;
            }
            j0 += nb;
        }
    }

    /// SIMD twin of `matmul_span_view_scalar` (composite views): the
    /// per-`p` weight tile is built SCALAR through `ParamView` (low-rank /
    /// dense-delta element order untouched), the accumulator consume is
    /// vectorized. Pad lanes of the stack tile stay zero and are never
    /// stored.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn matmul_span_view(
        a: &[f32],
        w: ParamView<'_>,
        k: usize,
        n: usize,
        row0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), rows * n);
        let ap = a.as_ptr();
        let mut acc = [[0f32; MATMUL_NR]; 4];
        let mut wtile = [0f32; MATMUL_NR];
        let mut j0 = 0;
        while j0 < n {
            let nb = MATMUL_NR.min(n - j0);
            let nv8 = (nb + 7) & !7; // wtile/acc are MATMUL_NR wide: in-bounds
            let mut i0 = 0;
            while i0 + 4 <= rows {
                for row in acc.iter_mut() {
                    row[..nb].fill(0.0);
                }
                for p in 0..k {
                    let wrow = w.row(p * n + j0, nb);
                    for (jj, t) in wtile[..nb].iter_mut().enumerate() {
                        *t = wrow.at(jj);
                    }
                    for (rr, arow) in acc.iter_mut().enumerate() {
                        let av = _mm256_set1_ps(*ap.add((row0 + i0 + rr) * k + p));
                        let mut jv = 0;
                        while jv < nv8 {
                            let cur = _mm256_loadu_ps(arow.as_ptr().add(jv));
                            let wv = _mm256_loadu_ps(wtile.as_ptr().add(jv));
                            _mm256_storeu_ps(arow.as_mut_ptr().add(jv), _mm256_add_ps(cur, _mm256_mul_ps(av, wv)));
                            jv += 8;
                        }
                    }
                }
                for (rr, arow) in acc.iter().enumerate() {
                    out[(i0 + rr) * n + j0..(i0 + rr) * n + j0 + nb].copy_from_slice(&arow[..nb]);
                }
                i0 += 4;
            }
            for i in i0..rows {
                acc[0][..nb].fill(0.0);
                for p in 0..k {
                    let wrow = w.row(p * n + j0, nb);
                    for (jj, t) in wtile[..nb].iter_mut().enumerate() {
                        *t = wrow.at(jj);
                    }
                    let av = _mm256_set1_ps(*ap.add((row0 + i) * k + p));
                    let mut jv = 0;
                    while jv < nv8 {
                        let cur = _mm256_loadu_ps(acc[0].as_ptr().add(jv));
                        let wv = _mm256_loadu_ps(wtile.as_ptr().add(jv));
                        _mm256_storeu_ps(acc[0].as_mut_ptr().add(jv), _mm256_add_ps(cur, _mm256_mul_ps(av, wv)));
                        jv += 8;
                    }
                }
                out[i * n + j0..i * n + j0 + nb].copy_from_slice(&acc[0][..nb]);
            }
            j0 += nb;
        }
    }

    /// SIMD twin of `matmul_at_span_scalar` (out rows over the k
    /// dimension, inner index i ascending).
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn matmul_at_span(
        a: &[f32],
        d: &[f32],
        m: usize,
        k: usize,
        n: usize,
        p_base: usize,
        prows: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), prows * n);
        let (ap, dp, op) = (a.as_ptr(), d.as_ptr(), out.as_mut_ptr());
        let mut j0 = 0;
        while j0 < n {
            let nb = MATMUL_NR.min(n - j0);
            let nv = nb & !7;
            let mut p0 = 0;
            while p0 + 4 <= prows {
                let c0 = ap.add(p_base + p0);
                let mut jv = 0;
                while jv < nv {
                    let mut acc0 = _mm256_setzero_ps();
                    let mut acc1 = _mm256_setzero_ps();
                    let mut acc2 = _mm256_setzero_ps();
                    let mut acc3 = _mm256_setzero_ps();
                    let mut drp = dp.add(j0 + jv);
                    let mut arp = c0;
                    for _i in 0..m {
                        let dv = _mm256_loadu_ps(drp);
                        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(*arp), dv));
                        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(*arp.add(1)), dv));
                        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(*arp.add(2)), dv));
                        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(*arp.add(3)), dv));
                        drp = drp.add(n);
                        arp = arp.add(k);
                    }
                    _mm256_storeu_ps(op.add(p0 * n + j0 + jv), acc0);
                    _mm256_storeu_ps(op.add((p0 + 1) * n + j0 + jv), acc1);
                    _mm256_storeu_ps(op.add((p0 + 2) * n + j0 + jv), acc2);
                    _mm256_storeu_ps(op.add((p0 + 3) * n + j0 + jv), acc3);
                    jv += 8;
                }
                for j in j0 + nv..j0 + nb {
                    for rr in 0..4 {
                        let mut acc = 0f32;
                        for i in 0..m {
                            acc += *ap.add(i * k + p_base + p0 + rr) * *dp.add(i * n + j);
                        }
                        *op.add((p0 + rr) * n + j) = acc;
                    }
                }
                p0 += 4;
            }
            while p0 < prows {
                let mut jv = 0;
                while jv < nv {
                    let mut acc = _mm256_setzero_ps();
                    let mut drp = dp.add(j0 + jv);
                    let mut arp = ap.add(p_base + p0);
                    for _i in 0..m {
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*arp), _mm256_loadu_ps(drp)));
                        drp = drp.add(n);
                        arp = arp.add(k);
                    }
                    _mm256_storeu_ps(op.add(p0 * n + j0 + jv), acc);
                    jv += 8;
                }
                for j in j0 + nv..j0 + nb {
                    let mut acc = 0f32;
                    for i in 0..m {
                        acc += *ap.add(i * k + p_base + p0) * *dp.add(i * n + j);
                    }
                    *op.add(p0 * n + j) = acc;
                }
                p0 += 1;
            }
            j0 += nb;
        }
    }

    /// SIMD twin of `matmul_at_span_fused_scalar` (`a` load is `w + sc*z`,
    /// broadcast per out-row — scalar fused loads, vector d consume).
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn matmul_at_span_fused(
        w: &[f32],
        z: &[f32],
        sc: f32,
        d: &[f32],
        m: usize,
        k: usize,
        n: usize,
        p_base: usize,
        prows: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), prows * n);
        let (wp, zp, dp, op) = (w.as_ptr(), z.as_ptr(), d.as_ptr(), out.as_mut_ptr());
        let mut j0 = 0;
        while j0 < n {
            let nb = MATMUL_NR.min(n - j0);
            let nv = nb & !7;
            let mut p0 = 0;
            while p0 + 4 <= prows {
                let mut jv = 0;
                while jv < nv {
                    let mut acc0 = _mm256_setzero_ps();
                    let mut acc1 = _mm256_setzero_ps();
                    let mut acc2 = _mm256_setzero_ps();
                    let mut acc3 = _mm256_setzero_ps();
                    let mut drp = dp.add(j0 + jv);
                    for i in 0..m {
                        let e = i * k + p_base + p0;
                        let dv = _mm256_loadu_ps(drp);
                        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(*wp.add(e) + sc * *zp.add(e)), dv));
                        acc1 = _mm256_add_ps(
                            acc1,
                            _mm256_mul_ps(_mm256_set1_ps(*wp.add(e + 1) + sc * *zp.add(e + 1)), dv),
                        );
                        acc2 = _mm256_add_ps(
                            acc2,
                            _mm256_mul_ps(_mm256_set1_ps(*wp.add(e + 2) + sc * *zp.add(e + 2)), dv),
                        );
                        acc3 = _mm256_add_ps(
                            acc3,
                            _mm256_mul_ps(_mm256_set1_ps(*wp.add(e + 3) + sc * *zp.add(e + 3)), dv),
                        );
                        drp = drp.add(n);
                    }
                    _mm256_storeu_ps(op.add(p0 * n + j0 + jv), acc0);
                    _mm256_storeu_ps(op.add((p0 + 1) * n + j0 + jv), acc1);
                    _mm256_storeu_ps(op.add((p0 + 2) * n + j0 + jv), acc2);
                    _mm256_storeu_ps(op.add((p0 + 3) * n + j0 + jv), acc3);
                    jv += 8;
                }
                for j in j0 + nv..j0 + nb {
                    for rr in 0..4 {
                        let mut acc = 0f32;
                        for i in 0..m {
                            let e = i * k + p_base + p0 + rr;
                            acc += (*wp.add(e) + sc * *zp.add(e)) * *dp.add(i * n + j);
                        }
                        *op.add((p0 + rr) * n + j) = acc;
                    }
                }
                p0 += 4;
            }
            while p0 < prows {
                let mut jv = 0;
                while jv < nv {
                    let mut acc = _mm256_setzero_ps();
                    let mut drp = dp.add(j0 + jv);
                    for i in 0..m {
                        let e = i * k + p_base + p0;
                        acc = _mm256_add_ps(
                            acc,
                            _mm256_mul_ps(_mm256_set1_ps(*wp.add(e) + sc * *zp.add(e)), _mm256_loadu_ps(drp)),
                        );
                        drp = drp.add(n);
                    }
                    _mm256_storeu_ps(op.add(p0 * n + j0 + jv), acc);
                    jv += 8;
                }
                for j in j0 + nv..j0 + nb {
                    let mut acc = 0f32;
                    for i in 0..m {
                        let e = i * k + p_base + p0;
                        acc += (*wp.add(e) + sc * *zp.add(e)) * *dp.add(i * n + j);
                    }
                    *op.add(p0 * n + j) = acc;
                }
                p0 += 1;
            }
            j0 += nb;
        }
    }

    /// SIMD twin of `matmul_bt_span_scalar`: 8 output columns per vector,
    /// each lane's dot running p-ascending over a gathered column of `bt`
    /// (stride-k rows → `_mm256_i32gather_ps` with a constant index
    /// vector). The packed kernel replaces the gathers with contiguous
    /// panel loads; this is the unpacked fallback.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn matmul_bt_span(
        a: &[f32],
        bt: &[f32],
        k: usize,
        n: usize,
        row0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), rows * n);
        let (ap, bp, op) = (a.as_ptr(), bt.as_ptr(), out.as_mut_ptr());
        let nv = n & !7;
        let ki = k as i32;
        let vidx = _mm256_setr_epi32(0, ki, 2 * ki, 3 * ki, 4 * ki, 5 * ki, 6 * ki, 7 * ki);
        for i in 0..rows {
            let arp = ap.add((row0 + i) * k);
            let mut j = 0;
            while j < nv {
                let mut acc = _mm256_setzero_ps();
                let base = bp.add(j * k);
                for p in 0..k {
                    let bv = _mm256_i32gather_ps::<4>(base.add(p), vidx);
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*arp.add(p)), bv));
                }
                _mm256_storeu_ps(op.add(i * n + j), acc);
                j += 8;
            }
            while j < n {
                let brp = bp.add(j * k);
                let mut acc = 0f32;
                for p in 0..k {
                    acc += *arp.add(p) * *brp.add(p);
                }
                *op.add(i * n + j) = acc;
                j += 1;
            }
        }
    }

    /// SIMD twin of `matmul_bt_span_fused_scalar` (gathered `w + sc*z`).
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn matmul_bt_span_fused(
        a: &[f32],
        w: &[f32],
        z: &[f32],
        sc: f32,
        k: usize,
        n: usize,
        row0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), rows * n);
        let (ap, wp, zp, op) = (a.as_ptr(), w.as_ptr(), z.as_ptr(), out.as_mut_ptr());
        let nv = n & !7;
        let ki = k as i32;
        let vidx = _mm256_setr_epi32(0, ki, 2 * ki, 3 * ki, 4 * ki, 5 * ki, 6 * ki, 7 * ki);
        let scv = _mm256_set1_ps(sc);
        for i in 0..rows {
            let arp = ap.add((row0 + i) * k);
            let mut j = 0;
            while j < nv {
                let mut acc = _mm256_setzero_ps();
                let wbase = wp.add(j * k);
                let zbase = zp.add(j * k);
                for p in 0..k {
                    let wv = _mm256_i32gather_ps::<4>(wbase.add(p), vidx);
                    let zv = _mm256_i32gather_ps::<4>(zbase.add(p), vidx);
                    let bv = _mm256_add_ps(wv, _mm256_mul_ps(scv, zv));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*arp.add(p)), bv));
                }
                _mm256_storeu_ps(op.add(i * n + j), acc);
                j += 8;
            }
            while j < n {
                let wrp = wp.add(j * k);
                let zrp = zp.add(j * k);
                let mut acc = 0f32;
                for p in 0..k {
                    acc += *arp.add(p) * (*wrp.add(p) + sc * *zrp.add(p));
                }
                *op.add(i * n + j) = acc;
                j += 1;
            }
        }
    }

    /// SIMD twin of `matmul_span_packed_scalar`: the hot packed-panel
    /// kernel. Plain/perturbed arms read full 64-lane zero-padded panels
    /// with contiguous vector loads; the composite arm builds the weight
    /// tile scalar (packed base + `ParamView` deltas) and consumes it
    /// vectorized.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn matmul_span_packed(
        a: &[f32],
        pk: &PackedB<'_>,
        k: usize,
        n: usize,
        row0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), rows * n);
        if let PackedB::Composite { .. } = pk {
            return matmul_span_packed_composite(a, pk, k, n, row0, rows, out);
        }
        let (ap, op) = (a.as_ptr(), out.as_mut_ptr());
        let (wp0, zp0, sc) = match *pk {
            PackedB::Plain(w) => (w.as_ptr(), std::ptr::null::<f32>(), 0f32),
            PackedB::Perturbed { w, z, sc } => (w.as_ptr(), z.as_ptr(), sc),
            PackedB::Composite { .. } => unreachable!(),
        };
        let fused = !zp0.is_null();
        let scv = _mm256_set1_ps(sc);
        let mut j0 = 0;
        let mut jt = 0;
        while j0 < n {
            let nb = MATMUL_NR.min(n - j0);
            let nv = nb & !7;
            let tb = jt * MATMUL_NR * k;
            let mut i0 = 0;
            while i0 + 4 <= rows {
                let ar0 = ap.add((row0 + i0) * k);
                let ar1 = ap.add((row0 + i0 + 1) * k);
                let ar2 = ap.add((row0 + i0 + 2) * k);
                let ar3 = ap.add((row0 + i0 + 3) * k);
                let mut jv = 0;
                while jv < nv {
                    let mut acc0 = _mm256_setzero_ps();
                    let mut acc1 = _mm256_setzero_ps();
                    let mut acc2 = _mm256_setzero_ps();
                    let mut acc3 = _mm256_setzero_ps();
                    let mut wp = wp0.add(tb + jv);
                    let mut zp = if fused { zp0.add(tb + jv) } else { zp0 };
                    for p in 0..k {
                        let bv = if fused {
                            let v = _mm256_add_ps(_mm256_loadu_ps(wp), _mm256_mul_ps(scv, _mm256_loadu_ps(zp)));
                            zp = zp.add(MATMUL_NR);
                            v
                        } else {
                            _mm256_loadu_ps(wp)
                        };
                        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(*ar0.add(p)), bv));
                        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(*ar1.add(p)), bv));
                        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(*ar2.add(p)), bv));
                        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(*ar3.add(p)), bv));
                        wp = wp.add(MATMUL_NR);
                    }
                    _mm256_storeu_ps(op.add(i0 * n + j0 + jv), acc0);
                    _mm256_storeu_ps(op.add((i0 + 1) * n + j0 + jv), acc1);
                    _mm256_storeu_ps(op.add((i0 + 2) * n + j0 + jv), acc2);
                    _mm256_storeu_ps(op.add((i0 + 3) * n + j0 + jv), acc3);
                    jv += 8;
                }
                for jj in nv..nb {
                    for (rr, arp) in [ar0, ar1, ar2, ar3].into_iter().enumerate() {
                        let mut acc = 0f32;
                        for p in 0..k {
                            let e = tb + p * MATMUL_NR + jj;
                            let wv = if fused { *wp0.add(e) + sc * *zp0.add(e) } else { *wp0.add(e) };
                            acc += *arp.add(p) * wv;
                        }
                        *op.add((i0 + rr) * n + j0 + jj) = acc;
                    }
                }
                i0 += 4;
            }
            while i0 < rows {
                let arp = ap.add((row0 + i0) * k);
                let mut jv = 0;
                while jv < nv {
                    let mut acc = _mm256_setzero_ps();
                    let mut wp = wp0.add(tb + jv);
                    let mut zp = if fused { zp0.add(tb + jv) } else { zp0 };
                    for p in 0..k {
                        let bv = if fused {
                            let v = _mm256_add_ps(_mm256_loadu_ps(wp), _mm256_mul_ps(scv, _mm256_loadu_ps(zp)));
                            zp = zp.add(MATMUL_NR);
                            v
                        } else {
                            _mm256_loadu_ps(wp)
                        };
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*arp.add(p)), bv));
                        wp = wp.add(MATMUL_NR);
                    }
                    _mm256_storeu_ps(op.add(i0 * n + j0 + jv), acc);
                    jv += 8;
                }
                for jj in nv..nb {
                    let mut acc = 0f32;
                    for p in 0..k {
                        let e = tb + p * MATMUL_NR + jj;
                        let wv = if fused { *wp0.add(e) + sc * *zp0.add(e) } else { *wp0.add(e) };
                        acc += *arp.add(p) * wv;
                    }
                    *op.add(i0 * n + j0 + jj) = acc;
                }
                i0 += 1;
            }
            j0 += nb;
            jt += 1;
        }
    }

    /// Composite arm of the packed kernel: scalar tile build (packed base
    /// value + `ParamView::at_with_base` deltas in the pinned order),
    /// vectorized consume.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn matmul_span_packed_composite(
        a: &[f32],
        pk: &PackedB<'_>,
        k: usize,
        n: usize,
        row0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        let (wp0, view, form) = match pk {
            PackedB::Composite { w, view, form } => (w.as_ptr(), view, *form),
            _ => unreachable!(),
        };
        let ap = a.as_ptr();
        let mut acc = [[0f32; MATMUL_NR]; 4];
        let mut wtile = [0f32; MATMUL_NR];
        let mut j0 = 0;
        let mut jt = 0;
        while j0 < n {
            let nb = MATMUL_NR.min(n - j0);
            let nv8 = (nb + 7) & !7;
            let tb = jt * MATMUL_NR * k;
            let mut i0 = 0;
            while i0 + 4 <= rows {
                for row in acc.iter_mut() {
                    row[..nb].fill(0.0);
                }
                for p in 0..k {
                    for (jj, t) in wtile[..nb].iter_mut().enumerate() {
                        let e = match form {
                            PackForm::B => p * n + j0 + jj,
                            PackForm::Bt => (j0 + jj) * k + p,
                        };
                        *t = view.at_with_base(*wp0.add(tb + p * MATMUL_NR + jj), e);
                    }
                    for (rr, arow) in acc.iter_mut().enumerate() {
                        let av = _mm256_set1_ps(*ap.add((row0 + i0 + rr) * k + p));
                        let mut jv = 0;
                        while jv < nv8 {
                            let cur = _mm256_loadu_ps(arow.as_ptr().add(jv));
                            let wv = _mm256_loadu_ps(wtile.as_ptr().add(jv));
                            _mm256_storeu_ps(arow.as_mut_ptr().add(jv), _mm256_add_ps(cur, _mm256_mul_ps(av, wv)));
                            jv += 8;
                        }
                    }
                }
                for (rr, arow) in acc.iter().enumerate() {
                    out[(i0 + rr) * n + j0..(i0 + rr) * n + j0 + nb].copy_from_slice(&arow[..nb]);
                }
                i0 += 4;
            }
            for i in i0..rows {
                acc[0][..nb].fill(0.0);
                for p in 0..k {
                    for (jj, t) in wtile[..nb].iter_mut().enumerate() {
                        let e = match form {
                            PackForm::B => p * n + j0 + jj,
                            PackForm::Bt => (j0 + jj) * k + p,
                        };
                        *t = view.at_with_base(*wp0.add(tb + p * MATMUL_NR + jj), e);
                    }
                    let av = _mm256_set1_ps(*ap.add((row0 + i) * k + p));
                    let mut jv = 0;
                    while jv < nv8 {
                        let cur = _mm256_loadu_ps(acc[0].as_ptr().add(jv));
                        let wv = _mm256_loadu_ps(wtile.as_ptr().add(jv));
                        _mm256_storeu_ps(acc[0].as_mut_ptr().add(jv), _mm256_add_ps(cur, _mm256_mul_ps(av, wv)));
                        jv += 8;
                    }
                }
                out[i * n + j0..i * n + j0 + nb].copy_from_slice(&acc[0][..nb]);
            }
            j0 += nb;
            jt += 1;
        }
    }

    // ---------------------------------------------------------------
    // Row / elementwise kernels.
    // ---------------------------------------------------------------

    /// SIMD twin of `axpy_into_scalar`: out = x + a*z (separate mul+add).
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn axpy_into(a: f32, z: &[f32], x: &[f32], out: &mut [f32]) {
        let nv = x.len() & !7;
        let av = _mm256_set1_ps(a);
        let (xp, zp, op) = (x.as_ptr(), z.as_ptr(), out.as_mut_ptr());
        let mut i = 0;
        while i < nv {
            let v = _mm256_add_ps(_mm256_loadu_ps(xp.add(i)), _mm256_mul_ps(av, _mm256_loadu_ps(zp.add(i))));
            _mm256_storeu_ps(op.add(i), v);
            i += 8;
        }
        while i < x.len() {
            *op.add(i) = *xp.add(i) + a * *zp.add(i);
            i += 1;
        }
    }

    /// SIMD twin of `add_bias_rows_scalar`.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn add_bias_rows(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
        let nv = cols & !7;
        let (xp, bp) = (x.as_mut_ptr(), bias.as_ptr());
        for i in 0..rows {
            let rp = xp.add(i * cols);
            let mut j = 0;
            while j < nv {
                let v = _mm256_add_ps(_mm256_loadu_ps(rp.add(j)), _mm256_loadu_ps(bp.add(j)));
                _mm256_storeu_ps(rp.add(j), v);
                j += 8;
            }
            while j < cols {
                *rp.add(j) += *bp.add(j);
                j += 1;
            }
        }
    }

    /// SIMD twin of the perturbed `add_bias_rows_view` arm:
    /// `row[j] += b[j] + sc*z[j]`.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn add_bias_rows_perturbed(x: &mut [f32], b: &[f32], z: &[f32], sc: f32, rows: usize, cols: usize) {
        let nv = cols & !7;
        let scv = _mm256_set1_ps(sc);
        let (xp, bp, zp) = (x.as_mut_ptr(), b.as_ptr(), z.as_ptr());
        for i in 0..rows {
            let rp = xp.add(i * cols);
            let mut j = 0;
            while j < nv {
                let bv = _mm256_add_ps(_mm256_loadu_ps(bp.add(j)), _mm256_mul_ps(scv, _mm256_loadu_ps(zp.add(j))));
                _mm256_storeu_ps(rp.add(j), _mm256_add_ps(_mm256_loadu_ps(rp.add(j)), bv));
                j += 8;
            }
            while j < cols {
                *rp.add(j) += *bp.add(j) + sc * *zp.add(j);
                j += 1;
            }
        }
    }

    /// SIMD twin of the layernorm affine row:
    /// `orow[j] = (row[j] - mean) * inv * g[j] + b[j]` (left-associated,
    /// like the scalar loop). The f64 mean/variance reduction stays in the
    /// scalar caller.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn layernorm_affine(row: &[f32], g: &[f32], b: &[f32], mean: f32, inv: f32, orow: &mut [f32]) {
        let cols = row.len();
        let nv = cols & !7;
        let mv = _mm256_set1_ps(mean);
        let iv = _mm256_set1_ps(inv);
        let (rp, gp, bp, op) = (row.as_ptr(), g.as_ptr(), b.as_ptr(), orow.as_mut_ptr());
        let mut j = 0;
        while j < nv {
            let t = _mm256_mul_ps(_mm256_sub_ps(_mm256_loadu_ps(rp.add(j)), mv), iv);
            let v = _mm256_add_ps(_mm256_mul_ps(t, _mm256_loadu_ps(gp.add(j))), _mm256_loadu_ps(bp.add(j)));
            _mm256_storeu_ps(op.add(j), v);
            j += 8;
        }
        while j < cols {
            *op.add(j) = (*rp.add(j) - mean) * inv * *gp.add(j) + *bp.add(j);
            j += 1;
        }
    }

    /// SIMD twin of the softmax rescale loop (`*v *= inv`); the max scan
    /// and the sequential exp/denominator accumulation stay scalar.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn scale_in_place(row: &mut [f32], inv: f32) {
        let nv = row.len() & !7;
        let iv = _mm256_set1_ps(inv);
        let rp = row.as_mut_ptr();
        let mut j = 0;
        while j < nv {
            _mm256_storeu_ps(rp.add(j), _mm256_mul_ps(_mm256_loadu_ps(rp.add(j)), iv));
            j += 8;
        }
        while j < row.len() {
            *rp.add(j) *= inv;
            j += 1;
        }
    }

    /// SIMD twin of `gelu_scalar`: the polynomial halves are vectorized
    /// with the scalar expression tree; `tanh` runs scalar per element
    /// through an 8-wide stack buffer (same `f32::tanh` call).
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn gelu(x: &mut [f32]) {
        const C: f32 = 0.797_884_56; // sqrt(2/pi)
        const A: f32 = 0.044715;
        let nv = x.len() & !7;
        let cv = _mm256_set1_ps(C);
        let av = _mm256_set1_ps(A);
        let half = _mm256_set1_ps(0.5);
        let one = _mm256_set1_ps(1.0);
        let xp = x.as_mut_ptr();
        let mut buf = [0f32; 8];
        let mut i = 0;
        while i < nv {
            let t = _mm256_loadu_ps(xp.add(i));
            // C * (t + ((A*t)*t)*t) — the scalar `C * (t + A*t*t*t)`
            let cube = _mm256_mul_ps(_mm256_mul_ps(_mm256_mul_ps(av, t), t), t);
            let arg = _mm256_mul_ps(cv, _mm256_add_ps(t, cube));
            _mm256_storeu_ps(buf.as_mut_ptr(), arg);
            for v in buf.iter_mut() {
                *v = v.tanh();
            }
            let th = _mm256_loadu_ps(buf.as_ptr());
            // (0.5*t) * (1 + th) — the scalar `0.5 * t * (1.0 + th)`
            let res = _mm256_mul_ps(_mm256_mul_ps(half, t), _mm256_add_ps(one, th));
            _mm256_storeu_ps(xp.add(i), res);
            i += 8;
        }
        while i < x.len() {
            let t = *xp.add(i);
            *xp.add(i) = 0.5 * t * (1.0 + (C * (t + A * t * t * t)).tanh());
            i += 1;
        }
    }
}

// Non-x86_64: the dispatchers in the parent module never take the SIMD
// branch (`enabled()` is false), but the symbols must exist — delegate to
// the scalar twins so any stray call is still correct.
#[cfg(not(target_arch = "x86_64"))]
pub(crate) use fallback::*;

#[cfg(not(target_arch = "x86_64"))]
mod fallback {
    use super::super::{PackedB, ParamView};

    pub(crate) unsafe fn matmul_span(a: &[f32], b: &[f32], k: usize, n: usize, row0: usize, rows: usize, out: &mut [f32]) {
        super::super::matmul_span_scalar(a, b, k, n, row0, rows, out)
    }
    #[allow(clippy::too_many_arguments)]
    pub(crate) unsafe fn matmul_span_fused(a: &[f32], w: &[f32], z: &[f32], sc: f32, k: usize, n: usize, row0: usize, rows: usize, out: &mut [f32]) {
        super::super::matmul_span_fused_scalar(a, w, z, sc, k, n, row0, rows, out)
    }
    pub(crate) unsafe fn matmul_span_view(a: &[f32], w: ParamView<'_>, k: usize, n: usize, row0: usize, rows: usize, out: &mut [f32]) {
        super::super::matmul_span_view_scalar(a, w, k, n, row0, rows, out)
    }
    #[allow(clippy::too_many_arguments)]
    pub(crate) unsafe fn matmul_at_span(a: &[f32], d: &[f32], m: usize, k: usize, n: usize, p_base: usize, prows: usize, out: &mut [f32]) {
        super::super::matmul_at_span_scalar(a, d, m, k, n, p_base, prows, out)
    }
    #[allow(clippy::too_many_arguments)]
    pub(crate) unsafe fn matmul_at_span_fused(w: &[f32], z: &[f32], sc: f32, d: &[f32], m: usize, k: usize, n: usize, p_base: usize, prows: usize, out: &mut [f32]) {
        super::super::matmul_at_span_fused_scalar(w, z, sc, d, m, k, n, p_base, prows, out)
    }
    pub(crate) unsafe fn matmul_bt_span(a: &[f32], bt: &[f32], k: usize, n: usize, row0: usize, rows: usize, out: &mut [f32]) {
        super::super::matmul_bt_span_scalar(a, bt, k, n, row0, rows, out)
    }
    #[allow(clippy::too_many_arguments)]
    pub(crate) unsafe fn matmul_bt_span_fused(a: &[f32], w: &[f32], z: &[f32], sc: f32, k: usize, n: usize, row0: usize, rows: usize, out: &mut [f32]) {
        super::super::matmul_bt_span_fused_scalar(a, w, z, sc, k, n, row0, rows, out)
    }
    pub(crate) unsafe fn matmul_span_packed(a: &[f32], pk: &PackedB<'_>, k: usize, n: usize, row0: usize, rows: usize, out: &mut [f32]) {
        super::super::matmul_span_packed_scalar(a, pk, k, n, row0, rows, out)
    }
    pub(crate) unsafe fn axpy_into(a: f32, z: &[f32], x: &[f32], out: &mut [f32]) {
        super::super::axpy_into_scalar(a, z, x, out)
    }
    pub(crate) unsafe fn add_bias_rows(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
        super::super::add_bias_rows_scalar(x, bias, rows, cols)
    }
    pub(crate) unsafe fn add_bias_rows_perturbed(x: &mut [f32], b: &[f32], z: &[f32], sc: f32, rows: usize, cols: usize) {
        super::super::add_bias_rows_perturbed_scalar(x, b, z, sc, rows, cols)
    }
    pub(crate) unsafe fn layernorm_affine(row: &[f32], g: &[f32], b: &[f32], mean: f32, inv: f32, orow: &mut [f32]) {
        super::super::layernorm_affine_scalar(row, g, b, mean, inv, orow)
    }
    pub(crate) unsafe fn scale_in_place(row: &mut [f32], inv: f32) {
        super::super::scale_in_place_scalar(row, inv)
    }
    pub(crate) unsafe fn gelu(x: &mut [f32]) {
        super::super::gelu_scalar(x)
    }
}
