//! Host-side flat-buffer f32 kernels — the L3 hot path for composed-mode
//! optimizers (HiZOO / LOZO / MeZO-SVRG / loop-based MeZO emulation).
//!
//! Mirrors the L1 Pallas kernel set one-for-one (`cone_direction`,
//! `perturb`, `zo_update`, ...) so either execution mode computes identical
//! math. Loops are written as chunked, multiplier-accumulator-friendly code
//! that LLVM auto-vectorizes; `cargo bench optimizer_math` tracks their
//! throughput against the memory-bandwidth roofline (EXPERIMENTS.md §Perf).
//!
//! The dense GEMMs (`matmul` / `matmul_at` / `matmul_bt`) additionally have
//! `*_threaded` twins that split the output rows into contiguous chunks and
//! dispatch them onto a persistent [`crate::parallel::WorkerPool`] (created
//! once per `Runtime` from `runtime::ParallelPolicy` — no per-call thread
//! spawning). Each output element is produced by exactly one task with the
//! same per-element accumulation order as the single-threaded kernel, so
//! pooled results are bit-identical at every pool size — pinned by
//! `threaded_gemms_bit_identical_across_pool_sizes`.
//!
//! ## [`ParamView`]: materialization-free antithetic perturbations
//!
//! The ZO hot loop evaluates `f(x + λz)` and `f(x − λz)` once per step.
//! Materializing the perturbed buffer (`axpy_into` into a `d`-sized
//! scratch the forward then re-reads) costs two full-`d` writes plus an
//! extra read per pair on a bandwidth-bound path. A [`ParamView`] —
//! `{base, dir, scale}` — instead fuses the perturbation into the
//! streaming loads: every weight-consuming kernel has a `*_view` variant
//! (`matmul_view_threaded`, `matmul_at_view_threaded`,
//! `matmul_bt_view_threaded`, `add_bias_rows_view`, `layernorm_rows_view`)
//! that computes `base[i] + scale * dir[i]` in-register at load time.
//! Because that is the exact FMA-free expression `axpy_into` writes,
//! fused-view results are **bit-identical** to running the plain kernel on
//! a materialized buffer — pinned here by
//! `view_gemms_match_materialized_across_pool_sizes` /
//! `view_bias_and_layernorm_match_materialized` and by model-/session-
//! level twins. A plain view (`dir = None`) dispatches straight to the
//! unfused kernel, so the non-perturbed paths pay nothing.
//!
//! ## [`AdapterBinding`]: low-rank tenant deltas over a shared base
//!
//! The multi-tenant serving layer (`crate::serve`) runs N finetuning jobs
//! against ONE read-only base buffer: each tenant owns only a small adapter
//! vector. An [`AdapterBinding`] maps per-tensor segments of the base onto
//! that vector — 2-D weights get rank-r factors (`U V^T / sqrt(r)` fused
//! into the loads), 1-D tensors get dense deltas — and a [`ParamView`]
//! carrying a binding resolves each `slice()` (how `runtime::model` carves
//! per-tensor views) to the matching segment. SPSA perturbations live in
//! ADAPTER coordinates: for a 2-D segment the effective element under
//! `scale = ±λ` is `base + ((U+λZu)(V+λZv)^T)/sqrt(r)`, so a tenant's whole
//! ZO state is O(rank·dims). Composite views route to `*_span_view` kernels
//! that walk the exact tile order of the fused spans while reading
//! `view.at(i)`, so results stay bit-identical to materializing the delta
//! and running the plain kernel — pinned by
//! `adapter_view_gemms_match_materialized_across_pool_sizes`.
//!
//! ## Explicit SIMD and bind-time weight packing
//!
//! Two raw-speed layers sit UNDER the kernels above without changing any
//! result bit:
//!
//! * **[`simd`]** holds AVX2 twins of the hot inner kernels (GEMM spans,
//!   `axpy_into`, bias/layernorm/gelu/softmax row loops). Dispatch is
//!   per-call through `simd::enabled()` (runtime `is_x86_feature_detected!`,
//!   overridable via `CONMEZO_SIMD={auto,off}` / `runtime.simd` config /
//!   `--simd`); the scalar bodies live on as `*_scalar` twins and are the
//!   always-compiled fallback. The SIMD kernels vectorize across
//!   INDEPENDENT output elements only (8 output columns per vector, each
//!   lane running the scalar p-ascending chain) and never contract the
//!   fused `w + sc*z` multiply-add into an FMA, so bit-identity against the
//!   scalar kernels — and through them against the materialized references
//!   — is preserved. Pinned by `simd_kernels_bit_identical_to_scalar`.
//! * **Packed panels**: [`pack_b`] / [`pack_bt`] re-stride a weight's
//!   B-side operand once into `MATMUL_NR`-wide, zero-padded column panels
//!   (`dst[jt*NR*k + p*NR + jj]`), so the GEMM inner loop reads
//!   contiguous cache lines instead of striding `n` (or gathering `k`-
//!   strided columns for the transposed LM head). [`PackedB`] carries the
//!   packed base plus an optional packed direction (`w + sc*z` fused
//!   in-register per ±λ arm) or a composite [`ParamView`] (adapter deltas
//!   fused on top of the packed base via [`ParamView::at_with_base`]);
//!   [`matmul_packed_view_threaded`] is the pooled entry.
//!   `runtime::model` packs each 2-D weight once per top-level call (once
//!   per antithetic PAIR in `pair_losses`/adapter `two_point`) into
//!   bind-time-allocated scratch — packing is a pure permutation copy, so
//!   packed results are bit-identical to the unpacked kernels (pinned by
//!   `packed_gemms_match_unpacked_across_pool_sizes`).

use crate::parallel::{SendPtr, WorkerPool};

pub mod simd;

/// One tensor's mapping from the shared base buffer onto a tenant's flat
/// adapter vector. Segments are built once per (preset, rank) by
/// `runtime::adapter::AdapterPlan` and shared by every tenant of that
/// shape; 2-D weights whose dims both reach `rank` get low-rank factors
/// (mirroring `optimizer::lozo`'s segmentation), everything else a dense
/// delta.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdapterSeg {
    /// A 2-D tensor `[rows, cols]` at `off` in the base: the tenant owns
    /// `U [rows, rank]` at `u_off` and `V [cols, rank]` at `v_off` in the
    /// adapter vector, and the effective element is
    /// `base + (U V^T)/sqrt(rank)`.
    Mat { off: usize, rows: usize, cols: usize, rank: usize, u_off: usize, v_off: usize },
    /// Any other tensor (1-D gains/biases, or 2-D too small for the rank):
    /// a dense delta of `len` elements at `a_off` in the adapter vector.
    Dense { off: usize, len: usize, a_off: usize },
}

impl AdapterSeg {
    /// Offset of this tensor in the base buffer.
    pub fn off(&self) -> usize {
        match self {
            AdapterSeg::Mat { off, .. } | AdapterSeg::Dense { off, .. } => *off,
        }
    }

    /// Element count of this tensor in the base buffer.
    pub fn elems(&self) -> usize {
        match self {
            AdapterSeg::Mat { rows, cols, .. } => rows * cols,
            AdapterSeg::Dense { len, .. } => *len,
        }
    }

    /// Tenant-owned parameter count for this segment: `(rows + cols) * rank`
    /// for a factored matrix, `len` for a dense delta.
    pub fn adapter_elems(&self) -> usize {
        match self {
            AdapterSeg::Mat { rows, cols, rank, .. } => (rows + cols) * rank,
            AdapterSeg::Dense { len, .. } => *len,
        }
    }
}

/// Total tenant-owned parameter count over a segment list — the dimension
/// the per-tenant ZO optimizer runs in.
pub fn adapter_dim(segs: &[AdapterSeg]) -> usize {
    segs.iter().map(|s| s.adapter_elems()).sum()
}

/// A tenant's adapter delta bound over a segment list, optionally carrying
/// an SPSA perturbation `dir` (same flat layout as `adapter`) at `scale`.
/// [`ParamView::adapter`] wraps the shared base with one of these; slicing
/// a tensor out of that view resolves to the matching segment.
#[derive(Clone, Copy, Debug)]
pub struct AdapterBinding<'a> {
    segs: &'a [AdapterSeg],
    adapter: &'a [f32],
    dir: Option<&'a [f32]>,
    scale: f32,
}

impl<'a> AdapterBinding<'a> {
    /// The unperturbed binding `base + delta(adapter)`.
    pub fn new(segs: &'a [AdapterSeg], adapter: &'a [f32]) -> AdapterBinding<'a> {
        assert_eq!(adapter.len(), adapter_dim(segs));
        AdapterBinding { segs, adapter, dir: None, scale: 0.0 }
    }

    /// The perturbed binding `base + delta(adapter + scale * dir)` where the
    /// perturbation composes in adapter coordinates (for 2-D segments both
    /// factors shift: `(U + scale*Zu)(V + scale*Zv)^T / sqrt(r)`).
    pub fn perturbed(
        segs: &'a [AdapterSeg],
        adapter: &'a [f32],
        dir: &'a [f32],
        scale: f32,
    ) -> AdapterBinding<'a> {
        assert_eq!(adapter.len(), adapter_dim(segs));
        assert_eq!(adapter.len(), dir.len());
        AdapterBinding { segs, adapter, dir: Some(dir), scale }
    }

    /// The segment list this binding resolves against.
    pub fn segs(&self) -> &'a [AdapterSeg] {
        self.segs
    }

    /// The per-tensor view for `seg` over its base slice.
    fn seg_view(&self, seg: &AdapterSeg, base: &'a [f32]) -> ParamView<'a> {
        debug_assert_eq!(base.len(), seg.elems());
        match *seg {
            AdapterSeg::Mat { rows, cols, rank, u_off, v_off, .. } => ParamView {
                base,
                dir: None,
                scale: 0.0,
                add: None,
                lowrank: Some(LowRankRef {
                    u: &self.adapter[u_off..u_off + rows * rank],
                    v: &self.adapter[v_off..v_off + cols * rank],
                    zu: self.dir.map(|z| &z[u_off..u_off + rows * rank]),
                    zv: self.dir.map(|z| &z[v_off..v_off + cols * rank]),
                    rank,
                    cols,
                    inv_sqrt_r: 1.0 / (rank as f32).sqrt(),
                    scale: self.scale,
                    elem_off: 0,
                }),
                binding: None,
            },
            AdapterSeg::Dense { len, a_off, .. } => ParamView {
                base,
                dir: self.dir.map(|z| &z[a_off..a_off + len]),
                scale: self.scale,
                add: Some(&self.adapter[a_off..a_off + len]),
                lowrank: None,
                binding: None,
            },
        }
    }

    /// The segment exactly covering `[off, off + len)` in the base buffer.
    fn find(&self, off: usize, len: usize) -> &'a AdapterSeg {
        let idx = self.segs.partition_point(|s| s.off() < off);
        match self.segs.get(idx) {
            Some(s) if s.off() == off && s.elems() == len => s,
            _ => panic!("adapter binding has no segment covering [{off}, {})", off + len),
        }
    }

    /// Effective element `i` of a whole-buffer adapter view (lanes past the
    /// segment coverage — the alignment pads — read the base verbatim).
    fn element(&self, base: &'a [f32], i: usize) -> f32 {
        let idx = self.segs.partition_point(|s| s.off() + s.elems() <= i);
        match self.segs.get(idx) {
            Some(s) if s.off() <= i => {
                let v = self.seg_view(s, &base[s.off()..s.off() + s.elems()]);
                v.at(i - s.off())
            }
            _ => base[i],
        }
    }
}

/// A rank-`r` factor delta over one 2-D tensor, resolved from an
/// [`AdapterBinding`] segment: element `(row, col)` reads
/// `sum_k (U[row,k] + scale*Zu[row,k]) * (V[col,k] + scale*Zv[col,k])`
/// times `1/sqrt(rank)`, k ascending from a zero f32 accumulator — the
/// exact order the materialized test reference uses, so fused reads are
/// bit-identical to materialize-then-run.
#[derive(Clone, Copy, Debug)]
pub struct LowRankRef<'a> {
    u: &'a [f32],
    v: &'a [f32],
    zu: Option<&'a [f32]>,
    zv: Option<&'a [f32]>,
    rank: usize,
    cols: usize,
    inv_sqrt_r: f32,
    scale: f32,
    /// Flat-element offset of this (possibly sub-sliced) view into the
    /// underlying `[rows, cols]` tensor.
    elem_off: usize,
}

impl LowRankRef<'_> {
    /// The delta at flat element `i` of the viewed range.
    #[inline(always)]
    fn at(&self, i: usize) -> f32 {
        let e = self.elem_off + i;
        let (r, c) = (e / self.cols, e % self.cols);
        let urow = &self.u[r * self.rank..(r + 1) * self.rank];
        let vrow = &self.v[c * self.rank..(c + 1) * self.rank];
        let mut acc = 0f32;
        match (self.zu, self.zv) {
            (Some(zu), Some(zv)) => {
                let zur = &zu[r * self.rank..(r + 1) * self.rank];
                let zvr = &zv[c * self.rank..(c + 1) * self.rank];
                for kk in 0..self.rank {
                    acc += (urow[kk] + self.scale * zur[kk]) * (vrow[kk] + self.scale * zvr[kk]);
                }
            }
            _ => {
                for kk in 0..self.rank {
                    acc += urow[kk] * vrow[kk];
                }
            }
        }
        acc * self.inv_sqrt_r
    }
}

/// A flat parameter buffer viewed through an optional rank-one
/// perturbation: element `i` reads as `base[i] + scale * dir[i]` (or just
/// `base[i]` when `dir` is `None`). The antithetic-pair core builds two of
/// these per step (`scale = ±λ`) so the forward streams `x ± λz` straight
/// out of `params` and `z` without ever writing a perturbed copy.
///
/// The fused expression is evaluated exactly as [`axpy_into`] evaluates it
/// (one f32 multiply, one f32 add, no FMA contraction), so view-kernel
/// results are bit-identical to materialize-then-run.
#[derive(Clone, Copy, Debug)]
pub struct ParamView<'a> {
    base: &'a [f32],
    dir: Option<&'a [f32]>,
    scale: f32,
    /// Unit-scale dense delta (a tenant's persistent 1-D adapter values).
    add: Option<&'a [f32]>,
    /// Low-rank factor delta (a tenant's 2-D adapter segment).
    lowrank: Option<LowRankRef<'a>>,
    /// Whole-buffer adapter binding: per-tensor `slice()` calls resolve
    /// against its segment list instead of slicing dense deltas.
    binding: Option<&'a AdapterBinding<'a>>,
}

impl<'a> ParamView<'a> {
    /// An unperturbed view: reads are plain `base[i]` loads and every
    /// `*_view` kernel dispatches to its unfused twin.
    pub fn plain(base: &'a [f32]) -> ParamView<'a> {
        ParamView { base, dir: None, scale: 0.0, add: None, lowrank: None, binding: None }
    }

    /// The perturbed view `base + scale * dir` (lengths must match).
    pub fn perturbed(base: &'a [f32], dir: &'a [f32], scale: f32) -> ParamView<'a> {
        assert_eq!(base.len(), dir.len());
        ParamView { base, dir: Some(dir), scale, add: None, lowrank: None, binding: None }
    }

    /// A view of the shared base buffer through a tenant's adapter delta:
    /// per-tensor `slice()` calls resolve against `binding`'s segments
    /// (low-rank for factored 2-D weights, dense for the rest), with any
    /// SPSA perturbation applied in adapter coordinates.
    pub fn adapter(base: &'a [f32], binding: &'a AdapterBinding<'a>) -> ParamView<'a> {
        ParamView { base, dir: None, scale: 0.0, add: None, lowrank: None, binding: Some(binding) }
    }

    pub fn len(&self) -> usize {
        self.base.len()
    }

    pub fn is_empty(&self) -> bool {
        self.base.is_empty()
    }

    /// The unperturbed payload.
    pub fn base(&self) -> &'a [f32] {
        self.base
    }

    /// `(dir, scale)` when this view carries a perturbation.
    pub fn dir(&self) -> Option<(&'a [f32], f32)> {
        self.dir.map(|d| (d, self.scale))
    }

    /// The sub-view `[off, off + len)` — how per-tensor views are carved
    /// out of the flat buffer (`runtime::model::Span::view`). On an adapter
    /// view the range must cover one segment exactly; the result carries
    /// that segment's low-rank or dense delta.
    pub fn slice(&self, off: usize, len: usize) -> ParamView<'a> {
        if let Some(bind) = self.binding {
            let seg = bind.find(off, len);
            return bind.seg_view(seg, &self.base[off..off + len]);
        }
        ParamView {
            base: &self.base[off..off + len],
            dir: self.dir.map(|d| &d[off..off + len]),
            scale: self.scale,
            add: self.add.map(|a| &a[off..off + len]),
            lowrank: self.lowrank.map(|mut lr| {
                lr.elem_off += off;
                lr
            }),
            binding: None,
        }
    }

    /// Whether this view carries any delta beyond a dense perturbation —
    /// the composite paths the adapter kernels must take.
    #[inline(always)]
    pub(crate) fn has_composite(&self) -> bool {
        self.add.is_some() || self.lowrank.is_some() || self.binding.is_some()
    }

    /// Whether reads differ from the raw base at all.
    #[inline(always)]
    pub(crate) fn has_delta(&self) -> bool {
        self.dir.is_some() || self.has_composite()
    }

    /// Element `i` with every delta fused into the load, accumulated in a
    /// fixed order (base, then dense adapter, then low-rank factor, then
    /// scaled perturbation) so the composite value is bitwise reproducible
    /// by the materialized reference.
    #[inline(always)]
    pub fn at(&self, i: usize) -> f32 {
        if let Some(bind) = self.binding {
            return bind.element(self.base, i);
        }
        self.at_with_base(self.base[i], i)
    }

    /// [`Self::at`] with the base value supplied by the caller — the packed
    /// GEMM arms read the base from a packed panel (a bit-exact copy of
    /// `base[i]`) and fuse the deltas on top in the same fixed order.
    /// Binding-carrying views must resolve to per-tensor views first.
    #[inline(always)]
    pub(crate) fn at_with_base(&self, base: f32, i: usize) -> f32 {
        debug_assert!(self.binding.is_none());
        let mut w = base;
        if let Some(a) = self.add {
            w += a[i];
        }
        if let Some(lr) = &self.lowrank {
            w += lr.at(i);
        }
        if let Some(d) = self.dir {
            w += self.scale * d[i];
        }
        w
    }

    /// The contiguous row `[off, off + len)` as a [`RowView`]: one dispatch
    /// (plain / perturbed / composite) hoisted out of the per-element loop.
    /// This is the ONE fused accessor behind every per-element view read —
    /// the embedding gather and the tied-LM-head column loop both route
    /// through it, so the two element-wise paths cannot drift.
    #[inline(always)]
    pub fn row(&self, off: usize, len: usize) -> RowView<'a> {
        if self.has_composite() {
            return RowView::Composite { v: *self, off };
        }
        match self.dir {
            None => RowView::Plain(&self.base[off..off + len]),
            Some(d) => RowView::Perturbed { b: &self.base[off..off + len], z: &d[off..off + len], sc: self.scale },
        }
    }

    /// Write the viewed values into `out` (the materialized reference the
    /// bit-identity tests compare against; cold paths only — the point of
    /// the view is NOT doing this on the step path).
    pub fn materialize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        if self.has_composite() {
            for (i, o) in out.iter_mut().enumerate() {
                *o = self.at(i);
            }
            return;
        }
        match self.dir {
            Some(d) => axpy_into(self.scale, d, self.base, out),
            None => out.copy_from_slice(self.base),
        }
    }
}

/// One contiguous row of a [`ParamView`] with the plain/perturbed/composite
/// dispatch resolved ONCE instead of per element. `at(j)` evaluates the
/// exact expression [`ParamView::at`] evaluates (same order, no FMA), so
/// routing a per-element loop through a `RowView` cannot change bits.
#[derive(Clone, Copy, Debug)]
pub enum RowView<'a> {
    /// Unperturbed slice: `at(j) = b[j]`.
    Plain(&'a [f32]),
    /// Dense perturbation: `at(j) = b[j] + sc * z[j]`.
    Perturbed { b: &'a [f32], z: &'a [f32], sc: f32 },
    /// Composite (adapter deltas and/or whole-buffer binding): `at(j)`
    /// falls back to `v.at(off + j)`.
    Composite { v: ParamView<'a>, off: usize },
}

impl RowView<'_> {
    /// Element `j` of the row.
    #[inline(always)]
    pub fn at(&self, j: usize) -> f32 {
        match self {
            RowView::Plain(b) => b[j],
            RowView::Perturbed { b, z, sc } => b[j] + sc * z[j],
            RowView::Composite { v, off } => v.at(off + j),
        }
    }
}

/// y <- y + a * x (BLAS axpy).
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * *xi;
    }
}

/// out <- x + a * z, writing into a separate buffer. The expression (one
/// f32 multiply, one f32 add, no FMA) is THE perturbation contract every
/// fused view kernel reproduces.
pub fn axpy_into(a: f32, z: &[f32], x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), z.len());
    assert_eq!(x.len(), out.len());
    if simd::enabled() {
        unsafe { simd::axpy_into(a, z, x, out) }
    } else {
        axpy_into_scalar(a, z, x, out);
    }
}

/// Scalar body of [`axpy_into`] (the always-compiled fallback).
pub(crate) fn axpy_into_scalar(a: f32, z: &[f32], x: &[f32], out: &mut [f32]) {
    for i in 0..x.len() {
        out[i] = x[i] + a * z[i];
    }
}

/// <x, y> with f64 accumulation (stable for d up to ~10^8).
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    // 4-way unrolled accumulators help LLVM keep the pipeline full
    let mut acc = [0f64; 4];
    let chunks = x.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += x[i] as f64 * y[i] as f64;
        acc[1] += x[i + 1] as f64 * y[i + 1] as f64;
        acc[2] += x[i + 2] as f64 * y[i + 2] as f64;
        acc[3] += x[i + 3] as f64 * y[i + 3] as f64;
    }
    let mut tail = 0f64;
    for i in chunks * 4..x.len() {
        tail += x[i] as f64 * y[i] as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// Euclidean norm.
pub fn nrm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// x <- a * x.
pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// The cone construction of Algorithm 1 (host-side twin of the Pallas
/// kernel): z <- sqrt(d_raw) * cos(theta)/||m|| * m + sin(theta) * u.
/// `m` must be zero on pad lanes; `z`'s pad lanes are zeroed explicitly.
pub fn cone_direction(m: &[f32], u: &[f32], theta: f32, d_raw: usize, z: &mut [f32]) {
    assert_eq!(m.len(), u.len());
    assert_eq!(m.len(), z.len());
    assert!(d_raw <= m.len());
    let mnorm = nrm2(m).max(1e-30) as f32;
    let cs = (d_raw as f32).sqrt() * theta.cos() / mnorm;
    let sn = theta.sin();
    for i in 0..d_raw {
        z[i] = cs * m[i] + sn * u[i];
    }
    for zi in z[d_raw..].iter_mut() {
        *zi = 0.0;
    }
}

/// Fused ConMeZO update (host twin of the Pallas `zo_update`):
/// x <- x - eta*g*z ; m <- beta*m + (1-beta)*g*z, one pass.
pub fn zo_update(x: &mut [f32], m: &mut [f32], z: &[f32], g: f32, eta: f32, beta: f32) {
    assert_eq!(x.len(), z.len());
    assert_eq!(m.len(), z.len());
    let ce = eta * g;
    let cm = (1.0 - beta) * g;
    for i in 0..x.len() {
        let zi = z[i];
        x[i] -= ce * zi;
        m[i] = beta * m[i] + cm * zi;
    }
}

/// Per-coordinate scaled perturbation used by HiZOO: out = x + a * s * z
/// where `s` is a per-coordinate scale vector (Sigma^{1/2}).
pub fn axpy_scaled(a: f32, s: &[f32], z: &[f32], x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), z.len());
    assert_eq!(x.len(), s.len());
    for i in 0..x.len() {
        out[i] = x[i] + a * s[i] * z[i];
    }
}

/// cos^2 of the angle between two vectors ((m^T g)^2 / (||m||^2 ||g||^2)).
pub fn cos2(a: &[f32], b: &[f32]) -> f64 {
    let num = dot(a, b);
    let den = (dot(a, a) * dot(b, b)).max(1e-60);
    num * num / den
}

// ---------------------------------------------------------------------------
// Dense kernels for the native transformer forward (runtime::model).
// ---------------------------------------------------------------------------

/// Micro-tile height of the blocked [`matmul`]: rows of `a` processed
/// together so each `b` row loaded from cache is reused MR times.
const MATMUL_MR: usize = 4;
/// Micro-tile width: the accumulator tile `MATMUL_MR x MATMUL_NR` lives in
/// registers/L1 across the whole k-loop.
const MATMUL_NR: usize = 64;

/// Minimum per-participant MAC count before a threaded kernel actually
/// dispatches onto the pool: below this, wake-up/synchronization overhead
/// dominates the kernel itself (nano/tiny-preset GEMMs always stay
/// single-threaded).
pub(crate) const PAR_MIN_MACS_PER_THREAD: usize = 1 << 18;

/// Effective participant count for a row-parallel kernel over `rows` units
/// of work with `macs_per_row` multiply-accumulates each. Shared by the
/// GEMMs here and the attention dispatches in `runtime::model` /
/// `runtime::autograd` ((batch, head, query-block) units on the streaming
/// forward, whole (batch, head) pairs elsewhere).
pub(crate) fn effective_threads(threads: usize, rows: usize, macs_per_row: usize) -> usize {
    if threads <= 1 || rows == 0 {
        return 1;
    }
    let by_work = (rows.saturating_mul(macs_per_row) / PAR_MIN_MACS_PER_THREAD).max(1);
    threads.min(rows).min(by_work)
}

/// Split `out` into `t` contiguous row-chunks and run `span` on each from
/// the persistent worker pool (one chunk per participant — zero thread
/// spawns, zero allocation on the dispatch path). Every output element is
/// written by exactly one task with the identical per-element accumulation
/// order the single-threaded kernel uses, so the result is bit-identical
/// for every pool size.
fn par_rows(
    out: &mut [f32],
    rows: usize,
    n: usize,
    t: usize,
    pool: &WorkerPool,
    span: impl Fn(usize, usize, &mut [f32]) + Sync,
) {
    // every pooled GEMM variant funnels through here, so one timer guard
    // covers the whole family (timing only; the math is untouched)
    let _gemm_t = pool.telemetry().and_then(|r| r.timer(&r.gemm));
    if t <= 1 {
        span(0, rows, out);
        return;
    }
    debug_assert_eq!(out.len(), rows * n);
    let base = rows / t;
    let extra = rows % t;
    let ptr = SendPtr(out.as_mut_ptr());
    pool.run(t, t, &|chunk| {
        // the same contiguous partition the scoped implementation used:
        // the first `extra` chunks carry one extra row
        let row0 = chunk * base + chunk.min(extra);
        let chunk_rows = base + usize::from(chunk < extra);
        if chunk_rows == 0 {
            return;
        }
        let slice = unsafe { ptr.slice_mut(row0 * n, chunk_rows * n) };
        span(row0, chunk_rows, slice);
    });
}

/// Rows `row0..row0+rows` of a[m, k] @ b[k, n]; `out` holds exactly that
/// row range. The register-blocked core shared by [`matmul`] and
/// [`matmul_threaded`]; dispatches to the AVX2 twin when [`simd::enabled`].
fn matmul_span(a: &[f32], b: &[f32], k: usize, n: usize, row0: usize, rows: usize, out: &mut [f32]) {
    if simd::enabled() {
        unsafe { simd::matmul_span(a, b, k, n, row0, rows, out) }
    } else {
        matmul_span_scalar(a, b, k, n, row0, rows, out);
    }
}

/// Scalar body of [`matmul_span`].
pub(crate) fn matmul_span_scalar(a: &[f32], b: &[f32], k: usize, n: usize, row0: usize, rows: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), rows * n);
    let mut acc = [[0f32; MATMUL_NR]; MATMUL_MR];
    let mut j0 = 0;
    while j0 < n {
        let nb = MATMUL_NR.min(n - j0);
        let mut i0 = 0;
        while i0 + MATMUL_MR <= rows {
            for row in acc.iter_mut() {
                row[..nb].fill(0.0);
            }
            for p in 0..k {
                let brow = &b[p * n + j0..p * n + j0 + nb];
                for (rr, row) in acc.iter_mut().enumerate() {
                    let av = a[(row0 + i0 + rr) * k + p];
                    for (o, &bv) in row[..nb].iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
            for (rr, row) in acc.iter().enumerate() {
                out[(i0 + rr) * n + j0..(i0 + rr) * n + j0 + nb].copy_from_slice(&row[..nb]);
            }
            i0 += MATMUL_MR;
        }
        // remainder rows: plain saxpy over the same j-tile
        for i in i0..rows {
            let orow = &mut out[i * n + j0..i * n + j0 + nb];
            orow.fill(0.0);
            for p in 0..k {
                let av = a[(row0 + i) * k + p];
                let brow = &b[p * n + j0..p * n + j0 + nb];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        j0 += nb;
    }
}

/// [`matmul_span`] with the weight operand perturbed in-register: every
/// load of `w` becomes `w[i] + sc * z[i]` (the exact expression
/// [`axpy_into`] materializes, evaluated per element before the multiply),
/// with the identical tile walk and per-element accumulation order — so
/// the result is bit-identical to materializing `w + sc z` and running
/// [`matmul_span`], without the `d`-sized write. The perturbed j-tile is
/// hoisted into a register/L1 temp once per `p` and reused by all
/// `MATMUL_MR` accumulator rows (the recompute would be deterministic and
/// identical anyway, so hoisting cannot change bits).
#[allow(clippy::too_many_arguments)]
fn matmul_span_fused(
    a: &[f32],
    w: &[f32],
    z: &[f32],
    sc: f32,
    k: usize,
    n: usize,
    row0: usize,
    rows: usize,
    out: &mut [f32],
) {
    if simd::enabled() {
        unsafe { simd::matmul_span_fused(a, w, z, sc, k, n, row0, rows, out) }
    } else {
        matmul_span_fused_scalar(a, w, z, sc, k, n, row0, rows, out);
    }
}

/// Scalar body of [`matmul_span_fused`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_span_fused_scalar(
    a: &[f32],
    w: &[f32],
    z: &[f32],
    sc: f32,
    k: usize,
    n: usize,
    row0: usize,
    rows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(w.len(), z.len());
    let mut acc = [[0f32; MATMUL_NR]; MATMUL_MR];
    let mut wtile = [0f32; MATMUL_NR];
    let mut j0 = 0;
    while j0 < n {
        let nb = MATMUL_NR.min(n - j0);
        let mut i0 = 0;
        while i0 + MATMUL_MR <= rows {
            for row in acc.iter_mut() {
                row[..nb].fill(0.0);
            }
            for p in 0..k {
                let wrow = &w[p * n + j0..p * n + j0 + nb];
                let zrow = &z[p * n + j0..p * n + j0 + nb];
                for ((t, &wv), &zv) in wtile[..nb].iter_mut().zip(wrow).zip(zrow) {
                    *t = wv + sc * zv;
                }
                for (rr, row) in acc.iter_mut().enumerate() {
                    let av = a[(row0 + i0 + rr) * k + p];
                    for (o, &wv) in row[..nb].iter_mut().zip(&wtile[..nb]) {
                        *o += av * wv;
                    }
                }
            }
            for (rr, row) in acc.iter().enumerate() {
                out[(i0 + rr) * n + j0..(i0 + rr) * n + j0 + nb].copy_from_slice(&row[..nb]);
            }
            i0 += MATMUL_MR;
        }
        // remainder rows: plain saxpy over the same j-tile
        for i in i0..rows {
            let orow = &mut out[i * n + j0..i * n + j0 + nb];
            orow.fill(0.0);
            for p in 0..k {
                let av = a[(row0 + i) * k + p];
                let wrow = &w[p * n + j0..p * n + j0 + nb];
                let zrow = &z[p * n + j0..p * n + j0 + nb];
                for ((o, &wv), &zv) in orow.iter_mut().zip(wrow).zip(zrow) {
                    *o += av * (wv + sc * zv);
                }
            }
        }
        j0 += nb;
    }
}

/// out[m, n] = a[m, k] @ b[k, n], all row-major, register-blocked: a
/// `MATMUL_MR x MATMUL_NR` accumulator tile is filled across the full inner
/// dimension before touching `out`, so `b`'s rows are read once per
/// MR-row-group instead of once per row (the forward/backward GEMM hot
/// path; `cargo bench optimizer_math` tracks naive-vs-blocked throughput).
///
/// Per output element the flop order is identical to the naive (i, p, j)
/// saxpy loop — p ascending from a zero accumulator — so results are
/// bit-stable against the pre-blocking implementation.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    matmul_span(a, b, k, n, 0, m, out);
}

/// [`matmul`] parallelized over output rows on the persistent
/// [`WorkerPool`] (the `ParallelPolicy`-sized pool flows here from the
/// runtime). Each task runs the identical blocked kernel on a disjoint row
/// range, so the result is bit-identical to [`matmul`] for every pool
/// size; tiny shapes fall back to the single-threaded path (see
/// [`PAR_MIN_MACS_PER_THREAD`]).
pub fn matmul_threaded(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], pool: &WorkerPool) {
    matmul_view_threaded(a, ParamView::plain(b), m, k, n, out, pool);
}

/// [`matmul_threaded`] with the weight operand behind a [`ParamView`]:
/// `out = a @ (b.base + b.scale * b.dir)` with the perturbation fused into
/// the weight loads (no materialized `b`). A plain view runs the unfused
/// kernel; a perturbed view runs [`matmul_span_fused`], which keeps the
/// identical per-element accumulation order, so results are bit-identical
/// to materialize-then-[`matmul`] at every pool size.
pub fn matmul_view_threaded(
    a: &[f32],
    b: ParamView<'_>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    pool: &WorkerPool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    let t = effective_threads(pool.threads(), m, k * n);
    if b.has_composite() {
        par_rows(out, m, n, t, pool, |row0, rows, chunk| {
            matmul_span_view(a, b, k, n, row0, rows, chunk)
        });
        return;
    }
    match b.dir() {
        None => {
            let w = b.base();
            par_rows(out, m, n, t, pool, |row0, rows, chunk| {
                matmul_span(a, w, k, n, row0, rows, chunk)
            });
        }
        Some((z, sc)) => {
            let w = b.base();
            par_rows(out, m, n, t, pool, |row0, rows, chunk| {
                matmul_span_fused(a, w, z, sc, k, n, row0, rows, chunk)
            });
        }
    }
}

/// [`matmul_span`] with the weight operand behind a composite
/// [`ParamView`] (low-rank adapter delta and/or dense add): every weight
/// load is `w.at(idx)`, hoisted into the same per-`p` j-tile temp as
/// [`matmul_span_fused`], with the identical tile walk and per-element
/// accumulation order — bit-identical to materializing the effective
/// weights and running [`matmul_span`].
fn matmul_span_view(
    a: &[f32],
    w: ParamView<'_>,
    k: usize,
    n: usize,
    row0: usize,
    rows: usize,
    out: &mut [f32],
) {
    if simd::enabled() {
        unsafe { simd::matmul_span_view(a, w, k, n, row0, rows, out) }
    } else {
        matmul_span_view_scalar(a, w, k, n, row0, rows, out);
    }
}

/// Scalar body of [`matmul_span_view`]. The per-`p` weight tile reads
/// through [`ParamView::row`] so the plain/perturbed/composite dispatch is
/// hoisted out of the element loop.
pub(crate) fn matmul_span_view_scalar(
    a: &[f32],
    w: ParamView<'_>,
    k: usize,
    n: usize,
    row0: usize,
    rows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(w.len(), k * n);
    let mut acc = [[0f32; MATMUL_NR]; MATMUL_MR];
    let mut wtile = [0f32; MATMUL_NR];
    let mut j0 = 0;
    while j0 < n {
        let nb = MATMUL_NR.min(n - j0);
        let mut i0 = 0;
        while i0 + MATMUL_MR <= rows {
            for row in acc.iter_mut() {
                row[..nb].fill(0.0);
            }
            for p in 0..k {
                let wrow = w.row(p * n + j0, nb);
                for (jj, t) in wtile[..nb].iter_mut().enumerate() {
                    *t = wrow.at(jj);
                }
                for (rr, row) in acc.iter_mut().enumerate() {
                    let av = a[(row0 + i0 + rr) * k + p];
                    for (o, &wv) in row[..nb].iter_mut().zip(&wtile[..nb]) {
                        *o += av * wv;
                    }
                }
            }
            for (rr, row) in acc.iter().enumerate() {
                out[(i0 + rr) * n + j0..(i0 + rr) * n + j0 + nb].copy_from_slice(&row[..nb]);
            }
            i0 += MATMUL_MR;
        }
        // remainder rows: plain saxpy over the same j-tile
        for i in i0..rows {
            let orow = &mut out[i * n + j0..i * n + j0 + nb];
            orow.fill(0.0);
            for p in 0..k {
                let av = a[(row0 + i) * k + p];
                let wrow = w.row(p * n + j0, nb);
                for (jj, o) in orow.iter_mut().enumerate() {
                    *o += av * wrow.at(jj);
                }
            }
        }
        j0 += nb;
    }
}

/// out[k, n] = a[m, k]^T @ d[m, n] — the weight-gradient half of the
/// [`matmul`] grad pair. For `Y = X @ W` (X: [m, k], W: [k, n]):
/// `dW = matmul_at(X, dY)` and `dX = matmul_bt(dY, W)`. Overwrites `out`.
///
/// Register-blocked with the same `MATMUL_MR x MATMUL_NR` accumulator tile
/// as [`matmul`] (here the tile spans rows of `out`, accumulated across the
/// full m dimension and written once), so the backward GEMMs share the
/// forward's cache behavior instead of re-streaming `out` m times. Per
/// element the accumulation order is i ascending from zero.
pub fn matmul_at(a: &[f32], d: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(d.len(), m * n);
    assert_eq!(out.len(), k * n);
    matmul_at_span(a, d, m, k, n, 0, k, out);
}

/// [`matmul_at`] parallelized over the k output rows (see
/// [`matmul_threaded`] for the bit-identity contract).
pub fn matmul_at_threaded(a: &[f32], d: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], pool: &WorkerPool) {
    matmul_at_view_threaded(ParamView::plain(a), d, m, k, n, out, pool);
}

/// [`matmul_at_threaded`] with the transposed operand behind a
/// [`ParamView`]: `out = (a.base + a.scale * a.dir)^T @ d`, perturbation
/// fused into the `a` loads (same accumulation order — bit-identical to
/// materialize-then-[`matmul_at`] at every pool size).
pub fn matmul_at_view_threaded(
    a: ParamView<'_>,
    d: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    pool: &WorkerPool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(d.len(), m * n);
    assert_eq!(out.len(), k * n);
    let t = effective_threads(pool.threads(), k, m * n);
    if a.has_composite() {
        par_rows(out, k, n, t, pool, |p0, prows, chunk| {
            matmul_at_span_view(a, d, m, k, n, p0, prows, chunk)
        });
        return;
    }
    match a.dir() {
        None => {
            let w = a.base();
            par_rows(out, k, n, t, pool, |p0, prows, chunk| {
                matmul_at_span(w, d, m, k, n, p0, prows, chunk)
            });
        }
        Some((z, sc)) => {
            let w = a.base();
            par_rows(out, k, n, t, pool, |p0, prows, chunk| {
                matmul_at_span_fused(w, z, sc, d, m, k, n, p0, prows, chunk)
            });
        }
    }
}

/// [`matmul_at_span`] with the transposed operand behind a composite
/// [`ParamView`] (`a[idx] -> view.at(idx)` at load time; identical tile
/// walk and accumulation order as the unfused span).
#[allow(clippy::too_many_arguments)]
fn matmul_at_span_view(
    w: ParamView<'_>,
    d: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p_base: usize,
    prows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), prows * n);
    debug_assert_eq!(w.len(), m * k);
    let mut acc = [[0f32; MATMUL_NR]; MATMUL_MR];
    let mut j0 = 0;
    while j0 < n {
        let nb = MATMUL_NR.min(n - j0);
        let mut p0 = 0;
        while p0 + MATMUL_MR <= prows {
            for row in acc.iter_mut() {
                row[..nb].fill(0.0);
            }
            for i in 0..m {
                let drow = &d[i * n + j0..i * n + j0 + nb];
                for (rr, row) in acc.iter_mut().enumerate() {
                    let av = w.at(i * k + p_base + p0 + rr);
                    for (o, &dv) in row[..nb].iter_mut().zip(drow) {
                        *o += av * dv;
                    }
                }
            }
            for (rr, row) in acc.iter().enumerate() {
                out[(p0 + rr) * n + j0..(p0 + rr) * n + j0 + nb].copy_from_slice(&row[..nb]);
            }
            p0 += MATMUL_MR;
        }
        // remainder out-rows: accumulate the j-tile directly in place
        for p in p0..prows {
            let orow = &mut out[p * n + j0..p * n + j0 + nb];
            orow.fill(0.0);
            for i in 0..m {
                let av = w.at(i * k + p_base + p);
                let drow = &d[i * n + j0..i * n + j0 + nb];
                for (o, &dv) in orow.iter_mut().zip(drow) {
                    *o += av * dv;
                }
            }
        }
        j0 += nb;
    }
}

/// Output rows `p_base..p_base+prows` of a^T @ d; `out` holds exactly that
/// row range of the [k, n] result.
#[allow(clippy::too_many_arguments)]
fn matmul_at_span(a: &[f32], d: &[f32], m: usize, k: usize, n: usize, p_base: usize, prows: usize, out: &mut [f32]) {
    if simd::enabled() {
        unsafe { simd::matmul_at_span(a, d, m, k, n, p_base, prows, out) }
    } else {
        matmul_at_span_scalar(a, d, m, k, n, p_base, prows, out);
    }
}

/// Scalar body of [`matmul_at_span`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_at_span_scalar(
    a: &[f32],
    d: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p_base: usize,
    prows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), prows * n);
    let mut acc = [[0f32; MATMUL_NR]; MATMUL_MR];
    let mut j0 = 0;
    while j0 < n {
        let nb = MATMUL_NR.min(n - j0);
        let mut p0 = 0;
        while p0 + MATMUL_MR <= prows {
            for row in acc.iter_mut() {
                row[..nb].fill(0.0);
            }
            for i in 0..m {
                let drow = &d[i * n + j0..i * n + j0 + nb];
                for (rr, row) in acc.iter_mut().enumerate() {
                    let av = a[i * k + p_base + p0 + rr];
                    for (o, &dv) in row[..nb].iter_mut().zip(drow) {
                        *o += av * dv;
                    }
                }
            }
            for (rr, row) in acc.iter().enumerate() {
                out[(p0 + rr) * n + j0..(p0 + rr) * n + j0 + nb].copy_from_slice(&row[..nb]);
            }
            p0 += MATMUL_MR;
        }
        // remainder out-rows: accumulate the j-tile directly in place
        for p in p0..prows {
            let orow = &mut out[p * n + j0..p * n + j0 + nb];
            orow.fill(0.0);
            for i in 0..m {
                let av = a[i * k + p_base + p];
                let drow = &d[i * n + j0..i * n + j0 + nb];
                for (o, &dv) in orow.iter_mut().zip(drow) {
                    *o += av * dv;
                }
            }
        }
        j0 += nb;
    }
}

/// [`matmul_at_span`] with the transposed operand perturbed in-register
/// (`a[i] -> w[i] + sc * z[i]` at load time; identical tile walk and
/// accumulation order as the unfused span).
#[allow(clippy::too_many_arguments)]
fn matmul_at_span_fused(
    w: &[f32],
    z: &[f32],
    sc: f32,
    d: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p_base: usize,
    prows: usize,
    out: &mut [f32],
) {
    if simd::enabled() {
        unsafe { simd::matmul_at_span_fused(w, z, sc, d, m, k, n, p_base, prows, out) }
    } else {
        matmul_at_span_fused_scalar(w, z, sc, d, m, k, n, p_base, prows, out);
    }
}

/// Scalar body of [`matmul_at_span_fused`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_at_span_fused_scalar(
    w: &[f32],
    z: &[f32],
    sc: f32,
    d: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p_base: usize,
    prows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), prows * n);
    debug_assert_eq!(w.len(), z.len());
    let mut acc = [[0f32; MATMUL_NR]; MATMUL_MR];
    let mut j0 = 0;
    while j0 < n {
        let nb = MATMUL_NR.min(n - j0);
        let mut p0 = 0;
        while p0 + MATMUL_MR <= prows {
            for row in acc.iter_mut() {
                row[..nb].fill(0.0);
            }
            for i in 0..m {
                let drow = &d[i * n + j0..i * n + j0 + nb];
                for (rr, row) in acc.iter_mut().enumerate() {
                    let idx = i * k + p_base + p0 + rr;
                    let av = w[idx] + sc * z[idx];
                    for (o, &dv) in row[..nb].iter_mut().zip(drow) {
                        *o += av * dv;
                    }
                }
            }
            for (rr, row) in acc.iter().enumerate() {
                out[(p0 + rr) * n + j0..(p0 + rr) * n + j0 + nb].copy_from_slice(&row[..nb]);
            }
            p0 += MATMUL_MR;
        }
        // remainder out-rows: accumulate the j-tile directly in place
        for p in p0..prows {
            let orow = &mut out[p * n + j0..p * n + j0 + nb];
            orow.fill(0.0);
            for i in 0..m {
                let idx = i * k + p_base + p;
                let av = w[idx] + sc * z[idx];
                let drow = &d[i * n + j0..i * n + j0 + nb];
                for (o, &dv) in orow.iter_mut().zip(drow) {
                    *o += av * dv;
                }
            }
        }
        j0 += nb;
    }
}

/// out[m, n] = a[m, k] @ bt[n, k]^T — `bt` stores the TRANSPOSE of b
/// row-major (e.g. the tied LM head: logits = x @ tok_emb^T with tok_emb
/// stored [vocab, d_model]). Inner loop is a dot of two contiguous rows.
pub fn matmul_bt(a: &[f32], bt: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    matmul_bt_span(a, bt, k, n, 0, m, out);
}

/// [`matmul_bt`] parallelized over output rows (see [`matmul_threaded`] for
/// the bit-identity contract). This is the LM-head GEMM — the widest matmul
/// of the forward — so it threads alongside the projection GEMMs.
pub fn matmul_bt_threaded(a: &[f32], bt: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], pool: &WorkerPool) {
    matmul_bt_view_threaded(a, ParamView::plain(bt), m, k, n, out, pool);
}

/// [`matmul_bt_threaded`] with the transposed weight operand behind a
/// [`ParamView`]: `out = a @ (bt.base + bt.scale * bt.dir)^T`, perturbation
/// fused into the weight loads (the tied-LM-head path of the perturbed
/// forward; same accumulation order — bit-identical to
/// materialize-then-[`matmul_bt`] at every pool size).
pub fn matmul_bt_view_threaded(
    a: &[f32],
    bt: ParamView<'_>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    pool: &WorkerPool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    let t = effective_threads(pool.threads(), m, k * n);
    if bt.has_composite() {
        par_rows(out, m, n, t, pool, |row0, rows, chunk| {
            matmul_bt_span_view(a, bt, k, n, row0, rows, chunk)
        });
        return;
    }
    match bt.dir() {
        None => {
            let w = bt.base();
            par_rows(out, m, n, t, pool, |row0, rows, chunk| {
                matmul_bt_span(a, w, k, n, row0, rows, chunk)
            });
        }
        Some((z, sc)) => {
            let w = bt.base();
            par_rows(out, m, n, t, pool, |row0, rows, chunk| {
                matmul_bt_span_fused(a, w, z, sc, k, n, row0, rows, chunk)
            });
        }
    }
}

/// [`matmul_bt_span`] with the transposed operand behind a composite
/// [`ParamView`]: each output column hoists one [`ParamView::row`] over
/// `bt`'s row `j` (row `j` of the [n, k] storage IS column `j` of `b`) so
/// the composite dispatch runs once per column instead of once per element;
/// the dot accumulates p ascending exactly like the unfused span. Stays
/// scalar: the dispatcher only routes composite views here, and the packed
/// composite kernel covers the SIMD case.
fn matmul_bt_span_view(
    a: &[f32],
    bt: ParamView<'_>,
    k: usize,
    n: usize,
    row0: usize,
    rows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(bt.len(), n * k);
    for i in 0..rows {
        let arow = &a[(row0 + i) * k..(row0 + i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = bt.row(j * k, k);
            let mut acc = 0f32;
            for (p, &av) in arow.iter().enumerate() {
                acc += av * brow.at(p);
            }
            orow[j] = acc;
        }
    }
}

/// Rows `row0..row0+rows` of a @ bt^T; `out` holds exactly that row range.
fn matmul_bt_span(a: &[f32], bt: &[f32], k: usize, n: usize, row0: usize, rows: usize, out: &mut [f32]) {
    if simd::enabled() {
        unsafe { simd::matmul_bt_span(a, bt, k, n, row0, rows, out) }
    } else {
        matmul_bt_span_scalar(a, bt, k, n, row0, rows, out);
    }
}

/// Scalar body of [`matmul_bt_span`].
pub(crate) fn matmul_bt_span_scalar(a: &[f32], bt: &[f32], k: usize, n: usize, row0: usize, rows: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), rows * n);
    for i in 0..rows {
        let arow = &a[(row0 + i) * k..(row0 + i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &bt[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            orow[j] = acc;
        }
    }
}

/// [`matmul_bt_span`] with the transposed operand perturbed in-register
/// (`bt[i] -> w[i] + sc * z[i]` at load time; the dot accumulates p
/// ascending exactly like the unfused span).
#[allow(clippy::too_many_arguments)]
fn matmul_bt_span_fused(
    a: &[f32],
    w: &[f32],
    z: &[f32],
    sc: f32,
    k: usize,
    n: usize,
    row0: usize,
    rows: usize,
    out: &mut [f32],
) {
    if simd::enabled() {
        unsafe { simd::matmul_bt_span_fused(a, w, z, sc, k, n, row0, rows, out) }
    } else {
        matmul_bt_span_fused_scalar(a, w, z, sc, k, n, row0, rows, out);
    }
}

/// Scalar body of [`matmul_bt_span_fused`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_bt_span_fused_scalar(
    a: &[f32],
    w: &[f32],
    z: &[f32],
    sc: f32,
    k: usize,
    n: usize,
    row0: usize,
    rows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(w.len(), z.len());
    for i in 0..rows {
        let arow = &a[(row0 + i) * k..(row0 + i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let wrow = &w[j * k..(j + 1) * k];
            let zrow = &z[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for ((&av, &wv), &zv) in arow.iter().zip(wrow).zip(zrow) {
                acc += av * (wv + sc * zv);
            }
            orow[j] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Packed B-side weight panels.
//
// A GEMM's B operand is read k*m times per call but the scalar spans
// re-stride it from row-major every time (stride n for [k, n] weights,
// stride k column gathers for the transposed LM head). Since model weights
// survive thousands of calls, `runtime::model` re-strides each 2-D weight
// ONCE per top-level call (once per antithetic pair) into the panel layout
// below, and the packed kernels stream contiguous cache lines.
// ---------------------------------------------------------------------------

/// Which row-major storage a packed panel was built from — decides how a
/// composite [`ParamView`]'s flat element index is reconstructed when
/// fusing adapter deltas on top of packed base values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackForm {
    /// Packed from `b[k, n]` (element `p*n + j`).
    B,
    /// Packed from `bt[n, k]`, the transposed storage (element `j*k + p`).
    Bt,
}

/// Length of the packed panel buffer for a `[k, n]`-shaped B operand:
/// `ceil(n / MATMUL_NR)` panels of `MATMUL_NR * k` elements. Tail panels
/// are zero-padded to the full width so the SIMD kernels can always load
/// whole vectors.
pub fn packed_len(k: usize, n: usize) -> usize {
    n.div_ceil(MATMUL_NR) * MATMUL_NR * k
}

/// Pack `src[k, n]` (row-major) into column panels:
/// `dst[jt*NR*k + p*NR + jj] = src[p*n + jt*NR + jj]`. Pad lanes of a tail
/// panel are never written — callers hand in zero-initialized buffers and
/// the pads stay zero across repacks (the geometry never changes after
/// bind). A pure permutation copy: packed values are bit-exact base values.
pub fn pack_b(src: &[f32], k: usize, n: usize, dst: &mut [f32]) {
    assert_eq!(src.len(), k * n);
    assert_eq!(dst.len(), packed_len(k, n));
    let mut jt = 0;
    let mut j0 = 0;
    while j0 < n {
        let nb = MATMUL_NR.min(n - j0);
        let tb = jt * MATMUL_NR * k;
        for p in 0..k {
            let srow = &src[p * n + j0..p * n + j0 + nb];
            dst[tb + p * MATMUL_NR..tb + p * MATMUL_NR + nb].copy_from_slice(srow);
        }
        j0 += nb;
        jt += 1;
    }
}

/// Pack `src[n, k]` (the TRANSPOSED storage, e.g. the tied LM head's
/// `[vocab, d_model]` embedding) into the SAME panel layout as [`pack_b`]:
/// `dst[jt*NR*k + p*NR + jj] = src[(jt*NR + jj)*k + p]`. One microkernel
/// then serves both operand forms — and the transposed GEMM's k-strided
/// column gathers become contiguous panel loads.
pub fn pack_bt(src: &[f32], k: usize, n: usize, dst: &mut [f32]) {
    assert_eq!(src.len(), n * k);
    assert_eq!(dst.len(), packed_len(k, n));
    let mut jt = 0;
    let mut j0 = 0;
    while j0 < n {
        let nb = MATMUL_NR.min(n - j0);
        let tb = jt * MATMUL_NR * k;
        for jj in 0..nb {
            let srow = &src[(j0 + jj) * k..(j0 + jj + 1) * k];
            for (p, &v) in srow.iter().enumerate() {
                dst[tb + p * MATMUL_NR + jj] = v;
            }
        }
        j0 += nb;
        jt += 1;
    }
}

/// A packed B operand for [`matmul_packed_view_threaded`], mirroring the
/// three [`ParamView`] dispatch arms.
#[derive(Clone, Copy, Debug)]
pub enum PackedB<'a> {
    /// Unperturbed packed panels.
    Plain(&'a [f32]),
    /// Base and direction both packed (one pack amortizes over both ±λ
    /// arms of a pair); the effective panel value `w + sc*z` is fused
    /// in-register with the exact [`axpy_into`] expression.
    Perturbed { w: &'a [f32], z: &'a [f32], sc: f32 },
    /// Packed base with a composite [`ParamView`]'s deltas (adapter
    /// low-rank/dense, plus any perturbation) fused on top via
    /// [`ParamView::at_with_base`]; `form` reconstructs the flat element
    /// index the deltas are addressed by.
    Composite { w: &'a [f32], view: ParamView<'a>, form: PackForm },
}

/// [`matmul_view_threaded`] over a pre-packed B operand: rows of
/// `out[m, n] = a[m, k] @ B` split across the pool, each task running the
/// packed span kernel. Bit-identical to the unpacked kernels for every
/// arm and pool size (packing is a permutation copy; the per-element
/// accumulation order is unchanged) — pinned by
/// `packed_gemms_match_unpacked_across_pool_sizes`.
pub fn matmul_packed_view_threaded(
    a: &[f32],
    pk: PackedB<'_>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    pool: &WorkerPool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(out.len(), m * n);
    let plen = packed_len(k, n);
    match pk {
        PackedB::Plain(w) => assert_eq!(w.len(), plen),
        PackedB::Perturbed { w, z, .. } => {
            assert_eq!(w.len(), plen);
            assert_eq!(z.len(), plen);
        }
        PackedB::Composite { w, .. } => assert_eq!(w.len(), plen),
    }
    let t = effective_threads(pool.threads(), m, k * n);
    par_rows(out, m, n, t, pool, |row0, rows, chunk| {
        matmul_span_packed(a, &pk, k, n, row0, rows, chunk)
    });
}

/// Row span of the packed GEMM; dispatches to the AVX2 twin when
/// [`simd::enabled`].
fn matmul_span_packed(a: &[f32], pk: &PackedB<'_>, k: usize, n: usize, row0: usize, rows: usize, out: &mut [f32]) {
    if simd::enabled() {
        unsafe { simd::matmul_span_packed(a, pk, k, n, row0, rows, out) }
    } else {
        matmul_span_packed_scalar(a, pk, k, n, row0, rows, out);
    }
}

/// Scalar body of [`matmul_span_packed`]: the [`matmul_span_fused`] tile
/// walk with the per-`p` weight tile read from a packed panel (plain copy,
/// fused `w + sc*z`, or composite [`ParamView::at_with_base`] — never
/// touching pad lanes past `nb`).
pub(crate) fn matmul_span_packed_scalar(
    a: &[f32],
    pk: &PackedB<'_>,
    k: usize,
    n: usize,
    row0: usize,
    rows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * n);
    let mut acc = [[0f32; MATMUL_NR]; MATMUL_MR];
    let mut wtile = [0f32; MATMUL_NR];
    let mut j0 = 0;
    let mut jt = 0;
    while j0 < n {
        let nb = MATMUL_NR.min(n - j0);
        let tb = jt * MATMUL_NR * k;
        let fill = |p: usize, wtile: &mut [f32; MATMUL_NR]| match *pk {
            PackedB::Plain(w) => {
                wtile[..nb].copy_from_slice(&w[tb + p * MATMUL_NR..tb + p * MATMUL_NR + nb]);
            }
            PackedB::Perturbed { w, z, sc } => {
                for (jj, t) in wtile[..nb].iter_mut().enumerate() {
                    let e = tb + p * MATMUL_NR + jj;
                    *t = w[e] + sc * z[e];
                }
            }
            PackedB::Composite { w, view, form } => {
                for (jj, t) in wtile[..nb].iter_mut().enumerate() {
                    let e = match form {
                        PackForm::B => p * n + j0 + jj,
                        PackForm::Bt => (j0 + jj) * k + p,
                    };
                    *t = view.at_with_base(w[tb + p * MATMUL_NR + jj], e);
                }
            }
        };
        let mut i0 = 0;
        while i0 + MATMUL_MR <= rows {
            for row in acc.iter_mut() {
                row[..nb].fill(0.0);
            }
            for p in 0..k {
                fill(p, &mut wtile);
                for (rr, row) in acc.iter_mut().enumerate() {
                    let av = a[(row0 + i0 + rr) * k + p];
                    for (o, &wv) in row[..nb].iter_mut().zip(&wtile[..nb]) {
                        *o += av * wv;
                    }
                }
            }
            for (rr, row) in acc.iter().enumerate() {
                out[(i0 + rr) * n + j0..(i0 + rr) * n + j0 + nb].copy_from_slice(&row[..nb]);
            }
            i0 += MATMUL_MR;
        }
        // remainder rows: plain saxpy over the same j-tile
        for i in i0..rows {
            let orow = &mut out[i * n + j0..i * n + j0 + nb];
            orow.fill(0.0);
            for p in 0..k {
                fill(p, &mut wtile);
                let av = a[(row0 + i) * k + p];
                for (o, &wv) in orow.iter_mut().zip(&wtile[..nb]) {
                    *o += av * wv;
                }
            }
        }
        j0 += nb;
        jt += 1;
    }
}

/// Row-wise softmax in place over an [rows, cols] buffer (max-subtracted).
/// The max scan and the exp/denominator pass are sequential dependence
/// chains and stay scalar; only the final rescale vectorizes (see
/// [`scale_in_place`]).
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols);
    for i in 0..rows {
        let row = &mut x[i * cols..(i + 1) * cols];
        let mut maxv = f32::NEG_INFINITY;
        for &v in row.iter() {
            if v > maxv {
                maxv = v;
            }
        }
        let mut denom = 0f32;
        for v in row.iter_mut() {
            *v = (*v - maxv).exp();
            denom += *v;
        }
        let inv = 1.0 / denom;
        scale_in_place(row, inv);
    }
}

/// `row[j] *= inv` for every element — the vectorizable tail of
/// [`softmax_rows`] (independent elements, one multiply each).
fn scale_in_place(row: &mut [f32], inv: f32) {
    if simd::enabled() {
        unsafe { simd::scale_in_place(row, inv) }
    } else {
        scale_in_place_scalar(row, inv);
    }
}

/// Scalar body of [`scale_in_place`].
pub(crate) fn scale_in_place_scalar(row: &mut [f32], inv: f32) {
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Row-wise LayerNorm: out[i, :] = (x[i, :] - mu_i) / sqrt(var_i + eps) * g + b.
/// Mean/variance accumulate in f64 (matches the jax reference within f32
/// tolerance for all preset widths).
pub fn layernorm_rows(x: &[f32], g: &[f32], b: &[f32], rows: usize, cols: usize, eps: f32, out: &mut [f32]) {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(out.len(), rows * cols);
    assert_eq!(g.len(), cols);
    assert_eq!(b.len(), cols);
    for i in 0..rows {
        let row = &x[i * cols..(i + 1) * cols];
        let orow = &mut out[i * cols..(i + 1) * cols];
        let mut mean = 0f64;
        for &v in row {
            mean += v as f64;
        }
        mean /= cols as f64;
        let mut var = 0f64;
        for &v in row {
            let d = v as f64 - mean;
            var += d * d;
        }
        var /= cols as f64;
        let inv = 1.0 / (var + eps as f64).sqrt();
        let (mean, inv) = (mean as f32, inv as f32);
        ln_affine(row, g, b, mean, inv, orow);
    }
}

/// The affine step of one layernorm row:
/// `orow[j] = (row[j] - mean) * inv * g[j] + b[j]` (left-associated). The
/// f64 mean/variance reduction stays in the caller — only this
/// independent-element loop vectorizes.
fn ln_affine(row: &[f32], g: &[f32], b: &[f32], mean: f32, inv: f32, orow: &mut [f32]) {
    if simd::enabled() {
        unsafe { simd::layernorm_affine(row, g, b, mean, inv, orow) }
    } else {
        layernorm_affine_scalar(row, g, b, mean, inv, orow);
    }
}

/// Scalar body of [`ln_affine`].
pub(crate) fn layernorm_affine_scalar(row: &[f32], g: &[f32], b: &[f32], mean: f32, inv: f32, orow: &mut [f32]) {
    for j in 0..row.len() {
        orow[j] = (row[j] - mean) * inv * g[j] + b[j];
    }
}

/// [`layernorm_rows`] with the gain/bias behind [`ParamView`]s: the row
/// statistics come from the activation `x` exactly as in the plain kernel,
/// and the affine step reads `g`/`b` with the perturbation fused into each
/// load — bit-identical to materializing the perturbed gain/bias first.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_rows_view(
    x: &[f32],
    g: ParamView<'_>,
    b: ParamView<'_>,
    rows: usize,
    cols: usize,
    eps: f32,
    out: &mut [f32],
) {
    if !g.has_delta() && !b.has_delta() {
        return layernorm_rows(x, g.base(), b.base(), rows, cols, eps, out);
    }
    assert_eq!(x.len(), rows * cols);
    assert_eq!(out.len(), rows * cols);
    assert_eq!(g.len(), cols);
    assert_eq!(b.len(), cols);
    for i in 0..rows {
        let row = &x[i * cols..(i + 1) * cols];
        let orow = &mut out[i * cols..(i + 1) * cols];
        let mut mean = 0f64;
        for &v in row {
            mean += v as f64;
        }
        mean /= cols as f64;
        let mut var = 0f64;
        for &v in row {
            let d = v as f64 - mean;
            var += d * d;
        }
        var /= cols as f64;
        let inv = 1.0 / (var + eps as f64).sqrt();
        let (mean, inv) = (mean as f32, inv as f32);
        for j in 0..cols {
            orow[j] = (row[j] - mean) * inv * g.at(j) + b.at(j);
        }
    }
}

/// GELU (tanh approximation — the jax.nn.gelu default used by the L2 model),
/// applied in place. The SIMD twin vectorizes the polynomial halves and
/// keeps `tanh` scalar per element (same `f32::tanh` call).
pub fn gelu(x: &mut [f32]) {
    if simd::enabled() {
        unsafe { simd::gelu(x) }
    } else {
        gelu_scalar(x);
    }
}

/// Scalar body of [`gelu`].
pub(crate) fn gelu_scalar(x: &mut [f32]) {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    for v in x.iter_mut() {
        let t = *v;
        *v = 0.5 * t * (1.0 + (C * (t + 0.044715 * t * t * t)).tanh());
    }
}

/// x[i, :] += bias for every row of an [rows, cols] buffer.
pub fn add_bias_rows(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(bias.len(), cols);
    if simd::enabled() {
        unsafe { simd::add_bias_rows(x, bias, rows, cols) }
    } else {
        add_bias_rows_scalar(x, bias, rows, cols);
    }
}

/// Scalar body of [`add_bias_rows`].
pub(crate) fn add_bias_rows_scalar(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    for i in 0..rows {
        let row = &mut x[i * cols..(i + 1) * cols];
        for j in 0..cols {
            row[j] += bias[j];
        }
    }
}

/// [`add_bias_rows`] with the bias behind a [`ParamView`]: each row gains
/// `bias.base[j] + bias.scale * bias.dir[j]`, the perturbed value computed
/// per element before the add — bit-identical to materializing the bias
/// and calling [`add_bias_rows`]. The per-row recompute is deliberate:
/// this kernel is bound on the `x` stream (bias/dir stay L1-resident), and
/// hoisting the perturbed bias would need a heap temp on the
/// allocation-free step path.
pub fn add_bias_rows_view(x: &mut [f32], bias: ParamView<'_>, rows: usize, cols: usize) {
    if bias.has_composite() {
        assert_eq!(x.len(), rows * cols);
        assert_eq!(bias.len(), cols);
        for i in 0..rows {
            let row = &mut x[i * cols..(i + 1) * cols];
            for (j, v) in row.iter_mut().enumerate() {
                *v += bias.at(j);
            }
        }
        return;
    }
    match bias.dir() {
        None => add_bias_rows(x, bias.base(), rows, cols),
        Some((z, sc)) => {
            assert_eq!(x.len(), rows * cols);
            assert_eq!(bias.len(), cols);
            add_bias_rows_perturbed(x, bias.base(), z, sc, rows, cols);
        }
    }
}

/// The perturbed arm of [`add_bias_rows_view`]:
/// `row[j] += b[j] + sc * z[j]`, the fused value computed per element
/// before the add.
fn add_bias_rows_perturbed(x: &mut [f32], b: &[f32], z: &[f32], sc: f32, rows: usize, cols: usize) {
    if simd::enabled() {
        unsafe { simd::add_bias_rows_perturbed(x, b, z, sc, rows, cols) }
    } else {
        add_bias_rows_perturbed_scalar(x, b, z, sc, rows, cols);
    }
}

/// Scalar body of [`add_bias_rows_perturbed`].
pub(crate) fn add_bias_rows_perturbed_scalar(x: &mut [f32], b: &[f32], z: &[f32], sc: f32, rows: usize, cols: usize) {
    for i in 0..rows {
        let row = &mut x[i * cols..(i + 1) * cols];
        for j in 0..cols {
            row[j] += b[j] + sc * z[j];
        }
    }
}

// ---------------------------------------------------------------------------
// Backward kernels for the native reverse pass (runtime::autograd).
// ---------------------------------------------------------------------------

/// Bias gradient of [`add_bias_rows`]: dbias[j] = sum_i dy[i, j], with f64
/// column accumulators. Overwrites `dbias`.
pub fn add_bias_rows_backward(dy: &[f32], rows: usize, cols: usize, dbias: &mut [f32]) {
    assert_eq!(dy.len(), rows * cols);
    assert_eq!(dbias.len(), cols);
    for (j, db) in dbias.iter_mut().enumerate() {
        let mut acc = 0f64;
        for i in 0..rows {
            acc += dy[i * cols + j] as f64;
        }
        *db = acc as f32;
    }
}

/// Softmax backward given the FORWARD OUTPUT `y` (row-wise probabilities):
/// dx[i, :] = y[i, :] * (dy[i, :] - <dy[i, :], y[i, :]>). The inner product
/// accumulates in f64. `dx` may not alias `y`/`dy`; overwritten.
pub fn softmax_rows_backward(y: &[f32], dy: &[f32], rows: usize, cols: usize, dx: &mut [f32]) {
    assert_eq!(y.len(), rows * cols);
    assert_eq!(dy.len(), rows * cols);
    assert_eq!(dx.len(), rows * cols);
    for i in 0..rows {
        let yr = &y[i * cols..(i + 1) * cols];
        let dyr = &dy[i * cols..(i + 1) * cols];
        let inner = dot(dyr, yr) as f32;
        let dxr = &mut dx[i * cols..(i + 1) * cols];
        for j in 0..cols {
            dxr[j] = yr[j] * (dyr[j] - inner);
        }
    }
}

/// LayerNorm backward: recomputes the row statistics from the forward input
/// `x` (f64, bit-identical to [`layernorm_rows`]), then
///   dg[j]    = sum_i dy[i,j] * xhat[i,j]        (overwrite, f64 accum)
///   db[j]    = sum_i dy[i,j]                    (overwrite, f64 accum)
///   dx[i,:]  = inv_i * (dxhat - mean(dxhat) - xhat * mean(dxhat * xhat))
/// where dxhat = dy * g and xhat = (x - mu_i) * inv_i.
///
/// Allocating wrapper over [`layernorm_rows_backward_ws`] (tests /
/// one-shot callers); the first-order hot path passes the f64 column
/// accumulators from `GradWorkspace` instead.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_rows_backward(
    x: &[f32],
    g: &[f32],
    rows: usize,
    cols: usize,
    eps: f32,
    dy: &[f32],
    dx: &mut [f32],
    dg: &mut [f32],
    db: &mut [f32],
) {
    let mut dg64 = vec![0f64; cols];
    let mut db64 = vec![0f64; cols];
    layernorm_rows_backward_ws(x, g, rows, cols, eps, dy, dx, dg, db, &mut dg64, &mut db64);
}

/// [`layernorm_rows_backward`] over caller-owned f64 column accumulators
/// (`dg64`/`db64`, length `cols`, contents overwritten) — the autograd
/// reverse pass passes buffers bound once in its `GradWorkspace`, so the
/// first-order step path is allocation-free in steady state.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_rows_backward_ws(
    x: &[f32],
    g: &[f32],
    rows: usize,
    cols: usize,
    eps: f32,
    dy: &[f32],
    dx: &mut [f32],
    dg: &mut [f32],
    db: &mut [f32],
    dg64: &mut [f64],
    db64: &mut [f64],
) {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(dy.len(), rows * cols);
    assert_eq!(dx.len(), rows * cols);
    assert_eq!(g.len(), cols);
    assert_eq!(dg.len(), cols);
    assert_eq!(db.len(), cols);
    assert_eq!(dg64.len(), cols);
    assert_eq!(db64.len(), cols);
    dg64.fill(0.0);
    db64.fill(0.0);
    for i in 0..rows {
        let row = &x[i * cols..(i + 1) * cols];
        let dyr = &dy[i * cols..(i + 1) * cols];
        let mut mean = 0f64;
        for &v in row {
            mean += v as f64;
        }
        mean /= cols as f64;
        let mut var = 0f64;
        for &v in row {
            let d = v as f64 - mean;
            var += d * d;
        }
        var /= cols as f64;
        let inv = 1.0 / (var + eps as f64).sqrt();
        let (mean, inv) = (mean as f32, inv as f32);
        // row means of dxhat and dxhat * xhat (f64), plus dg/db columns
        let (mut m1, mut m2) = (0f64, 0f64);
        for j in 0..cols {
            let xhat = (row[j] - mean) * inv;
            let dxhat = dyr[j] * g[j];
            m1 += dxhat as f64;
            m2 += dxhat as f64 * xhat as f64;
            dg64[j] += dyr[j] as f64 * xhat as f64;
            db64[j] += dyr[j] as f64;
        }
        let m1 = (m1 / cols as f64) as f32;
        let m2 = (m2 / cols as f64) as f32;
        let dxr = &mut dx[i * cols..(i + 1) * cols];
        for j in 0..cols {
            let xhat = (row[j] - mean) * inv;
            let dxhat = dyr[j] * g[j];
            dxr[j] = inv * (dxhat - m1 - xhat * m2);
        }
    }
    for j in 0..cols {
        dg[j] = dg64[j] as f32;
        db[j] = db64[j] as f32;
    }
}

/// GELU backward (tanh approximation, matching [`gelu`]): dx = dy * g'(x)
/// with g'(x) = 0.5 (1 + tanh u) + 0.5 x (1 - tanh^2 u) * u'(x),
/// u = sqrt(2/pi) (x + 0.044715 x^3). `x` is the PRE-activation input.
pub fn gelu_backward(x: &[f32], dy: &[f32], dx: &mut [f32]) {
    assert_eq!(x.len(), dy.len());
    assert_eq!(x.len(), dx.len());
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    const A: f32 = 0.044715;
    for i in 0..x.len() {
        let t = x[i];
        let th = (C * (t + A * t * t * t)).tanh();
        let du = C * (1.0 + 3.0 * A * t * t);
        dx[i] = dy[i] * (0.5 * (1.0 + th) + 0.5 * t * (1.0 - th * th) * du);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0f32; n];
        r.fill_normal_f32(&mut v);
        v
    }

    #[test]
    fn dot_matches_naive() {
        let x = randv(1001, 1);
        let y = randv(1001, 2);
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| *a as f64 * *b as f64).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9);
    }

    #[test]
    fn axpy_matches_scalar() {
        let x = randv(37, 3);
        let mut y = randv(37, 4);
        let y0 = y.clone();
        axpy(0.5, &x, &mut y);
        for i in 0..37 {
            assert_eq!(y[i], y0[i] + 0.5 * x[i]);
        }
    }

    #[test]
    fn cone_direction_properties() {
        let d_pad = 2048;
        let d_raw = 2000;
        let mut m = randv(d_pad, 5);
        for v in m[d_raw..].iter_mut() {
            *v = 0.0;
        }
        let u = randv(d_pad, 6);
        let mut z = vec![0f32; d_pad];

        // theta = 0: z = sqrt(d) * m_hat
        cone_direction(&m, &u, 0.0, d_raw, &mut z);
        let mn = nrm2(&m);
        for i in 0..d_raw {
            let want = (d_raw as f64).sqrt() as f32 / mn as f32 * m[i];
            assert!((z[i] - want).abs() < 1e-4, "{} vs {}", z[i], want);
        }
        // pads zero
        assert!(z[d_raw..].iter().all(|&v| v == 0.0));

        // theta = pi/2: z = u on the valid lanes
        cone_direction(&m, &u, std::f32::consts::FRAC_PI_2, d_raw, &mut z);
        for i in 0..d_raw {
            assert!((z[i] - u[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn cone_norm_identity_with_unit_inputs() {
        // with u restricted to the sphere sqrt(d) S^{d-1} and orthogonal to
        // m, ||z||^2 == d exactly (Lemma 2 setting)
        let d = 4096;
        let m = randv(d, 7);
        let mut u = randv(d, 8);
        // orthogonalize then normalize to sqrt(d)
        let proj = (dot(&u, &m) / dot(&m, &m)) as f32;
        for i in 0..d {
            u[i] -= proj * m[i];
        }
        let s = ((d as f64).sqrt() / nrm2(&u)) as f32;
        scale(s, &mut u);
        let mut z = vec![0f32; d];
        cone_direction(&m, &u, 0.9, d, &mut z);
        let zz = dot(&z, &z);
        assert!((zz - d as f64).abs() / (d as f64) < 1e-4, "||z||^2 = {zz}");
    }

    #[test]
    fn zo_update_matches_reference() {
        let d = 515;
        let mut x = randv(d, 9);
        let mut m = randv(d, 10);
        let z = randv(d, 11);
        let (x0, m0) = (x.clone(), m.clone());
        let (g, eta, beta) = (1.7f32, 1e-3f32, 0.95f32);
        zo_update(&mut x, &mut m, &z, g, eta, beta);
        for i in 0..d {
            assert!((x[i] - (x0[i] - eta * g * z[i])).abs() < 1e-6);
            assert!((m[i] - (beta * m0[i] + (1.0 - beta) * g * z[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn cos2_bounds_and_self() {
        let a = randv(512, 12);
        let b = randv(512, 13);
        let c = cos2(&a, &b);
        assert!((0.0..=1.0).contains(&c));
        assert!((cos2(&a, &a) - 1.0).abs() < 1e-9);
        // scaled copies are perfectly aligned
        let mut a2 = a.clone();
        scale(-3.0, &mut a2);
        assert!((cos2(&a, &a2) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn axpy_scaled_matches_scalar() {
        let d = 64;
        let x = randv(d, 14);
        let z = randv(d, 15);
        let s = randv(d, 16);
        let mut out = vec![0f32; d];
        axpy_scaled(2.0, &s, &z, &x, &mut out);
        for i in 0..d {
            assert!((out[i] - (x[i] + 2.0 * s[i] * z[i])).abs() < 1e-6);
        }
    }

    // -----------------------------------------------------------------------
    // property-based coverage for the dense kernels (testing::property)
    // -----------------------------------------------------------------------

    use crate::testing::{property, Gen, Pair, UsizeRange};
    use crate::util::rng::Xoshiro256pp as Rng;

    /// (rows, cols, data) matrix generator.
    struct MatGen {
        max_rows: usize,
        max_cols: usize,
    }

    impl Gen for MatGen {
        type Value = (usize, usize, Vec<f32>);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            let r = 1 + rng.gen_range(self.max_rows);
            let c = 1 + rng.gen_range(self.max_cols);
            let mut v = vec![0f32; r * c];
            rng.fill_normal_f32(&mut v);
            (r, c, v)
        }
    }

    #[test]
    fn prop_softmax_rows_sum_to_one() {
        let g = MatGen { max_rows: 8, max_cols: 48 };
        property("softmax-normalizes", &g, 64, |(r, c, data)| {
            let mut x = data.clone();
            // widen the dynamic range to stress max-subtraction
            for v in x.iter_mut() {
                *v *= 30.0;
            }
            softmax_rows(&mut x, *r, *c);
            (0..*r).all(|i| {
                let row = &x[i * c..(i + 1) * c];
                let s: f64 = row.iter().map(|&v| v as f64).sum();
                (s - 1.0).abs() < 1e-4 && row.iter().all(|&v| (0.0..=1.0).contains(&v))
            })
        });
    }

    #[test]
    fn prop_layernorm_zero_mean_unit_var() {
        let g = MatGen { max_rows: 6, max_cols: 64 };
        property("layernorm-standardizes", &g, 64, |(r, c, data)| {
            if *c < 8 {
                return true; // eps dominates tiny rows; not the regime used
            }
            let gamma = vec![1f32; *c];
            let beta = vec![0f32; *c];
            let mut out = vec![0f32; r * c];
            layernorm_rows(data, &gamma, &beta, *r, *c, 1e-5, &mut out);
            (0..*r).all(|i| {
                let row = &out[i * c..(i + 1) * c];
                let mean: f64 = row.iter().map(|&v| v as f64).sum::<f64>() / *c as f64;
                let var: f64 =
                    row.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / *c as f64;
                mean.abs() < 1e-4 && (var - 1.0).abs() < 2e-2
            })
        });
    }

    #[test]
    fn prop_matmul_matches_naive_triple_loop() {
        // random (m, k, n) small shapes; compare against the j-outer naive
        // order, which exercises a different accumulation pattern
        let g = Pair(UsizeRange(1, 9), Pair(UsizeRange(1, 9), UsizeRange(1, 9)));
        property("matmul-naive", &g, 48, |&(m, (k, n))| {
            let mut rng = Rng::seed_from_u64((m * 97 + k * 13 + n) as u64);
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; k * n];
            rng.fill_normal_f32(&mut a);
            rng.fill_normal_f32(&mut b);
            let mut fast = vec![0f32; m * n];
            matmul(&a, &b, m, k, n, &mut fast);
            let mut bt = vec![0f32; n * k];
            for p in 0..k {
                for j in 0..n {
                    bt[j * k + p] = b[p * n + j];
                }
            }
            let mut fast_bt = vec![0f32; m * n];
            matmul_bt(&a, &bt, m, k, n, &mut fast_bt);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0f64;
                    for p in 0..k {
                        acc += a[i * k + p] as f64 * b[p * n + j] as f64;
                    }
                    let naive = acc as f32;
                    if (fast[i * n + j] - naive).abs() > 1e-4 * naive.abs().max(1.0) {
                        return false;
                    }
                    if (fast_bt[i * n + j] - naive).abs() > 1e-4 * naive.abs().max(1.0) {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn prop_cone_norm_is_d_in_lemma2_setting() {
        // Lemma 2: with u restricted to sqrt(d) S^{d-1} and orthogonal to m,
        // ||z||^2 = d cos^2(theta) + d sin^2(theta) = d for EVERY theta and d
        let g = Pair(UsizeRange(16, 512), crate::testing::F64Range(0.05, 3.0));
        property("cone-lemma2-norm", &g, 64, |&(d, theta)| {
            let mut rng = Rng::seed_from_u64(d as u64 ^ 0xC0DE);
            let mut m = vec![0f32; d];
            let mut u = vec![0f32; d];
            rng.fill_normal_f32(&mut m);
            rng.fill_normal_f32(&mut u);
            // orthogonalize u against m, then rescale to ||u|| = sqrt(d)
            let proj = (dot(&u, &m) / dot(&m, &m)) as f32;
            for i in 0..d {
                u[i] -= proj * m[i];
            }
            let su = ((d as f64).sqrt() / nrm2(&u)) as f32;
            scale(su, &mut u);
            let mut z = vec![0f32; d];
            cone_direction(&m, &u, theta as f32, d, &mut z);
            let zz = dot(&z, &z);
            (zz - d as f64).abs() / d as f64 < 1e-3
        });
    }

    #[test]
    fn gelu_matches_reference_points() {
        // gelu(0) = 0; gelu(x) -> x for large x; gelu(-x) small negative
        let mut x = vec![0.0f32, 1.0, -1.0, 3.0, -3.0, 0.5];
        gelu(&mut x);
        assert_eq!(x[0], 0.0);
        assert!((x[1] - 0.8412).abs() < 1e-3, "{}", x[1]); // tanh-approx value
        assert!((x[2] + 0.1588).abs() < 1e-3, "{}", x[2]);
        assert!((x[3] - 2.9964).abs() < 1e-3);
        assert!(x[4].abs() < 0.01);
        assert!((x[5] - 0.3457).abs() < 1e-3, "{}", x[5]);
    }

    #[test]
    fn add_bias_rows_broadcasts() {
        let mut x = vec![1f32; 6];
        add_bias_rows(&mut x, &[0.5, -0.5, 2.0], 2, 3);
        assert_eq!(x, vec![1.5, 0.5, 3.0, 1.5, 0.5, 3.0]);
    }

    // -----------------------------------------------------------------------
    // gradcheck property tests for the backward kernels: every analytic
    // gradient is checked against central differences of the f32 forward,
    // rel-err <= 1e-2 with a 1e-3 absolute floor (tolerances calibrated
    // against a numpy mirror of these exact f32 kernels).
    // -----------------------------------------------------------------------

    const FD_EPS: f32 = 1e-3;
    const FD_RTOL: f64 = 1e-2;
    const FD_FLOOR: f64 = 1e-3;

    /// Central-difference check of `grad` against the scalar map
    /// x -> sum(w ⊙ f(x)) at every coordinate of `x`.
    fn fd_check(name: &str, f: &dyn Fn(&[f32]) -> Vec<f32>, w: &[f32], x: &[f32], grad: &[f32]) {
        let scalar = |x: &[f32]| -> f64 {
            f(x).iter().zip(w).map(|(&y, &wi)| y as f64 * wi as f64).sum()
        };
        for i in 0..x.len() {
            let mut xp = x.to_vec();
            xp[i] += FD_EPS;
            let mut xm = x.to_vec();
            xm[i] -= FD_EPS;
            let fd = (scalar(&xp) - scalar(&xm)) / (2.0 * FD_EPS as f64);
            let rel = (fd - grad[i] as f64).abs() / (grad[i] as f64).abs().max(FD_FLOOR);
            assert!(
                rel < FD_RTOL,
                "{name}: coord {i}: analytic {} vs central-diff {fd} (rel {rel:.2e})",
                grad[i]
            );
        }
    }

    #[test]
    fn matmul_at_matches_naive_transpose_product() {
        // n up to 70 straddles the MATMUL_NR j-tile boundary; k up to 9
        // covers both the MR tile path and the remainder rows
        let g = Pair(UsizeRange(1, 9), Pair(UsizeRange(1, 9), UsizeRange(1, 70)));
        property("matmul-at-naive", &g, 48, |&(m, (k, n))| {
            let mut rng = Rng::seed_from_u64((m * 31 + k * 7 + n) as u64);
            let mut a = vec![0f32; m * k];
            let mut d = vec![0f32; m * n];
            rng.fill_normal_f32(&mut a);
            rng.fill_normal_f32(&mut d);
            let mut got = vec![0f32; k * n];
            matmul_at(&a, &d, m, k, n, &mut got);
            for p in 0..k {
                for j in 0..n {
                    let mut acc = 0f64;
                    for i in 0..m {
                        acc += a[i * k + p] as f64 * d[i * n + j] as f64;
                    }
                    if (got[p * n + j] as f64 - acc).abs() > 1e-4 * acc.abs().max(1.0) {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn prop_blocked_matmul_covers_tile_remainders() {
        // shapes straddling the MR/NR tile boundaries exercise every edge
        // path of the blocked kernel
        let g = Pair(UsizeRange(1, 10), UsizeRange(60, 70));
        property("matmul-blocked-edges", &g, 24, |&(m, n)| {
            let k = 17;
            let mut rng = Rng::seed_from_u64((m * 131 + n) as u64);
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; k * n];
            rng.fill_normal_f32(&mut a);
            rng.fill_normal_f32(&mut b);
            let mut fast = vec![0f32; m * n];
            matmul(&a, &b, m, k, n, &mut fast);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0f64;
                    for p in 0..k {
                        acc += a[i * k + p] as f64 * b[p * n + j] as f64;
                    }
                    if (fast[i * n + j] as f64 - acc).abs() > 1e-4 * acc.abs().max(1.0) {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn threaded_gemms_bit_identical_across_pool_sizes() {
        // big enough that the per-participant work gate actually engages the
        // pool (see PAR_MIN_MACS_PER_THREAD); odd dims straddle the MR/NR
        // tiles so the per-chunk row partition differs from the tile
        // partition
        let (m, k, n) = (256usize, 96usize, 130usize);
        let a = randv(m * k, 41);
        let b = randv(k * n, 42);
        let mut want = vec![0f32; m * n];
        matmul(&a, &b, m, k, n, &mut want);
        let d = randv(m * n, 43);
        let mut want_at = vec![0f32; k * n];
        matmul_at(&a, &d, m, k, n, &mut want_at);
        let bt = randv(n * k, 44);
        let mut want_bt = vec![0f32; m * n];
        matmul_bt(&a, &bt, m, k, n, &mut want_bt);
        for t in [1usize, 2, 3, 4, 8] {
            let pool = WorkerPool::new(t);
            assert!(effective_threads(t, m, k * n) >= t.min(8).min(m), "gate too strict for t={t}");
            let mut got = vec![0f32; m * n];
            matmul_threaded(&a, &b, m, k, n, &mut got, &pool);
            assert_eq!(got, want, "matmul_threaded({t}) != matmul");
            let mut got_at = vec![0f32; k * n];
            matmul_at_threaded(&a, &d, m, k, n, &mut got_at, &pool);
            assert_eq!(got_at, want_at, "matmul_at_threaded({t}) != matmul_at");
            let mut got_bt = vec![0f32; m * n];
            matmul_bt_threaded(&a, &bt, m, k, n, &mut got_bt, &pool);
            assert_eq!(got_bt, want_bt, "matmul_bt_threaded({t}) != matmul_bt");
        }
    }

    #[test]
    fn param_view_basics() {
        let base = randv(64, 60);
        let dir = randv(64, 61);
        let v = ParamView::perturbed(&base, &dir, 0.5);
        assert_eq!(v.len(), 64);
        assert!(!v.is_empty());
        for i in 0..64 {
            assert_eq!(v.at(i), base[i] + 0.5 * dir[i]);
        }
        // slicing carves base AND dir
        let s = v.slice(8, 16);
        assert_eq!(s.len(), 16);
        for i in 0..16 {
            assert_eq!(s.at(i), v.at(8 + i));
        }
        // materialize_into IS axpy_into
        let mut mat = vec![0f32; 64];
        v.materialize_into(&mut mat);
        let mut want = vec![0f32; 64];
        axpy_into(0.5, &dir, &base, &mut want);
        assert_eq!(mat, want);
        // a plain view reads base verbatim
        let p = ParamView::plain(&base);
        assert!(p.dir().is_none());
        for i in 0..64 {
            assert_eq!(p.at(i), base[i]);
        }
    }

    #[test]
    fn view_gemms_match_materialized_across_pool_sizes() {
        // THE ParamView contract: the fused in-register perturbation must
        // equal materialize-then-run BITWISE, at every pool size and for
        // both antithetic scales. m = 254 and k = 97 leave remainder rows
        // in every chunk partition so the MR-tile and tail paths of all
        // three fused spans are exercised; n = 130 straddles the NR
        // j-tiles.
        let (m, k, n) = (254usize, 97usize, 130usize);
        let a = randv(m * k, 71);
        let w = randv(k * n, 72);
        let z = randv(k * n, 73);
        let wa = randv(m * k, 74);
        let za = randv(m * k, 75);
        let d = randv(m * n, 76);
        let wbt = randv(n * k, 77);
        let zbt = randv(n * k, 78);
        let lam = 1e-3f32;
        for sc in [lam, -lam] {
            let mut wmat = vec![0f32; k * n];
            axpy_into(sc, &z, &w, &mut wmat);
            let mut want = vec![0f32; m * n];
            matmul(&a, &wmat, m, k, n, &mut want);
            let mut wa_mat = vec![0f32; m * k];
            axpy_into(sc, &za, &wa, &mut wa_mat);
            let mut want_at = vec![0f32; k * n];
            matmul_at(&wa_mat, &d, m, k, n, &mut want_at);
            let mut wbt_mat = vec![0f32; n * k];
            axpy_into(sc, &zbt, &wbt, &mut wbt_mat);
            let mut want_bt = vec![0f32; m * n];
            matmul_bt(&a, &wbt_mat, m, k, n, &mut want_bt);
            for t in [1usize, 2, 4] {
                let pool = WorkerPool::new(t);
                let mut got = vec![0f32; m * n];
                let wview = ParamView::perturbed(&w, &z, sc);
                matmul_view_threaded(&a, wview, m, k, n, &mut got, &pool);
                assert_eq!(got, want, "matmul_view (t={t}, sc={sc})");
                let mut got_at = vec![0f32; k * n];
                matmul_at_view_threaded(
                    ParamView::perturbed(&wa, &za, sc),
                    &d,
                    m,
                    k,
                    n,
                    &mut got_at,
                    &pool,
                );
                assert_eq!(got_at, want_at, "matmul_at_view (t={t}, sc={sc})");
                let mut got_bt = vec![0f32; m * n];
                matmul_bt_view_threaded(
                    &a,
                    ParamView::perturbed(&wbt, &zbt, sc),
                    m,
                    k,
                    n,
                    &mut got_bt,
                    &pool,
                );
                assert_eq!(got_bt, want_bt, "matmul_bt_view (t={t}, sc={sc})");
            }
        }
    }

    #[test]
    fn plain_view_gemms_dispatch_to_unfused_kernels() {
        // a dir-less view must reproduce the plain threaded entry points
        // exactly (they now share one implementation)
        let (m, k, n) = (256usize, 96usize, 130usize);
        let a = randv(m * k, 81);
        let w = randv(k * n, 82);
        let pool = WorkerPool::new(3);
        let mut want = vec![0f32; m * n];
        matmul_threaded(&a, &w, m, k, n, &mut want, &pool);
        let mut got = vec![0f32; m * n];
        matmul_view_threaded(&a, ParamView::plain(&w), m, k, n, &mut got, &pool);
        assert_eq!(got, want);
    }

    #[test]
    fn view_bias_and_layernorm_match_materialized() {
        let (rows, cols) = (7usize, 96usize);
        let x = randv(rows * cols, 83);
        let bias = randv(cols, 84);
        let zb = randv(cols, 85);
        let g = randv(cols, 86);
        let zg = randv(cols, 87);
        for sc in [2e-3f32, -2e-3f32] {
            let mut bias_mat = vec![0f32; cols];
            axpy_into(sc, &zb, &bias, &mut bias_mat);
            let mut g_mat = vec![0f32; cols];
            axpy_into(sc, &zg, &g, &mut g_mat);

            let mut want = x.clone();
            add_bias_rows(&mut want, &bias_mat, rows, cols);
            let mut got = x.clone();
            add_bias_rows_view(&mut got, ParamView::perturbed(&bias, &zb, sc), rows, cols);
            assert_eq!(got, want, "add_bias_rows_view (sc={sc})");

            let mut want_ln = vec![0f32; rows * cols];
            layernorm_rows(&x, &g_mat, &bias_mat, rows, cols, 1e-5, &mut want_ln);
            let mut got_ln = vec![0f32; rows * cols];
            layernorm_rows_view(
                &x,
                ParamView::perturbed(&g, &zg, sc),
                ParamView::perturbed(&bias, &zb, sc),
                rows,
                cols,
                1e-5,
                &mut got_ln,
            );
            assert_eq!(got_ln, want_ln, "layernorm_rows_view (sc={sc})");
        }
        // plain views dispatch to the unfused kernels
        let mut want = x.clone();
        add_bias_rows(&mut want, &bias, rows, cols);
        let mut got = x.clone();
        add_bias_rows_view(&mut got, ParamView::plain(&bias), rows, cols);
        assert_eq!(got, want);
        let mut want_ln = vec![0f32; rows * cols];
        layernorm_rows(&x, &g, &bias, rows, cols, 1e-5, &mut want_ln);
        let mut got_ln = vec![0f32; rows * cols];
        layernorm_rows_view(
            &x,
            ParamView::plain(&g),
            ParamView::plain(&bias),
            rows,
            cols,
            1e-5,
            &mut got_ln,
        );
        assert_eq!(got_ln, want_ln);
    }

    /// One factored segment covering a whole `[rows, cols]` buffer, with
    /// `U` at adapter offset 0 and `V` right after it.
    fn mat_segs(rows: usize, cols: usize, rank: usize) -> Vec<AdapterSeg> {
        vec![AdapterSeg::Mat { off: 0, rows, cols, rank, u_off: 0, v_off: rows * rank }]
    }

    #[test]
    fn adapter_view_resolves_segments_and_pads() {
        // a Mat + Dense binding over one buffer: slicing resolves each
        // tensor to its segment, whole-view at() agrees with the sliced
        // views, and lanes past the segment coverage read the base verbatim
        let (rows, cols, rank, dlen) = (6usize, 10usize, 2usize, 16usize);
        let segs = vec![
            AdapterSeg::Mat { off: 0, rows, cols, rank, u_off: 0, v_off: rows * rank },
            AdapterSeg::Dense { off: rows * cols, len: dlen, a_off: (rows + cols) * rank },
        ];
        let dim = adapter_dim(&segs);
        assert_eq!(dim, (rows + cols) * rank + dlen);
        let base = randv(rows * cols + dlen + 4, 120); // 4 pad lanes
        let adapter = randv(dim, 121);
        let z = randv(dim, 122);
        let lam = 1e-3f32;
        let bind = AdapterBinding::perturbed(&segs, &adapter, &z, lam);
        let whole = ParamView::adapter(&base, &bind);
        let inv = 1.0 / (rank as f32).sqrt();

        let mat = whole.slice(0, rows * cols);
        for e in 0..rows * cols {
            let (r, c) = (e / cols, e % cols);
            let mut acc = 0f32;
            for kk in 0..rank {
                acc += (adapter[r * rank + kk] + lam * z[r * rank + kk])
                    * (adapter[rows * rank + c * rank + kk]
                        + lam * z[rows * rank + c * rank + kk]);
            }
            assert_eq!(mat.at(e), base[e] + acc * inv, "mat elem {e}");
            assert_eq!(whole.at(e), mat.at(e), "whole-view mat elem {e}");
        }
        // sub-slicing a resolved Mat view shifts the element offset
        let sub = mat.slice(cols, cols);
        for j in 0..cols {
            assert_eq!(sub.at(j), mat.at(cols + j));
        }

        let dense = whole.slice(rows * cols, dlen);
        let a0 = (rows + cols) * rank;
        for j in 0..dlen {
            let want = base[rows * cols + j] + adapter[a0 + j] + lam * z[a0 + j];
            assert_eq!(dense.at(j), want, "dense elem {j}");
            assert_eq!(whole.at(rows * cols + j), want);
        }
        // pad lanes: base verbatim
        for p in rows * cols + dlen..base.len() {
            assert_eq!(whole.at(p), base[p]);
        }
        // materialize_into IS the per-element at() map
        let mut mt = vec![0f32; base.len()];
        whole.materialize_into(&mut mt);
        for (i, &v) in mt.iter().enumerate() {
            assert_eq!(v, whole.at(i), "materialized elem {i}");
        }
    }

    #[test]
    fn adapter_view_gemms_match_materialized_across_pool_sizes() {
        // THE AdapterBinding contract: the fused low-rank delta must equal
        // materialize-then-run BITWISE, at every pool size and for both
        // antithetic scales, across all three view-taking GEMM families.
        // Same tile-straddling shapes as the dense-ParamView pin.
        let (m, k, n) = (254usize, 97usize, 130usize);
        let rank = 3usize;
        let a = randv(m * k, 131);
        let d = randv(m * n, 132);
        let w = randv(k * n, 133); // matmul weight [k, n]
        let wa = randv(m * k, 134); // matmul_at operand [m, k]
        let wbt = randv(n * k, 135); // matmul_bt operand [n, k]
        let segs_w = mat_segs(k, n, rank);
        let segs_wa = mat_segs(m, k, rank);
        let segs_wbt = mat_segs(n, k, rank);
        let ad_w = randv(adapter_dim(&segs_w), 136);
        let z_w = randv(adapter_dim(&segs_w), 137);
        let ad_wa = randv(adapter_dim(&segs_wa), 138);
        let z_wa = randv(adapter_dim(&segs_wa), 139);
        let ad_wbt = randv(adapter_dim(&segs_wbt), 140);
        let z_wbt = randv(adapter_dim(&segs_wbt), 141);
        let lam = 1e-3f32;
        for sc in [lam, -lam] {
            let bind_w = AdapterBinding::perturbed(&segs_w, &ad_w, &z_w, sc);
            let view_w = ParamView::adapter(&w, &bind_w).slice(0, k * n);
            let bind_wa = AdapterBinding::perturbed(&segs_wa, &ad_wa, &z_wa, sc);
            let view_wa = ParamView::adapter(&wa, &bind_wa).slice(0, m * k);
            let bind_wbt = AdapterBinding::perturbed(&segs_wbt, &ad_wbt, &z_wbt, sc);
            let view_wbt = ParamView::adapter(&wbt, &bind_wbt).slice(0, n * k);

            let mut w_mat = vec![0f32; k * n];
            view_w.materialize_into(&mut w_mat);
            let mut want = vec![0f32; m * n];
            matmul(&a, &w_mat, m, k, n, &mut want);
            let mut wa_mat = vec![0f32; m * k];
            view_wa.materialize_into(&mut wa_mat);
            let mut want_at = vec![0f32; k * n];
            matmul_at(&wa_mat, &d, m, k, n, &mut want_at);
            let mut wbt_mat = vec![0f32; n * k];
            view_wbt.materialize_into(&mut wbt_mat);
            let mut want_bt = vec![0f32; m * n];
            matmul_bt(&a, &wbt_mat, m, k, n, &mut want_bt);

            for t in [1usize, 2, 4] {
                let pool = WorkerPool::new(t);
                let mut got = vec![0f32; m * n];
                matmul_view_threaded(&a, view_w, m, k, n, &mut got, &pool);
                assert_eq!(got, want, "adapter matmul_view (t={t}, sc={sc})");
                let mut got_at = vec![0f32; k * n];
                matmul_at_view_threaded(view_wa, &d, m, k, n, &mut got_at, &pool);
                assert_eq!(got_at, want_at, "adapter matmul_at_view (t={t}, sc={sc})");
                let mut got_bt = vec![0f32; m * n];
                matmul_bt_view_threaded(&a, view_wbt, m, k, n, &mut got_bt, &pool);
                assert_eq!(got_bt, want_bt, "adapter matmul_bt_view (t={t}, sc={sc})");
            }
        }
    }

    #[test]
    fn adapter_view_bias_and_layernorm_match_materialized() {
        // dense (1-D) adapter segments through the bias/layernorm kernels:
        // persistent delta plus SPSA perturbation, vs materialize-then-run
        let (rows, cols) = (7usize, 96usize);
        let x = randv(rows * cols, 150);
        let bias = randv(cols, 151);
        let g = randv(cols, 152);
        let segs = vec![AdapterSeg::Dense { off: 0, len: cols, a_off: 0 }];
        let ad_b = randv(cols, 153);
        let z_b = randv(cols, 154);
        let ad_g = randv(cols, 155);
        let z_g = randv(cols, 156);
        for sc in [2e-3f32, -2e-3f32] {
            let bind_b = AdapterBinding::perturbed(&segs, &ad_b, &z_b, sc);
            let bview = ParamView::adapter(&bias, &bind_b).slice(0, cols);
            let bind_g = AdapterBinding::perturbed(&segs, &ad_g, &z_g, sc);
            let gview = ParamView::adapter(&g, &bind_g).slice(0, cols);
            let mut b_mat = vec![0f32; cols];
            bview.materialize_into(&mut b_mat);
            let mut g_mat = vec![0f32; cols];
            gview.materialize_into(&mut g_mat);

            let mut want = x.clone();
            add_bias_rows(&mut want, &b_mat, rows, cols);
            let mut got = x.clone();
            add_bias_rows_view(&mut got, bview, rows, cols);
            assert_eq!(got, want, "adapter add_bias_rows_view (sc={sc})");

            let mut want_ln = vec![0f32; rows * cols];
            layernorm_rows(&x, &g_mat, &b_mat, rows, cols, 1e-5, &mut want_ln);
            let mut got_ln = vec![0f32; rows * cols];
            layernorm_rows_view(&x, gview, bview, rows, cols, 1e-5, &mut got_ln);
            assert_eq!(got_ln, want_ln, "adapter layernorm_rows_view (sc={sc})");
        }
    }

    #[test]
    fn layernorm_backward_ws_matches_allocating_wrapper() {
        // the GradWorkspace-scratch variant must be the same math with the
        // accumulators overwritten per call (stale contents ignored)
        let (rows, cols) = (5usize, 24usize);
        let x = randv(rows * cols, 91);
        let g = randv(cols, 92);
        let dy = randv(rows * cols, 93);
        let mut dx_a = vec![0f32; rows * cols];
        let mut dg_a = vec![0f32; cols];
        let mut db_a = vec![0f32; cols];
        layernorm_rows_backward(&x, &g, rows, cols, 1e-5, &dy, &mut dx_a, &mut dg_a, &mut db_a);
        let mut dx_b = vec![0f32; rows * cols];
        let mut dg_b = vec![0f32; cols];
        let mut db_b = vec![0f32; cols];
        // poison the scratch to prove it is overwritten, not accumulated
        let mut dg64 = vec![7.5f64; cols];
        let mut db64 = vec![-3.25f64; cols];
        for _ in 0..2 {
            layernorm_rows_backward_ws(
                &x, &g, rows, cols, 1e-5, &dy, &mut dx_b, &mut dg_b, &mut db_b, &mut dg64,
                &mut db64,
            );
            assert_eq!(dx_b, dx_a);
            assert_eq!(dg_b, dg_a);
            assert_eq!(db_b, db_a);
        }
    }

    #[test]
    fn threaded_gemm_small_shapes_fall_back_single() {
        // below the work gate the threaded entry points must not dispatch
        // and must still be exact; also covers rows < pool size
        let pool = WorkerPool::new(4);
        for (m, k, n) in [(1usize, 3usize, 2usize), (5, 7, 9), (3, 64, 65)] {
            let a = randv(m * k, (m * 100 + n) as u64);
            let b = randv(k * n, (k * 100 + n) as u64);
            let mut want = vec![0f32; m * n];
            matmul(&a, &b, m, k, n, &mut want);
            let mut got = vec![0f32; m * n];
            matmul_threaded(&a, &b, m, k, n, &mut got, &pool);
            assert_eq!(got, want);
            assert_eq!(effective_threads(pool.threads(), m, k * n), 1);
        }
    }

    #[test]
    fn pooled_gemms_reuse_threads_across_calls() {
        // the ROADMAP item this PR closes: repeated threaded GEMMs must not
        // spawn any OS thread beyond the pool's initial workers
        let (m, k, n) = (256usize, 96usize, 130usize);
        let a = randv(m * k, 51);
        let b = randv(k * n, 52);
        let mut want = vec![0f32; m * n];
        matmul(&a, &b, m, k, n, &mut want);
        let pool = WorkerPool::new(4);
        assert_eq!(pool.os_threads_spawned(), 3);
        let mut got = vec![0f32; m * n];
        for _ in 0..50 {
            matmul_threaded(&a, &b, m, k, n, &mut got, &pool);
            assert_eq!(got, want);
        }
        assert_eq!(pool.os_threads_spawned(), 3, "steady-state GEMMs must not spawn");
    }

    #[test]
    fn param_view_row_matches_at() {
        // RowView is the single fused accessor behind every per-element
        // view read: each arm must reproduce at() exactly
        let (rows, cols, rank) = (6usize, 10usize, 2usize);
        let base = randv(rows * cols, 160);
        let dir = randv(rows * cols, 161);
        let segs = mat_segs(rows, cols, rank);
        let ad = randv(adapter_dim(&segs), 162);
        let zd = randv(adapter_dim(&segs), 163);
        let bind = AdapterBinding::perturbed(&segs, &ad, &zd, 1e-3);
        let views = [
            ParamView::plain(&base),
            ParamView::perturbed(&base, &dir, -1e-3),
            ParamView::adapter(&base, &bind),
            ParamView::adapter(&base, &bind).slice(0, rows * cols),
        ];
        for (vi, v) in views.iter().enumerate() {
            for r in 0..rows {
                let rv = v.row(r * cols, cols);
                for j in 0..cols {
                    assert_eq!(rv.at(j), v.at(r * cols + j), "view {vi} row {r} elem {j}");
                }
            }
        }
    }

    #[test]
    fn simd_kernels_bit_identical_to_scalar() {
        // THE SIMD contract: every AVX2 twin must reproduce its scalar body
        // BITWISE — lanes in index order, p-ascending per output element,
        // mul+add never contracted to FMA. Shapes deliberately straddle the
        // 8-lane vectors (n = 130 leaves a 2-wide tail panel) and the
        // MR-row groups (m % 4 != 0). Compares the kernels directly so the
        // global dispatch policy cannot interfere.
        if !simd::available() {
            return;
        }
        let (m, k, n) = (37usize, 97usize, 130usize);
        let a = randv(m * k, 170);
        let w = randv(k * n, 171);
        let z = randv(k * n, 172);
        let d = randv(m * n, 173);
        let wbt = randv(n * k, 174);
        let zbt = randv(n * k, 175);
        for sc in [1e-3f32, -1e-3f32] {
            let mut want = vec![0f32; m * n];
            let mut got = vec![0f32; m * n];
            matmul_span_scalar(&a, &w, k, n, 0, m, &mut want);
            unsafe { simd::matmul_span(&a, &w, k, n, 0, m, &mut got) };
            assert_eq!(got, want, "matmul_span");
            matmul_span_fused_scalar(&a, &w, &z, sc, k, n, 0, m, &mut want);
            unsafe { simd::matmul_span_fused(&a, &w, &z, sc, k, n, 0, m, &mut got) };
            assert_eq!(got, want, "matmul_span_fused sc={sc}");

            let segs = mat_segs(k, n, 3);
            let ad = randv(adapter_dim(&segs), 176);
            let zd = randv(adapter_dim(&segs), 177);
            let bind = AdapterBinding::perturbed(&segs, &ad, &zd, sc);
            let view = ParamView::adapter(&w, &bind).slice(0, k * n);
            matmul_span_view_scalar(&a, view, k, n, 0, m, &mut want);
            unsafe { simd::matmul_span_view(&a, view, k, n, 0, m, &mut got) };
            assert_eq!(got, want, "matmul_span_view sc={sc}");

            let mut want_at = vec![0f32; k * n];
            let mut got_at = vec![0f32; k * n];
            matmul_at_span_scalar(&a, &d, m, k, n, 0, k, &mut want_at);
            unsafe { simd::matmul_at_span(&a, &d, m, k, n, 0, k, &mut got_at) };
            assert_eq!(got_at, want_at, "matmul_at_span");
            let za = randv(m * k, 178);
            matmul_at_span_fused_scalar(&a, &za, sc, &d, m, k, n, 0, k, &mut want_at);
            unsafe { simd::matmul_at_span_fused(&a, &za, sc, &d, m, k, n, 0, k, &mut got_at) };
            assert_eq!(got_at, want_at, "matmul_at_span_fused sc={sc}");

            matmul_bt_span_scalar(&a, &wbt, k, n, 0, m, &mut want);
            unsafe { simd::matmul_bt_span(&a, &wbt, k, n, 0, m, &mut got) };
            assert_eq!(got, want, "matmul_bt_span");
            matmul_bt_span_fused_scalar(&a, &wbt, &zbt, sc, k, n, 0, m, &mut want);
            unsafe { simd::matmul_bt_span_fused(&a, &wbt, &zbt, sc, k, n, 0, m, &mut got) };
            assert_eq!(got, want, "matmul_bt_span_fused sc={sc}");

            // row/elementwise kernels at a non-multiple-of-8 width
            let cols = 130usize;
            let rows = 5usize;
            let x0 = randv(rows * cols, 179);
            let bias = randv(cols, 180);
            let zb = randv(cols, 181);
            let mut xs = x0.clone();
            let mut xv = x0.clone();
            add_bias_rows_scalar(&mut xs, &bias, rows, cols);
            unsafe { simd::add_bias_rows(&mut xv, &bias, rows, cols) };
            assert_eq!(xv, xs, "add_bias_rows");
            let mut xs = x0.clone();
            let mut xv = x0.clone();
            add_bias_rows_perturbed_scalar(&mut xs, &bias, &zb, sc, rows, cols);
            unsafe { simd::add_bias_rows_perturbed(&mut xv, &bias, &zb, sc, rows, cols) };
            assert_eq!(xv, xs, "add_bias_rows_perturbed sc={sc}");

            let gv = randv(cols, 182);
            let mut os = vec![0f32; cols];
            let mut ov = vec![0f32; cols];
            layernorm_affine_scalar(&x0[..cols], &gv, &bias, 0.125, 1.5, &mut os);
            unsafe { simd::layernorm_affine(&x0[..cols], &gv, &bias, 0.125, 1.5, &mut ov) };
            assert_eq!(ov, os, "layernorm_affine");

            let mut rs = x0[..cols].to_vec();
            let mut rv = x0[..cols].to_vec();
            scale_in_place_scalar(&mut rs, 0.73);
            unsafe { simd::scale_in_place(&mut rv, 0.73) };
            assert_eq!(rv, rs, "scale_in_place");

            let mut gs = x0.clone();
            let mut gvx = x0.clone();
            gelu_scalar(&mut gs);
            unsafe { simd::gelu(&mut gvx) };
            assert_eq!(gvx, gs, "gelu");

            let xa = randv(257, 183);
            let za2 = randv(257, 184);
            let mut oas = vec![0f32; 257];
            let mut oav = vec![0f32; 257];
            axpy_into_scalar(sc, &za2, &xa, &mut oas);
            unsafe { simd::axpy_into(sc, &za2, &xa, &mut oav) };
            assert_eq!(oav, oas, "axpy_into sc={sc}");
        }
    }

    #[test]
    fn packed_gemms_match_unpacked_across_pool_sizes() {
        // THE packing contract: packing is a permutation copy, so every
        // PackedB arm must equal its unpacked twin BITWISE at every pool
        // size and both antithetic scales. n = 130 leaves a zero-padded
        // tail panel; m = 254 leaves remainder rows in every partition.
        let (m, k, n) = (254usize, 97usize, 130usize);
        let a = randv(m * k, 190);
        let w = randv(k * n, 191);
        let z = randv(k * n, 192);
        let wbt = randv(n * k, 193);
        let zbt = randv(n * k, 194);
        let mut pw = vec![0f32; packed_len(k, n)];
        let mut pz = vec![0f32; packed_len(k, n)];
        let mut pwbt = vec![0f32; packed_len(k, n)];
        let mut pzbt = vec![0f32; packed_len(k, n)];
        pack_b(&w, k, n, &mut pw);
        pack_b(&z, k, n, &mut pz);
        pack_bt(&wbt, k, n, &mut pwbt);
        pack_bt(&zbt, k, n, &mut pzbt);

        let segs = mat_segs(k, n, 3);
        let ad = randv(adapter_dim(&segs), 195);
        let zd = randv(adapter_dim(&segs), 196);
        let segs_bt = mat_segs(n, k, 3);
        let ad_bt = randv(adapter_dim(&segs_bt), 197);
        let zd_bt = randv(adapter_dim(&segs_bt), 198);

        for t in [1usize, 2, 4] {
            let pool = WorkerPool::new(t);
            let mut want = vec![0f32; m * n];
            let mut got = vec![0f32; m * n];

            matmul_threaded(&a, &w, m, k, n, &mut want, &pool);
            matmul_packed_view_threaded(&a, PackedB::Plain(&pw), m, k, n, &mut got, &pool);
            assert_eq!(got, want, "packed plain (t={t})");

            matmul_bt_threaded(&a, &wbt, m, k, n, &mut want, &pool);
            matmul_packed_view_threaded(&a, PackedB::Plain(&pwbt), m, k, n, &mut got, &pool);
            assert_eq!(got, want, "packed bt plain (t={t})");

            for sc in [1e-3f32, -1e-3f32] {
                matmul_view_threaded(&a, ParamView::perturbed(&w, &z, sc), m, k, n, &mut want, &pool);
                matmul_packed_view_threaded(
                    &a,
                    PackedB::Perturbed { w: &pw, z: &pz, sc },
                    m,
                    k,
                    n,
                    &mut got,
                    &pool,
                );
                assert_eq!(got, want, "packed perturbed (t={t}, sc={sc})");

                matmul_bt_view_threaded(&a, ParamView::perturbed(&wbt, &zbt, sc), m, k, n, &mut want, &pool);
                matmul_packed_view_threaded(
                    &a,
                    PackedB::Perturbed { w: &pwbt, z: &pzbt, sc },
                    m,
                    k,
                    n,
                    &mut got,
                    &pool,
                );
                assert_eq!(got, want, "packed bt perturbed (t={t}, sc={sc})");

                let bind = AdapterBinding::perturbed(&segs, &ad, &zd, sc);
                let view = ParamView::adapter(&w, &bind).slice(0, k * n);
                matmul_view_threaded(&a, view, m, k, n, &mut want, &pool);
                matmul_packed_view_threaded(
                    &a,
                    PackedB::Composite { w: &pw, view, form: PackForm::B },
                    m,
                    k,
                    n,
                    &mut got,
                    &pool,
                );
                assert_eq!(got, want, "packed composite (t={t}, sc={sc})");

                let bind_bt = AdapterBinding::perturbed(&segs_bt, &ad_bt, &zd_bt, sc);
                let view_bt = ParamView::adapter(&wbt, &bind_bt).slice(0, n * k);
                matmul_bt_view_threaded(&a, view_bt, m, k, n, &mut want, &pool);
                matmul_packed_view_threaded(
                    &a,
                    PackedB::Composite { w: &pwbt, view: view_bt, form: PackForm::Bt },
                    m,
                    k,
                    n,
                    &mut got,
                    &pool,
                );
                assert_eq!(got, want, "packed bt composite (t={t}, sc={sc})");
            }
        }
    }

    #[test]
    fn pack_pads_stay_zero_across_repacks() {
        // tail panels are zero-padded at allocation and never rewritten —
        // the SIMD kernels rely on pad lanes staying 0 across repacks
        let (k, n) = (5usize, 70usize); // one full panel + a 6-wide tail
        let w = randv(k * n, 200);
        let mut dst = vec![0f32; packed_len(k, n)];
        for round in 0..3 {
            pack_b(&w, k, n, &mut dst);
            let tb = MATMUL_NR * k; // tail panel base
            for p in 0..k {
                for jj in 0..MATMUL_NR {
                    let v = dst[tb + p * MATMUL_NR + jj];
                    if jj < n - MATMUL_NR {
                        assert_eq!(v, w[p * n + MATMUL_NR + jj], "round {round}");
                    } else {
                        assert_eq!(v, 0.0, "pad lane ({p}, {jj}) round {round}");
                    }
                }
            }
        }
    }

    #[test]
    fn gradcheck_matmul_backward_pair() {
        // Y = X @ W: dX = matmul_bt(dY, W), dW = matmul_at(X, dY); check
        // both against central differences on randomized shapes
        let g = Pair(UsizeRange(1, 5), Pair(UsizeRange(1, 6), UsizeRange(1, 5)));
        property("gradcheck-matmul", &g, 8, |&(m, (k, n))| {
            let mut rng = Rng::seed_from_u64((m * 311 + k * 17 + n) as u64);
            let mut x = vec![0f32; m * k];
            let mut wmat = vec![0f32; k * n];
            let mut up = vec![0f32; m * n];
            rng.fill_normal_f32(&mut x);
            rng.fill_normal_f32(&mut wmat);
            rng.fill_normal_f32(&mut up);
            let mut dx = vec![0f32; m * k];
            matmul_bt(&up, &wmat, m, n, k, &mut dx);
            let wmat2 = wmat.clone();
            fd_check(
                "matmul-dx",
                &move |xv: &[f32]| {
                    let mut y = vec![0f32; m * n];
                    matmul(xv, &wmat2, m, k, n, &mut y);
                    y
                },
                &up,
                &x,
                &dx,
            );
            let mut dw = vec![0f32; k * n];
            matmul_at(&x, &up, m, k, n, &mut dw);
            let x2 = x.clone();
            fd_check(
                "matmul-dw",
                &move |wv: &[f32]| {
                    let mut y = vec![0f32; m * n];
                    matmul(&x2, wv, m, k, n, &mut y);
                    y
                },
                &up,
                &wmat,
                &dw,
            );
            true
        });
    }

    #[test]
    fn gradcheck_softmax_rows_backward() {
        let g = Pair(UsizeRange(1, 5), UsizeRange(2, 12));
        property("gradcheck-softmax", &g, 12, |&(r, c)| {
            let mut rng = Rng::seed_from_u64((r * 101 + c) as u64);
            let mut x = vec![0f32; r * c];
            let mut up = vec![0f32; r * c];
            rng.fill_normal_f32(&mut x);
            rng.fill_normal_f32(&mut up);
            let mut y = x.clone();
            softmax_rows(&mut y, r, c);
            let mut dx = vec![0f32; r * c];
            softmax_rows_backward(&y, &up, r, c, &mut dx);
            fd_check(
                "softmax",
                &move |xv: &[f32]| {
                    let mut yv = xv.to_vec();
                    softmax_rows(&mut yv, r, c);
                    yv
                },
                &up,
                &x,
                &dx,
            );
            true
        });
    }

    #[test]
    fn gradcheck_layernorm_rows_backward() {
        let g = Pair(UsizeRange(1, 4), UsizeRange(8, 24));
        property("gradcheck-layernorm", &g, 10, |&(r, c)| {
            let mut rng = Rng::seed_from_u64((r * 211 + c) as u64);
            let mut x = vec![0f32; r * c];
            let mut up = vec![0f32; r * c];
            let mut gamma = vec![0f32; c];
            let mut beta = vec![0f32; c];
            rng.fill_normal_f32(&mut x);
            rng.fill_normal_f32(&mut up);
            rng.fill_normal_f32(&mut gamma);
            rng.fill_normal_f32(&mut beta);
            let mut dx = vec![0f32; r * c];
            let mut dg = vec![0f32; c];
            let mut db = vec![0f32; c];
            layernorm_rows_backward(&x, &gamma, r, c, 1e-5, &up, &mut dx, &mut dg, &mut db);
            let (g2, b2) = (gamma.clone(), beta.clone());
            fd_check(
                "layernorm-dx",
                &move |xv: &[f32]| {
                    let mut y = vec![0f32; r * c];
                    layernorm_rows(xv, &g2, &b2, r, c, 1e-5, &mut y);
                    y
                },
                &up,
                &x,
                &dx,
            );
            let (x2, b3) = (x.clone(), beta.clone());
            fd_check(
                "layernorm-dg",
                &move |gv: &[f32]| {
                    let mut y = vec![0f32; r * c];
                    layernorm_rows(&x2, gv, &b3, r, c, 1e-5, &mut y);
                    y
                },
                &up,
                &gamma,
                &dg,
            );
            let (x3, g3) = (x.clone(), gamma.clone());
            fd_check(
                "layernorm-db",
                &move |bv: &[f32]| {
                    let mut y = vec![0f32; r * c];
                    layernorm_rows(&x3, &g3, bv, r, c, 1e-5, &mut y);
                    y
                },
                &up,
                &beta,
                &db,
            );
            true
        });
    }

    #[test]
    fn gradcheck_gelu_backward() {
        let g = UsizeRange(1, 48);
        property("gradcheck-gelu", &g, 16, |&n| {
            let mut rng = Rng::seed_from_u64(n as u64 ^ 0x6E10);
            let mut x = vec![0f32; n];
            let mut up = vec![0f32; n];
            rng.fill_normal_f32(&mut x);
            rng.fill_normal_f32(&mut up);
            let mut dx = vec![0f32; n];
            gelu_backward(&x, &up, &mut dx);
            fd_check(
                "gelu",
                &move |xv: &[f32]| {
                    let mut y = xv.to_vec();
                    gelu(&mut y);
                    y
                },
                &up,
                &x,
                &dx,
            );
            true
        });
    }

    #[test]
    fn gradcheck_add_bias_rows_backward() {
        let g = Pair(UsizeRange(1, 6), UsizeRange(1, 10));
        property("gradcheck-bias", &g, 12, |&(r, c)| {
            let mut rng = Rng::seed_from_u64((r * 7 + c) as u64);
            let mut up = vec![0f32; r * c];
            let mut bias = vec![0f32; c];
            rng.fill_normal_f32(&mut up);
            rng.fill_normal_f32(&mut bias);
            let mut db = vec![0f32; c];
            add_bias_rows_backward(&up, r, c, &mut db);
            fd_check(
                "bias",
                &move |bv: &[f32]| {
                    let mut y = vec![0f32; r * c];
                    add_bias_rows(&mut y, bv, r, c);
                    y
                },
                &up,
                &bias,
                &db,
            );
            true
        });
    }
}
