//! Host-side flat-buffer f32 kernels — the L3 hot path for composed-mode
//! optimizers (HiZOO / LOZO / MeZO-SVRG / loop-based MeZO emulation).
//!
//! Mirrors the L1 Pallas kernel set one-for-one (`cone_direction`,
//! `perturb`, `zo_update`, ...) so either execution mode computes identical
//! math. Loops are written as chunked, multiplier-accumulator-friendly code
//! that LLVM auto-vectorizes; `cargo bench optimizer_math` tracks their
//! throughput against the memory-bandwidth roofline (EXPERIMENTS.md §Perf).

/// y <- y + a * x (BLAS axpy).
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * *xi;
    }
}

/// out <- x + a * z, writing into a separate buffer.
pub fn axpy_into(a: f32, z: &[f32], x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), z.len());
    assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = x[i] + a * z[i];
    }
}

/// <x, y> with f64 accumulation (stable for d up to ~10^8).
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    // 4-way unrolled accumulators help LLVM keep the pipeline full
    let mut acc = [0f64; 4];
    let chunks = x.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += x[i] as f64 * y[i] as f64;
        acc[1] += x[i + 1] as f64 * y[i + 1] as f64;
        acc[2] += x[i + 2] as f64 * y[i + 2] as f64;
        acc[3] += x[i + 3] as f64 * y[i + 3] as f64;
    }
    let mut tail = 0f64;
    for i in chunks * 4..x.len() {
        tail += x[i] as f64 * y[i] as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// Euclidean norm.
pub fn nrm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// x <- a * x.
pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// The cone construction of Algorithm 1 (host-side twin of the Pallas
/// kernel): z <- sqrt(d_raw) * cos(theta)/||m|| * m + sin(theta) * u.
/// `m` must be zero on pad lanes; `z`'s pad lanes are zeroed explicitly.
pub fn cone_direction(m: &[f32], u: &[f32], theta: f32, d_raw: usize, z: &mut [f32]) {
    assert_eq!(m.len(), u.len());
    assert_eq!(m.len(), z.len());
    assert!(d_raw <= m.len());
    let mnorm = nrm2(m).max(1e-30) as f32;
    let cs = (d_raw as f32).sqrt() * theta.cos() / mnorm;
    let sn = theta.sin();
    for i in 0..d_raw {
        z[i] = cs * m[i] + sn * u[i];
    }
    for zi in z[d_raw..].iter_mut() {
        *zi = 0.0;
    }
}

/// Fused ConMeZO update (host twin of the Pallas `zo_update`):
/// x <- x - eta*g*z ; m <- beta*m + (1-beta)*g*z, one pass.
pub fn zo_update(x: &mut [f32], m: &mut [f32], z: &[f32], g: f32, eta: f32, beta: f32) {
    assert_eq!(x.len(), z.len());
    assert_eq!(m.len(), z.len());
    let ce = eta * g;
    let cm = (1.0 - beta) * g;
    for i in 0..x.len() {
        let zi = z[i];
        x[i] -= ce * zi;
        m[i] = beta * m[i] + cm * zi;
    }
}

/// Per-coordinate scaled perturbation used by HiZOO: out = x + a * s * z
/// where `s` is a per-coordinate scale vector (Sigma^{1/2}).
pub fn axpy_scaled(a: f32, s: &[f32], z: &[f32], x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), z.len());
    assert_eq!(x.len(), s.len());
    for i in 0..x.len() {
        out[i] = x[i] + a * s[i] * z[i];
    }
}

/// cos^2 of the angle between two vectors ((m^T g)^2 / (||m||^2 ||g||^2)).
pub fn cos2(a: &[f32], b: &[f32]) -> f64 {
    let num = dot(a, b);
    let den = (dot(a, a) * dot(b, b)).max(1e-60);
    num * num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0f32; n];
        r.fill_normal_f32(&mut v);
        v
    }

    #[test]
    fn dot_matches_naive() {
        let x = randv(1001, 1);
        let y = randv(1001, 2);
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| *a as f64 * *b as f64).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9);
    }

    #[test]
    fn axpy_matches_scalar() {
        let x = randv(37, 3);
        let mut y = randv(37, 4);
        let y0 = y.clone();
        axpy(0.5, &x, &mut y);
        for i in 0..37 {
            assert_eq!(y[i], y0[i] + 0.5 * x[i]);
        }
    }

    #[test]
    fn cone_direction_properties() {
        let d_pad = 2048;
        let d_raw = 2000;
        let mut m = randv(d_pad, 5);
        for v in m[d_raw..].iter_mut() {
            *v = 0.0;
        }
        let u = randv(d_pad, 6);
        let mut z = vec![0f32; d_pad];

        // theta = 0: z = sqrt(d) * m_hat
        cone_direction(&m, &u, 0.0, d_raw, &mut z);
        let mn = nrm2(&m);
        for i in 0..d_raw {
            let want = (d_raw as f64).sqrt() as f32 / mn as f32 * m[i];
            assert!((z[i] - want).abs() < 1e-4, "{} vs {}", z[i], want);
        }
        // pads zero
        assert!(z[d_raw..].iter().all(|&v| v == 0.0));

        // theta = pi/2: z = u on the valid lanes
        cone_direction(&m, &u, std::f32::consts::FRAC_PI_2, d_raw, &mut z);
        for i in 0..d_raw {
            assert!((z[i] - u[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn cone_norm_identity_with_unit_inputs() {
        // with u restricted to the sphere sqrt(d) S^{d-1} and orthogonal to
        // m, ||z||^2 == d exactly (Lemma 2 setting)
        let d = 4096;
        let m = randv(d, 7);
        let mut u = randv(d, 8);
        // orthogonalize then normalize to sqrt(d)
        let proj = (dot(&u, &m) / dot(&m, &m)) as f32;
        for i in 0..d {
            u[i] -= proj * m[i];
        }
        let s = ((d as f64).sqrt() / nrm2(&u)) as f32;
        scale(s, &mut u);
        let mut z = vec![0f32; d];
        cone_direction(&m, &u, 0.9, d, &mut z);
        let zz = dot(&z, &z);
        assert!((zz - d as f64).abs() / (d as f64) < 1e-4, "||z||^2 = {zz}");
    }

    #[test]
    fn zo_update_matches_reference() {
        let d = 515;
        let mut x = randv(d, 9);
        let mut m = randv(d, 10);
        let z = randv(d, 11);
        let (x0, m0) = (x.clone(), m.clone());
        let (g, eta, beta) = (1.7f32, 1e-3f32, 0.95f32);
        zo_update(&mut x, &mut m, &z, g, eta, beta);
        for i in 0..d {
            assert!((x[i] - (x0[i] - eta * g * z[i])).abs() < 1e-6);
            assert!((m[i] - (beta * m0[i] + (1.0 - beta) * g * z[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn cos2_bounds_and_self() {
        let a = randv(512, 12);
        let b = randv(512, 13);
        let c = cos2(&a, &b);
        assert!((0.0..=1.0).contains(&c));
        assert!((cos2(&a, &a) - 1.0).abs() < 1e-9);
        // scaled copies are perfectly aligned
        let mut a2 = a.clone();
        scale(-3.0, &mut a2);
        assert!((cos2(&a, &a2) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn axpy_scaled_matches_scalar() {
        let d = 64;
        let x = randv(d, 14);
        let z = randv(d, 15);
        let s = randv(d, 16);
        let mut out = vec![0f32; d];
        axpy_scaled(2.0, &s, &z, &x, &mut out);
        for i in 0..d {
            assert!((out[i] - (x[i] + 2.0 * s[i] * z[i])).abs() < 1e-6);
        }
    }
}
