//! Synthetic data substrate: vocabulary, procedural task suite, batchers.
//!
//! Stand-ins for the paper's GLUE/SuperGLUE/QA datasets (repro band 0/5 —
//! DESIGN.md §2 documents the substitution and why it preserves the
//! optimizer comparisons).

pub mod batcher;
pub mod tasks;
pub mod vocab;

pub use batcher::{finetune_batch, lm_batch, PretrainSampler, TrainSampler};
pub use tasks::{registry, spec, Example, TaskGen, TaskKind, TaskSpec};
pub use vocab::Vocab;
