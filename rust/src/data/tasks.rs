//! Procedural task suite — synthetic stand-ins for the paper's benchmarks.
//!
//! Every paper task maps to a generator preserving its *shape* (class
//! count, single- vs two-segment prompts, open-vocabulary QA) so the
//! optimizer comparisons exercise the same readout structure:
//!
//! | paper task | kind | classes |
//! |---|---|---|
//! | SST-2 | Classify, 1 segment | 2 |
//! | SST-5 | Classify, 1 segment | 5 |
//! | SNLI / MNLI | Classify, 2 segments | 3 |
//! | RTE | Classify, 2 segments | 2 |
//! | TREC | Classify, 1 segment (prefix cue) | 6 |
//! | BoolQ | Classify, 2 segments | 2 |
//! | WiC | WordInContext | 2 |
//! | SQuAD / DROP | KeyValue QA (open vocab) | — |
//! | ReCoRD / MultiRC | MultiChoice | 2 |
//!
//! Difficulty is controlled by `signal` (fraction of class-signature tokens
//! in the prompt); evaluation is argmax over the task's candidate tokens at
//! the query position.

use crate::data::vocab::{Vocab, BOS, PAD, QRY, SEP};
use crate::util::rng::Xoshiro256pp;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TaskKind {
    /// n-way classification from class-conditional token statistics.
    Classify { n_classes: usize, two_segment: bool, prefix_cue: bool },
    /// Retrieve the VALUE token paired with the queried KEY token.
    KeyValue { n_pairs: usize },
    /// Do the two occurrences of the target word share a sense marker?
    WordInContext,
    /// Is the candidate answer token present in the passage?
    MultiChoice,
}

#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    pub kind: TaskKind,
    /// fraction of prompt tokens carrying the class signature
    pub signal: f32,
}

/// One generated example, model-ready.
#[derive(Clone, Debug)]
pub struct Example {
    /// padded to seq_len by the caller
    pub tokens: Vec<i32>,
    /// position whose logits predict the answer (the QRY token's position)
    pub predict_pos: usize,
    /// gold answer token id
    pub label: i32,
    /// candidate answer tokens for evaluation (argmax restricted to these)
    pub candidates: Vec<i32>,
    /// class index when applicable (for per-class sampling / noise)
    pub class: usize,
}

/// The registry mapping paper task names to generator specs.
pub fn registry() -> Vec<TaskSpec> {
    use TaskKind::*;
    vec![
        TaskSpec { name: "sst2", kind: Classify { n_classes: 2, two_segment: false, prefix_cue: false }, signal: 0.35 },
        TaskSpec { name: "sst5", kind: Classify { n_classes: 5, two_segment: false, prefix_cue: false }, signal: 0.30 },
        TaskSpec { name: "snli", kind: Classify { n_classes: 3, two_segment: true, prefix_cue: false }, signal: 0.35 },
        TaskSpec { name: "mnli", kind: Classify { n_classes: 3, two_segment: true, prefix_cue: false }, signal: 0.28 },
        TaskSpec { name: "rte", kind: Classify { n_classes: 2, two_segment: true, prefix_cue: false }, signal: 0.30 },
        TaskSpec { name: "trec", kind: Classify { n_classes: 6, two_segment: false, prefix_cue: true }, signal: 0.35 },
        TaskSpec { name: "boolq", kind: Classify { n_classes: 2, two_segment: true, prefix_cue: false }, signal: 0.30 },
        TaskSpec { name: "wic", kind: WordInContext, signal: 0.5 },
        TaskSpec { name: "squad", kind: KeyValue { n_pairs: 4 }, signal: 1.0 },
        TaskSpec { name: "drop", kind: KeyValue { n_pairs: 6 }, signal: 1.0 },
        TaskSpec { name: "record", kind: MultiChoice, signal: 0.5 },
        TaskSpec { name: "multirc", kind: MultiChoice, signal: 0.5 },
    ]
}

pub fn spec(name: &str) -> Option<TaskSpec> {
    registry().into_iter().find(|t| t.name == name)
}

/// Deterministic example generator for one task.
pub struct TaskGen {
    pub spec: TaskSpec,
    pub vocab: Vocab,
    pub seq_len: usize,
}

impl TaskGen {
    pub fn new(spec: TaskSpec, vocab_size: usize, seq_len: usize) -> Self {
        TaskGen { spec, vocab: Vocab::new(vocab_size), seq_len }
    }

    pub fn n_classes(&self) -> usize {
        match self.spec.kind {
            TaskKind::Classify { n_classes, .. } => n_classes,
            TaskKind::WordInContext | TaskKind::MultiChoice => 2,
            TaskKind::KeyValue { .. } => 0, // open vocabulary
        }
    }

    /// Candidate tokens for eval argmax.
    pub fn candidates(&self) -> Vec<i32> {
        match self.spec.kind {
            TaskKind::KeyValue { .. } => self.vocab.content_range().collect(),
            _ => (0..self.n_classes()).map(|c| self.vocab.label_token(c)).collect(),
        }
    }

    fn pad_to_seq(&self, mut tokens: Vec<i32>) -> (Vec<i32>, usize) {
        // predict position = index of the final QRY token
        assert!(tokens.len() <= self.seq_len, "prompt {} > seq {}", tokens.len(), self.seq_len);
        let predict_pos = tokens.len() - 1;
        tokens.resize(self.seq_len, PAD);
        (tokens, predict_pos)
    }

    pub fn generate(&self, rng: &mut Xoshiro256pp) -> Example {
        match self.spec.kind {
            TaskKind::Classify { n_classes, two_segment, prefix_cue } => {
                self.gen_classify(rng, n_classes, two_segment, prefix_cue)
            }
            TaskKind::KeyValue { n_pairs } => self.gen_keyvalue(rng, n_pairs),
            TaskKind::WordInContext => self.gen_wic(rng),
            TaskKind::MultiChoice => self.gen_multichoice(rng),
        }
    }

    fn draw(&self, rng: &mut Xoshiro256pp, range: &std::ops::Range<i32>) -> i32 {
        range.start + rng.gen_range((range.end - range.start) as usize) as i32
    }

    /// Class-signature pool: the lower 3/4 of the content range split into
    /// `n_classes` disjoint chunks; the shared (class-neutral) pool is the
    /// upper 1/4, disjoint from every signature.
    pub fn class_chunk(&self, c: usize, n_classes: usize) -> std::ops::Range<i32> {
        let r = self.vocab.content_range();
        let sig_span = (r.end - r.start) * 3 / 4;
        let per = sig_span / n_classes as i32;
        let start = r.start + c as i32 * per;
        start..start + per
    }

    fn shared_pool(&self) -> std::ops::Range<i32> {
        let r = self.vocab.content_range();
        (r.start + (r.end - r.start) * 3 / 4)..r.end
    }

    fn gen_classify(&self, rng: &mut Xoshiro256pp, n_classes: usize, two_segment: bool, prefix_cue: bool) -> Example {
        let c = rng.gen_range(n_classes);
        let sig = self.class_chunk(c, n_classes);
        let shared = self.shared_pool();
        let body_len = self.seq_len - 3; // BOS ... QRY (answer predicted, not in prompt)
        let mut tokens = vec![BOS];
        if prefix_cue {
            // TREC-style: a cue token early in the prompt carries most signal
            tokens.push(self.draw(rng, &sig));
        }
        let seg_boundary = if two_segment { body_len / 2 } else { usize::MAX };
        while tokens.len() < 1 + body_len {
            if tokens.len() == seg_boundary {
                tokens.push(SEP);
                continue;
            }
            let from_sig = rng.next_f32() < self.spec.signal;
            tokens.push(if from_sig { self.draw(rng, &sig) } else { self.draw(rng, &shared) });
        }
        tokens.push(QRY);
        let (tokens, predict_pos) = self.pad_to_seq(tokens);
        Example {
            tokens,
            predict_pos,
            label: self.vocab.label_token(c),
            candidates: self.candidates(),
            class: c,
        }
    }

    fn gen_keyvalue(&self, rng: &mut Xoshiro256pp, n_pairs: usize) -> Example {
        // passage: KEY_i VALUE_i pairs; question: QRY KEY_j -> VALUE_j
        let content = self.vocab.content_range();
        let mut keys = Vec::with_capacity(n_pairs);
        let mut vals = Vec::with_capacity(n_pairs);
        for _ in 0..n_pairs {
            loop {
                let k = self.draw(rng, &content);
                if !keys.contains(&k) {
                    keys.push(k);
                    break;
                }
            }
            vals.push(self.draw(rng, &content));
        }
        let mut tokens = vec![BOS];
        for i in 0..n_pairs {
            tokens.push(keys[i]);
            tokens.push(vals[i]);
        }
        tokens.push(SEP);
        let j = rng.gen_range(n_pairs);
        tokens.push(keys[j]);
        tokens.push(QRY);
        let (tokens, predict_pos) = self.pad_to_seq(tokens);
        Example {
            tokens,
            predict_pos,
            label: vals[j],
            candidates: self.candidates(),
            class: 0,
        }
    }

    fn gen_wic(&self, rng: &mut Xoshiro256pp) -> Example {
        // two segments, each: context tokens + [word, sense-marker].
        // label = do the sense markers come from the same half?
        let content = self.vocab.content_range();
        let half = (content.end - content.start) / 2;
        let word = self.draw(rng, &content);
        let same = rng.gen_range(2) == 1;
        let m1_half = rng.gen_range(2) as i32;
        let m2_half = if same { m1_half } else { 1 - m1_half };
        let marker = |h: i32, r: &mut Xoshiro256pp| {
            content.start + h * half + r.gen_range(half as usize) as i32
        };
        let ctx = (self.seq_len - 9) / 2;
        let mut tokens = vec![BOS];
        for _ in 0..ctx {
            tokens.push(self.draw(rng, &content));
        }
        tokens.push(word);
        tokens.push(marker(m1_half, rng));
        tokens.push(SEP);
        for _ in 0..ctx {
            tokens.push(self.draw(rng, &content));
        }
        tokens.push(word);
        tokens.push(marker(m2_half, rng));
        tokens.push(QRY);
        let (tokens, predict_pos) = self.pad_to_seq(tokens);
        Example {
            tokens,
            predict_pos,
            label: self.vocab.label_token(same as usize),
            candidates: self.candidates(),
            class: same as usize,
        }
    }

    fn gen_multichoice(&self, rng: &mut Xoshiro256pp) -> Example {
        // passage tokens; then SEP candidate QRY -> is candidate in passage?
        let content = self.vocab.content_range();
        let plen = self.seq_len - 5;
        let mut passage = Vec::with_capacity(plen);
        for _ in 0..plen {
            passage.push(self.draw(rng, &content));
        }
        let inside = rng.gen_range(2) == 1;
        let cand = if inside {
            passage[rng.gen_range(plen)]
        } else {
            loop {
                let c = self.draw(rng, &content);
                if !passage.contains(&c) {
                    break c;
                }
            }
        };
        let mut tokens = vec![BOS];
        tokens.extend_from_slice(&passage);
        tokens.push(SEP);
        tokens.push(cand);
        tokens.push(QRY);
        let (tokens, predict_pos) = self.pad_to_seq(tokens);
        Example {
            tokens,
            predict_pos,
            label: self.vocab.label_token(inside as usize),
            candidates: self.candidates(),
            class: inside as usize,
        }
    }

    /// Generate a dataset of `n` examples from a named stream.
    pub fn dataset(&self, n: usize, seed: u64) -> Vec<Example> {
        let mut rng = Xoshiro256pp::derive_stream(seed, crate::util::rng::STREAM_DATA, fxhash(self.spec.name));
        (0..n).map(|_| self.generate(&mut rng)).collect()
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(name: &str) -> TaskGen {
        TaskGen::new(spec(name).unwrap(), 256, 32)
    }

    #[test]
    fn registry_covers_paper_tasks() {
        let names: Vec<&str> = registry().iter().map(|t| t.name).collect();
        for t in ["sst2", "sst5", "snli", "mnli", "rte", "trec", "boolq", "wic", "squad", "drop", "record", "multirc"] {
            assert!(names.contains(&t), "{t}");
        }
    }

    #[test]
    fn examples_are_well_formed() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        for spec in registry() {
            let g = TaskGen::new(spec.clone(), 256, 32);
            for _ in 0..20 {
                let e = g.generate(&mut rng);
                assert_eq!(e.tokens.len(), 32, "{}", spec.name);
                assert_eq!(e.tokens[e.predict_pos], QRY, "{}", spec.name);
                assert!(e.tokens[0] == BOS);
                assert!(e.candidates.contains(&e.label), "{}", spec.name);
                assert!(e.tokens.iter().all(|&t| t >= 0 && (t as usize) < 256));
                // everything after predict_pos is padding
                assert!(e.tokens[e.predict_pos + 1..].iter().all(|&t| t == PAD));
            }
        }
    }

    #[test]
    fn classify_labels_balanced() {
        let g = gen("sst2");
        let data = g.dataset(2000, 7);
        let pos = data.iter().filter(|e| e.class == 1).count();
        assert!((800..1200).contains(&pos), "{pos}");
    }

    #[test]
    fn classify_signal_tokens_present() {
        // class-0 examples should contain tokens from chunk 0 much more
        // often than class-1 examples do
        let g = gen("sst2");
        let data = g.dataset(500, 9);
        let chunk0 = g.class_chunk(0, 2);
        let count = |class: usize| -> usize {
            data.iter()
                .filter(|e| e.class == class)
                .map(|e| e.tokens.iter().filter(|t| chunk0.contains(t)).count())
                .sum()
        };
        assert!(count(0) > 3 * count(1).max(1), "{} vs {}", count(0), count(1));
    }

    #[test]
    fn keyvalue_answer_is_paired_value() {
        let g = gen("squad");
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        for _ in 0..50 {
            let e = g.generate(&mut rng);
            // the queried key is the token right before QRY
            let key = e.tokens[e.predict_pos - 1];
            // find it in the passage; the next token is the value
            let body = &e.tokens[1..e.predict_pos - 2];
            let idx = body.iter().position(|&t| t == key).unwrap();
            assert_eq!(body[idx + 1], e.label);
        }
    }

    #[test]
    fn wic_same_markers_match_label() {
        let g = gen("wic");
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let half = (g.vocab.content_range().end - g.vocab.content_range().start) / 2;
        for _ in 0..50 {
            let e = g.generate(&mut rng);
            let m1 = e.tokens[e.predict_pos - 1 - (g.seq_len - 9) / 2 - 3]; // marker 1
            let m2 = e.tokens[e.predict_pos - 1];
            let h1 = (m1 - g.vocab.content_range().start) / half;
            let h2 = (m2 - g.vocab.content_range().start) / half;
            let same = h1 == h2;
            assert_eq!(e.label, g.vocab.label_token(same as usize));
        }
    }

    #[test]
    fn multichoice_label_is_membership() {
        let g = gen("record");
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        for _ in 0..50 {
            let e = g.generate(&mut rng);
            let cand = e.tokens[e.predict_pos - 1];
            let passage = &e.tokens[1..e.predict_pos - 2];
            let inside = passage.contains(&cand);
            assert_eq!(e.label, g.vocab.label_token(inside as usize));
        }
    }

    #[test]
    fn datasets_are_deterministic() {
        let g = gen("mnli");
        let a = g.dataset(10, 42);
        let b = g.dataset(10, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.label, y.label);
        }
        let c = g.dataset(10, 43);
        assert!(a.iter().zip(&c).any(|(x, y)| x.tokens != y.tokens));
    }

    #[test]
    fn works_at_larger_geometry() {
        let g = TaskGen::new(spec("squad").unwrap(), 512, 64);
        let data = g.dataset(20, 1);
        for e in data {
            assert_eq!(e.tokens.len(), 64);
            assert_eq!(e.tokens[e.predict_pos], QRY);
        }
    }
}
