//! Batch assembly: examples -> fixed-shape [B, S] token batches for the
//! runtime loss programs, for both finetuning (predict-at-query) and LM
//! pretraining (next-token) objectives.

use crate::data::tasks::{Example, TaskGen};
use crate::data::vocab::PAD;
use crate::objective::{Batch, BatchSource};
use crate::util::rng::{Xoshiro256pp, STREAM_DATA};

/// Finetuning batch: loss mass only at each example's query position,
/// target = the gold answer token (the prompt-conditioned few-shot regime).
pub fn finetune_batch(examples: &[&Example], batch: usize, seq: usize) -> Batch {
    assert!(examples.len() <= batch);
    let mut b = Batch::zeros(batch, seq);
    for (i, e) in examples.iter().enumerate() {
        assert_eq!(e.tokens.len(), seq);
        b.input_ids[i * seq..(i + 1) * seq].copy_from_slice(&e.tokens);
        b.targets[i * seq + e.predict_pos] = e.label;
        b.mask[i * seq + e.predict_pos] = 1.0;
    }
    // rows beyond examples.len() stay fully masked (zero loss weight)
    b
}

/// LM pretraining batch over prompt+answer sequences: next-token targets on
/// every non-pad transition. `label_noise` corrupts the answer token with
/// the given probability (creates the accuracy headroom ZO finetuning then
/// recovers; DESIGN.md §2).
pub fn lm_batch(
    examples: &[&Example],
    batch: usize,
    seq: usize,
    label_noise: f32,
    candidates: &[i32],
    rng: &mut Xoshiro256pp,
) -> Batch {
    let mut b = Batch::zeros(batch, seq);
    for (i, e) in examples.iter().enumerate() {
        let mut toks = e.tokens.clone();
        // append the answer right after QRY so the LM learns prompt->answer
        let ans_pos = e.predict_pos + 1;
        let mut label = e.label;
        if label_noise > 0.0 && rng.next_f32() < label_noise && !candidates.is_empty() {
            label = candidates[rng.gen_range(candidates.len())];
        }
        if ans_pos < seq {
            toks[ans_pos] = label;
        }
        b.input_ids[i * seq..(i + 1) * seq].copy_from_slice(&toks);
        for t in 0..seq - 1 {
            let next = toks[t + 1];
            if toks[t] != PAD && next != PAD {
                b.targets[i * seq + t] = next;
                b.mask[i * seq + t] = 1.0;
            }
        }
    }
    b
}

/// BatchSource drawing finetune batches from a fixed few-shot train set
/// (with-replacement sampling, per-worker stream).
pub struct TrainSampler {
    pub data: Vec<Example>,
    pub batch: usize,
    pub seq: usize,
    rng: Xoshiro256pp,
}

impl TrainSampler {
    pub fn new(data: Vec<Example>, batch: usize, seq: usize, seed: u64, worker: u64) -> Self {
        TrainSampler {
            data,
            batch,
            seq,
            rng: Xoshiro256pp::derive_stream(seed, STREAM_DATA ^ 0xB47C, worker),
        }
    }
}

impl BatchSource for TrainSampler {
    fn next_batch(&mut self) -> Batch {
        let refs: Vec<&Example> = (0..self.batch)
            .map(|_| &self.data[self.rng.gen_range(self.data.len())])
            .collect();
        finetune_batch(&refs, self.batch, self.seq)
    }
}

/// BatchSource producing LM pretraining batches straight from a generator
/// (infinite synthetic corpus).
pub struct PretrainSampler {
    pub gens: Vec<TaskGen>,
    pub batch: usize,
    pub seq: usize,
    pub label_noise: f32,
    rng: Xoshiro256pp,
}

impl PretrainSampler {
    pub fn new(gens: Vec<TaskGen>, batch: usize, seq: usize, label_noise: f32, seed: u64) -> Self {
        PretrainSampler {
            gens,
            batch,
            seq,
            label_noise,
            rng: Xoshiro256pp::derive_stream(seed, STREAM_DATA ^ 0x9E7A, 0),
        }
    }
}

impl BatchSource for PretrainSampler {
    fn next_batch(&mut self) -> Batch {
        let mut exs = Vec::with_capacity(self.batch);
        let mut cands = Vec::new();
        for _ in 0..self.batch {
            let g = &self.gens[self.rng.gen_range(self.gens.len())];
            exs.push(g.generate(&mut self.rng));
            if cands.is_empty() {
                cands = g.candidates();
            }
        }
        let refs: Vec<&Example> = exs.iter().collect();
        lm_batch(&refs, self.batch, self.seq, self.label_noise, &cands, &mut self.rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{spec, TaskGen};
    use crate::data::vocab::QRY;

    fn examples(n: usize) -> (TaskGen, Vec<Example>) {
        let g = TaskGen::new(spec("sst2").unwrap(), 256, 32);
        let d = g.dataset(n, 1);
        (g, d)
    }

    #[test]
    fn finetune_batch_masks_only_query_positions() {
        let (_, data) = examples(4);
        let refs: Vec<&Example> = data.iter().collect();
        let b = finetune_batch(&refs, 4, 32);
        assert_eq!(b.mask.iter().filter(|&&m| m == 1.0).count(), 4);
        for (i, e) in data.iter().enumerate() {
            assert_eq!(b.targets[i * 32 + e.predict_pos], e.label);
            assert_eq!(b.input_ids[i * 32 + e.predict_pos], QRY);
        }
    }

    #[test]
    fn finetune_batch_pads_missing_rows() {
        let (_, data) = examples(2);
        let refs: Vec<&Example> = data.iter().collect();
        let b = finetune_batch(&refs, 8, 32);
        // rows 2..8: no loss mass
        assert!(b.mask[2 * 32..].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn lm_batch_targets_are_shifted_inputs() {
        let (g, data) = examples(3);
        let refs: Vec<&Example> = data.iter().collect();
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let b = lm_batch(&refs, 3, 32, 0.0, &g.candidates(), &mut rng);
        for i in 0..3 {
            for t in 0..31 {
                if b.mask[i * 32 + t] == 1.0 {
                    assert_eq!(b.targets[i * 32 + t], b.input_ids[i * 32 + t + 1]);
                }
            }
        }
        // the answer token follows QRY in the inputs
        let e = &data[0];
        assert_eq!(b.input_ids[e.predict_pos + 1], e.label);
        // and the QRY position carries loss mass predicting it
        assert_eq!(b.mask[e.predict_pos], 1.0);
        assert_eq!(b.targets[e.predict_pos], e.label);
    }

    #[test]
    fn lm_batch_label_noise_corrupts_some_answers() {
        let (g, data) = examples(64);
        let refs: Vec<&Example> = data.iter().collect();
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let b = lm_batch(&refs, 64, 32, 0.5, &g.candidates(), &mut rng);
        let corrupted = data
            .iter()
            .enumerate()
            .filter(|(i, e)| b.input_ids[i * 32 + e.predict_pos + 1] != e.label)
            .count();
        assert!(corrupted > 5 && corrupted < 40, "{corrupted}");
    }

    #[test]
    fn train_sampler_is_deterministic_per_worker() {
        let (_, data) = examples(50);
        let mut a = TrainSampler::new(data.clone(), 4, 32, 7, 0);
        let mut b = TrainSampler::new(data.clone(), 4, 32, 7, 0);
        let mut c = TrainSampler::new(data, 4, 32, 7, 1);
        let ba = a.next_batch();
        assert_eq!(ba, b.next_batch());
        assert_ne!(ba, c.next_batch());
    }

    #[test]
    fn pretrain_sampler_mixes_tasks() {
        let g1 = TaskGen::new(spec("sst2").unwrap(), 256, 32);
        let g2 = TaskGen::new(spec("trec").unwrap(), 256, 32);
        let mut s = PretrainSampler::new(vec![g1, g2], 8, 32, 0.0, 3);
        let b = s.next_batch();
        assert_eq!(b.input_ids.len(), 8 * 32);
        assert!(b.mask.iter().sum::<f32>() > 8.0); // LM loss covers many positions
    }
}
