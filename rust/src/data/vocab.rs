//! Synthetic vocabulary layout shared by every task generator.
//!
//! The paper finetunes on GLUE/SuperGLUE/QA datasets we cannot ship
//! (repro band 0/5), so tasks are procedurally generated over a synthetic
//! token space (DESIGN.md §2). The vocabulary is laid out as:
//!
//!   0 PAD | 1 BOS | 2 SEP | 3 QRY | 4..4+MAX_CLASSES label verbalizers |
//!   CONTENT_START..V content tokens
//!
//! Verbalizer tokens play the role of the paper's label words ("great",
//! "terrible", ...): classification is "predict the verbalizer at the query
//! position", exactly the prompt-conditioned regime of App. C.2.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const SEP: i32 = 2;
pub const QRY: i32 = 3;
pub const LABEL_BASE: i32 = 4;
pub const MAX_CLASSES: usize = 8;
pub const CONTENT_START: i32 = LABEL_BASE + MAX_CLASSES as i32; // 12

#[derive(Clone, Copy, Debug)]
pub struct Vocab {
    pub size: usize,
}

impl Vocab {
    pub fn new(size: usize) -> Self {
        assert!(size as i32 > CONTENT_START + 16, "vocab too small: {size}");
        Vocab { size }
    }

    pub fn label_token(&self, class: usize) -> i32 {
        assert!(class < MAX_CLASSES);
        LABEL_BASE + class as i32
    }

    pub fn content_range(&self) -> std::ops::Range<i32> {
        CONTENT_START..self.size as i32
    }

    pub fn n_content(&self) -> usize {
        self.size - CONTENT_START as usize
    }

    /// The c-th disjoint signature chunk when the content range is split
    /// into `n_chunks` equal parts (class-conditional token pools).
    pub fn signature_chunk(&self, c: usize, n_chunks: usize) -> std::ops::Range<i32> {
        let n = self.n_content();
        let per = n / n_chunks;
        let start = CONTENT_START + (c * per) as i32;
        start..start + per as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_disjoint() {
        let v = Vocab::new(256);
        assert!(v.label_token(0) > QRY);
        assert!(v.label_token(MAX_CLASSES - 1) < CONTENT_START);
        assert_eq!(v.content_range().start, CONTENT_START);
        assert_eq!(v.content_range().end, 256);
    }

    #[test]
    fn signature_chunks_partition() {
        let v = Vocab::new(256);
        let a = v.signature_chunk(0, 4);
        let b = v.signature_chunk(1, 4);
        let d = v.signature_chunk(3, 4);
        assert_eq!(a.end, b.start);
        assert!(d.end <= 256);
        assert_eq!(a.len(), b.len());
    }

    #[test]
    #[should_panic]
    fn tiny_vocab_rejected() {
        Vocab::new(20);
    }
}
