//! Property-based testing mini-framework (proptest is not vendored).
//!
//! A `Gen` produces random values from the crate PRNG; `property` runs a
//! predicate over N generated cases and, on failure, greedily shrinks the
//! case via the value's `Shrink` implementation before reporting. Used for
//! the coordinator/optimizer invariants listed in DESIGN.md §7.

use crate::util::rng::Xoshiro256pp;

/// Number of cases per property (overridable via CONMEZO_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("CONMEZO_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// A generator of random test inputs.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value;
    /// Candidate smaller versions of a failing value (for shrinking).
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run `prop` over `cases` generated inputs; panic with the (shrunk)
/// counterexample on failure.
pub fn property<G: Gen>(name: &str, gen: &G, cases: usize, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Xoshiro256pp::seed_from_u64(0xC0FFEE ^ name.len() as u64);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            let shrunk = shrink_loop(gen, v, &prop);
            panic!("property {name:?} failed at case {case}: {shrunk:?}");
        }
    }
}

fn shrink_loop<G: Gen>(gen: &G, mut v: G::Value, prop: &impl Fn(&G::Value) -> bool) -> G::Value {
    // greedy descent: keep taking the first failing shrink candidate
    'outer: for _ in 0..1000 {
        for cand in gen.shrink(&v) {
            if !prop(&cand) {
                v = cand;
                continue 'outer;
            }
        }
        break;
    }
    v
}

// ---------------------------------------------------------------------------
// Stock generators
// ---------------------------------------------------------------------------

/// Uniform usize in [lo, hi].
pub struct UsizeRange(pub usize, pub usize);

impl Gen for UsizeRange {
    type Value = usize;
    fn generate(&self, rng: &mut Xoshiro256pp) -> usize {
        self.0 + rng.gen_range(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1); // linear fallback so boundaries are reachable
        }
        out.dedup();
        out.retain(|x| x != v);
        out
    }
}

/// Uniform f64 in [lo, hi].
pub struct F64Range(pub f64, pub f64);

impl Gen for F64Range {
    type Value = f64;
    fn generate(&self, rng: &mut Xoshiro256pp) -> f64 {
        self.0 + rng.next_f64() * (self.1 - self.0)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mid = (self.0 + *v) / 2.0;
        if (mid - *v).abs() > 1e-12 {
            vec![self.0, mid]
        } else {
            vec![]
        }
    }
}

/// Vector of standard normals with a generated length in [min_len, max_len].
pub struct NormalVec {
    pub min_len: usize,
    pub max_len: usize,
}

impl Gen for NormalVec {
    type Value = Vec<f32>;
    fn generate(&self, rng: &mut Xoshiro256pp) -> Vec<f32> {
        let n = self.min_len + rng.gen_range(self.max_len - self.min_len + 1);
        let mut v = vec![0f32; n];
        rng.fill_normal_f32(&mut v);
        v
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..self.min_len.max(v.len() / 2)].to_vec());
        }
        // zero half the entries — smaller in the "structure" sense
        if v.iter().any(|&x| x != 0.0) {
            let mut z = v.clone();
            for x in z.iter_mut().take(v.len() / 2) {
                *x = 0.0;
            }
            out.push(z);
        }
        out
    }
}

/// Pair of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usize_range_respects_bounds() {
        property("bounds", &UsizeRange(3, 17), 200, |v| (3..=17).contains(v));
    }

    #[test]
    fn normal_vec_lengths() {
        let g = NormalVec { min_len: 4, max_len: 32 };
        property("lengths", &g, 100, |v| v.len() >= 4 && v.len() <= 32);
    }

    #[test]
    #[should_panic(expected = "failed")]
    fn failing_property_panics_with_counterexample() {
        property("always-small", &UsizeRange(0, 100), 200, |v| *v < 50);
    }

    #[test]
    fn shrinking_finds_minimal_usize() {
        // the shrunk counterexample for v >= 50 with range [0,100] should
        // land near 50 via bisection from below; just check it shrinks at all
        let g = UsizeRange(0, 100);
        let shrunk = super::shrink_loop(&g, 97, &|v: &usize| *v < 50);
        assert_eq!(shrunk, 50, "minimal counterexample of v >= 50");
    }

    #[test]
    fn pair_generates_both() {
        let g = Pair(UsizeRange(1, 5), F64Range(-1.0, 1.0));
        property("pair", &g, 100, |(a, b)| (1..=5).contains(a) && (-1.0..=1.0).contains(b));
    }
}
