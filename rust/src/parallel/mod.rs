//! Persistent worker-pool substrate for the native backend.
//!
//! ConMeZO's step cost is two transformer forwards, and each forward used
//! to pay `std::thread::scope` OS-thread spawns for every GEMM (~10 spawns
//! per forward at the medium preset). A [`WorkerPool`] is created ONCE per
//! `Runtime` (sized by `runtime::ParallelPolicy`) and every threaded
//! kernel — the `vecmath` GEMMs plus the attention loops in
//! `runtime::model` ((batch, head, query-block) tasks on both the
//! streaming forward and the kernel-composition twin), the bind-time
//! weight-packing pass (`runtime::model::pack_flat`, one chunk per packed
//! tensor writing a disjoint destination range), and `runtime::autograd` —
//! dispatches onto it through [`WorkerPool::run`], a deterministic
//! parallel-for over chunks. Steady state spawns zero threads (pinned by
//! [`WorkerPool::os_threads_spawned`] instrumentation tests) and allocates
//! nothing per dispatch.
//!
//! Parallelism composes with SIMD, not against it: the pool splits output
//! ROWS across participants, and inside each row span the `vecmath`
//! kernels vectorize across output COLUMNS (`vecmath::simd`, AVX2+FMA
//! when detected). Column lanes are independent dot products, so lane
//! width never interacts with the row partition and the bit-identity
//! contract above holds at every (pool size, SIMD on/off) combination.
//!
//! ## Determinism contract
//!
//! `run(parts, chunks, task)` executes `task(c)` exactly once for every
//! chunk `c in 0..chunks`; chunk `c` is handled by participant `c % parts`
//! (participant 0 is the calling thread, participants `1..parts` are pool
//! workers). Which OS thread computes a chunk never changes WHAT it
//! computes: callers partition output buffers into disjoint regions by
//! chunk index and keep per-element accumulation order identical to the
//! sequential loop, so results are bit-identical at every pool size. The
//! chunk→participant mapping is also how callers carve per-task scratch:
//! slot `c % parts` is only ever touched by one participant, so `parts`
//! scratch slots suffice (see `FwdScratch`/`GradWorkspace`).
//!
//! Tasks must not dispatch onto the pool they run on (no nesting); the
//! kernels never do.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::telemetry::Registry;

/// A dispatched parallel-for: a type-erased pointer to the caller's
/// closure plus the chunk geometry. The caller blocks inside
/// [`WorkerPool::run`] until every worker acknowledged the epoch, so the
/// borrow behind `data` outlives all uses.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    chunks: usize,
    parts: usize,
}

// The raw pointer crosses threads only while `run` keeps the referent
// alive on the calling stack frame.
unsafe impl Send for Job {}

struct State {
    /// bumped once per dispatch; workers run a job exactly once per epoch
    epoch: u64,
    job: Option<Job>,
    /// PARTICIPATING workers (`participant < parts`) that have not yet
    /// acknowledged the current epoch — idle workers note the epoch and go
    /// straight back to sleep without joining the barrier
    outstanding: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// workers wait here for a new epoch (or shutdown)
    work: Condvar,
    /// the dispatching caller waits here for `outstanding == 0`
    done: Condvar,
    /// a worker task panicked (re-raised on the calling thread)
    panicked: AtomicBool,
    /// instrumentation registry shared with the owning `Runtime` (`None`
    /// for bare pools, e.g. a model's default sequential pool) — recording
    /// is timing-only and never changes what a dispatch computes
    telemetry: Option<Arc<Registry>>,
    /// registry-clock timestamp of the most recent dispatch; woken
    /// participants subtract it from "now" to measure queue wait. Written
    /// before the epoch bump under the state mutex, so the release/acquire
    /// pair of the mutex publishes it to every woken worker.
    dispatch_start_ns: AtomicU64,
}

/// A persistent pool of `threads - 1` OS workers plus the calling thread.
/// Created once (per `Runtime` on the native backend) and reused for every
/// GEMM/attention dispatch; see the module docs for the determinism
/// contract.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
    /// OS threads spawned over this pool's lifetime — stays at
    /// `threads - 1` forever (the no-steady-state-spawning pin).
    spawned: AtomicUsize,
    /// serializes concurrent `run` callers (dispatch state is per-pool)
    run_lock: Mutex<()>,
}

/// Poison-tolerant lock: a panicked task already records its failure via
/// `Shared::panicked`; the pool state itself stays consistent.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn worker_loop(shared: Arc<Shared>, participant: usize) {
    // workers must match the main thread's FTZ/DAZ mode or threaded and
    // single-threaded results could diverge on denormals
    crate::runtime::enable_flush_to_zero();
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen_epoch {
                    seen_epoch = st.epoch;
                    // `None` here means the epoch was dispatched AND retired
                    // (run() returned and cleared the job) before this worker
                    // woke. That only happens when the worker sat out that
                    // dispatch (participant >= parts): run() waits for every
                    // participating ack before clearing the job, so a
                    // participant always finds it Some. Note the epoch and
                    // keep sleeping — panicking would kill the worker and
                    // hang the next wide dispatch on its missing ack.
                    if let Some(job) = st.job {
                        break job;
                    }
                    continue;
                }
                st = shared.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        // idle epochs (participant >= parts) are only noted — the worker
        // goes straight back to sleep without touching the ack barrier, so
        // narrow dispatches on a wide pool never wait on idle workers
        if participant < job.parts {
            let tel = shared.telemetry.as_deref().filter(|r| r.enabled());
            let t0 = tel.map(|r| {
                r.pool_queue_wait.record_ns(
                    r.now_ns()
                        .saturating_sub(shared.dispatch_start_ns.load(Ordering::Relaxed)),
                );
                Instant::now()
            });
            let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut c = participant;
                while c < job.chunks {
                    unsafe { (job.call)(job.data, c) };
                    c += job.parts;
                }
            }));
            if ran.is_err() {
                shared.panicked.store(true, Ordering::SeqCst);
            }
            if let (Some(r), Some(t0)) = (tel, t0) {
                // recorded before the ack below: the state mutex then
                // publishes these stores to the caller's imbalance read
                let busy = t0.elapsed().as_nanos() as u64;
                r.pool_compute.record_ns(busy);
                if let Some(slot) = r.pool_busy_ns.get(participant) {
                    slot.fetch_add(busy, Ordering::Relaxed);
                }
                if let Some(slot) = r.pool_last_busy_ns.get(participant) {
                    slot.store(busy, Ordering::Relaxed);
                }
            }
            let mut st = lock(&shared.state);
            st.outstanding -= 1;
            if st.outstanding == 0 {
                shared.done.notify_all();
            }
        }
    }
}

impl WorkerPool {
    /// Pool with `threads` participants: the caller plus `threads - 1`
    /// spawned OS workers (`threads <= 1` spawns nothing and runs every
    /// dispatch inline). FTZ/DAZ is enabled on the constructing thread, in
    /// every worker, and re-pinned on the calling thread by each
    /// [`WorkerPool::run`], so caller-computed chunks always share the
    /// workers' float mode no matter which thread dispatches.
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool::with_telemetry(threads, None)
    }

    /// Like [`WorkerPool::new`], reporting dispatch/queue-wait/compute
    /// timing into `telemetry` (sized for at least `threads` participants).
    pub fn with_telemetry(threads: usize, telemetry: Option<Arc<Registry>>) -> WorkerPool {
        crate::runtime::enable_flush_to_zero();
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { epoch: 0, job: None, outstanding: 0, shutdown: false }),
            work: Condvar::new(),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
            telemetry,
            dispatch_start_ns: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(threads - 1);
        let spawned = AtomicUsize::new(0);
        for w in 1..threads {
            let sh = shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("conmezo-pool-{w}"))
                    .spawn(move || worker_loop(sh, w))
                    .expect("spawning pool worker"),
            );
            spawned.fetch_add(1, Ordering::SeqCst);
        }
        WorkerPool { shared, handles, threads, spawned, run_lock: Mutex::new(()) }
    }

    /// A no-worker pool: every dispatch runs inline on the caller (the
    /// deterministic-by-construction default; threading is bit-identical
    /// anyway, this just avoids idle workers).
    pub fn sequential() -> WorkerPool {
        WorkerPool::new(1)
    }

    /// Participant count (caller + workers); the thread budget kernels
    /// split work across.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Total OS threads this pool has ever spawned. Constant after
    /// construction — the instrumentation behind the
    /// no-steady-state-spawning tests.
    pub fn os_threads_spawned(&self) -> usize {
        self.spawned.load(Ordering::SeqCst)
    }

    /// The instrumentation registry this pool reports into (shared with
    /// the owning `Runtime`), if any. Kernel call sites use this to time
    /// GEMM/attention spans without threading a registry through every
    /// signature.
    pub fn telemetry(&self) -> Option<&Registry> {
        self.shared.telemetry.as_deref()
    }

    /// Owned handle to the registry — for contexts that cannot hold a
    /// borrow of the pool across `&mut self` calls (e.g. session `execute`).
    pub fn telemetry_arc(&self) -> Option<Arc<Registry>> {
        self.shared.telemetry.clone()
    }

    /// Deterministic parallel-for over `chunks` chunks using `parts`
    /// participants (`parts` must be <= [`WorkerPool::threads`]): `task(c)`
    /// runs exactly once per chunk, chunk `c` on participant `c % parts`,
    /// participant 0 being the calling thread. Blocks until every chunk
    /// completed. Allocation-free on the dispatch path.
    pub fn run<F: Fn(usize) + Sync>(&self, parts: usize, chunks: usize, task: &F) {
        // pin the CALLING thread's float mode on every dispatch, not just at
        // pool construction: a bound session can be moved to a thread that
        // never enabled FTZ/DAZ, and participant 0's chunks must use the
        // same denormal handling as the pool workers (and as a pool-size-1
        // run) or bit-identity breaks. One MXCSR read+write — noise next to
        // any kernel that clears the work gate.
        crate::runtime::enable_flush_to_zero();
        let parts = parts.max(1).min(chunks.max(1));
        assert!(
            parts <= self.threads,
            "pool dispatch with {parts} participants on a {}-thread pool",
            self.threads
        );
        let tel = self.shared.telemetry.as_deref().filter(|r| r.enabled());
        if parts <= 1 || self.handles.is_empty() {
            let t0 = tel.map(|r| {
                r.pool_dispatches.inc();
                Instant::now()
            });
            for c in 0..chunks {
                task(c);
            }
            if let (Some(r), Some(t0)) = (tel, t0) {
                let busy = t0.elapsed().as_nanos() as u64;
                r.pool_compute.record_ns(busy);
                if let Some(slot) = r.pool_busy_ns.first() {
                    slot.fetch_add(busy, Ordering::Relaxed);
                }
                if let Some(slot) = r.pool_last_busy_ns.first() {
                    slot.store(busy, Ordering::Relaxed);
                }
            }
            return;
        }
        unsafe fn call_erased<F: Fn(usize)>(data: *const (), chunk: usize) {
            (*(data as *const F))(chunk)
        }
        let job = Job {
            data: task as *const F as *const (),
            call: call_erased::<F>,
            chunks,
            parts,
        };
        let _dispatch = lock(&self.run_lock);
        {
            let mut st = lock(&self.shared.state);
            if let Some(r) = tel {
                r.pool_dispatches.inc();
                self.shared.dispatch_start_ns.store(r.now_ns(), Ordering::Relaxed);
            }
            st.job = Some(job);
            st.epoch += 1;
            // only participants join the completion barrier (workers are
            // participants 1..parts); parts <= threads = handles + 1
            st.outstanding = parts - 1;
            self.shared.work.notify_all();
        }
        // participant 0: the caller computes its own chunk stride while the
        // workers run theirs. A caller-side panic is deferred until every
        // worker finished — the job borrows this stack frame.
        let t0 = tel.map(|_| Instant::now());
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut c = 0usize;
            while c < chunks {
                task(c);
                c += parts;
            }
        }));
        if let (Some(r), Some(t0)) = (tel, t0) {
            let busy = t0.elapsed().as_nanos() as u64;
            r.pool_compute.record_ns(busy);
            if let Some(slot) = r.pool_busy_ns.first() {
                slot.fetch_add(busy, Ordering::Relaxed);
            }
            if let Some(slot) = r.pool_last_busy_ns.first() {
                slot.store(busy, Ordering::Relaxed);
            }
        }
        let mut st = lock(&self.shared.state);
        while st.outstanding != 0 {
            st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        drop(st);
        // every participant's last-dispatch busy time is visible now (the
        // workers store before their ack; the state mutex publishes it):
        // gauge the dispatch balance as max/mean over participants
        if let Some(r) = tel {
            let (mut max, mut sum) = (0u64, 0u64);
            for slot in r.pool_last_busy_ns.iter().take(parts) {
                let b = slot.load(Ordering::Relaxed);
                max = max.max(b);
                sum += b;
            }
            if sum > 0 {
                r.pool_imbalance.set(max as f64 * parts as f64 / sum as f64);
            }
        }
        // clear the worker-panic flag BEFORE re-raising a caller-side
        // panic, so a failed dispatch can never leak a stale flag into the
        // next (clean) one
        let worker_panicked = self.shared.panicked.swap(false, Ordering::SeqCst);
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("a worker-pool task panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw-pointer wrapper that lets `run` tasks carve disjoint `&mut` regions
/// of one buffer by chunk index (the chunks are guaranteed disjoint by the
/// caller's partition, so handing each task its own slice is sound).
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// The task-side slice `[off, off + len)` of the shared buffer. Safety:
    /// the caller's chunk partition must make concurrently-live regions
    /// disjoint, and the underlying buffer must outlive the dispatch.
    pub unsafe fn slice_mut<'a>(&self, off: usize, len: usize) -> &'a mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(off), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn every_chunk_runs_exactly_once() {
        let pool = WorkerPool::new(4);
        for &(parts, chunks) in &[(1usize, 7usize), (2, 2), (3, 17), (4, 4), (4, 1), (2, 0)] {
            let counts: Vec<AtomicU32> = (0..chunks).map(|_| AtomicU32::new(0)).collect();
            pool.run(parts, chunks, &|c| {
                counts[c].fetch_add(1, Ordering::SeqCst);
            });
            assert!(
                counts.iter().all(|c| c.load(Ordering::SeqCst) == 1),
                "parts={parts} chunks={chunks}"
            );
        }
    }

    #[test]
    fn chunk_to_participant_mapping_is_deterministic() {
        // chunk c runs on participant c % parts: two chunks with the same
        // residue never run concurrently, which is what makes slot-indexed
        // scratch (slot = c % parts) race-free
        let pool = WorkerPool::new(3);
        let slots: Vec<AtomicU32> = (0..3).map(|_| AtomicU32::new(0)).collect();
        pool.run(3, 12, &|c| {
            let slot = &slots[c % 3];
            let inflight = slot.fetch_add(1, Ordering::SeqCst);
            assert_eq!(inflight, 0, "slot {} entered concurrently", c % 3);
            std::thread::sleep(std::time::Duration::from_micros(200));
            slot.fetch_sub(1, Ordering::SeqCst);
        });
    }

    #[test]
    fn disjoint_writes_through_send_ptr() {
        let pool = WorkerPool::new(4);
        let mut buf = vec![0u32; 64];
        let ptr = SendPtr(buf.as_mut_ptr());
        pool.run(4, 8, &|c| {
            let chunk = unsafe { ptr.slice_mut(c * 8, 8) };
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (c * 8 + j) as u32;
            }
        });
        assert!(buf.iter().enumerate().all(|(i, &v)| v == i as u32));
    }

    #[test]
    fn pool_reuse_spawns_no_new_threads() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.os_threads_spawned(), 2);
        let hits = AtomicU32::new(0);
        for _ in 0..200 {
            pool.run(3, 6, &|_| {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(hits.load(Ordering::SeqCst), 200 * 6);
        assert_eq!(pool.os_threads_spawned(), 2, "dispatch must never respawn");
    }

    #[test]
    fn narrow_dispatches_do_not_strand_idle_workers() {
        // Regression: a narrow dispatch (parts < threads) retires its epoch
        // as soon as the PARTICIPATING workers ack. An idle worker woken by
        // the dispatch's notify_all can observe the advanced epoch only
        // after the job is cleared; it must treat that as a retired epoch
        // and keep sleeping (not die), or the next wide dispatch counts a
        // dead worker in its barrier and hangs forever.
        let pool = WorkerPool::new(4);
        let hits = AtomicU32::new(0);
        for _ in 0..500 {
            pool.run(2, 2, &|_| {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }
        // wide dispatches still complete: every worker is alive and acks
        for _ in 0..50 {
            pool.run(4, 8, &|_| {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(hits.load(Ordering::SeqCst), 500 * 2 + 50 * 8);
        assert_eq!(pool.os_threads_spawned(), 3);
    }

    #[test]
    fn sequential_pool_runs_inline() {
        let pool = WorkerPool::sequential();
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.os_threads_spawned(), 0);
        let order = Mutex::new(Vec::new());
        pool.run(1, 5, &|c| order.lock().unwrap().push(c));
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "worker-pool task panicked")]
    fn worker_panic_is_reraised_on_caller() {
        let pool = WorkerPool::new(2);
        pool.run(2, 2, &|c| {
            if c == 1 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn telemetry_records_dispatches_and_participant_busy_time() {
        let reg = Arc::new(Registry::with_capacity(3, 16));
        let pool = WorkerPool::with_telemetry(3, Some(reg.clone()));
        assert_eq!(pool.os_threads_spawned(), 2, "telemetry must not change spawning");
        assert!(pool.telemetry().is_some());
        for _ in 0..4 {
            pool.run(3, 6, &|_| {
                std::thread::sleep(std::time::Duration::from_micros(100));
            });
        }
        assert_eq!(reg.pool_dispatches.get(), 4);
        // caller + 2 workers time their chunk strides on every dispatch
        assert_eq!(reg.pool_compute.count(), 12);
        assert_eq!(reg.pool_queue_wait.count(), 8, "only woken workers have queue wait");
        for p in 0..3 {
            assert!(
                reg.pool_busy_ns[p].load(Ordering::Relaxed) > 0,
                "participant {p} never recorded busy time"
            );
        }
        let imb = reg.pool_imbalance.get();
        assert!(imb >= 1.0, "max/mean imbalance below 1: {imb}");
        // narrow (inline) dispatches count too, attributed to the caller
        let before = reg.pool_busy_ns[0].load(Ordering::Relaxed);
        pool.run(1, 4, &|_| {});
        assert_eq!(reg.pool_dispatches.get(), 5);
        assert!(reg.pool_busy_ns[0].load(Ordering::Relaxed) >= before);
        // the enabled flag gates recording without rebuilding the pool
        reg.set_enabled(false);
        pool.run(3, 6, &|_| {});
        assert_eq!(reg.pool_dispatches.get(), 5, "disabled registry still recorded");
        reg.set_enabled(true);
    }

    #[test]
    fn pool_survives_a_panicked_dispatch() {
        let pool = WorkerPool::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, 2, &|c| {
                if c == 1 {
                    panic!("boom");
                }
            })
        }));
        assert!(res.is_err());
        // the pool is still functional afterwards
        let hits = AtomicU32::new(0);
        pool.run(2, 4, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
        assert_eq!(pool.os_threads_spawned(), 1);
    }
}
