//! Objective abstraction: the ZO oracle f(x) of Definition 1.
//!
//! Composed-mode optimizers (HiZOO, LOZO, MeZO-SVRG, ZO-AdaMM and the
//! loop-based MeZO emulation of Table 3) only interact with the model
//! through this trait — two function evaluations per step, exactly like the
//! paper's setting. Two implementations:
//!
//! * [`NativeQuadratic`] — the Fig. 3 / App. C.1 synthetic objective in
//!   pure Rust (microseconds per eval; used for the 10^5-step grid sweeps).
//! * [`ModelObjective`] — the transformer loss, executing the
//!   `{preset}_loss` / `{preset}_two_point` programs through bound
//!   [`Session`]s on whichever runtime backend is active (native CPU by
//!   default, PJRT with `--features pjrt`). Sessions are held behind
//!   [`SharedSession`] handles: [`ModelObjective::new`] binds a private
//!   pair, while [`ModelObjective::with_sessions`] builds additional
//!   replicas over an EXISTING pair — distributed workers in one process
//!   share one bound two_point session (one forward scratch, one
//!   `WorkerPool`) instead of one per replica. Sharing is sound because
//!   session workspaces carry no state across calls (the workspace-reuse
//!   invariant pinned in rust/tests), so shared-session replicas stay
//!   bit-identical to private-session ones. The antithetic pair runs
//!   through the first-class [`Session::two_point`] entry point — on the
//!   native backend that pair is materialization-free (`x ± λz` streams
//!   through `vecmath::ParamView`s; zero parameter-sized writes per
//!   step). (Formerly named `HloObjective`, then a `Program::call`
//!   wrapper; migrated when execution grew the bind-once/run-many session
//!   API.)

use std::cell::RefCell;
use std::rc::Rc;

use crate::util::error::{bail, Result};

use crate::runtime::adapter::AdapterSession;
use crate::runtime::{lit_f32, Arg, Runtime, Session};

/// A bound session shareable by several objectives in one process
/// (single-threaded interior mutability; the step loop never re-enters).
pub type SharedSession = Rc<RefCell<Box<dyn Session>>>;

/// An adapter session shareable by every tenant of one (preset, rank)
/// pair: the serve scheduler runs jobs one quantum at a time, so all
/// tenants evaluate through ONE forward scratch and the marginal tenant
/// owns only its adapter + optimizer state (O(rank·dims), not O(d)).
pub type SharedAdapterSession = Rc<RefCell<AdapterSession>>;

/// Fixed-shape token batch fed to the runtime loss programs.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    pub input_ids: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    pub fn zeros(batch: usize, seq: usize) -> Batch {
        Batch {
            input_ids: vec![0; batch * seq],
            targets: vec![0; batch * seq],
            mask: vec![0.0; batch * seq],
            batch,
            seq,
        }
    }

    pub fn dims(&self) -> [usize; 2] {
        [self.batch, self.seq]
    }
}

/// Supplies minibatches to a stochastic objective.
pub trait BatchSource {
    fn next_batch(&mut self) -> Batch;
}

/// The ZO oracle.
pub trait Objective {
    /// Padded flat dimension (buffer length).
    fn dim(&self) -> usize;
    /// True parameter count d (<= dim()).
    fn d_raw(&self) -> usize;
    /// f(x) on the current minibatch.
    fn loss(&mut self, x: &[f32]) -> Result<f64>;
    /// (f(x + lam z), f(x - lam z)) on the *same* minibatch — the SPSA pair
    /// must see identical data (Definition 1).
    fn two_point(&mut self, x: &[f32], z: &[f32], lam: f32) -> Result<(f64, f64)>;
    /// Advance to the next minibatch (no-op for deterministic objectives).
    fn advance(&mut self) {}
    /// Total function evaluations so far (the ZO cost metric).
    fn evals(&self) -> u64;
}

// ---------------------------------------------------------------------------
// NativeQuadratic
// ---------------------------------------------------------------------------

/// f(x) = sum_i sigma_i x_i^2 with sigma_i geometric from 1/d to 1
/// (condition number d) — byte-for-byte the python `quadratic.sigmas`.
pub struct NativeQuadratic {
    pub sigmas: Vec<f32>,
    evals: u64,
}

impl NativeQuadratic {
    pub fn new(d: usize) -> Self {
        let ratio = (d as f64).powf(1.0 / (d as f64 - 1.0));
        let mut sigmas = Vec::with_capacity(d);
        let mut cur = 1.0 / d as f64;
        for _ in 0..d {
            sigmas.push(cur as f32);
            cur *= ratio;
        }
        NativeQuadratic { sigmas, evals: 0 }
    }

    /// Analytic gradient (tests + Fig. 6-style probes).
    pub fn grad(&self, x: &[f32], out: &mut [f32]) {
        for i in 0..x.len() {
            out[i] = 2.0 * self.sigmas[i] * x[i];
        }
    }

    fn eval(&self, x: &[f32]) -> f64 {
        let mut acc = 0f64;
        for (xi, si) in x.iter().zip(&self.sigmas) {
            acc += *si as f64 * (*xi as f64) * (*xi as f64);
        }
        acc
    }
}

impl Objective for NativeQuadratic {
    fn dim(&self) -> usize {
        self.sigmas.len()
    }

    fn d_raw(&self) -> usize {
        self.sigmas.len()
    }

    fn loss(&mut self, x: &[f32]) -> Result<f64> {
        self.evals += 1;
        Ok(self.eval(x))
    }

    fn two_point(&mut self, x: &[f32], z: &[f32], lam: f32) -> Result<(f64, f64)> {
        self.evals += 2;
        // evaluate without materializing x +- lam z
        let (mut lp, mut lm) = (0f64, 0f64);
        let lam = lam as f64;
        for i in 0..x.len() {
            let s = self.sigmas[i] as f64;
            let xp = x[i] as f64 + lam * z[i] as f64;
            let xm = x[i] as f64 - lam * z[i] as f64;
            lp += s * xp * xp;
            lm += s * xm * xm;
        }
        Ok((lp, lm))
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

// ---------------------------------------------------------------------------
// ModelObjective
// ---------------------------------------------------------------------------

/// Transformer loss via bound `loss`/`two_point` [`Session`]s (any
/// backend). Holds [`SharedSession`] handles — workspaces bind once and
/// every eval after that runs allocation-free — plus the current
/// minibatch. Each objective keeps its OWN batch source (data shard);
/// only the stateless execution sessions can be shared.
pub struct ModelObjective {
    loss_sess: SharedSession,
    two_point_sess: SharedSession,
    pub batch: Batch,
    source: Box<dyn BatchSource>,
    d_pad: usize,
    d_raw: usize,
    evals: u64,
}

/// Batch args for a session run (ids, targets, mask).
fn batch_args(batch: &Batch) -> [Arg<'_>; 3] {
    let dims = [batch.batch, batch.seq];
    [
        Arg::TensorI32(&batch.input_ids, vec![dims[0], dims[1]]),
        Arg::TensorI32(&batch.targets, vec![dims[0], dims[1]]),
        Arg::TensorF32(&batch.mask, vec![dims[0], dims[1]]),
    ]
}

impl ModelObjective {
    pub fn new(rt: &Runtime, preset: &str, source: Box<dyn BatchSource>) -> Result<Self> {
        let loss_sess = Rc::new(RefCell::new(rt.bind_kind(preset, "loss")?));
        let two_point_sess = Rc::new(RefCell::new(rt.bind_kind(preset, "two_point")?));
        Self::with_sessions(rt, preset, source, loss_sess, two_point_sess)
    }

    /// Build a replica over an EXISTING session pair (see
    /// [`ModelObjective::sessions`]): N distributed workers in one process
    /// share one bound two_point session — one forward scratch, one
    /// `WorkerPool` — instead of binding one per replica.
    pub fn with_sessions(
        rt: &Runtime,
        preset: &str,
        source: Box<dyn BatchSource>,
        loss_sess: SharedSession,
        two_point_sess: SharedSession,
    ) -> Result<Self> {
        let meta = rt.preset(preset)?.clone();
        for (sess, kind) in [(&loss_sess, "loss"), (&two_point_sess, "two_point")] {
            let spec = sess.borrow().spec().clone();
            if spec.preset != preset || spec.kind != kind {
                bail!(
                    "shared session {} (preset {:?}, kind {:?}) cannot serve a {preset} {kind} objective",
                    spec.name,
                    spec.preset,
                    spec.kind
                );
            }
        }
        let mut source = source;
        let batch = source.next_batch();
        Ok(ModelObjective {
            loss_sess,
            two_point_sess,
            batch,
            source,
            d_pad: meta.d_pad,
            d_raw: meta.d_raw,
            evals: 0,
        })
    }

    /// Clone handles to this objective's bound sessions for sharing with
    /// further replicas.
    pub fn sessions(&self) -> (SharedSession, SharedSession) {
        (self.loss_sess.clone(), self.two_point_sess.clone())
    }
}

impl Objective for ModelObjective {
    fn dim(&self) -> usize {
        self.d_pad
    }

    fn d_raw(&self) -> usize {
        self.d_raw
    }

    fn loss(&mut self, x: &[f32]) -> Result<f64> {
        self.evals += 1;
        let [ids, tgt, mask] = batch_args(&self.batch);
        let mut sess = self.loss_sess.borrow_mut();
        let outs = sess.run(&[Arg::VecF32(x), ids, tgt, mask])?;
        Ok(lit_f32(&outs[0])? as f64)
    }

    fn two_point(&mut self, x: &[f32], z: &[f32], lam: f32) -> Result<(f64, f64)> {
        self.evals += 2;
        // the paired fast path: one session call, shared scratch, same
        // minibatch for both evals (Definition 1)
        self.two_point_sess.borrow_mut().two_point(
            x,
            z,
            lam,
            &self.batch.input_ids,
            &self.batch.targets,
            &self.batch.mask,
        )
    }

    fn advance(&mut self) {
        self.batch = self.source.next_batch();
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

// ---------------------------------------------------------------------------
// AdapterObjective
// ---------------------------------------------------------------------------

/// The ZO oracle over a tenant's low-rank adapter: `x` is the
/// `plan.dim()`-sized adapter vector, the loss is
/// `f(base + delta(adapter))` on the tenant's own minibatch stream, and
/// `two_point` perturbs ONLY the adapter coordinates (the low-rank delta
/// fuses into the weight loads through
/// [`crate::vecmath::AdapterBinding`]; no materialized per-tenant weight
/// buffer exists). The base buffer and the [`AdapterSession`] are shared
/// across tenants — each objective owns nothing but its batch source.
pub struct AdapterObjective {
    sess: SharedAdapterSession,
    base: Rc<Vec<f32>>,
    pub batch: Batch,
    source: Box<dyn BatchSource>,
    dim: usize,
    evals: u64,
}

impl AdapterObjective {
    /// Bind a tenant over a shared session + shared base. The base must be
    /// the session preset's padded parameter buffer.
    pub fn new(
        sess: SharedAdapterSession,
        base: Rc<Vec<f32>>,
        source: Box<dyn BatchSource>,
    ) -> Result<Self> {
        let dim = {
            let s = sess.borrow();
            if base.len() != s.meta().d_pad {
                bail!(
                    "adapter objective: base has {} elements, preset {:?} wants d_pad {}",
                    base.len(),
                    s.meta().name,
                    s.meta().d_pad
                );
            }
            s.plan().dim()
        };
        let mut source = source;
        let batch = source.next_batch();
        Ok(AdapterObjective { sess, base, batch, source, dim, evals: 0 })
    }

    /// Clone the shared session handle for further tenants.
    pub fn session(&self) -> SharedAdapterSession {
        self.sess.clone()
    }
}

impl Objective for AdapterObjective {
    /// Adapter vectors have no pad lanes: every coordinate is live.
    fn dim(&self) -> usize {
        self.dim
    }

    fn d_raw(&self) -> usize {
        self.dim
    }

    fn loss(&mut self, x: &[f32]) -> Result<f64> {
        self.evals += 1;
        let b = &self.batch;
        let l = self.sess.borrow_mut().loss(
            &self.base,
            x,
            &b.input_ids,
            &b.targets,
            &b.mask,
            b.batch,
            b.seq,
        );
        Ok(l as f64)
    }

    fn two_point(&mut self, x: &[f32], z: &[f32], lam: f32) -> Result<(f64, f64)> {
        self.evals += 2;
        let b = &self.batch;
        let (lp, lm) = self.sess.borrow_mut().two_point(
            &self.base,
            x,
            z,
            lam,
            &b.input_ids,
            &b.targets,
            &b.mask,
            b.batch,
            b.seq,
        );
        Ok((lp as f64, lm as f64))
    }

    fn advance(&mut self) {
        self.batch = self.source.next_batch();
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

/// A trivial batch source cycling over a fixed dataset (tests/benches).
pub struct CyclicBatches {
    pub batches: Vec<Batch>,
    pub i: usize,
}

impl BatchSource for CyclicBatches {
    fn next_batch(&mut self) -> Batch {
        let b = self.batches[self.i % self.batches.len()].clone();
        self.i += 1;
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_matches_python_golden() {
        // pinned against python/tests/test_quadratic.py::test_golden_value
        let d = 1000usize;
        let mut q = NativeQuadratic::new(d);
        let x = vec![1f32; d];
        let got = q.loss(&x).unwrap();
        let r = (d as f64).powf(1.0 / (d as f64 - 1.0));
        let want = (1.0 / d as f64) * (r.powi(d as i32) - 1.0) / (r - 1.0);
        assert!((got - want).abs() / want < 1e-5, "{got} vs {want}");
    }

    #[test]
    fn quadratic_sigma_endpoints() {
        let q = NativeQuadratic::new(1000);
        assert!((q.sigmas[0] - 1e-3).abs() < 1e-9);
        assert!((q.sigmas[999] - 1.0).abs() < 2e-4);
        assert!(q.sigmas.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn two_point_consistent_with_loss() {
        let d = 64;
        let mut q = NativeQuadratic::new(d);
        let x: Vec<f32> = (0..d).map(|i| (i as f32 * 0.1).sin()).collect();
        let z: Vec<f32> = (0..d).map(|i| (i as f32 * 0.3).cos()).collect();
        let lam = 1e-2f32;
        let (lp, lm) = q.two_point(&x, &z, lam).unwrap();
        let xp: Vec<f32> = x.iter().zip(&z).map(|(a, b)| a + lam * b).collect();
        let xm: Vec<f32> = x.iter().zip(&z).map(|(a, b)| a - lam * b).collect();
        assert!((lp - q.loss(&xp).unwrap()).abs() < 1e-6);
        assert!((lm - q.loss(&xm).unwrap()).abs() < 1e-6);
        assert_eq!(q.evals(), 4);
    }

    #[test]
    fn quadratic_grad_matches_finite_difference() {
        let d = 32;
        let q = NativeQuadratic::new(d);
        let x: Vec<f32> = (0..d).map(|i| 0.5 + i as f32 * 0.01).collect();
        let mut g = vec![0f32; d];
        q.grad(&x, &mut g);
        let eps = 1e-3f32;
        for i in [0usize, 15, 31] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (q.eval(&xp) - q.eval(&xm)) / (2.0 * eps as f64);
            assert!((g[i] as f64 - fd).abs() < 1e-3, "coord {i}: {} vs {fd}", g[i]);
        }
    }

    #[test]
    fn cyclic_batches_cycle() {
        let mut src = CyclicBatches {
            batches: vec![Batch::zeros(1, 4), {
                let mut b = Batch::zeros(1, 4);
                b.input_ids[0] = 7;
                b
            }],
            i: 0,
        };
        let a = src.next_batch();
        let b = src.next_batch();
        let c = src.next_batch();
        assert_eq!(a.input_ids[0], 0);
        assert_eq!(b.input_ids[0], 7);
        assert_eq!(c, a);
    }
}
