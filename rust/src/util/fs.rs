//! Crash-safe filesystem primitives.
//!
//! [`atomic_write`] is the durability contract every snapshot-shaped
//! artifact in the tree goes through (CMZ1 checkpoints, run metrics,
//! `BENCH_native.json`): readers observe either the old complete file or
//! the new complete file, never a torn in-between, even across power loss.
//!
//! Protocol: write to a same-directory tempfile, `sync_all` it, `rename`
//! over the destination (atomic on POSIX when source and target share a
//! filesystem — which the same-directory placement guarantees), then fsync
//! the parent directory so the rename itself is durable. A crash at any
//! point leaves either the old file intact (possibly plus a stale
//! `.tmp-*` sibling, which a later writer ignores and overwrites) or the
//! new file fully in place.

use std::io::Write;
use std::path::Path;

use crate::util::error::{Context, Result};

/// Suffix marking in-flight tempfiles; stale ones (crash between write and
/// rename) are harmless and are reclaimed by the next write to the same
/// destination.
const TMP_SUFFIX: &str = ".tmp-atomic";

fn tmp_path(path: &Path) -> std::path::PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(TMP_SUFFIX);
    path.with_file_name(name)
}

/// Atomically replace `path` with `bytes`: same-dir tempfile → write →
/// `sync_all` → rename → parent-dir fsync. Creates missing parent
/// directories first.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    let tmp = tmp_path(path);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes).with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    // fsync the parent directory so the rename (the commit point) survives
    // power loss, not just the file contents
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("conmezo_fs_tests").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn writes_and_replaces() {
        let dir = tmpdir("replace");
        let p = dir.join("out.bin");
        atomic_write(&p, b"first").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        atomic_write(&p, b"second, longer contents").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second, longer contents");
        // no tempfile left behind on the happy path
        assert!(!tmp_path(&p).exists());
    }

    #[test]
    fn creates_parent_dirs() {
        let dir = tmpdir("parents");
        let p = dir.join("a/b/c/out.bin");
        atomic_write(&p, b"deep").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"deep");
    }

    #[test]
    fn crash_before_rename_leaves_old_file_intact() {
        // simulate a crash between tempfile write and rename: the stale
        // tempfile sits next to an untouched destination; the reader sees
        // the old contents and the next atomic_write reclaims the temp
        let dir = tmpdir("crash");
        let p = dir.join("out.bin");
        atomic_write(&p, b"committed").unwrap();
        std::fs::write(tmp_path(&p), b"torn half-writ").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"committed", "old file must survive");
        atomic_write(&p, b"recovered").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"recovered");
        assert!(!tmp_path(&p).exists(), "stale tempfile reclaimed");
    }
}
