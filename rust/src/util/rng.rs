//! Deterministic PRNG substrate (no external crates available offline).
//!
//! `Xoshiro256pp` (xoshiro256++) seeded through SplitMix64, plus Gaussian
//! sampling via the polar (Marsaglia) method. Determinism is a *system
//! requirement*, not a convenience: the distributed ZO trainer broadcasts a
//! 64-bit seed per step and every worker must regenerate the identical
//! perturbation direction bit-for-bit (the shared-randomness trick that
//! makes per-step communication O(1); DESIGN.md §4).

/// SplitMix64 — used to expand a single u64 seed into xoshiro state and to
/// derive independent per-purpose streams (`derive_stream`).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
    /// cached second Gaussian from the polar method
    spare: Option<f64>,
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 so that low-entropy seeds (0, 1, 2, ...) still
    /// produce well-distributed states.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream for (seed, purpose, index) — e.g. the
    /// direction stream for training step `t` is
    /// `derive_stream(run_seed, STREAM_DIRECTION, t)`.
    pub fn derive_stream(seed: u64, purpose: u64, index: u64) -> Self {
        // mix the three words through splitmix to decorrelate
        let mut sm = seed ^ purpose.rotate_left(24) ^ index.rotate_left(48);
        let a = splitmix64(&mut sm);
        let mut sm2 = a ^ index;
        Self::seed_from_u64(splitmix64(&mut sm2))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) by rejection-free Lemire reduction.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal (mean 0, std 1) via the polar method.
    pub fn next_normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let a = 2.0 * self.next_f64() - 1.0;
            let b = 2.0 * self.next_f64() - 1.0;
            let r = a * a + b * b;
            if r < 1.0 && r > 0.0 {
                let f = (-2.0 * r.ln() / r).sqrt();
                self.spare = Some(b * f);
                return a * f;
            }
        }
    }

    /// Fill a flat f32 buffer with iid standard normals (the perturbation
    /// direction u of Definition 1 / App. C.2).
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_normal() as f32;
        }
    }

    /// Fisher–Yates shuffle (used by the data batcher).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (reservoir when k << n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Stream purposes for `derive_stream` — keep these constants stable across
/// versions: checkpointed runs replay seeds recorded against them.
pub const STREAM_DIRECTION: u64 = 0x4449_5245_4354; // "DIRECT"
pub const STREAM_DATA: u64 = 0x4441_5441; // "DATA"
pub const STREAM_INIT: u64 = 0x494E_4954; // "INIT"

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256pp::seed_from_u64(1);
        let mut b = Xoshiro256pp::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let n = 200_000;
        let (mut s1, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = r.next_normal();
            s1 += x;
            s2 += x * x;
            s4 += x * x * x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        let kurt = s4 / n as f64 / (var * var);
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        assert!((kurt - 3.0).abs() < 0.1, "kurtosis {kurt}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.gen_range(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn derived_streams_are_independent() {
        let mut a = Xoshiro256pp::derive_stream(5, STREAM_DIRECTION, 0);
        let mut b = Xoshiro256pp::derive_stream(5, STREAM_DIRECTION, 1);
        let mut c = Xoshiro256pp::derive_stream(5, STREAM_DATA, 0);
        let x = a.next_u64();
        assert_ne!(x, b.next_u64());
        assert_ne!(x, c.next_u64());
        // replaying the same triple gives the same stream
        let mut a2 = Xoshiro256pp::derive_stream(5, STREAM_DIRECTION, 0);
        assert_eq!(x, a2.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256pp::seed_from_u64(13);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn fill_normal_f32_matches_scalar_path() {
        let mut a = Xoshiro256pp::seed_from_u64(21);
        let mut b = Xoshiro256pp::seed_from_u64(21);
        let mut buf = vec![0f32; 17];
        a.fill_normal_f32(&mut buf);
        for v in &buf {
            assert_eq!(*v, b.next_normal() as f32);
        }
    }
}
