//! Substrate utilities implemented from scratch for the offline build:
//! errors, PRNG, JSON, logging, memory accounting, and small helpers.

pub mod error;
pub mod fs;
pub mod json;
pub mod logging;
pub mod plot;
pub mod memory;
pub mod rng;

use std::time::Instant;

/// Simple stopwatch for coarse phase timing.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Mean and (population) standard deviation of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        assert!((s - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_basic() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}
