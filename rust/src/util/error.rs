//! Minimal error substrate (drop-in for the `anyhow` surface this crate
//! uses: `Result`, `anyhow!`, `bail!`, `Context`).
//!
//! The offline build has no registry access, so the crate must compile with
//! zero external dependencies. [`Error`] is a message string plus a context
//! chain; any `std::error::Error` converts into it via `?`, and the
//! [`Context`] extension trait layers human-readable context exactly like
//! anyhow's (`reading foo.json: No such file or directory`).

use std::fmt;

/// A string-backed error with a context chain (outermost context first).
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into(), context: Vec::new() }
    }

    pub fn push_context(mut self, ctx: impl Into<String>) -> Error {
        self.context.push(ctx.into());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // render outermost context first: "ctx2: ctx1: root cause"
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

// Any concrete std error converts via `?`. `Error` itself deliberately does
// NOT implement `std::error::Error`, which keeps this blanket impl coherent
// with the reflexive `From<Error> for Error` (the same trick anyhow uses).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option`, anyhow-style.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (anyhow's `anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => { $crate::util::error::Error::msg(format!($($t)*)) };
}

/// Early-return an error from a format string (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*).into()) };
}

// Let call sites keep `use crate::util::error::{anyhow, bail, ...}` even
// though #[macro_export] places the macros at the crate root.
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path/xyz")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    #[test]
    fn context_chains_render_outermost_first() {
        let e = io_fail().context("loading experiment").unwrap_err();
        let s = e.to_string();
        assert!(s.starts_with("loading experiment: reading config: "), "{s}");
    }

    #[test]
    fn anyhow_and_bail_macros() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn std_errors_convert_via_question_mark() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing key").unwrap_err().to_string(), "missing key");
    }
}
