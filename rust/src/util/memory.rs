//! Optimizer/model state byte accounting — the substitute for the paper's
//! GPU VRAM measurements (Fig. 4, Table 8; DESIGN.md §2).
//!
//! The paper's memory story is about *persistent state*: MeZO keeps only the
//! parameters; ConMeZO adds one momentum buffer (a constant Δ per model);
//! ZO-AdaMM adds a second-moment buffer; first-order AdamW adds gradients +
//! two moments + activation storage for backprop. `MemoryMeter` tracks named
//! allocations so every experiment reports peak bytes with the same
//! semantics across optimizers.

use std::collections::BTreeMap;

#[derive(Default, Debug, Clone)]
pub struct MemoryMeter {
    live: BTreeMap<String, usize>,
    current: usize,
    peak: usize,
}

pub const MIB: usize = 1024 * 1024;

impl MemoryMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a named persistent buffer of `bytes`. Re-recording a name
    /// replaces the old size (buffers are resized, not duplicated).
    pub fn alloc(&mut self, name: &str, bytes: usize) {
        if let Some(old) = self.live.insert(name.to_string(), bytes) {
            self.current -= old;
        }
        self.current += bytes;
        self.peak = self.peak.max(self.current);
    }

    /// Record a named buffer of `n` f32 elements.
    pub fn alloc_f32(&mut self, name: &str, n: usize) {
        self.alloc(name, n * 4);
    }

    /// Record a transient allocation that exists only within a step (e.g.
    /// the activation working set of one forward pass): raises the peak but
    /// not the persistent size.
    pub fn transient(&mut self, bytes: usize) {
        self.peak = self.peak.max(self.current + bytes);
    }

    pub fn free(&mut self, name: &str) {
        if let Some(old) = self.live.remove(name) {
            self.current -= old;
        }
    }

    pub fn current_bytes(&self) -> usize {
        self.current
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak
    }

    pub fn peak_mib(&self) -> f64 {
        self.peak as f64 / MIB as f64
    }

    /// Itemized live buffers (for the Table 8 breakdown).
    pub fn breakdown(&self) -> &BTreeMap<String, usize> {
        &self.live
    }
}

/// Estimate of the transformer forward-pass activation working set in bytes
/// for a [B, S] batch (used to make FO-vs-ZO peaks comparable: backprop must
/// retain activations, ZO releases them after each forward).
pub fn activation_bytes(batch: usize, seq: usize, d_model: usize, d_ff: usize, n_layers: usize, vocab: usize, retain_for_backprop: bool) -> usize {
    let per_layer = batch * seq * (4 * d_model + d_ff) * 4; // qkv+attn-out+mlp hidden
    let logits = batch * seq * vocab * 4;
    if retain_for_backprop {
        n_layers * per_layer + logits
    } else {
        // only one layer's working set is live at a time in inference
        per_layer + logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_max_of_current() {
        let mut m = MemoryMeter::new();
        m.alloc("params", 1000);
        m.alloc("momentum", 1000);
        assert_eq!(m.peak_bytes(), 2000);
        m.free("momentum");
        assert_eq!(m.current_bytes(), 1000);
        assert_eq!(m.peak_bytes(), 2000);
    }

    #[test]
    fn realloc_replaces() {
        let mut m = MemoryMeter::new();
        m.alloc("b", 500);
        m.alloc("b", 700);
        assert_eq!(m.current_bytes(), 700);
        assert_eq!(m.peak_bytes(), 700);
    }

    #[test]
    fn transient_raises_peak_only() {
        let mut m = MemoryMeter::new();
        m.alloc("params", 100);
        m.transient(1000);
        assert_eq!(m.current_bytes(), 100);
        assert_eq!(m.peak_bytes(), 1100);
    }

    #[test]
    fn mezo_vs_conmezo_vs_adamw_ordering() {
        // the Fig. 4 shape: AdamW >> ConMeZO > MeZO, with ConMeZO - MeZO a
        // constant equal to one parameter buffer.
        let d = 1_000_000;
        let mut mezo = MemoryMeter::new();
        mezo.alloc_f32("params", d);
        let mut con = MemoryMeter::new();
        con.alloc_f32("params", d);
        con.alloc_f32("momentum", d);
        let mut adamw = MemoryMeter::new();
        adamw.alloc_f32("params", d);
        adamw.alloc_f32("grad", d);
        adamw.alloc_f32("adam.mu", d);
        adamw.alloc_f32("adam.nu", d);
        assert!(mezo.peak_bytes() < con.peak_bytes());
        assert!(con.peak_bytes() < adamw.peak_bytes());
        assert_eq!(con.peak_bytes() - mezo.peak_bytes(), d * 4);
    }

    #[test]
    fn activation_estimate_backprop_dominates() {
        let inf = activation_bytes(8, 64, 128, 512, 6, 512, false);
        let bp = activation_bytes(8, 64, 128, 512, 6, 512, true);
        assert!(bp > 3 * inf);
    }
}
