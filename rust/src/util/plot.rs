//! ASCII line plots for terminal figure rendering (no plotting libs
//! offline). Used by the `repro` driver to sketch Fig. 1/3/6/7 curves next
//! to the JSON records.

/// Render one or more named series into a fixed-size ASCII canvas with a
/// log-y option (loss curves span decades).
pub struct Plot {
    pub width: usize,
    pub height: usize,
    pub log_y: bool,
    series: Vec<(String, Vec<(f64, f64)>, char)>,
}

const MARKS: [char; 6] = ['*', '+', 'o', 'x', '#', '@'];

impl Plot {
    pub fn new(width: usize, height: usize) -> Self {
        Plot { width, height, log_y: false, series: Vec::new() }
    }

    pub fn log_y(mut self) -> Self {
        self.log_y = true;
        self
    }

    pub fn series(&mut self, name: &str, points: &[(f64, f64)]) -> &mut Self {
        let mark = MARKS[self.series.len() % MARKS.len()];
        self.series.push((name.to_string(), points.to_vec(), mark));
        self
    }

    fn ty(&self, y: f64) -> f64 {
        if self.log_y {
            y.max(1e-30).log10()
        } else {
            y
        }
    }

    pub fn render(&self) -> String {
        let mut pts: Vec<(f64, f64)> = Vec::new();
        for (_, s, _) in &self.series {
            pts.extend(s.iter().map(|&(x, y)| (x, self.ty(y))));
        }
        if pts.is_empty() {
            return String::from("(empty plot)\n");
        }
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &pts {
            if x.is_finite() {
                x0 = x0.min(x);
                x1 = x1.max(x);
            }
            if y.is_finite() {
                y0 = y0.min(y);
                y1 = y1.max(y);
            }
        }
        if x1 <= x0 {
            x1 = x0 + 1.0;
        }
        if y1 <= y0 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (_, s, mark) in &self.series {
            for &(x, y) in s {
                let ty = self.ty(y);
                if !x.is_finite() || !ty.is_finite() {
                    continue;
                }
                let cx = ((x - x0) / (x1 - x0) * (self.width - 1) as f64).round() as usize;
                let cy = ((ty - y0) / (y1 - y0) * (self.height - 1) as f64).round() as usize;
                let row = self.height - 1 - cy.min(self.height - 1);
                grid[row][cx.min(self.width - 1)] = *mark;
            }
        }
        let mut out = String::new();
        let ylab = |v: f64| -> String {
            if self.log_y {
                format!("{:>9.2e}", 10f64.powf(v))
            } else {
                format!("{v:>9.3}")
            }
        };
        for (i, row) in grid.iter().enumerate() {
            let frac = 1.0 - i as f64 / (self.height - 1) as f64;
            let yv = y0 + frac * (y1 - y0);
            let label = if i == 0 || i == self.height - 1 || i == self.height / 2 {
                ylab(yv)
            } else {
                " ".repeat(9)
            };
            out.push_str(&format!("{label} |{}\n", row.iter().collect::<String>()));
        }
        out.push_str(&format!(
            "{} +{}\n{}  {:<w$.0}{:>w2$.0}\n",
            " ".repeat(9),
            "-".repeat(self.width),
            " ".repeat(9),
            x0,
            x1,
            w = self.width / 2,
            w2 = self.width - self.width / 2
        ));
        for (name, _, mark) in &self.series {
            out.push_str(&format!("{} {mark} {name}\n", " ".repeat(9)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_marks_in_bounds() {
        let mut p = Plot::new(40, 10);
        p.series("a", &[(0.0, 0.0), (10.0, 1.0), (20.0, 4.0)]);
        p.series("b", &[(0.0, 4.0), (20.0, 0.0)]);
        let s = p.render();
        assert!(s.contains('*') && s.contains('+'));
        assert!(s.contains("a\n") && s.contains("b\n"));
        // every line fits the canvas width + labels
        for line in s.lines() {
            assert!(line.len() <= 9 + 2 + 42, "{line}");
        }
    }

    #[test]
    fn log_scale_handles_decades() {
        let mut p = Plot::new(30, 8).log_y();
        p.series("loss", &[(0.0, 100.0), (1.0, 1.0), (2.0, 0.01)]);
        let s = p.render();
        assert!(s.contains("e"), "log labels expected: {s}");
    }

    #[test]
    fn empty_plot_does_not_panic() {
        let p = Plot::new(10, 5);
        assert!(p.render().contains("empty"));
    }

    #[test]
    fn constant_series_does_not_divide_by_zero() {
        let mut p = Plot::new(20, 6);
        p.series("flat", &[(0.0, 1.0), (5.0, 1.0)]);
        let s = p.render();
        assert!(s.contains('*'));
    }
}
