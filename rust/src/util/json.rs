//! Minimal JSON substrate (parser + emitter).
//!
//! The offline build has no registry dependencies at all, so the manifest
//! reader, metrics recorder, parity fixtures, and checkpoint metadata
//! implement JSON from scratch here. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII artifacts).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{anyhow, bail, Result};

/// A parsed JSON value. Object keys keep insertion order irrelevant; we use
/// a BTreeMap for deterministic emission.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- emit ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s);
        s
    }

    fn emit(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => emit_num(*n, out),
            Json::Str(s) => emit_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.emit(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_str(k, out);
                    out.push(':');
                    v.emit(out);
                }
                out.push('}');
            }
        }
    }

    // ---- parse -----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }
}

fn emit_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null like most tolerant writers
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: find the full sequence
                    let start = self.i - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    if start + len > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| {
            anyhow!("bad number {txt:?} at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "0", "-1.5", "3.25e2", "\"hi\""] {
            let v = Json::parse(t).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{t}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn emit_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_emitted_without_point() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-0.5).to_string(), "-0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"version": 1, "programs": [{"name": "nano_loss",
            "inputs": [{"name": "params", "dtype": "float32", "shape": [28672]}]}],
            "presets": {"nano": {"d_pad": 28672}}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.expect("version").unwrap().as_i64(), Some(1));
        let p = &v.expect("programs").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str(), Some("nano_loss"));
        let shape = p.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(28672));
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse(r#""é λ""#).unwrap();
        assert_eq!(v.as_str(), Some("é λ"));
    }
}
