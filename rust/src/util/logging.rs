//! Tiny leveled logger writing to stderr; level from `CONMEZO_LOG`
//! (error|warn|info|debug|trace, default info).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

/// Map a `CONMEZO_LOG` value to a level; the bool is true when the value
/// was present but unrecognized (caller warns once). Unset -> info.
fn parse_level(var: Option<&str>) -> (u8, bool) {
    match var {
        Some("error") => (0, false),
        Some("warn") => (1, false),
        Some("info") => (2, false),
        Some("debug") => (3, false),
        Some("trace") => (4, false),
        Some(_) => (2, true),
        None => (2, false),
    }
}

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let var = std::env::var("CONMEZO_LOG").ok();
    let (parsed, unrecognized) = parse_level(var.as_deref());
    // compare-exchange so exactly one caller transitions off the sentinel
    // and owns the one-time warning
    let first = LEVEL
        .compare_exchange(255, parsed, Ordering::Relaxed, Ordering::Relaxed)
        .is_ok();
    if first && unrecognized {
        let _ = writeln!(
            std::io::stderr().lock(),
            "[conmezo] unrecognized CONMEZO_LOG value {:?} (expected error|warn|info|debug|trace); defaulting to info",
            var.as_deref().unwrap_or("")
        );
    }
    parsed
}

/// Override the log level programmatically (tests, CLI flags).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if (l as u8) > level() {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let _ = writeln!(std::io::stderr().lock(), "[{t:.3} {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_documented_value_is_recognized() {
        // "info" used to fall through the match and only worked by accident
        assert_eq!(parse_level(Some("error")), (0, false));
        assert_eq!(parse_level(Some("warn")), (1, false));
        assert_eq!(parse_level(Some("info")), (2, false));
        assert_eq!(parse_level(Some("debug")), (3, false));
        assert_eq!(parse_level(Some("trace")), (4, false));
        assert_eq!(parse_level(None), (2, false));
    }

    #[test]
    fn unrecognized_values_default_to_info_and_flag_a_warning() {
        assert_eq!(parse_level(Some("verbose")), (2, true));
        assert_eq!(parse_level(Some("INFO")), (2, true), "values are case-sensitive");
        assert_eq!(parse_level(Some("")), (2, true));
    }
}
