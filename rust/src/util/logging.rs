//! Tiny leveled logger writing to stderr; level from `CONMEZO_LOG`
//! (error|warn|info|debug|trace, default info).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let parsed = match std::env::var("CONMEZO_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        Ok("trace") => 4,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the log level programmatically (tests, CLI flags).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if (l as u8) > level() {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let _ = writeln!(std::io::stderr().lock(), "[{t:.3} {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}
