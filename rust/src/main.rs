//! `conmezo` — the launcher CLI.
//!
//! Subcommands:
//!   train     finetune a preset on a task with any optimizer (config file
//!             + --set overrides)
//!   pretrain  build the pretrained checkpoint for a preset
//!   serve     run a multi-tenant adapter-finetuning workload from a
//!             manifest (N LoRA-style ZO jobs over one shared base)
//!   worker    join a distributed run (connect to a leader)
//!   leader    host a distributed run over TCP
//!   info      print artifact/platform info

use std::path::Path;

use conmezo::util::error::{bail, Result};
use conmezo::cli::App;
use conmezo::config::Config;
use conmezo::coordinator::{self, DistHypers, Mode, TrainConfig, Trainer, ZoWorker};
use conmezo::data::{TaskGen, TrainSampler};
use conmezo::net::{TcpTransport, Transport};
use conmezo::objective::ModelObjective;
use conmezo::optimizer::BetaSchedule;
use conmezo::runtime::{lit_vec_f32, Arg, ParallelPolicy, Runtime};
use conmezo::serve::{Server, ServeConfig};
use conmezo::util::json::Json;

fn app() -> App {
    App::new("conmezo", "gradient-free LLM finetuning (ConMeZO, AISTATS 2026)")
        .subcommand("train", "finetune a preset on a task")
        .subcommand("pretrain", "build a pretrained checkpoint")
        .subcommand("serve", "run a multi-tenant adapter-finetuning workload")
        .subcommand("leader", "host a distributed ZO run")
        .subcommand("worker", "join a distributed ZO run")
        .subcommand("trace-summary", "summarize a --trace JSONL step trace")
        .subcommand("info", "print artifacts / platform info")
        .opt_default("backend", "auto", "execution backend (native|pjrt|auto)")
        .opt("threads", "native worker-pool size for GEMMs + attention (0 = all cores, clamped to available cores; precedence: --threads > runtime.threads > CONMEZO_THREADS > 1)")
        .opt("simd", "explicit-SIMD kernel dispatch (auto|off; precedence: --simd > runtime.simd > CONMEZO_SIMD > runtime AVX2+FMA detection)")
        .opt("config", "TOML config file")
        .repeated("set", "config override key=value")
        .opt_default("preset", "tiny", "model preset (nano|tiny|small|medium)")
        .opt_default("task", "sst2", "task name (see data::tasks registry)")
        .opt_default("optimizer", "conmezo", "optimizer name")
        .opt_default("steps", "1000", "training steps")
        .opt_default("eta", "0.05", "learning rate")
        .opt_default("lam", "0.001", "smoothing parameter lambda")
        .opt_default("theta", "1.35", "cone half-angle")
        .opt_default("beta", "0.99", "final momentum beta")
        .opt_default("seed", "42", "run seed")
        .opt_default("mode", "fused", "execution mode (fused|composed)")
        .opt("init-from", "checkpoint to warm-start from")
        .flag("pretrained", "warm-start from the preset's pretrained ckpt (builds it if missing)")
        .flag("no-warmup", "disable the §3.4 beta warm-up")
        .opt_default("eval-every", "200", "evaluate every N steps")
        .opt_default("listen", "127.0.0.1:7070", "leader bind address")
        .opt_default("connect", "127.0.0.1:7070", "worker connect address")
        .opt_default("workers", "2", "expected worker count (leader)")
        .opt_default("worker-id", "0", "worker id = data shard index")
        .opt_default("proj-timeout-ms", "30000", "leader: max wait for a worker's Proj before skipping it (0 = block forever)")
        .opt_default("eval-timeout-ms", "120000", "leader: max wait for a worker's EvalResult (0 = block forever)")
        .opt_default("max-strikes", "3", "leader: consecutive timeouts before dropping a straggler")
        .opt_default("hash-check-every", "100", "leader: divergence tripwire period in steps (0 = only after rejoins)")
        .opt("step-log", "leader: persist the per-step replay WAL here (rejoin + restart substrate)")
        .opt_default("fsync", "every-step", "leader: WAL durability policy (every-step|every-N|close)")
        .flag("resume", "leader: rebuild state from the --step-log WAL after a crash")
        .opt("trace", "stream one JSONL StepTrace record per step here (train/leader)")
        .opt_default("metrics-every", "0", "leader: heartbeat-RTT + health line every N steps (0 = off)")
        .opt("manifest", "serve: tenant workload manifest file")
        .opt_default("ckpt-dir", "results/serve_ckpts", "serve: per-tenant checkpoint directory")
        .opt("quantum", "serve: override the manifest's round-robin quantum")
        .opt("ckpt", "worker: replica checkpoint path")
        .opt_default("ckpt-every", "0", "worker: checkpoint every N applied steps (0 = shutdown only)")
        .opt("die-at-step", "worker: fault injection - crash upon receiving Step N")
        .opt_default("reconnect", "0", "worker: reconnect attempts after a lost leader connection")
        .opt_default("out", "", "output JSON path for the run summary")
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = match app().parse(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    match p.subcommand.as_str() {
        "train" => cmd_train(&p),
        "pretrain" => cmd_pretrain(&p),
        "serve" => cmd_serve(&p),
        "leader" => cmd_leader(&p),
        "worker" => cmd_worker(&p),
        "trace-summary" => cmd_trace_summary(&p),
        "info" | "" => cmd_info(&p),
        other => bail!("unhandled subcommand {other}"),
    }
}

/// The layered config sources every subcommand accepts: `--config` file
/// with `--set` overrides on top.
fn load_file_cfg(p: &conmezo::cli::Parsed) -> Result<Config> {
    let mut file_cfg = match p.value("config") {
        Some(path) => Config::load(Path::new(path))?,
        None => Config::new(),
    };
    for kv in p.values("set") {
        file_cfg.set_from_str(kv)?;
    }
    Ok(file_cfg)
}

/// ParallelPolicy from the layered sources: explicit `--threads` beats the
/// config's `runtime.threads` beats the `CONMEZO_THREADS` env var. Every
/// layer resolves identically through `ParallelPolicy::from_count`: 0
/// means all cores, and explicit counts are clamped to
/// `std::thread::available_parallelism()`. An unparsable `--threads` is a
/// hard error, not a silent fallthrough.
fn thread_policy(p: &conmezo::cli::Parsed, file_cfg: &Config) -> Result<ParallelPolicy> {
    if let Some(s) = p.value("threads") {
        let n: usize = s.trim().parse().map_err(|_| {
            conmezo::anyhow!("--threads must be a non-negative integer (0 = all cores), got {s:?}")
        })?;
        return Ok(ParallelPolicy::from_count(n));
    }
    Ok(match file_cfg.get("runtime.threads").and_then(|v| v.as_f64()) {
        Some(n) if n >= 0.0 => ParallelPolicy::from_count(n as usize),
        _ => ParallelPolicy::from_env(),
    })
}

/// Apply the SIMD dispatch policy from the layered sources: an explicit
/// `--simd` beats the config's `runtime.simd` beats the `CONMEZO_SIMD` env
/// var (which `vecmath::simd` consults lazily when nothing explicit is
/// set, falling through to runtime AVX2+FMA detection). `auto` means
/// detect, `off` forces the always-compiled scalar fallback; results are
/// bit-identical either way — the knob trades speed, never numerics.
fn apply_simd_policy(p: &conmezo::cli::Parsed, file_cfg: &Config) -> Result<()> {
    use conmezo::vecmath::simd::{self, SimdPolicy};
    let chosen = match p.value("simd") {
        Some(s) => s.to_string(),
        None => file_cfg.str_or("runtime.simd", ""),
    };
    match chosen.as_str() {
        "" => {}
        "auto" => simd::set_policy(SimdPolicy::Auto),
        "off" => simd::set_policy(SimdPolicy::Off),
        other => bail!("--simd / runtime.simd must be auto or off, got {other:?}"),
    }
    Ok(())
}

/// (train config, backend name, thread policy) from the layered sources.
fn build_config(p: &conmezo::cli::Parsed) -> Result<(TrainConfig, String, ParallelPolicy)> {
    // layering: file < CLI flags < --set overrides
    let file_cfg = load_file_cfg(p)?;
    // an explicit --backend beats the config file (file < CLI flags); the
    // "auto" default defers to the file's runtime.backend when present
    let backend = match p.str_or("backend", "auto").as_str() {
        "auto" => file_cfg.str_or("runtime.backend", "auto"),
        explicit => explicit.to_string(),
    };
    let policy = thread_policy(p, &file_cfg)?;
    apply_simd_policy(p, &file_cfg)?;
    let mut cfg = TrainConfig::preset(
        &file_cfg.str_or("model.preset", &p.str_or("preset", "tiny")),
        &file_cfg.str_or("train.task", &p.str_or("task", "sst2")),
        &file_cfg.str_or("train.optimizer", &p.str_or("optimizer", "conmezo")),
    );
    cfg.steps = file_cfg.usize_or("train.steps", p.usize_or("steps", 1000));
    cfg.eta = file_cfg.f64_or("train.eta", p.f64_or("eta", 0.05)) as f32;
    cfg.lam = file_cfg.f64_or("train.lam", p.f64_or("lam", 1e-3)) as f32;
    cfg.theta = file_cfg.f64_or("train.theta", p.f64_or("theta", 1.35)) as f32;
    cfg.beta_final = file_cfg.f64_or("train.beta", p.f64_or("beta", 0.99)) as f32;
    cfg.warmup = !p.flag("no-warmup") && file_cfg.bool_or("train.warmup", true);
    cfg.seed = file_cfg.i64_or("train.seed", p.usize_or("seed", 42) as i64) as u64;
    cfg.eval_every = file_cfg.usize_or("train.eval_every", p.usize_or("eval-every", 200));
    cfg.mode = match file_cfg.str_or("train.mode", &p.str_or("mode", "fused")).as_str() {
        "composed" => Mode::Composed,
        _ => Mode::Fused,
    };
    if let Some(path) = p.value("init-from") {
        cfg.init_from = Some(path.into());
    }
    if let Some(path) = p.value("trace") {
        cfg.trace = Some(path.into());
    }
    Ok((cfg, backend, policy))
}

fn cmd_train(p: &conmezo::cli::Parsed) -> Result<()> {
    let (mut cfg, backend, policy) = build_config(p)?;
    let rt = Runtime::from_name_with(&backend, policy)?;
    if p.flag("pretrained") && cfg.init_from.is_none() {
        cfg.init_from = Some(coordinator::ensure_pretrained(&rt, &cfg.preset, 400, 1e-3, 0.3)?);
    }
    println!(
        "training {} on {} with {} ({} steps, mode {:?}, backend {})",
        cfg.preset, cfg.task, cfg.optimizer, cfg.steps, cfg.mode, rt.platform()
    );
    let mut tr = Trainer::new(&rt, cfg)?;
    let summary = tr.run()?;
    println!(
        "done: final loss {:.4}, accuracy {:.3}, {:.2} steps/s, peak mem {:.1} MiB",
        summary.final_loss, summary.final_accuracy, summary.steps_per_sec, summary.peak_mem_mib
    );
    let out = p.str_or("out", "");
    if !out.is_empty() {
        let mut rec = coordinator::RunRecord::new(Path::new(&out).file_stem().unwrap().to_str().unwrap());
        rec.meta_str("task", &summary.task).meta_str("optimizer", &summary.optimizer);
        rec.meta_num("final_accuracy", summary.final_accuracy);
        rec.meta_num("final_loss", summary.final_loss);
        for (s, l) in &summary.loss_curve {
            rec.row(vec![("step", Json::num(*s as f64)), ("loss", Json::num(*l))]);
        }
        let dir = Path::new(&out).parent().unwrap_or(Path::new("results"));
        rec.save_in(dir)?;
    }
    Ok(())
}

fn cmd_pretrain(p: &conmezo::cli::Parsed) -> Result<()> {
    let file_cfg = load_file_cfg(p)?;
    let policy = thread_policy(p, &file_cfg)?;
    apply_simd_policy(p, &file_cfg)?;
    let rt = Runtime::from_name_with(&p.str_or("backend", "auto"), policy)?;
    let preset = p.str_or("preset", "tiny");
    let steps = p.usize_or("steps", 400);
    let path = coordinator::pretrained_path(&preset);
    let curve = coordinator::pretrain(&rt, &preset, steps, 1e-3, 0.3, p.usize_or("seed", 7) as u64, &path)?;
    println!("pretrained {preset} for {steps} steps -> {}", path.display());
    if let Some((_, l)) = curve.last() {
        println!("final LM loss {l:.4}");
    }
    Ok(())
}

fn cmd_serve(p: &conmezo::cli::Parsed) -> Result<()> {
    let file_cfg = load_file_cfg(p)?;
    let policy = thread_policy(p, &file_cfg)?;
    apply_simd_policy(p, &file_cfg)?;
    let rt = Runtime::from_name_with(&p.str_or("backend", "auto"), policy)?;
    let manifest = p
        .value("manifest")
        .ok_or_else(|| conmezo::anyhow!("serve needs --manifest <workload file>"))?;
    let mut cfg = ServeConfig::load(Path::new(manifest))?;
    if let Some(q) = p.value("quantum") {
        cfg.quantum = q
            .trim()
            .parse()
            .map_err(|_| conmezo::anyhow!("--quantum must be a positive integer, got {q:?}"))?;
        if cfg.quantum == 0 {
            bail!("--quantum must be >= 1");
        }
    }
    let ckpt_dir = p.str_or("ckpt-dir", "results/serve_ckpts");
    println!(
        "serving {} tenants from {manifest} (quantum {}, backend {})",
        cfg.tenants.len(),
        cfg.quantum,
        rt.platform()
    );
    let mut server = Server::new(&rt, cfg, ckpt_dir.into())?;
    let report = server.run()?;
    for j in &report.jobs {
        println!("{}", j.summary_line());
    }
    println!(
        "serve complete: {} tenants, peak mem {:.1} MiB",
        report.jobs.len(),
        server.meter().peak_mib()
    );
    Ok(())
}

/// `--*-timeout-ms` flags: 0 means "block forever" (lockstep semantics).
fn timeout_opt(p: &conmezo::cli::Parsed, name: &str, default: usize) -> Option<std::time::Duration> {
    match p.usize_or(name, default) {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms as u64)),
    }
}

fn cmd_leader(p: &conmezo::cli::Parsed) -> Result<()> {
    let addr = p.str_or("listen", "127.0.0.1:7070");
    let n = p.usize_or("workers", 2);
    let steps = p.usize_or("steps", 1000) as u64;
    let hypers = DistHypers {
        theta: p.f64_or("theta", 1.35) as f32,
        eta: p.f64_or("eta", 0.05) as f32,
        lam: p.f64_or("lam", 1e-3) as f32,
    };
    let beta = BetaSchedule::PaperWarmup {
        beta_final: p.f64_or("beta", 0.99) as f32,
        total_steps: steps as usize,
    };
    let seed = p.usize_or("seed", 42) as u64;
    let mut cfg = coordinator::LeaderConfig::new(n as u32, seed, steps, hypers, beta);
    cfg.eval_every = p.usize_or("eval-every", 200) as u64;
    cfg.proj_timeout = timeout_opt(p, "proj-timeout-ms", 30_000);
    cfg.eval_timeout = timeout_opt(p, "eval-timeout-ms", 120_000);
    cfg.max_strikes = p.usize_or("max-strikes", 3) as u32;
    cfg.hash_check_every = p.usize_or("hash-check-every", 100) as u64;
    cfg.step_log = p.value("step-log").map(|s| s.into());
    cfg.fsync = conmezo::checkpoint::FsyncPolicy::parse(&p.str_or("fsync", "every-step"))?;
    cfg.metrics_every = p.usize_or("metrics-every", 0) as u64;
    cfg.trace = p.value("trace").map(|s| s.into());
    // socket-level I/O bound: hung peers error out instead of blocking the
    // whole cluster (handshakes and sends included)
    let io_timeout = cfg.proj_timeout;

    let leader = if p.flag("resume") {
        let l = coordinator::Leader::resume(cfg, p.value("init-from").map(Path::new))?;
        println!("leader: resumed from WAL at step {}", l.t());
        l
    } else {
        coordinator::Leader::new(cfg)
    };
    println!(
        "leader: waiting for {n} workers on {addr} (protocol v{})",
        conmezo::net::PROTO_VERSION
    );
    let listener = std::net::TcpListener::bind(&addr)?;
    let mut conns: Vec<Box<dyn Transport>> = Vec::new();
    for i in 0..n {
        let (s, peer) = listener.accept()?;
        println!("leader: worker connection {i} from {peer}");
        let mut t = TcpTransport::new(s)?;
        t.set_timeouts(io_timeout, io_timeout)?;
        conns.push(Box::new(t));
    }
    // after initial registration the accept loop goes non-blocking: the
    // leader polls it between steps so crashed workers can rejoin mid-run
    listener.set_nonblocking(true)?;
    let summary = leader.run_with_joiner(conns, |_t| {
        let mut joined: Vec<Box<dyn Transport>> = Vec::new();
        loop {
            match listener.accept() {
                Ok((s, peer)) => {
                    println!("leader: (re)join connection from {peer}");
                    match TcpTransport::new(s) {
                        Ok(mut t) => {
                            if t.set_timeouts(io_timeout, io_timeout).is_ok() {
                                joined.push(Box::new(t));
                            }
                        }
                        Err(e) => eprintln!("leader: bad connection from {peer}: {e}"),
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => {
                    eprintln!("leader: accept failed: {e}");
                    break;
                }
            }
        }
        joined
    })?;
    println!(
        "distributed run done: {} steps, {:.1} B/step/worker wire (+{} B control), final loss {:.4}",
        summary.steps,
        summary.wire_bytes as f64 / summary.steps as f64 / n as f64,
        summary.control_bytes,
        summary.loss_curve.last().map(|x| x.1).unwrap_or(f64::NAN)
    );
    if summary.straggler_events + summary.workers_lost + summary.rejoins > 0 {
        println!(
            "fault events: {} straggler timeouts, {} workers dropped, {} rejoins",
            summary.straggler_events, summary.workers_lost, summary.rejoins
        );
    }
    for (t, acc) in &summary.eval_curve {
        println!("  eval@{t}: {acc:.3}");
    }
    Ok(())
}

fn cmd_worker(p: &conmezo::cli::Parsed) -> Result<()> {
    let file_cfg = load_file_cfg(p)?;
    let policy = thread_policy(p, &file_cfg)?;
    apply_simd_policy(p, &file_cfg)?;
    let rt = Runtime::from_name_with(&p.str_or("backend", "auto"), policy)?;
    let preset = p.str_or("preset", "tiny");
    let task = p.str_or("task", "sst2");
    let id = p.usize_or("worker-id", 0) as u32;
    let seed = p.usize_or("seed", 42) as u64;
    let meta = rt.preset(&preset)?.clone();
    let spec = conmezo::data::spec(&task).ok_or_else(|| conmezo::anyhow!("unknown task {task}"))?;
    let gen = TaskGen::new(spec, meta.vocab, meta.seq_len);
    let train = gen.dataset(256, seed);
    let evalset = gen.dataset(64, seed ^ 0xEEE ^ id as u64);
    // every worker shards data by its own sampler stream (worker id)
    let sampler = TrainSampler::new(train, meta.batch, meta.seq_len, seed, id as u64);
    let obj = ModelObjective::new(&rt, &preset, Box::new(sampler))?;

    // warm-start from a snapshot when one exists (rejoin after a crash);
    // otherwise the shared init program gives every worker identical
    // initial params
    let mut w = match p.value("init-from").map(Path::new) {
        Some(path) if path.exists() => {
            let ckpt = conmezo::checkpoint::Checkpoint::load(path)?;
            println!("worker {id}: warm-starting from {} (step {})", path.display(), ckpt.step);
            ZoWorker::from_checkpoint(id, &ckpt, Box::new(obj))?
        }
        other => {
            if let Some(path) = other {
                println!("worker {id}: {} not found, starting fresh", path.display());
            }
            let init = rt.load_kind(&preset, "init")?;
            let params = lit_vec_f32(&init.call(&[Arg::I32(seed as i32)])?[0])?;
            ZoWorker::new(id, params, Box::new(obj))
        }
    };
    let evaluator = coordinator::Evaluator::new(&rt, &preset, evalset)?;
    w.eval_fn = Some(Box::new(move |x: &[f32]| {
        match evaluator.evaluate(x) {
            Ok(r) => (r.correct as u64, r.total as u64),
            Err(_) => (0, 0),
        }
    }));

    let opts = coordinator::WorkerOpts {
        preset: preset.clone(),
        ckpt: p.value("ckpt").map(|s| s.into()),
        ckpt_every: p.usize_or("ckpt-every", 0) as u64,
        die_at_step: p.value("die-at-step").and_then(|s| s.parse().ok()),
    };
    let addr = p.str_or("connect", "127.0.0.1:7070");
    let mut reconnects = p.usize_or("reconnect", 0);
    loop {
        println!("worker {id}: connecting to {addr} (at step {})", w.t);
        let mut conn = TcpTransport::connect_retry(
            &addr,
            id,
            20,
            std::time::Duration::from_millis(250),
            std::time::Duration::from_secs(5),
        )?;
        match coordinator::run_worker_with(&mut conn, &mut w, &opts) {
            Ok(()) => break,
            Err(e) => {
                use conmezo::net::TransportErrorKind as K;
                // retry only what the transport layer classified as a
                // connection-level failure; injected crashes, handshake
                // rejections and divergence bails must not loop
                let retryable = matches!(
                    K::classify(&e),
                    Some(K::Timeout) | Some(K::Closed) | Some(K::Corrupt)
                );
                if reconnects == 0 || !retryable {
                    return Err(e);
                }
                reconnects -= 1;
                eprintln!(
                    "worker {id}: connection lost at step {}: {e}; reconnecting ({reconnects} retries left)",
                    w.t
                );
                std::thread::sleep(std::time::Duration::from_millis(500));
            }
        }
    }
    println!("worker {id}: shutdown at t={} params_hash={:016x}", w.t, w.params_hash());
    Ok(())
}

/// `conmezo trace-summary run.jsonl`: per-field percentiles of a step
/// trace, rendered as an aligned table.
fn cmd_trace_summary(p: &conmezo::cli::Parsed) -> Result<()> {
    let path = match p.positional.first() {
        Some(s) => Path::new(s),
        None => bail!("usage: conmezo trace-summary <trace.jsonl>"),
    };
    let trace = conmezo::telemetry::read_trace(path)?;
    if trace.is_empty() {
        bail!("{}: no step records", path.display());
    }
    println!("{}: {} steps", path.display(), trace.len());

    let fields: [(&str, fn(&conmezo::telemetry::StepTrace) -> f64); 6] = [
        ("loss", |r| r.loss),
        ("loss_plus", |r| r.loss_plus),
        ("loss_minus", |r| r.loss_minus),
        ("proj_grad", |r| r.proj_grad),
        ("cos_zm", |r| r.cos_zm),
        ("wall_ms", |r| r.wall_s * 1e3),
    ];
    let fmt = |v: f64| if v.is_nan() { "-".to_string() } else { format!("{v:.4}") };
    let mut rows = Vec::new();
    for (name, get) in fields {
        // nulls on the wire parse back as NaN; summarize what's present
        let xs: Vec<f64> = trace.iter().map(get).filter(|v| v.is_finite()).collect();
        let (mean, _) = conmezo::util::mean_std(&xs);
        rows.push(vec![
            name.to_string(),
            xs.len().to_string(),
            fmt(mean),
            fmt(conmezo::util::percentile(&xs, 50.0)),
            fmt(conmezo::util::percentile(&xs, 90.0)),
            fmt(conmezo::util::percentile(&xs, 99.0)),
        ]);
    }
    print!(
        "{}",
        coordinator::metrics::render_table(&["field", "n", "mean", "p50", "p90", "p99"], &rows)
    );
    let first = trace.first().unwrap();
    let last = trace.last().unwrap();
    println!(
        "steps {}..{}  eta={}  loss {} -> {}",
        first.step,
        last.step,
        fmt(first.eta),
        fmt(first.loss),
        fmt(last.loss)
    );
    Ok(())
}

fn cmd_info(p: &conmezo::cli::Parsed) -> Result<()> {
    apply_simd_policy(p, &load_file_cfg(p)?)?;
    let rt = Runtime::from_name(&p.str_or("backend", "auto"))?;
    println!("platform: {}", rt.platform());
    println!("simd: {}", conmezo::vecmath::simd::status());
    println!("programs: {}", rt.manifest().programs.len());
    for (name, preset) in &rt.manifest().presets {
        println!(
            "  preset {name}: d={} (pad {}), vocab {}, {} layers, seq {}",
            preset.d_raw, preset.d_pad, preset.vocab, preset.n_layers, preset.seq_len
        );
    }
    Ok(())
}
