//! `conmezo` — the launcher CLI.
//!
//! Subcommands:
//!   train     finetune a preset on a task with any optimizer (config file
//!             + --set overrides)
//!   pretrain  build the pretrained checkpoint for a preset
//!   worker    join a distributed run (connect to a leader)
//!   leader    host a distributed run over TCP
//!   info      print artifact/platform info

use std::path::Path;

use conmezo::util::error::{bail, Result};
use conmezo::cli::App;
use conmezo::config::Config;
use conmezo::coordinator::{self, DistHypers, Mode, TrainConfig, Trainer, ZoWorker};
use conmezo::data::{TaskGen, TrainSampler};
use conmezo::net::{TcpTransport, Transport};
use conmezo::objective::ModelObjective;
use conmezo::optimizer::BetaSchedule;
use conmezo::runtime::{lit_vec_f32, Arg, ParallelPolicy, Runtime};
use conmezo::util::json::Json;

fn app() -> App {
    App::new("conmezo", "gradient-free LLM finetuning (ConMeZO, AISTATS 2026)")
        .subcommand("train", "finetune a preset on a task")
        .subcommand("pretrain", "build a pretrained checkpoint")
        .subcommand("leader", "host a distributed ZO run")
        .subcommand("worker", "join a distributed ZO run")
        .subcommand("info", "print artifacts / platform info")
        .opt_default("backend", "auto", "execution backend (native|pjrt|auto)")
        .opt("threads", "native worker-pool size for GEMMs + attention (0 = all cores, clamped to available cores; precedence: --threads > runtime.threads > CONMEZO_THREADS > 1)")
        .opt("config", "TOML config file")
        .repeated("set", "config override key=value")
        .opt_default("preset", "tiny", "model preset (nano|tiny|small|medium)")
        .opt_default("task", "sst2", "task name (see data::tasks registry)")
        .opt_default("optimizer", "conmezo", "optimizer name")
        .opt_default("steps", "1000", "training steps")
        .opt_default("eta", "0.05", "learning rate")
        .opt_default("lam", "0.001", "smoothing parameter lambda")
        .opt_default("theta", "1.35", "cone half-angle")
        .opt_default("beta", "0.99", "final momentum beta")
        .opt_default("seed", "42", "run seed")
        .opt_default("mode", "fused", "execution mode (fused|composed)")
        .opt("init-from", "checkpoint to warm-start from")
        .flag("pretrained", "warm-start from the preset's pretrained ckpt (builds it if missing)")
        .flag("no-warmup", "disable the §3.4 beta warm-up")
        .opt_default("eval-every", "200", "evaluate every N steps")
        .opt_default("listen", "127.0.0.1:7070", "leader bind address")
        .opt_default("connect", "127.0.0.1:7070", "worker connect address")
        .opt_default("workers", "2", "expected worker count (leader)")
        .opt_default("worker-id", "0", "worker id")
        .opt_default("out", "", "output JSON path for the run summary")
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = match app().parse(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    match p.subcommand.as_str() {
        "train" => cmd_train(&p),
        "pretrain" => cmd_pretrain(&p),
        "leader" => cmd_leader(&p),
        "worker" => cmd_worker(&p),
        "info" | "" => cmd_info(&p),
        other => bail!("unhandled subcommand {other}"),
    }
}

/// The layered config sources every subcommand accepts: `--config` file
/// with `--set` overrides on top.
fn load_file_cfg(p: &conmezo::cli::Parsed) -> Result<Config> {
    let mut file_cfg = match p.value("config") {
        Some(path) => Config::load(Path::new(path))?,
        None => Config::new(),
    };
    for kv in p.values("set") {
        file_cfg.set_from_str(kv)?;
    }
    Ok(file_cfg)
}

/// ParallelPolicy from the layered sources: explicit `--threads` beats the
/// config's `runtime.threads` beats the `CONMEZO_THREADS` env var. Every
/// layer resolves identically through `ParallelPolicy::from_count`: 0
/// means all cores, and explicit counts are clamped to
/// `std::thread::available_parallelism()`. An unparsable `--threads` is a
/// hard error, not a silent fallthrough.
fn thread_policy(p: &conmezo::cli::Parsed, file_cfg: &Config) -> Result<ParallelPolicy> {
    if let Some(s) = p.value("threads") {
        let n: usize = s.trim().parse().map_err(|_| {
            conmezo::anyhow!("--threads must be a non-negative integer (0 = all cores), got {s:?}")
        })?;
        return Ok(ParallelPolicy::from_count(n));
    }
    Ok(match file_cfg.get("runtime.threads").and_then(|v| v.as_f64()) {
        Some(n) if n >= 0.0 => ParallelPolicy::from_count(n as usize),
        _ => ParallelPolicy::from_env(),
    })
}

/// (train config, backend name, thread policy) from the layered sources.
fn build_config(p: &conmezo::cli::Parsed) -> Result<(TrainConfig, String, ParallelPolicy)> {
    // layering: file < CLI flags < --set overrides
    let file_cfg = load_file_cfg(p)?;
    // an explicit --backend beats the config file (file < CLI flags); the
    // "auto" default defers to the file's runtime.backend when present
    let backend = match p.str_or("backend", "auto").as_str() {
        "auto" => file_cfg.str_or("runtime.backend", "auto"),
        explicit => explicit.to_string(),
    };
    let policy = thread_policy(p, &file_cfg)?;
    let mut cfg = TrainConfig::preset(
        &file_cfg.str_or("model.preset", &p.str_or("preset", "tiny")),
        &file_cfg.str_or("train.task", &p.str_or("task", "sst2")),
        &file_cfg.str_or("train.optimizer", &p.str_or("optimizer", "conmezo")),
    );
    cfg.steps = file_cfg.usize_or("train.steps", p.usize_or("steps", 1000));
    cfg.eta = file_cfg.f64_or("train.eta", p.f64_or("eta", 0.05)) as f32;
    cfg.lam = file_cfg.f64_or("train.lam", p.f64_or("lam", 1e-3)) as f32;
    cfg.theta = file_cfg.f64_or("train.theta", p.f64_or("theta", 1.35)) as f32;
    cfg.beta_final = file_cfg.f64_or("train.beta", p.f64_or("beta", 0.99)) as f32;
    cfg.warmup = !p.flag("no-warmup") && file_cfg.bool_or("train.warmup", true);
    cfg.seed = file_cfg.i64_or("train.seed", p.usize_or("seed", 42) as i64) as u64;
    cfg.eval_every = file_cfg.usize_or("train.eval_every", p.usize_or("eval-every", 200));
    cfg.mode = match file_cfg.str_or("train.mode", &p.str_or("mode", "fused")).as_str() {
        "composed" => Mode::Composed,
        _ => Mode::Fused,
    };
    if let Some(path) = p.value("init-from") {
        cfg.init_from = Some(path.into());
    }
    Ok((cfg, backend, policy))
}

fn cmd_train(p: &conmezo::cli::Parsed) -> Result<()> {
    let (mut cfg, backend, policy) = build_config(p)?;
    let rt = Runtime::from_name_with(&backend, policy)?;
    if p.flag("pretrained") && cfg.init_from.is_none() {
        cfg.init_from = Some(coordinator::ensure_pretrained(&rt, &cfg.preset, 400, 1e-3, 0.3)?);
    }
    println!(
        "training {} on {} with {} ({} steps, mode {:?}, backend {})",
        cfg.preset, cfg.task, cfg.optimizer, cfg.steps, cfg.mode, rt.platform()
    );
    let mut tr = Trainer::new(&rt, cfg)?;
    let summary = tr.run()?;
    println!(
        "done: final loss {:.4}, accuracy {:.3}, {:.2} steps/s, peak mem {:.1} MiB",
        summary.final_loss, summary.final_accuracy, summary.steps_per_sec, summary.peak_mem_mib
    );
    let out = p.str_or("out", "");
    if !out.is_empty() {
        let mut rec = coordinator::RunRecord::new(Path::new(&out).file_stem().unwrap().to_str().unwrap());
        rec.meta_str("task", &summary.task).meta_str("optimizer", &summary.optimizer);
        rec.meta_num("final_accuracy", summary.final_accuracy);
        rec.meta_num("final_loss", summary.final_loss);
        for (s, l) in &summary.loss_curve {
            rec.row(vec![("step", Json::num(*s as f64)), ("loss", Json::num(*l))]);
        }
        let dir = Path::new(&out).parent().unwrap_or(Path::new("results"));
        rec.save_in(dir)?;
    }
    Ok(())
}

fn cmd_pretrain(p: &conmezo::cli::Parsed) -> Result<()> {
    let policy = thread_policy(p, &load_file_cfg(p)?)?;
    let rt = Runtime::from_name_with(&p.str_or("backend", "auto"), policy)?;
    let preset = p.str_or("preset", "tiny");
    let steps = p.usize_or("steps", 400);
    let path = coordinator::pretrained_path(&preset);
    let curve = coordinator::pretrain(&rt, &preset, steps, 1e-3, 0.3, p.usize_or("seed", 7) as u64, &path)?;
    println!("pretrained {preset} for {steps} steps -> {}", path.display());
    if let Some((_, l)) = curve.last() {
        println!("final LM loss {l:.4}");
    }
    Ok(())
}

fn cmd_leader(p: &conmezo::cli::Parsed) -> Result<()> {
    let addr = p.str_or("listen", "127.0.0.1:7070");
    let n = p.usize_or("workers", 2);
    let steps = p.usize_or("steps", 1000) as u64;
    let hypers = DistHypers {
        theta: p.f64_or("theta", 1.35) as f32,
        eta: p.f64_or("eta", 0.05) as f32,
        lam: p.f64_or("lam", 1e-3) as f32,
    };
    let beta = BetaSchedule::PaperWarmup {
        beta_final: p.f64_or("beta", 0.99) as f32,
        total_steps: steps as usize,
    };
    println!("leader: waiting for {n} workers on {addr}");
    let listener = std::net::TcpListener::bind(&addr)?;
    let mut conns: Vec<Box<dyn Transport>> = Vec::new();
    for i in 0..n {
        let (s, peer) = listener.accept()?;
        println!("worker {i} connected from {peer}");
        conns.push(Box::new(TcpTransport::new(s)?));
    }
    let seed = p.usize_or("seed", 42) as u64;
    let summary = coordinator::run_leader(&mut conns, seed, steps, hypers, &beta, p.usize_or("eval-every", 200) as u64)?;
    println!(
        "distributed run done: {} steps, {:.1} B/step/worker on the wire, final loss {:.4}",
        summary.steps,
        summary.wire_bytes as f64 / summary.steps as f64 / n as f64,
        summary.loss_curve.last().map(|x| x.1).unwrap_or(f64::NAN)
    );
    for (t, acc) in &summary.eval_curve {
        println!("  eval@{t}: {acc:.3}");
    }
    Ok(())
}

fn cmd_worker(p: &conmezo::cli::Parsed) -> Result<()> {
    let policy = thread_policy(p, &load_file_cfg(p)?)?;
    let rt = Runtime::from_name_with(&p.str_or("backend", "auto"), policy)?;
    let preset = p.str_or("preset", "tiny");
    let task = p.str_or("task", "sst2");
    let id = p.usize_or("worker-id", 0) as u32;
    let seed = p.usize_or("seed", 42) as u64;
    let meta = rt.preset(&preset)?.clone();
    let spec = conmezo::data::spec(&task).ok_or_else(|| conmezo::anyhow!("unknown task {task}"))?;
    let gen = TaskGen::new(spec, meta.vocab, meta.seq_len);
    let train = gen.dataset(256, seed);
    let evalset = gen.dataset(64, seed ^ 0xEEE ^ id as u64);
    // every worker shards data by its own sampler stream (worker id)
    let sampler = TrainSampler::new(train, meta.batch, meta.seq_len, seed, id as u64);
    let obj = ModelObjective::new(&rt, &preset, Box::new(sampler))?;

    // identical initial params on every worker: the shared init program
    let init = rt.load_kind(&preset, "init")?;
    let params = lit_vec_f32(&init.call(&[Arg::I32(seed as i32)])?[0])?;
    let mut w = ZoWorker::new(id, params, Box::new(obj));
    let evaluator = coordinator::Evaluator::new(&rt, &preset, evalset)?;
    w.eval_fn = Some(Box::new(move |x: &[f32]| {
        match evaluator.evaluate(x) {
            Ok(r) => (r.correct as u64, r.total as u64),
            Err(_) => (0, 0),
        }
    }));

    let addr = p.str_or("connect", "127.0.0.1:7070");
    println!("worker {id}: connecting to {addr}");
    let mut conn = TcpTransport::connect(&addr)?;
    coordinator::run_worker(&mut conn, &mut w)?;
    println!("worker {id}: shutdown");
    Ok(())
}

fn cmd_info(p: &conmezo::cli::Parsed) -> Result<()> {
    let rt = Runtime::from_name(&p.str_or("backend", "auto"))?;
    println!("platform: {}", rt.platform());
    println!("programs: {}", rt.manifest().programs.len());
    for (name, preset) in &rt.manifest().presets {
        println!(
            "  preset {name}: d={} (pad {}), vocab {}, {} layers, seq {}",
            preset.d_raw, preset.d_pad, preset.vocab, preset.n_layers, preset.seq_len
        );
    }
    Ok(())
}
