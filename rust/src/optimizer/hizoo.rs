//! HiZOO (Zhao et al. 2025): Hessian-informed zeroth-order optimizer
//! (Table 4 baseline).
//!
//! Per step, THREE function evaluations estimate both the directional
//! gradient and the local curvature, maintaining a diagonal Hessian
//! surrogate Sigma used to precondition the perturbation:
//!
//!   z ~ N(0, I)
//!   f0 = f(x);  f+ = f(x + lam S z);  f- = f(x - lam S z),  S = Sigma^{-1/2}
//!   g  = (f+ - f-)/(2 lam)
//!   h  = (f+ + f- - 2 f0)/lam^2          (curvature along S z)
//!   Sigma_i <- (1-alpha) Sigma_i + alpha |h| (S_i z_i)^2 (clamped)
//!   x <- x - eta g S z
//!
//! The per-step cost is 3 evals (1.5x MeZO/ConMeZO) — exactly the wall-clock
//! overhead the paper reports in §6.1.

use crate::util::error::Result;

use super::{sample_direction, StepStats, ZoOptimizer};
use crate::objective::Objective;
use crate::util::memory::MemoryMeter;

pub struct HiZoo {
    pub eta: f32,
    pub lam: f32,
    /// smoothing for the Hessian EMA
    pub alpha: f32,
    /// diagonal Hessian surrogate, clamped to [sigma_min, sigma_max]
    sigma: Vec<f32>,
    z: Vec<f32>,
    /// scratch: the preconditioned direction S z
    sz: Vec<f32>,
}

const SIGMA_MIN: f32 = 1e-3;
const SIGMA_MAX: f32 = 1e3;

impl HiZoo {
    pub fn new(dim: usize, eta: f32, lam: f32) -> Self {
        HiZoo {
            eta,
            lam,
            alpha: 1e-2,
            sigma: vec![1.0; dim],
            z: vec![0.0; dim],
            sz: vec![0.0; dim],
        }
    }
}

impl ZoOptimizer for HiZoo {
    fn name(&self) -> &'static str {
        "hizoo"
    }

    fn step(&mut self, x: &mut [f32], obj: &mut dyn Objective, t: usize, run_seed: u64) -> Result<StepStats> {
        let d_raw = obj.d_raw();
        sample_direction(&mut self.z, d_raw, run_seed, t);
        // preconditioned direction sz = Sigma^{-1/2} z
        for i in 0..d_raw {
            self.sz[i] = self.z[i] / self.sigma[i].sqrt();
        }
        for v in self.sz[d_raw..].iter_mut() {
            *v = 0.0;
        }
        let f0 = obj.loss(x)?;
        let (lp, lm) = obj.two_point(x, &self.sz, self.lam)?;
        let g = ((lp - lm) / (2.0 * self.lam as f64)) as f32;
        let h = ((lp + lm - 2.0 * f0) / (self.lam as f64 * self.lam as f64)) as f32;
        // update the diagonal surrogate with the curvature evidence
        let habs = h.abs();
        let a = self.alpha;
        let denom = (d_raw as f32).max(1.0);
        for i in 0..d_raw {
            let szi = self.sz[i];
            let evidence = habs * szi * szi / denom * d_raw as f32; // per-coord share
            self.sigma[i] = ((1.0 - a) * self.sigma[i] + a * evidence).clamp(SIGMA_MIN, SIGMA_MAX);
        }
        // descent along the preconditioned direction
        crate::vecmath::axpy(-self.eta * g, &self.sz, x);
        Ok(StepStats { loss: f0, proj_grad: g as f64, evals: 3 })
    }

    fn record_memory(&self, meter: &mut MemoryMeter) {
        meter.alloc_f32("opt.sigma", self.sigma.len());
        meter.alloc_f32("opt.direction", self.z.len());
        meter.alloc_f32("opt.precond", self.sz.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::NativeQuadratic;
    use crate::optimizer::test_support::{initial_quadratic_loss, quadratic_final_loss};

    #[test]
    fn descends_on_quadratic() {
        // HiZOO is the slowest descender of the family on this quadratic
        // (simulated final ~0.64 l0 at this budget), so its threshold is
        // looser than the other baselines'
        let d = 200;
        let l0 = initial_quadratic_loss(d, 12);
        let l = quadratic_final_loss(&mut HiZoo::new(d, 1e-3, 1e-2), d, 800, 12);
        assert!(l < 0.8 * l0, "{l} vs {l0}");
    }

    #[test]
    fn three_evals_per_step() {
        let d = 32;
        let mut obj = NativeQuadratic::new(d);
        let mut opt = HiZoo::new(d, 1e-3, 1e-2);
        let mut x = vec![1f32; d];
        let stats = opt.step(&mut x, &mut obj, 0, 1).unwrap();
        assert_eq!(stats.evals, 3);
        assert_eq!(obj.evals(), 3);
    }

    #[test]
    fn sigma_stays_clamped_and_positive() {
        let d = 64;
        let mut obj = NativeQuadratic::new(d);
        let mut opt = HiZoo::new(d, 1e-2, 1e-1);
        let mut x = vec![5f32; d];
        for t in 0..50 {
            opt.step(&mut x, &mut obj, t, 2).unwrap();
        }
        for &s in &opt.sigma {
            assert!((SIGMA_MIN..=SIGMA_MAX).contains(&s));
        }
    }

    #[test]
    fn curvature_raises_sigma_on_stiff_coordinates() {
        // on the quadratic, stiff coordinates (large sigma_i of the
        // objective) produce larger |h| evidence on average; after many
        // steps HiZOO's Sigma should be positively correlated with the
        // objective curvature profile on average (weak statistical check)
        let d = 400;
        let mut obj = NativeQuadratic::new(d);
        let mut opt = HiZoo::new(d, 1e-3, 1e-1);
        let mut x = vec![1f32; d];
        for t in 0..300 {
            opt.step(&mut x, &mut obj, t, 3).unwrap();
        }
        let lo: f32 = opt.sigma[..d / 4].iter().sum();
        let hi: f32 = opt.sigma[3 * d / 4..].iter().sum();
        // not a strict guarantee per-coordinate, but the aggregate should
        // not be wildly inverted
        assert!(hi > 0.2 * lo, "hi {hi} lo {lo}");
    }
}
