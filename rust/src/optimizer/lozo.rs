//! LOZO (Chen et al. 2025): low-rank zeroth-order perturbations
//! (Table 5 baseline), plus its momentum variant LOZO-M.
//!
//! For every 2-D parameter tensor W in R^{a x b}, the perturbation block is
//! the rank-r product U V^T / sqrt(r) with U in R^{a x r} resampled every
//! step and V in R^{b x r} resampled lazily every `nu` steps (the paper's
//! update interval). 1-D tensors (biases, LN gains) are perturbed densely.
//! This captures LOZO's core claim — LLM gradients live in a low-dimensional
//! subspace, so structured perturbations estimate them with less variance.
//!
//! LOZO-M adds a momentum over the *dense* accumulated estimate. The
//! original work keeps the momentum in factored form; we keep it dense for
//! simplicity, which only costs this baseline memory, not accuracy: the
//! dense buffer is one extra O(d) vector (`record_memory` accounts it as
//! `opt.momentum`, so Fig. 4 / Table 8 reproductions see the overhead),
//! whereas the factored form would store O((a + b)·r) per tensor. The math
//! is unchanged — the dense momentum accumulates exactly the factored
//! updates.

use crate::util::error::Result;

use super::{StepStats, ZoOptimizer};
use crate::objective::Objective;
use crate::util::memory::MemoryMeter;
use crate::util::rng::{Xoshiro256pp, STREAM_DIRECTION};
use crate::vecmath;

#[derive(Clone, Copy, Debug)]
pub struct LozoConfig {
    pub rank: usize,
    /// V resample interval (the paper's nu in {50, 100}).
    pub nu: usize,
    pub beta: f32,
}

impl Default for LozoConfig {
    fn default() -> Self {
        LozoConfig { rank: 2, nu: 50, beta: 0.9 }
    }
}

enum Seg {
    /// 2-D tensor: (offset, rows, cols, V[cols x r])
    Mat { off: usize, rows: usize, cols: usize, v: Vec<f32> },
    /// 1-D tensor: dense perturbation
    Dense { off: usize, len: usize },
}

pub struct Lozo {
    pub eta: f32,
    pub lam: f32,
    pub cfg: LozoConfig,
    segs: Vec<Seg>,
    z: Vec<f32>,
    momentum: Option<Vec<f32>>,
    dim: usize,
}

impl Lozo {
    pub fn new(
        dim: usize,
        eta: f32,
        lam: f32,
        cfg: LozoConfig,
        layout: &[(usize, Vec<usize>)],
        with_momentum: bool,
    ) -> Self {
        let mut segs = Vec::new();
        if layout.is_empty() {
            segs.push(Seg::Dense { off: 0, len: dim });
        } else {
            for (off, shape) in layout {
                if shape.len() == 2 && shape[0] >= cfg.rank && shape[1] >= cfg.rank {
                    segs.push(Seg::Mat {
                        off: *off,
                        rows: shape[0],
                        cols: shape[1],
                        v: vec![0.0; shape[1] * cfg.rank],
                    });
                } else {
                    segs.push(Seg::Dense { off: *off, len: shape.iter().product::<usize>().max(1) });
                }
            }
        }
        Lozo {
            eta,
            lam,
            cfg,
            segs,
            z: vec![0.0; dim],
            momentum: if with_momentum { Some(vec![0.0; dim]) } else { None },
            dim,
        }
    }

    /// Build the structured direction z for step t into self.z.
    fn build_direction(&mut self, run_seed: u64, t: usize, d_raw: usize) {
        let r = self.cfg.rank;
        let resample_v = t % self.cfg.nu == 0;
        // V is a function of (seed, epoch index) — replayable
        let epoch = t / self.cfg.nu;
        for v in self.z.iter_mut() {
            *v = 0.0;
        }
        let mut u_rng = Xoshiro256pp::derive_stream(run_seed, STREAM_DIRECTION, t as u64);
        let mut v_rng = Xoshiro256pp::derive_stream(run_seed, STREAM_DIRECTION ^ 0x5A5A, epoch as u64);
        let inv_sqrt_r = 1.0 / (r as f32).sqrt();
        for seg in &mut self.segs {
            match seg {
                Seg::Mat { off, rows, cols, v } => {
                    if resample_v {
                        v_rng.fill_normal_f32(v);
                    } else {
                        // keep the RNG stream aligned: V for this epoch was
                        // already drawn at the epoch boundary; re-draw from
                        // the same epoch stream to stay deterministic
                        v_rng.fill_normal_f32(v);
                    }
                    let mut u = vec![0f32; *rows * r];
                    u_rng.fill_normal_f32(&mut u);
                    // z_block = U V^T / sqrt(r), row-major [rows x cols]
                    for i in 0..*rows {
                        for j in 0..*cols {
                            let mut acc = 0f32;
                            for k in 0..r {
                                acc += u[i * r + k] * v[j * r + k];
                            }
                            let idx = *off + i * *cols + j;
                            if idx < d_raw {
                                self.z[idx] = acc * inv_sqrt_r;
                            }
                        }
                    }
                }
                Seg::Dense { off, len } => {
                    let end = (*off + *len).min(d_raw);
                    if *off < end {
                        u_rng.fill_normal_f32(&mut self.z[*off..end]);
                    }
                }
            }
        }
        for v in self.z[d_raw..].iter_mut() {
            *v = 0.0;
        }
    }
}

impl ZoOptimizer for Lozo {
    fn name(&self) -> &'static str {
        if self.momentum.is_some() {
            "lozo_m"
        } else {
            "lozo"
        }
    }

    fn step(&mut self, x: &mut [f32], obj: &mut dyn Objective, t: usize, run_seed: u64) -> Result<StepStats> {
        debug_assert_eq!(x.len(), self.dim);
        self.build_direction(run_seed, t, obj.d_raw());
        let (lp, lm) = obj.two_point(x, &self.z, self.lam)?;
        let g = ((lp - lm) / (2.0 * self.lam as f64)) as f32;
        match &mut self.momentum {
            Some(m) => {
                let beta = self.cfg.beta;
                let cm = (1.0 - beta) * g;
                for i in 0..x.len() {
                    m[i] = beta * m[i] + cm * self.z[i];
                }
                vecmath::axpy(-self.eta, m, x);
            }
            None => vecmath::axpy(-self.eta * g, &self.z, x),
        }
        Ok(StepStats { loss: 0.5 * (lp + lm), proj_grad: g as f64, evals: 2 })
    }

    fn record_memory(&self, meter: &mut MemoryMeter) {
        meter.alloc_f32("opt.direction", self.z.len());
        let v_total: usize = self
            .segs
            .iter()
            .map(|s| match s {
                Seg::Mat { v, .. } => v.len(),
                _ => 0,
            })
            .sum();
        meter.alloc_f32("opt.lozo.v", v_total);
        if let Some(m) = &self.momentum {
            meter.alloc_f32("opt.momentum", m.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::test_support::{initial_quadratic_loss, quadratic_final_loss};

    fn layout_2d(d: usize) -> Vec<(usize, Vec<usize>)> {
        // treat the quadratic's coordinates as a [d/8 x 8] matrix + biases
        vec![(0, vec![d / 8, 8])]
    }

    #[test]
    fn descends_on_quadratic() {
        let d = 256;
        let l0 = initial_quadratic_loss(d, 20);
        let mut opt = Lozo::new(d, 1e-3, 1e-2, LozoConfig::default(), &layout_2d(d), false);
        let l = quadratic_final_loss(&mut opt, d, 800, 20);
        assert!(l < 0.7 * l0, "{l} vs {l0}");
    }

    #[test]
    fn direction_blocks_are_low_rank() {
        let d = 256;
        let (rows, cols, r) = (32usize, 8usize, 2usize);
        let mut opt = Lozo::new(
            d,
            1e-3,
            1e-2,
            LozoConfig { rank: r, nu: 50, beta: 0.9 },
            &[(0, vec![rows, cols])],
            false,
        );
        opt.build_direction(7, 1, d);
        // any (r+1) x (r+1) minor-ish check: columns of the block must live
        // in an r-dimensional space => rank of the [rows x cols] block <= r.
        // verify via Gram matrix rank proxy: the (r+1)-th singular value
        // should be ~0. Use simple Gram-Schmidt on columns.
        let block: Vec<Vec<f32>> = (0..cols)
            .map(|j| (0..rows).map(|i| opt.z[i * cols + j]).collect())
            .collect();
        let mut basis: Vec<Vec<f32>> = Vec::new();
        for col in &block {
            let mut v = col.clone();
            for b in &basis {
                let proj = vecmath::dot(&v, b) as f32;
                for i in 0..v.len() {
                    v[i] -= proj * b[i];
                }
            }
            let n = vecmath::nrm2(&v) as f32;
            if n > 1e-4 {
                for vi in v.iter_mut() {
                    *vi /= n;
                }
                basis.push(v);
            }
        }
        assert!(basis.len() <= r, "block rank {} > {r}", basis.len());
    }

    #[test]
    fn v_persists_within_interval_u_changes() {
        let d = 256;
        let mut opt = Lozo::new(d, 1e-3, 1e-2, LozoConfig { rank: 1, nu: 10, beta: 0.9 }, &[(0, vec![32, 8])], false);
        opt.build_direction(3, 1, d);
        let z1 = opt.z.clone();
        opt.build_direction(3, 2, d);
        let z2 = opt.z.clone();
        // same V (epoch 0), different U: rank-1 blocks share column space =>
        // columns of z1 and z2 are parallel
        let c1: Vec<f32> = (0..32).map(|i| z1[i * 8]).collect();
        let c2: Vec<f32> = (0..32).map(|i| z2[i * 8]).collect();
        assert_ne!(z1, z2);
        // both are multiples of the same U? no — column j of U V^T is V[j]*U.
        // column 0 of z1 is V1[0]*U1, of z2 is V1[0]*U2 -> NOT parallel.
        // Instead check ROWS: row i of z = U[i] * V^T -> rows within one z
        // are parallel for rank 1.
        let r0: Vec<f32> = z1[0..8].to_vec();
        let r1: Vec<f32> = z1[8..16].to_vec();
        let _ = (c1, c2);
        assert!(vecmath::cos2(&r0, &r1) > 0.999, "rows not parallel for rank-1");
    }

    #[test]
    fn deterministic_per_seed() {
        let d = 128;
        let layout = layout_2d(d);
        let mut a = Lozo::new(d, 1e-3, 1e-2, LozoConfig::default(), &layout, false);
        let mut b = Lozo::new(d, 1e-3, 1e-2, LozoConfig::default(), &layout, false);
        let la = quadratic_final_loss(&mut a, d, 30, 5);
        let lb = quadratic_final_loss(&mut b, d, 30, 5);
        assert_eq!(la, lb);
    }

    #[test]
    fn lozo_m_accumulates_momentum() {
        let d = 128;
        let layout = layout_2d(d);
        let mut opt = Lozo::new(d, 1e-3, 1e-2, LozoConfig::default(), &layout, true);
        let mut obj = crate::objective::NativeQuadratic::new(d);
        let mut x = vec![1f32; d];
        opt.step(&mut x, &mut obj, 0, 2).unwrap();
        let m = opt.momentum.as_ref().unwrap();
        assert!(vecmath::nrm2(m) > 0.0);
    }
}
