//! Hyperparameter schedules — notably the paper's momentum warm-up (§3.4).

/// The three-phase β warm-up of §3.4, written for a 20K-step run and scaled
/// linearly to other horizons (the paper halves the breakpoints for 10K
/// runs, i.e. scales by T/20000):
///
/// ```text
/// beta_t = 0.1                                    0     <= t <= 200 s
///        = bf - (bf - 0.1)/(1 + 8 ((t-200s)/(1800s))^1.8)^3   200s < t <= 2000 s
///        = bf                                     t > 2000 s
/// ```
/// with `s = total_steps / 20000`.
#[derive(Clone, Debug)]
pub enum BetaSchedule {
    Constant(f32),
    PaperWarmup { beta_final: f32, total_steps: usize },
}

impl BetaSchedule {
    pub fn at(&self, t: usize) -> f32 {
        match self {
            BetaSchedule::Constant(b) => *b,
            BetaSchedule::PaperWarmup { beta_final, total_steps } => {
                let s = (*total_steps as f64 / 20_000.0).max(1e-9);
                let t1 = 200.0 * s;
                let t2 = 2000.0 * s;
                let w = 1800.0 * s;
                let t = t as f64;
                let bf = *beta_final as f64;
                if t <= t1 {
                    0.1
                } else if t <= t2 {
                    let r = (t - t1) / w;
                    (bf - (bf - 0.1) / (1.0 + 8.0 * r.powf(1.8)).powi(3)) as f32 as f64 as f32
                } else {
                    *beta_final
                }
            }
        }
    }

    /// Emit the whole curve (Fig. 8).
    pub fn curve(&self, total: usize) -> Vec<f32> {
        (0..total).map(|t| self.at(t)).collect()
    }
}

/// Learning-rate schedule (constant in the paper; linear decay provided as
/// an extension knob for the ablation benches).
#[derive(Clone, Debug)]
pub enum LrSchedule {
    Constant(f32),
    LinearDecay { lr0: f32, lr1: f32, total_steps: usize },
}

impl LrSchedule {
    pub fn at(&self, t: usize) -> f32 {
        match self {
            LrSchedule::Constant(l) => *l,
            LrSchedule::LinearDecay { lr0, lr1, total_steps } => {
                let r = (t as f32 / (*total_steps).max(1) as f32).min(1.0);
                lr0 + (lr1 - lr0) * r
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_paper_breakpoints_20k() {
        let s = BetaSchedule::PaperWarmup { beta_final: 0.99, total_steps: 20_000 };
        // flat start
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(200), 0.1);
        // end of ramp hits ~bf: at t=2000, r=1 -> bf - (bf-0.1)/9^3 = bf - 0.00122
        let b2000 = s.at(2000);
        assert!((b2000 - (0.99 - 0.89 / 729.0) as f32).abs() < 1e-4, "{b2000}");
        // saturated
        assert_eq!(s.at(2001), 0.99);
        assert_eq!(s.at(19_999), 0.99);
    }

    #[test]
    fn warmup_monotone_nondecreasing() {
        let s = BetaSchedule::PaperWarmup { beta_final: 0.99, total_steps: 20_000 };
        let c = s.curve(20_000);
        for w in c.windows(2) {
            assert!(w[1] >= w[0] - 1e-6, "{} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn warmup_10k_halves_breakpoints() {
        // the paper: "for 10K runs we halve the interval lengths"
        let s = BetaSchedule::PaperWarmup { beta_final: 0.99, total_steps: 10_000 };
        assert_eq!(s.at(100), 0.1);
        assert!(s.at(150) > 0.1);
        assert_eq!(s.at(1001), 0.99);
    }

    #[test]
    fn constant_is_constant() {
        let s = BetaSchedule::Constant(0.95);
        assert_eq!(s.at(0), 0.95);
        assert_eq!(s.at(10_000), 0.95);
    }

    #[test]
    fn lr_linear_decay() {
        let s = LrSchedule::LinearDecay { lr0: 1e-3, lr1: 1e-4, total_steps: 100 };
        assert!((s.at(0) - 1e-3).abs() < 1e-9);
        assert!((s.at(100) - 1e-4).abs() < 1e-9);
        assert!(s.at(50) < 1e-3 && s.at(50) > 1e-4);
    }

    #[test]
    fn warmup_midpoint_matches_formula() {
        // spot-check the exact closed form at t=1100 (halfway through ramp)
        let s = BetaSchedule::PaperWarmup { beta_final: 0.99, total_steps: 20_000 };
        let r: f64 = 900.0 / 1800.0;
        let want = 0.99 - 0.89 / (1.0 + 8.0 * r.powf(1.8)).powi(3);
        assert!((s.at(1100) as f64 - want).abs() < 1e-6);
    }
}
