//! The ZO optimizer family (composed mode).
//!
//! Every algorithm implements [`ZoOptimizer`] against the [`Objective`]
//! oracle — two (or three/four) function evaluations per step, mirroring
//! the paper's setting; on the model objective each evaluation executes
//! through a bound runtime `Session` on whichever backend is active
//! (native by default, PJRT behind the feature flag). The fused execution
//! mode (whole step as one bound step program) lives in
//! `coordinator::fused` and is semantically equivalent to the composed
//! ConMeZO/MeZO here (cross-checked in integration tests).
//!
//! | module | algorithm | paper artefact |
//! |---|---|---|
//! | `conmezo` | Algorithm 1 + §3.4 warm-up | everything |
//! | `mezo` | MeZO (vectorized) + loop-based emulation | all tables, Table 3 |
//! | `mezo_momentum` | MeZO+Momentum baseline | Table 1 |
//! | `zo_adamm` | ZO-AdaMM (Chen et al. 2019) | Table 7 |
//! | `hizoo` | HiZOO diagonal-Hessian ZO | Table 4 |
//! | `lozo` | LOZO / LOZO-M low-rank perturbations | Table 5 |
//! | `mezo_svrg` | MeZO-SVRG variance reduction | Table 6 |

pub mod conmezo;
pub mod hizoo;
pub mod lozo;
pub mod mezo;
pub mod mezo_momentum;
pub mod mezo_svrg;
pub mod schedule;
pub mod zo_adamm;

use crate::util::error::Result;

use crate::objective::Objective;
use crate::util::memory::MemoryMeter;
use crate::util::rng::{Xoshiro256pp, STREAM_DIRECTION};

pub use conmezo::ConMeZo;
pub use hizoo::HiZoo;
pub use lozo::{Lozo, LozoConfig};
pub use mezo::{Mezo, MezoLoop};
pub use mezo_momentum::MezoMomentum;
pub use mezo_svrg::{MezoSvrg, SvrgConfig};
pub use schedule::{BetaSchedule, LrSchedule};
pub use zo_adamm::ZoAdaMM;

/// Per-step telemetry.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// Mean of the two perturbed losses (the paper's reported train loss).
    pub loss: f64,
    /// Projected gradient g = (f+ - f-)/(2 lambda).
    pub proj_grad: f64,
    /// Function evaluations consumed by this step.
    pub evals: u32,
}

/// A zeroth-order optimizer over the flat parameter buffer.
pub trait ZoOptimizer {
    fn name(&self) -> &'static str;

    /// One iteration: mutate `x` in place using only `obj` evaluations.
    /// `t` is the step index; `run_seed` the experiment seed — the
    /// perturbation direction MUST be a pure function of (run_seed, t) so
    /// distributed replicas regenerate it identically (DESIGN.md §4).
    fn step(&mut self, x: &mut [f32], obj: &mut dyn Objective, t: usize, run_seed: u64) -> Result<StepStats>;

    /// Account persistent optimizer state (Fig. 4 / Table 8).
    fn record_memory(&self, meter: &mut MemoryMeter);

    /// Persistent state buffers to checkpoint alongside `x`: (name,
    /// payload) pairs sufficient to resume [`ZoOptimizer::step`]
    /// bit-identically at the next `t`. Per-step scratch regenerated from
    /// `(run_seed, t)` is NOT state; stateless optimizers keep the empty
    /// default (`crate::serve` checkpoints these per job).
    fn state(&self) -> Vec<(&'static str, &[f32])> {
        Vec::new()
    }

    /// Restore one buffer previously exported by [`ZoOptimizer::state`].
    /// The default (stateless) rejects every name.
    fn restore(&mut self, name: &str, data: &[f32]) -> Result<()> {
        let _ = data;
        crate::bail!("{}: unknown optimizer state buffer {name:?}", self.name())
    }
}

/// The shared direction stream: u ~ N(0, I_d) on valid lanes, zero pads.
/// Public because distributed workers must regenerate identical directions.
pub fn sample_direction(buf: &mut [f32], d_raw: usize, run_seed: u64, t: usize) {
    let mut rng = Xoshiro256pp::derive_stream(run_seed, STREAM_DIRECTION, t as u64);
    rng.fill_normal_f32(&mut buf[..d_raw]);
    for v in buf[d_raw..].iter_mut() {
        *v = 0.0;
    }
}

/// Build an optimizer by name with the paper-default hyperparameters
/// (overridable afterwards through the concrete types or config).
pub fn by_name(
    name: &str,
    dim: usize,
    eta: f32,
    lam: f32,
    theta: f32,
    beta: BetaSchedule,
    layout: &[(usize, Vec<usize>)],
) -> Result<Box<dyn ZoOptimizer>> {
    Ok(match name {
        "conmezo" => Box::new(ConMeZo::new(dim, eta, lam, theta, beta)),
        "mezo" => Box::new(Mezo::new(dim, eta, lam)),
        "mezo_loop" => Box::new(MezoLoop::new(dim, eta, lam, layout)),
        "mezo_momentum" => Box::new(MezoMomentum::new(dim, eta, lam, beta)),
        "zo_adamm" => Box::new(ZoAdaMM::new(dim, eta, lam)),
        "hizoo" => Box::new(HiZoo::new(dim, eta, lam)),
        "lozo" => Box::new(Lozo::new(dim, eta, lam, LozoConfig::default(), layout, false)),
        "lozo_m" => Box::new(Lozo::new(dim, eta, lam, LozoConfig::default(), layout, true)),
        "mezo_svrg" => Box::new(MezoSvrg::new(dim, eta, lam, SvrgConfig::default())),
        other => crate::bail!("unknown optimizer {other:?}"),
    })
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::objective::NativeQuadratic;

    /// Run `opt` on the Fig. 3 quadratic from a fixed start; return the
    /// final loss. Used by every optimizer's descent test.
    pub fn quadratic_final_loss(opt: &mut dyn ZoOptimizer, d: usize, steps: usize, seed: u64) -> f64 {
        let mut obj = NativeQuadratic::new(d);
        // ||x0|| = 10 like App. C.1
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut x = vec![0f32; d];
        rng.fill_normal_f32(&mut x);
        let n = crate::vecmath::nrm2(&x) as f32;
        crate::vecmath::scale(10.0 / n, &mut x);
        for t in 0..steps {
            opt.step(&mut x, &mut obj, t, seed).unwrap();
        }
        obj.loss(&x).unwrap()
    }

    pub fn initial_quadratic_loss(d: usize, seed: u64) -> f64 {
        let mut obj = NativeQuadratic::new(d);
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut x = vec![0f32; d];
        rng.fill_normal_f32(&mut x);
        let n = crate::vecmath::nrm2(&x) as f32;
        crate::vecmath::scale(10.0 / n, &mut x);
        obj.loss(&x).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_direction_deterministic_and_padded() {
        let mut a = vec![1f32; 100];
        let mut b = vec![2f32; 100];
        sample_direction(&mut a, 90, 7, 3);
        sample_direction(&mut b, 90, 7, 3);
        assert_eq!(a, b);
        assert!(a[90..].iter().all(|&v| v == 0.0));
        let mut c = vec![0f32; 100];
        sample_direction(&mut c, 90, 7, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn by_name_constructs_all() {
        let layout = vec![(0usize, vec![8usize, 4]), (32, vec![8])];
        for name in [
            "conmezo", "mezo", "mezo_loop", "mezo_momentum", "zo_adamm",
            "hizoo", "lozo", "lozo_m", "mezo_svrg",
        ] {
            let o = by_name(name, 40, 1e-3, 1e-3, 1.35, BetaSchedule::Constant(0.9), &layout);
            assert!(o.is_ok(), "{name}");
        }
        assert!(by_name("bogus", 40, 1e-3, 1e-3, 1.35, BetaSchedule::Constant(0.9), &[]).is_err());
    }
}
