//! ConMeZO — Algorithm 1 of the paper, composed-mode implementation.
//!
//! Per step t:
//!   u_t ~ N(0, I_d)                      (App. C.2 practice)
//!   m_0 = u_0                            (first iteration)
//!   z_t = sqrt(d) cos(theta) m_t/||m_t|| + sin(theta) u_t
//!   g   = (f(x + lam z) - f(x - lam z)) / (2 lam)
//!   x  <- x - eta_t g z
//!   m  <- beta_t m + (1 - beta_t) g z    (fused single pass, §3.3)
//!
//! beta_t follows the §3.4 warm-up schedule when configured.

use crate::util::error::Result;

use super::{sample_direction, BetaSchedule, StepStats, ZoOptimizer};
use crate::objective::Objective;
use crate::util::memory::MemoryMeter;
use crate::vecmath;

pub struct ConMeZo {
    pub eta: f32,
    pub lam: f32,
    pub theta: f32,
    pub beta: BetaSchedule,
    /// Momentum buffer m_t (the paper's extra optimizer state, §3.3).
    pub m: Vec<f32>,
    /// Scratch: the raw direction u_t.
    u: Vec<f32>,
    /// Scratch: the cone direction z_t.
    z: Vec<f32>,
    started: bool,
}

impl ConMeZo {
    pub fn new(dim: usize, eta: f32, lam: f32, theta: f32, beta: BetaSchedule) -> Self {
        ConMeZo {
            eta,
            lam,
            theta,
            beta,
            m: vec![0.0; dim],
            u: vec![0.0; dim],
            z: vec![0.0; dim],
            started: false,
        }
    }

    /// Current momentum-vs-vector alignment (Fig. 6 probe helper).
    pub fn momentum_cos2(&self, v: &[f32]) -> f64 {
        vecmath::cos2(&self.m, v)
    }
}

impl ZoOptimizer for ConMeZo {
    fn name(&self) -> &'static str {
        "conmezo"
    }

    fn step(&mut self, x: &mut [f32], obj: &mut dyn Objective, t: usize, run_seed: u64) -> Result<StepStats> {
        let d_raw = obj.d_raw();
        sample_direction(&mut self.u, d_raw, run_seed, t);
        if !self.started {
            // Algorithm 1: m_0 <- u_0
            self.m.copy_from_slice(&self.u);
            self.started = true;
        }
        vecmath::cone_direction(&self.m, &self.u, self.theta, d_raw, &mut self.z);
        let (lp, lm) = obj.two_point(x, &self.z, self.lam)?;
        let g = ((lp - lm) / (2.0 * self.lam as f64)) as f32;
        let beta = self.beta.at(t);
        vecmath::zo_update(x, &mut self.m, &self.z, g, self.eta, beta);
        Ok(StepStats { loss: 0.5 * (lp + lm), proj_grad: g as f64, evals: 2 })
    }

    fn record_memory(&self, meter: &mut MemoryMeter) {
        meter.alloc_f32("opt.momentum", self.m.len());
        // u is regenerated per step but lives as a persistent scratch buffer
        // in this implementation (the paper stores the perturbation in the
        // momentum buffer; either way it is one extra vector, §3.3)
        meter.alloc_f32("opt.direction", self.u.len());
        meter.alloc_f32("opt.cone", self.z.len());
    }

    fn state(&self) -> Vec<(&'static str, &[f32])> {
        vec![("m", &self.m)]
    }

    fn restore(&mut self, name: &str, data: &[f32]) -> Result<()> {
        match name {
            "m" => {
                if data.len() != self.m.len() {
                    crate::bail!(
                        "conmezo momentum: checkpoint has {} elements, optimizer {}",
                        data.len(),
                        self.m.len()
                    );
                }
                self.m.copy_from_slice(data);
                // a restored momentum replaces the m_0 <- u_0 bootstrap
                self.started = true;
                Ok(())
            }
            other => crate::bail!("conmezo: unknown state buffer {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::test_support::{initial_quadratic_loss, quadratic_final_loss};

    fn mk(d: usize) -> ConMeZo {
        ConMeZo::new(d, 1e-3, 1e-2, 1.35, BetaSchedule::Constant(0.95))
    }

    #[test]
    fn descends_on_quadratic() {
        let d = 200;
        let l0 = initial_quadratic_loss(d, 1);
        let l = quadratic_final_loss(&mut mk(d), d, 800, 1);
        assert!(l < 0.5 * l0, "loss {l} vs initial {l0}");
    }

    #[test]
    fn beats_pure_random_direction_on_quadratic() {
        // theta < pi/2 with momentum should descend at least as fast as
        // theta = pi/2 (which is MeZO) in this well-conditioned regime
        let d = 500;
        let steps = 1500;
        let lc = quadratic_final_loss(&mut mk(d), d, steps, 3);
        let mut mezo_like = ConMeZo::new(d, 1e-3, 1e-2, std::f32::consts::FRAC_PI_2, BetaSchedule::Constant(0.95));
        let lm = quadratic_final_loss(&mut mezo_like, d, steps, 3);
        assert!(lc < lm, "cone {lc} should beat isotropic {lm}");
    }

    #[test]
    fn deterministic_given_seed() {
        let d = 64;
        let la = quadratic_final_loss(&mut mk(d), d, 50, 9);
        let lb = quadratic_final_loss(&mut mk(d), d, 50, 9);
        assert_eq!(la, lb);
        let lc = quadratic_final_loss(&mut mk(d), d, 50, 10);
        assert_ne!(la, lc);
    }

    #[test]
    fn momentum_initialized_from_first_direction() {
        let d = 32;
        let mut opt = mk(d);
        let mut obj = crate::objective::NativeQuadratic::new(d);
        let mut x = vec![1f32; d];
        opt.step(&mut x, &mut obj, 0, 5).unwrap();
        // after one step m = beta*u0 + (1-beta)*g*z where z built from m=u0:
        // m must be correlated with u0 (cos2 >> 1/d)
        let mut u0 = vec![0f32; d];
        super::super::sample_direction(&mut u0, d, 5, 0);
        assert!(opt.momentum_cos2(&u0) > 0.5);
    }

    #[test]
    fn memory_is_three_extra_buffers() {
        let mut meter = MemoryMeter::new();
        mk(128).record_memory(&mut meter);
        assert_eq!(meter.current_bytes(), 3 * 128 * 4);
    }

    #[test]
    fn warmup_schedule_is_consulted() {
        // with PaperWarmup, beta in the flat phase is 0.1: momentum is
        // dominated by fresh gradient estimates rather than u0. One step is
        // degenerate (z_0 is parallel to m_0 = u_0, so both cos2 are ~1);
        // after TWO steps the low-beta momentum has rotated toward z_1 while
        // beta=0.99 still points at u0 (simulated cos2: ~0.78 vs ~0.997).
        let d = 64;
        let mut opt = ConMeZo::new(d, 1e-3, 1e-2, 1.35, BetaSchedule::PaperWarmup { beta_final: 0.99, total_steps: 20_000 });
        let mut obj = crate::objective::NativeQuadratic::new(d);
        let mut x = vec![1f32; d];
        opt.step(&mut x, &mut obj, 0, 5).unwrap();
        opt.step(&mut x, &mut obj, 1, 5).unwrap();
        let mut opt2 = ConMeZo::new(d, 1e-3, 1e-2, 1.35, BetaSchedule::Constant(0.99));
        let mut obj2 = crate::objective::NativeQuadratic::new(d);
        let mut x2 = vec![1f32; d];
        opt2.step(&mut x2, &mut obj2, 0, 5).unwrap();
        opt2.step(&mut x2, &mut obj2, 1, 5).unwrap();
        let mut u0 = vec![0f32; d];
        super::super::sample_direction(&mut u0, d, 5, 0);
        assert!(opt2.momentum_cos2(&u0) > opt.momentum_cos2(&u0) + 0.05);
    }
}
