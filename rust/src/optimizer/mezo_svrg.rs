//! MeZO-SVRG (Gautam et al. 2024): variance reduction via a periodically
//! refreshed full-batch anchor (Table 6 baseline).
//!
//! Every `anchor_every` steps, refresh:
//!   x_a <- x
//!   g_a <- (1/K) sum_j ghat(x_a; z_j, B_j)      (dense anchor gradient)
//! On regular steps, with a fresh direction z and minibatch B:
//!   c   = proj(x; z, B) - proj(x_a; z, B)       (control variate scalar)
//!   x  <- x - eta (c * z + g_a)
//!
//! Cost: 4 evals on regular steps (two two-point pairs), 2K on anchor
//! steps — the ~16x per-100-step wall-clock overhead the paper reports in
//! §6.3 comes from K being the full-batch/minibatch ratio.
//!
//! Memory: two extra dense vectors (x_a, g_a) — more than ConMeZO's one.

use crate::util::error::Result;

use super::{sample_direction, StepStats, ZoOptimizer};
use crate::objective::Objective;
use crate::util::memory::MemoryMeter;
use crate::vecmath;

#[derive(Clone, Copy, Debug)]
pub struct SvrgConfig {
    /// refresh the anchor every N steps
    pub anchor_every: usize,
    /// number of minibatch estimates averaged into the anchor gradient
    pub anchor_batches: usize,
}

impl Default for SvrgConfig {
    fn default() -> Self {
        SvrgConfig { anchor_every: 50, anchor_batches: 8 }
    }
}

pub struct MezoSvrg {
    pub eta: f32,
    pub lam: f32,
    pub cfg: SvrgConfig,
    x_anchor: Vec<f32>,
    g_anchor: Vec<f32>,
    z: Vec<f32>,
    have_anchor: bool,
}

impl MezoSvrg {
    pub fn new(dim: usize, eta: f32, lam: f32, cfg: SvrgConfig) -> Self {
        MezoSvrg {
            eta,
            lam,
            cfg,
            x_anchor: vec![0.0; dim],
            g_anchor: vec![0.0; dim],
            z: vec![0.0; dim],
            have_anchor: false,
        }
    }

    fn refresh_anchor(&mut self, x: &[f32], obj: &mut dyn Objective, t: usize, run_seed: u64) -> Result<u32> {
        self.x_anchor.copy_from_slice(x);
        for v in self.g_anchor.iter_mut() {
            *v = 0.0;
        }
        let k = self.cfg.anchor_batches.max(1);
        let mut evals = 0;
        for j in 0..k {
            // distinct directions per anchor component, replayable
            sample_direction(&mut self.z, obj.d_raw(), run_seed ^ 0xA17C_4042, t as usize * 1000 + j);
            let (lp, lm) = obj.two_point(x, &self.z, self.lam)?;
            evals += 2;
            let g = ((lp - lm) / (2.0 * self.lam as f64)) as f32 / k as f32;
            vecmath::axpy(g, &self.z, &mut self.g_anchor);
            obj.advance(); // anchor averages across minibatches
        }
        self.have_anchor = true;
        Ok(evals)
    }
}

impl ZoOptimizer for MezoSvrg {
    fn name(&self) -> &'static str {
        "mezo_svrg"
    }

    fn step(&mut self, x: &mut [f32], obj: &mut dyn Objective, t: usize, run_seed: u64) -> Result<StepStats> {
        let mut evals = 0;
        if !self.have_anchor || t % self.cfg.anchor_every == 0 {
            evals += self.refresh_anchor(x, obj, t, run_seed)?;
        }
        sample_direction(&mut self.z, obj.d_raw(), run_seed, t);
        // minibatch projections at x and at the anchor, same z + same batch
        let (lp, lm) = obj.two_point(x, &self.z, self.lam)?;
        let (ap, am) = obj.two_point(&self.x_anchor, &self.z, self.lam)?;
        evals += 4;
        let gx = (lp - lm) / (2.0 * self.lam as f64);
        let ga = (ap - am) / (2.0 * self.lam as f64);
        let c = (gx - ga) as f32;
        // x <- x - eta (c z + g_anchor)
        for i in 0..x.len() {
            x[i] -= self.eta * (c * self.z[i] + self.g_anchor[i]);
        }
        Ok(StepStats { loss: 0.5 * (lp + lm), proj_grad: gx, evals })
    }

    fn record_memory(&self, meter: &mut MemoryMeter) {
        meter.alloc_f32("opt.svrg.x_anchor", self.x_anchor.len());
        meter.alloc_f32("opt.svrg.g_anchor", self.g_anchor.len());
        meter.alloc_f32("opt.direction", self.z.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::NativeQuadratic;
    use crate::optimizer::test_support::{initial_quadratic_loss, quadratic_final_loss};

    #[test]
    fn descends_on_quadratic() {
        let d = 200;
        let l0 = initial_quadratic_loss(d, 30);
        let mut opt = MezoSvrg::new(d, 1e-3, 1e-2, SvrgConfig { anchor_every: 20, anchor_batches: 4 });
        let l = quadratic_final_loss(&mut opt, d, 600, 30);
        assert!(l < 0.7 * l0, "{l} vs {l0}");
    }

    #[test]
    fn anchor_step_costs_more_evals() {
        let d = 64;
        let mut obj = NativeQuadratic::new(d);
        let mut opt = MezoSvrg::new(d, 1e-3, 1e-2, SvrgConfig { anchor_every: 100, anchor_batches: 4 });
        let mut x = vec![1f32; d];
        let s0 = opt.step(&mut x, &mut obj, 0, 1).unwrap();
        let s1 = opt.step(&mut x, &mut obj, 1, 1).unwrap();
        assert_eq!(s0.evals, 4 + 2 * 4, "anchor step: 4 + 2*anchor_batches");
        assert_eq!(s1.evals, 4, "regular step");
    }

    #[test]
    fn control_variate_vanishes_at_anchor() {
        // immediately after an anchor refresh, x == x_anchor, so the
        // control variate c == 0 and the update direction equals g_anchor
        let d = 32;
        let mut obj = NativeQuadratic::new(d);
        let mut opt = MezoSvrg::new(d, 1.0, 1e-2, SvrgConfig { anchor_every: 1000, anchor_batches: 2 });
        let mut x = vec![1f32; d];
        let x0 = x.clone();
        opt.step(&mut x, &mut obj, 0, 5).unwrap();
        // x - x0 = -eta * (0 * z + g_anchor) = -g_anchor
        for i in 0..d {
            let want = x0[i] - opt.g_anchor[i];
            assert!((x[i] - want).abs() < 1e-4, "coord {i}: {} vs {want}", x[i]);
        }
    }

    #[test]
    fn memory_includes_two_dense_anchors() {
        let mut meter = MemoryMeter::new();
        MezoSvrg::new(100, 1e-3, 1e-3, SvrgConfig::default()).record_memory(&mut meter);
        assert_eq!(meter.current_bytes(), 3 * 100 * 4);
    }
}
