//! MeZO+Momentum — the paper's own baseline (§5.2): keeps the isotropic
//! MeZO perturbation but replaces the update direction with the momentum:
//!
//!   z ~ N(0, I)           (perturbation NOT biased by momentum)
//!   g = (f+ - f-)/(2 lam)
//!   m <- beta m + (1 - beta) g z
//!   x <- x - eta m
//!
//! The paper shows this is consistently weaker than ConMeZO (Table 1),
//! demonstrating that *where* the momentum enters (sampling vs update)
//! matters.

use crate::util::error::Result;

use super::{sample_direction, BetaSchedule, StepStats, ZoOptimizer};
use crate::objective::Objective;
use crate::util::memory::MemoryMeter;
use crate::vecmath;

pub struct MezoMomentum {
    pub eta: f32,
    pub lam: f32,
    pub beta: BetaSchedule,
    pub m: Vec<f32>,
    z: Vec<f32>,
}

impl MezoMomentum {
    pub fn new(dim: usize, eta: f32, lam: f32, beta: BetaSchedule) -> Self {
        MezoMomentum { eta, lam, beta, m: vec![0.0; dim], z: vec![0.0; dim] }
    }
}

impl ZoOptimizer for MezoMomentum {
    fn name(&self) -> &'static str {
        "mezo_momentum"
    }

    fn step(&mut self, x: &mut [f32], obj: &mut dyn Objective, t: usize, run_seed: u64) -> Result<StepStats> {
        sample_direction(&mut self.z, obj.d_raw(), run_seed, t);
        let (lp, lm) = obj.two_point(x, &self.z, self.lam)?;
        let g = ((lp - lm) / (2.0 * self.lam as f64)) as f32;
        let beta = self.beta.at(t);
        // m <- beta m + (1-beta) g z
        let cm = (1.0 - beta) * g;
        for i in 0..self.m.len() {
            self.m[i] = beta * self.m[i] + cm * self.z[i];
        }
        vecmath::axpy(-self.eta, &self.m, x);
        Ok(StepStats { loss: 0.5 * (lp + lm), proj_grad: g as f64, evals: 2 })
    }

    fn record_memory(&self, meter: &mut MemoryMeter) {
        meter.alloc_f32("opt.momentum", self.m.len());
        meter.alloc_f32("opt.direction", self.z.len());
    }

    fn state(&self) -> Vec<(&'static str, &[f32])> {
        vec![("m", &self.m)]
    }

    fn restore(&mut self, name: &str, data: &[f32]) -> Result<()> {
        match name {
            "m" => {
                if data.len() != self.m.len() {
                    crate::bail!(
                        "mezo_momentum: checkpoint has {} elements, optimizer {}",
                        data.len(),
                        self.m.len()
                    );
                }
                self.m.copy_from_slice(data);
                Ok(())
            }
            other => crate::bail!("mezo_momentum: unknown state buffer {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::test_support::{initial_quadratic_loss, quadratic_final_loss};

    #[test]
    fn descends_on_quadratic() {
        let d = 200;
        let l0 = initial_quadratic_loss(d, 6);
        let mut opt = MezoMomentum::new(d, 5e-3, 1e-2, BetaSchedule::Constant(0.9));
        let l = quadratic_final_loss(&mut opt, d, 800, 6);
        assert!(l < 0.7 * l0, "{l} vs {l0}");
    }

    #[test]
    fn update_uses_momentum_not_direction() {
        let d = 16;
        let mut opt = MezoMomentum::new(d, 1.0, 1e-2, BetaSchedule::Constant(0.5));
        let mut obj = crate::objective::NativeQuadratic::new(d);
        let mut x = vec![1f32; d];
        let x0 = x.clone();
        opt.step(&mut x, &mut obj, 0, 3).unwrap();
        // x - x0 must be exactly -eta * m
        for i in 0..d {
            assert!((x[i] - (x0[i] - opt.m[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn momentum_accumulates_across_steps() {
        let d = 16;
        let mut opt = MezoMomentum::new(d, 1e-3, 1e-2, BetaSchedule::Constant(0.9));
        let mut obj = crate::objective::NativeQuadratic::new(d);
        let mut x = vec![1f32; d];
        opt.step(&mut x, &mut obj, 0, 3).unwrap();
        let m1 = opt.m.clone();
        opt.step(&mut x, &mut obj, 1, 3).unwrap();
        // m2 = 0.9*m1 + 0.1*g2 z2 -> correlated with m1
        assert!(vecmath::cos2(&opt.m, &m1) > 0.2);
    }
}
