//! ZO-AdaMM (Chen et al. 2019): Adam-style adaptive moments driven by the
//! ZO gradient estimate ghat = g * z (Table 7 baseline).
//!
//!   mu <- b1 mu + (1-b1) ghat
//!   nu <- max(nu, b2 nu + (1-b2) ghat^2)   (AMSGrad-style max, per paper)
//!   x  <- x - eta mu / (sqrt(nu) + eps)
//!
//! Stores TWO extra d-vectors — strictly more memory than ConMeZO's one
//! (the point the paper makes in §6.4).

use crate::util::error::Result;

use super::{sample_direction, StepStats, ZoOptimizer};
use crate::objective::Objective;
use crate::util::memory::MemoryMeter;

pub struct ZoAdaMM {
    pub eta: f32,
    pub lam: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    mu: Vec<f32>,
    nu: Vec<f32>,
    z: Vec<f32>,
}

impl ZoAdaMM {
    pub fn new(dim: usize, eta: f32, lam: f32) -> Self {
        ZoAdaMM {
            eta,
            lam,
            b1: 0.9,
            b2: 0.999,
            eps: 1e-8,
            mu: vec![0.0; dim],
            nu: vec![0.0; dim],
            z: vec![0.0; dim],
        }
    }
}

impl ZoOptimizer for ZoAdaMM {
    fn name(&self) -> &'static str {
        "zo_adamm"
    }

    fn step(&mut self, x: &mut [f32], obj: &mut dyn Objective, t: usize, run_seed: u64) -> Result<StepStats> {
        sample_direction(&mut self.z, obj.d_raw(), run_seed, t);
        let (lp, lm) = obj.two_point(x, &self.z, self.lam)?;
        let g = ((lp - lm) / (2.0 * self.lam as f64)) as f32;
        let (b1, b2) = (self.b1, self.b2);
        for i in 0..x.len() {
            let ghat = g * self.z[i];
            self.mu[i] = b1 * self.mu[i] + (1.0 - b1) * ghat;
            let nu_new = b2 * self.nu[i] + (1.0 - b2) * ghat * ghat;
            self.nu[i] = self.nu[i].max(nu_new);
            x[i] -= self.eta * self.mu[i] / (self.nu[i].sqrt() + self.eps);
        }
        Ok(StepStats { loss: 0.5 * (lp + lm), proj_grad: g as f64, evals: 2 })
    }

    fn record_memory(&self, meter: &mut MemoryMeter) {
        meter.alloc_f32("opt.mu", self.mu.len());
        meter.alloc_f32("opt.nu", self.nu.len());
        meter.alloc_f32("opt.direction", self.z.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::test_support::{initial_quadratic_loss, quadratic_final_loss};
    use crate::util::memory::MemoryMeter;

    #[test]
    fn descends_on_quadratic() {
        let d = 200;
        let l0 = initial_quadratic_loss(d, 8);
        let l = quadratic_final_loss(&mut ZoAdaMM::new(d, 5e-2, 1e-2), d, 800, 8);
        assert!(l < 0.7 * l0, "{l} vs {l0}");
    }

    #[test]
    fn nu_is_monotone_nondecreasing() {
        let d = 32;
        let mut opt = ZoAdaMM::new(d, 1e-3, 1e-2);
        let mut obj = crate::objective::NativeQuadratic::new(d);
        let mut x = vec![1f32; d];
        opt.step(&mut x, &mut obj, 0, 1).unwrap();
        let nu1 = opt.nu.clone();
        for t in 1..10 {
            opt.step(&mut x, &mut obj, t, 1).unwrap();
        }
        for i in 0..d {
            assert!(opt.nu[i] >= nu1[i]);
        }
    }

    #[test]
    fn uses_more_memory_than_conmezo_momentum() {
        let mut a = MemoryMeter::new();
        ZoAdaMM::new(100, 1e-3, 1e-3).record_memory(&mut a);
        let mut c = MemoryMeter::new();
        crate::optimizer::ConMeZo::new(100, 1e-3, 1e-3, 1.35, super::super::BetaSchedule::Constant(0.9))
            .record_memory(&mut c);
        // mu+nu+z = 3 buffers vs m+u+z = 3 in this impl accounting, but the
        // *persistent state* (excluding regenerable direction scratch) is
        // 2 vs 1 buffers:
        assert!(a.current_bytes() >= c.current_bytes());
    }
}
