//! MeZO (Malladi et al. 2023): isotropic two-point SPSA.
//!
//! Two implementations:
//!
//! * [`Mezo`] — the *vectorized* flat-buffer variant (one direction buffer,
//!   fused perturb/update passes). This is the fair algorithmic baseline
//!   used in all accuracy tables.
//! * [`MezoLoop`] — a faithful emulation of the reference MeZO
//!   implementation's *loop-based* perturbation: it walks the parameter
//!   layout tensor-by-tensor and regenerates the random direction four
//!   times per step from the same seed (perturb +λ, hop to -λ, restore,
//!   update), never materializing a full direction buffer. This is the
//!   memory-minimal variant the paper contrasts against in §3.3/Table 3 —
//!   ConMeZO's extra momentum buffer is what lets it skip two of the four
//!   regenerations.

use crate::util::error::Result;

use super::{sample_direction, StepStats, ZoOptimizer};
use crate::objective::Objective;
use crate::util::memory::MemoryMeter;
use crate::util::rng::{Xoshiro256pp, STREAM_DIRECTION};
use crate::vecmath;

// ---------------------------------------------------------------------------
// Vectorized MeZO
// ---------------------------------------------------------------------------

pub struct Mezo {
    pub eta: f32,
    pub lam: f32,
    z: Vec<f32>,
}

impl Mezo {
    pub fn new(dim: usize, eta: f32, lam: f32) -> Self {
        Mezo { eta, lam, z: vec![0.0; dim] }
    }
}

impl ZoOptimizer for Mezo {
    fn name(&self) -> &'static str {
        "mezo"
    }

    fn step(&mut self, x: &mut [f32], obj: &mut dyn Objective, t: usize, run_seed: u64) -> Result<StepStats> {
        sample_direction(&mut self.z, obj.d_raw(), run_seed, t);
        let (lp, lm) = obj.two_point(x, &self.z, self.lam)?;
        let g = ((lp - lm) / (2.0 * self.lam as f64)) as f32;
        vecmath::axpy(-self.eta * g, &self.z, x);
        Ok(StepStats { loss: 0.5 * (lp + lm), proj_grad: g as f64, evals: 2 })
    }

    fn record_memory(&self, meter: &mut MemoryMeter) {
        meter.alloc_f32("opt.direction", self.z.len());
    }
}

// ---------------------------------------------------------------------------
// Loop-based MeZO emulation (§3.3 / Table 3 comparison target)
// ---------------------------------------------------------------------------

pub struct MezoLoop {
    pub eta: f32,
    pub lam: f32,
    /// (offset, len) of every parameter tensor in the flat buffer.
    segments: Vec<(usize, usize)>,
    dim: usize,
}

impl MezoLoop {
    /// `layout` is (offset, shape) per tensor, as recorded in the manifest.
    pub fn new(dim: usize, eta: f32, lam: f32, layout: &[(usize, Vec<usize>)]) -> Self {
        let mut segments: Vec<(usize, usize)> = layout
            .iter()
            .map(|(off, shape)| (*off, shape.iter().product::<usize>().max(1)))
            .collect();
        if segments.is_empty() {
            segments.push((0, dim));
        }
        MezoLoop { eta, lam, segments, dim }
    }

    /// One pass over all tensors applying x += scale * z with z regenerated
    /// from `seed` — the MeZO `efficient_perturb_parameters` (App. B).
    fn perturb_pass(&self, x: &mut [f32], scale: f32, run_seed: u64, t: usize) {
        // regenerate the SAME stream each pass (torch.manual_seed(seed))
        let mut rng = Xoshiro256pp::derive_stream(run_seed, STREAM_DIRECTION, t as u64);
        let mut chunk = vec![0f32; 0];
        for &(off, len) in &self.segments {
            chunk.resize(len, 0.0);
            rng.fill_normal_f32(&mut chunk);
            vecmath::axpy(scale, &chunk, &mut x[off..off + len]);
        }
    }
}

impl ZoOptimizer for MezoLoop {
    fn name(&self) -> &'static str {
        "mezo_loop"
    }

    fn step(&mut self, x: &mut [f32], obj: &mut dyn Objective, t: usize, run_seed: u64) -> Result<StepStats> {
        debug_assert_eq!(x.len(), self.dim);
        // 1st regeneration: x -> x + lam z
        self.perturb_pass(x, self.lam, run_seed, t);
        let lp = obj.loss(x)?;
        // 2nd regeneration: x -> x - lam z (hop of -2 lam)
        self.perturb_pass(x, -2.0 * self.lam, run_seed, t);
        let lm = obj.loss(x)?;
        // 3rd regeneration: restore x
        self.perturb_pass(x, self.lam, run_seed, t);
        let g = ((lp - lm) / (2.0 * self.lam as f64)) as f32;
        // 4th regeneration: the update x -= eta g z
        self.perturb_pass(x, -self.eta * g, run_seed, t);
        Ok(StepStats { loss: 0.5 * (lp + lm), proj_grad: g as f64, evals: 2 })
    }

    fn record_memory(&self, meter: &mut MemoryMeter) {
        // only the largest tensor chunk is ever materialized
        let max_seg = self.segments.iter().map(|&(_, l)| l).max().unwrap_or(0);
        meter.alloc_f32("opt.chunk", max_seg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::NativeQuadratic;
    use crate::optimizer::test_support::{initial_quadratic_loss, quadratic_final_loss};

    #[test]
    fn mezo_descends_on_quadratic() {
        let d = 200;
        let l0 = initial_quadratic_loss(d, 2);
        let l = quadratic_final_loss(&mut Mezo::new(d, 1e-3, 1e-2), d, 800, 2);
        assert!(l < 0.7 * l0, "loss {l} vs {l0}");
    }

    #[test]
    fn loop_variant_matches_vectorized_losses() {
        // MezoLoop must be *algorithmically identical* to Mezo when the
        // segment walk covers the buffer in order (same RNG stream order):
        // identical per-step losses and identical final iterate (up to f32
        // rounding of the different pass structure).
        let d = 128;
        let layout = vec![(0usize, vec![32usize, 2]), (64, vec![64usize])];
        let mut a = Mezo::new(d, 1e-3, 1e-2);
        let mut b = MezoLoop::new(d, 1e-3, 1e-2, &layout);
        let mut oa = NativeQuadratic::new(d);
        let mut ob = NativeQuadratic::new(d);
        let mut xa = vec![1f32; d];
        let mut xb = vec![1f32; d];
        for t in 0..20 {
            let sa = a.step(&mut xa, &mut oa, t, 4).unwrap();
            let sb = b.step(&mut xb, &mut ob, t, 4).unwrap();
            assert!((sa.loss - sb.loss).abs() < 1e-4, "t={t}: {} vs {}", sa.loss, sb.loss);
        }
        for i in 0..d {
            assert!((xa[i] - xb[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn loop_variant_restores_params_when_gradient_zero() {
        // on a flat objective g == 0, so after a step x must be unchanged
        // (the 3 perturbation passes must cancel exactly in f32)
        struct Flat;
        impl Objective for Flat {
            fn dim(&self) -> usize { 64 }
            fn d_raw(&self) -> usize { 64 }
            fn loss(&mut self, _x: &[f32]) -> Result<f64> { Ok(1.0) }
            fn two_point(&mut self, _x: &[f32], _z: &[f32], _l: f32) -> Result<(f64, f64)> {
                Ok((1.0, 1.0))
            }
            fn evals(&self) -> u64 { 0 }
        }
        let mut opt = MezoLoop::new(64, 1e-3, 1e-3, &[(0, vec![64])]);
        let x0: Vec<f32> = (0..64).map(|i| i as f32 * 0.25).collect();
        let mut x = x0.clone();
        opt.step(&mut x, &mut Flat, 0, 7).unwrap();
        for i in 0..64 {
            assert!((x[i] - x0[i]).abs() < 1e-5, "coord {i}: {} vs {}", x[i], x0[i]);
        }
    }

    #[test]
    fn mezo_loop_memory_is_chunk_sized() {
        let layout = vec![(0usize, vec![100usize]), (100, vec![50usize])];
        let mut meter = MemoryMeter::new();
        MezoLoop::new(150, 1e-3, 1e-3, &layout).record_memory(&mut meter);
        assert_eq!(meter.current_bytes(), 100 * 4);
        let mut meter2 = MemoryMeter::new();
        Mezo::new(150, 1e-3, 1e-3).record_memory(&mut meter2);
        assert_eq!(meter2.current_bytes(), 150 * 4);
    }

    #[test]
    fn deterministic_per_seed() {
        let d = 64;
        let a = quadratic_final_loss(&mut Mezo::new(d, 1e-3, 1e-2), d, 50, 11);
        let b = quadratic_final_loss(&mut Mezo::new(d, 1e-3, 1e-2), d, 50, 11);
        assert_eq!(a, b);
    }
}
