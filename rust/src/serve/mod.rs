//! Multi-tenant adapter finetuning service: N concurrent LoRA-style ZO
//! jobs multiplexed over ONE `Runtime`, ONE `WorkerPool`, and ONE shared
//! read-only base-weight buffer.
//!
//! The serving model inverts the trainer's one-run-owns-everything shape:
//!
//! * **shared, per preset** — the base parameter buffer (`init` program,
//!   `base_seed`) and one [`AdapterSession`] per `(preset, rank)` pair
//!   (model plan + forward scratch). These are O(d) and paid once.
//! * **per tenant** — an adapter vector of `AdapterPlan::dim()` floats
//!   plus the tenant's optimizer state over that vector. The low-rank
//!   delta fuses into the weight loads ([`crate::vecmath::AdapterBinding`])
//!   so no tenant ever materializes a private weight copy: the marginal
//!   tenant costs O(rank·dims), not O(d).
//!
//! Scheduling is a deterministic round-robin: each runnable job gets up to
//! `quantum` units per turn (a unit = one ZO train step, or one full eval
//! pass for `mode=eval` tenants). Every job's direction/batch/eval streams
//! are pure functions of its OWN `(seed, t)` — nothing reads the global
//! interleaving — so the final adapters are bit-identical for any quantum
//! (pinned by `scheduler_is_deterministic_across_quanta`).
//!
//! Job lifecycle: `Active -> (pause_at: checkpoint + drop state) Paused ->
//! (next turn: reload + replay batch stream) Active -> Done`. Checkpoints
//! are per-tenant CMZ1 files holding the adapter plus every
//! [`ZoOptimizer::state`] buffer; resume rebuilds the objective and calls
//! `advance()` t times so step t sees the same minibatch it would have in
//! an uninterrupted run (pinned bit-identically by
//! `checkpoint_roundtrip_is_bit_identical`).
//!
//! [`AdapterSession`]: crate::runtime::adapter::AdapterSession

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use crate::util::error::{bail, Result};

use crate::checkpoint::{params_hash, Checkpoint};
use crate::data::{self, Example, TaskGen, TrainSampler};
use crate::eval::{predict, score};
use crate::objective::{AdapterObjective, Objective, SharedAdapterSession};
use crate::optimizer::{BetaSchedule, ZoOptimizer};
use crate::runtime::{lit_vec_f32, Arg, PresetMeta, Runtime};
use crate::util::memory::MemoryMeter;

// ---------------------------------------------------------------------------
// Workload manifest
// ---------------------------------------------------------------------------

/// What a tenant's job units do.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum JobMode {
    /// Each unit is one ZO train step (with optional periodic eval).
    Train,
    /// Each unit is one full eval pass over `eval_n` examples.
    Eval,
}

/// One tenant line of the workload manifest.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    pub preset: String,
    pub rank: usize,
    pub optimizer: String,
    pub task: String,
    /// Train steps (or eval passes for `mode=eval`).
    pub steps: usize,
    pub seed: u64,
    pub eta: f32,
    pub lam: f32,
    pub theta: f32,
    pub beta: f32,
    /// Run an eval pass every N train steps (0 = never).
    pub eval_every: usize,
    pub eval_n: usize,
    pub train_n: usize,
    /// Checkpoint + drop all live state after this many completed steps;
    /// the job resumes from the CMZ1 file on its next turn.
    pub pause_at: Option<usize>,
    pub mode: JobMode,
}

impl TenantSpec {
    fn defaults(idx: usize, base_seed: u64) -> TenantSpec {
        TenantSpec {
            name: format!("t{idx}"),
            preset: "nano".to_string(),
            rank: 4,
            optimizer: "conmezo".to_string(),
            task: "sst2".to_string(),
            steps: 10,
            seed: base_seed.wrapping_add(idx as u64),
            eta: 1e-2,
            lam: 1e-3,
            theta: 1.35,
            beta: 0.9,
            eval_every: 0,
            eval_n: 32,
            train_n: 64,
            pause_at: None,
            mode: JobMode::Train,
        }
    }
}

/// A parsed workload: scheduler settings + tenant list.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Units per job per round-robin turn (>= 1).
    pub quantum: usize,
    /// Seed for the shared base weights (`init` program argument).
    pub base_seed: u64,
    pub tenants: Vec<TenantSpec>,
}

fn num<T: std::str::FromStr>(v: &str, what: &str, ln: usize) -> Result<T> {
    v.parse().map_err(|_| crate::anyhow!("manifest line {ln}: bad {what} value {v:?}"))
}

impl ServeConfig {
    /// Parse the text manifest format: one directive per line, `#`
    /// comments. `quantum N` and `base_seed N` apply to subsequent lines;
    /// `tenant key=value ...` declares one job (unknown keys are errors).
    pub fn parse(text: &str) -> Result<ServeConfig> {
        let mut cfg = ServeConfig { quantum: 1, base_seed: 42, tenants: Vec::new() };
        for (i, raw) in text.lines().enumerate() {
            let ln = i + 1;
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            match it.next().unwrap() {
                "quantum" => {
                    let v = it.next().ok_or_else(|| {
                        crate::anyhow!("manifest line {ln}: quantum needs a value")
                    })?;
                    cfg.quantum = num(v, "quantum", ln)?;
                    if cfg.quantum == 0 {
                        bail!("manifest line {ln}: quantum must be >= 1");
                    }
                }
                "base_seed" => {
                    let v = it.next().ok_or_else(|| {
                        crate::anyhow!("manifest line {ln}: base_seed needs a value")
                    })?;
                    cfg.base_seed = num(v, "base_seed", ln)?;
                }
                "tenant" => {
                    let mut t = TenantSpec::defaults(cfg.tenants.len(), cfg.base_seed);
                    for kv in it {
                        let (k, v) = kv.split_once('=').ok_or_else(|| {
                            crate::anyhow!("manifest line {ln}: expected key=value, got {kv:?}")
                        })?;
                        match k {
                            "name" => t.name = v.to_string(),
                            "preset" => t.preset = v.to_string(),
                            "rank" => t.rank = num(v, "rank", ln)?,
                            "opt" => t.optimizer = v.to_string(),
                            "task" => t.task = v.to_string(),
                            "steps" => t.steps = num(v, "steps", ln)?,
                            "seed" => t.seed = num(v, "seed", ln)?,
                            "eta" => t.eta = num(v, "eta", ln)?,
                            "lam" => t.lam = num(v, "lam", ln)?,
                            "theta" => t.theta = num(v, "theta", ln)?,
                            "beta" => t.beta = num(v, "beta", ln)?,
                            "eval_every" => t.eval_every = num(v, "eval_every", ln)?,
                            "eval_n" => t.eval_n = num(v, "eval_n", ln)?,
                            "train_n" => t.train_n = num(v, "train_n", ln)?,
                            "pause_at" => t.pause_at = Some(num(v, "pause_at", ln)?),
                            "mode" => {
                                t.mode = match v {
                                    "train" => JobMode::Train,
                                    "eval" => JobMode::Eval,
                                    other => bail!("manifest line {ln}: unknown mode {other:?}"),
                                }
                            }
                            other => bail!("manifest line {ln}: unknown tenant key {other:?}"),
                        }
                    }
                    if t.rank == 0 {
                        bail!("manifest line {ln}: rank must be >= 1");
                    }
                    if t.mode == JobMode::Eval && t.eval_n == 0 {
                        bail!("manifest line {ln}: mode=eval needs eval_n >= 1");
                    }
                    cfg.tenants.push(t);
                }
                other => bail!("manifest line {ln}: unknown directive {other:?}"),
            }
        }
        if cfg.tenants.is_empty() {
            bail!("manifest declares no tenants");
        }
        for (i, a) in cfg.tenants.iter().enumerate() {
            for b in &cfg.tenants[i + 1..] {
                if a.name == b.name {
                    bail!("duplicate tenant name {:?} (checkpoints are keyed by name)", a.name);
                }
            }
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| crate::anyhow!("reading manifest {}: {e}", path.display()))?;
        Self::parse(&text)
    }
}

// ---------------------------------------------------------------------------
// Per-job state + telemetry
// ---------------------------------------------------------------------------

/// Per-job counters and timings.
#[derive(Clone, Debug, Default)]
pub struct JobStats {
    pub steps: usize,
    pub evals: usize,
    pub checkpoints: usize,
    pub resumes: usize,
    pub last_loss: f64,
    pub last_acc: f64,
    /// Time spent waiting for a scheduler turn.
    pub queue_wait_ns: u64,
    /// Time spent actually computing units.
    pub compute_ns: u64,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum JobState {
    Active,
    /// Checkpointed to disk at the recorded step; no live adapter /
    /// optimizer / objective until the next turn reloads them.
    Paused,
    Done,
}

struct Job {
    spec: TenantSpec,
    meta: PresetMeta,
    state: JobState,
    /// Completed units (train steps, or eval passes for `mode=eval`).
    t: usize,
    adapter: Vec<f32>,
    opt: Option<Box<dyn ZoOptimizer>>,
    obj: Option<AdapterObjective>,
    sess: SharedAdapterSession,
    base: Rc<Vec<f32>>,
    train: Vec<Example>,
    eval_examples: Vec<Example>,
    paused_once: bool,
    stats: JobStats,
    last_release: Instant,
}

fn build_opt(spec: &TenantSpec, dim: usize) -> Result<Box<dyn ZoOptimizer>> {
    // the adapter vector is the optimizer's whole world: no pad lanes, no
    // tensor layout (structured perturbations already live in the plan)
    crate::optimizer::by_name(
        &spec.optimizer,
        dim,
        spec.eta,
        spec.lam,
        spec.theta,
        BetaSchedule::Constant(spec.beta),
        &[],
    )
}

impl Job {
    fn build(spec: TenantSpec, sess: SharedAdapterSession, base: Rc<Vec<f32>>) -> Result<Job> {
        let (meta, dim, adapter) = {
            let s = sess.borrow();
            (s.meta().clone(), s.plan().dim(), s.plan().init(spec.seed as i32))
        };
        let task = data::spec(&spec.task).ok_or_else(|| {
            crate::anyhow!("tenant {:?}: unknown task {:?}", spec.name, spec.task)
        })?;
        let gen = TaskGen::new(task, meta.vocab, meta.seq_len);
        let train = gen.dataset(spec.train_n, spec.seed);
        let eval_examples = gen.dataset(spec.eval_n, spec.seed ^ 0xEEE);
        let (opt, obj) = match spec.mode {
            JobMode::Train => {
                let opt = build_opt(&spec, dim)?;
                let sampler =
                    TrainSampler::new(train.clone(), meta.batch, meta.seq_len, spec.seed, 0);
                let obj = AdapterObjective::new(sess.clone(), base.clone(), Box::new(sampler))?;
                (Some(opt), Some(obj))
            }
            JobMode::Eval => (None, None),
        };
        let state = if spec.steps == 0 { JobState::Done } else { JobState::Active };
        let stats = JobStats { last_loss: f64::NAN, last_acc: f64::NAN, ..JobStats::default() };
        Ok(Job {
            spec,
            meta,
            state,
            t: 0,
            adapter,
            opt,
            obj,
            sess,
            base,
            train,
            eval_examples,
            paused_once: false,
            stats,
            last_release: Instant::now(),
        })
    }

    fn ckpt_path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.cmz1", self.spec.name))
    }

    /// Persistent bytes the tenant owns beyond the shared base/session:
    /// adapter vector + optimizer state/scratch — all O(rank·dims).
    fn tenant_bytes(&self) -> usize {
        let mut m = MemoryMeter::new();
        m.alloc_f32("adapter", self.adapter.len());
        if let Some(opt) = &self.opt {
            opt.record_memory(&mut m);
        }
        m.current_bytes()
    }

    /// One scheduler turn: resume if paused, then run up to `quantum`
    /// units (a pause ends the turn early).
    fn run_turn(&mut self, quantum: usize, ckpt_dir: &Path) -> Result<()> {
        if self.state == JobState::Paused {
            self.resume(ckpt_dir)?;
        }
        for _ in 0..quantum {
            if self.state != JobState::Active {
                break;
            }
            let t0 = Instant::now();
            self.unit(ckpt_dir)?;
            self.stats.compute_ns += t0.elapsed().as_nanos() as u64;
        }
        Ok(())
    }

    fn unit(&mut self, ckpt_dir: &Path) -> Result<()> {
        match self.spec.mode {
            JobMode::Train => {
                let opt = self.opt.as_mut().expect("active train job has an optimizer");
                let obj = self.obj.as_mut().expect("active train job has an objective");
                let st = opt.step(&mut self.adapter, obj, self.t, self.spec.seed)?;
                obj.advance();
                self.stats.last_loss = st.loss;
                self.stats.steps += 1;
                self.t += 1;
                if self.spec.eval_every > 0 && self.t % self.spec.eval_every == 0 {
                    self.run_eval();
                }
                if self.t >= self.spec.steps {
                    self.state = JobState::Done;
                } else if Some(self.t) == self.spec.pause_at && !self.paused_once {
                    self.pause(ckpt_dir)?;
                }
            }
            JobMode::Eval => {
                self.run_eval();
                self.t += 1;
                if self.t >= self.spec.steps {
                    self.state = JobState::Done;
                }
            }
        }
        Ok(())
    }

    /// Candidate-restricted eval over the job's fixed example set through
    /// the position-masked LM head (only the predicted positions hit the
    /// tied-embedding GEMM).
    fn run_eval(&mut self) {
        let (b, s, v) = (self.meta.batch, self.meta.seq_len, self.meta.vocab);
        let mut ids = vec![0i32; b * s];
        let mut pos = vec![0i32; b];
        let mut out = vec![0f32; b * v];
        let mut pairs = Vec::with_capacity(self.eval_examples.len());
        let mut sess = self.sess.borrow_mut();
        for chunk in self.eval_examples.chunks(b) {
            ids.fill(0);
            pos.fill(0);
            for (i, e) in chunk.iter().enumerate() {
                ids[i * s..(i + 1) * s].copy_from_slice(&e.tokens);
                pos[i] = e.predict_pos as i32;
            }
            sess.eval_logits(&self.base, &self.adapter, &ids, &pos, b, s, &mut out);
            for (i, e) in chunk.iter().enumerate() {
                pairs.push((e.label, predict(&out[i * v..(i + 1) * v], &e.candidates)));
            }
        }
        self.stats.last_acc = score(&pairs).accuracy();
        self.stats.evals += 1;
    }

    /// Write the CMZ1 checkpoint (adapter + every optimizer state buffer)
    /// and drop all live per-tenant state.
    fn pause(&mut self, ckpt_dir: &Path) -> Result<()> {
        let mut ck = Checkpoint::new(&self.spec.preset, self.t as u64);
        ck.put("adapter", &self.adapter);
        if let Some(opt) = &self.opt {
            for (name, data) in opt.state() {
                ck.put(&format!("opt.{name}"), data);
            }
        }
        ck.save(&self.ckpt_path(ckpt_dir))?;
        self.adapter = Vec::new();
        self.opt = None;
        self.obj = None;
        self.state = JobState::Paused;
        self.paused_once = true;
        self.stats.checkpoints += 1;
        Ok(())
    }

    /// Reload the checkpoint and rebuild live state: optimizer buffers via
    /// [`ZoOptimizer::restore`], and a fresh objective advanced `t` times
    /// so step `t` consumes the same minibatch an uninterrupted run would
    /// (the batch stream is a pure function of `(seed, draw index)`).
    fn resume(&mut self, ckpt_dir: &Path) -> Result<()> {
        let path = self.ckpt_path(ckpt_dir);
        let ck = Checkpoint::load(&path)?;
        if ck.preset != self.spec.preset {
            bail!(
                "tenant {:?}: checkpoint preset {:?} != spec preset {:?}",
                self.spec.name,
                ck.preset,
                self.spec.preset
            );
        }
        self.t = ck.step as usize;
        self.adapter = ck.get("adapter")?.to_vec();
        let mut opt = build_opt(&self.spec, self.adapter.len())?;
        for (name, data) in &ck.buffers {
            if let Some(buf) = name.strip_prefix("opt.") {
                opt.restore(buf, data)?;
            }
        }
        let sampler = TrainSampler::new(
            self.train.clone(),
            self.meta.batch,
            self.meta.seq_len,
            self.spec.seed,
            0,
        );
        let mut obj =
            AdapterObjective::new(self.sess.clone(), self.base.clone(), Box::new(sampler))?;
        for _ in 0..self.t {
            obj.advance();
        }
        self.opt = Some(opt);
        self.obj = Some(obj);
        self.state = JobState::Active;
        self.stats.resumes += 1;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Server: deterministic fair-share scheduler
// ---------------------------------------------------------------------------

/// Final state of one tenant's job.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub name: String,
    /// The tenant's final adapter vector (bit-exact; determinism and
    /// checkpoint-roundtrip tests compare these directly).
    pub adapter: Vec<f32>,
    /// FNV-1a over the adapter bits (display / cheap comparison).
    pub adapter_hash: u64,
    /// Final optimizer state buffers, e.g. `("m", momentum)`.
    pub opt_state: Vec<(String, Vec<f32>)>,
    /// Per-tenant incremental memory (adapter + optimizer state bytes).
    pub tenant_bytes: usize,
    pub stats: JobStats,
}

impl JobReport {
    /// One greppable summary line (`examples/run_serve.sh` asserts on
    /// these).
    pub fn summary_line(&self) -> String {
        format!(
            "tenant {}: steps={} evals={} checkpoints={} resumes={} loss={:.4} acc={:.3} \
             wait={:.2}ms compute={:.2}ms tenant_kib={:.1} adapter_hash={:016x}",
            self.name,
            self.stats.steps,
            self.stats.evals,
            self.stats.checkpoints,
            self.stats.resumes,
            self.stats.last_loss,
            self.stats.last_acc,
            self.stats.queue_wait_ns as f64 / 1e6,
            self.stats.compute_ns as f64 / 1e6,
            self.tenant_bytes as f64 / 1024.0,
            self.adapter_hash,
        )
    }
}

/// Everything the workload produced, in manifest order.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub jobs: Vec<JobReport>,
}

/// The multi-tenant scheduler: owns the shared bases/sessions and every
/// job, and drives them round-robin until all are done. (The `Runtime` is
/// only needed at build time: sessions own their model plans and pool
/// handles.)
pub struct Server {
    cfg: ServeConfig,
    ckpt_dir: PathBuf,
    jobs: Vec<Job>,
    meter: MemoryMeter,
}

impl Server {
    /// Build all shared state and all jobs. Bases are loaded once per
    /// preset (the `init` program with `base_seed`); adapter sessions bind
    /// once per `(preset, rank)` and are shared by every matching tenant.
    pub fn new(rt: &Runtime, cfg: ServeConfig, ckpt_dir: PathBuf) -> Result<Server> {
        let mut bases: HashMap<String, Rc<Vec<f32>>> = HashMap::new();
        let mut sessions: HashMap<(String, usize), SharedAdapterSession> = HashMap::new();
        let mut meter = MemoryMeter::new();
        let mut jobs = Vec::with_capacity(cfg.tenants.len());
        for spec in &cfg.tenants {
            let base = match bases.get(&spec.preset) {
                Some(b) => b.clone(),
                None => {
                    let init = rt.load_kind(&spec.preset, "init")?;
                    let x = lit_vec_f32(&init.call(&[Arg::I32(cfg.base_seed as i32)])?[0])?;
                    meter.alloc_f32(&format!("base.{}", spec.preset), x.len());
                    let b = Rc::new(x);
                    bases.insert(spec.preset.clone(), b.clone());
                    b
                }
            };
            let key = (spec.preset.clone(), spec.rank);
            let sess = match sessions.get(&key) {
                Some(s) => s.clone(),
                None => {
                    let s: SharedAdapterSession =
                        Rc::new(RefCell::new(rt.bind_adapter(&spec.preset, spec.rank)?));
                    sessions.insert(key, s.clone());
                    s
                }
            };
            let job = Job::build(spec.clone(), sess, base)?;
            meter.alloc(&format!("tenant.{}", spec.name), job.tenant_bytes());
            jobs.push(job);
        }
        Ok(Server { cfg, ckpt_dir, jobs, meter })
    }

    /// Shared + per-tenant memory accounting (`base.<preset>` entries are
    /// the shared O(d) cost, `tenant.<name>` entries the O(rank·dims)
    /// marginals).
    pub fn meter(&self) -> &MemoryMeter {
        &self.meter
    }

    /// Run the workload to completion: round-robin turns of `quantum`
    /// units per runnable job until every job is `Done`.
    pub fn run(&mut self) -> Result<ServeReport> {
        let start = Instant::now();
        for job in &mut self.jobs {
            job.last_release = start;
        }
        loop {
            let mut any_runnable = false;
            for job in &mut self.jobs {
                if job.state == JobState::Done {
                    continue;
                }
                any_runnable = true;
                let now = Instant::now();
                job.stats.queue_wait_ns += now.duration_since(job.last_release).as_nanos() as u64;
                job.run_turn(self.cfg.quantum, &self.ckpt_dir)?;
                job.last_release = Instant::now();
            }
            if !any_runnable {
                break;
            }
        }
        Ok(self.report())
    }

    fn report(&self) -> ServeReport {
        let jobs = self
            .jobs
            .iter()
            .map(|j| JobReport {
                name: j.spec.name.clone(),
                adapter: j.adapter.clone(),
                adapter_hash: params_hash(&j.adapter),
                opt_state: j
                    .opt
                    .as_ref()
                    .map(|o| {
                        o.state()
                            .into_iter()
                            .map(|(n, d)| (n.to_string(), d.to_vec()))
                            .collect()
                    })
                    .unwrap_or_default(),
                tenant_bytes: j.tenant_bytes(),
                stats: j.stats.clone(),
            })
            .collect();
        ServeReport { jobs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParallelPolicy;

    fn rt() -> Runtime {
        Runtime::native_with(ParallelPolicy::single())
    }

    fn tmp_dir(test: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("conmezo_serve_{test}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn run_manifest(text: &str, quantum: Option<usize>, dir: &str) -> ServeReport {
        let mut cfg = ServeConfig::parse(text).unwrap();
        if let Some(q) = quantum {
            cfg.quantum = q;
        }
        let rt = rt();
        let mut server = Server::new(&rt, cfg, tmp_dir(dir)).unwrap();
        server.run().unwrap()
    }

    #[test]
    fn manifest_parses_directives_and_defaults() {
        let cfg = ServeConfig::parse(
            "# workload\nquantum 3\nbase_seed 9\n\
             tenant name=a opt=mezo steps=5 rank=2 eval_every=2 eval_n=8\n\
             tenant task=rte mode=eval steps=2\n",
        )
        .unwrap();
        assert_eq!(cfg.quantum, 3);
        assert_eq!(cfg.base_seed, 9);
        assert_eq!(cfg.tenants.len(), 2);
        let a = &cfg.tenants[0];
        assert_eq!((a.name.as_str(), a.rank, a.steps), ("a", 2, 5));
        assert_eq!(a.optimizer, "mezo");
        assert_eq!((a.eval_every, a.eval_n), (2, 8));
        assert_eq!(a.seed, 9); // base_seed + index 0
        let b = &cfg.tenants[1];
        assert_eq!(b.name, "t1"); // default name from index
        assert_eq!(b.task, "rte");
        assert_eq!(b.mode, JobMode::Eval);
        assert_eq!(b.seed, 10);
    }

    #[test]
    fn manifest_rejects_bad_input() {
        assert!(ServeConfig::parse("").is_err(), "no tenants");
        assert!(ServeConfig::parse("tenant name=a bogus=1\n").is_err(), "unknown key");
        assert!(ServeConfig::parse("quantum 0\ntenant name=a\n").is_err(), "zero quantum");
        assert!(ServeConfig::parse("tenant name=a\ntenant name=a\n").is_err(), "dup name");
        assert!(ServeConfig::parse("tenant name=a mode=weird\n").is_err(), "bad mode");
        assert!(ServeConfig::parse("frobnicate 3\n").is_err(), "unknown directive");
        assert!(ServeConfig::parse("tenant name=a rank=0\n").is_err(), "zero rank");
    }

    /// SATELLITE (c): same manifest + seeds => bit-identical final
    /// adapters and optimizer state, independent of the interleaving the
    /// quantum produces (every per-job stream is a function of (seed, t)).
    #[test]
    fn scheduler_is_deterministic_across_quanta() {
        let mani = "base_seed 5\n\
             tenant name=a opt=conmezo steps=5 seed=3 train_n=16\n\
             tenant name=b opt=mezo_momentum steps=4 seed=4 train_n=16 task=rte\n\
             tenant name=c opt=conmezo steps=3 seed=7 train_n=16 eval_every=2 eval_n=8\n";
        let r1 = run_manifest(mani, Some(1), "det_q1");
        let r3 = run_manifest(mani, Some(3), "det_q3");
        assert_eq!(r1.jobs.len(), 3);
        for (a, b) in r1.jobs.iter().zip(&r3.jobs) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.adapter, b.adapter, "adapter diverged for {}", a.name);
            assert_eq!(a.adapter_hash, b.adapter_hash);
            assert_eq!(a.opt_state, b.opt_state, "opt state diverged for {}", a.name);
            assert_eq!(a.stats.steps, b.stats.steps);
            assert_eq!(a.stats.evals, b.stats.evals);
            assert!(a.stats.last_loss.is_finite());
        }
        // the eval tenant actually evaluated (t=2 of 3)
        assert_eq!(r1.jobs[2].stats.evals, 1);
        assert!(r1.jobs[2].stats.last_acc.is_finite());
    }

    /// SATELLITE (f): pause -> CMZ1 checkpoint -> drop state -> resume
    /// must reproduce the uninterrupted run's (adapter, momentum)
    /// bit-identically.
    #[test]
    fn checkpoint_roundtrip_is_bit_identical() {
        let paused_mani = "tenant name=p opt=conmezo steps=6 seed=11 train_n=16 pause_at=3\n";
        let straight_mani = "tenant name=p opt=conmezo steps=6 seed=11 train_n=16\n";
        let dir = tmp_dir("roundtrip_paused");
        let rt_ = rt();
        let mut server =
            Server::new(&rt_, ServeConfig::parse(paused_mani).unwrap(), dir.clone()).unwrap();
        let paused = server.run().unwrap();
        let straight = run_manifest(straight_mani, None, "roundtrip_straight");
        let (p, s) = (&paused.jobs[0], &straight.jobs[0]);
        assert_eq!(p.stats.checkpoints, 1);
        assert_eq!(p.stats.resumes, 1);
        assert_eq!(s.stats.checkpoints, 0);
        assert!(dir.join("p.cmz1").exists(), "checkpoint file must persist");
        assert_eq!(p.stats.steps, 6);
        assert_eq!(s.stats.steps, 6);
        assert_eq!(p.adapter, s.adapter, "resumed adapter != uninterrupted adapter");
        assert_eq!(p.opt_state, s.opt_state, "resumed momentum != uninterrupted momentum");
        // the checkpoint on disk holds the step-3 state, not the final one
        let ck = Checkpoint::load(&dir.join("p.cmz1")).unwrap();
        assert_eq!(ck.step, 3);
        assert_ne!(ck.get("adapter").unwrap(), &p.adapter[..]);
        assert!(ck.get("opt.m").is_ok());
    }

    /// TENTPOLE acceptance: 16 concurrent tenants on one Runtime, with
    /// per-tenant incremental memory O(rank·dims) — a fraction of what 16
    /// independent full-weight trainers would pay.
    #[test]
    fn sixteen_tenants_share_one_runtime_with_small_marginals() {
        let mut mani = String::from("quantum 2\nbase_seed 3\n");
        for i in 0..16 {
            let line = match i % 4 {
                0 => format!("tenant name=j{i} opt=conmezo steps=1 seed={} train_n=8\n", 20 + i),
                1 => format!("tenant name=j{i} opt=mezo steps=1 seed={} train_n=8\n", 20 + i),
                2 => format!(
                    "tenant name=j{i} opt=mezo_momentum steps=1 seed={} train_n=8 task=rte\n",
                    20 + i
                ),
                _ => format!("tenant name=j{i} mode=eval steps=1 seed={} eval_n=8\n", 20 + i),
            };
            mani.push_str(&line);
        }
        let cfg = ServeConfig::parse(&mani).unwrap();
        let rt_ = rt();
        let mut server = Server::new(&rt_, cfg, tmp_dir("sixteen")).unwrap();
        let meta = rt_.preset("nano").unwrap().clone();
        // shared base accounted once, at full d_pad
        let base_bytes = *server.meter().breakdown().get("base.nano").unwrap();
        assert_eq!(base_bytes, meta.d_pad * 4);
        // every tenant's marginal is a small fraction of a full-weight
        // trainer's persistent state (params + m + u + z at d_pad)
        let full_weight = meta.d_pad * 4 * 4;
        let tenants: Vec<(String, usize)> = server
            .meter()
            .breakdown()
            .iter()
            .filter(|(k, _)| k.starts_with("tenant."))
            .map(|(k, v)| (k.clone(), *v))
            .collect();
        assert_eq!(tenants.len(), 16);
        for (name, bytes) in &tenants {
            assert!(
                bytes * 4 <= full_weight,
                "{name}: marginal {bytes} B not << full-weight {full_weight} B"
            );
        }
        let report = server.run().unwrap();
        assert_eq!(report.jobs.len(), 16);
        // eval-mode tenants (every 4th) evaluated, the rest trained
        for (i, j) in report.jobs.iter().enumerate() {
            assert!(j.tenant_bytes * 4 <= full_weight, "{}", j.name);
            if i % 4 == 3 {
                assert_eq!((j.stats.steps, j.stats.evals), (0, 1), "{}", j.name);
                assert!(j.stats.last_acc.is_finite());
            } else {
                assert_eq!((j.stats.steps, j.stats.evals), (1, 0), "{}", j.name);
                assert!(j.stats.last_loss.is_finite());
            }
        }
    }

    #[test]
    fn queue_and_compute_times_are_recorded() {
        let r = run_manifest(
            "tenant name=a steps=2 train_n=8\ntenant name=b steps=2 train_n=8\n",
            None,
            "timing",
        );
        for j in &r.jobs {
            assert!(j.stats.compute_ns > 0, "{} compute time", j.name);
        }
        // with two tenants round-robining, each waits while the other runs
        assert!(r.jobs.iter().any(|j| j.stats.queue_wait_ns > 0));
    }
}
