//! Pluggable execution runtime.
//!
//! The manifest's program set (`{preset}_loss`, `{preset}_two_point`, the
//! fused `*_step` programs, ...) can execute on any [`Backend`]:
//!
//! * [`native::NativeBackend`] — pure-Rust transformer forward + reverse
//!   pass ([`autograd`]) + fused ZO step emulation built on `vecmath`.
//!   Zero external dependencies, no artifacts on disk, always available;
//!   this is the default, so the full train/eval/distributed stack AND the
//!   first-order programs (`fo_sgd_step`, `fo_adamw_step`, `grad_cos2`,
//!   hence `pretrain`) run offline.
//! * `pjrt::PjrtBackend` (cargo feature `pjrt`) — loads the AOT artifacts
//!   (`artifacts/*.hlo.txt` from `python/compile/aot.py`) and executes them
//!   on the PJRT CPU client via the external `xla` crate. Adds the
//!   `loss_pallas` kernel-ablation variant that native does not implement.
//!
//! [`Runtime`] is the façade the rest of the crate talks to: it owns one
//! backend, resolves program names through the manifest, validates argument
//! shapes once (turning silent size mismatches into named errors on every
//! backend), and caches prepared programs.
//!
//! Backend selection: `Runtime::from_name("native"|"pjrt"|"auto")`, the
//! `CONMEZO_BACKEND` env var, or `Runtime::open_default()` (auto).

pub mod autograd;
pub mod manifest;
pub mod model;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::util::error::{bail, Result};

pub use manifest::{LayoutEntry, Manifest, PresetMeta, ProgramSpec, TensorSpec};
pub use native::NativeBackend;

/// A runtime argument. Vector/matrix payloads are borrowed to keep the step
/// loop allocation-free on the caller side.
pub enum Arg<'a> {
    F32(f32),
    I32(i32),
    VecF32(&'a [f32]),
    /// int32 tensor with explicit dims (e.g. token batches [B, S]).
    TensorI32(&'a [i32], Vec<usize>),
    /// f32 tensor with explicit dims.
    TensorF32(&'a [f32], Vec<usize>),
}

impl Arg<'_> {
    pub fn shape_of(&self) -> Vec<usize> {
        match self {
            Arg::F32(_) | Arg::I32(_) => vec![],
            Arg::VecF32(v) => vec![v.len()],
            Arg::TensorI32(_, d) | Arg::TensorF32(_, d) => d.clone(),
        }
    }
}

/// An owned program output (backend-agnostic replacement for the PJRT
/// literal). All exported programs return f32 payloads; I32 exists for
/// forward-compatibility with integer outputs.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Value {
    pub fn scalar(v: f32) -> Value {
        Value::F32(vec![v])
    }

    pub fn element_count(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
        }
    }
}

/// Extraction helpers for output values (same names as the old literal
/// helpers so call sites read identically across backends).
pub fn lit_f32(v: &Value) -> Result<f32> {
    match v {
        Value::F32(x) if !x.is_empty() => Ok(x[0]),
        Value::I32(x) if !x.is_empty() => Ok(x[0] as f32),
        _ => bail!("empty output value"),
    }
}

pub fn lit_vec_f32(v: &Value) -> Result<Vec<f32>> {
    match v {
        Value::F32(x) => Ok(x.clone()),
        Value::I32(_) => bail!("expected f32 output, got i32"),
    }
}

/// Copy a value's f32 payload into an existing buffer (hot path: avoids
/// the Vec allocation per step).
pub fn lit_copy_f32(v: &Value, dst: &mut [f32]) -> Result<()> {
    match v {
        Value::F32(x) => {
            if x.len() != dst.len() {
                bail!("output has {} elements, dst {}", x.len(), dst.len());
            }
            dst.copy_from_slice(x);
            Ok(())
        }
        Value::I32(_) => bail!("expected f32 output, got i32"),
    }
}

/// Backend-side executable for one manifest program.
pub trait ProgramImpl {
    fn call(&self, spec: &ProgramSpec, args: &[Arg<'_>]) -> Result<Vec<Value>>;
}

/// An execution backend: resolves manifest programs into executables.
pub trait Backend {
    /// Human-readable platform name ("native-cpu", PJRT platform, ...).
    fn platform(&self) -> String;
    /// The program/preset manifest this backend serves.
    fn manifest(&self) -> &Manifest;
    /// Prepare (compile/instantiate) one program. Called once per program
    /// name; the [`Runtime`] caches the result.
    fn instantiate(&self, spec: &ProgramSpec) -> Result<Box<dyn ProgramImpl>>;
}

/// A prepared program plus its manifest spec. Shape checking happens here,
/// against the manifest, identically on every backend.
pub struct Program {
    pub spec: ProgramSpec,
    imp: Box<dyn ProgramImpl>,
}

impl Program {
    /// Execute with typed args; returns output values in manifest order.
    pub fn call(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} args ({:?}), got {}",
                self.spec.name,
                self.spec.inputs.len(),
                self.spec.inputs.iter().map(|i| i.name.as_str()).collect::<Vec<_>>(),
                args.len()
            );
        }
        for (a, spec) in args.iter().zip(&self.spec.inputs) {
            let got = a.shape_of();
            if got != spec.shape {
                bail!(
                    "{}: arg {:?} shape mismatch: got {:?}, manifest says {:?}",
                    self.spec.name,
                    spec.name,
                    got,
                    spec.shape
                );
            }
        }
        let outs = self.imp.call(&self.spec, args)?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: program returned {} outputs, manifest says {}",
                self.spec.name,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(outs)
    }
}

/// Enable FTZ + DAZ on this thread BEFORE any execution threads spawn
/// (children inherit MXCSR). ZO momentum buffers decay geometrically
/// (beta = 0.99), and denormal f32 arithmetic on x86 traps to microcode at
/// ~100x the cost — measured as a progressive 4-5x slowdown over long
/// ConMeZO runs before this was set (EXPERIMENTS.md §Perf).
pub fn enable_flush_to_zero() {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        use std::arch::x86_64::{_mm_getcsr, _mm_setcsr};
        // bit 15 = FTZ, bit 6 = DAZ
        _mm_setcsr(_mm_getcsr() | (1 << 15) | (1 << 6));
    }
}

/// The runtime façade: one backend + a prepared-program cache.
pub struct Runtime {
    backend: Box<dyn Backend>,
    cache: RefCell<HashMap<String, Rc<Program>>>,
}

impl Runtime {
    /// Wrap an explicit backend.
    pub fn from_backend(backend: Box<dyn Backend>) -> Runtime {
        enable_flush_to_zero();
        Runtime { backend, cache: RefCell::new(HashMap::new()) }
    }

    /// The pure-Rust native backend over the built-in presets. Always
    /// available; needs no artifacts on disk.
    pub fn native() -> Runtime {
        Runtime::from_backend(Box::new(NativeBackend::new()))
    }

    /// Open a PJRT artifact directory (requires the `pjrt` cargo feature).
    #[cfg(feature = "pjrt")]
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        Ok(Runtime::from_backend(Box::new(pjrt::PjrtBackend::open(dir)?)))
    }

    /// Open a PJRT artifact directory (requires the `pjrt` cargo feature).
    #[cfg(not(feature = "pjrt"))]
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let _ = dir;
        bail!("this build has no PJRT support; rebuild with `--features pjrt` or use the native backend")
    }

    #[cfg(feature = "pjrt")]
    fn open_pjrt_default() -> Result<Runtime> {
        Ok(Runtime::from_backend(Box::new(pjrt::PjrtBackend::open_default()?)))
    }

    #[cfg(not(feature = "pjrt"))]
    fn open_pjrt_default() -> Result<Runtime> {
        bail!("backend \"pjrt\" requested but this build has no PJRT support; rebuild with `--features pjrt`")
    }

    /// Select a backend by name: "native", "pjrt", or "auto" (pjrt when the
    /// feature is compiled in AND artifacts exist, native otherwise).
    pub fn from_name(name: &str) -> Result<Runtime> {
        match name {
            "native" => Ok(Runtime::native()),
            "pjrt" => Self::open_pjrt_default(),
            "auto" | "" => Runtime::open_default(),
            other => bail!("unknown backend {other:?} (expected native|pjrt|auto)"),
        }
    }

    /// Default backend selection: the `CONMEZO_BACKEND` env var when set
    /// ("native" or "pjrt"), otherwise PJRT if compiled in and artifacts are
    /// present, otherwise native.
    pub fn open_default() -> Result<Runtime> {
        match std::env::var("CONMEZO_BACKEND").as_deref() {
            Ok("native") => return Ok(Runtime::native()),
            Ok("pjrt") => return Self::open_pjrt_default(),
            Ok("auto") | Ok("") | Err(_) => {}
            Ok(other) => {
                bail!("CONMEZO_BACKEND={other:?} not recognized (expected native|pjrt|auto)")
            }
        }
        #[cfg(feature = "pjrt")]
        if let Ok(b) = pjrt::PjrtBackend::open_default() {
            return Ok(Runtime::from_backend(Box::new(b)));
        }
        Ok(Runtime::native())
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    /// Load (and prepare, once) a program by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<Program>> {
        if let Some(p) = self.cache.borrow().get(name) {
            return Ok(p.clone());
        }
        let spec = self.backend.manifest().program(name)?.clone();
        let t0 = std::time::Instant::now();
        let imp = self.backend.instantiate(&spec)?;
        crate::debug!(
            "runtime",
            "prepared {name} in {:.3}s",
            t0.elapsed().as_secs_f64()
        );
        let prog = Rc::new(Program { spec, imp });
        self.cache.borrow_mut().insert(name.to_string(), prog.clone());
        Ok(prog)
    }

    /// Load a preset-scoped program, e.g. ("tiny", "conmezo_step").
    pub fn load_kind(&self, preset: &str, kind: &str) -> Result<Rc<Program>> {
        self.load(&format!("{preset}_{kind}"))
    }

    pub fn preset(&self, name: &str) -> Result<&PresetMeta> {
        self.backend.manifest().preset(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_always_opens() {
        let rt = Runtime::native();
        assert_eq!(rt.platform(), "native-cpu");
        assert!(rt.manifest().programs.len() >= 8);
        assert!(rt.preset("nano").is_ok());
    }

    #[test]
    fn from_name_selects() {
        assert!(Runtime::from_name("native").is_ok());
        assert!(Runtime::from_name("auto").is_ok());
        assert!(Runtime::from_name("bogus").is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(Runtime::from_name("pjrt").is_err());
    }

    #[test]
    fn value_helpers() {
        let v = Value::F32(vec![1.5, 2.5]);
        assert_eq!(lit_f32(&v).unwrap(), 1.5);
        assert_eq!(lit_vec_f32(&v).unwrap(), vec![1.5, 2.5]);
        let mut dst = [0f32; 2];
        lit_copy_f32(&v, &mut dst).unwrap();
        assert_eq!(dst, [1.5, 2.5]);
        let mut short = [0f32; 1];
        assert!(lit_copy_f32(&v, &mut short).is_err());
        assert!(lit_f32(&Value::F32(vec![])).is_err());
    }

    #[test]
    fn program_cache_returns_same_rc() {
        let rt = Runtime::native();
        let a = rt.load("nano_loss").unwrap();
        let b = rt.load("nano_loss").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }
}
