//! L3 runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute them
//! on the PJRT CPU client via the `xla` crate.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Programs are compiled once and cached;
//! after that the binary is self-contained — Python never runs again.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{LayoutEntry, Manifest, PresetMeta, ProgramSpec, TensorSpec};

/// A runtime argument. Vector/matrix payloads are borrowed to keep the step
/// loop allocation-free on the caller side.
pub enum Arg<'a> {
    F32(f32),
    I32(i32),
    VecF32(&'a [f32]),
    /// int32 tensor with explicit dims (e.g. token batches [B, S]).
    TensorI32(&'a [i32], Vec<usize>),
    /// f32 tensor with explicit dims.
    TensorF32(&'a [f32], Vec<usize>),
}

impl Arg<'_> {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Arg::F32(v) => xla::Literal::scalar(*v),
            Arg::I32(v) => xla::Literal::scalar(*v),
            Arg::VecF32(v) => xla::Literal::vec1(v),
            Arg::TensorI32(v, dims) => {
                let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(v).reshape(&d)?
            }
            Arg::TensorF32(v, dims) => {
                let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(v).reshape(&d)?
            }
        })
    }

    fn shape_of(&self) -> Vec<usize> {
        match self {
            Arg::F32(_) | Arg::I32(_) => vec![],
            Arg::VecF32(v) => vec![v.len()],
            Arg::TensorI32(_, d) | Arg::TensorF32(_, d) => d.clone(),
        }
    }
}

/// A compiled program plus its manifest spec.
pub struct Program {
    pub spec: ProgramSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Program {
    /// Execute with typed args; returns output literals in manifest order.
    ///
    /// Shape checking happens against the manifest up front, turning silent
    /// PJRT size mismatches into named errors.
    pub fn call(&self, args: &[Arg<'_>]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} args ({:?}), got {}",
                self.spec.name,
                self.spec.inputs.len(),
                self.spec.inputs.iter().map(|i| i.name.as_str()).collect::<Vec<_>>(),
                args.len()
            );
        }
        for (a, spec) in args.iter().zip(&self.spec.inputs) {
            let got = a.shape_of();
            if got != spec.shape {
                bail!(
                    "{}: arg {:?} shape mismatch: got {:?}, manifest says {:?}",
                    self.spec.name,
                    spec.name,
                    got,
                    spec.shape
                );
            }
        }
        let mut lits = Vec::with_capacity(args.len());
        for a in args {
            lits.push(a.to_literal()?);
        }
        let bufs = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", self.spec.name))?;
        // return_tuple=True => one tuple-shaped output buffer
        let tuple = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching outputs of {}", self.spec.name))?;
        let outs = tuple.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: program returned {} outputs, manifest says {}",
                self.spec.name,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(outs)
    }
}

/// Enable FTZ + DAZ on this thread BEFORE the PJRT client spawns its
/// thread pool (children inherit MXCSR). ZO momentum buffers decay
/// geometrically (beta = 0.99), and denormal f32 arithmetic on x86 traps to
/// microcode at ~100x the cost — measured as a progressive 4-5x slowdown
/// over long ConMeZO runs before this was set (EXPERIMENTS.md §Perf).
pub fn enable_flush_to_zero() {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        use std::arch::x86_64::{_mm_getcsr, _mm_setcsr};
        // bit 15 = FTZ, bit 6 = DAZ
        _mm_setcsr(_mm_getcsr() | (1 << 15) | (1 << 6));
    }
}

/// Extraction helpers for output literals.
pub fn lit_f32(l: &xla::Literal) -> Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}

pub fn lit_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Copy a literal's f32 payload into an existing buffer (hot path: avoids
/// the Vec allocation per step).
pub fn lit_copy_f32(l: &xla::Literal, dst: &mut [f32]) -> Result<()> {
    if l.element_count() != dst.len() {
        bail!("literal has {} elements, dst {}", l.element_count(), dst.len());
    }
    l.copy_raw_to(dst)?;
    Ok(())
}

/// The PJRT runtime: client + artifact directory + compiled-program cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Program>>>,
}

impl Runtime {
    /// Open the artifact directory (compiles nothing yet).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        enable_flush_to_zero();
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifact location relative to the repo root.
    pub fn open_default() -> Result<Runtime> {
        let candidates = ["artifacts", "../artifacts", "../../artifacts"];
        for c in candidates {
            if Path::new(c).join("manifest.json").exists() {
                return Self::open(c);
            }
        }
        // fall back to CARGO_MANIFEST_DIR for tests
        let from_env = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if from_env.join("manifest.json").exists() {
            return Self::open(from_env);
        }
        bail!("artifacts/manifest.json not found; run `make artifacts`")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (and compile, once) a program by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<Program>> {
        if let Some(p) = self.cache.borrow().get(name) {
            return Ok(p.clone());
        }
        let spec = self.manifest.program(name)?.clone();
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        crate::debug!(
            "runtime",
            "compiled {name} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        let prog = Rc::new(Program { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), prog.clone());
        Ok(prog)
    }

    /// Load a preset-scoped program, e.g. ("tiny", "conmezo_step").
    pub fn load_kind(&self, preset: &str, kind: &str) -> Result<Rc<Program>> {
        self.load(&format!("{preset}_{kind}"))
    }

    pub fn preset(&self, name: &str) -> Result<&PresetMeta> {
        self.manifest.preset(name)
    }
}
