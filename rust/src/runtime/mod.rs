//! Pluggable execution runtime with a bind-once / run-many session API.
//!
//! The manifest's program set (`{preset}_loss`, `{preset}_two_point`, the
//! fused `*_step` programs, ...) can execute on any [`Backend`]:
//!
//! * [`native::NativeBackend`] — pure-Rust transformer forward + reverse
//!   pass ([`autograd`]) + fused ZO step emulation built on `vecmath`,
//!   including a native `loss_pallas` kernel-ablation twin. Zero external
//!   dependencies, no artifacts on disk, always available; this is the
//!   default, so the full train/eval/distributed stack AND the first-order
//!   programs (`fo_sgd_step`, `fo_adamw_step`, `grad_cos2`, hence
//!   `pretrain`) run offline.
//! * `pjrt::PjrtBackend` (cargo feature `pjrt`) — loads the AOT artifacts
//!   (`artifacts/*.hlo.txt` from `python/compile/aot.py`) and executes them
//!   on the PJRT CPU client via the external `xla` crate.
//!
//! ## Execution model: bind once, run many
//!
//! ConMeZO's cost profile is two forward evals per step across millions of
//! steps, so the per-call surface is the hot path of the whole system. A
//! program is *bound* once into a [`Session`] — which owns its forward
//! scratch, autograd tape workspace, output buffers and (on the native
//! backend) a bind-time `ModelPlan` of resolved layout offsets — and then
//! *run* many times with zero steady-state allocation and zero string
//! formatting:
//!
//! ```ignore
//! let mut sess = rt.bind_kind("tiny", "loss")?;          // bind once
//! let outs = sess.run(&[Arg::VecF32(&params), ids, tgt, mask])?; // run many
//! ```
//!
//! [`Session::two_point`] is the first-class antithetic-pair entry point:
//! both SPSA evals of one step execute in a single call over one scratch
//! set, and on the native backend the pair is **materialization-free** —
//! `f(x ± λz)` streams through [`crate::vecmath::ParamView`]s with the
//! perturbation fused into the weight loads, so no perturbed parameter
//! buffer is ever written (bit-identical to the materialized path by
//! construction). [`Program::call`] remains as a thin compat shim
//! (`load`/`call` call sites work unchanged) that delegates to an
//! internally cached session.
//!
//! [`Runtime`] is the façade the rest of the crate talks to: it owns one
//! backend, resolves program names through the manifest, validates argument
//! shapes identically on every backend (turning silent size mismatches into
//! named errors), and caches bound compat programs. A [`ParallelPolicy`]
//! chosen by cli/config/env sizes the backend's ONE persistent
//! [`crate::parallel::WorkerPool`]; the `vecmath` GEMMs and the threaded
//! attention loops ((batch, head, query-block) tasks on the streaming
//! forward; whole (batch, head) pairs on `loss_pallas` and the autograd
//! backward) dispatch onto it, spawn no threads in steady state, and stay
//! bit-identical at every pool size.
//!
//! Backend selection: `Runtime::from_name("native"|"pjrt"|"auto")`, the
//! `CONMEZO_BACKEND` env var, or `Runtime::open_default()` (auto); thread
//! count via `ParallelPolicy` (`--threads`, `runtime.threads`, or the
//! `CONMEZO_THREADS` env var — 0 means all cores; explicit counts are
//! clamped to `std::thread::available_parallelism()`, identically at every
//! layer).

pub mod adapter;
pub mod autograd;
pub mod manifest;
pub mod model;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::util::error::{bail, Result};

pub use manifest::{LayoutEntry, Manifest, PresetMeta, ProgramSpec, TensorSpec};
pub use native::NativeBackend;

/// A runtime argument. Vector/matrix payloads are borrowed to keep the step
/// loop allocation-free on the caller side.
pub enum Arg<'a> {
    F32(f32),
    I32(i32),
    VecF32(&'a [f32]),
    /// int32 tensor with explicit dims (e.g. token batches [B, S]).
    TensorI32(&'a [i32], Vec<usize>),
    /// f32 tensor with explicit dims.
    TensorF32(&'a [f32], Vec<usize>),
}

impl Arg<'_> {
    pub fn shape_of(&self) -> Vec<usize> {
        match self {
            Arg::F32(_) | Arg::I32(_) => vec![],
            Arg::VecF32(v) => vec![v.len()],
            Arg::TensorI32(_, d) | Arg::TensorF32(_, d) => d.clone(),
        }
    }

    /// Shape check without materializing the shape (`validate_args` runs
    /// per call on the hot path; [`Arg::shape_of`] stays for error text).
    fn matches_shape(&self, shape: &[usize]) -> bool {
        match self {
            Arg::F32(_) | Arg::I32(_) => shape.is_empty(),
            Arg::VecF32(v) => shape.len() == 1 && shape[0] == v.len(),
            Arg::TensorI32(_, d) | Arg::TensorF32(_, d) => d == shape,
        }
    }
}

/// An owned program output (backend-agnostic replacement for the PJRT
/// literal). All exported programs return f32 payloads; I32 exists for
/// forward-compatibility with integer outputs.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Value {
    pub fn scalar(v: f32) -> Value {
        Value::F32(vec![v])
    }

    pub fn element_count(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
        }
    }
}

/// Extraction helpers for output values (same names as the old literal
/// helpers so call sites read identically across backends).
pub fn lit_f32(v: &Value) -> Result<f32> {
    match v {
        Value::F32(x) if !x.is_empty() => Ok(x[0]),
        Value::I32(x) if !x.is_empty() => Ok(x[0] as f32),
        _ => bail!("empty output value"),
    }
}

pub fn lit_vec_f32(v: &Value) -> Result<Vec<f32>> {
    match v {
        Value::F32(x) => Ok(x.clone()),
        Value::I32(_) => bail!("expected f32 output, got i32"),
    }
}

/// Copy a value's f32 payload into an existing buffer (hot path: avoids
/// the Vec allocation per step).
pub fn lit_copy_f32(v: &Value, dst: &mut [f32]) -> Result<()> {
    match v {
        Value::F32(x) => {
            if x.len() != dst.len() {
                bail!("output has {} elements, dst {}", x.len(), dst.len());
            }
            dst.copy_from_slice(x);
            Ok(())
        }
        Value::I32(_) => bail!("expected f32 output, got i32"),
    }
}

/// Worker-thread budget for the backend's dense kernels: sizes the ONE
/// persistent [`crate::parallel::WorkerPool`] a native backend creates,
/// onto which the `vecmath` GEMMs and the attention loops dispatch while
/// keeping per-element accumulation order — and therefore results —
/// bit-identical to the single-threaded kernels at every count.
///
/// Resolution is identical across every source (`--threads`,
/// `runtime.threads`, `CONMEZO_THREADS`): 0 means one worker per available
/// core, and explicit counts are clamped to
/// `std::thread::available_parallelism()` — oversubscribing cores only
/// ever slows the GEMMs down.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelPolicy {
    pub threads: usize,
}

impl ParallelPolicy {
    /// Single-threaded execution (the deterministic-by-construction default
    /// — threading is bit-identical anyway, this just avoids idle pool
    /// workers on small presets).
    pub fn single() -> ParallelPolicy {
        ParallelPolicy { threads: 1 }
    }

    /// One worker per available core.
    pub fn auto() -> ParallelPolicy {
        Self::from_count(0)
    }

    /// From an explicit count; 0 means "all cores", and any count is
    /// clamped to the machine's available parallelism.
    pub fn from_count(threads: usize) -> ParallelPolicy {
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let t = if threads == 0 { avail } else { threads.min(avail) };
        ParallelPolicy { threads: t.max(1) }
    }

    /// From the `CONMEZO_THREADS` env var (unset -> single; 0 -> all
    /// cores; clamped like every other source).
    pub fn from_env() -> ParallelPolicy {
        match std::env::var("CONMEZO_THREADS").ok().and_then(|s| s.trim().parse::<usize>().ok()) {
            Some(n) => Self::from_count(n),
            None => Self::single(),
        }
    }
}

impl Default for ParallelPolicy {
    fn default() -> Self {
        Self::single()
    }
}

/// Validate typed args against a program's manifest signature — identical
/// checking (and error text) on every backend; every [`Session::run`] goes
/// through this.
pub fn validate_args(spec: &ProgramSpec, args: &[Arg<'_>]) -> Result<()> {
    if args.len() != spec.inputs.len() {
        bail!(
            "{}: expected {} args ({:?}), got {}",
            spec.name,
            spec.inputs.len(),
            spec.inputs.iter().map(|i| i.name.as_str()).collect::<Vec<_>>(),
            args.len()
        );
    }
    for (a, ispec) in args.iter().zip(&spec.inputs) {
        if !a.matches_shape(&ispec.shape) {
            bail!(
                "{}: arg {:?} shape mismatch: got {:?}, manifest says {:?}",
                spec.name,
                ispec.name,
                a.shape_of(),
                ispec.shape
            );
        }
    }
    Ok(())
}

/// A bound program: owns whatever workspaces its backend needs (forward
/// scratch, autograd tape, output buffers) so repeated [`Session::run`]
/// calls execute without steady-state buffer allocation. Bind once via
/// [`Runtime::bind`] / [`Backend::bind`], run many times.
pub trait Session {
    /// The manifest spec this session is bound to.
    fn spec(&self) -> &ProgramSpec;

    /// Execute with typed args; returns output values in manifest order,
    /// borrowed from the session's reusable output buffers (valid until the
    /// next `run` / `two_point`).
    fn run(&mut self, args: &[Arg<'_>]) -> Result<&[Value]>;

    /// First-class antithetic-pair evaluation for `two_point`-kind
    /// programs: (f(x + lam z), f(x - lam z)) on one batch in a single
    /// call. Backends with native workspaces evaluate both points over one
    /// scratch set (shared setup, no output materialization); the default
    /// routes through [`Session::run`].
    fn two_point(
        &mut self,
        x: &[f32],
        z: &[f32],
        lam: f32,
        ids: &[i32],
        targets: &[i32],
        mask: &[f32],
    ) -> Result<(f64, f64)> {
        if self.spec().kind != "two_point" {
            bail!(
                "{}: the two_point entry point needs a two_point session, got kind {:?}",
                self.spec().name,
                self.spec().kind
            );
        }
        let dims = self
            .spec()
            .inputs
            .iter()
            .find(|i| i.name == "input_ids")
            .map(|i| i.shape.clone())
            .ok_or_else(|| crate::anyhow!("{}: two_point program without input_ids", self.spec().name))?;
        let outs = self.run(&[
            Arg::VecF32(x),
            Arg::VecF32(z),
            Arg::F32(lam),
            Arg::TensorI32(ids, dims.clone()),
            Arg::TensorI32(targets, dims.clone()),
            Arg::TensorF32(mask, dims),
        ])?;
        Ok((lit_f32(&outs[0])? as f64, lit_f32(&outs[1])? as f64))
    }
}

/// Backend-side per-call executable (the pre-session surface; still what
/// PJRT implements). [`CallSession`] adapts one into a [`Session`].
pub trait ProgramImpl {
    fn call(&self, spec: &ProgramSpec, args: &[Arg<'_>]) -> Result<Vec<Value>>;
}

/// Adapter wrapping a per-call [`ProgramImpl`] into the [`Session`] API for
/// backends without native workspace reuse (PJRT, the quad programs).
pub struct CallSession {
    spec: ProgramSpec,
    imp: Box<dyn ProgramImpl>,
    outs: Vec<Value>,
}

impl CallSession {
    pub fn new(spec: ProgramSpec, imp: Box<dyn ProgramImpl>) -> CallSession {
        CallSession { spec, imp, outs: Vec::new() }
    }
}

impl Session for CallSession {
    fn spec(&self) -> &ProgramSpec {
        &self.spec
    }

    fn run(&mut self, args: &[Arg<'_>]) -> Result<&[Value]> {
        validate_args(&self.spec, args)?;
        let outs = self.imp.call(&self.spec, args)?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: program returned {} outputs, manifest says {}",
                self.spec.name,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        self.outs = outs;
        Ok(&self.outs)
    }
}

/// An execution backend: resolves manifest programs into bound sessions.
pub trait Backend {
    /// Human-readable platform name ("native-cpu", PJRT platform, ...).
    fn platform(&self) -> String;
    /// The program/preset manifest this backend serves.
    fn manifest(&self) -> &Manifest;
    /// Bind one program into a reusable [`Session`] owning its workspaces.
    fn bind(&self, spec: &ProgramSpec) -> Result<Box<dyn Session>>;
    /// The backend's telemetry registry, shared by every session it binds
    /// (`None` for backends without instrumentation, e.g. PJRT).
    fn telemetry(&self) -> Option<&std::sync::Arc<crate::telemetry::Registry>> {
        None
    }
    /// Bind a per-tenant low-rank [`adapter::AdapterSession`] for `preset`
    /// at `rank`: the shared-base multi-tenant surface (`crate::serve`).
    /// Backends without adapter support keep the erroring default.
    fn bind_adapter(&self, preset: &str, rank: usize) -> Result<adapter::AdapterSession> {
        let _ = (preset, rank);
        bail!("backend {:?} has no adapter-session support", self.platform())
    }
}

/// Compat shim over the session API: the old `load`/`call` surface. Holds
/// one bound session behind a `RefCell`, so even legacy call sites reuse
/// workspaces across calls — `call` only pays an output `Vec<Value>` clone
/// that [`Session::run`] avoids.
pub struct Program {
    pub spec: ProgramSpec,
    sess: RefCell<Box<dyn Session>>,
}

impl Program {
    /// Execute with typed args; returns output values in manifest order.
    /// (Migration: prefer `Runtime::bind` + `Session::run` on hot paths.)
    pub fn call(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        Ok(self.sess.borrow_mut().run(args)?.to_vec())
    }
}

/// Enable FTZ + DAZ on this thread. ZO momentum buffers decay
/// geometrically (beta = 0.99), and denormal f32 arithmetic on x86 traps
/// to microcode at ~100x the cost — measured as a progressive 4-5x
/// slowdown over long ConMeZO runs before this was set (EXPERIMENTS.md
/// §Perf). Worker-pool threads call this themselves on startup
/// (`crate::parallel`), so pooled and caller-computed chunks always share
/// one float mode.
pub fn enable_flush_to_zero() {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        use std::arch::x86_64::{_mm_getcsr, _mm_setcsr};
        // bit 15 = FTZ, bit 6 = DAZ
        _mm_setcsr(_mm_getcsr() | (1 << 15) | (1 << 6));
    }
}

/// The runtime façade: one backend + a bound compat-program cache.
pub struct Runtime {
    backend: Box<dyn Backend>,
    cache: RefCell<HashMap<String, Rc<Program>>>,
}

impl Runtime {
    /// Wrap an explicit backend.
    pub fn from_backend(backend: Box<dyn Backend>) -> Runtime {
        enable_flush_to_zero();
        Runtime { backend, cache: RefCell::new(HashMap::new()) }
    }

    /// The pure-Rust native backend over the built-in presets. Always
    /// available; needs no artifacts on disk. Thread count comes from the
    /// `CONMEZO_THREADS` env var (see [`ParallelPolicy::from_env`]).
    pub fn native() -> Runtime {
        Runtime::native_with(ParallelPolicy::from_env())
    }

    /// The native backend with an explicit [`ParallelPolicy`].
    pub fn native_with(policy: ParallelPolicy) -> Runtime {
        Runtime::from_backend(Box::new(NativeBackend::with_policy(policy)))
    }

    /// Open a PJRT artifact directory (requires the `pjrt` cargo feature).
    #[cfg(feature = "pjrt")]
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        Ok(Runtime::from_backend(Box::new(pjrt::PjrtBackend::open(dir)?)))
    }

    /// Open a PJRT artifact directory (requires the `pjrt` cargo feature).
    #[cfg(not(feature = "pjrt"))]
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let _ = dir;
        bail!("this build has no PJRT support; rebuild with `--features pjrt` or use the native backend")
    }

    #[cfg(feature = "pjrt")]
    fn open_pjrt_default() -> Result<Runtime> {
        Ok(Runtime::from_backend(Box::new(pjrt::PjrtBackend::open_default()?)))
    }

    #[cfg(not(feature = "pjrt"))]
    fn open_pjrt_default() -> Result<Runtime> {
        bail!("backend \"pjrt\" requested but this build has no PJRT support; rebuild with `--features pjrt`")
    }

    /// Select a backend by name: "native", "pjrt", or "auto" (pjrt when the
    /// feature is compiled in AND artifacts exist, native otherwise).
    pub fn from_name(name: &str) -> Result<Runtime> {
        Self::from_name_with(name, ParallelPolicy::from_env())
    }

    /// [`Runtime::from_name`] with an explicit [`ParallelPolicy`] (the
    /// cli/config `--threads` / `runtime.threads` plumbing; PJRT manages its
    /// own intra-op threading, so the policy only shapes native backends).
    pub fn from_name_with(name: &str, policy: ParallelPolicy) -> Result<Runtime> {
        match name {
            "native" => Ok(Runtime::native_with(policy)),
            "pjrt" => Self::open_pjrt_default(),
            "auto" | "" => Runtime::open_default_with(policy),
            other => bail!("unknown backend {other:?} (expected native|pjrt|auto)"),
        }
    }

    /// Default backend selection: the `CONMEZO_BACKEND` env var when set
    /// ("native" or "pjrt"), otherwise PJRT if compiled in and artifacts are
    /// present, otherwise native.
    pub fn open_default() -> Result<Runtime> {
        Self::open_default_with(ParallelPolicy::from_env())
    }

    /// [`Runtime::open_default`] with an explicit [`ParallelPolicy`].
    pub fn open_default_with(policy: ParallelPolicy) -> Result<Runtime> {
        match std::env::var("CONMEZO_BACKEND").as_deref() {
            Ok("native") => return Ok(Runtime::native_with(policy)),
            Ok("pjrt") => return Self::open_pjrt_default(),
            Ok("auto") | Ok("") | Err(_) => {}
            Ok(other) => {
                bail!("CONMEZO_BACKEND={other:?} not recognized (expected native|pjrt|auto)")
            }
        }
        #[cfg(feature = "pjrt")]
        if let Ok(b) = pjrt::PjrtBackend::open_default() {
            return Ok(Runtime::from_backend(Box::new(b)));
        }
        Ok(Runtime::native_with(policy))
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    /// The backend's telemetry registry (one per `Runtime`; every bound
    /// session and the pool report into it). `None` on backends without
    /// instrumentation.
    pub fn telemetry(&self) -> Option<&std::sync::Arc<crate::telemetry::Registry>> {
        self.backend.telemetry()
    }

    /// Bind a program by manifest name into a fresh [`Session`] owning its
    /// own workspaces (the hot-path API; each caller gets an independent
    /// session).
    pub fn bind(&self, name: &str) -> Result<Box<dyn Session>> {
        let spec = self.backend.manifest().program(name)?.clone();
        let t0 = std::time::Instant::now();
        let sess = self.backend.bind(&spec)?;
        crate::debug!(
            "runtime",
            "bound {name} in {:.3}s",
            t0.elapsed().as_secs_f64()
        );
        Ok(sess)
    }

    /// Bind a preset-scoped program, e.g. ("tiny", "conmezo_step").
    pub fn bind_kind(&self, preset: &str, kind: &str) -> Result<Box<dyn Session>> {
        self.bind(&format!("{preset}_{kind}"))
    }

    /// Load (and bind, once) a compat [`Program`] by manifest name. Legacy
    /// surface: shares one cached session per name behind `call`'s output
    /// clone — migrate hot paths to [`Runtime::bind`] + [`Session::run`].
    pub fn load(&self, name: &str) -> Result<Rc<Program>> {
        if let Some(p) = self.cache.borrow().get(name) {
            return Ok(p.clone());
        }
        let spec = self.backend.manifest().program(name)?.clone();
        let sess = self.backend.bind(&spec)?;
        let prog = Rc::new(Program { spec, sess: RefCell::new(sess) });
        self.cache.borrow_mut().insert(name.to_string(), prog.clone());
        Ok(prog)
    }

    /// Load a preset-scoped program, e.g. ("tiny", "conmezo_step").
    pub fn load_kind(&self, preset: &str, kind: &str) -> Result<Rc<Program>> {
        self.load(&format!("{preset}_{kind}"))
    }

    pub fn preset(&self, name: &str) -> Result<&PresetMeta> {
        self.backend.manifest().preset(name)
    }

    /// Bind a per-tenant adapter session (shared base + O(rank·dims)
    /// tenant state) — the `serve` scheduler's per-preset surface.
    pub fn bind_adapter(&self, preset: &str, rank: usize) -> Result<adapter::AdapterSession> {
        self.backend.bind_adapter(preset, rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_always_opens() {
        let rt = Runtime::native();
        assert_eq!(rt.platform(), "native-cpu");
        assert!(rt.manifest().programs.len() >= 8);
        assert!(rt.preset("nano").is_ok());
    }

    #[test]
    fn from_name_selects() {
        assert!(Runtime::from_name("native").is_ok());
        assert!(Runtime::from_name("auto").is_ok());
        assert!(Runtime::from_name("bogus").is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(Runtime::from_name("pjrt").is_err());
    }

    #[test]
    fn value_helpers() {
        let v = Value::F32(vec![1.5, 2.5]);
        assert_eq!(lit_f32(&v).unwrap(), 1.5);
        assert_eq!(lit_vec_f32(&v).unwrap(), vec![1.5, 2.5]);
        let mut dst = [0f32; 2];
        lit_copy_f32(&v, &mut dst).unwrap();
        assert_eq!(dst, [1.5, 2.5]);
        let mut short = [0f32; 1];
        assert!(lit_copy_f32(&v, &mut short).is_err());
        assert!(lit_f32(&Value::F32(vec![])).is_err());
    }

    #[test]
    fn program_cache_returns_same_rc() {
        let rt = Runtime::native();
        let a = rt.load("nano_loss").unwrap();
        let b = rt.load("nano_loss").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn parallel_policy_resolution() {
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert_eq!(ParallelPolicy::default(), ParallelPolicy::single());
        assert_eq!(ParallelPolicy::from_count(1).threads, 1);
        assert_eq!(ParallelPolicy::from_count(3).threads, 3.min(avail));
        assert_eq!(ParallelPolicy::from_count(0).threads, avail, "0 means all cores");
        assert_eq!(ParallelPolicy::auto().threads, avail);
        // explicit counts clamp to the machine instead of oversubscribing
        assert_eq!(ParallelPolicy::from_count(1_000_000).threads, avail);
    }

    #[test]
    fn bind_gives_independent_deterministic_sessions() {
        let rt = Runtime::native();
        let mut a = rt.bind("nano_sample_u").unwrap();
        let mut b = rt.bind_kind("nano", "sample_u").unwrap();
        assert_eq!(a.spec().name, "nano_sample_u");
        let va = a.run(&[Arg::I32(1)]).unwrap()[0].clone();
        let vb = b.run(&[Arg::I32(1)]).unwrap()[0].clone();
        assert_eq!(va, vb, "independent sessions must agree");
    }
}
