//! Native reverse-mode autodiff over the transformer forward of
//! [`crate::runtime::model::NativeModel`].
//!
//! The forward pass IS `NativeModel::forward_into` with tape recording
//! switched on — one implementation, optional recording — so the returned
//! loss is bit-identical to `NativeModel::loss` by construction (the old
//! op-for-op replica and its pinning test are gone). The backward pass
//! walks the recorded [`Tape`] in reverse through the backward kernels
//! (`matmul_at`/`matmul_bt` grad pair, `softmax_rows_backward`,
//! `layernorm_rows_backward`, `gelu_backward`, `add_bias_rows_backward`)
//! and the masked-cross-entropy gradient, producing dloss/dparams on the
//! padded flat buffer (pad lanes structurally zero).
//!
//! All buffers the reverse pass touches live in a [`GradWorkspace`] that
//! sessions allocate once at bind time and reuse every step (the pretrain
//! allocation-traffic item from ROADMAP). Gradients are pinned two ways:
//! central-difference gradchecks in this module and the vecmath kernel
//! tests, and the jax golden fixture `rust/tests/fixtures/fo_parity.json`
//! (regenerate with `python -m compile.gen_fixtures`).

use crate::runtime::manifest::PresetMeta;
use crate::runtime::model::{masked_mean_xent, FwdScratch, NativeModel, Tape};
use crate::vecmath;

/// Loss plus its gradient over the padded flat parameter buffer.
pub struct LossGrad {
    pub loss: f32,
    /// dloss/dparams, length `d_pad`, pad lanes zero.
    pub grad: Vec<f32>,
}

/// Reusable reverse-pass workspace: the activation tape plus every
/// gradient buffer, allocated once per session.
pub struct GradWorkspace {
    tape: Tape,
    /// dloss/dparams, length `d_pad` — the reverse pass leaves its result
    /// here; pad lanes zero.
    pub grad: Vec<f32>,
    dlogits: Vec<f32>,
    dx: Vec<f32>,
    dx_ln: Vec<f32>,
    dff: Vec<f32>,
    dffpre: Vec<f32>,
    dh: Vec<f32>,
    dqkv: Vec<f32>,
    dg: Vec<f32>,
    db: Vec<f32>,
    dw_seg: Vec<f32>,
    dscore: Vec<f32>,
}

impl GradWorkspace {
    pub fn new(meta: &PresetMeta) -> GradWorkspace {
        let (b, s, d, ff, v) = (meta.batch, meta.seq_len, meta.d_model, meta.d_ff, meta.vocab);
        let r = b * s;
        GradWorkspace {
            tape: Tape::new(meta),
            grad: vec![0.0; meta.d_pad],
            dlogits: vec![0.0; r * v],
            dx: vec![0.0; r * d],
            dx_ln: vec![0.0; r * d],
            dff: vec![0.0; r * ff],
            dffpre: vec![0.0; r * ff],
            dh: vec![0.0; r * d],
            dqkv: vec![0.0; r * 3 * d],
            dg: vec![0.0; d],
            db: vec![0.0; d],
            dw_seg: vec![0.0; s],
            dscore: vec![0.0; s],
        }
    }
}

/// (offset, element count) of a layout tensor.
fn entry(model: &NativeModel, name: &str) -> (usize, usize) {
    let ent = model
        .meta
        .layout
        .iter()
        .find(|e| e.name == name)
        .unwrap_or_else(|| panic!("layout has no tensor {name:?}"));
    (ent.offset, ent.shape.iter().product())
}

/// View of one layout tensor inside a flat buffer.
fn param_slice<'a>(model: &NativeModel, params: &'a [f32], name: &str) -> &'a [f32] {
    let (off, n) = entry(model, name);
    &params[off..off + n]
}

/// dloss/dlogits of the masked mean cross-entropy:
/// dlogits[i, c] = (w_i / msum) * (softmax_c - 1[c == target_i]),
/// zero on unmasked rows. Probabilities use the same f64 max-subtracted
/// logsumexp as the loss.
fn softmax_xent_backward(
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    v: usize,
    dlogits: &mut [f32],
) {
    let msum: f64 = mask.iter().map(|&w| w as f64).sum::<f64>().max(1.0);
    for dl in dlogits.iter_mut() {
        *dl = 0.0;
    }
    for i in 0..rows {
        let w = mask[i] as f64;
        if w == 0.0 {
            continue;
        }
        let row = &logits[i * v..(i + 1) * v];
        let mut maxv = f32::NEG_INFINITY;
        for &x in row {
            if x > maxv {
                maxv = x;
            }
        }
        let mut denom = 0f64;
        for &x in row {
            denom += ((x - maxv) as f64).exp();
        }
        let inv = 1.0 / denom;
        let coef = w / msum;
        let drow = &mut dlogits[i * v..(i + 1) * v];
        for (c, dv) in drow.iter_mut().enumerate() {
            let p = ((row[c] - maxv) as f64).exp() * inv;
            *dv = (coef * p) as f32;
        }
        drow[targets[i] as usize] -= coef as f32;
    }
}

/// Loss and dloss/dparams on one batch: taped forward + reverse pass, all
/// allocation-free over the caller's scratch/workspace (the session hot
/// path). The gradient is left in `ws.grad` (pad lanes zero); ids/targets:
/// [b, s] row-major; mask: [b, s].
#[allow(clippy::too_many_arguments)]
pub fn loss_and_grad_ws(
    model: &NativeModel,
    params: &[f32],
    ids: &[i32],
    targets: &[i32],
    mask: &[f32],
    b: usize,
    s: usize,
    fwd: &mut FwdScratch,
    ws: &mut GradWorkspace,
) -> f32 {
    let m = &model.meta;
    let (v, d, h, ff) = (m.vocab, m.d_model, m.n_heads, m.d_ff);
    let hd = d / h;
    let r = b * s;
    let threads = model.threads;

    model.forward_into(params, ids, b, s, fwd, Some(&mut ws.tape));
    let logits = &fwd.logits[..r * v];
    let loss = masked_mean_xent(logits, targets, mask, r, v);
    let tape = &ws.tape;

    let grad = &mut ws.grad;
    grad.fill(0.0);

    // --- cross-entropy + tied LM head ---
    let dlogits = &mut ws.dlogits[..r * v];
    softmax_xent_backward(logits, targets, mask, r, v, dlogits);
    let mut dx: &mut [f32] = &mut ws.dx[..r * d];
    let mut dx_ln: &mut [f32] = &mut ws.dx_ln[..r * d];
    vecmath::matmul_threaded(dlogits, param_slice(model, params, "tok_emb"), r, v, d, dx, threads); // dhf
    {
        let (off, n) = entry(model, "tok_emb");
        vecmath::matmul_at_threaded(dlogits, &tape.hf, r, v, d, &mut grad[off..off + n], threads);
    }

    // --- final LayerNorm ---
    let dg = &mut ws.dg;
    let db = &mut ws.db;
    vecmath::layernorm_rows_backward(
        &tape.xf,
        param_slice(model, params, "ln_f.g"),
        r,
        d,
        1e-5,
        dx,
        dx_ln,
        dg,
        db,
    );
    write_grad(model, grad, "ln_f.g", dg);
    write_grad(model, grad, "ln_f.b", db);
    std::mem::swap(&mut dx, &mut dx_ln); // dx is now d(loss)/d(xf)

    // --- layers in reverse ---
    let dff = &mut ws.dff[..r * ff];
    let dffpre = &mut ws.dffpre[..r * ff];
    let dh = &mut ws.dh[..r * d];
    let dqkv = &mut ws.dqkv[..r * 3 * d];
    let dw_seg = &mut ws.dw_seg;
    let dscore = &mut ws.dscore;
    let scale = 1.0 / (hd as f32).sqrt();

    for l in (0..m.n_layers).rev() {
        let name = |suffix: &str| format!("layer{l}.{suffix}");
        let lt = &tape.layers[l];

        // --- MLP block backward: x_out = x_mid + gelu(ln2(x_mid) @ w1 + b1) @ w2 + b2 ---
        {
            let (off, n) = entry(model, &name("mlp.b2"));
            vecmath::add_bias_rows_backward(dx, r, d, &mut grad[off..off + n]);
        }
        vecmath::matmul_bt_threaded(dx, param_slice(model, params, &name("mlp.w2")), r, d, ff, dff, threads);
        {
            let (off, n) = entry(model, &name("mlp.w2"));
            vecmath::matmul_at_threaded(&lt.ffact, dx, r, ff, d, &mut grad[off..off + n], threads);
        }
        vecmath::gelu_backward(&lt.ffpre, dff, dffpre);
        {
            let (off, n) = entry(model, &name("mlp.b1"));
            vecmath::add_bias_rows_backward(dffpre, r, ff, &mut grad[off..off + n]);
        }
        vecmath::matmul_bt_threaded(dffpre, param_slice(model, params, &name("mlp.w1")), r, ff, d, dh, threads);
        {
            let (off, n) = entry(model, &name("mlp.w1"));
            vecmath::matmul_at_threaded(&lt.h2, dffpre, r, d, ff, &mut grad[off..off + n], threads);
        }
        vecmath::layernorm_rows_backward(
            &lt.x_mid,
            param_slice(model, params, &name("ln2.g")),
            r,
            d,
            1e-5,
            dh,
            dx_ln,
            dg,
            db,
        );
        write_grad(model, grad, &name("ln2.g"), dg);
        write_grad(model, grad, &name("ln2.b"), db);
        vecmath::axpy(1.0, dx_ln, dx); // residual: d(x_mid) = d(x_out) + LN path

        // --- attention block backward: x_mid = x_in + attn(ln1(x_in)) @ wo + bo ---
        {
            let (off, n) = entry(model, &name("attn.bo"));
            vecmath::add_bias_rows_backward(dx, r, d, &mut grad[off..off + n]);
        }
        vecmath::matmul_bt_threaded(dx, param_slice(model, params, &name("attn.wo")), r, d, d, dh, threads); // dattn
        {
            let (off, n) = entry(model, &name("attn.wo"));
            vecmath::matmul_at_threaded(&lt.attn, dx, r, d, d, &mut grad[off..off + n], threads);
        }
        // attention core: per (batch, head, query) softmax-attention backward
        for dv in dqkv.iter_mut() {
            *dv = 0.0;
        }
        for i in 0..b {
            for head in 0..h {
                let qoff = head * hd;
                let koff = d + head * hd;
                let voff = 2 * d + head * hd;
                for t in 0..s {
                    let dorow = &dh[(i * s + t) * d + head * hd..][..hd];
                    let prow = &lt.probs[((i * h + head) * s + t) * s..][..t + 1];
                    // dv[t2] += w[t2] * dout ; dw[t2] = <dout, v[t2]>
                    for t2 in 0..=t {
                        let vrow = &lt.qkv[(i * s + t2) * 3 * d + voff..][..hd];
                        dw_seg[t2] = vecmath::dot(dorow, vrow) as f32;
                        let w = prow[t2];
                        let dvrow = &mut dqkv[(i * s + t2) * 3 * d + voff..][..hd];
                        for (dvj, &doj) in dvrow.iter_mut().zip(dorow) {
                            *dvj += w * doj;
                        }
                    }
                    // softmax backward on the causal row segment
                    vecmath::softmax_rows_backward(
                        prow,
                        &dw_seg[..t + 1],
                        1,
                        t + 1,
                        &mut dscore[..t + 1],
                    );
                    // dq[t] += scale * sum_t2 dscore[t2] k[t2] ; dk[t2] += scale * dscore[t2] q[t]
                    let qrow_off = (i * s + t) * 3 * d + qoff;
                    for t2 in 0..=t {
                        let ds = dscore[t2] * scale;
                        let krow = (i * s + t2) * 3 * d + koff;
                        for j in 0..hd {
                            dqkv[qrow_off + j] += ds * lt.qkv[krow + j];
                            dqkv[krow + j] += ds * lt.qkv[qrow_off + j];
                        }
                    }
                }
            }
        }
        {
            let (off, n) = entry(model, &name("attn.bqkv"));
            vecmath::add_bias_rows_backward(dqkv, r, 3 * d, &mut grad[off..off + n]);
        }
        vecmath::matmul_bt_threaded(dqkv, param_slice(model, params, &name("attn.wqkv")), r, 3 * d, d, dh, threads); // dh1
        {
            let (off, n) = entry(model, &name("attn.wqkv"));
            vecmath::matmul_at_threaded(&lt.h1, dqkv, r, d, 3 * d, &mut grad[off..off + n], threads);
        }
        vecmath::layernorm_rows_backward(
            &lt.x_in,
            param_slice(model, params, &name("ln1.g")),
            r,
            d,
            1e-5,
            dh,
            dx_ln,
            dg,
            db,
        );
        write_grad(model, grad, &name("ln1.g"), dg);
        write_grad(model, grad, &name("ln1.b"), db);
        vecmath::axpy(1.0, dx_ln, dx); // d(x_in) = d(x_mid) + LN path
    }

    // --- embeddings: x0[i*s+t] = tok_emb[ids[i,t]] + pos_emb[t] ---
    {
        let (toff, _) = entry(model, "tok_emb");
        let (poff, _) = entry(model, "pos_emb");
        for i in 0..b {
            for t in 0..s {
                let id = ids[i * s + t] as usize;
                let dxrow = &dx[(i * s + t) * d..(i * s + t + 1) * d];
                for j in 0..d {
                    grad[toff + id * d + j] += dxrow[j];
                    grad[poff + t * d + j] += dxrow[j];
                }
            }
        }
    }

    loss
}

/// Allocating wrapper over [`loss_and_grad_ws`] (tests / one-shot callers).
pub fn loss_and_grad(
    model: &NativeModel,
    params: &[f32],
    ids: &[i32],
    targets: &[i32],
    mask: &[f32],
    b: usize,
    s: usize,
) -> LossGrad {
    let mut fwd = FwdScratch::new(&model.meta);
    let mut ws = GradWorkspace::new(&model.meta);
    let loss = loss_and_grad_ws(model, params, ids, targets, mask, b, s, &mut fwd, &mut ws);
    LossGrad { loss, grad: ws.grad }
}

/// Copy a tensor gradient into its slot of the flat gradient buffer.
fn write_grad(model: &NativeModel, grad: &mut [f32], name: &str, src: &[f32]) {
    let (off, n) = entry(model, name);
    debug_assert_eq!(src.len(), n);
    grad[off..off + n].copy_from_slice(src);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::model::build_preset;
    use crate::testing::{property, UsizeRange};
    use crate::util::rng::Xoshiro256pp;
    use crate::vecmath::{dot, nrm2};

    /// Small custom geometry so gradchecks stay fast.
    fn tiny_model() -> NativeModel {
        NativeModel::new(build_preset("grad-test", 16, 8, 2, 2, 6, 2))
    }

    fn test_batch(model: &NativeModel, seed: u64) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let m = &model.meta;
        let (b, s, v) = (m.batch, m.seq_len, m.vocab);
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let ids: Vec<i32> = (0..b * s).map(|_| rng.gen_range(v) as i32).collect();
        let tgt: Vec<i32> = (0..b * s).map(|_| rng.gen_range(v) as i32).collect();
        let mut mask = vec![0f32; b * s];
        for i in 0..b {
            // two masked positions per example
            mask[i * s + rng.gen_range(s)] = 1.0;
            mask[i * s + rng.gen_range(s)] = 1.0;
        }
        (ids, tgt, mask)
    }

    #[test]
    fn taped_loss_equals_model_loss() {
        // the taped forward IS the model forward (one implementation with
        // optional recording), so equality is structural — this guards the
        // workspace plumbing, not a replica
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let params = model.init_flat(3);
        let (ids, tgt, mask) = test_batch(&model, 5);
        let lg = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        let want = model.loss(&params, &ids, &tgt, &mask, b, s);
        assert_eq!(lg.loss, want);
    }

    #[test]
    fn grad_workspace_reuse_is_bit_identical() {
        // repeated loss_and_grad_ws over ONE workspace must reproduce the
        // fresh-allocation result exactly (no stale gradient accumulation)
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let params = model.init_flat(21);
        let (ids, tgt, mask) = test_batch(&model, 31);
        let fresh = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        let mut fwd = FwdScratch::new(&model.meta);
        let mut ws = GradWorkspace::new(&model.meta);
        for _ in 0..3 {
            let loss = loss_and_grad_ws(&model, &params, &ids, &tgt, &mask, b, s, &mut fwd, &mut ws);
            assert_eq!(loss, fresh.loss);
            assert_eq!(ws.grad, fresh.grad);
        }
    }

    #[test]
    fn grad_is_zero_on_pad_lanes_and_finite() {
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let params = model.init_flat(7);
        let (ids, tgt, mask) = test_batch(&model, 11);
        let lg = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        assert_eq!(lg.grad.len(), model.meta.d_pad);
        assert!(lg.grad[model.meta.d_raw..].iter().all(|&g| g == 0.0));
        assert!(lg.grad.iter().all(|g| g.is_finite()));
        assert!(nrm2(&lg.grad) > 0.0, "gradient must be nonzero on a random batch");
    }

    #[test]
    fn prop_end_to_end_gradient_matches_central_differences() {
        // directional central-difference gradcheck of the full transformer
        // loss: |(f(x+eps v) - f(x-eps v))/(2 eps) - <grad, v>| / |<grad, v>|
        // <= 1e-2 (eps = 1e-2, calibrated against the numpy mirror where the
        // worst case measured 8.5e-4)
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let d_raw = model.meta.d_raw;
        let g = UsizeRange(1, 10_000);
        property("e2e-gradcheck", &g, 6, |&case| {
            let params = model.init_flat(case as i32);
            let (ids, tgt, mask) = test_batch(&model, case as u64 ^ 0xABCD);
            let lg = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
            let mut rng = Xoshiro256pp::seed_from_u64(case as u64);
            let mut v = vec![0f32; params.len()];
            rng.fill_normal_f32(&mut v[..d_raw]);
            let n = nrm2(&v) as f32;
            for vi in v.iter_mut() {
                *vi /= n;
            }
            let eps = 1e-2f32;
            let mut xp = params.clone();
            vecmath::axpy(eps, &v, &mut xp);
            let mut xm = params.clone();
            vecmath::axpy(-eps, &v, &mut xm);
            let fp = model.loss(&xp, &ids, &tgt, &mask, b, s) as f64;
            let fm = model.loss(&xm, &ids, &tgt, &mask, b, s) as f64;
            let fd = (fp - fm) / (2.0 * eps as f64);
            let an = dot(&lg.grad, &v);
            (fd - an).abs() / an.abs().max(1e-6) < 1e-2
        });
    }

    #[test]
    fn per_coordinate_gradcheck_on_embedding_and_head_rows() {
        // spot-check individual coordinates across tensor kinds (embedding,
        // attention weight, MLP weight, final LN gain) with per-coordinate
        // central differences; f32 loss noise bounds accuracy to ~5e-2 at
        // the 1e-3 gradient floor (numpy-mirror calibrated), so assert 1e-1
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let params = model.init_flat(13);
        let (ids, tgt, mask) = test_batch(&model, 17);
        let lg = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        let probe: Vec<usize> = vec![
            entry(&model, "tok_emb").0 + 3,
            entry(&model, "layer0.attn.wqkv").0 + 5,
            entry(&model, "layer1.mlp.w1").0 + 7,
            entry(&model, "ln_f.g").0 + 1,
        ];
        let eps = 3e-3f32;
        for i in probe {
            let mut xp = params.clone();
            xp[i] += eps;
            let mut xm = params.clone();
            xm[i] -= eps;
            let fd = (model.loss(&xp, &ids, &tgt, &mask, b, s) as f64
                - model.loss(&xm, &ids, &tgt, &mask, b, s) as f64)
                / (2.0 * eps as f64);
            let an = lg.grad[i] as f64;
            let rel = (fd - an).abs() / an.abs().max(1e-3);
            assert!(rel < 1e-1, "coord {i}: analytic {an} vs fd {fd} (rel {rel:.2e})");
        }
    }

    #[test]
    fn gradient_descends_the_loss() {
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let params = model.init_flat(19);
        let (ids, tgt, mask) = test_batch(&model, 23);
        let lg = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        let gn2 = dot(&lg.grad, &lg.grad);
        let eta = (0.05 / gn2.sqrt()) as f32; // small step along -grad
        let mut xs = params.clone();
        vecmath::axpy(-eta, &lg.grad, &mut xs);
        let after = model.loss(&xs, &ids, &tgt, &mask, b, s);
        assert!(
            (after as f64) < lg.loss as f64,
            "step along -grad must reduce the loss: {} -> {after}",
            lg.loss
        );
    }

    #[test]
    fn unmasked_targets_get_zero_logit_gradient_rows() {
        let model = tiny_model();
        let m = &model.meta;
        let (b, s, v) = (m.batch, m.seq_len, m.vocab);
        let params = model.init_flat(29);
        let ids: Vec<i32> = (0..b * s).map(|i| (i % v) as i32).collect();
        let tgt: Vec<i32> = vec![1; b * s];
        let mut mask = vec![0f32; b * s];
        mask[2] = 1.0;
        // gradient wrt a target only used at an unmasked position is driven
        // purely by the forward path, not the label: flipping that target
        // must not change the gradient
        let g1 = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        let mut tgt2 = tgt.clone();
        tgt2[7] = 9; // unmasked position
        let g2 = loss_and_grad(&model, &params, &ids, &tgt2, &mask, b, s);
        assert_eq!(g1.grad, g2.grad);
        assert_eq!(g1.loss, g2.loss);
    }
}
