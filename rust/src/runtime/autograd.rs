//! Native reverse-mode autodiff over the transformer forward of
//! [`crate::runtime::model::NativeModel`].
//!
//! The forward pass IS `NativeModel::forward_into` with tape recording
//! switched on — one implementation, optional recording — so the returned
//! loss is bit-identical to `NativeModel::loss` by construction (the old
//! op-for-op replica and its pinning test are gone). That shared forward
//! also means the taped pass consumes the bind-time packed weight panels
//! (and the SIMD kernels) for free: `forward_into` repacks values and
//! dispatches the packed GEMMs exactly like the eval path, while the
//! backward GEMMs below read the flat buffer directly (their A^T/B^T
//! operand shapes don't reuse the forward's B-side panels). The backward
//! pass
//! walks the recorded [`Tape`] in reverse through the backward kernels
//! (`matmul_at`/`matmul_bt` grad pair, `softmax_rows_backward`,
//! `layernorm_rows_backward`, `gelu_backward`, `add_bias_rows_backward`)
//! and the masked-cross-entropy gradient, producing dloss/dparams on the
//! padded flat buffer (pad lanes structurally zero).
//!
//! All buffers the reverse pass touches live in a [`GradWorkspace`] that
//! sessions allocate once at bind time and reuse every step — including
//! the f64 column accumulators `layernorm_rows_backward_ws` fills, which
//! the kernel used to heap-allocate per call — so the first-order step
//! path is allocation-free in steady state. Layout offsets come from the
//! model's bind-time `ModelPlan` (no per-call `format!` lookups), the
//! backward GEMMs and the per-(batch, head) attention backward dispatch
//! onto the model's persistent `WorkerPool`, and results are bit-identical
//! at every pool size (each gradient element is produced by exactly one
//! task in the sequential accumulation order). Gradients are pinned two
//! ways: central-difference gradchecks in this module and the vecmath
//! kernel tests, and the jax golden fixture
//! `rust/tests/fixtures/fo_parity.json` (regenerate with
//! `python -m compile.gen_fixtures`).

use crate::parallel::SendPtr;
use crate::runtime::manifest::PresetMeta;
use crate::runtime::model::{masked_mean_xent, FwdScratch, NativeModel, Span, Tape};
use crate::vecmath;

/// Loss plus its gradient over the padded flat parameter buffer.
pub struct LossGrad {
    pub loss: f32,
    /// dloss/dparams, length `d_pad`, pad lanes zero.
    pub grad: Vec<f32>,
}

/// Reusable reverse-pass workspace: the activation tape plus every
/// gradient buffer, allocated once per session. The per-(batch, head)
/// attention-backward scratch (`dw_seg`/`dscore`) carries `slots`
/// independent copies — one per worker-pool participant.
pub struct GradWorkspace {
    tape: Tape,
    /// attention-backward scratch slots this workspace was sized for
    slots: usize,
    /// dloss/dparams, length `d_pad` — the reverse pass leaves its result
    /// here; pad lanes zero.
    pub grad: Vec<f32>,
    dlogits: Vec<f32>,
    dx: Vec<f32>,
    dx_ln: Vec<f32>,
    dff: Vec<f32>,
    dffpre: Vec<f32>,
    dh: Vec<f32>,
    dqkv: Vec<f32>,
    dg: Vec<f32>,
    db: Vec<f32>,
    /// f64 column accumulators for `layernorm_rows_backward_ws` — bound
    /// here so the reverse pass allocates nothing per call (the kernel
    /// used to heap-allocate these two buffers every LayerNorm backward)
    dg64: Vec<f64>,
    db64: Vec<f64>,
    dw_seg: Vec<f32>,
    dscore: Vec<f32>,
}

impl GradWorkspace {
    /// Single-slot workspace (sequential attention backward); sessions
    /// size slots from the model's pool via [`GradWorkspace::for_model`].
    pub fn new(meta: &PresetMeta) -> GradWorkspace {
        Self::with_slots(meta, 1)
    }

    /// Workspace sized for `model`'s worker pool.
    pub fn for_model(model: &NativeModel) -> GradWorkspace {
        Self::with_slots(&model.meta, model.pool().threads())
    }

    /// Workspace with `slots` independent attention-backward scratch
    /// copies (one per worker-pool participant).
    pub fn with_slots(meta: &PresetMeta, slots: usize) -> GradWorkspace {
        let (b, s, d, ff, v) = (meta.batch, meta.seq_len, meta.d_model, meta.d_ff, meta.vocab);
        let r = b * s;
        let p = slots.max(1);
        GradWorkspace {
            tape: Tape::new(meta),
            slots: p,
            grad: vec![0.0; meta.d_pad],
            dlogits: vec![0.0; r * v],
            dx: vec![0.0; r * d],
            dx_ln: vec![0.0; r * d],
            dff: vec![0.0; r * ff],
            dffpre: vec![0.0; r * ff],
            dh: vec![0.0; r * d],
            dqkv: vec![0.0; r * 3 * d],
            dg: vec![0.0; d],
            db: vec![0.0; d],
            dg64: vec![0.0; d],
            db64: vec![0.0; d],
            dw_seg: vec![0.0; p * s],
            dscore: vec![0.0; p * s],
        }
    }
}

/// dloss/dlogits of the masked mean cross-entropy:
/// dlogits[i, c] = (w_i / msum) * (softmax_c - 1[c == target_i]),
/// zero on unmasked rows. Probabilities use the same f64 max-subtracted
/// logsumexp as the loss.
fn softmax_xent_backward(
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    v: usize,
    dlogits: &mut [f32],
) {
    let msum: f64 = mask.iter().map(|&w| w as f64).sum::<f64>().max(1.0);
    for dl in dlogits.iter_mut() {
        *dl = 0.0;
    }
    for i in 0..rows {
        let w = mask[i] as f64;
        if w == 0.0 {
            continue;
        }
        let row = &logits[i * v..(i + 1) * v];
        let mut maxv = f32::NEG_INFINITY;
        for &x in row {
            if x > maxv {
                maxv = x;
            }
        }
        let mut denom = 0f64;
        for &x in row {
            denom += ((x - maxv) as f64).exp();
        }
        let inv = 1.0 / denom;
        let coef = w / msum;
        let drow = &mut dlogits[i * v..(i + 1) * v];
        for (c, dv) in drow.iter_mut().enumerate() {
            let p = ((row[c] - maxv) as f64).exp() * inv;
            *dv = (coef * p) as f32;
        }
        drow[targets[i] as usize] -= coef as f32;
    }
}

/// Loss and dloss/dparams on one batch: taped forward + reverse pass, all
/// allocation-free over the caller's scratch/workspace (the session hot
/// path). The gradient is left in `ws.grad` (pad lanes zero); ids/targets:
/// [b, s] row-major; mask: [b, s].
#[allow(clippy::too_many_arguments)]
pub fn loss_and_grad_ws(
    model: &NativeModel,
    params: &[f32],
    ids: &[i32],
    targets: &[i32],
    mask: &[f32],
    b: usize,
    s: usize,
    fwd: &mut FwdScratch,
    ws: &mut GradWorkspace,
) -> f32 {
    let m = &model.meta;
    let plan = &model.plan;
    let (v, d, h, ff) = (m.vocab, m.d_model, m.n_heads, m.d_ff);
    let hd = d / h;
    let r = b * s;
    let pool = model.pool();
    // attention-backward dispatch width: whole (batch, head) pairs (dk/dv
    // accumulate across the causal query loop, so a query split here would
    // need per-participant accumulators + a deterministic reduction; see
    // ROADMAP), gated like the GEMMs and capped by this workspace's
    // scratch slots
    let att_parts = vecmath::effective_threads(pool.threads().min(ws.slots), b * h, s * s * hd);

    model.forward_into(params, ids, b, s, fwd, Some(&mut ws.tape));
    let logits = &fwd.logits[..r * v];
    let loss = masked_mean_xent(logits, targets, mask, r, v);
    let tape = &ws.tape;

    let grad = &mut ws.grad;
    grad.fill(0.0);

    // --- cross-entropy + tied LM head ---
    let dlogits = &mut ws.dlogits[..r * v];
    softmax_xent_backward(logits, targets, mask, r, v, dlogits);
    let mut dx: &mut [f32] = &mut ws.dx[..r * d];
    let mut dx_ln: &mut [f32] = &mut ws.dx_ln[..r * d];
    vecmath::matmul_threaded(dlogits, plan.tok_emb.of(params), r, v, d, dx, pool); // dhf
    vecmath::matmul_at_threaded(dlogits, &tape.hf, r, v, d, plan.tok_emb.of_mut(grad), pool);

    // --- final LayerNorm ---
    let dg = &mut ws.dg;
    let db = &mut ws.db;
    let dg64 = &mut ws.dg64;
    let db64 = &mut ws.db64;
    vecmath::layernorm_rows_backward_ws(
        &tape.xf,
        plan.ln_f_g.of(params),
        r,
        d,
        1e-5,
        dx,
        dx_ln,
        dg,
        db,
        dg64,
        db64,
    );
    write_grad(grad, plan.ln_f_g, dg);
    write_grad(grad, plan.ln_f_b, db);
    std::mem::swap(&mut dx, &mut dx_ln); // dx is now d(loss)/d(xf)

    // --- layers in reverse ---
    let dff = &mut ws.dff[..r * ff];
    let dffpre = &mut ws.dffpre[..r * ff];
    let dh = &mut ws.dh[..r * d];
    let dqkv = &mut ws.dqkv[..r * 3 * d];
    let dw_seg = &mut ws.dw_seg;
    let dscore = &mut ws.dscore;
    let scale = 1.0 / (hd as f32).sqrt();

    for l in (0..m.n_layers).rev() {
        let lp = &plan.layers[l];
        let lt = &tape.layers[l];

        // --- MLP block backward: x_out = x_mid + gelu(ln2(x_mid) @ w1 + b1) @ w2 + b2 ---
        vecmath::add_bias_rows_backward(dx, r, d, lp.b2.of_mut(grad));
        vecmath::matmul_bt_threaded(dx, lp.w2.of(params), r, d, ff, dff, pool);
        vecmath::matmul_at_threaded(&lt.ffact, dx, r, ff, d, lp.w2.of_mut(grad), pool);
        vecmath::gelu_backward(&lt.ffpre, dff, dffpre);
        vecmath::add_bias_rows_backward(dffpre, r, ff, lp.b1.of_mut(grad));
        vecmath::matmul_bt_threaded(dffpre, lp.w1.of(params), r, ff, d, dh, pool);
        vecmath::matmul_at_threaded(&lt.h2, dffpre, r, d, ff, lp.w1.of_mut(grad), pool);
        vecmath::layernorm_rows_backward_ws(
            &lt.x_mid,
            lp.ln2_g.of(params),
            r,
            d,
            1e-5,
            dh,
            dx_ln,
            dg,
            db,
            dg64,
            db64,
        );
        write_grad(grad, lp.ln2_g, dg);
        write_grad(grad, lp.ln2_b, db);
        vecmath::axpy(1.0, dx_ln, dx); // residual: d(x_mid) = d(x_out) + LN path

        // --- attention block backward: x_mid = x_in + attn(ln1(x_in)) @ wo + bo ---
        vecmath::add_bias_rows_backward(dx, r, d, lp.bo.of_mut(grad));
        vecmath::matmul_bt_threaded(dx, lp.wo.of(params), r, d, d, dh, pool); // dattn
        vecmath::matmul_at_threaded(&lt.attn, dx, r, d, d, lp.wo.of_mut(grad), pool);
        // attention core: per (batch, head, query) softmax-attention
        // backward, one (batch, head) pair per pool task — every task
        // writes a disjoint (batch-row, head-column) region of dqkv with
        // the sequential loop's accumulation order, so pooled gradients
        // are bit-identical at every pool size
        for dv in dqkv.iter_mut() {
            *dv = 0.0;
        }
        {
            let dh_ro: &[f32] = dh;
            let dqkv_ptr = SendPtr(dqkv.as_mut_ptr());
            let dw_ptr = SendPtr(dw_seg.as_mut_ptr());
            let dsc_ptr = SendPtr(dscore.as_mut_ptr());
            let _att_t = pool.telemetry().and_then(|r| r.timer(&r.attention));
            pool.run(att_parts, b * h, &|task| {
                let i = task / h;
                let head = task % h;
                let slot = task % att_parts;
                let dw_seg = unsafe { dw_ptr.slice_mut(slot * s, s) };
                let dscore = unsafe { dsc_ptr.slice_mut(slot * s, s) };
                let qoff = head * hd;
                let koff = d + head * hd;
                let voff = 2 * d + head * hd;
                for t in 0..s {
                    let dorow = &dh_ro[(i * s + t) * d + head * hd..][..hd];
                    let prow = &lt.probs[((i * h + head) * s + t) * s..][..t + 1];
                    // dv[t2] += w[t2] * dout ; dw[t2] = <dout, v[t2]>
                    for t2 in 0..=t {
                        let vrow = &lt.qkv[(i * s + t2) * 3 * d + voff..][..hd];
                        dw_seg[t2] = vecmath::dot(dorow, vrow) as f32;
                        let w = prow[t2];
                        let dvrow = unsafe { dqkv_ptr.slice_mut((i * s + t2) * 3 * d + voff, hd) };
                        for (dvj, &doj) in dvrow.iter_mut().zip(dorow) {
                            *dvj += w * doj;
                        }
                    }
                    // softmax backward on the causal row segment
                    vecmath::softmax_rows_backward(
                        prow,
                        &dw_seg[..t + 1],
                        1,
                        t + 1,
                        &mut dscore[..t + 1],
                    );
                    // dq[t] += scale * sum_t2 dscore[t2] k[t2] ; dk[t2] += scale * dscore[t2] q[t]
                    let qrow_off = (i * s + t) * 3 * d + qoff;
                    let qrow = &lt.qkv[qrow_off..qrow_off + hd];
                    let dqrow = unsafe { dqkv_ptr.slice_mut(qrow_off, hd) };
                    for t2 in 0..=t {
                        let ds = dscore[t2] * scale;
                        let krow_off = (i * s + t2) * 3 * d + koff;
                        let krow = &lt.qkv[krow_off..krow_off + hd];
                        let dkrow = unsafe { dqkv_ptr.slice_mut(krow_off, hd) };
                        for j in 0..hd {
                            dqrow[j] += ds * krow[j];
                            dkrow[j] += ds * qrow[j];
                        }
                    }
                }
            });
        }
        vecmath::add_bias_rows_backward(dqkv, r, 3 * d, lp.bqkv.of_mut(grad));
        vecmath::matmul_bt_threaded(dqkv, lp.wqkv.of(params), r, 3 * d, d, dh, pool); // dh1
        vecmath::matmul_at_threaded(&lt.h1, dqkv, r, d, 3 * d, lp.wqkv.of_mut(grad), pool);
        vecmath::layernorm_rows_backward_ws(
            &lt.x_in,
            lp.ln1_g.of(params),
            r,
            d,
            1e-5,
            dh,
            dx_ln,
            dg,
            db,
            dg64,
            db64,
        );
        write_grad(grad, lp.ln1_g, dg);
        write_grad(grad, lp.ln1_b, db);
        vecmath::axpy(1.0, dx_ln, dx); // d(x_in) = d(x_mid) + LN path
    }

    // --- embeddings: x0[i*s+t] = tok_emb[ids[i,t]] + pos_emb[t] ---
    {
        let toff = plan.tok_emb.off;
        let poff = plan.pos_emb.off;
        for i in 0..b {
            for t in 0..s {
                let id = ids[i * s + t] as usize;
                let dxrow = &dx[(i * s + t) * d..(i * s + t + 1) * d];
                for j in 0..d {
                    grad[toff + id * d + j] += dxrow[j];
                    grad[poff + t * d + j] += dxrow[j];
                }
            }
        }
    }

    loss
}

/// Allocating wrapper over [`loss_and_grad_ws`] (tests / one-shot callers).
pub fn loss_and_grad(
    model: &NativeModel,
    params: &[f32],
    ids: &[i32],
    targets: &[i32],
    mask: &[f32],
    b: usize,
    s: usize,
) -> LossGrad {
    let mut fwd = model.scratch();
    let mut ws = GradWorkspace::for_model(model);
    let loss = loss_and_grad_ws(model, params, ids, targets, mask, b, s, &mut fwd, &mut ws);
    LossGrad { loss, grad: ws.grad }
}

/// Copy a tensor gradient into its resolved span of the flat gradient
/// buffer.
fn write_grad(grad: &mut [f32], sp: Span, src: &[f32]) {
    debug_assert_eq!(src.len(), sp.len);
    grad[sp.off..sp.off + sp.len].copy_from_slice(src);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::model::build_preset;
    use crate::testing::{property, UsizeRange};
    use crate::util::rng::Xoshiro256pp;
    use crate::vecmath::{dot, nrm2};

    /// Small custom geometry so gradchecks stay fast.
    fn tiny_model() -> NativeModel {
        NativeModel::new(build_preset("grad-test", 16, 8, 2, 2, 6, 2))
    }

    fn test_batch(model: &NativeModel, seed: u64) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let m = &model.meta;
        let (b, s, v) = (m.batch, m.seq_len, m.vocab);
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let ids: Vec<i32> = (0..b * s).map(|_| rng.gen_range(v) as i32).collect();
        let tgt: Vec<i32> = (0..b * s).map(|_| rng.gen_range(v) as i32).collect();
        let mut mask = vec![0f32; b * s];
        for i in 0..b {
            // two masked positions per example
            mask[i * s + rng.gen_range(s)] = 1.0;
            mask[i * s + rng.gen_range(s)] = 1.0;
        }
        (ids, tgt, mask)
    }

    #[test]
    fn taped_loss_equals_model_loss() {
        // the taped forward IS the model forward (one implementation with
        // optional recording), so equality is structural — this guards the
        // workspace plumbing, not a replica
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let params = model.init_flat(3);
        let (ids, tgt, mask) = test_batch(&model, 5);
        let lg = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        let want = model.loss(&params, &ids, &tgt, &mask, b, s);
        assert_eq!(lg.loss, want);
    }

    #[test]
    fn grad_workspace_reuse_is_bit_identical() {
        // repeated loss_and_grad_ws over ONE workspace must reproduce the
        // fresh-allocation result exactly (no stale gradient accumulation)
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let params = model.init_flat(21);
        let (ids, tgt, mask) = test_batch(&model, 31);
        let fresh = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        let mut fwd = FwdScratch::new(&model.meta);
        let mut ws = GradWorkspace::new(&model.meta);
        for _ in 0..3 {
            let loss = loss_and_grad_ws(&model, &params, &ids, &tgt, &mask, b, s, &mut fwd, &mut ws);
            assert_eq!(loss, fresh.loss);
            assert_eq!(ws.grad, fresh.grad);
        }
    }

    #[test]
    fn grad_is_zero_on_pad_lanes_and_finite() {
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let params = model.init_flat(7);
        let (ids, tgt, mask) = test_batch(&model, 11);
        let lg = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        assert_eq!(lg.grad.len(), model.meta.d_pad);
        assert!(lg.grad[model.meta.d_raw..].iter().all(|&g| g == 0.0));
        assert!(lg.grad.iter().all(|g| g.is_finite()));
        assert!(nrm2(&lg.grad) > 0.0, "gradient must be nonzero on a random batch");
    }

    #[test]
    fn prop_end_to_end_gradient_matches_central_differences() {
        // directional central-difference gradcheck of the full transformer
        // loss: |(f(x+eps v) - f(x-eps v))/(2 eps) - <grad, v>| / |<grad, v>|
        // <= 1e-2 (eps = 1e-2, calibrated against the numpy mirror where the
        // worst case measured 8.5e-4)
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let d_raw = model.meta.d_raw;
        let g = UsizeRange(1, 10_000);
        property("e2e-gradcheck", &g, 6, |&case| {
            let params = model.init_flat(case as i32);
            let (ids, tgt, mask) = test_batch(&model, case as u64 ^ 0xABCD);
            let lg = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
            let mut rng = Xoshiro256pp::seed_from_u64(case as u64);
            let mut v = vec![0f32; params.len()];
            rng.fill_normal_f32(&mut v[..d_raw]);
            let n = nrm2(&v) as f32;
            for vi in v.iter_mut() {
                *vi /= n;
            }
            let eps = 1e-2f32;
            let mut xp = params.clone();
            vecmath::axpy(eps, &v, &mut xp);
            let mut xm = params.clone();
            vecmath::axpy(-eps, &v, &mut xm);
            let fp = model.loss(&xp, &ids, &tgt, &mask, b, s) as f64;
            let fm = model.loss(&xm, &ids, &tgt, &mask, b, s) as f64;
            let fd = (fp - fm) / (2.0 * eps as f64);
            let an = dot(&lg.grad, &v);
            (fd - an).abs() / an.abs().max(1e-6) < 1e-2
        });
    }

    #[test]
    fn per_coordinate_gradcheck_on_embedding_and_head_rows() {
        // spot-check individual coordinates across tensor kinds (embedding,
        // attention weight, MLP weight, final LN gain) with per-coordinate
        // central differences; f32 loss noise bounds accuracy to ~5e-2 at
        // the 1e-3 gradient floor (numpy-mirror calibrated), so assert 1e-1
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let params = model.init_flat(13);
        let (ids, tgt, mask) = test_batch(&model, 17);
        let lg = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        let probe: Vec<usize> = vec![
            model.plan.tok_emb.off + 3,
            model.plan.layers[0].wqkv.off + 5,
            model.plan.layers[1].w1.off + 7,
            model.plan.ln_f_g.off + 1,
        ];
        let eps = 3e-3f32;
        for i in probe {
            let mut xp = params.clone();
            xp[i] += eps;
            let mut xm = params.clone();
            xm[i] -= eps;
            let fd = (model.loss(&xp, &ids, &tgt, &mask, b, s) as f64
                - model.loss(&xm, &ids, &tgt, &mask, b, s) as f64)
                / (2.0 * eps as f64);
            let an = lg.grad[i] as f64;
            let rel = (fd - an).abs() / an.abs().max(1e-3);
            assert!(rel < 1e-1, "coord {i}: analytic {an} vs fd {fd} (rel {rel:.2e})");
        }
    }

    #[test]
    fn gradients_bit_identical_across_pool_sizes() {
        // the threaded attention backward (and pooled backward GEMMs) must
        // reproduce the sequential gradient bitwise; geometry sized so both
        // the GEMM and attention work gates actually engage the pool
        let meta = build_preset("grad-thr", 64, 64, 2, 2, 64, 8);
        let single = NativeModel::new(meta.clone());
        let (b, s) = (single.meta.batch, single.meta.seq_len);
        let params = single.init_flat(41);
        let (ids, tgt, mask) = test_batch(&single, 43);
        let want = loss_and_grad(&single, &params, &ids, &tgt, &mask, b, s);
        for t in [2usize, 4] {
            let m = NativeModel::new(meta.clone()).with_threads(t);
            let got = loss_and_grad(&m, &params, &ids, &tgt, &mask, b, s);
            assert_eq!(got.loss, want.loss, "threads={t}");
            assert_eq!(got.grad, want.grad, "threads={t}");
        }
    }

    #[test]
    fn gradient_descends_the_loss() {
        let model = tiny_model();
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let params = model.init_flat(19);
        let (ids, tgt, mask) = test_batch(&model, 23);
        let lg = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        let gn2 = dot(&lg.grad, &lg.grad);
        let eta = (0.05 / gn2.sqrt()) as f32; // small step along -grad
        let mut xs = params.clone();
        vecmath::axpy(-eta, &lg.grad, &mut xs);
        let after = model.loss(&xs, &ids, &tgt, &mask, b, s);
        assert!(
            (after as f64) < lg.loss as f64,
            "step along -grad must reduce the loss: {} -> {after}",
            lg.loss
        );
    }

    #[test]
    fn unmasked_targets_get_zero_logit_gradient_rows() {
        let model = tiny_model();
        let m = &model.meta;
        let (b, s, v) = (m.batch, m.seq_len, m.vocab);
        let params = model.init_flat(29);
        let ids: Vec<i32> = (0..b * s).map(|i| (i % v) as i32).collect();
        let tgt: Vec<i32> = vec![1; b * s];
        let mut mask = vec![0f32; b * s];
        mask[2] = 1.0;
        // gradient wrt a target only used at an unmasked position is driven
        // purely by the forward path, not the label: flipping that target
        // must not change the gradient
        let g1 = loss_and_grad(&model, &params, &ids, &tgt, &mask, b, s);
        let mut tgt2 = tgt.clone();
        tgt2[7] = 9; // unmasked position
        let g2 = loss_and_grad(&model, &params, &ids, &tgt2, &mask, b, s);
        assert_eq!(g1.grad, g2.grad);
        assert_eq!(g1.loss, g2.loss);
    }
}
