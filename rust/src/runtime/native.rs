//! NativeBackend: the manifest's program set executed in pure Rust.
//!
//! Implements `init`, `sample_u`, `loss`, `loss_pallas` (the
//! kernel-composition attention ablation twin — ROADMAP's last pjrt-only
//! program, now offline), `two_point`, `eval_logits`, the fused
//! `conmezo_step` / `mezo_step` / `mezo_momentum_step` programs, the
//! first-order programs (`fo_sgd_step`, `fo_adamw_step`, `grad_cos2` via
//! the reverse-mode pass in [`crate::runtime::autograd`]) and the
//! `quad_loss`/`quad_grad` synthetic objective for every built-in preset —
//! no Python, no XLA, no artifacts on disk.
//!
//! Programs bind into a [`NativeSession`]: one bound program owning its
//! forward scratch, autograd workspace, direction buffers and output
//! slots, with every per-layer layout offset resolved at bind time into
//! the model's `ModelPlan` — so steady-state `run`/`two_point` executes
//! with zero allocation and zero string formatting (the bind-once/run-many
//! contract of [`crate::runtime::Session`]). The session also implements
//! the antithetic-pair fast path `two_point` over a single scratch set.
//! All sessions of one backend share ONE persistent
//! [`crate::parallel::WorkerPool`] (sized by [`ParallelPolicy`]) for the
//! GEMMs and the threaded attention tasks; no OS thread is ever spawned on
//! the step path.
//!
//! Antithetic pairs are **materialization-free**: `pair_losses`
//! evaluates `f(x + λz)` and `f(x − λz)` through
//! [`crate::vecmath::ParamView`]s — the perturbation is fused into the
//! forward's weight loads, so a pair performs ZERO parameter-sized writes
//! (the old `d`-sized `xs` scratch is gone from the session entirely).
//! Because the fused expression is exactly what `axpy_into` materializes,
//! the pair losses are bit-identical to the retired materialized path
//! (pinned by `pair_losses_match_materialized_reference` at pool sizes
//! {1, 2, 4}).
//!
//! Fused-step emulation reuses the exact `vecmath` kernels the composed
//! path uses (`cone_direction`, `zo_update`, `axpy_into` for the parameter
//! update), so fused and composed modes are bit-consistent on this backend
//! — the equivalence the integration tests assert exactly rather than
//! within tolerance.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::parallel::WorkerPool;
use crate::runtime::adapter::AdapterSession;
use crate::runtime::autograd::{self, GradWorkspace};
use crate::runtime::manifest::{Manifest, PresetMeta, ProgramSpec, TensorSpec};
use crate::runtime::model::{builtin_presets, FwdScratch, NativeModel, QUAD_DIM};
use crate::runtime::{
    validate_args, Arg, Backend, CallSession, ParallelPolicy, ProgramImpl, Session, Value,
};
use crate::util::error::{bail, Result};
use crate::vecmath::{self, ParamView};

/// Program kinds the native backend implements per preset.
pub const NATIVE_KINDS: [&str; 12] = [
    "init",
    "sample_u",
    "loss",
    "loss_pallas",
    "two_point",
    "eval_logits",
    "conmezo_step",
    "mezo_step",
    "mezo_momentum_step",
    "fo_sgd_step",
    "fo_adamw_step",
    "grad_cos2",
];

/// AdamW constants of the reference `fo_adamw_step` (python/compile/steps.py).
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;
pub const ADAM_WD: f32 = 0.0;

pub struct NativeBackend {
    manifest: Manifest,
    policy: ParallelPolicy,
    /// ONE persistent worker pool per backend (hence per `Runtime`),
    /// shared by every bound session's model — workers spawn here once and
    /// serve all GEMM/attention dispatches forever.
    pool: Arc<WorkerPool>,
    /// ONE telemetry registry per backend, shared by the pool and every
    /// bound session (sessions reach it through their model's pool handle,
    /// so a rebind reuses the same instruments).
    telemetry: Arc<crate::telemetry::Registry>,
}

impl NativeBackend {
    /// Backend over the built-in presets (nano/tiny/small/medium/xl),
    /// single-threaded kernels.
    pub fn new() -> NativeBackend {
        Self::with_policy(ParallelPolicy::single())
    }

    /// Built-in presets with an explicit GEMM thread policy.
    pub fn with_policy(policy: ParallelPolicy) -> NativeBackend {
        Self::with_presets_policy(builtin_presets(), policy)
    }

    /// Backend over an explicit preset list (tests/fixtures use this to run
    /// custom geometries).
    pub fn with_presets(presets: Vec<PresetMeta>) -> NativeBackend {
        Self::with_presets_policy(presets, ParallelPolicy::single())
    }

    /// This backend's [`ParallelPolicy`].
    pub fn policy(&self) -> ParallelPolicy {
        self.policy
    }

    /// A handle to the backend's shared worker pool (tests use this to pin
    /// the no-steady-state-spawning invariant).
    pub fn pool_handle(&self) -> Arc<WorkerPool> {
        self.pool.clone()
    }

    pub fn with_presets_policy(presets: Vec<PresetMeta>, policy: ParallelPolicy) -> NativeBackend {
        let mut programs = BTreeMap::new();
        for (kind, outs) in [("loss", "loss"), ("grad", "grad")] {
            let name = format!("quad_{kind}");
            programs.insert(
                name.clone(),
                ProgramSpec {
                    name,
                    preset: "quad".into(),
                    kind: kind.into(),
                    file: String::new(),
                    inputs: vec![tensor("x", "float32", vec![QUAD_DIM])],
                    outputs: vec![outs.to_string()],
                },
            );
        }
        let mut preset_map = BTreeMap::new();
        for meta in presets {
            for kind in NATIVE_KINDS {
                let spec = program_spec(&meta, kind);
                programs.insert(spec.name.clone(), spec);
            }
            preset_map.insert(meta.name.clone(), meta);
        }
        // registry first, pool second: the pool reports dispatch timing
        // into the registry it is constructed with, and both live as long
        // as the backend (telemetry is preallocated here so instrumented
        // steady-state run()/two_point() never allocates)
        let telemetry = Arc::new(crate::telemetry::Registry::new(policy.threads));
        let pool = Arc::new(WorkerPool::with_telemetry(policy.threads, Some(telemetry.clone())));
        NativeBackend {
            manifest: Manifest { programs, presets: preset_map },
            policy,
            pool,
            telemetry,
        }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn telemetry(&self) -> Option<&Arc<crate::telemetry::Registry>> {
        Some(&self.telemetry)
    }

    fn bind(&self, spec: &ProgramSpec) -> Result<Box<dyn Session>> {
        if spec.preset == "quad" {
            // the synthetic quadratic is microseconds per eval — the
            // per-call adapter is plenty
            return Ok(Box::new(CallSession::new(spec.clone(), Box::new(QuadProgram))));
        }
        let meta = self.manifest.preset(&spec.preset)?.clone();
        let model = NativeModel::new(meta).with_pool(self.pool.clone());
        Ok(Box::new(NativeSession::new(spec.clone(), model)))
    }

    fn bind_adapter(&self, preset: &str, rank: usize) -> Result<AdapterSession> {
        let meta = self.manifest.preset(preset)?.clone();
        let model = NativeModel::new(meta).with_pool(self.pool.clone());
        Ok(AdapterSession::new(model, rank))
    }
}

fn tensor(name: &str, dtype: &str, shape: Vec<usize>) -> TensorSpec {
    TensorSpec { name: name.to_string(), dtype: dtype.to_string(), shape }
}

/// Input/output signature per kind — mirrors `python/compile/aot.py`
/// (`_inputs_for` / `_OUTPUTS`) so both backends accept identical calls.
fn program_spec(meta: &PresetMeta, kind: &str) -> ProgramSpec {
    let dp = meta.d_pad;
    let (b, s) = (meta.batch, meta.seq_len);
    let vec = |n: &str| tensor(n, "float32", vec![dp]);
    let scalar = |n: &str| tensor(n, "float32", vec![]);
    let iscalar = |n: &str| tensor(n, "int32", vec![]);
    let batch = || {
        vec3(
            tensor("input_ids", "int32", vec![b, s]),
            tensor("targets", "int32", vec![b, s]),
            tensor("mask", "float32", vec![b, s]),
        )
    };
    let (inputs, outputs): (Vec<TensorSpec>, Vec<&str>) = match kind {
        "init" => (vec![iscalar("seed")], vec!["params"]),
        "sample_u" => (vec![iscalar("seed")], vec!["u"]),
        "loss" | "loss_pallas" => (with(vec![vec("params")], batch()), vec!["loss"]),
        "two_point" => (
            with(vec![vec("params"), vec("z"), scalar("lam")], batch()),
            vec!["loss_plus", "loss_minus"],
        ),
        "eval_logits" => (
            vec![
                vec("params"),
                tensor("input_ids", "int32", vec![b, s]),
                tensor("pos", "int32", vec![b]),
            ],
            vec!["logits"],
        ),
        "conmezo_step" => (
            with(
                vec![
                    vec("params"),
                    vec("m"),
                    iscalar("seed"),
                    scalar("theta"),
                    scalar("beta"),
                    scalar("eta"),
                    scalar("lam"),
                ],
                batch(),
            ),
            vec!["params", "m", "loss_plus", "loss_minus", "proj_grad"],
        ),
        "mezo_step" => (
            with(
                vec![vec("params"), iscalar("seed"), scalar("eta"), scalar("lam")],
                batch(),
            ),
            vec!["params", "loss_plus", "loss_minus", "proj_grad"],
        ),
        "mezo_momentum_step" => (
            with(
                vec![
                    vec("params"),
                    vec("m"),
                    iscalar("seed"),
                    scalar("beta"),
                    scalar("eta"),
                    scalar("lam"),
                ],
                batch(),
            ),
            vec!["params", "m", "loss_plus", "loss_minus", "proj_grad"],
        ),
        "fo_sgd_step" => (
            with(vec![vec("params"), scalar("eta")], batch()),
            vec!["params", "loss"],
        ),
        "fo_adamw_step" => (
            with(
                vec![vec("params"), vec("mu"), vec("nu"), scalar("t"), scalar("eta")],
                batch(),
            ),
            vec!["params", "mu", "nu", "loss"],
        ),
        "grad_cos2" => (
            with(vec![vec("params"), vec("m")], batch()),
            vec!["cos2", "loss"],
        ),
        other => panic!("program_spec: unknown native kind {other:?}"),
    };
    ProgramSpec {
        name: format!("{}_{kind}", meta.name),
        preset: meta.name.clone(),
        kind: kind.to_string(),
        file: String::new(),
        inputs,
        outputs: outputs.into_iter().map(str::to_string).collect(),
    }
}

fn vec3(a: TensorSpec, b: TensorSpec, c: TensorSpec) -> Vec<TensorSpec> {
    vec![a, b, c]
}

fn with(mut head: Vec<TensorSpec>, tail: Vec<TensorSpec>) -> Vec<TensorSpec> {
    head.extend(tail);
    head
}

// ---------------------------------------------------------------------------
// Argument extraction
// ---------------------------------------------------------------------------

fn arg_f32s<'a>(a: &Arg<'a>, what: &str) -> Result<&'a [f32]> {
    match a {
        Arg::VecF32(v) => Ok(v),
        Arg::TensorF32(v, _) => Ok(v),
        _ => bail!("expected f32 tensor for {what}"),
    }
}

fn arg_i32s<'a>(a: &Arg<'a>, what: &str) -> Result<&'a [i32]> {
    match a {
        Arg::TensorI32(v, _) => Ok(v),
        _ => bail!("expected i32 tensor for {what}"),
    }
}

fn arg_f32(a: &Arg<'_>, what: &str) -> Result<f32> {
    match a {
        Arg::F32(v) => Ok(*v),
        _ => bail!("expected f32 scalar for {what}"),
    }
}

fn arg_i32(a: &Arg<'_>, what: &str) -> Result<i32> {
    match a {
        Arg::I32(v) => Ok(*v),
        _ => bail!("expected i32 scalar for {what}"),
    }
}

/// (input_ids, targets, mask) starting at position `at`.
fn batch_at<'a>(args: &[Arg<'a>], at: usize) -> Result<(&'a [i32], &'a [i32], &'a [f32])> {
    Ok((
        arg_i32s(&args[at], "input_ids")?,
        arg_i32s(&args[at + 1], "targets")?,
        arg_f32s(&args[at + 2], "mask")?,
    ))
}

// ---------------------------------------------------------------------------
// Per-preset bound sessions
// ---------------------------------------------------------------------------

/// One bound native program: the model plus every workspace its kind needs,
/// allocated once at bind time. Antithetic-pair kinds own NO perturbed-
/// parameter buffer — `x ± λz` streams through the forward via
/// [`ParamView`], so the only parameter-sized session buffers are the
/// direction(s) the step kinds sample.
pub struct NativeSession {
    spec: ProgramSpec,
    model: NativeModel,
    /// transformer forward scratch (all kinds that run the model)
    fwd: Option<FwdScratch>,
    /// reverse-pass workspace (first-order kinds)
    grad: Option<GradWorkspace>,
    /// raw direction u (ZO step kinds)
    u: Vec<f32>,
    /// cone direction z (conmezo_step)
    z: Vec<f32>,
    /// reusable output slots, sized once from the manifest signature
    outs: Vec<Value>,
    /// the backend's telemetry registry, resolved once at bind time (owned
    /// handle: phase timers must not hold a borrow of the model across the
    /// `&mut self` execute body)
    tel: Option<Arc<crate::telemetry::Registry>>,
}

/// Output buffer size by manifest output name.
fn out_slot(meta: &PresetMeta, name: &str) -> Value {
    let n = match name {
        "params" | "m" | "u" | "mu" | "nu" => meta.d_pad,
        "logits" => meta.batch * meta.vocab,
        _ => 1, // scalars: loss, loss_plus, loss_minus, proj_grad, cos2
    };
    Value::F32(vec![0.0; n])
}

/// The f32 payload of an output slot.
fn f32_mut(v: &mut Value) -> &mut [f32] {
    match v {
        Value::F32(x) => x.as_mut_slice(),
        Value::I32(_) => unreachable!("native output slots are f32"),
    }
}

/// (f(x + lam z), f(x - lam z)) on one batch over one scratch set — the
/// antithetic-pair core shared by the `two_point` program, the fused ZO
/// steps and the [`Session::two_point`] fast path. Both evals stream
/// `x ± λz` through [`ParamView`]s with the perturbation fused into the
/// weight loads: zero parameter-sized writes per pair, bit-identical to
/// the retired materialize-into-`xs` path. The GEMM weights pack ONCE per
/// pair ([`NativeModel::pack_pair`]: base and direction panels), so both
/// ±λ forwards consume cache-friendly tiles with `w + sc·z` fused
/// in-register — one packing pass amortized over the two arms.
#[allow(clippy::too_many_arguments)]
fn pair_losses(
    model: &NativeModel,
    fwd: &mut FwdScratch,
    params: &[f32],
    z: &[f32],
    lam: f32,
    ids: &[i32],
    tgt: &[i32],
    mask: &[f32],
) -> (f32, f32) {
    let (b, s) = (model.meta.batch, model.meta.seq_len);
    model.pack_pair(params, z, fwd);
    let lp = model.loss_view_with_prepacked(
        ParamView::perturbed(params, z, lam),
        ids,
        tgt,
        mask,
        b,
        s,
        fwd,
        true,
    );
    let lm = model.loss_view_with_prepacked(
        ParamView::perturbed(params, z, -lam),
        ids,
        tgt,
        mask,
        b,
        s,
        fwd,
        true,
    );
    (lp, lm)
}

impl NativeSession {
    fn new(spec: ProgramSpec, model: NativeModel) -> NativeSession {
        let meta = &model.meta;
        let kind = spec.kind.as_str();
        let needs_fwd = !matches!(kind, "init" | "sample_u");
        let needs_grad = matches!(kind, "fo_sgd_step" | "fo_adamw_step" | "grad_cos2");
        // pair kinds need NO perturbed-parameter buffer: x ± λz streams
        // through ParamViews (see pair_losses)
        let needs_u = matches!(kind, "conmezo_step" | "mezo_step" | "mezo_momentum_step");
        let needs_z = kind == "conmezo_step";
        let d = meta.d_pad;
        let fwd = needs_fwd.then(|| model.scratch());
        let grad = needs_grad.then(|| GradWorkspace::for_model(&model));
        let outs: Vec<Value> = spec.outputs.iter().map(|name| out_slot(meta, name)).collect();
        let tel = model.telemetry_arc();
        NativeSession {
            spec,
            fwd,
            grad,
            u: vec![0.0; if needs_u { d } else { 0 }],
            z: vec![0.0; if needs_z { d } else { 0 }],
            outs,
            tel,
            model,
        }
    }

    fn execute(&mut self, args: &[Arg<'_>]) -> Result<()> {
        let (b, s) = (self.model.meta.batch, self.model.meta.seq_len);
        let d_raw = self.model.meta.d_raw;
        let tel = self.tel.as_deref().filter(|r| r.enabled());
        // one span covering the whole fused step (sampling + both forwards
        // + the parameter/momentum update); drops when execute returns
        let _step_span = match self.spec.kind.as_str() {
            "conmezo_step" | "mezo_step" | "mezo_momentum_step" | "fo_sgd_step"
            | "fo_adamw_step" => tel.and_then(|r| r.span("fused_step", Some(&r.fused_step))),
            _ => None,
        };
        match self.spec.kind.as_str() {
            "init" => {
                let seed = arg_i32(&args[0], "seed")?;
                self.model.init_into(seed, f32_mut(&mut self.outs[0]));
            }
            "sample_u" => {
                let seed = arg_i32(&args[0], "seed")?;
                self.model.sample_u_into(seed, f32_mut(&mut self.outs[0]));
            }
            "loss" | "loss_pallas" => {
                let params = arg_f32s(&args[0], "params")?;
                let (ids, tgt, mask) = batch_at(args, 1)?;
                let fwd = self.fwd.as_mut().expect("loss session owns forward scratch");
                let l = {
                    let _t = tel.and_then(|r| r.span("forward", Some(&r.forward)));
                    if self.spec.kind == "loss_pallas" {
                        self.model.loss_pallas_with(params, ids, tgt, mask, b, s, fwd)
                    } else {
                        self.model.loss_with(params, ids, tgt, mask, b, s, fwd)
                    }
                };
                f32_mut(&mut self.outs[0])[0] = l;
            }
            "two_point" => {
                let params = arg_f32s(&args[0], "params")?;
                let z = arg_f32s(&args[1], "z")?;
                let lam = arg_f32(&args[2], "lam")?;
                let (ids, tgt, mask) = batch_at(args, 3)?;
                let (lp, lm) = {
                    let _t = tel.and_then(|r| r.span("forward", Some(&r.forward)));
                    pair_losses(
                        &self.model,
                        self.fwd.as_mut().expect("two_point session owns forward scratch"),
                        params,
                        z,
                        lam,
                        ids,
                        tgt,
                        mask,
                    )
                };
                f32_mut(&mut self.outs[0])[0] = lp;
                f32_mut(&mut self.outs[1])[0] = lm;
            }
            "eval_logits" => {
                let params = arg_f32s(&args[0], "params")?;
                let ids = arg_i32s(&args[1], "input_ids")?;
                let pos = arg_i32s(&args[2], "pos")?;
                let fwd = self.fwd.as_mut().expect("eval session owns forward scratch");
                self.model.eval_logits_with(params, ids, pos, b, s, fwd, f32_mut(&mut self.outs[0]));
            }
            "conmezo_step" => {
                let params = arg_f32s(&args[0], "params")?;
                let m_in = arg_f32s(&args[1], "m")?;
                let seed = arg_i32(&args[2], "seed")?;
                let theta = arg_f32(&args[3], "theta")?;
                let beta = arg_f32(&args[4], "beta")?;
                let eta = arg_f32(&args[5], "eta")?;
                let lam = arg_f32(&args[6], "lam")?;
                let (ids, tgt, mask) = batch_at(args, 7)?;
                self.model.sample_u_into(seed, &mut self.u);
                vecmath::cone_direction(m_in, &self.u, theta, d_raw, &mut self.z);
                let (lp, lm) = {
                    let _t = tel.and_then(|r| r.span("forward", Some(&r.forward)));
                    pair_losses(
                        &self.model,
                        self.fwd.as_mut().expect("step session owns forward scratch"),
                        params,
                        &self.z,
                        lam,
                        ids,
                        tgt,
                        mask,
                    )
                };
                let g = ((lp as f64 - lm as f64) / (2.0 * lam as f64)) as f32;
                let [o_x, o_m, o_lp, o_lm, o_g] = &mut self.outs[..] else {
                    unreachable!("conmezo_step has 5 outputs")
                };
                let x_new = f32_mut(o_x);
                let m_new = f32_mut(o_m);
                x_new.copy_from_slice(params);
                m_new.copy_from_slice(m_in);
                vecmath::zo_update(x_new, m_new, &self.z, g, eta, beta);
                f32_mut(o_lp)[0] = lp;
                f32_mut(o_lm)[0] = lm;
                f32_mut(o_g)[0] = g;
            }
            "mezo_step" => {
                let params = arg_f32s(&args[0], "params")?;
                let seed = arg_i32(&args[1], "seed")?;
                let eta = arg_f32(&args[2], "eta")?;
                let lam = arg_f32(&args[3], "lam")?;
                let (ids, tgt, mask) = batch_at(args, 4)?;
                self.model.sample_u_into(seed, &mut self.u);
                let (lp, lm) = {
                    let _t = tel.and_then(|r| r.span("forward", Some(&r.forward)));
                    pair_losses(
                        &self.model,
                        self.fwd.as_mut().expect("step session owns forward scratch"),
                        params,
                        &self.u,
                        lam,
                        ids,
                        tgt,
                        mask,
                    )
                };
                let g = ((lp as f64 - lm as f64) / (2.0 * lam as f64)) as f32;
                let [o_x, o_lp, o_lm, o_g] = &mut self.outs[..] else {
                    unreachable!("mezo_step has 4 outputs")
                };
                vecmath::axpy_into(-eta * g, &self.u, params, f32_mut(o_x));
                f32_mut(o_lp)[0] = lp;
                f32_mut(o_lm)[0] = lm;
                f32_mut(o_g)[0] = g;
            }
            "mezo_momentum_step" => {
                let params = arg_f32s(&args[0], "params")?;
                let m_in = arg_f32s(&args[1], "m")?;
                let seed = arg_i32(&args[2], "seed")?;
                let beta = arg_f32(&args[3], "beta")?;
                let eta = arg_f32(&args[4], "eta")?;
                let lam = arg_f32(&args[5], "lam")?;
                let (ids, tgt, mask) = batch_at(args, 6)?;
                self.model.sample_u_into(seed, &mut self.u);
                let (lp, lm) = {
                    let _t = tel.and_then(|r| r.span("forward", Some(&r.forward)));
                    pair_losses(
                        &self.model,
                        self.fwd.as_mut().expect("step session owns forward scratch"),
                        params,
                        &self.u,
                        lam,
                        ids,
                        tgt,
                        mask,
                    )
                };
                let g = ((lp as f64 - lm as f64) / (2.0 * lam as f64)) as f32;
                // m' = beta m + (1-beta) g u ; x' = x - eta m'
                // (same float ops as vecmath::zo_update's momentum pass)
                let cm = (1.0 - beta) * g;
                let [o_x, o_m, o_lp, o_lm, o_g] = &mut self.outs[..] else {
                    unreachable!("mezo_momentum_step has 5 outputs")
                };
                let m_new = f32_mut(o_m);
                for i in 0..m_in.len() {
                    m_new[i] = beta * m_in[i] + cm * self.u[i];
                }
                vecmath::axpy_into(-eta, m_new, params, f32_mut(o_x));
                f32_mut(o_lp)[0] = lp;
                f32_mut(o_lm)[0] = lm;
                f32_mut(o_g)[0] = g;
            }
            "fo_sgd_step" => {
                let params = arg_f32s(&args[0], "params")?;
                let eta = arg_f32(&args[1], "eta")?;
                let (ids, tgt, mask) = batch_at(args, 2)?;
                let fwd = self.fwd.as_mut().expect("fo session owns forward scratch");
                let gw = self.grad.as_mut().expect("fo session owns grad workspace");
                let loss = {
                    let _t = tel.and_then(|r| r.span("backward", Some(&r.backward)));
                    autograd::loss_and_grad_ws(&self.model, params, ids, tgt, mask, b, s, fwd, gw)
                };
                let [o_x, o_loss] = &mut self.outs[..] else {
                    unreachable!("fo_sgd_step has 2 outputs")
                };
                vecmath::axpy_into(-eta, &gw.grad, params, f32_mut(o_x));
                f32_mut(o_loss)[0] = loss;
            }
            "fo_adamw_step" => {
                let params = arg_f32s(&args[0], "params")?;
                let mu = arg_f32s(&args[1], "mu")?;
                let nu = arg_f32s(&args[2], "nu")?;
                let t = arg_f32(&args[3], "t")?;
                let eta = arg_f32(&args[4], "eta")?;
                let (ids, tgt, mask) = batch_at(args, 5)?;
                let fwd = self.fwd.as_mut().expect("fo session owns forward scratch");
                let gw = self.grad.as_mut().expect("fo session owns grad workspace");
                let loss = {
                    let _t = tel.and_then(|r| r.span("backward", Some(&r.backward)));
                    autograd::loss_and_grad_ws(&self.model, params, ids, tgt, mask, b, s, fwd, gw)
                };
                // AdamW with bias correction, t the 1-based step counter
                // (same float ops as python/compile/steps.py::fo_adamw_step)
                let bc1 = 1.0 - ADAM_B1.powf(t);
                let bc2 = 1.0 - ADAM_B2.powf(t);
                let [o_x, o_mu, o_nu, o_loss] = &mut self.outs[..] else {
                    unreachable!("fo_adamw_step has 4 outputs")
                };
                let x_new = f32_mut(o_x);
                let mu_new = f32_mut(o_mu);
                let nu_new = f32_mut(o_nu);
                for i in 0..params.len() {
                    let g = gw.grad[i];
                    let m1 = ADAM_B1 * mu[i] + (1.0 - ADAM_B1) * g;
                    let v1 = ADAM_B2 * nu[i] + (1.0 - ADAM_B2) * g * g;
                    let step = (m1 / bc1) / ((v1 / bc2).sqrt() + ADAM_EPS) + ADAM_WD * params[i];
                    x_new[i] = params[i] - eta * step;
                    mu_new[i] = m1;
                    nu_new[i] = v1;
                }
                f32_mut(o_loss)[0] = loss;
            }
            "grad_cos2" => {
                let params = arg_f32s(&args[0], "params")?;
                let m_in = arg_f32s(&args[1], "m")?;
                let (ids, tgt, mask) = batch_at(args, 2)?;
                let fwd = self.fwd.as_mut().expect("probe session owns forward scratch");
                let gw = self.grad.as_mut().expect("probe session owns grad workspace");
                let loss = {
                    let _t = tel.and_then(|r| r.span("backward", Some(&r.backward)));
                    autograd::loss_and_grad_ws(&self.model, params, ids, tgt, mask, b, s, fwd, gw)
                };
                let c = vecmath::cos2(m_in, &gw.grad) as f32;
                f32_mut(&mut self.outs[0])[0] = c;
                f32_mut(&mut self.outs[1])[0] = loss;
            }
            other => bail!("native backend cannot execute program kind {other:?}"),
        }
        Ok(())
    }
}

impl Session for NativeSession {
    fn spec(&self) -> &ProgramSpec {
        &self.spec
    }

    fn run(&mut self, args: &[Arg<'_>]) -> Result<&[Value]> {
        validate_args(&self.spec, args)?;
        let t0 = match self.tel.as_deref() {
            Some(r) if r.enabled() => Some(std::time::Instant::now()),
            _ => None,
        };
        self.execute(args)?;
        if let (Some(r), Some(t0)) = (self.tel.as_deref(), t0) {
            r.run_latency.observe(t0.elapsed());
        }
        Ok(&self.outs)
    }

    /// The antithetic-pair fast path: both SPSA evals over one scratch set,
    /// no Arg packing, no output materialization.
    fn two_point(
        &mut self,
        x: &[f32],
        z: &[f32],
        lam: f32,
        ids: &[i32],
        targets: &[i32],
        mask: &[f32],
    ) -> Result<(f64, f64)> {
        if self.spec.kind != "two_point" {
            bail!("{}: the two_point fast path needs a two_point session", self.spec.name);
        }
        let meta = &self.model.meta;
        let r = meta.batch * meta.seq_len;
        if x.len() != meta.d_pad || z.len() != meta.d_pad {
            bail!(
                "{}: two_point expects x/z of length {}, got {}/{}",
                self.spec.name,
                meta.d_pad,
                x.len(),
                z.len()
            );
        }
        if ids.len() != r || targets.len() != r || mask.len() != r {
            bail!("{}: two_point batch must have {r} tokens", self.spec.name);
        }
        let tel = self.tel.as_deref().filter(|t| t.enabled());
        let t0 = tel.map(|_| std::time::Instant::now());
        let (lp, lm) = {
            let _t = tel.and_then(|t| t.span("forward", Some(&t.forward)));
            pair_losses(
                &self.model,
                self.fwd.as_mut().expect("two_point session owns forward scratch"),
                x,
                z,
                lam,
                ids,
                targets,
                mask,
            )
        };
        if let (Some(t), Some(t0)) = (tel, t0) {
            t.run_latency.observe(t0.elapsed());
        }
        f32_mut(&mut self.outs[0])[0] = lp;
        f32_mut(&mut self.outs[1])[0] = lm;
        Ok((lp as f64, lm as f64))
    }
}

// ---------------------------------------------------------------------------
// Synthetic quadratic (Fig. 3 / App. C.1)
// ---------------------------------------------------------------------------

/// Delegates to [`crate::objective::NativeQuadratic`] so the program and the
/// composed-mode objective can never drift apart.
struct QuadProgram;

impl ProgramImpl for QuadProgram {
    fn call(&self, spec: &ProgramSpec, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        use crate::objective::{NativeQuadratic, Objective};
        let x = arg_f32s(&args[0], "x")?;
        let mut q = NativeQuadratic::new(x.len());
        match spec.kind.as_str() {
            "loss" => Ok(vec![Value::scalar(q.loss(x)? as f32)]),
            "grad" => {
                let mut g = vec![0f32; x.len()];
                q.grad(x, &mut g);
                Ok(vec![Value::F32(g)])
            }
            other => bail!("quad program kind {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::model::build_preset;
    use crate::runtime::{lit_f32, lit_vec_f32, Runtime};

    fn rt() -> Runtime {
        Runtime::native_with(ParallelPolicy::single())
    }

    /// Geometry big enough that both the GEMM and attention work gates
    /// engage the pool (512 forward rows, 64 (batch, head, query-block)
    /// attention tasks of 32Ki MACs).
    fn thr_preset() -> PresetMeta {
        build_preset("thr", 64, 64, 2, 2, 64, 8)
    }

    fn thr_batch(meta: &PresetMeta) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let r = meta.batch * meta.seq_len;
        let ids: Vec<i32> = (0..r).map(|i| ((i * 7) % 63) as i32).collect();
        let tgt: Vec<i32> = (0..r).map(|i| ((i * 11) % 63) as i32).collect();
        let mut mask = vec![0f32; r];
        for i in 0..meta.batch {
            mask[i * meta.seq_len + (5 * i + 2) % meta.seq_len] = 1.0;
        }
        (ids, tgt, mask)
    }

    /// The retired materialized pair path — `axpy_into` a `d`-sized
    /// scratch the forward then re-reads — kept as the test-only reference
    /// the fused [`ParamView`] pair is pinned against bitwise.
    #[allow(clippy::too_many_arguments)]
    fn pair_losses_materialized(
        model: &NativeModel,
        fwd: &mut FwdScratch,
        params: &[f32],
        z: &[f32],
        lam: f32,
        ids: &[i32],
        tgt: &[i32],
        mask: &[f32],
    ) -> (f32, f32) {
        let (b, s) = (model.meta.batch, model.meta.seq_len);
        let mut xs = vec![0f32; params.len()];
        vecmath::axpy_into(lam, z, params, &mut xs);
        let lp = model.loss_with(&xs, ids, tgt, mask, b, s, fwd);
        vecmath::axpy_into(-lam, z, params, &mut xs);
        let lm = model.loss_with(&xs, ids, tgt, mask, b, s, fwd);
        (lp, lm)
    }

    #[test]
    fn pair_losses_match_materialized_reference() {
        // session-level tentpole pin: the materialization-free pair (the
        // two_point fast path AND the fused step kinds' internal pair)
        // must equal the retired materialized path BITWISE at pool sizes
        // {1, 2, 4}
        let meta = thr_preset();
        let (ids, tgt, mask) = thr_batch(&meta);
        let dims = vec![meta.batch, meta.seq_len];
        let lam = 1e-3f32;
        for threads in [1usize, 2, 4] {
            let be =
                NativeBackend::with_presets_policy(vec![meta.clone()], ParallelPolicy { threads });
            let rt = Runtime::from_backend(Box::new(be));
            let mut init = rt.bind_kind("thr", "init").unwrap();
            let params = lit_vec_f32(&init.run(&[Arg::I32(3)]).unwrap()[0]).unwrap();
            let mut sample = rt.bind_kind("thr", "sample_u").unwrap();
            let z = lit_vec_f32(&sample.run(&[Arg::I32(9)]).unwrap()[0]).unwrap();
            // reference over a private model with the same pool size
            let model = NativeModel::new(meta.clone()).with_threads(threads);
            let mut fwd = model.scratch();
            let (want_lp, want_lm) =
                pair_losses_materialized(&model, &mut fwd, &params, &z, lam, &ids, &tgt, &mask);
            let mut sess = rt.bind_kind("thr", "two_point").unwrap();
            let (lp, lm) = sess.two_point(&params, &z, lam, &ids, &tgt, &mask).unwrap();
            assert_eq!((lp as f32, lm as f32), (want_lp, want_lm), "two_point threads={threads}");

            // mezo_step runs the same pair core on its sampled direction
            let u = lit_vec_f32(&sample.run(&[Arg::I32(21)]).unwrap()[0]).unwrap();
            let (mlp, mlm) =
                pair_losses_materialized(&model, &mut fwd, &params, &u, lam, &ids, &tgt, &mask);
            let mut step = rt.bind_kind("thr", "mezo_step").unwrap();
            let outs = step
                .run(&[
                    Arg::VecF32(&params),
                    Arg::I32(21),
                    Arg::F32(1e-3),
                    Arg::F32(lam),
                    Arg::TensorI32(&ids, dims.clone()),
                    Arg::TensorI32(&tgt, dims.clone()),
                    Arg::TensorF32(&mask, dims.clone()),
                ])
                .unwrap();
            assert_eq!(lit_f32(&outs[1]).unwrap(), mlp, "mezo lp threads={threads}");
            assert_eq!(lit_f32(&outs[2]).unwrap(), mlm, "mezo lm threads={threads}");

            // conmezo_step: reproduce its cone direction, then the same pin
            let m_in = lit_vec_f32(&sample.run(&[Arg::I32(5)]).unwrap()[0]).unwrap();
            let u2 = lit_vec_f32(&sample.run(&[Arg::I32(33)]).unwrap()[0]).unwrap();
            let theta = 1.1f32;
            let mut zc = vec![0f32; meta.d_pad];
            vecmath::cone_direction(&m_in, &u2, theta, meta.d_raw, &mut zc);
            let (clp, clm) =
                pair_losses_materialized(&model, &mut fwd, &params, &zc, lam, &ids, &tgt, &mask);
            let mut cstep = rt.bind_kind("thr", "conmezo_step").unwrap();
            let outs = cstep
                .run(&[
                    Arg::VecF32(&params),
                    Arg::VecF32(&m_in),
                    Arg::I32(33),
                    Arg::F32(theta),
                    Arg::F32(0.9),
                    Arg::F32(1e-3),
                    Arg::F32(lam),
                    Arg::TensorI32(&ids, dims.clone()),
                    Arg::TensorI32(&tgt, dims.clone()),
                    Arg::TensorF32(&mask, dims.clone()),
                ])
                .unwrap();
            assert_eq!(lit_f32(&outs[2]).unwrap(), clp, "conmezo lp threads={threads}");
            assert_eq!(lit_f32(&outs[3]).unwrap(), clm, "conmezo lm threads={threads}");
        }
    }

    #[test]
    fn pair_sessions_own_no_perturbation_buffer() {
        // the removed-xs pin: pair kinds stream x ± λz through ParamViews,
        // so a bound session holds NO perturbed-parameter scratch — the
        // only parameter-sized buffers are the directions step kinds sample
        let meta = thr_preset();
        let sess =
            NativeSession::new(program_spec(&meta, "two_point"), NativeModel::new(meta.clone()));
        assert!(sess.u.is_empty() && sess.z.is_empty(), "two_point owns no param-sized scratch");
        let sess =
            NativeSession::new(program_spec(&meta, "mezo_step"), NativeModel::new(meta.clone()));
        assert_eq!(sess.u.len(), meta.d_pad, "mezo_step holds its sampled direction");
        assert!(sess.z.is_empty());
        let sess =
            NativeSession::new(program_spec(&meta, "conmezo_step"), NativeModel::new(meta.clone()));
        assert_eq!(sess.u.len(), meta.d_pad);
        assert_eq!(sess.z.len(), meta.d_pad, "conmezo_step holds its cone direction");
    }

    #[test]
    fn two_point_packing_is_steady_state_allocation_free() {
        // packing pins: the session's panel buffers size themselves on the
        // FIRST pair (packz lazily) and every later two_point repacks in
        // place — same pointer, same lengths, step after step
        let meta = thr_preset();
        let (ids, tgt, mask) = thr_batch(&meta);
        let mut sess =
            NativeSession::new(program_spec(&meta, "two_point"), NativeModel::new(meta.clone()));
        let params = sess.model.init_flat(3);
        let z = sess.model.sample_u(9);
        sess.two_point(&params, &z, 1e-3, &ids, &tgt, &mask).unwrap();
        let total = sess.model.plan.packed_total;
        let (pw, lw, lz) = sess.fwd.as_ref().unwrap().pack_storage();
        assert_eq!((lw, lz), (total, total), "both panel buffers sized after the first pair");
        for step in 0..3 {
            sess.two_point(&params, &z, 1e-3, &ids, &tgt, &mask).unwrap();
            let (pw2, lw2, lz2) = sess.fwd.as_ref().unwrap().pack_storage();
            assert_eq!(pw, pw2, "packw reallocated at step {step}");
            assert_eq!((lw, lz), (lw2, lz2), "panel buffers grew at step {step}");
        }
    }

    #[test]
    fn two_point_step_bit_identical_across_pool_sizes() {
        // the full antithetic pair — perturb, forward (pooled GEMMs +
        // threaded attention), loss — must be bit-identical at pool sizes
        // {1, 2, 4}. ParallelPolicy is constructed directly so core-count
        // clamping on small CI machines cannot shrink the pool under test.
        let meta = thr_preset();
        let (ids, tgt, mask) = thr_batch(&meta);
        let run_with = |threads: usize| -> (f64, f64) {
            let be =
                NativeBackend::with_presets_policy(vec![meta.clone()], ParallelPolicy { threads });
            let rt = Runtime::from_backend(Box::new(be));
            let mut init = rt.bind_kind("thr", "init").unwrap();
            let params = lit_vec_f32(&init.run(&[Arg::I32(3)]).unwrap()[0]).unwrap();
            let mut sample = rt.bind_kind("thr", "sample_u").unwrap();
            let z = lit_vec_f32(&sample.run(&[Arg::I32(9)]).unwrap()[0]).unwrap();
            let mut sess = rt.bind_kind("thr", "two_point").unwrap();
            sess.two_point(&params, &z, 1e-3, &ids, &tgt, &mask).unwrap()
        };
        let want = run_with(1);
        for t in [2usize, 4] {
            assert_eq!(run_with(t), want, "pool size {t} diverged");
        }
    }

    #[test]
    fn planned_session_reuses_pool_and_output_slots() {
        // the pool-reuse contract: repeated run()/two_point() on a bound
        // session spawns zero OS threads beyond the pool's initial workers
        // and returns results from the SAME output buffers every time.
        // Since the xs slot was removed, a two_point session's only
        // buffers are the forward scratch and these output slots — there
        // is no perturbed-parameter buffer left to realloc or write.
        let meta = thr_preset();
        let (ids, tgt, mask) = thr_batch(&meta);
        let be = NativeBackend::with_presets_policy(vec![meta], ParallelPolicy { threads: 3 });
        let pool = be.pool_handle();
        let rt = Runtime::from_backend(Box::new(be));
        let mut init = rt.bind_kind("thr", "init").unwrap();
        let params = lit_vec_f32(&init.run(&[Arg::I32(4)]).unwrap()[0]).unwrap();
        let mut sample = rt.bind_kind("thr", "sample_u").unwrap();
        let z = lit_vec_f32(&sample.run(&[Arg::I32(5)]).unwrap()[0]).unwrap();
        let mut sess = rt.bind_kind("thr", "two_point").unwrap();
        let first = sess.two_point(&params, &z, 1e-3, &ids, &tgt, &mask).unwrap();
        let p0 = match &sess.run(&[
            Arg::VecF32(&params),
            Arg::VecF32(&z),
            Arg::F32(1e-3),
            Arg::TensorI32(&ids, vec![8, 64]),
            Arg::TensorI32(&tgt, vec![8, 64]),
            Arg::TensorF32(&mask, vec![8, 64]),
        ])
        .unwrap()[0]
        {
            Value::F32(v) => v.as_ptr(),
            _ => panic!("loss_plus must be f32"),
        };
        let spawned = pool.os_threads_spawned();
        assert_eq!(spawned, 2, "a 3-thread policy spawns exactly 2 workers");
        for _ in 0..10 {
            assert_eq!(sess.two_point(&params, &z, 1e-3, &ids, &tgt, &mask).unwrap(), first);
            let outs = sess
                .run(&[
                    Arg::VecF32(&params),
                    Arg::VecF32(&z),
                    Arg::F32(1e-3),
                    Arg::TensorI32(&ids, vec![8, 64]),
                    Arg::TensorI32(&tgt, vec![8, 64]),
                    Arg::TensorF32(&mask, vec![8, 64]),
                ])
                .unwrap();
            match &outs[0] {
                Value::F32(v) => assert_eq!(v.as_ptr(), p0, "output slot must be stable"),
                _ => panic!("loss_plus must be f32"),
            }
        }
        assert_eq!(
            pool.os_threads_spawned(),
            spawned,
            "steady-state run()/two_point() must never spawn threads"
        );
    }

    #[test]
    fn telemetry_registry_is_shared_across_rebinds() {
        // ONE registry per Runtime: the worker pool and every bound session
        // record into the same preallocated instruments, and rebinding a
        // session accumulates instead of resetting
        let meta = thr_preset();
        let (ids, tgt, mask) = thr_batch(&meta);
        let be = NativeBackend::with_presets_policy(vec![meta], ParallelPolicy { threads: 2 });
        let pool = be.pool_handle();
        let rt = Runtime::from_backend(Box::new(be));
        let reg = rt.telemetry().expect("native backend always carries a registry").clone();
        assert!(
            std::sync::Arc::ptr_eq(&reg, &pool.telemetry_arc().unwrap()),
            "pool must share the runtime's registry"
        );

        let mut init = rt.bind_kind("thr", "init").unwrap();
        let params = lit_vec_f32(&init.run(&[Arg::I32(4)]).unwrap()[0]).unwrap();
        let mut sample = rt.bind_kind("thr", "sample_u").unwrap();
        let z = lit_vec_f32(&sample.run(&[Arg::I32(5)]).unwrap()[0]).unwrap();

        let mut s1 = rt.bind_kind("thr", "two_point").unwrap();
        s1.two_point(&params, &z, 1e-3, &ids, &tgt, &mask).unwrap();
        let after_first = reg.run_latency.count();
        assert!(after_first >= 1, "session runs must land in run_latency");
        assert!(reg.gemm.count() > 0, "pooled GEMMs must land in the gemm histogram");
        drop(s1);
        let mut s2 = rt.bind_kind("thr", "two_point").unwrap();
        s2.two_point(&params, &z, 1e-3, &ids, &tgt, &mask).unwrap();
        assert!(
            reg.run_latency.count() > after_first,
            "a rebound session must accumulate into the SAME registry"
        );
    }

    #[test]
    fn steady_state_telemetry_is_allocation_free() {
        // the tentpole's headline contract: with telemetry ENABLED (the
        // default), steady-state two_point() neither spawns threads nor
        // reallocates — output slots are pinned by
        // planned_session_reuses_pool_and_output_slots; here the span ring
        // and pool stay at the same addresses while the instruments
        // demonstrably keep recording
        let meta = thr_preset();
        let (ids, tgt, mask) = thr_batch(&meta);
        let be = NativeBackend::with_presets_policy(vec![meta], ParallelPolicy { threads: 3 });
        let pool = be.pool_handle();
        let rt = Runtime::from_backend(Box::new(be));
        let reg = rt.telemetry().unwrap().clone();
        assert!(reg.enabled(), "telemetry is on by default");

        let mut init = rt.bind_kind("thr", "init").unwrap();
        let params = lit_vec_f32(&init.run(&[Arg::I32(4)]).unwrap()[0]).unwrap();
        let mut sample = rt.bind_kind("thr", "sample_u").unwrap();
        let z = lit_vec_f32(&sample.run(&[Arg::I32(5)]).unwrap()[0]).unwrap();
        let mut sess = rt.bind_kind("thr", "two_point").unwrap();

        // warm-up: the first call settles pool workers and ring entries
        let first = sess.two_point(&params, &z, 1e-3, &ids, &tgt, &mask).unwrap();
        let spawned = pool.os_threads_spawned();
        let ring_ptr = reg.spans.buf_ptr();
        let n0 = reg.run_latency.count();
        for _ in 0..16 {
            assert_eq!(sess.two_point(&params, &z, 1e-3, &ids, &tgt, &mask).unwrap(), first);
        }
        assert_eq!(reg.run_latency.count(), n0 + 16, "every call must be measured");
        assert_eq!(pool.os_threads_spawned(), spawned, "recording must not spawn threads");
        assert_eq!(reg.spans.buf_ptr(), ring_ptr, "span ring must never reallocate");
        assert!(!reg.spans.is_empty() && reg.spans.len() <= reg.spans.capacity());
        assert!(reg.pool_dispatches.get() > 0);
    }

    #[test]
    fn manifest_has_full_native_program_set() {
        let rt = rt();
        for preset in ["nano", "tiny", "small", "medium", "xl"] {
            for kind in NATIVE_KINDS {
                assert!(
                    rt.manifest().program(&format!("{preset}_{kind}")).is_ok(),
                    "{preset}_{kind}"
                );
            }
        }
        assert!(rt.manifest().program("quad_loss").is_ok());
        // loss_pallas is native now (kernel-composition attention twin);
        // only genuinely unknown names yield the named error
        assert!(rt.manifest().program("nano_loss_pallas").is_ok());
        assert!(rt.manifest().program("nano_fo_sgd_step").is_ok());
        let err = rt.manifest().program("nano_flash_loss").unwrap_err().to_string();
        assert!(err.contains("not in this backend's manifest"), "{err}");
    }

    fn nano_batch(meta: &PresetMeta) -> (Vec<i32>, Vec<i32>, Vec<f32>, Vec<usize>) {
        let ids = vec![1i32; meta.batch * meta.seq_len];
        let tgt = vec![4i32; meta.batch * meta.seq_len];
        let mut mask = vec![0f32; meta.batch * meta.seq_len];
        mask[meta.seq_len - 1] = 1.0;
        (ids, tgt, mask, vec![meta.batch, meta.seq_len])
    }

    #[test]
    fn loss_program_signature_and_value() {
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.call(&[Arg::I32(1)]).unwrap()[0]).unwrap();
        assert_eq!(params.len(), meta.d_pad);
        let loss = rt.load_kind("nano", "loss").unwrap();
        let (ids, tgt, mask, dims) = nano_batch(&meta);
        let outs = loss
            .call(&[
                Arg::VecF32(&params),
                Arg::TensorI32(&ids, dims.clone()),
                Arg::TensorI32(&tgt, dims.clone()),
                Arg::TensorF32(&mask, dims),
            ])
            .unwrap();
        let l = lit_f32(&outs[0]).unwrap();
        assert!(l.is_finite() && l > 0.0);
    }

    #[test]
    fn loss_pallas_program_matches_loss() {
        // the kernel-ablation twin: same loss within f32 kernel-schedule
        // tolerance, so the ablation bench runs fully offline
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.call(&[Arg::I32(8)]).unwrap()[0]).unwrap();
        let (ids, tgt, mask, dims) = nano_batch(&meta);
        let call = |kind: &str| {
            let prog = rt.load_kind("nano", kind).unwrap();
            let outs = prog
                .call(&[
                    Arg::VecF32(&params),
                    Arg::TensorI32(&ids, dims.clone()),
                    Arg::TensorI32(&tgt, dims.clone()),
                    Arg::TensorF32(&mask, dims.clone()),
                ])
                .unwrap();
            lit_f32(&outs[0]).unwrap()
        };
        let (l, lp) = (call("loss"), call("loss_pallas"));
        assert!(
            (l - lp).abs() <= 1e-5 * l.abs().max(1.0),
            "pallas twin diverged: {l} vs {lp}"
        );
    }

    #[test]
    fn session_outputs_are_reused_not_regrown() {
        // the workspace-reuse contract: repeated run() returns bit-identical
        // results from the SAME output buffers (no allocation growth)
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let mut init = rt.bind_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.run(&[Arg::I32(1)]).unwrap()[0]).unwrap();
        let mut sess = rt.bind_kind("nano", "loss").unwrap();
        let (ids, tgt, mask, dims) = nano_batch(&meta);
        let args = |d: &Vec<usize>| {
            [
                Arg::VecF32(&params),
                Arg::TensorI32(&ids, d.clone()),
                Arg::TensorI32(&tgt, d.clone()),
                Arg::TensorF32(&mask, d.clone()),
            ]
        };
        let (p1, v1) = match &sess.run(&args(&dims)).unwrap()[0] {
            Value::F32(v) => (v.as_ptr(), v[0]),
            _ => panic!("loss output must be f32"),
        };
        for _ in 0..3 {
            let (p2, v2) = match &sess.run(&args(&dims)).unwrap()[0] {
                Value::F32(v) => (v.as_ptr(), v[0]),
                _ => panic!("loss output must be f32"),
            };
            assert_eq!(v1, v2, "repeated run must replay exactly");
            assert_eq!(p1, p2, "output buffer must be reused, not reallocated");
        }
    }

    #[test]
    fn two_point_fast_path_matches_run() {
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.call(&[Arg::I32(2)]).unwrap()[0]).unwrap();
        let sample = rt.load_kind("nano", "sample_u").unwrap();
        let z = lit_vec_f32(&sample.call(&[Arg::I32(5)]).unwrap()[0]).unwrap();
        let (ids, tgt, mask, dims) = nano_batch(&meta);
        let lam = 1e-3f32;
        let mut sess = rt.bind_kind("nano", "two_point").unwrap();
        let (lp_fast, lm_fast) = sess.two_point(&params, &z, lam, &ids, &tgt, &mask).unwrap();
        let outs = sess
            .run(&[
                Arg::VecF32(&params),
                Arg::VecF32(&z),
                Arg::F32(lam),
                Arg::TensorI32(&ids, dims.clone()),
                Arg::TensorI32(&tgt, dims.clone()),
                Arg::TensorF32(&mask, dims),
            ])
            .unwrap();
        assert_eq!(lp_fast as f32, lit_f32(&outs[0]).unwrap());
        assert_eq!(lm_fast as f32, lit_f32(&outs[1]).unwrap());
        // wrong-kind sessions refuse the fast path with a named error
        let mut loss_sess = rt.bind_kind("nano", "loss").unwrap();
        let err = loss_sess.two_point(&params, &z, lam, &ids, &tgt, &mask).unwrap_err();
        assert!(err.to_string().contains("two_point"), "{err}");
    }

    #[test]
    fn quad_programs_match_native_objective() {
        use crate::objective::{NativeQuadratic, Objective};
        let rt = rt();
        let prog = rt.load("quad_loss").unwrap();
        let grad = rt.load("quad_grad").unwrap();
        let mut q = NativeQuadratic::new(QUAD_DIM);
        let x: Vec<f32> = (0..QUAD_DIM).map(|i| ((i as f32) * 0.01).sin()).collect();
        let l = lit_f32(&prog.call(&[Arg::VecF32(&x)]).unwrap()[0]).unwrap() as f64;
        let want = q.loss(&x).unwrap();
        assert!((l - want).abs() / want.abs().max(1e-9) < 1e-5, "{l} vs {want}");
        let g = lit_vec_f32(&grad.call(&[Arg::VecF32(&x)]).unwrap()[0]).unwrap();
        let mut gw = vec![0f32; QUAD_DIM];
        q.grad(&x, &mut gw);
        assert_eq!(g, gw);
    }

    #[test]
    fn mezo_step_program_updates_along_direction() {
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.call(&[Arg::I32(3)]).unwrap()[0]).unwrap();
        let step = rt.load_kind("nano", "mezo_step").unwrap();
        let sample = rt.load_kind("nano", "sample_u").unwrap();
        let ids = vec![2i32; meta.batch * meta.seq_len];
        let tgt = vec![5i32; meta.batch * meta.seq_len];
        let mut mask = vec![0f32; meta.batch * meta.seq_len];
        for i in 0..meta.batch {
            mask[i * meta.seq_len + 3] = 1.0;
        }
        let dims = vec![meta.batch, meta.seq_len];
        let (seed, eta, lam) = (11i32, 1e-3f32, 1e-3f32);
        let outs = step
            .call(&[
                Arg::VecF32(&params),
                Arg::I32(seed),
                Arg::F32(eta),
                Arg::F32(lam),
                Arg::TensorI32(&ids, dims.clone()),
                Arg::TensorI32(&tgt, dims.clone()),
                Arg::TensorF32(&mask, dims),
            ])
            .unwrap();
        let new = lit_vec_f32(&outs[0]).unwrap();
        let g = lit_f32(&outs[3]).unwrap();
        let z = lit_vec_f32(&sample.call(&[Arg::I32(seed)]).unwrap()[0]).unwrap();
        // x' must equal x - eta g z exactly
        for i in (0..meta.d_pad).step_by(997) {
            let want = params[i] - eta * g * z[i];
            assert_eq!(new[i], want, "coord {i}");
        }
        // pads untouched
        assert!(new[meta.d_raw..].iter().all(|&v| v == 0.0));
    }

    fn fo_batch(meta: &crate::runtime::PresetMeta) -> (Vec<i32>, Vec<i32>, Vec<f32>, Vec<usize>) {
        let ids: Vec<i32> = (0..meta.batch * meta.seq_len).map(|i| (i % 61) as i32).collect();
        let tgt: Vec<i32> = (0..meta.batch * meta.seq_len).map(|i| ((i * 7) % 61) as i32).collect();
        let mut mask = vec![0f32; meta.batch * meta.seq_len];
        for i in 0..meta.batch {
            mask[i * meta.seq_len + (2 * i + 1) % meta.seq_len] = 1.0;
        }
        (ids, tgt, mask, vec![meta.batch, meta.seq_len])
    }

    #[test]
    fn fo_sgd_step_program_descends_and_preserves_pads() {
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.call(&[Arg::I32(5)]).unwrap()[0]).unwrap();
        let (ids, tgt, mask, dims) = fo_batch(&meta);
        let step = rt.load_kind("nano", "fo_sgd_step").unwrap();
        let call = |p: &[f32], eta: f32| {
            step.call(&[
                Arg::VecF32(p),
                Arg::F32(eta),
                Arg::TensorI32(&ids, dims.clone()),
                Arg::TensorI32(&tgt, dims.clone()),
                Arg::TensorF32(&mask, dims.clone()),
            ])
            .unwrap()
        };
        let outs = call(&params, 0.1);
        let p1 = lit_vec_f32(&outs[0]).unwrap();
        let l0 = lit_f32(&outs[1]).unwrap();
        assert!(l0.is_finite() && l0 > 0.0);
        assert!(p1[meta.d_raw..].iter().all(|&v| v == 0.0), "pads must stay zero");
        assert_ne!(p1, params, "gradient step must move the parameters");
        // the next loss on the SAME batch must be lower (plain GD descent)
        let l1 = lit_f32(&call(&p1, 0.1)[1]).unwrap();
        assert!(l1 < l0, "sgd did not descend: {l0} -> {l1}");
        // eta = 0 is the identity on params
        let frozen = lit_vec_f32(&call(&params, 0.0)[0]).unwrap();
        assert_eq!(frozen, params);
    }

    #[test]
    fn fo_adamw_step_program_descends_with_moment_state() {
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let mut params = lit_vec_f32(&init.call(&[Arg::I32(6)]).unwrap()[0]).unwrap();
        let (ids, tgt, mask, dims) = fo_batch(&meta);
        let step = rt.load_kind("nano", "fo_adamw_step").unwrap();
        let mut mu = vec![0f32; meta.d_pad];
        let mut nu = vec![0f32; meta.d_pad];
        let mut losses = Vec::new();
        for t in 1..=8 {
            let outs = step
                .call(&[
                    Arg::VecF32(&params),
                    Arg::VecF32(&mu),
                    Arg::VecF32(&nu),
                    Arg::F32(t as f32),
                    Arg::F32(1e-3),
                    Arg::TensorI32(&ids, dims.clone()),
                    Arg::TensorI32(&tgt, dims.clone()),
                    Arg::TensorF32(&mask, dims.clone()),
                ])
                .unwrap();
            params = lit_vec_f32(&outs[0]).unwrap();
            mu = lit_vec_f32(&outs[1]).unwrap();
            nu = lit_vec_f32(&outs[2]).unwrap();
            losses.push(lit_f32(&outs[3]).unwrap());
        }
        assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");
        assert!(params[meta.d_raw..].iter().all(|&v| v == 0.0));
        assert!(mu[meta.d_raw..].iter().all(|&v| v == 0.0));
        assert!(nu[meta.d_raw..].iter().all(|&v| v == 0.0));
        assert!(nu.iter().all(|&v| v >= 0.0), "second moment must be non-negative");
    }

    #[test]
    fn grad_cos2_program_is_bounded_and_detects_alignment() {
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.call(&[Arg::I32(7)]).unwrap()[0]).unwrap();
        let (ids, tgt, mask, dims) = fo_batch(&meta);
        let prog = rt.load_kind("nano", "grad_cos2").unwrap();
        let cos2_of = |m: &[f32]| {
            let outs = prog
                .call(&[
                    Arg::VecF32(&params),
                    Arg::VecF32(m),
                    Arg::TensorI32(&ids, dims.clone()),
                    Arg::TensorI32(&tgt, dims.clone()),
                    Arg::TensorF32(&mask, dims.clone()),
                ])
                .unwrap();
            (lit_f32(&outs[0]).unwrap(), lit_f32(&outs[1]).unwrap())
        };
        // a random direction is nearly orthogonal to the gradient: cos2 ~ 1/d
        let sample = rt.load_kind("nano", "sample_u").unwrap();
        let u = lit_vec_f32(&sample.call(&[Arg::I32(3)]).unwrap()[0]).unwrap();
        let (c_rand, loss) = cos2_of(&u);
        assert!((0.0..=1.0).contains(&c_rand), "{c_rand}");
        assert!(loss.is_finite() && loss > 0.0);
        assert!(c_rand < 0.05, "random direction should be near-orthogonal: {c_rand}");
        // the gradient itself is perfectly aligned: recover it via fo_sgd
        // with eta = -1 (params' = params + grad)
        let sgd = rt.load_kind("nano", "fo_sgd_step").unwrap();
        let outs = sgd
            .call(&[
                Arg::VecF32(&params),
                Arg::F32(-1.0),
                Arg::TensorI32(&ids, dims.clone()),
                Arg::TensorI32(&tgt, dims.clone()),
                Arg::TensorF32(&mask, dims.clone()),
            ])
            .unwrap();
        let shifted = lit_vec_f32(&outs[0]).unwrap();
        let grad: Vec<f32> = shifted.iter().zip(&params).map(|(a, b)| a - b).collect();
        let (c_self, _) = cos2_of(&grad);
        assert!(c_self > 0.999, "gradient must align with itself: {c_self}");
    }
}
