//! NativeBackend: the manifest's program set executed in pure Rust.
//!
//! Implements `init`, `sample_u`, `loss`, `two_point`, `eval_logits`, the
//! fused `conmezo_step` / `mezo_step` / `mezo_momentum_step` programs, the
//! first-order programs (`fo_sgd_step`, `fo_adamw_step`, `grad_cos2` via
//! the reverse-mode pass in [`crate::runtime::autograd`]) and the
//! `quad_loss`/`quad_grad` synthetic objective for every built-in preset —
//! no Python, no XLA, no artifacts on disk. This is the full PJRT program
//! set except the `loss_pallas` kernel-ablation variant, so pretraining,
//! the FO baselines of Table 1 and the Fig. 6 alignment probe all run
//! offline.
//!
//! Fused-step emulation reuses the exact `vecmath` kernels the composed
//! path uses (`cone_direction`, `zo_update`, `axpy_into`), so fused and
//! composed modes are bit-consistent on this backend — the equivalence the
//! integration tests assert exactly rather than within tolerance.

use std::collections::BTreeMap;

use crate::runtime::autograd;
use crate::runtime::manifest::{Manifest, PresetMeta, ProgramSpec, TensorSpec};
use crate::runtime::model::{builtin_presets, NativeModel, QUAD_DIM};
use crate::runtime::{Arg, Backend, ProgramImpl, Value};
use crate::util::error::{bail, Result};
use crate::vecmath;

/// Program kinds the native backend implements per preset.
pub const NATIVE_KINDS: [&str; 11] = [
    "init",
    "sample_u",
    "loss",
    "two_point",
    "eval_logits",
    "conmezo_step",
    "mezo_step",
    "mezo_momentum_step",
    "fo_sgd_step",
    "fo_adamw_step",
    "grad_cos2",
];

/// AdamW constants of the reference `fo_adamw_step` (python/compile/steps.py).
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;
pub const ADAM_WD: f32 = 0.0;

pub struct NativeBackend {
    manifest: Manifest,
}

impl NativeBackend {
    /// Backend over the built-in presets (nano/tiny/small/medium/xl).
    pub fn new() -> NativeBackend {
        Self::with_presets(builtin_presets())
    }

    /// Backend over an explicit preset list (tests/fixtures use this to run
    /// custom geometries).
    pub fn with_presets(presets: Vec<PresetMeta>) -> NativeBackend {
        let mut programs = BTreeMap::new();
        for (kind, outs) in [("loss", "loss"), ("grad", "grad")] {
            let name = format!("quad_{kind}");
            programs.insert(
                name.clone(),
                ProgramSpec {
                    name,
                    preset: "quad".into(),
                    kind: kind.into(),
                    file: String::new(),
                    inputs: vec![tensor("x", "float32", vec![QUAD_DIM])],
                    outputs: vec![outs.to_string()],
                },
            );
        }
        let mut preset_map = BTreeMap::new();
        for meta in presets {
            for kind in NATIVE_KINDS {
                let spec = program_spec(&meta, kind);
                programs.insert(spec.name.clone(), spec);
            }
            preset_map.insert(meta.name.clone(), meta);
        }
        NativeBackend { manifest: Manifest { programs, presets: preset_map } }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn instantiate(&self, spec: &ProgramSpec) -> Result<Box<dyn ProgramImpl>> {
        if spec.preset == "quad" {
            return Ok(Box::new(QuadProgram));
        }
        let meta = self.manifest.preset(&spec.preset)?.clone();
        Ok(Box::new(NativeProgram { model: NativeModel::new(meta) }))
    }
}

fn tensor(name: &str, dtype: &str, shape: Vec<usize>) -> TensorSpec {
    TensorSpec { name: name.to_string(), dtype: dtype.to_string(), shape }
}

/// Input/output signature per kind — mirrors `python/compile/aot.py`
/// (`_inputs_for` / `_OUTPUTS`) so both backends accept identical calls.
fn program_spec(meta: &PresetMeta, kind: &str) -> ProgramSpec {
    let dp = meta.d_pad;
    let (b, s) = (meta.batch, meta.seq_len);
    let vec = |n: &str| tensor(n, "float32", vec![dp]);
    let scalar = |n: &str| tensor(n, "float32", vec![]);
    let iscalar = |n: &str| tensor(n, "int32", vec![]);
    let batch = || {
        vec3(
            tensor("input_ids", "int32", vec![b, s]),
            tensor("targets", "int32", vec![b, s]),
            tensor("mask", "float32", vec![b, s]),
        )
    };
    let (inputs, outputs): (Vec<TensorSpec>, Vec<&str>) = match kind {
        "init" => (vec![iscalar("seed")], vec!["params"]),
        "sample_u" => (vec![iscalar("seed")], vec!["u"]),
        "loss" => (with(vec![vec("params")], batch()), vec!["loss"]),
        "two_point" => (
            with(vec![vec("params"), vec("z"), scalar("lam")], batch()),
            vec!["loss_plus", "loss_minus"],
        ),
        "eval_logits" => (
            vec![
                vec("params"),
                tensor("input_ids", "int32", vec![b, s]),
                tensor("pos", "int32", vec![b]),
            ],
            vec!["logits"],
        ),
        "conmezo_step" => (
            with(
                vec![
                    vec("params"),
                    vec("m"),
                    iscalar("seed"),
                    scalar("theta"),
                    scalar("beta"),
                    scalar("eta"),
                    scalar("lam"),
                ],
                batch(),
            ),
            vec!["params", "m", "loss_plus", "loss_minus", "proj_grad"],
        ),
        "mezo_step" => (
            with(
                vec![vec("params"), iscalar("seed"), scalar("eta"), scalar("lam")],
                batch(),
            ),
            vec!["params", "loss_plus", "loss_minus", "proj_grad"],
        ),
        "mezo_momentum_step" => (
            with(
                vec![
                    vec("params"),
                    vec("m"),
                    iscalar("seed"),
                    scalar("beta"),
                    scalar("eta"),
                    scalar("lam"),
                ],
                batch(),
            ),
            vec!["params", "m", "loss_plus", "loss_minus", "proj_grad"],
        ),
        "fo_sgd_step" => (
            with(vec![vec("params"), scalar("eta")], batch()),
            vec!["params", "loss"],
        ),
        "fo_adamw_step" => (
            with(
                vec![vec("params"), vec("mu"), vec("nu"), scalar("t"), scalar("eta")],
                batch(),
            ),
            vec!["params", "mu", "nu", "loss"],
        ),
        "grad_cos2" => (
            with(vec![vec("params"), vec("m")], batch()),
            vec!["cos2", "loss"],
        ),
        other => panic!("program_spec: unknown native kind {other:?}"),
    };
    ProgramSpec {
        name: format!("{}_{kind}", meta.name),
        preset: meta.name.clone(),
        kind: kind.to_string(),
        file: String::new(),
        inputs,
        outputs: outputs.into_iter().map(str::to_string).collect(),
    }
}

fn vec3(a: TensorSpec, b: TensorSpec, c: TensorSpec) -> Vec<TensorSpec> {
    vec![a, b, c]
}

fn with(mut head: Vec<TensorSpec>, tail: Vec<TensorSpec>) -> Vec<TensorSpec> {
    head.extend(tail);
    head
}

// ---------------------------------------------------------------------------
// Argument extraction
// ---------------------------------------------------------------------------

fn arg_f32s<'a>(a: &Arg<'a>, what: &str) -> Result<&'a [f32]> {
    match a {
        Arg::VecF32(v) => Ok(v),
        Arg::TensorF32(v, _) => Ok(v),
        _ => bail!("expected f32 tensor for {what}"),
    }
}

fn arg_i32s<'a>(a: &Arg<'a>, what: &str) -> Result<&'a [i32]> {
    match a {
        Arg::TensorI32(v, _) => Ok(v),
        _ => bail!("expected i32 tensor for {what}"),
    }
}

fn arg_f32(a: &Arg<'_>, what: &str) -> Result<f32> {
    match a {
        Arg::F32(v) => Ok(*v),
        _ => bail!("expected f32 scalar for {what}"),
    }
}

fn arg_i32(a: &Arg<'_>, what: &str) -> Result<i32> {
    match a {
        Arg::I32(v) => Ok(*v),
        _ => bail!("expected i32 scalar for {what}"),
    }
}

// ---------------------------------------------------------------------------
// Per-preset program execution
// ---------------------------------------------------------------------------

struct NativeProgram {
    model: NativeModel,
}

impl NativeProgram {
    fn batch<'a>(&self, args: &[Arg<'a>], at: usize) -> Result<(&'a [i32], &'a [i32], &'a [f32])> {
        Ok((
            arg_i32s(&args[at], "input_ids")?,
            arg_i32s(&args[at + 1], "targets")?,
            arg_f32s(&args[at + 2], "mask")?,
        ))
    }

    /// (f(x + lam z), f(x - lam z)) on one batch, reusing one scratch buffer.
    fn two_point_losses(
        &self,
        params: &[f32],
        z: &[f32],
        lam: f32,
        ids: &[i32],
        tgt: &[i32],
        mask: &[f32],
    ) -> (f32, f32) {
        let m = &self.model.meta;
        let (b, s) = (m.batch, m.seq_len);
        let mut xs = vec![0f32; params.len()];
        vecmath::axpy_into(lam, z, params, &mut xs);
        let lp = self.model.loss(&xs, ids, tgt, mask, b, s);
        vecmath::axpy_into(-lam, z, params, &mut xs);
        let lm = self.model.loss(&xs, ids, tgt, mask, b, s);
        (lp, lm)
    }
}

impl ProgramImpl for NativeProgram {
    fn call(&self, spec: &ProgramSpec, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        let meta = &self.model.meta;
        let (b, s) = (meta.batch, meta.seq_len);
        match spec.kind.as_str() {
            "init" => {
                let seed = arg_i32(&args[0], "seed")?;
                Ok(vec![Value::F32(self.model.init_flat(seed))])
            }
            "sample_u" => {
                let seed = arg_i32(&args[0], "seed")?;
                Ok(vec![Value::F32(self.model.sample_u(seed))])
            }
            "loss" => {
                let params = arg_f32s(&args[0], "params")?;
                let (ids, tgt, mask) = self.batch(args, 1)?;
                let l = self.model.loss(params, ids, tgt, mask, b, s);
                Ok(vec![Value::scalar(l)])
            }
            "two_point" => {
                let params = arg_f32s(&args[0], "params")?;
                let z = arg_f32s(&args[1], "z")?;
                let lam = arg_f32(&args[2], "lam")?;
                let (ids, tgt, mask) = self.batch(args, 3)?;
                let (lp, lm) = self.two_point_losses(params, z, lam, ids, tgt, mask);
                Ok(vec![Value::scalar(lp), Value::scalar(lm)])
            }
            "eval_logits" => {
                let params = arg_f32s(&args[0], "params")?;
                let ids = arg_i32s(&args[1], "input_ids")?;
                let pos = arg_i32s(&args[2], "pos")?;
                Ok(vec![Value::F32(self.model.eval_logits(params, ids, pos, b, s))])
            }
            "conmezo_step" => {
                let params = arg_f32s(&args[0], "params")?;
                let m = arg_f32s(&args[1], "m")?;
                let seed = arg_i32(&args[2], "seed")?;
                let theta = arg_f32(&args[3], "theta")?;
                let beta = arg_f32(&args[4], "beta")?;
                let eta = arg_f32(&args[5], "eta")?;
                let lam = arg_f32(&args[6], "lam")?;
                let (ids, tgt, mask) = self.batch(args, 7)?;
                let u = self.model.sample_u(seed);
                let mut z = vec![0f32; meta.d_pad];
                vecmath::cone_direction(m, &u, theta, meta.d_raw, &mut z);
                let (lp, lm) = self.two_point_losses(params, &z, lam, ids, tgt, mask);
                let g = ((lp as f64 - lm as f64) / (2.0 * lam as f64)) as f32;
                let mut x_new = params.to_vec();
                let mut m_new = m.to_vec();
                vecmath::zo_update(&mut x_new, &mut m_new, &z, g, eta, beta);
                Ok(vec![
                    Value::F32(x_new),
                    Value::F32(m_new),
                    Value::scalar(lp),
                    Value::scalar(lm),
                    Value::scalar(g),
                ])
            }
            "mezo_step" => {
                let params = arg_f32s(&args[0], "params")?;
                let seed = arg_i32(&args[1], "seed")?;
                let eta = arg_f32(&args[2], "eta")?;
                let lam = arg_f32(&args[3], "lam")?;
                let (ids, tgt, mask) = self.batch(args, 4)?;
                let z = self.model.sample_u(seed);
                let (lp, lm) = self.two_point_losses(params, &z, lam, ids, tgt, mask);
                let g = ((lp as f64 - lm as f64) / (2.0 * lam as f64)) as f32;
                let mut x_new = vec![0f32; params.len()];
                vecmath::axpy_into(-eta * g, &z, params, &mut x_new);
                Ok(vec![
                    Value::F32(x_new),
                    Value::scalar(lp),
                    Value::scalar(lm),
                    Value::scalar(g),
                ])
            }
            "mezo_momentum_step" => {
                let params = arg_f32s(&args[0], "params")?;
                let m = arg_f32s(&args[1], "m")?;
                let seed = arg_i32(&args[2], "seed")?;
                let beta = arg_f32(&args[3], "beta")?;
                let eta = arg_f32(&args[4], "eta")?;
                let lam = arg_f32(&args[5], "lam")?;
                let (ids, tgt, mask) = self.batch(args, 6)?;
                let z = self.model.sample_u(seed);
                let (lp, lm) = self.two_point_losses(params, &z, lam, ids, tgt, mask);
                let g = ((lp as f64 - lm as f64) / (2.0 * lam as f64)) as f32;
                // m' = beta m + (1-beta) g z ; x' = x - eta m'
                // (same float ops as vecmath::zo_update's momentum pass)
                let cm = (1.0 - beta) * g;
                let mut m_new = vec![0f32; m.len()];
                for i in 0..m.len() {
                    m_new[i] = beta * m[i] + cm * z[i];
                }
                let mut x_new = vec![0f32; params.len()];
                vecmath::axpy_into(-eta, &m_new, params, &mut x_new);
                Ok(vec![
                    Value::F32(x_new),
                    Value::F32(m_new),
                    Value::scalar(lp),
                    Value::scalar(lm),
                    Value::scalar(g),
                ])
            }
            "fo_sgd_step" => {
                let params = arg_f32s(&args[0], "params")?;
                let eta = arg_f32(&args[1], "eta")?;
                let (ids, tgt, mask) = self.batch(args, 2)?;
                let lg = autograd::loss_and_grad(&self.model, params, ids, tgt, mask, b, s);
                let mut x_new = vec![0f32; params.len()];
                vecmath::axpy_into(-eta, &lg.grad, params, &mut x_new);
                Ok(vec![Value::F32(x_new), Value::scalar(lg.loss)])
            }
            "fo_adamw_step" => {
                let params = arg_f32s(&args[0], "params")?;
                let mu = arg_f32s(&args[1], "mu")?;
                let nu = arg_f32s(&args[2], "nu")?;
                let t = arg_f32(&args[3], "t")?;
                let eta = arg_f32(&args[4], "eta")?;
                let (ids, tgt, mask) = self.batch(args, 5)?;
                let lg = autograd::loss_and_grad(&self.model, params, ids, tgt, mask, b, s);
                // AdamW with bias correction, t the 1-based step counter
                // (same float ops as python/compile/steps.py::fo_adamw_step)
                let bc1 = 1.0 - ADAM_B1.powf(t);
                let bc2 = 1.0 - ADAM_B2.powf(t);
                let mut x_new = vec![0f32; params.len()];
                let mut mu_new = vec![0f32; params.len()];
                let mut nu_new = vec![0f32; params.len()];
                for i in 0..params.len() {
                    let g = lg.grad[i];
                    let m1 = ADAM_B1 * mu[i] + (1.0 - ADAM_B1) * g;
                    let v1 = ADAM_B2 * nu[i] + (1.0 - ADAM_B2) * g * g;
                    let step = (m1 / bc1) / ((v1 / bc2).sqrt() + ADAM_EPS) + ADAM_WD * params[i];
                    x_new[i] = params[i] - eta * step;
                    mu_new[i] = m1;
                    nu_new[i] = v1;
                }
                Ok(vec![
                    Value::F32(x_new),
                    Value::F32(mu_new),
                    Value::F32(nu_new),
                    Value::scalar(lg.loss),
                ])
            }
            "grad_cos2" => {
                let params = arg_f32s(&args[0], "params")?;
                let m = arg_f32s(&args[1], "m")?;
                let (ids, tgt, mask) = self.batch(args, 2)?;
                let lg = autograd::loss_and_grad(&self.model, params, ids, tgt, mask, b, s);
                Ok(vec![
                    Value::scalar(vecmath::cos2(m, &lg.grad) as f32),
                    Value::scalar(lg.loss),
                ])
            }
            other => bail!("native backend cannot execute program kind {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Synthetic quadratic (Fig. 3 / App. C.1)
// ---------------------------------------------------------------------------

/// Delegates to [`crate::objective::NativeQuadratic`] so the program and the
/// composed-mode objective can never drift apart.
struct QuadProgram;

impl ProgramImpl for QuadProgram {
    fn call(&self, spec: &ProgramSpec, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        use crate::objective::{NativeQuadratic, Objective};
        let x = arg_f32s(&args[0], "x")?;
        let mut q = NativeQuadratic::new(x.len());
        match spec.kind.as_str() {
            "loss" => Ok(vec![Value::scalar(q.loss(x)? as f32)]),
            "grad" => {
                let mut g = vec![0f32; x.len()];
                q.grad(x, &mut g);
                Ok(vec![Value::F32(g)])
            }
            other => bail!("quad program kind {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{lit_f32, lit_vec_f32, Runtime};

    fn rt() -> Runtime {
        Runtime::native()
    }

    #[test]
    fn manifest_has_full_native_program_set() {
        let rt = rt();
        for preset in ["nano", "tiny", "small", "medium", "xl"] {
            for kind in NATIVE_KINDS {
                assert!(
                    rt.manifest().program(&format!("{preset}_{kind}")).is_ok(),
                    "{preset}_{kind}"
                );
            }
        }
        assert!(rt.manifest().program("quad_loss").is_ok());
        // the first-order programs are native now (reverse-mode autograd);
        // only genuinely unknown names yield the named error
        assert!(rt.manifest().program("nano_fo_sgd_step").is_ok());
        assert!(rt.manifest().program("nano_grad_cos2").is_ok());
        let err = rt.manifest().program("nano_loss_pallas").unwrap_err().to_string();
        assert!(err.contains("not in this backend's manifest"), "{err}");
    }

    #[test]
    fn loss_program_signature_and_value() {
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.call(&[Arg::I32(1)]).unwrap()[0]).unwrap();
        assert_eq!(params.len(), meta.d_pad);
        let loss = rt.load_kind("nano", "loss").unwrap();
        let ids = vec![1i32; meta.batch * meta.seq_len];
        let tgt = vec![4i32; meta.batch * meta.seq_len];
        let mut mask = vec![0f32; meta.batch * meta.seq_len];
        mask[meta.seq_len - 1] = 1.0;
        let dims = vec![meta.batch, meta.seq_len];
        let outs = loss
            .call(&[
                Arg::VecF32(&params),
                Arg::TensorI32(&ids, dims.clone()),
                Arg::TensorI32(&tgt, dims.clone()),
                Arg::TensorF32(&mask, dims),
            ])
            .unwrap();
        let l = lit_f32(&outs[0]).unwrap();
        assert!(l.is_finite() && l > 0.0);
    }

    #[test]
    fn quad_programs_match_native_objective() {
        use crate::objective::{NativeQuadratic, Objective};
        let rt = rt();
        let prog = rt.load("quad_loss").unwrap();
        let grad = rt.load("quad_grad").unwrap();
        let mut q = NativeQuadratic::new(QUAD_DIM);
        let x: Vec<f32> = (0..QUAD_DIM).map(|i| ((i as f32) * 0.01).sin()).collect();
        let l = lit_f32(&prog.call(&[Arg::VecF32(&x)]).unwrap()[0]).unwrap() as f64;
        let want = q.loss(&x).unwrap();
        assert!((l - want).abs() / want.abs().max(1e-9) < 1e-5, "{l} vs {want}");
        let g = lit_vec_f32(&grad.call(&[Arg::VecF32(&x)]).unwrap()[0]).unwrap();
        let mut gw = vec![0f32; QUAD_DIM];
        q.grad(&x, &mut gw);
        assert_eq!(g, gw);
    }

    #[test]
    fn mezo_step_program_updates_along_direction() {
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.call(&[Arg::I32(3)]).unwrap()[0]).unwrap();
        let step = rt.load_kind("nano", "mezo_step").unwrap();
        let sample = rt.load_kind("nano", "sample_u").unwrap();
        let ids = vec![2i32; meta.batch * meta.seq_len];
        let tgt = vec![5i32; meta.batch * meta.seq_len];
        let mut mask = vec![0f32; meta.batch * meta.seq_len];
        for i in 0..meta.batch {
            mask[i * meta.seq_len + 3] = 1.0;
        }
        let dims = vec![meta.batch, meta.seq_len];
        let (seed, eta, lam) = (11i32, 1e-3f32, 1e-3f32);
        let outs = step
            .call(&[
                Arg::VecF32(&params),
                Arg::I32(seed),
                Arg::F32(eta),
                Arg::F32(lam),
                Arg::TensorI32(&ids, dims.clone()),
                Arg::TensorI32(&tgt, dims.clone()),
                Arg::TensorF32(&mask, dims),
            ])
            .unwrap();
        let new = lit_vec_f32(&outs[0]).unwrap();
        let g = lit_f32(&outs[3]).unwrap();
        let z = lit_vec_f32(&sample.call(&[Arg::I32(seed)]).unwrap()[0]).unwrap();
        // x' must equal x - eta g z exactly
        for i in (0..meta.d_pad).step_by(997) {
            let want = params[i] - eta * g * z[i];
            assert_eq!(new[i], want, "coord {i}");
        }
        // pads untouched
        assert!(new[meta.d_raw..].iter().all(|&v| v == 0.0));
    }

    fn fo_batch(meta: &crate::runtime::PresetMeta) -> (Vec<i32>, Vec<i32>, Vec<f32>, Vec<usize>) {
        let ids: Vec<i32> = (0..meta.batch * meta.seq_len).map(|i| (i % 61) as i32).collect();
        let tgt: Vec<i32> = (0..meta.batch * meta.seq_len).map(|i| ((i * 7) % 61) as i32).collect();
        let mut mask = vec![0f32; meta.batch * meta.seq_len];
        for i in 0..meta.batch {
            mask[i * meta.seq_len + (2 * i + 1) % meta.seq_len] = 1.0;
        }
        (ids, tgt, mask, vec![meta.batch, meta.seq_len])
    }

    #[test]
    fn fo_sgd_step_program_descends_and_preserves_pads() {
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.call(&[Arg::I32(5)]).unwrap()[0]).unwrap();
        let (ids, tgt, mask, dims) = fo_batch(&meta);
        let step = rt.load_kind("nano", "fo_sgd_step").unwrap();
        let call = |p: &[f32], eta: f32| {
            step.call(&[
                Arg::VecF32(p),
                Arg::F32(eta),
                Arg::TensorI32(&ids, dims.clone()),
                Arg::TensorI32(&tgt, dims.clone()),
                Arg::TensorF32(&mask, dims.clone()),
            ])
            .unwrap()
        };
        let outs = call(&params, 0.1);
        let p1 = lit_vec_f32(&outs[0]).unwrap();
        let l0 = lit_f32(&outs[1]).unwrap();
        assert!(l0.is_finite() && l0 > 0.0);
        assert!(p1[meta.d_raw..].iter().all(|&v| v == 0.0), "pads must stay zero");
        assert_ne!(p1, params, "gradient step must move the parameters");
        // the next loss on the SAME batch must be lower (plain GD descent)
        let l1 = lit_f32(&call(&p1, 0.1)[1]).unwrap();
        assert!(l1 < l0, "sgd did not descend: {l0} -> {l1}");
        // eta = 0 is the identity on params
        let frozen = lit_vec_f32(&call(&params, 0.0)[0]).unwrap();
        assert_eq!(frozen, params);
    }

    #[test]
    fn fo_adamw_step_program_descends_with_moment_state() {
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let mut params = lit_vec_f32(&init.call(&[Arg::I32(6)]).unwrap()[0]).unwrap();
        let (ids, tgt, mask, dims) = fo_batch(&meta);
        let step = rt.load_kind("nano", "fo_adamw_step").unwrap();
        let mut mu = vec![0f32; meta.d_pad];
        let mut nu = vec![0f32; meta.d_pad];
        let mut losses = Vec::new();
        for t in 1..=8 {
            let outs = step
                .call(&[
                    Arg::VecF32(&params),
                    Arg::VecF32(&mu),
                    Arg::VecF32(&nu),
                    Arg::F32(t as f32),
                    Arg::F32(1e-3),
                    Arg::TensorI32(&ids, dims.clone()),
                    Arg::TensorI32(&tgt, dims.clone()),
                    Arg::TensorF32(&mask, dims.clone()),
                ])
                .unwrap();
            params = lit_vec_f32(&outs[0]).unwrap();
            mu = lit_vec_f32(&outs[1]).unwrap();
            nu = lit_vec_f32(&outs[2]).unwrap();
            losses.push(lit_f32(&outs[3]).unwrap());
        }
        assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");
        assert!(params[meta.d_raw..].iter().all(|&v| v == 0.0));
        assert!(mu[meta.d_raw..].iter().all(|&v| v == 0.0));
        assert!(nu[meta.d_raw..].iter().all(|&v| v == 0.0));
        assert!(nu.iter().all(|&v| v >= 0.0), "second moment must be non-negative");
    }

    #[test]
    fn grad_cos2_program_is_bounded_and_detects_alignment() {
        let rt = rt();
        let meta = rt.preset("nano").unwrap().clone();
        let init = rt.load_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.call(&[Arg::I32(7)]).unwrap()[0]).unwrap();
        let (ids, tgt, mask, dims) = fo_batch(&meta);
        let prog = rt.load_kind("nano", "grad_cos2").unwrap();
        let cos2_of = |m: &[f32]| {
            let outs = prog
                .call(&[
                    Arg::VecF32(&params),
                    Arg::VecF32(m),
                    Arg::TensorI32(&ids, dims.clone()),
                    Arg::TensorI32(&tgt, dims.clone()),
                    Arg::TensorF32(&mask, dims.clone()),
                ])
                .unwrap();
            (lit_f32(&outs[0]).unwrap(), lit_f32(&outs[1]).unwrap())
        };
        // a random direction is nearly orthogonal to the gradient: cos2 ~ 1/d
        let sample = rt.load_kind("nano", "sample_u").unwrap();
        let u = lit_vec_f32(&sample.call(&[Arg::I32(3)]).unwrap()[0]).unwrap();
        let (c_rand, loss) = cos2_of(&u);
        assert!((0.0..=1.0).contains(&c_rand), "{c_rand}");
        assert!(loss.is_finite() && loss > 0.0);
        assert!(c_rand < 0.05, "random direction should be near-orthogonal: {c_rand}");
        // the gradient itself is perfectly aligned: recover it via fo_sgd
        // with eta = -1 (params' = params + grad)
        let sgd = rt.load_kind("nano", "fo_sgd_step").unwrap();
        let outs = sgd
            .call(&[
                Arg::VecF32(&params),
                Arg::F32(-1.0),
                Arg::TensorI32(&ids, dims.clone()),
                Arg::TensorI32(&tgt, dims.clone()),
                Arg::TensorF32(&mask, dims.clone()),
            ])
            .unwrap();
        let shifted = lit_vec_f32(&outs[0]).unwrap();
        let grad: Vec<f32> = shifted.iter().zip(&params).map(|(a, b)| a - b).collect();
        let (c_self, _) = cos2_of(&grad);
        assert!(c_self > 0.999, "gradient must align with itself: {c_self}");
    }
}
