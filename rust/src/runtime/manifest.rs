//! Typed view of `artifacts/manifest.json` — the contract between the
//! python compile path and the Rust runtime.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::error::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub name: String,
    pub preset: String,
    pub kind: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct LayoutEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

#[derive(Clone, Debug)]
pub struct PresetMeta {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub d_ff: usize,
    pub d_raw: usize,
    pub d_pad: usize,
    pub layout: Vec<LayoutEntry>,
}

impl PresetMeta {
    /// Parameter count (unpadded) — what the paper calls d.
    pub fn dim(&self) -> usize {
        self.d_raw
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub programs: BTreeMap<String, ProgramSpec>,
    pub presets: BTreeMap<String, PresetMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts` first", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let mut programs = BTreeMap::new();
        for p in v.expect("programs")?.as_arr().ok_or_else(|| anyhow!("programs not array"))? {
            let spec = parse_program(p)?;
            programs.insert(spec.name.clone(), spec);
        }
        let mut presets = BTreeMap::new();
        if let Some(Json::Obj(m)) = v.get("presets") {
            for (name, pj) in m {
                presets.insert(name.clone(), parse_preset(name, pj)?);
            }
        }
        Ok(Manifest { programs, presets })
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs
            .get(name)
            .ok_or_else(|| {
                anyhow!(
                    "program {name:?} not in this backend's manifest (the native backend \
                     serves the full program set including the `loss_pallas` kernel \
                     ablation — check the preset/kind name; on pjrt, re-run `make artifacts`)"
                )
            })
    }

    pub fn preset(&self, name: &str) -> Result<&PresetMeta> {
        self.presets
            .get(name)
            .ok_or_else(|| anyhow!("preset {name:?} not in manifest"))
    }
}

fn parse_program(p: &Json) -> Result<ProgramSpec> {
    let gets = |k: &str| -> Result<String> {
        Ok(p.expect(k)?.as_str().ok_or_else(|| anyhow!("{k} not str"))?.to_string())
    };
    let mut inputs = Vec::new();
    for i in p.expect("inputs")?.as_arr().unwrap_or(&[]) {
        inputs.push(TensorSpec {
            name: i.expect("name")?.as_str().unwrap_or("").to_string(),
            dtype: i.expect("dtype")?.as_str().unwrap_or("").to_string(),
            shape: i
                .expect("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
        });
    }
    let outputs = p
        .expect("outputs")?
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|x| x.as_str().map(str::to_string))
        .collect();
    Ok(ProgramSpec {
        name: gets("name")?,
        preset: gets("preset")?,
        kind: gets("kind")?,
        file: gets("file")?,
        inputs,
        outputs,
    })
}

fn parse_preset(name: &str, p: &Json) -> Result<PresetMeta> {
    let getu = |k: &str| -> Result<usize> {
        p.expect(k)?.as_usize().ok_or_else(|| anyhow!("{k} not usize"))
    };
    let mut layout = Vec::new();
    for ent in p.expect("layout")?.as_arr().unwrap_or(&[]) {
        layout.push(LayoutEntry {
            name: ent.expect("name")?.as_str().unwrap_or("").to_string(),
            shape: ent
                .expect("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            offset: ent.expect("offset")?.as_usize().unwrap_or(0),
        });
    }
    Ok(PresetMeta {
        name: name.to_string(),
        vocab: getu("vocab")?,
        d_model: getu("d_model")?,
        n_layers: getu("n_layers")?,
        n_heads: getu("n_heads")?,
        seq_len: getu("seq_len")?,
        batch: getu("batch")?,
        d_ff: getu("d_ff")?,
        d_raw: getu("d_raw")?,
        d_pad: getu("d_pad")?,
        layout,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "programs": [
        {"name": "nano_loss", "preset": "nano", "kind": "loss", "file": "nano_loss.hlo.txt",
         "inputs": [{"name": "params", "dtype": "float32", "shape": [28672]},
                    {"name": "input_ids", "dtype": "int32", "shape": [4, 16]}],
         "outputs": ["loss"]}
      ],
      "presets": {"nano": {"vocab": 64, "d_model": 32, "n_layers": 2, "n_heads": 2,
        "seq_len": 16, "batch": 4, "d_ff": 128, "d_raw": 28032, "d_pad": 28672,
        "layout": [{"name": "tok_emb", "shape": [64, 32], "offset": 0}]}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let p = m.program("nano_loss").unwrap();
        assert_eq!(p.inputs.len(), 2);
        assert_eq!(p.inputs[0].element_count(), 28672);
        assert_eq!(p.inputs[1].shape, vec![4, 16]);
        assert_eq!(p.outputs, vec!["loss"]);
        let preset = m.preset("nano").unwrap();
        assert_eq!(preset.d_pad, 28672);
        assert_eq!(preset.layout[0].name, "tok_emb");
    }

    #[test]
    fn missing_program_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.program("nope").is_err());
        assert!(m.preset("nope").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.programs.len() >= 10);
            let nano = m.preset("nano").unwrap();
            assert_eq!(nano.d_pad % 1024, 0);
            // every program's file exists
            for p in m.programs.values() {
                assert!(dir.join(&p.file).exists(), "{}", p.file);
            }
        }
    }
}
