//! Per-tenant low-rank adapter sessions over one shared base-weight buffer.
//!
//! The multi-tenant serving arc ([`crate::serve`]) runs N concurrent ZO
//! finetuning jobs on ONE `Runtime`/`WorkerPool`: every tenant reads the
//! SAME read-only base parameters and owns only a tiny adapter vector.
//! [`AdapterPlan`] maps a preset's layout onto that vector (built once per
//! (preset, rank) and shared by every tenant of that shape):
//!
//! * 2-D weights `[rows, cols]` with both dims ≥ rank become factored
//!   [`AdapterSeg::Mat`] segments — the tenant owns `U [rows, rank]` and
//!   `V [cols, rank]`, and the effective weight element is
//!   `base + (U V^T)/sqrt(rank)`, the LoRA parameterization with LOZO's
//!   rank normalization (`optimizer::lozo` uses the same segmentation).
//! * everything else (1-D gains/biases, tensors smaller than the rank)
//!   keeps a dense delta: `base + a`.
//!
//! SPSA perturbs ONLY the adapter coordinates: a direction `z` has
//! `plan.dim()` elements (laid out exactly like the adapter vector), and
//! `f(base, adapter ± λz)` evaluates through
//! [`crate::vecmath::AdapterBinding::perturbed`] with the low-rank product
//! `(U + λZ_u)(V + λZ_v)^T / sqrt(r)` fused in-register into the existing
//! view-taking GEMM/bias/layernorm/embedding kernels — no materialized
//! per-tenant weight copy exists at any point, so per-tenant incremental
//! memory is O(rank·dims) (adapter + optimizer state), not O(d).
//!
//! [`AdapterSession`] is the bound surface: one forward scratch + model
//! plan, reusable across tenants (the serve scheduler runs jobs one
//! quantum at a time, so all tenants of a preset share ONE session and the
//! marginal tenant costs only its adapter vector).

use crate::runtime::model::{FwdScratch, NativeModel};
use crate::runtime::PresetMeta;
use crate::util::rng::{Xoshiro256pp, STREAM_INIT};
use crate::vecmath::{self, AdapterBinding, AdapterSeg, ParamView};

/// A preset's layout mapped onto a flat per-tenant adapter vector (one
/// segment per tensor, offsets ascending — the shape every tenant of a
/// (preset, rank) pair shares).
#[derive(Clone, Debug)]
pub struct AdapterPlan {
    segs: Vec<AdapterSeg>,
    dim: usize,
    rank: usize,
}

impl AdapterPlan {
    /// Segment `meta.layout` at `rank`: 2-D tensors whose dims both reach
    /// `rank` get `U/V` factors, everything else a dense delta (the same
    /// criterion as `optimizer::lozo`'s per-tensor segmentation).
    pub fn new(meta: &PresetMeta, rank: usize) -> AdapterPlan {
        assert!(rank >= 1, "adapter rank must be at least 1");
        let mut segs = Vec::with_capacity(meta.layout.len());
        let mut a_off = 0usize;
        for e in &meta.layout {
            if e.shape.len() == 2 && e.shape[0] >= rank && e.shape[1] >= rank {
                let (rows, cols) = (e.shape[0], e.shape[1]);
                segs.push(AdapterSeg::Mat {
                    off: e.offset,
                    rows,
                    cols,
                    rank,
                    u_off: a_off,
                    v_off: a_off + rows * rank,
                });
                a_off += (rows + cols) * rank;
            } else {
                let len: usize = e.shape.iter().product();
                segs.push(AdapterSeg::Dense { off: e.offset, len, a_off });
                a_off += len;
            }
        }
        debug_assert_eq!(a_off, vecmath::adapter_dim(&segs));
        AdapterPlan { segs, dim: a_off, rank }
    }

    /// The segment list (what [`AdapterBinding`]s resolve against).
    pub fn segs(&self) -> &[AdapterSeg] {
        &self.segs
    }

    /// Tenant-owned parameter count — the dimension the tenant's ZO
    /// optimizer runs in (no padding: every coordinate is live).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The low-rank factor width.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Deterministic adapter init: `U ~ N(0, 0.02)` per segment stream,
    /// `V = 0` (so the initial delta is exactly zero — tenants start at
    /// the shared base — but the ZO gradient still flows through the
    /// `U·Z_v^T` cross term), dense deltas zero.
    pub fn init(&self, seed: i32) -> Vec<f32> {
        let mut x = vec![0f32; self.dim];
        for (idx, seg) in self.segs.iter().enumerate() {
            if let AdapterSeg::Mat { rows, rank, u_off, .. } = seg {
                let mut rng =
                    Xoshiro256pp::derive_stream(seed as u32 as u64, STREAM_INIT, idx as u64);
                for u in &mut x[*u_off..*u_off + rows * rank] {
                    *u = rng.next_normal() as f32 * 0.02;
                }
            }
        }
        x
    }
}

/// A bound adapter-evaluation surface: one model plan + one forward
/// scratch serving every tenant of a (preset, rank) pair. The base buffer
/// is passed per call (it is shared, read-only, and owned by the caller),
/// the adapter/direction vectors are the tenant's own `plan.dim()`-sized
/// state.
pub struct AdapterSession {
    model: NativeModel,
    plan: AdapterPlan,
    ws: FwdScratch,
}

impl AdapterSession {
    /// Bind over an already-pooled model (backends construct these via
    /// [`crate::runtime::Backend::bind_adapter`]).
    pub fn new(model: NativeModel, rank: usize) -> AdapterSession {
        let plan = AdapterPlan::new(&model.meta, rank);
        let ws = model.scratch();
        AdapterSession { model, plan, ws }
    }

    pub fn plan(&self) -> &AdapterPlan {
        &self.plan
    }

    pub fn meta(&self) -> &PresetMeta {
        &self.model.meta
    }

    /// `f(base + delta(adapter))` on one batch — the unperturbed loss.
    #[allow(clippy::too_many_arguments)]
    pub fn loss(
        &mut self,
        base: &[f32],
        adapter: &[f32],
        ids: &[i32],
        targets: &[i32],
        mask: &[f32],
        b: usize,
        s: usize,
    ) -> f32 {
        let bind = AdapterBinding::new(self.plan.segs(), adapter);
        let view = ParamView::adapter(base, &bind);
        self.model.loss_view_with(view, ids, targets, mask, b, s, &mut self.ws)
    }

    /// The antithetic pair `(f(adapter + λz), f(adapter - λz))` with the
    /// perturbation applied in adapter coordinates and fused into the
    /// weight loads — zero parameter-sized writes, bit-identical to
    /// materializing `base + delta(adapter ± λz)` first. The shared base
    /// packs into weight panels ONCE per pair; both ±λ evals then fuse the
    /// tenant's low-rank/dense deltas (which carry the perturbation)
    /// in-register on top of the packed base tiles (`z_packed = false` —
    /// the direction lives in adapter coordinates, not a dense panel).
    #[allow(clippy::too_many_arguments)]
    pub fn two_point(
        &mut self,
        base: &[f32],
        adapter: &[f32],
        z: &[f32],
        lam: f32,
        ids: &[i32],
        targets: &[i32],
        mask: &[f32],
        b: usize,
        s: usize,
    ) -> (f32, f32) {
        self.model.pack_base(base, &mut self.ws);
        let plus = AdapterBinding::perturbed(self.plan.segs(), adapter, z, lam);
        let lp = self.model.loss_view_with_prepacked(
            ParamView::adapter(base, &plus),
            ids,
            targets,
            mask,
            b,
            s,
            &mut self.ws,
            false,
        );
        let minus = AdapterBinding::perturbed(self.plan.segs(), adapter, z, -lam);
        let lm = self.model.loss_view_with_prepacked(
            ParamView::adapter(base, &minus),
            ids,
            targets,
            mask,
            b,
            s,
            &mut self.ws,
            false,
        );
        (lp, lm)
    }

    /// Per-example eval logits (`ids [b, s]`, `pos [b]` -> `out [b, vocab]`)
    /// through the position-masked LM head.
    #[allow(clippy::too_many_arguments)]
    pub fn eval_logits(
        &mut self,
        base: &[f32],
        adapter: &[f32],
        ids: &[i32],
        pos: &[i32],
        b: usize,
        s: usize,
        out: &mut [f32],
    ) {
        let bind = AdapterBinding::new(self.plan.segs(), adapter);
        let view = ParamView::adapter(base, &bind);
        self.model.eval_logits_view_with(view, ids, pos, b, s, &mut self.ws, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::model::build_preset;
    use crate::util::rng::STREAM_DIRECTION;

    fn nano() -> PresetMeta {
        build_preset("nano", 64, 32, 2, 2, 16, 4)
    }

    fn sample_dir(dim: usize, seed: u64, t: u64) -> Vec<f32> {
        let mut z = vec![0f32; dim];
        Xoshiro256pp::derive_stream(seed, STREAM_DIRECTION, t).fill_normal_f32(&mut z);
        z
    }

    #[test]
    fn plan_segments_match_layout_and_lozo_criterion() {
        let meta = nano();
        let plan = AdapterPlan::new(&meta, 4);
        assert_eq!(plan.segs().len(), meta.layout.len());
        let mut dim = 0usize;
        for (seg, e) in plan.segs().iter().zip(&meta.layout) {
            assert_eq!(seg.off(), e.offset);
            assert_eq!(seg.elems(), e.shape.iter().product::<usize>());
            let factored = e.shape.len() == 2 && e.shape[0] >= 4 && e.shape[1] >= 4;
            match seg {
                AdapterSeg::Mat { rows, cols, rank, u_off, v_off, .. } => {
                    assert!(factored, "{} should not be factored", e.name);
                    assert_eq!((*rows, *cols), (e.shape[0], e.shape[1]));
                    assert_eq!(*rank, 4);
                    assert_eq!(*u_off, dim);
                    assert_eq!(*v_off, dim + rows * rank);
                }
                AdapterSeg::Dense { len, a_off, .. } => {
                    assert!(!factored, "{} should be factored", e.name);
                    assert_eq!(*len, e.shape.iter().product::<usize>());
                    assert_eq!(*a_off, dim);
                }
            }
            dim += seg.adapter_elems();
        }
        assert_eq!(plan.dim(), dim);
        // the whole point: tenant state is a small fraction of d
        assert!(plan.dim() * 4 < meta.d_raw, "dim {} vs d_raw {}", plan.dim(), meta.d_raw);
        // a rank larger than every tensor dim degenerates to all-dense
        let huge = AdapterPlan::new(&meta, 1 << 20);
        assert!(huge.segs().iter().all(|s| matches!(s, AdapterSeg::Dense { .. })));
        assert_eq!(huge.dim(), meta.d_raw);
    }

    #[test]
    fn init_is_deterministic_and_delta_starts_at_zero() {
        let meta = nano();
        let plan = AdapterPlan::new(&meta, 4);
        let a = plan.init(7);
        assert_eq!(a, plan.init(7));
        assert_ne!(a, plan.init(8));
        assert!(a.iter().any(|&v| v != 0.0), "U factors must be initialized");
        // V = 0 and dense = 0 => the materialized view IS the base
        let model = NativeModel::new(meta.clone());
        let base = model.init_flat(3);
        let bind = AdapterBinding::new(plan.segs(), &a);
        let mut mat = vec![0f32; meta.d_pad];
        ParamView::adapter(&base, &bind).materialize_into(&mut mat);
        assert_eq!(mat, base, "fresh adapter must leave the base unchanged");
    }

    #[test]
    fn adapter_two_point_matches_materialized_across_pool_sizes() {
        // THE tentpole contract at the session level: evaluating
        // f(base + delta(adapter ± λz)) through the fused adapter view must
        // reproduce materialize-then-forward BITWISE at pool sizes {1,2,4}
        let meta = build_preset("adpt-thr", 64, 64, 2, 2, 64, 8);
        let (b, s) = (meta.batch, meta.seq_len);
        let ids: Vec<i32> = (0..b * s).map(|i| ((i * 5) % 61) as i32).collect();
        let tgt: Vec<i32> = (0..b * s).map(|i| ((i * 11) % 61) as i32).collect();
        let mut mask = vec![0f32; b * s];
        for i in 0..b {
            mask[i * s + s - 1] = 1.0;
        }
        let ref_model = NativeModel::new(meta.clone());
        let base = ref_model.init_flat(21);
        let plan = AdapterPlan::new(&meta, 4);
        let mut adapter = plan.init(5);
        // give V a nonzero value so the low-rank delta actually bites
        Xoshiro256pp::derive_stream(99, STREAM_INIT, 0).fill_normal_f32(&mut adapter);
        for v in adapter.iter_mut() {
            *v *= 0.02;
        }
        let z = sample_dir(plan.dim(), 17, 3);
        let lam = 1e-3f32;
        for t in [1usize, 2, 4] {
            let model = NativeModel::new(meta.clone()).with_threads(t);
            let mut sess = AdapterSession::new(model, 4);
            let (lp, lm) = sess.two_point(&base, &adapter, &z, lam, &ids, &tgt, &mask, b, s);
            let l0 = sess.loss(&base, &adapter, &ids, &tgt, &mask, b, s);
            let check = NativeModel::new(meta.clone()).with_threads(t);
            let mut ws = check.scratch();
            for (want_l, sc) in [(lp, lam), (lm, -lam)] {
                let bind = AdapterBinding::perturbed(plan.segs(), &adapter, &z, sc);
                let mut xs = vec![0f32; meta.d_pad];
                ParamView::adapter(&base, &bind).materialize_into(&mut xs);
                let want = check.loss_with(&xs, &ids, &tgt, &mask, b, s, &mut ws);
                assert_eq!(want_l, want, "adapter two_point diverged (t={t}, sc={sc})");
            }
            let bind = AdapterBinding::new(plan.segs(), &adapter);
            let mut xs = vec![0f32; meta.d_pad];
            ParamView::adapter(&base, &bind).materialize_into(&mut xs);
            let want0 = check.loss_with(&xs, &ids, &tgt, &mask, b, s, &mut ws);
            assert_eq!(l0, want0, "adapter loss diverged (t={t})");
        }
    }

    #[test]
    fn adapter_eval_logits_matches_materialized_full_path() {
        let meta = nano();
        let (b, s) = (meta.batch, meta.seq_len);
        let model = NativeModel::new(meta.clone());
        let base = model.init_flat(11);
        let plan = AdapterPlan::new(&meta, 4);
        let mut adapter = plan.init(2);
        Xoshiro256pp::derive_stream(42, STREAM_INIT, 1).fill_normal_f32(&mut adapter);
        for v in adapter.iter_mut() {
            *v *= 0.02;
        }
        let ids: Vec<i32> = (0..b * s).map(|i| ((i * 7) % 64) as i32).collect();
        let pos = [1i32, 5, 9, 15];
        let mut sess = AdapterSession::new(NativeModel::new(meta.clone()), 4);
        let mut got = vec![0f32; b * meta.vocab];
        sess.eval_logits(&base, &adapter, &ids, &pos, b, s, &mut got);
        // reference: materialize the delta, run the full-logits forward,
        // gather the requested rows
        let bind = AdapterBinding::new(plan.segs(), &adapter);
        let mut xs = vec![0f32; meta.d_pad];
        ParamView::adapter(&base, &bind).materialize_into(&mut xs);
        let full = model.forward(&xs, &ids, b, s);
        for i in 0..b {
            let p = pos[i] as usize;
            let v = meta.vocab;
            assert_eq!(got[i * v..(i + 1) * v], full[(i * s + p) * v..(i * s + p + 1) * v]);
        }
    }
}
