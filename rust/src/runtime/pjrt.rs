//! PJRT backend (cargo feature `pjrt`): load AOT artifacts
//! (`artifacts/*.hlo.txt`) and execute them on the PJRT CPU client via the
//! external `xla` crate.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Programs are compiled once and cached by
//! the [`Runtime`](crate::runtime::Runtime) façade; after that the binary
//! is self-contained — Python never runs again.
//!
//! This is the only module that touches the `xla` crate; the crate's
//! default build never compiles it. With `--features pjrt` alone it
//! compiles against the [`stub`] below — same API surface, every runtime
//! entry point a named error — so CI can type-check this path with zero
//! dependencies; `--features pjrt,xla` links the real client (see
//! rust/Cargo.toml for how to declare the dependency).

use std::path::{Path, PathBuf};

use crate::runtime::manifest::Manifest;
use crate::runtime::{Arg, Backend, CallSession, ProgramImpl, ProgramSpec, Session, Value};
use crate::util::error::{anyhow, bail, Context, Result};

#[cfg(not(feature = "xla"))]
use stub as xla;

/// Dependency-free stand-in for the `xla` crate's API surface (the subset
/// this module calls). Everything type-checks; constructing a client fails
/// with a named error, so no later entry point is ever reached at runtime.
#[cfg(not(feature = "xla"))]
mod stub {
    use std::fmt;
    use std::path::Path;

    /// Error type standing in for `xla::Error` (converts into the crate
    /// error via the blanket `std::error::Error` impl).
    pub struct XlaStubError;

    impl fmt::Display for XlaStubError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(
                f,
                "built without the `xla` crate: declare the dependency in rust/Cargo.toml \
                 and rebuild with `--features pjrt,xla` (or use the native backend)"
            )
        }
    }

    impl fmt::Debug for XlaStubError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            fmt::Display::fmt(self, f)
        }
    }

    impl std::error::Error for XlaStubError {}

    pub struct Literal;

    impl Literal {
        pub fn scalar<T>(_v: T) -> Literal {
            Literal
        }

        pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
            Literal
        }

        pub fn reshape(self, _dims: &[i64]) -> Result<Literal, XlaStubError> {
            Err(XlaStubError)
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaStubError> {
            Err(XlaStubError)
        }

        pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaStubError> {
            Err(XlaStubError)
        }
    }

    pub struct PjRtClient;

    impl PjRtClient {
        /// The one entry point reached in stub builds: a named error.
        pub fn cpu() -> Result<PjRtClient, XlaStubError> {
            Err(XlaStubError)
        }

        pub fn platform_name(&self) -> String {
            "xla-stub".to_string()
        }

        pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaStubError> {
            Err(XlaStubError)
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, XlaStubError> {
            Err(XlaStubError)
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaStubError> {
            Err(XlaStubError)
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, XlaStubError> {
            Err(XlaStubError)
        }
    }
}

fn to_literal(a: &Arg<'_>) -> Result<xla::Literal> {
    Ok(match a {
        Arg::F32(v) => xla::Literal::scalar(*v),
        Arg::I32(v) => xla::Literal::scalar(*v),
        Arg::VecF32(v) => xla::Literal::vec1(v),
        Arg::TensorI32(v, dims) => {
            let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
            xla::Literal::vec1(v).reshape(&d)?
        }
        Arg::TensorF32(v, dims) => {
            let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
            xla::Literal::vec1(v).reshape(&d)?
        }
    })
}

fn to_value(l: &xla::Literal) -> Result<Value> {
    Ok(Value::F32(l.to_vec::<f32>()?))
}

/// The PJRT backend: client + artifact directory + manifest.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
}

impl PjrtBackend {
    /// Open the artifact directory (compiles nothing yet).
    pub fn open(dir: impl AsRef<Path>) -> Result<PjrtBackend> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtBackend { client, dir, manifest })
    }

    /// Default artifact location relative to the repo root.
    pub fn open_default() -> Result<PjrtBackend> {
        let candidates = ["artifacts", "../artifacts", "../../artifacts"];
        for c in candidates {
            if Path::new(c).join("manifest.json").exists() {
                return Self::open(c);
            }
        }
        // fall back to CARGO_MANIFEST_DIR for tests
        let from_env = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if from_env.join("manifest.json").exists() {
            return Self::open(from_env);
        }
        bail!("artifacts/manifest.json not found; run `make artifacts`")
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn bind(&self, spec: &ProgramSpec) -> Result<Box<dyn Session>> {
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;
        // PJRT buffers stay device-managed, so the per-call adapter is the
        // session here; workspace reuse is XLA's job on this backend
        Ok(Box::new(CallSession::new(spec.clone(), Box::new(PjrtProgram { exe }))))
    }
}

struct PjrtProgram {
    exe: xla::PjRtLoadedExecutable,
}

impl ProgramImpl for PjrtProgram {
    fn call(&self, spec: &ProgramSpec, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        let mut lits = Vec::with_capacity(args.len());
        for a in args {
            lits.push(to_literal(a)?);
        }
        let bufs = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", spec.name))?;
        // return_tuple=True => one tuple-shaped output buffer
        let tuple = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching outputs of {}", spec.name))?;
        let outs = tuple.to_tuple()?;
        let mut values = Vec::with_capacity(outs.len());
        for o in &outs {
            values.push(to_value(o)?);
        }
        Ok(values)
    }
}
