//! PJRT backend (cargo feature `pjrt`): load AOT artifacts
//! (`artifacts/*.hlo.txt`) and execute them on the PJRT CPU client via the
//! external `xla` crate.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Programs are compiled once and cached by
//! the [`Runtime`](crate::runtime::Runtime) façade; after that the binary
//! is self-contained — Python never runs again.
//!
//! This is the only module that touches the `xla` crate; the crate's
//! default build never compiles it (see rust/Cargo.toml for how to enable).

use std::path::{Path, PathBuf};

use crate::runtime::manifest::Manifest;
use crate::runtime::{Arg, Backend, ProgramImpl, ProgramSpec, Value};
use crate::util::error::{anyhow, bail, Context, Result};

fn to_literal(a: &Arg<'_>) -> Result<xla::Literal> {
    Ok(match a {
        Arg::F32(v) => xla::Literal::scalar(*v),
        Arg::I32(v) => xla::Literal::scalar(*v),
        Arg::VecF32(v) => xla::Literal::vec1(v),
        Arg::TensorI32(v, dims) => {
            let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
            xla::Literal::vec1(v).reshape(&d)?
        }
        Arg::TensorF32(v, dims) => {
            let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
            xla::Literal::vec1(v).reshape(&d)?
        }
    })
}

fn to_value(l: &xla::Literal) -> Result<Value> {
    Ok(Value::F32(l.to_vec::<f32>()?))
}

/// The PJRT backend: client + artifact directory + manifest.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
}

impl PjrtBackend {
    /// Open the artifact directory (compiles nothing yet).
    pub fn open(dir: impl AsRef<Path>) -> Result<PjrtBackend> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtBackend { client, dir, manifest })
    }

    /// Default artifact location relative to the repo root.
    pub fn open_default() -> Result<PjrtBackend> {
        let candidates = ["artifacts", "../artifacts", "../../artifacts"];
        for c in candidates {
            if Path::new(c).join("manifest.json").exists() {
                return Self::open(c);
            }
        }
        // fall back to CARGO_MANIFEST_DIR for tests
        let from_env = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if from_env.join("manifest.json").exists() {
            return Self::open(from_env);
        }
        bail!("artifacts/manifest.json not found; run `make artifacts`")
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn instantiate(&self, spec: &ProgramSpec) -> Result<Box<dyn ProgramImpl>> {
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;
        Ok(Box::new(PjrtProgram { exe }))
    }
}

struct PjrtProgram {
    exe: xla::PjRtLoadedExecutable,
}

impl ProgramImpl for PjrtProgram {
    fn call(&self, spec: &ProgramSpec, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        let mut lits = Vec::with_capacity(args.len());
        for a in args {
            lits.push(to_literal(a)?);
        }
        let bufs = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", spec.name))?;
        // return_tuple=True => one tuple-shaped output buffer
        let tuple = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching outputs of {}", spec.name))?;
        let outs = tuple.to_tuple()?;
        let mut values = Vec::with_capacity(outs.len());
        for o in &outs {
            values.push(to_value(o)?);
        }
        Ok(values)
    }
}
