//! Evaluation metrics: accuracy and macro-F1 over candidate-restricted
//! argmax predictions (the paper reports accuracy for classification tasks
//! and F1 for QA; our KeyValue tasks use exact-match which equals F1 for
//! single-token answers).

/// Restricted argmax: the candidate token with the highest logit.
pub fn predict(logits: &[f32], candidates: &[i32]) -> i32 {
    let mut best = candidates[0];
    let mut best_v = f32::NEG_INFINITY;
    for &c in candidates {
        let v = logits[c as usize];
        if v > best_v {
            best_v = v;
            best = c;
        }
    }
    best
}

#[derive(Clone, Debug, Default)]
pub struct EvalResult {
    pub correct: usize,
    pub total: usize,
    pub macro_f1: f64,
}

impl EvalResult {
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        self.correct as f64 / self.total as f64
    }
}

/// Compute accuracy + macro-F1 from (gold, predicted) pairs.
pub fn score(pairs: &[(i32, i32)]) -> EvalResult {
    let correct = pairs.iter().filter(|(g, p)| g == p).count();
    // macro-F1 over the set of gold classes
    let mut classes: Vec<i32> = pairs.iter().map(|(g, _)| *g).collect();
    classes.sort_unstable();
    classes.dedup();
    let mut f1_sum = 0f64;
    for &c in &classes {
        let tp = pairs.iter().filter(|(g, p)| *g == c && *p == c).count() as f64;
        let fp = pairs.iter().filter(|(g, p)| *g != c && *p == c).count() as f64;
        let fnn = pairs.iter().filter(|(g, p)| *g == c && *p != c).count() as f64;
        let prec = if tp + fp > 0.0 { tp / (tp + fp) } else { 0.0 };
        let rec = if tp + fnn > 0.0 { tp / (tp + fnn) } else { 0.0 };
        f1_sum += if prec + rec > 0.0 { 2.0 * prec * rec / (prec + rec) } else { 0.0 };
    }
    EvalResult {
        correct,
        total: pairs.len(),
        macro_f1: if classes.is_empty() { f64::NAN } else { f1_sum / classes.len() as f64 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_restricts_to_candidates() {
        let mut logits = vec![0f32; 10];
        logits[0] = 100.0; // not a candidate
        logits[4] = 1.0;
        logits[5] = 2.0;
        assert_eq!(predict(&logits, &[4, 5]), 5);
    }

    #[test]
    fn perfect_predictions() {
        let pairs: Vec<(i32, i32)> = (0..10).map(|i| (i % 3, i % 3)).collect();
        let r = score(&pairs);
        assert_eq!(r.accuracy(), 1.0);
        assert!((r.macro_f1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn chance_level_binary() {
        // alternating predictions against constant gold: accuracy 0.5,
        // macro-f1 well below 1
        let pairs: Vec<(i32, i32)> = (0..100).map(|i| (4, if i % 2 == 0 { 4 } else { 5 })).collect();
        let r = score(&pairs);
        assert!((r.accuracy() - 0.5).abs() < 1e-12);
        assert!(r.macro_f1 < 0.7);
    }

    #[test]
    fn macro_f1_penalizes_minority_misses() {
        // 90 of class A all correct; 10 of class B all predicted A
        let mut pairs = vec![(0, 0); 90];
        pairs.extend(vec![(1, 0); 10]);
        let r = score(&pairs);
        assert!((r.accuracy() - 0.9).abs() < 1e-12);
        // class B f1 = 0 -> macro ~ 0.47
        assert!(r.macro_f1 < 0.6);
    }
}
