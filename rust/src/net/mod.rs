//! Distributed transport: length-prefixed binary protocol over TCP.
//!
//! The shared-randomness property of ZO makes distributed finetuning
//! communication-trivial: the leader broadcasts (step, seed, hypers) —
//! O(1) bytes — each worker evaluates the two-point losses on its own data
//! shard with the locally regenerated direction, returns two f64 scalars,
//! and applies the identical update after the leader broadcasts the
//! aggregated projected gradient. Bytes per step are independent of d
//! (~90 B/step/worker vs 4·d B for gradient all-reduce — the Zelikman et
//! al. 2023 observation, cited in the paper's related work).
//!
//! Protocol v2 adds the fault-tolerance surface (see
//! `coordinator::cluster`): a protocol-version byte in the
//! [`Msg::Hello`]/[`Msg::Welcome`] handshake, seed-log replay for worker
//! rejoin ([`Msg::Replay`]/[`Msg::Ready`]), a parameter-divergence
//! tripwire ([`Msg::HashCheck`]/[`Msg::HashReport`]) and liveness
//! [`Msg::Heartbeat`]s during long local evals.
//!
//! Frame: `u32 payload_len | u8 tag | payload` (little-endian). The
//! steady-state per-step frames are `Step` = 37 B, `Proj` = 33 B and
//! `Apply` = 21 B on the wire (5-byte header + payload); see the README
//! wire-format table.
//!
//! Three [`Transport`] implementations:
//! * [`TcpTransport`] — framing over a TCP stream with an internal reassembly
//!   buffer, so [`Transport::recv_timeout`] can give up mid-frame without
//!   corrupting the stream, plus configurable read/write timeouts;
//! * [`ChannelTransport`] — an in-process mpsc pair for deterministic tests;
//! * [`FaultTransport`] — a scripted fault injector (delay/kill at the nth
//!   send/recv) wrapping any transport, used to pin every recovery path.

use std::io::Read;
use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::checkpoint::{StepRecord, STEP_RECORD_BYTES};
use crate::util::error::{bail, Result};

/// Wire-protocol version; carried in the `Hello`/`Welcome` handshake so a
/// mismatched leader/worker pair fails with a clear error instead of a
/// garbled decode.
pub const PROTO_VERSION: u8 = 2;

/// Hard cap on a single frame's payload (decode-side DoS guard).
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Max `StepRecord`s per `Replay` frame (keeps frames well under
/// [`MAX_FRAME_BYTES`]; a rejoin across T steps ships ceil(T/chunk) frames).
pub const REPLAY_CHUNK: usize = 4096;

/// Protocol messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// worker -> leader on (re)connect; `t` is the worker's completed-step
    /// count (0 for a fresh start, >0 when warm-started from a checkpoint)
    Hello { proto: u8, worker_id: u32, t: u64 },
    /// leader -> worker after registration; `t` is the leader's current
    /// step (a rejoining worker must catch up to it via `Replay`) and
    /// `params_hash` the consensus parameter hash AT step `t` when known
    /// (0 = unknown; only filled when the last tripwire ran at exactly `t`)
    Welcome { proto: u8, n_workers: u32, run_seed: u64, t: u64, params_hash: u64 },
    /// leader -> workers: compute the two-point projection for step t
    Step { t: u64, seed: u64, theta: f32, beta: f32, eta: f32, lam: f32 },
    /// worker -> leader: the two scalar losses on the local shard
    Proj { t: u64, worker_id: u32, loss_plus: f64, loss_minus: f64 },
    /// leader -> workers: aggregated projected gradient; apply the update
    Apply { t: u64, g: f64 },
    /// leader -> workers: run local evaluation
    Eval { t: u64 },
    /// worker -> leader
    EvalResult { t: u64, worker_id: u32, correct: u64, total: u64 },
    /// leader -> workers: clean shutdown
    Shutdown,
    /// leader -> rejoining worker: logged step records `from_t..from_t+n`
    /// for seed replay (O(1) bytes per step)
    Replay { from_t: u64, records: Vec<StepRecord> },
    /// worker -> leader: caught up to step `t` with the given params hash
    Ready { t: u64, worker_id: u32, params_hash: u64 },
    /// leader -> workers: report your parameter hash (divergence tripwire)
    HashCheck { t: u64 },
    /// worker -> leader
    HashReport { t: u64, worker_id: u32, hash: u64 },
    /// worker -> leader: still alive (sent around long local evals so the
    /// leader's timeout does not misread a slow eval as a dead worker)
    Heartbeat { t: u64 },
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::Welcome { .. } => 2,
            Msg::Step { .. } => 3,
            Msg::Proj { .. } => 4,
            Msg::Apply { .. } => 5,
            Msg::Eval { .. } => 6,
            Msg::EvalResult { .. } => 7,
            Msg::Shutdown => 8,
            Msg::Replay { .. } => 9,
            Msg::Ready { .. } => 10,
            Msg::HashCheck { .. } => 11,
            Msg::HashReport { .. } => 12,
            Msg::Heartbeat { .. } => 13,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(64);
        match self {
            Msg::Hello { proto, worker_id, t } => {
                p.push(*proto);
                p.extend(worker_id.to_le_bytes());
                p.extend(t.to_le_bytes());
            }
            Msg::Welcome { proto, n_workers, run_seed, t, params_hash } => {
                p.push(*proto);
                p.extend(n_workers.to_le_bytes());
                p.extend(run_seed.to_le_bytes());
                p.extend(t.to_le_bytes());
                p.extend(params_hash.to_le_bytes());
            }
            Msg::Step { t, seed, theta, beta, eta, lam } => {
                p.extend(t.to_le_bytes());
                p.extend(seed.to_le_bytes());
                p.extend(theta.to_le_bytes());
                p.extend(beta.to_le_bytes());
                p.extend(eta.to_le_bytes());
                p.extend(lam.to_le_bytes());
            }
            Msg::Proj { t, worker_id, loss_plus, loss_minus } => {
                p.extend(t.to_le_bytes());
                p.extend(worker_id.to_le_bytes());
                p.extend(loss_plus.to_le_bytes());
                p.extend(loss_minus.to_le_bytes());
            }
            Msg::Apply { t, g } => {
                p.extend(t.to_le_bytes());
                p.extend(g.to_le_bytes());
            }
            Msg::Eval { t } => p.extend(t.to_le_bytes()),
            Msg::EvalResult { t, worker_id, correct, total } => {
                p.extend(t.to_le_bytes());
                p.extend(worker_id.to_le_bytes());
                p.extend(correct.to_le_bytes());
                p.extend(total.to_le_bytes());
            }
            Msg::Shutdown => {}
            Msg::Replay { from_t, records } => {
                p.extend(from_t.to_le_bytes());
                p.extend((records.len() as u32).to_le_bytes());
                for r in records {
                    r.encode_into(&mut p);
                }
            }
            Msg::Ready { t, worker_id, params_hash } => {
                p.extend(t.to_le_bytes());
                p.extend(worker_id.to_le_bytes());
                p.extend(params_hash.to_le_bytes());
            }
            Msg::HashCheck { t } => p.extend(t.to_le_bytes()),
            Msg::HashReport { t, worker_id, hash } => {
                p.extend(t.to_le_bytes());
                p.extend(worker_id.to_le_bytes());
                p.extend(hash.to_le_bytes());
            }
            Msg::Heartbeat { t } => p.extend(t.to_le_bytes()),
        }
        let mut frame = Vec::with_capacity(p.len() + 5);
        frame.extend((p.len() as u32).to_le_bytes());
        frame.push(self.tag());
        frame.extend(p);
        frame
    }

    pub fn decode(tag: u8, p: &[u8]) -> Result<Msg> {
        let mut r = Cursor { b: p, i: 0 };
        Ok(match tag {
            1 => Msg::Hello { proto: r.u8()?, worker_id: r.u32()?, t: r.u64()? },
            2 => Msg::Welcome {
                proto: r.u8()?,
                n_workers: r.u32()?,
                run_seed: r.u64()?,
                t: r.u64()?,
                params_hash: r.u64()?,
            },
            3 => Msg::Step {
                t: r.u64()?,
                seed: r.u64()?,
                theta: r.f32()?,
                beta: r.f32()?,
                eta: r.f32()?,
                lam: r.f32()?,
            },
            4 => Msg::Proj { t: r.u64()?, worker_id: r.u32()?, loss_plus: r.f64()?, loss_minus: r.f64()? },
            5 => Msg::Apply { t: r.u64()?, g: r.f64()? },
            6 => Msg::Eval { t: r.u64()? },
            7 => Msg::EvalResult { t: r.u64()?, worker_id: r.u32()?, correct: r.u64()?, total: r.u64()? },
            8 => Msg::Shutdown,
            9 => {
                let from_t = r.u64()?;
                let count = r.u32()? as usize;
                // validate the claimed count against the actual payload
                // BEFORE allocating: a crafted count must error, not OOM
                let need = count
                    .checked_mul(STEP_RECORD_BYTES)
                    .ok_or_else(|| crate::anyhow!("Replay record count {count} overflows"))?;
                if r.remaining() != need {
                    bail!(
                        "Replay claims {count} records ({need} B) but carries {} B",
                        r.remaining()
                    );
                }
                let mut records = Vec::with_capacity(count);
                for _ in 0..count {
                    records.push(StepRecord::decode(r.take(STEP_RECORD_BYTES)?));
                }
                Msg::Replay { from_t, records }
            }
            10 => Msg::Ready { t: r.u64()?, worker_id: r.u32()?, params_hash: r.u64()? },
            11 => Msg::HashCheck { t: r.u64()? },
            12 => Msg::HashReport { t: r.u64()?, worker_id: r.u32()?, hash: r.u64()? },
            13 => Msg::Heartbeat { t: r.u64()? },
            _ => bail!("unknown message tag {tag}"),
        })
    }

    /// Wire size of this message (for the O(1)-bytes-per-step accounting).
    pub fn wire_bytes(&self) -> usize {
        self.encode().len()
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked_add: a crafted n must produce an error, never a wrapped
        // bounds check that panics out-of-bounds in release mode
        let end = match self.i.checked_add(n) {
            Some(e) if e <= self.b.len() => e,
            _ => bail!("truncated message"),
        };
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// A bidirectional message channel.
pub trait Transport {
    fn send(&mut self, msg: &Msg) -> Result<()>;
    fn recv(&mut self) -> Result<Msg>;

    /// Wait up to `timeout` for a message. `Ok(None)` means no complete
    /// message arrived in time (the peer may merely be slow — a straggler);
    /// `Err` means the connection is dead. The default implementation
    /// blocks (transports without timeout support behave like lockstep).
    fn recv_timeout(&mut self, _timeout: Duration) -> Result<Option<Msg>> {
        self.recv().map(Some)
    }
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

/// TCP framing over a connected stream with an internal reassembly buffer:
/// `recv_timeout` can expire mid-frame and the partial bytes stay buffered,
/// so a later recv picks up exactly where the stream left off (a naive
/// `read_exact` + timeout would corrupt the framing).
pub struct TcpTransport {
    stream: TcpStream,
    rbuf: Vec<u8>,
    read_timeout: Option<Duration>,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true)?;
        // sockets accepted from a non-blocking listener inherit the flag on
        // some platforms; the framing layer manages timeouts itself
        stream.set_nonblocking(false)?;
        Ok(TcpTransport { stream, rbuf: Vec::new(), read_timeout: None })
    }

    pub fn connect(addr: &str) -> Result<Self> {
        Self::new(TcpStream::connect(addr)?)
    }

    /// Connect with retries (worker-side reconnect loop): `attempts`
    /// additional tries spaced by `backoff` after the first failure.
    pub fn connect_retry(addr: &str, attempts: u32, backoff: Duration) -> Result<Self> {
        let mut tries = 0u32;
        loop {
            match Self::connect(addr) {
                Ok(t) => return Ok(t),
                Err(e) => {
                    if tries >= attempts {
                        return Err(e);
                    }
                    tries += 1;
                    std::thread::sleep(backoff);
                }
            }
        }
    }

    /// Configure I/O timeouts: `read` bounds every blocking [`Transport::recv`]
    /// (a peer silent for longer is reported as an error); `write` bounds
    /// sends at the socket level. `None` = block forever (lockstep).
    pub fn set_timeouts(&mut self, read: Option<Duration>, write: Option<Duration>) -> Result<()> {
        self.read_timeout = read;
        self.stream.set_write_timeout(write)?;
        Ok(())
    }

    /// Decode one frame from the reassembly buffer if complete.
    fn try_decode(&mut self) -> Result<Option<Msg>> {
        if self.rbuf.len() < 5 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.rbuf[..4].try_into().unwrap()) as usize;
        if len > MAX_FRAME_BYTES {
            bail!("oversized frame: {len} bytes");
        }
        if self.rbuf.len() < 5 + len {
            return Ok(None);
        }
        let msg = Msg::decode(self.rbuf[4], &self.rbuf[5..5 + len])?;
        self.rbuf.drain(..5 + len);
        Ok(Some(msg))
    }

    /// Pull more bytes into the buffer, waiting at most `wait` (`None` =
    /// block). Returns false on timeout, errors on EOF / socket failure.
    fn fill(&mut self, wait: Option<Duration>) -> Result<bool> {
        self.stream.set_read_timeout(wait)?;
        let mut tmp = [0u8; 4096];
        match self.stream.read(&mut tmp) {
            Ok(0) => bail!("connection closed by peer"),
            Ok(n) => {
                self.rbuf.extend_from_slice(&tmp[..n]);
                Ok(true)
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Ok(false)
            }
            Err(e) => Err(e.into()),
        }
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        self.stream.write_all(&msg.encode())?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Msg> {
        match self.read_timeout {
            Some(d) => match self.recv_timeout(d)? {
                Some(m) => Ok(m),
                None => bail!("recv timed out after {d:?} (peer unresponsive)"),
            },
            None => loop {
                if let Some(msg) = self.try_decode()? {
                    return Ok(msg);
                }
                self.fill(None)?;
            },
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(msg) = self.try_decode()? {
                return Ok(Some(msg));
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            if !self.fill(Some(deadline - now))? {
                return Ok(None);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// In-process channel transport (deterministic tests)
// ---------------------------------------------------------------------------

/// In-memory duplex transport over mpsc channels of encoded frames: real
/// `recv_timeout` semantics without sockets, so cluster fault-handling
/// tests stay deterministic and sandbox-friendly.
pub struct ChannelTransport {
    tx: std::sync::mpsc::Sender<Vec<u8>>,
    rx: std::sync::mpsc::Receiver<Vec<u8>>,
}

/// Create a connected pair of in-memory transports.
pub fn channel_pair() -> (ChannelTransport, ChannelTransport) {
    let (atx, brx) = std::sync::mpsc::channel();
    let (btx, arx) = std::sync::mpsc::channel();
    (ChannelTransport { tx: atx, rx: arx }, ChannelTransport { tx: btx, rx: brx })
}

fn decode_frame(frame: &[u8]) -> Result<Msg> {
    if frame.len() < 5 {
        bail!("short frame: {} bytes", frame.len());
    }
    Msg::decode(frame[4], &frame[5..])
}

impl Transport for ChannelTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        self.tx
            .send(msg.encode())
            .map_err(|_| crate::anyhow!("connection closed by peer"))
    }

    fn recv(&mut self) -> Result<Msg> {
        match self.rx.recv() {
            Ok(frame) => decode_frame(&frame),
            Err(_) => bail!("connection closed by peer"),
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>> {
        use std::sync::mpsc::RecvTimeoutError;
        match self.rx.recv_timeout(timeout) {
            Ok(frame) => decode_frame(&frame).map(Some),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => bail!("connection closed by peer"),
        }
    }
}

// ---------------------------------------------------------------------------
// Scripted fault injection
// ---------------------------------------------------------------------------

/// One scripted fault, keyed by the 0-based index of the send/recv call it
/// fires at (each direction counts its own calls).
#[derive(Clone, Copy, Debug)]
pub enum Fault {
    /// sleep before performing the nth send (straggler simulation: a
    /// delayed `Proj` makes the leader's timeout fire while the message is
    /// still in flight)
    DelaySend { at: u64, by: Duration },
    /// sleep before performing the nth recv
    DelayRecv { at: u64, by: Duration },
    /// fail the nth and all later sends (killed socket)
    KillAtSend { at: u64 },
    /// fail the nth and all later recvs
    KillAtRecv { at: u64 },
}

/// Fault-injection wrapper: applies a script of [`Fault`]s around any
/// transport. Once a kill fires the transport stays dead, like a closed
/// socket. The harness behind the ISSUE-6 recovery-path tests.
pub struct FaultTransport {
    inner: Box<dyn Transport>,
    faults: Vec<Fault>,
    sends: u64,
    recvs: u64,
    dead: bool,
}

impl FaultTransport {
    pub fn new(inner: Box<dyn Transport>, faults: Vec<Fault>) -> Self {
        FaultTransport { inner, faults, sends: 0, recvs: 0, dead: false }
    }

    fn check_send(&mut self) -> Result<()> {
        if self.dead {
            bail!("fault injection: connection killed");
        }
        let n = self.sends;
        self.sends += 1;
        for f in &self.faults {
            match *f {
                Fault::DelaySend { at, by } if at == n => std::thread::sleep(by),
                Fault::KillAtSend { at } if at <= n => {
                    self.dead = true;
                    bail!("fault injection: connection killed at send #{n}");
                }
                _ => {}
            }
        }
        Ok(())
    }

    fn check_recv(&mut self) -> Result<()> {
        if self.dead {
            bail!("fault injection: connection killed");
        }
        let n = self.recvs;
        self.recvs += 1;
        for f in &self.faults {
            match *f {
                Fault::DelayRecv { at, by } if at == n => std::thread::sleep(by),
                Fault::KillAtRecv { at } if at <= n => {
                    self.dead = true;
                    bail!("fault injection: connection killed at recv #{n}");
                }
                _ => {}
            }
        }
        Ok(())
    }
}

impl Transport for FaultTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        self.check_send()?;
        self.inner.send(msg)
    }

    fn recv(&mut self) -> Result<Msg> {
        self.check_recv()?;
        self.inner.recv()
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>> {
        self.check_recv()?;
        self.inner.recv_timeout(timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn roundtrip(m: Msg) {
        let enc = m.encode();
        let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
        assert_eq!(len + 5, enc.len());
        let dec = Msg::decode(enc[4], &enc[5..]).unwrap();
        assert_eq!(dec, m);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Hello { proto: PROTO_VERSION, worker_id: 3, t: 17 });
        roundtrip(Msg::Welcome {
            proto: PROTO_VERSION,
            n_workers: 4,
            run_seed: 0xDEADBEEF,
            t: 9,
            params_hash: 0xABCDEF,
        });
        roundtrip(Msg::Step { t: 17, seed: 42, theta: 1.35, beta: 0.99, eta: 1e-6, lam: 1e-3 });
        roundtrip(Msg::Proj { t: 17, worker_id: 1, loss_plus: 0.5, loss_minus: 0.25 });
        roundtrip(Msg::Apply { t: 17, g: -1.5 });
        roundtrip(Msg::Eval { t: 100 });
        roundtrip(Msg::EvalResult { t: 100, worker_id: 2, correct: 80, total: 100 });
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::Replay {
            from_t: 5,
            records: vec![
                StepRecord { seed: 1, g: -0.25, theta: 1.35, eta: 1e-3, beta: 0.9 },
                StepRecord { seed: 2, g: 0.5, theta: 1.35, eta: 1e-3, beta: 0.99 },
            ],
        });
        roundtrip(Msg::Ready { t: 7, worker_id: 2, params_hash: 0x1234 });
        roundtrip(Msg::HashCheck { t: 50 });
        roundtrip(Msg::HashReport { t: 50, worker_id: 0, hash: 0x5678 });
        roundtrip(Msg::Heartbeat { t: 51 });
    }

    #[test]
    fn step_message_is_o1_bytes() {
        // the whole point: per-step wire traffic independent of d
        let m = Msg::Step { t: 0, seed: 0, theta: 0.0, beta: 0.0, eta: 0.0, lam: 0.0 };
        assert!(m.wire_bytes() < 64, "{}", m.wire_bytes());
        let p = Msg::Proj { t: 0, worker_id: 0, loss_plus: 0.0, loss_minus: 0.0 };
        assert!(p.wire_bytes() < 64);
    }

    #[test]
    fn steady_state_frame_sizes_pinned() {
        // the sizes the leader-side accounting and the README table quote;
        // Proj is 33 B (5-byte len|tag header + 28-byte payload) — the old
        // hardcoded 29 in run_leader undercounted by 4 B per recv
        assert_eq!(Msg::Step { t: 0, seed: 0, theta: 0.0, beta: 0.0, eta: 0.0, lam: 0.0 }.wire_bytes(), 37);
        assert_eq!(Msg::Proj { t: 0, worker_id: 0, loss_plus: 0.0, loss_minus: 0.0 }.wire_bytes(), 33);
        assert_eq!(Msg::Apply { t: 0, g: 0.0 }.wire_bytes(), 21);
        assert_eq!(Msg::Hello { proto: 2, worker_id: 0, t: 0 }.wire_bytes(), 18);
        assert_eq!(
            Msg::Welcome { proto: 2, n_workers: 0, run_seed: 0, t: 0, params_hash: 0 }.wire_bytes(),
            34
        );
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(Msg::decode(99, &[]).is_err());
        assert!(Msg::decode(3, &[0u8; 4]).is_err()); // truncated Step
    }

    #[test]
    fn crafted_replay_count_errors_without_allocating() {
        // payload: from_t + count=u32::MAX but no records — must error
        // cleanly (no OOM, no wrapped-length panic)
        let mut p = Vec::new();
        p.extend(0u64.to_le_bytes());
        p.extend(u32::MAX.to_le_bytes());
        let err = Msg::decode(9, &p).unwrap_err().to_string();
        assert!(err.contains("Replay"), "{err}");
        // count that disagrees with the payload length is also rejected
        let mut p = Vec::new();
        p.extend(0u64.to_le_bytes());
        p.extend(2u32.to_le_bytes());
        p.extend([0u8; STEP_RECORD_BYTES]); // only one record present
        assert!(Msg::decode(9, &p).is_err());
    }

    #[test]
    fn tcp_roundtrip_over_localhost() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(s).unwrap();
            let m = t.recv().unwrap();
            assert_eq!(m, Msg::Hello { proto: PROTO_VERSION, worker_id: 7, t: 0 });
            t.send(&Msg::Welcome {
                proto: PROTO_VERSION,
                n_workers: 1,
                run_seed: 5,
                t: 0,
                params_hash: 0,
            })
            .unwrap();
            let m = t.recv().unwrap();
            assert!(matches!(m, Msg::Proj { worker_id: 7, .. }));
            t.send(&Msg::Shutdown).unwrap();
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        c.send(&Msg::Hello { proto: PROTO_VERSION, worker_id: 7, t: 0 }).unwrap();
        assert_eq!(
            c.recv().unwrap(),
            Msg::Welcome { proto: PROTO_VERSION, n_workers: 1, run_seed: 5, t: 0, params_hash: 0 }
        );
        c.send(&Msg::Proj { t: 0, worker_id: 7, loss_plus: 1.0, loss_minus: 2.0 }).unwrap();
        assert_eq!(c.recv().unwrap(), Msg::Shutdown);
        h.join().unwrap();
    }

    #[test]
    fn tcp_recv_timeout_preserves_partial_frames() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let frame = Msg::Apply { t: 3, g: 1.5 }.encode();
            // dribble the frame: 3 header bytes, pause, then the rest
            s.write_all(&frame[..3]).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(120));
            s.write_all(&frame[3..]).unwrap();
            s.flush().unwrap();
            // hold the socket open until the client is done
            std::thread::sleep(Duration::from_millis(300));
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        // nothing yet: a short timeout must report None, not an error
        assert!(c.recv_timeout(Duration::from_millis(10)).unwrap().is_none());
        // the partial header may arrive during this window; still incomplete
        assert!(c.recv_timeout(Duration::from_millis(30)).unwrap().is_none());
        // once the rest lands the SAME frame decodes — no bytes were lost
        let got = c.recv_timeout(Duration::from_millis(500)).unwrap();
        assert_eq!(got, Some(Msg::Apply { t: 3, g: 1.5 }));
        h.join().unwrap();
    }

    #[test]
    fn channel_pair_roundtrip_and_timeout() {
        let (mut a, mut b) = channel_pair();
        a.send(&Msg::Heartbeat { t: 1 }).unwrap();
        assert_eq!(b.recv().unwrap(), Msg::Heartbeat { t: 1 });
        assert!(b.recv_timeout(Duration::from_millis(5)).unwrap().is_none());
        drop(a);
        assert!(b.recv().is_err()); // disconnected peer is an error
    }

    #[test]
    fn fault_transport_kills_and_stays_dead() {
        let (a, mut b) = channel_pair();
        let mut f = FaultTransport::new(Box::new(a), vec![Fault::KillAtSend { at: 1 }]);
        f.send(&Msg::Heartbeat { t: 0 }).unwrap();
        assert_eq!(b.recv().unwrap(), Msg::Heartbeat { t: 0 });
        assert!(f.send(&Msg::Heartbeat { t: 1 }).is_err());
        assert!(f.send(&Msg::Heartbeat { t: 2 }).is_err()); // still dead
        assert!(f.recv().is_err()); // both directions die together
    }

    #[test]
    fn fault_transport_delays_send() {
        let (a, mut b) = channel_pair();
        let mut f = FaultTransport::new(
            Box::new(a),
            vec![Fault::DelaySend { at: 0, by: Duration::from_millis(60) }],
        );
        let t0 = Instant::now();
        f.send(&Msg::Heartbeat { t: 0 }).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(50));
        assert_eq!(b.recv().unwrap(), Msg::Heartbeat { t: 0 });
    }
}
