//! Distributed transport: length-prefixed binary protocol over TCP.
//!
//! The shared-randomness property of ZO makes distributed finetuning
//! communication-trivial: the leader broadcasts (step, seed, hypers) —
//! O(1) bytes — each worker evaluates the two-point losses on its own data
//! shard with the locally regenerated direction, returns two f64 scalars,
//! and applies the identical update after the leader broadcasts the
//! aggregated projected gradient. Bytes per step are independent of d
//! (~60 B/step/worker vs 4·d B for gradient all-reduce — the Zelikman et
//! al. 2023 observation, cited in the paper's related work).
//!
//! Frame: `u32 payload_len | u8 tag | payload` (little-endian).

use std::io::{Read, Write};
use std::net::TcpStream;

use crate::util::error::{bail, Result};

/// Protocol messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// worker -> leader on connect
    Hello { worker_id: u32 },
    /// leader -> worker after registration
    Welcome { n_workers: u32, run_seed: u64 },
    /// leader -> workers: compute the two-point projection for step t
    Step { t: u64, seed: u64, theta: f32, beta: f32, eta: f32, lam: f32 },
    /// worker -> leader: the two scalar losses on the local shard
    Proj { t: u64, worker_id: u32, loss_plus: f64, loss_minus: f64 },
    /// leader -> workers: aggregated projected gradient; apply the update
    Apply { t: u64, g: f64 },
    /// leader -> workers: run local evaluation
    Eval { t: u64 },
    /// worker -> leader
    EvalResult { t: u64, worker_id: u32, correct: u64, total: u64 },
    /// leader -> workers: clean shutdown
    Shutdown,
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::Welcome { .. } => 2,
            Msg::Step { .. } => 3,
            Msg::Proj { .. } => 4,
            Msg::Apply { .. } => 5,
            Msg::Eval { .. } => 6,
            Msg::EvalResult { .. } => 7,
            Msg::Shutdown => 8,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(64);
        match self {
            Msg::Hello { worker_id } => p.extend(worker_id.to_le_bytes()),
            Msg::Welcome { n_workers, run_seed } => {
                p.extend(n_workers.to_le_bytes());
                p.extend(run_seed.to_le_bytes());
            }
            Msg::Step { t, seed, theta, beta, eta, lam } => {
                p.extend(t.to_le_bytes());
                p.extend(seed.to_le_bytes());
                p.extend(theta.to_le_bytes());
                p.extend(beta.to_le_bytes());
                p.extend(eta.to_le_bytes());
                p.extend(lam.to_le_bytes());
            }
            Msg::Proj { t, worker_id, loss_plus, loss_minus } => {
                p.extend(t.to_le_bytes());
                p.extend(worker_id.to_le_bytes());
                p.extend(loss_plus.to_le_bytes());
                p.extend(loss_minus.to_le_bytes());
            }
            Msg::Apply { t, g } => {
                p.extend(t.to_le_bytes());
                p.extend(g.to_le_bytes());
            }
            Msg::Eval { t } => p.extend(t.to_le_bytes()),
            Msg::EvalResult { t, worker_id, correct, total } => {
                p.extend(t.to_le_bytes());
                p.extend(worker_id.to_le_bytes());
                p.extend(correct.to_le_bytes());
                p.extend(total.to_le_bytes());
            }
            Msg::Shutdown => {}
        }
        let mut frame = Vec::with_capacity(p.len() + 5);
        frame.extend((p.len() as u32).to_le_bytes());
        frame.push(self.tag());
        frame.extend(p);
        frame
    }

    pub fn decode(tag: u8, p: &[u8]) -> Result<Msg> {
        let mut r = Cursor { b: p, i: 0 };
        Ok(match tag {
            1 => Msg::Hello { worker_id: r.u32()? },
            2 => Msg::Welcome { n_workers: r.u32()?, run_seed: r.u64()? },
            3 => Msg::Step {
                t: r.u64()?,
                seed: r.u64()?,
                theta: r.f32()?,
                beta: r.f32()?,
                eta: r.f32()?,
                lam: r.f32()?,
            },
            4 => Msg::Proj { t: r.u64()?, worker_id: r.u32()?, loss_plus: r.f64()?, loss_minus: r.f64()? },
            5 => Msg::Apply { t: r.u64()?, g: r.f64()? },
            6 => Msg::Eval { t: r.u64()? },
            7 => Msg::EvalResult { t: r.u64()?, worker_id: r.u32()?, correct: r.u64()?, total: r.u64()? },
            8 => Msg::Shutdown,
            _ => bail!("unknown message tag {tag}"),
        })
    }

    /// Wire size of this message (for the O(1)-bytes-per-step accounting).
    pub fn wire_bytes(&self) -> usize {
        self.encode().len()
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated message");
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// A bidirectional message channel.
pub trait Transport {
    fn send(&mut self, msg: &Msg) -> Result<()>;
    fn recv(&mut self) -> Result<Msg>;
}

/// TCP framing over a connected stream.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true)?;
        Ok(TcpTransport { stream })
    }

    pub fn connect(addr: &str) -> Result<Self> {
        Self::new(TcpStream::connect(addr)?)
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        self.stream.write_all(&msg.encode())?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Msg> {
        let mut hdr = [0u8; 5];
        self.stream.read_exact(&mut hdr)?;
        let len = u32::from_le_bytes(hdr[..4].try_into().unwrap()) as usize;
        if len > 1 << 20 {
            bail!("oversized frame: {len} bytes");
        }
        let tag = hdr[4];
        let mut payload = vec![0u8; len];
        self.stream.read_exact(&mut payload)?;
        Msg::decode(tag, &payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn roundtrip(m: Msg) {
        let enc = m.encode();
        let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
        assert_eq!(len + 5, enc.len());
        let dec = Msg::decode(enc[4], &enc[5..]).unwrap();
        assert_eq!(dec, m);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Hello { worker_id: 3 });
        roundtrip(Msg::Welcome { n_workers: 4, run_seed: 0xDEADBEEF });
        roundtrip(Msg::Step { t: 17, seed: 42, theta: 1.35, beta: 0.99, eta: 1e-6, lam: 1e-3 });
        roundtrip(Msg::Proj { t: 17, worker_id: 1, loss_plus: 0.5, loss_minus: 0.25 });
        roundtrip(Msg::Apply { t: 17, g: -1.5 });
        roundtrip(Msg::Eval { t: 100 });
        roundtrip(Msg::EvalResult { t: 100, worker_id: 2, correct: 80, total: 100 });
        roundtrip(Msg::Shutdown);
    }

    #[test]
    fn step_message_is_o1_bytes() {
        // the whole point: per-step wire traffic independent of d
        let m = Msg::Step { t: 0, seed: 0, theta: 0.0, beta: 0.0, eta: 0.0, lam: 0.0 };
        assert!(m.wire_bytes() < 64, "{}", m.wire_bytes());
        let p = Msg::Proj { t: 0, worker_id: 0, loss_plus: 0.0, loss_minus: 0.0 };
        assert!(p.wire_bytes() < 64);
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(Msg::decode(99, &[]).is_err());
        assert!(Msg::decode(3, &[0u8; 4]).is_err()); // truncated Step
    }

    #[test]
    fn tcp_roundtrip_over_localhost() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(s).unwrap();
            let m = t.recv().unwrap();
            assert_eq!(m, Msg::Hello { worker_id: 7 });
            t.send(&Msg::Welcome { n_workers: 1, run_seed: 5 }).unwrap();
            let m = t.recv().unwrap();
            assert!(matches!(m, Msg::Proj { worker_id: 7, .. }));
            t.send(&Msg::Shutdown).unwrap();
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        c.send(&Msg::Hello { worker_id: 7 }).unwrap();
        assert_eq!(c.recv().unwrap(), Msg::Welcome { n_workers: 1, run_seed: 5 });
        c.send(&Msg::Proj { t: 0, worker_id: 7, loss_plus: 1.0, loss_minus: 2.0 }).unwrap();
        assert_eq!(c.recv().unwrap(), Msg::Shutdown);
        h.join().unwrap();
    }
}
