//! Distributed transport: length-prefixed binary protocol over TCP.
//!
//! The shared-randomness property of ZO makes distributed finetuning
//! communication-trivial: the leader broadcasts (step, seed, hypers) —
//! O(1) bytes — each worker evaluates the two-point losses on its own data
//! shard with the locally regenerated direction, returns two f64 scalars,
//! and applies the identical update after the leader broadcasts the
//! aggregated projected gradient. Bytes per step are independent of d
//! (~90 B/step/worker vs 4·d B for gradient all-reduce — the Zelikman et
//! al. 2023 observation, cited in the paper's related work).
//!
//! Protocol v2 adds the fault-tolerance surface (see
//! `coordinator::cluster`): a protocol-version byte in the
//! [`Msg::Hello`]/[`Msg::Welcome`] handshake, seed-log replay for worker
//! rejoin ([`Msg::Replay`]/[`Msg::Ready`]), a parameter-divergence
//! tripwire ([`Msg::HashCheck`]/[`Msg::HashReport`]) and liveness
//! [`Msg::Heartbeat`]s during long local evals.
//!
//! Frame: `u32 payload_len | u8 tag | payload` (little-endian). The
//! steady-state per-step frames are `Step` = 37 B, `Proj` = 33 B and
//! `Apply` = 21 B on the wire (5-byte header + payload); see the README
//! wire-format table.
//!
//! Three [`Transport`] implementations:
//! * [`TcpTransport`] — framing over a TCP stream with an internal reassembly
//!   buffer, so [`Transport::recv_timeout`] can give up mid-frame without
//!   corrupting the stream, plus configurable read/write timeouts;
//! * [`ChannelTransport`] — an in-process mpsc pair for deterministic tests;
//! * [`FaultTransport`] — a scripted fault injector (delay/kill/corrupt/
//!   truncate/reorder at the nth send/recv) wrapping any transport, used to
//!   pin every recovery path; [`ChaosPlan`] expands a seed into fault
//!   scripts for whole-cluster chaos runs.
//!
//! Transport failures carry a structured classification
//! ([`TransportErrorKind`]: Timeout / Closed / Corrupt / FaultInjected) as a
//! stable machine token embedded in the error chain, so callers branch on
//! [`TransportErrorKind::classify`] instead of matching prose substrings.

use std::io::Read;
use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::checkpoint::{StepRecord, STEP_RECORD_BYTES};
use crate::util::error::{bail, Error, Result};

/// Wire-protocol version; carried in the `Hello`/`Welcome` handshake so a
/// mismatched leader/worker pair fails with a clear error instead of a
/// garbled decode.
pub const PROTO_VERSION: u8 = 2;

/// Hard cap on a single frame's payload (decode-side DoS guard).
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Max `StepRecord`s per `Replay` frame (keeps frames well under
/// [`MAX_FRAME_BYTES`]; a rejoin across T steps ships ceil(T/chunk) frames).
pub const REPLAY_CHUNK: usize = 4096;

// ---------------------------------------------------------------------------
// Structured transport errors
// ---------------------------------------------------------------------------

/// Why a transport operation failed. The crate's string-backed error type
/// has no downcasting, so each kind embeds a stable machine token (e.g.
/// `[net::timeout]`) into the message it builds; [`classify`] recovers the
/// kind from any error whose chain passed through this layer. Callers that
/// previously matched prose (`msg.contains("fault injection")`) match kinds
/// instead — a loss message that happens to contain those words can no
/// longer change control flow.
///
/// [`classify`]: TransportErrorKind::classify
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportErrorKind {
    /// The peer was silent past the configured deadline (maybe merely slow).
    Timeout,
    /// The connection is gone: EOF, reset, refused, or a socket-level error.
    Closed,
    /// Bytes arrived but do not form a valid frame (bad tag, bad length,
    /// truncated payload, oversized frame).
    Corrupt,
    /// A scripted [`Fault`] fired; only test harnesses produce this.
    FaultInjected,
}

impl TransportErrorKind {
    const ALL: [TransportErrorKind; 4] = [
        TransportErrorKind::Timeout,
        TransportErrorKind::Closed,
        TransportErrorKind::Corrupt,
        TransportErrorKind::FaultInjected,
    ];

    /// The stable token this kind stamps into error messages.
    pub fn token(self) -> &'static str {
        match self {
            TransportErrorKind::Timeout => "[net::timeout]",
            TransportErrorKind::Closed => "[net::closed]",
            TransportErrorKind::Corrupt => "[net::corrupt]",
            TransportErrorKind::FaultInjected => "[net::fault-injected]",
        }
    }

    /// Build a classified transport error: `{token} {detail}`.
    pub fn err(self, detail: impl std::fmt::Display) -> Error {
        crate::anyhow!("{} {detail}", self.token())
    }

    /// Recover the classification from an error whose chain passed through
    /// the transport layer; `None` for errors that never did.
    pub fn classify(e: &Error) -> Option<TransportErrorKind> {
        TransportErrorKind::classify_str(&e.to_string())
    }

    /// Same classification over an already-stringified message (the leader
    /// carries drop reasons as plain strings once the connection is gone).
    pub fn classify_str(msg: &str) -> Option<TransportErrorKind> {
        TransportErrorKind::ALL.into_iter().find(|k| msg.contains(k.token()))
    }
}

impl std::fmt::Display for TransportErrorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            TransportErrorKind::Timeout => "timeout",
            TransportErrorKind::Closed => "closed",
            TransportErrorKind::Corrupt => "corrupt",
            TransportErrorKind::FaultInjected => "fault-injected",
        };
        write!(f, "{s}")
    }
}

/// Protocol messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// worker -> leader on (re)connect; `t` is the worker's completed-step
    /// count (0 for a fresh start, >0 when warm-started from a checkpoint)
    Hello { proto: u8, worker_id: u32, t: u64 },
    /// leader -> worker after registration; `t` is the leader's current
    /// step (a rejoining worker must catch up to it via `Replay`) and
    /// `params_hash` the consensus parameter hash AT step `t` when known
    /// (0 = unknown; only filled when the last tripwire ran at exactly `t`)
    Welcome { proto: u8, n_workers: u32, run_seed: u64, t: u64, params_hash: u64 },
    /// leader -> workers: compute the two-point projection for step t
    Step { t: u64, seed: u64, theta: f32, beta: f32, eta: f32, lam: f32 },
    /// worker -> leader: the two scalar losses on the local shard
    Proj { t: u64, worker_id: u32, loss_plus: f64, loss_minus: f64 },
    /// leader -> workers: aggregated projected gradient; apply the update
    Apply { t: u64, g: f64 },
    /// leader -> workers: run local evaluation
    Eval { t: u64 },
    /// worker -> leader
    EvalResult { t: u64, worker_id: u32, correct: u64, total: u64 },
    /// leader -> workers: clean shutdown
    Shutdown,
    /// leader -> rejoining worker: logged step records `from_t..from_t+n`
    /// for seed replay (O(1) bytes per step)
    Replay { from_t: u64, records: Vec<StepRecord> },
    /// worker -> leader: caught up to step `t` with the given params hash
    Ready { t: u64, worker_id: u32, params_hash: u64 },
    /// leader -> workers: report your parameter hash (divergence tripwire)
    HashCheck { t: u64 },
    /// worker -> leader
    HashReport { t: u64, worker_id: u32, hash: u64 },
    /// worker -> leader: still alive (sent around long local evals so the
    /// leader's timeout does not misread a slow eval as a dead worker)
    Heartbeat { t: u64 },
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::Welcome { .. } => 2,
            Msg::Step { .. } => 3,
            Msg::Proj { .. } => 4,
            Msg::Apply { .. } => 5,
            Msg::Eval { .. } => 6,
            Msg::EvalResult { .. } => 7,
            Msg::Shutdown => 8,
            Msg::Replay { .. } => 9,
            Msg::Ready { .. } => 10,
            Msg::HashCheck { .. } => 11,
            Msg::HashReport { .. } => 12,
            Msg::Heartbeat { .. } => 13,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(64);
        match self {
            Msg::Hello { proto, worker_id, t } => {
                p.push(*proto);
                p.extend(worker_id.to_le_bytes());
                p.extend(t.to_le_bytes());
            }
            Msg::Welcome { proto, n_workers, run_seed, t, params_hash } => {
                p.push(*proto);
                p.extend(n_workers.to_le_bytes());
                p.extend(run_seed.to_le_bytes());
                p.extend(t.to_le_bytes());
                p.extend(params_hash.to_le_bytes());
            }
            Msg::Step { t, seed, theta, beta, eta, lam } => {
                p.extend(t.to_le_bytes());
                p.extend(seed.to_le_bytes());
                p.extend(theta.to_le_bytes());
                p.extend(beta.to_le_bytes());
                p.extend(eta.to_le_bytes());
                p.extend(lam.to_le_bytes());
            }
            Msg::Proj { t, worker_id, loss_plus, loss_minus } => {
                p.extend(t.to_le_bytes());
                p.extend(worker_id.to_le_bytes());
                p.extend(loss_plus.to_le_bytes());
                p.extend(loss_minus.to_le_bytes());
            }
            Msg::Apply { t, g } => {
                p.extend(t.to_le_bytes());
                p.extend(g.to_le_bytes());
            }
            Msg::Eval { t } => p.extend(t.to_le_bytes()),
            Msg::EvalResult { t, worker_id, correct, total } => {
                p.extend(t.to_le_bytes());
                p.extend(worker_id.to_le_bytes());
                p.extend(correct.to_le_bytes());
                p.extend(total.to_le_bytes());
            }
            Msg::Shutdown => {}
            Msg::Replay { from_t, records } => {
                p.extend(from_t.to_le_bytes());
                p.extend((records.len() as u32).to_le_bytes());
                for r in records {
                    r.encode_into(&mut p);
                }
            }
            Msg::Ready { t, worker_id, params_hash } => {
                p.extend(t.to_le_bytes());
                p.extend(worker_id.to_le_bytes());
                p.extend(params_hash.to_le_bytes());
            }
            Msg::HashCheck { t } => p.extend(t.to_le_bytes()),
            Msg::HashReport { t, worker_id, hash } => {
                p.extend(t.to_le_bytes());
                p.extend(worker_id.to_le_bytes());
                p.extend(hash.to_le_bytes());
            }
            Msg::Heartbeat { t } => p.extend(t.to_le_bytes()),
        }
        let mut frame = Vec::with_capacity(p.len() + 5);
        frame.extend((p.len() as u32).to_le_bytes());
        frame.push(self.tag());
        frame.extend(p);
        frame
    }

    pub fn decode(tag: u8, p: &[u8]) -> Result<Msg> {
        let mut r = Cursor { b: p, i: 0 };
        Ok(match tag {
            1 => Msg::Hello { proto: r.u8()?, worker_id: r.u32()?, t: r.u64()? },
            2 => Msg::Welcome {
                proto: r.u8()?,
                n_workers: r.u32()?,
                run_seed: r.u64()?,
                t: r.u64()?,
                params_hash: r.u64()?,
            },
            3 => Msg::Step {
                t: r.u64()?,
                seed: r.u64()?,
                theta: r.f32()?,
                beta: r.f32()?,
                eta: r.f32()?,
                lam: r.f32()?,
            },
            4 => Msg::Proj { t: r.u64()?, worker_id: r.u32()?, loss_plus: r.f64()?, loss_minus: r.f64()? },
            5 => Msg::Apply { t: r.u64()?, g: r.f64()? },
            6 => Msg::Eval { t: r.u64()? },
            7 => Msg::EvalResult { t: r.u64()?, worker_id: r.u32()?, correct: r.u64()?, total: r.u64()? },
            8 => Msg::Shutdown,
            9 => {
                let from_t = r.u64()?;
                let count = r.u32()? as usize;
                // validate the claimed count against the actual payload
                // BEFORE allocating: a crafted count must error, not OOM
                let need = count
                    .checked_mul(STEP_RECORD_BYTES)
                    .ok_or_else(|| crate::anyhow!("Replay record count {count} overflows"))?;
                if r.remaining() != need {
                    bail!(
                        "Replay claims {count} records ({need} B) but carries {} B",
                        r.remaining()
                    );
                }
                let mut records = Vec::with_capacity(count);
                for _ in 0..count {
                    records.push(StepRecord::decode(r.take(STEP_RECORD_BYTES)?));
                }
                Msg::Replay { from_t, records }
            }
            10 => Msg::Ready { t: r.u64()?, worker_id: r.u32()?, params_hash: r.u64()? },
            11 => Msg::HashCheck { t: r.u64()? },
            12 => Msg::HashReport { t: r.u64()?, worker_id: r.u32()?, hash: r.u64()? },
            13 => Msg::Heartbeat { t: r.u64()? },
            _ => bail!("unknown message tag {tag}"),
        })
    }

    /// Wire size of this message (for the O(1)-bytes-per-step accounting).
    pub fn wire_bytes(&self) -> usize {
        self.encode().len()
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked_add: a crafted n must produce an error, never a wrapped
        // bounds check that panics out-of-bounds in release mode
        let end = match self.i.checked_add(n) {
            Some(e) if e <= self.b.len() => e,
            _ => bail!("truncated message"),
        };
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// A bidirectional message channel.
pub trait Transport {
    fn send(&mut self, msg: &Msg) -> Result<()>;
    fn recv(&mut self) -> Result<Msg>;

    /// Wait up to `timeout` for a message. `Ok(None)` means no complete
    /// message arrived in time (the peer may merely be slow — a straggler);
    /// `Err` means the connection is dead. The default implementation
    /// blocks (transports without timeout support behave like lockstep).
    fn recv_timeout(&mut self, _timeout: Duration) -> Result<Option<Msg>> {
        self.recv().map(Some)
    }

    /// Ship pre-encoded (possibly deliberately malformed) frame bytes.
    /// Only the fault injector uses this — it is how `CorruptAtSend` and
    /// `TruncateAtSend` put invalid bytes on a live connection. Transports
    /// that cannot express raw bytes refuse.
    fn send_frame(&mut self, _frame: &[u8]) -> Result<()> {
        bail!("transport does not support raw frames")
    }
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

/// TCP framing over a connected stream with an internal reassembly buffer:
/// `recv_timeout` can expire mid-frame and the partial bytes stay buffered,
/// so a later recv picks up exactly where the stream left off (a naive
/// `read_exact` + timeout would corrupt the framing).
pub struct TcpTransport {
    stream: TcpStream,
    rbuf: Vec<u8>,
    read_timeout: Option<Duration>,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true)?;
        // sockets accepted from a non-blocking listener inherit the flag on
        // some platforms; the framing layer manages timeouts itself
        stream.set_nonblocking(false)?;
        Ok(TcpTransport { stream, rbuf: Vec::new(), read_timeout: None })
    }

    pub fn connect(addr: &str) -> Result<Self> {
        Self::new(TcpStream::connect(addr)?)
    }

    /// Connect with retries (worker-side reconnect loop): up to `attempts`
    /// additional tries after the first failure, spaced by
    /// [`backoff_delay`] — capped exponential backoff with deterministic
    /// per-worker jitter, so a fleet restarting together fans out instead
    /// of thundering-herding the leader on every retry tick.
    pub fn connect_retry(
        addr: &str,
        worker_id: u32,
        attempts: u32,
        base: Duration,
        cap: Duration,
    ) -> Result<Self> {
        let mut attempt = 0u32;
        loop {
            match Self::connect(addr) {
                Ok(t) => return Ok(t),
                Err(e) => {
                    if attempt >= attempts {
                        return Err(TransportErrorKind::Closed
                            .err(format!("connect to {addr} failed after {attempts} retries: {e}")));
                    }
                    std::thread::sleep(backoff_delay(worker_id, attempt, base, cap));
                    attempt += 1;
                }
            }
        }
    }

    /// Configure I/O timeouts: `read` bounds every blocking [`Transport::recv`]
    /// (a peer silent for longer is reported as an error); `write` bounds
    /// sends at the socket level. `None` = block forever (lockstep).
    pub fn set_timeouts(&mut self, read: Option<Duration>, write: Option<Duration>) -> Result<()> {
        self.read_timeout = read;
        self.stream.set_write_timeout(write)?;
        Ok(())
    }

    /// Decode one frame from the reassembly buffer if complete.
    fn try_decode(&mut self) -> Result<Option<Msg>> {
        if self.rbuf.len() < 5 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.rbuf[..4].try_into().unwrap()) as usize;
        if len > MAX_FRAME_BYTES {
            return Err(TransportErrorKind::Corrupt.err(format!("oversized frame: {len} bytes")));
        }
        if self.rbuf.len() < 5 + len {
            return Ok(None);
        }
        let msg = Msg::decode(self.rbuf[4], &self.rbuf[5..5 + len])
            .map_err(|e| TransportErrorKind::Corrupt.err(e))?;
        self.rbuf.drain(..5 + len);
        Ok(Some(msg))
    }

    /// Pull more bytes into the buffer, waiting at most `wait` (`None` =
    /// block). Returns false on timeout, errors on EOF / socket failure.
    fn fill(&mut self, wait: Option<Duration>) -> Result<bool> {
        self.stream.set_read_timeout(wait)?;
        let mut tmp = [0u8; 4096];
        match self.stream.read(&mut tmp) {
            Ok(0) => Err(TransportErrorKind::Closed.err("connection closed by peer")),
            Ok(n) => {
                self.rbuf.extend_from_slice(&tmp[..n]);
                Ok(true)
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Ok(false)
            }
            Err(e) => Err(TransportErrorKind::Closed.err(e)),
        }
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        self.send_frame(&msg.encode())
    }

    fn recv(&mut self) -> Result<Msg> {
        match self.read_timeout {
            Some(d) => match self.recv_timeout(d)? {
                Some(m) => Ok(m),
                None => Err(TransportErrorKind::Timeout
                    .err(format!("recv timed out after {d:?} (peer unresponsive)"))),
            },
            None => loop {
                if let Some(msg) = self.try_decode()? {
                    return Ok(msg);
                }
                self.fill(None)?;
            },
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(msg) = self.try_decode()? {
                return Ok(Some(msg));
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            if !self.fill(Some(deadline - now))? {
                return Ok(None);
            }
        }
    }

    fn send_frame(&mut self, frame: &[u8]) -> Result<()> {
        self.stream.write_all(frame).map_err(|e| TransportErrorKind::Closed.err(e))
    }
}

/// splitmix64 — the same mixer the coordinator's seed schedule uses; kept
/// private here so `net` stays independent of `coordinator`.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Reconnect backoff schedule: `min(base * 2^attempt, cap)` plus a
/// deterministic jitter in `[0, base)` mixed from `(worker_id, attempt)`.
/// Pure function — the same worker retries on the same schedule every run
/// (reproducible tests), different workers spread across the base window
/// (no thundering herd).
pub fn backoff_delay(worker_id: u32, attempt: u32, base: Duration, cap: Duration) -> Duration {
    let exp = base
        .saturating_mul(1u32.checked_shl(attempt.min(16)).unwrap_or(u32::MAX))
        .min(cap);
    let base_nanos = base.as_nanos().max(1);
    let h = mix64(((worker_id as u64) << 32) | attempt as u64);
    let jitter_nanos = (h as u128 % base_nanos) as u64;
    exp + Duration::from_nanos(jitter_nanos)
}

// ---------------------------------------------------------------------------
// In-process channel transport (deterministic tests)
// ---------------------------------------------------------------------------

/// In-memory duplex transport over mpsc channels of encoded frames: real
/// `recv_timeout` semantics without sockets, so cluster fault-handling
/// tests stay deterministic and sandbox-friendly.
pub struct ChannelTransport {
    tx: std::sync::mpsc::Sender<Vec<u8>>,
    rx: std::sync::mpsc::Receiver<Vec<u8>>,
}

/// Create a connected pair of in-memory transports.
pub fn channel_pair() -> (ChannelTransport, ChannelTransport) {
    let (atx, brx) = std::sync::mpsc::channel();
    let (btx, arx) = std::sync::mpsc::channel();
    (ChannelTransport { tx: atx, rx: arx }, ChannelTransport { tx: btx, rx: brx })
}

fn decode_frame(frame: &[u8]) -> Result<Msg> {
    if frame.len() < 5 {
        return Err(TransportErrorKind::Corrupt.err(format!("short frame: {} bytes", frame.len())));
    }
    let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
    if len != frame.len() - 5 {
        return Err(TransportErrorKind::Corrupt
            .err(format!("frame header claims {len} B payload, carries {}", frame.len() - 5)));
    }
    Msg::decode(frame[4], &frame[5..]).map_err(|e| TransportErrorKind::Corrupt.err(e))
}

impl Transport for ChannelTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        self.send_frame(&msg.encode())
    }

    fn recv(&mut self) -> Result<Msg> {
        match self.rx.recv() {
            Ok(frame) => decode_frame(&frame),
            Err(_) => Err(TransportErrorKind::Closed.err("connection closed by peer")),
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>> {
        use std::sync::mpsc::RecvTimeoutError;
        match self.rx.recv_timeout(timeout) {
            Ok(frame) => decode_frame(&frame).map(Some),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(TransportErrorKind::Closed.err("connection closed by peer"))
            }
        }
    }

    fn send_frame(&mut self, frame: &[u8]) -> Result<()> {
        self.tx
            .send(frame.to_vec())
            .map_err(|_| TransportErrorKind::Closed.err("connection closed by peer"))
    }
}

// ---------------------------------------------------------------------------
// Scripted fault injection
// ---------------------------------------------------------------------------

/// One scripted fault, keyed by the 0-based index of the send/recv call it
/// fires at (each direction counts its own calls).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// sleep before performing the nth send (straggler simulation: a
    /// delayed `Proj` makes the leader's timeout fire while the message is
    /// still in flight)
    DelaySend { at: u64, by: Duration },
    /// sleep before performing the nth recv
    DelayRecv { at: u64, by: Duration },
    /// fail the nth and all later sends (killed socket)
    KillAtSend { at: u64 },
    /// fail the nth and all later recvs
    KillAtRecv { at: u64 },
    /// flip a byte inside the nth sent frame: framing survives, contents
    /// don't — the receiver observes a `Corrupt`-classified decode failure
    CorruptAtSend { at: u64 },
    /// ship only the first half of the nth frame, then kill the
    /// connection — a torn write on the wire
    TruncateAtSend { at: u64 },
    /// deliver the nth received message after its successor (adjacent
    /// swap — models a reordering middlebox / retry race)
    ReorderRecv { at: u64 },
}

/// Fault-injection wrapper: applies a script of [`Fault`]s around any
/// transport. Once a kill fires the transport stays dead, like a closed
/// socket; every fault-originated error is classified
/// [`TransportErrorKind::FaultInjected`]. The harness behind the recovery
/// and chaos suites.
pub struct FaultTransport {
    inner: Box<dyn Transport>,
    faults: Vec<Fault>,
    sends: u64,
    recvs: u64,
    dead: bool,
    /// held-back messages from an in-flight `ReorderRecv` swap
    pending: std::collections::VecDeque<Msg>,
}

impl FaultTransport {
    pub fn new(inner: Box<dyn Transport>, faults: Vec<Fault>) -> Self {
        FaultTransport {
            inner,
            faults,
            sends: 0,
            recvs: 0,
            dead: false,
            pending: std::collections::VecDeque::new(),
        }
    }

    fn dead_err(&self) -> Error {
        TransportErrorKind::FaultInjected.err("connection killed")
    }

    /// Count a recv call, apply delay/kill faults, and report whether this
    /// call is the pivot of a `ReorderRecv` swap.
    fn check_recv(&mut self) -> Result<bool> {
        if self.dead {
            return Err(self.dead_err());
        }
        let n = self.recvs;
        self.recvs += 1;
        let mut reorder = false;
        for f in &self.faults {
            match *f {
                Fault::DelayRecv { at, by } if at == n => std::thread::sleep(by),
                Fault::KillAtRecv { at } if at <= n => {
                    self.dead = true;
                    return Err(TransportErrorKind::FaultInjected
                        .err(format!("connection killed at recv #{n}")));
                }
                Fault::ReorderRecv { at } if at == n => reorder = true,
                _ => {}
            }
        }
        Ok(reorder)
    }

    /// On a reorder pivot: hold `first` back and deliver its successor, if
    /// one arrives promptly. If nothing follows, the swap degrades to
    /// in-order delivery rather than stalling the caller.
    fn swap_with_successor(&mut self, first: Msg) -> Result<Msg> {
        match self.inner.recv_timeout(Duration::from_millis(100)) {
            Ok(Some(second)) => {
                self.pending.push_back(first);
                Ok(second)
            }
            _ => Ok(first),
        }
    }
}

impl Transport for FaultTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        if self.dead {
            return Err(self.dead_err());
        }
        let n = self.sends;
        self.sends += 1;
        let (mut corrupt, mut truncate) = (false, false);
        for f in &self.faults {
            match *f {
                Fault::DelaySend { at, by } if at == n => std::thread::sleep(by),
                Fault::KillAtSend { at } if at <= n => {
                    self.dead = true;
                    return Err(TransportErrorKind::FaultInjected
                        .err(format!("connection killed at send #{n}")));
                }
                Fault::CorruptAtSend { at } if at == n => corrupt = true,
                Fault::TruncateAtSend { at } if at == n => truncate = true,
                _ => {}
            }
        }
        if truncate {
            let frame = msg.encode();
            let _ = self.inner.send_frame(&frame[..frame.len() / 2]);
            self.dead = true;
            return Err(TransportErrorKind::FaultInjected
                .err(format!("frame truncated at send #{n}, connection killed")));
        }
        if corrupt {
            let mut frame = msg.encode();
            // flip the tag byte's high bit: the length prefix stays honest
            // so the receiver reads a whole frame and then fails decode
            // with an unknown tag. (A payload flip would be silent — the
            // fixed-width messages carry no per-frame checksum; on real
            // links TCP's checksum covers that, and the divergence
            // tripwire catches anything that slips through.)
            frame[4] ^= 0x80;
            return self.inner.send_frame(&frame);
        }
        self.inner.send(msg)
    }

    fn recv(&mut self) -> Result<Msg> {
        if let Some(m) = self.pending.pop_front() {
            return Ok(m);
        }
        let reorder = self.check_recv()?;
        let first = self.inner.recv()?;
        if reorder {
            return self.swap_with_successor(first);
        }
        Ok(first)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>> {
        if let Some(m) = self.pending.pop_front() {
            return Ok(Some(m));
        }
        let reorder = self.check_recv()?;
        match self.inner.recv_timeout(timeout)? {
            Some(first) if reorder => self.swap_with_successor(first).map(Some),
            other => Ok(other),
        }
    }
}

// ---------------------------------------------------------------------------
// Seeded chaos planning
// ---------------------------------------------------------------------------

/// Expands a seed into per-worker fault scripts: the deterministic input to
/// the chaos suite (`rust/tests/chaos.rs`). The same `(seed, worker_id)`
/// always yields the same script, so a failing storm is replayable from its
/// seed alone.
#[derive(Clone, Copy, Debug)]
pub struct ChaosPlan {
    seed: u64,
}

impl ChaosPlan {
    pub fn new(seed: u64) -> Self {
        ChaosPlan { seed }
    }

    fn draw(&self, worker_id: u32, salt: u64) -> u64 {
        mix64(self.seed ^ mix64(((worker_id as u64) << 32) ^ salt))
    }

    /// Script for one worker's connection: 0–2 faults with call indices
    /// drawn from `[0, horizon)`. `lethal` gates the kinds that may
    /// legitimately end the run (kill/corrupt/truncate/reorder) — with it
    /// off the script is pure delays, faults a run must absorb while
    /// staying bit-identical.
    pub fn faults_for(&self, worker_id: u32, horizon: u64, lethal: bool) -> Vec<Fault> {
        let horizon = horizon.max(1);
        let n = self.draw(worker_id, 0) % 3;
        let mut out = Vec::new();
        for k in 0..n {
            let at = self.draw(worker_id, 2 * k + 1) % horizon;
            let kind = self.draw(worker_id, 2 * k + 2) % if lethal { 6 } else { 2 };
            out.push(match kind {
                0 => Fault::DelaySend { at, by: Duration::from_millis(1 + at % 20) },
                1 => Fault::DelayRecv { at, by: Duration::from_millis(1 + at % 20) },
                2 => Fault::CorruptAtSend { at },
                3 => Fault::TruncateAtSend { at },
                4 => Fault::KillAtSend { at },
                _ => Fault::ReorderRecv { at },
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn roundtrip(m: Msg) {
        let enc = m.encode();
        let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
        assert_eq!(len + 5, enc.len());
        let dec = Msg::decode(enc[4], &enc[5..]).unwrap();
        assert_eq!(dec, m);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Hello { proto: PROTO_VERSION, worker_id: 3, t: 17 });
        roundtrip(Msg::Welcome {
            proto: PROTO_VERSION,
            n_workers: 4,
            run_seed: 0xDEADBEEF,
            t: 9,
            params_hash: 0xABCDEF,
        });
        roundtrip(Msg::Step { t: 17, seed: 42, theta: 1.35, beta: 0.99, eta: 1e-6, lam: 1e-3 });
        roundtrip(Msg::Proj { t: 17, worker_id: 1, loss_plus: 0.5, loss_minus: 0.25 });
        roundtrip(Msg::Apply { t: 17, g: -1.5 });
        roundtrip(Msg::Eval { t: 100 });
        roundtrip(Msg::EvalResult { t: 100, worker_id: 2, correct: 80, total: 100 });
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::Replay {
            from_t: 5,
            records: vec![
                StepRecord { seed: 1, g: -0.25, theta: 1.35, eta: 1e-3, beta: 0.9 },
                StepRecord { seed: 2, g: 0.5, theta: 1.35, eta: 1e-3, beta: 0.99 },
            ],
        });
        roundtrip(Msg::Ready { t: 7, worker_id: 2, params_hash: 0x1234 });
        roundtrip(Msg::HashCheck { t: 50 });
        roundtrip(Msg::HashReport { t: 50, worker_id: 0, hash: 0x5678 });
        roundtrip(Msg::Heartbeat { t: 51 });
    }

    #[test]
    fn step_message_is_o1_bytes() {
        // the whole point: per-step wire traffic independent of d
        let m = Msg::Step { t: 0, seed: 0, theta: 0.0, beta: 0.0, eta: 0.0, lam: 0.0 };
        assert!(m.wire_bytes() < 64, "{}", m.wire_bytes());
        let p = Msg::Proj { t: 0, worker_id: 0, loss_plus: 0.0, loss_minus: 0.0 };
        assert!(p.wire_bytes() < 64);
    }

    #[test]
    fn steady_state_frame_sizes_pinned() {
        // the sizes the leader-side accounting and the README table quote;
        // Proj is 33 B (5-byte len|tag header + 28-byte payload) — the old
        // hardcoded 29 in run_leader undercounted by 4 B per recv
        assert_eq!(Msg::Step { t: 0, seed: 0, theta: 0.0, beta: 0.0, eta: 0.0, lam: 0.0 }.wire_bytes(), 37);
        assert_eq!(Msg::Proj { t: 0, worker_id: 0, loss_plus: 0.0, loss_minus: 0.0 }.wire_bytes(), 33);
        assert_eq!(Msg::Apply { t: 0, g: 0.0 }.wire_bytes(), 21);
        assert_eq!(Msg::Hello { proto: 2, worker_id: 0, t: 0 }.wire_bytes(), 18);
        assert_eq!(
            Msg::Welcome { proto: 2, n_workers: 0, run_seed: 0, t: 0, params_hash: 0 }.wire_bytes(),
            34
        );
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(Msg::decode(99, &[]).is_err());
        assert!(Msg::decode(3, &[0u8; 4]).is_err()); // truncated Step
    }

    #[test]
    fn crafted_replay_count_errors_without_allocating() {
        // payload: from_t + count=u32::MAX but no records — must error
        // cleanly (no OOM, no wrapped-length panic)
        let mut p = Vec::new();
        p.extend(0u64.to_le_bytes());
        p.extend(u32::MAX.to_le_bytes());
        let err = Msg::decode(9, &p).unwrap_err().to_string();
        assert!(err.contains("Replay"), "{err}");
        // count that disagrees with the payload length is also rejected
        let mut p = Vec::new();
        p.extend(0u64.to_le_bytes());
        p.extend(2u32.to_le_bytes());
        p.extend([0u8; STEP_RECORD_BYTES]); // only one record present
        assert!(Msg::decode(9, &p).is_err());
    }

    #[test]
    fn tcp_roundtrip_over_localhost() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(s).unwrap();
            let m = t.recv().unwrap();
            assert_eq!(m, Msg::Hello { proto: PROTO_VERSION, worker_id: 7, t: 0 });
            t.send(&Msg::Welcome {
                proto: PROTO_VERSION,
                n_workers: 1,
                run_seed: 5,
                t: 0,
                params_hash: 0,
            })
            .unwrap();
            let m = t.recv().unwrap();
            assert!(matches!(m, Msg::Proj { worker_id: 7, .. }));
            t.send(&Msg::Shutdown).unwrap();
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        c.send(&Msg::Hello { proto: PROTO_VERSION, worker_id: 7, t: 0 }).unwrap();
        assert_eq!(
            c.recv().unwrap(),
            Msg::Welcome { proto: PROTO_VERSION, n_workers: 1, run_seed: 5, t: 0, params_hash: 0 }
        );
        c.send(&Msg::Proj { t: 0, worker_id: 7, loss_plus: 1.0, loss_minus: 2.0 }).unwrap();
        assert_eq!(c.recv().unwrap(), Msg::Shutdown);
        h.join().unwrap();
    }

    #[test]
    fn tcp_recv_timeout_preserves_partial_frames() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let frame = Msg::Apply { t: 3, g: 1.5 }.encode();
            // dribble the frame: 3 header bytes, pause, then the rest
            s.write_all(&frame[..3]).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(120));
            s.write_all(&frame[3..]).unwrap();
            s.flush().unwrap();
            // hold the socket open until the client is done
            std::thread::sleep(Duration::from_millis(300));
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        // nothing yet: a short timeout must report None, not an error
        assert!(c.recv_timeout(Duration::from_millis(10)).unwrap().is_none());
        // the partial header may arrive during this window; still incomplete
        assert!(c.recv_timeout(Duration::from_millis(30)).unwrap().is_none());
        // once the rest lands the SAME frame decodes — no bytes were lost
        let got = c.recv_timeout(Duration::from_millis(500)).unwrap();
        assert_eq!(got, Some(Msg::Apply { t: 3, g: 1.5 }));
        h.join().unwrap();
    }

    #[test]
    fn channel_pair_roundtrip_and_timeout() {
        let (mut a, mut b) = channel_pair();
        a.send(&Msg::Heartbeat { t: 1 }).unwrap();
        assert_eq!(b.recv().unwrap(), Msg::Heartbeat { t: 1 });
        assert!(b.recv_timeout(Duration::from_millis(5)).unwrap().is_none());
        drop(a);
        assert!(b.recv().is_err()); // disconnected peer is an error
    }

    #[test]
    fn fault_transport_kills_and_stays_dead() {
        let (a, mut b) = channel_pair();
        let mut f = FaultTransport::new(Box::new(a), vec![Fault::KillAtSend { at: 1 }]);
        f.send(&Msg::Heartbeat { t: 0 }).unwrap();
        assert_eq!(b.recv().unwrap(), Msg::Heartbeat { t: 0 });
        assert!(f.send(&Msg::Heartbeat { t: 1 }).is_err());
        assert!(f.send(&Msg::Heartbeat { t: 2 }).is_err()); // still dead
        assert!(f.recv().is_err()); // both directions die together
    }

    #[test]
    fn fault_transport_delays_send() {
        let (a, mut b) = channel_pair();
        let mut f = FaultTransport::new(
            Box::new(a),
            vec![Fault::DelaySend { at: 0, by: Duration::from_millis(60) }],
        );
        let t0 = Instant::now();
        f.send(&Msg::Heartbeat { t: 0 }).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(50));
        assert_eq!(b.recv().unwrap(), Msg::Heartbeat { t: 0 });
    }

    #[test]
    fn transport_errors_classify() {
        // every failure produced by the transport layer carries its kind
        let (a, mut b) = channel_pair();
        drop(a);
        let e = b.recv().unwrap_err();
        assert_eq!(TransportErrorKind::classify(&e), Some(TransportErrorKind::Closed));

        let (a2, mut b2) = channel_pair();
        let mut f = FaultTransport::new(Box::new(a2), vec![Fault::KillAtSend { at: 0 }]);
        let e = f.send(&Msg::Heartbeat { t: 0 }).unwrap_err();
        assert_eq!(TransportErrorKind::classify(&e), Some(TransportErrorKind::FaultInjected));
        assert!(b2.recv_timeout(Duration::from_millis(5)).unwrap().is_none());

        // prose that merely mentions faults does NOT classify: the token,
        // not the wording, is the contract
        let bland = crate::anyhow!("loss exploded during fault injection drill, hash mismatch");
        assert_eq!(TransportErrorKind::classify(&bland), None);
    }

    #[test]
    fn corrupt_at_send_yields_classified_corrupt_recv() {
        let (a, mut b) = channel_pair();
        let mut f = FaultTransport::new(Box::new(a), vec![Fault::CorruptAtSend { at: 1 }]);
        f.send(&Msg::Apply { t: 0, g: 1.0 }).unwrap();
        assert_eq!(b.recv().unwrap(), Msg::Apply { t: 0, g: 1.0 });
        // the corrupted frame still ships (sender is oblivious)...
        f.send(&Msg::Apply { t: 1, g: 2.0 }).unwrap();
        // ...and the receiver classifies the damage
        let e = b.recv().unwrap_err();
        assert_eq!(TransportErrorKind::classify(&e), Some(TransportErrorKind::Corrupt));
    }

    #[test]
    fn truncate_at_send_kills_and_corrupts() {
        let (a, mut b) = channel_pair();
        let mut f = FaultTransport::new(Box::new(a), vec![Fault::TruncateAtSend { at: 0 }]);
        let e = f.send(&Msg::Apply { t: 0, g: 1.0 }).unwrap_err();
        assert_eq!(TransportErrorKind::classify(&e), Some(TransportErrorKind::FaultInjected));
        // the torn half-frame reaches the peer as classified corruption
        let e = b.recv().unwrap_err();
        assert_eq!(TransportErrorKind::classify(&e), Some(TransportErrorKind::Corrupt));
        // and the faulted side stays dead
        let e = f.send(&Msg::Heartbeat { t: 1 }).unwrap_err();
        assert_eq!(TransportErrorKind::classify(&e), Some(TransportErrorKind::FaultInjected));
    }

    #[test]
    fn reorder_recv_swaps_adjacent_messages() {
        let (mut a, b) = channel_pair();
        let mut f = FaultTransport::new(Box::new(b), vec![Fault::ReorderRecv { at: 1 }]);
        a.send(&Msg::Heartbeat { t: 0 }).unwrap();
        a.send(&Msg::Heartbeat { t: 1 }).unwrap();
        a.send(&Msg::Heartbeat { t: 2 }).unwrap();
        a.send(&Msg::Heartbeat { t: 3 }).unwrap();
        let got: Vec<Msg> = (0..4).map(|_| f.recv().unwrap()).collect();
        assert_eq!(
            got,
            vec![
                Msg::Heartbeat { t: 0 },
                Msg::Heartbeat { t: 2 }, // swapped pair
                Msg::Heartbeat { t: 1 },
                Msg::Heartbeat { t: 3 },
            ]
        );
    }

    #[test]
    fn reorder_with_no_successor_degrades_to_in_order() {
        let (mut a, b) = channel_pair();
        let mut f = FaultTransport::new(Box::new(b), vec![Fault::ReorderRecv { at: 0 }]);
        a.send(&Msg::Heartbeat { t: 0 }).unwrap();
        assert_eq!(f.recv().unwrap(), Msg::Heartbeat { t: 0 });
    }

    #[test]
    fn backoff_schedule_pinned() {
        let base = Duration::from_millis(50);
        let cap = Duration::from_secs(2);
        let sched: Vec<Duration> = (0..8).map(|a| backoff_delay(7, a, base, cap)).collect();
        for (a, d) in sched.iter().enumerate() {
            // deterministic: the same (worker, attempt) always re-derives
            // the exact same delay
            assert_eq!(*d, backoff_delay(7, a as u32, base, cap), "attempt {a}");
            // exponential component: base * 2^a, capped
            let exp = std::cmp::min(base * 2u32.saturating_pow(a as u32), cap);
            assert!(*d >= exp, "attempt {a}: {d:?} < {exp:?}");
            // jitter strictly bounded by one base interval
            assert!(*d < exp + base, "attempt {a}: jitter escaped [0, base)");
        }
        // doubling up to the cap
        assert!(sched[1] >= sched[0] && sched[1] >= base * 2);
        assert!(backoff_delay(7, 20, base, cap) < cap + base, "cap holds for huge attempts");
        // different workers land on different offsets within the window
        // (this is the anti-thundering-herd property)
        let spread: std::collections::HashSet<Duration> =
            (0..16).map(|w| backoff_delay(w, 0, base, cap)).collect();
        assert!(spread.len() > 8, "jitter failed to spread 16 workers: {}", spread.len());
    }

    #[test]
    fn chaos_plan_is_deterministic_and_gated() {
        let plan = ChaosPlan::new(0xC4A0_5EED);
        for w in 0..8u32 {
            assert_eq!(plan.faults_for(w, 64, true), plan.faults_for(w, 64, true));
            for f in plan.faults_for(w, 64, false) {
                assert!(
                    matches!(f, Fault::DelaySend { .. } | Fault::DelayRecv { .. }),
                    "non-lethal plan produced {f:?}"
                );
            }
        }
        // different seeds produce different storms (overwhelmingly likely
        // across 32 workers; equality would mean the seed is ignored)
        let other = ChaosPlan::new(1);
        let a: Vec<_> = (0..32).map(|w| plan.faults_for(w, 64, true)).collect();
        let b: Vec<_> = (0..32).map(|w| other.faults_for(w, 64, true)).collect();
        assert_ne!(a, b);
    }
}
